"""Fault-tolerant, mesh-agnostic checkpointing.

Design (DESIGN.md §3 large-scale runnability):
  * arrays saved as logical (unsharded) .npy files + a JSON manifest holding
    the pytree structure, dtypes, and per-file checksums;
  * writes go to ``step_K.tmp`` then an atomic ``os.rename`` — a crash
    mid-save never corrupts the latest checkpoint;
  * restore re-shards onto *any* mesh via device_put with target shardings
    (elastic scaling: a 256-chip checkpoint restores on 8 chips and back);
  * async mode hands the (host-copied) arrays to a writer thread so the
    train loop keeps stepping — writer errors are captured and re-raised
    from ``wait()``/the next ``save()``, never swallowed;
  * ``keep_last`` garbage-collects old steps.

Recovery (docs/robustness.md): a checksum mismatch, truncated leaf, or
dtype/shape drift raises :class:`CheckpointCorruptionError`; callers
(``Index.load``) quarantine the bad step (``step_K.quarantined`` — never
listed, never restored) and fall back to the previous intact step. Stale
``step_K.tmp`` dirs left by a killed writer are reaped on startup via
:func:`reap_tmp`.

Manifests digest with sha256; pre-existing md5 manifests verify through a
back-compat read path (the digest key names the algorithm).

On a multi-host pod each host writes its addressable shards; here (single
process) logical arrays are written whole — the manifest format is the same.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np

_DIGEST_CHUNK = 1 << 20        # stream checksums in 1 MB chunks


class CheckpointCorruptionError(AssertionError):
    """A checkpoint failed integrity verification (checksum mismatch,
    truncated leaf, or shape/dtype drift). Subclasses AssertionError for
    back-compat with callers that caught the old bare asserts."""


def _leaf_name(i: int) -> str:
    return f"leaf_{i:05d}.npy"


def _file_digest(path: str, algo: str = "sha256") -> str:
    """Streaming file digest — constant memory regardless of leaf size."""
    h = hashlib.new(algo)
    with open(path, "rb") as f:
        while chunk := f.read(_DIGEST_CHUNK):
            h.update(chunk)
    return h.hexdigest()


# public alias: non-leaf checkpoint payloads (e.g. the disk backend's slab
# files, Index.save) checksum through the same streaming digest
file_digest = _file_digest


def _tree_paths(tree) -> list:
    paths = []
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        paths.append(jax.tree_util.keystr(path))
    return paths


class _AsyncWriter(threading.Thread):
    """Writer thread that captures its exception instead of dying silently
    (a daemon thread's traceback otherwise vanishes with the step)."""

    def __init__(self, fn):
        super().__init__(daemon=True)
        self._fn = fn
        self.exc: Optional[BaseException] = None

    def run(self):
        try:
            self._fn()
        except BaseException as e:     # noqa: BLE001 — re-raised in wait()
            self.exc = e


def save(ckpt_dir: str, step: int, tree: Any, async_write: bool = False,
         keep_last: int = 3, injector=None) -> Optional[_AsyncWriter]:
    """Save a pytree checkpoint. Returns the writer thread if async.

    ``injector`` (a ``faults.FaultInjector``) makes leaf writes flaky for
    chaos testing: an injected fault truncates the leaf mid-write and
    raises IOError, leaving the ``step_K.tmp`` dir behind exactly like a
    crashed writer — the published checkpoint is untouched either way.
    """
    os.makedirs(ckpt_dir, exist_ok=True)
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    host_leaves = [np.asarray(jax.device_get(l)) for l in leaves]
    names = _tree_paths(tree)

    def _write():
        tmp = os.path.join(ckpt_dir, f"step_{step}.tmp")
        final = os.path.join(ckpt_dir, f"step_{step}")
        shutil.rmtree(tmp, ignore_errors=True)
        os.makedirs(tmp)
        manifest = {"step": step, "leaves": []}
        for i, (arr, name) in enumerate(zip(host_leaves, names)):
            fn = _leaf_name(i)
            fpath = os.path.join(tmp, fn)
            np.save(fpath, arr)
            if injector is not None and injector.ckpt_write_fails(step, i):
                with open(fpath, "r+b") as f:   # truncated mid-write
                    f.truncate(max(0, os.path.getsize(fpath) // 2))
                raise IOError(
                    f"injected write fault: step {step} leaf {i} ({name})")
            manifest["leaves"].append({
                "index": i, "path": name, "file": fn,
                "shape": list(arr.shape), "dtype": str(arr.dtype),
                "sha256": _file_digest(fpath)})
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        shutil.rmtree(final, ignore_errors=True)
        os.rename(tmp, final)                       # atomic publish
        _gc(ckpt_dir, keep_last)

    if async_write:
        th = _AsyncWriter(_write)
        th.start()
        return th
    _write()
    return None


def _gc(ckpt_dir: str, keep_last: int):
    steps = sorted(s for s in _list_steps(ckpt_dir))
    for s in steps[:-keep_last]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s}"),
                      ignore_errors=True)


def _list_steps(ckpt_dir: str) -> list:
    """Published, non-quarantined steps only: ``step_<int>`` exactly —
    ``step_K.tmp`` (in-flight/crashed writes) and ``step_K.quarantined``
    (failed verification) never list, so they are never restored."""
    out = []
    if not os.path.isdir(ckpt_dir):
        return out
    for name in os.listdir(ckpt_dir):
        if not name.startswith("step_"):
            continue
        suffix = name[len("step_"):]
        if suffix.isdigit():
            out.append(int(suffix))
    return out


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = _list_steps(ckpt_dir)
    return max(steps) if steps else None


def reap_tmp(ckpt_dir: str) -> list:
    """Delete stale ``step_K.tmp`` dirs left by killed/failed writers.

    Run at startup (``Index.load`` does) — an in-flight tmp dir is never
    valid across process restarts since publishes are atomic renames.
    Returns the reaped dir names."""
    reaped = []
    if not os.path.isdir(ckpt_dir):
        return reaped
    for name in sorted(os.listdir(ckpt_dir)):
        if name.startswith("step_") and name.endswith(".tmp"):
            shutil.rmtree(os.path.join(ckpt_dir, name), ignore_errors=True)
            reaped.append(name)
    return reaped


def quarantine(ckpt_dir: str, step: int) -> str:
    """Sideline a corrupted step as ``step_K.quarantined`` (kept on disk
    for forensics, excluded from listing/restore). Returns the new path."""
    src = os.path.join(ckpt_dir, f"step_{step}")
    dst = src + ".quarantined"
    shutil.rmtree(dst, ignore_errors=True)
    os.rename(src, dst)
    return dst


def _verify_leaf(path: str, meta: dict, leaf_path: str):
    """Integrity-check one leaf file against its manifest entry."""
    if not os.path.exists(path):
        raise CheckpointCorruptionError(f"{leaf_path}: leaf file missing")
    for algo in ("sha256", "md5"):      # md5: pre-sha256 manifests
        if algo in meta:
            if _file_digest(path, algo) != meta[algo]:
                raise CheckpointCorruptionError(
                    f"checksum mismatch for {leaf_path}")
            return
    raise CheckpointCorruptionError(f"{leaf_path}: manifest carries no "
                                    "digest")


def restore(ckpt_dir: str, step: int, target_tree: Any,
            shardings: Any = None, verify: bool = True) -> Any:
    """Restore into the structure of ``target_tree`` (arrays or
    ShapeDtypeStructs). ``shardings`` (same structure) re-shards elastically
    onto the current mesh. Integrity failures (missing/truncated leaf,
    checksum mismatch, shape or dtype drift) raise
    :class:`CheckpointCorruptionError`."""
    path = os.path.join(ckpt_dir, f"step_{step}")
    manifest_fn = os.path.join(path, "manifest.json")
    if not os.path.exists(manifest_fn):
        raise CheckpointCorruptionError(
            f"step {step}: manifest.json missing (truncated checkpoint?)")
    with open(manifest_fn) as f:
        manifest = json.load(f)
    leaves, treedef = jax.tree_util.tree_flatten(target_tree)
    if len(leaves) != len(manifest["leaves"]):
        raise CheckpointCorruptionError(
            f"checkpoint has {len(manifest['leaves'])} leaves, "
            f"target {len(leaves)}")
    shard_leaves = (treedef.flatten_up_to(shardings)
                    if shardings is not None else [None] * len(leaves))
    out = []
    for meta, tgt, shd in zip(manifest["leaves"], leaves, shard_leaves):
        fn = os.path.join(path, meta["file"])
        if verify:
            _verify_leaf(fn, meta, meta["path"])
        try:
            arr = np.load(fn)
        except Exception as e:          # unreadable/truncated npy payload
            raise CheckpointCorruptionError(
                f"{meta['path']}: unreadable leaf ({e})") from e
        if list(arr.shape) != list(tgt.shape):
            raise CheckpointCorruptionError(
                f"{meta['path']}: shape {arr.shape} vs target {tgt.shape}")
        if np.dtype(arr.dtype) != np.dtype(tgt.dtype):
            raise CheckpointCorruptionError(
                f"{meta['path']}: dtype {arr.dtype} vs target {tgt.dtype}")
        if shd is not None:
            out.append(jax.device_put(arr, shd))
        else:
            out.append(jax.device_put(arr))
    return treedef.unflatten(out)


class CheckpointManager:
    """Convenience wrapper with async save + resume.

    An async writer's exception is captured (``_AsyncWriter``) and
    re-raised from ``wait()`` — which the next ``save()`` calls first —
    so a failed background step surfaces at the next checkpoint
    interaction instead of vanishing with the daemon thread."""

    def __init__(self, ckpt_dir: str, keep_last: int = 3,
                 async_write: bool = True):
        self.ckpt_dir = ckpt_dir
        self.keep_last = keep_last
        self.async_write = async_write
        self._pending: Optional[_AsyncWriter] = None

    def save(self, step: int, tree: Any, injector=None):
        self.wait()
        self._pending = save(self.ckpt_dir, step, tree,
                             async_write=self.async_write,
                             keep_last=self.keep_last, injector=injector)

    def wait(self):
        """Join the in-flight writer; re-raise its error if it failed."""
        if self._pending is not None:
            th, self._pending = self._pending, None
            th.join()
            if th.exc is not None:
                raise th.exc

    def latest(self) -> Optional[int]:
        return latest_step(self.ckpt_dir)

    def restore(self, target_tree, shardings=None, step=None):
        step = step if step is not None else self.latest()
        assert step is not None, f"no checkpoint in {self.ckpt_dir}"
        return step, restore(self.ckpt_dir, step, target_tree, shardings)
