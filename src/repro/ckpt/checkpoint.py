"""Fault-tolerant, mesh-agnostic checkpointing.

Design (DESIGN.md §3 large-scale runnability):
  * arrays saved as logical (unsharded) .npy files + a JSON manifest holding
    the pytree structure, dtypes, and per-file checksums;
  * writes go to ``step_K.tmp`` then an atomic ``os.rename`` — a crash
    mid-save never corrupts the latest checkpoint;
  * restore re-shards onto *any* mesh via device_put with target shardings
    (elastic scaling: a 256-chip checkpoint restores on 8 chips and back);
  * async mode hands the (host-copied) arrays to a writer thread so the
    train loop keeps stepping;
  * ``keep_last`` garbage-collects old steps.

On a multi-host pod each host writes its addressable shards; here (single
process) logical arrays are written whole — the manifest format is the same.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np


def _leaf_name(i: int) -> str:
    return f"leaf_{i:05d}.npy"


def _tree_paths(tree) -> list:
    paths = []
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        paths.append(jax.tree_util.keystr(path))
    return paths


def save(ckpt_dir: str, step: int, tree: Any, async_write: bool = False,
         keep_last: int = 3) -> Optional[threading.Thread]:
    """Save a pytree checkpoint. Returns the writer thread if async."""
    os.makedirs(ckpt_dir, exist_ok=True)
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    host_leaves = [np.asarray(jax.device_get(l)) for l in leaves]
    names = _tree_paths(tree)

    def _write():
        tmp = os.path.join(ckpt_dir, f"step_{step}.tmp")
        final = os.path.join(ckpt_dir, f"step_{step}")
        shutil.rmtree(tmp, ignore_errors=True)
        os.makedirs(tmp)
        manifest = {"step": step, "leaves": []}
        for i, (arr, name) in enumerate(zip(host_leaves, names)):
            fn = _leaf_name(i)
            np.save(os.path.join(tmp, fn), arr)
            with open(os.path.join(tmp, fn), "rb") as f:
                digest = hashlib.md5(f.read()).hexdigest()
            manifest["leaves"].append({
                "index": i, "path": name, "file": fn,
                "shape": list(arr.shape), "dtype": str(arr.dtype),
                "md5": digest})
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        shutil.rmtree(final, ignore_errors=True)
        os.rename(tmp, final)                       # atomic publish
        _gc(ckpt_dir, keep_last)

    if async_write:
        th = threading.Thread(target=_write, daemon=True)
        th.start()
        return th
    _write()
    return None


def _gc(ckpt_dir: str, keep_last: int):
    steps = sorted(s for s in _list_steps(ckpt_dir))
    for s in steps[:-keep_last]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s}"),
                      ignore_errors=True)


def _list_steps(ckpt_dir: str) -> list:
    out = []
    if not os.path.isdir(ckpt_dir):
        return out
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and not name.endswith(".tmp"):
            try:
                out.append(int(name.split("_")[1]))
            except ValueError:
                continue
    return out


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = _list_steps(ckpt_dir)
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, target_tree: Any,
            shardings: Any = None, verify: bool = True) -> Any:
    """Restore into the structure of ``target_tree`` (arrays or
    ShapeDtypeStructs). ``shardings`` (same structure) re-shards elastically
    onto the current mesh."""
    path = os.path.join(ckpt_dir, f"step_{step}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    leaves, treedef = jax.tree_util.tree_flatten(target_tree)
    assert len(leaves) == len(manifest["leaves"]), \
        f"checkpoint has {len(manifest['leaves'])} leaves, target {len(leaves)}"
    shard_leaves = (treedef.flatten_up_to(shardings)
                    if shardings is not None else [None] * len(leaves))
    out = []
    for meta, tgt, shd in zip(manifest["leaves"], leaves, shard_leaves):
        fn = os.path.join(path, meta["file"])
        if verify:
            with open(fn, "rb") as f:
                assert hashlib.md5(f.read()).hexdigest() == meta["md5"], \
                    f"checksum mismatch for {meta['path']}"
        arr = np.load(fn)
        assert list(arr.shape) == list(tgt.shape), \
            f"{meta['path']}: shape {arr.shape} vs target {tgt.shape}"
        if shd is not None:
            out.append(jax.device_put(arr, shd))
        else:
            out.append(jax.device_put(arr))
    return treedef.unflatten(out)


class CheckpointManager:
    """Convenience wrapper with async save + resume."""

    def __init__(self, ckpt_dir: str, keep_last: int = 3,
                 async_write: bool = True):
        self.ckpt_dir = ckpt_dir
        self.keep_last = keep_last
        self.async_write = async_write
        self._pending: Optional[threading.Thread] = None

    def save(self, step: int, tree: Any):
        self.wait()
        self._pending = save(self.ckpt_dir, step, tree,
                             async_write=self.async_write,
                             keep_last=self.keep_last)

    def wait(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def latest(self) -> Optional[int]:
        return latest_step(self.ckpt_dir)

    def restore(self, target_tree, shardings=None, step=None):
        step = step if step is not None else self.latest()
        assert step is not None, f"no checkpoint in {self.ckpt_dir}"
        return step, restore(self.ckpt_dir, step, target_tree, shardings)
