from repro.ckpt.checkpoint import (CheckpointManager, latest_step, restore,
                                   save)
