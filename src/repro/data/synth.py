"""Synthetic filtered-ANNS datasets mirroring the paper's workload shapes.

Vectors: Gaussian mixture (clustered, like real embeddings).
Labels:  Zipf-distributed categorical labels (YFCC/LAION-style head/tail).
Values:  lognormal numeric attribute (LAION image-width analogue).

Workload generators produce (query vector, Selector) pairs for the paper's
five workloads: Label, LabelAnd, LabelOr, Range, Hybrid.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.selectors import (AndSelector, LabelAndSelector,
                                  LabelOrSelector, OrSelector, RangeSelector,
                                  Selector)


@dataclasses.dataclass
class SynthFilteredDataset:
    vectors: np.ndarray          # (N, D) float32
    label_offsets: np.ndarray    # (N+1,) int64
    label_flat: np.ndarray       # (nnz,) int32
    n_labels: int
    values: np.ndarray           # (N,) float32
    queries: np.ndarray          # (Q, D) float32
    query_labels: list           # per query: list[int]
    query_ranges: np.ndarray     # (Q, 2) float32

    def metadata(self, tag_field: str = "label",
                 num_field: str = "value") -> list[dict]:
        """Per-record metadata dicts for ``repro.api.Index.build``.

        NOTE: Index.build renumbers tags by first appearance — resolve
        query labels through ``index.label_id(tag_field, value)`` (as
        ``make_selectors`` does), never by raw dataset label id.
        """
        return [
            {tag_field: self.label_flat[s:e].tolist(), num_field: float(v)}
            for s, e, v in zip(self.label_offsets[:-1],
                               self.label_offsets[1:], self.values)
        ]


def make_filtered_dataset(n: int = 20000, d: int = 48, n_queries: int = 64,
                          n_labels: int = 200, avg_labels: float = 4.0,
                          n_clusters: int = 32, zipf_a: float = 1.3,
                          seed: int = 0) -> SynthFilteredDataset:
    rng = np.random.default_rng(seed)
    centers = rng.normal(0, 1.0, (n_clusters, d)).astype(np.float32)
    assign = rng.integers(0, n_clusters, n)
    vectors = (centers[assign]
               + rng.normal(0, 0.35, (n, d))).astype(np.float32)

    # Zipf labels: label popularity ~ 1/rank^a
    ranks = np.arange(1, n_labels + 1, dtype=np.float64)
    popularity = 1.0 / ranks ** zipf_a
    popularity /= popularity.sum()
    counts = rng.poisson(avg_labels, n).clip(1, 16)
    flat = []
    for c in counts:
        flat.append(rng.choice(n_labels, size=c, replace=True, p=popularity))
    label_flat = np.concatenate(flat).astype(np.int32)
    offsets = np.zeros(n + 1, np.int64)
    np.cumsum(counts, out=offsets[1:])

    values = rng.lognormal(6.0, 0.8, n).astype(np.float32)

    qassign = rng.integers(0, n_clusters, n_queries)
    queries = (centers[qassign]
               + rng.normal(0, 0.35, (n_queries, d))).astype(np.float32)
    # query labels drawn from the same popularity law (1-3 each)
    query_labels = []
    for _ in range(n_queries):
        qc = int(rng.integers(1, 4))
        query_labels.append(sorted(set(
            int(x) for x in rng.choice(n_labels, qc, replace=True,
                                       p=popularity))))
    # query ranges spanning selectivities from ~0.1% to ~50%
    q = np.sort(values)
    ranges = np.zeros((n_queries, 2), np.float32)
    for i in range(n_queries):
        frac = float(10 ** rng.uniform(-3, np.log10(0.5)))
        lo_idx = int(rng.uniform(0, max(1, (1 - frac))) * n)
        hi_idx = min(n - 1, lo_idx + max(1, int(frac * n)))
        ranges[i] = (q[lo_idx], q[hi_idx])
    return SynthFilteredDataset(vectors, offsets, label_flat, n_labels,
                                values, queries, query_labels, ranges)


def _resolve_labels(engine, labels, tag_field: str) -> tuple[list[int], bool]:
    """Map dataset label values to engine label ids.

    The ``repro.api`` Index renumbers tags by vocabulary first-appearance
    order, so dataset ids must go through ``engine.label_id``; raw
    engines use dataset ids verbatim. Returns (ids, any_unseen) — unseen
    labels (zero corpus occurrences) have no vocabulary entry and are
    dropped from the id list."""
    mapper = getattr(engine, "label_id", None)
    if mapper is None:
        return [int(l) for l in labels], False
    ids = [mapper(tag_field, int(l)) for l in labels]
    return [i for i in ids if i is not None], any(i is None for i in ids)


def make_selectors(ds: SynthFilteredDataset, engine, workload: str,
                   n_queries: int | None = None,
                   tag_field: str = "label") -> list[Selector]:
    """Build per-query Selector objects for one of the paper's workloads."""
    ls, rs = engine.label_store, engine.range_store
    nq = n_queries or ds.queries.shape[0]
    sels: list[Selector] = []
    for i in range(nq):
        labels = ds.query_labels[i]
        lo, hi = float(ds.query_ranges[i, 0]), float(ds.query_ranges[i, 1])
        if workload == "label":            # single label (paper Fig. 7)
            ids, _ = _resolve_labels(engine, labels[:1], tag_field)
            sels.append(LabelOrSelector(ls, ids))
        elif workload == "label_and":
            ids, unseen = _resolve_labels(engine, labels, tag_field)
            # AND with an unseen label matches nothing: empty-OR selector
            sels.append(LabelOrSelector(ls, []) if unseen
                        else LabelAndSelector(ls, ids))
        elif workload == "label_or":
            ids, _ = _resolve_labels(engine, labels, tag_field)
            sels.append(LabelOrSelector(ls, ids))
        elif workload == "range":
            sels.append(RangeSelector(rs, lo, hi))
        elif workload == "hybrid":         # LabelOr OR Range (paper §5.1)
            ids, _ = _resolve_labels(engine, labels, tag_field)
            sels.append(OrSelector([LabelOrSelector(ls, ids),
                                    RangeSelector(rs, lo, hi)]))
        elif workload == "label_and_range":
            ids, unseen = _resolve_labels(engine, labels[:2], tag_field)
            lab = LabelOrSelector(ls, []) if unseen \
                else LabelAndSelector(ls, ids)
            sels.append(AndSelector([lab, RangeSelector(rs, lo, hi)]))
        else:
            raise ValueError(workload)
    return sels


def make_sliding_range_selectors(engine, selectivity: float,
                                 n_queries: int, field: int = 0) -> list:
    """Per-query range filters of one controlled selectivity, sliding the
    window across the value distribution so queries don't share a filter
    — the mid-selectivity workload shape of the paper's Fig. 2 sweeps.
    Shared by benchmarks/bench_search.py and the search A/B parity suite
    (one definition, so both measure the same workload)."""
    values = np.sort(np.asarray(engine.range_store.field_store(field).values))
    n = values.size
    width = max(1, int(round(selectivity * n)))
    out = []
    for i in range(n_queries):
        lo_i = int((n - width) * (i / max(1, n_queries - 1)))
        lo = float(values[lo_i])
        hi = float(values[min(lo_i + width, n - 1)]) + 1e-3
        out.append(RangeSelector(engine.range_store, lo, hi, field=field))
    return out
