from repro.data.synth import SynthFilteredDataset, make_filtered_dataset
