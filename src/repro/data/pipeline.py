"""Host data pipeline: bounded prefetch queue + straggler watchdog.

The producer thread stays `prefetch` batches ahead of the training loop;
``skip_to`` implements resume-exact restart (batches are pure functions of
the step index — see data/tokens.py). The watchdog flags steps slower than
`watchdog_factor`× the running median — on a real cluster this feeds the
straggler-mitigation policy (re-dispatch / hot-spare); here it logs.
"""
from __future__ import annotations

import queue
import threading
import time
from typing import Callable, Iterator, Optional


class Prefetcher:
    def __init__(self, make_batch: Callable[[int], dict], start_step: int = 0,
                 prefetch: int = 2):
        self.make_batch = make_batch
        self.step = start_step
        self.q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._produce, daemon=True)
        self._thread.start()

    def _produce(self):
        s = self.step
        while not self._stop.is_set():
            try:
                self.q.put((s, self.make_batch(s)), timeout=0.2)
                s += 1
            except queue.Full:
                continue

    def __iter__(self) -> Iterator:
        while True:
            s, batch = self.q.get()
            yield s, batch

    def stop(self):
        self._stop.set()
        self._thread.join(timeout=2.0)


class StepWatchdog:
    """Detects straggling steps (slow I/O, slow device, bad host)."""

    def __init__(self, factor: float = 3.0, warmup: int = 5):
        self.factor = factor
        self.warmup = warmup
        self.times: list = []
        self.flagged: list = []
        self._t0: Optional[float] = None

    def start(self):
        self._t0 = time.perf_counter()

    def stop(self, step: int) -> bool:
        dt = time.perf_counter() - self._t0
        slow = False
        if len(self.times) >= self.warmup:
            med = sorted(self.times)[len(self.times) // 2]
            slow = dt > self.factor * med
            if slow:
                self.flagged.append((step, dt, med))
        self.times.append(dt)
        return slow
