"""Deterministic synthetic LM data: motif-repeat streams.

Each sequence tiles a random motif, so next-token prediction is learnable
(the model must copy with period `motif_len`) — the quickstart trains a
~100M model to visibly falling loss in a few hundred steps.

Batches are pure functions of (step, shard) — resume-exact data skipping
for fault tolerance: restarting at step K regenerates exactly batch K.
"""
from __future__ import annotations

import numpy as np

from repro.models.common import ModelConfig


def lm_batch(cfg: ModelConfig, batch: int, seq: int, step: int,
             shard: int = 0, n_shards: int = 1, motif_len: int = 32,
             pool_size: int = 16) -> dict:
    rng = np.random.default_rng(
        np.random.SeedSequence([step, shard, n_shards, 0xA5]))
    # motifs come from a small FIXED pool (independent of step) so the task
    # is memorizable within a few hundred steps; which motif each row gets
    # varies per step (still a pure function of (step, shard))
    pool_rng = np.random.default_rng(
        np.random.SeedSequence([shard, n_shards, 0x5EED]))
    pool = pool_rng.integers(0, cfg.vocab, (pool_size, motif_len),
                             dtype=np.int64)
    reps = -(-(seq + 1) // motif_len)
    motifs = pool[rng.integers(0, pool_size, batch)]
    stream = np.tile(motifs, (1, reps))[:, :seq + 1].astype(np.int32)
    out = {"tokens": stream[:, :-1], "targets": stream[:, 1:]}
    if cfg.frontend == "audio":
        # frame embedding stub: deterministic projection of the token id
        emb = _hash_embed(out["tokens"], cfg.d_model)
        out = {"frame_embeds": emb, "targets": out["targets"]}
    elif cfg.frontend == "vision":
        p = cfg.vision_prefix
        patches = rng.normal(0, 1, (batch, p, cfg.d_model)).astype(np.float32)
        out["patch_embeds"] = patches
    return out


def _hash_embed(tokens: np.ndarray, d: int) -> np.ndarray:
    """Cheap deterministic token -> embedding stub (audio frontend)."""
    t = tokens.astype(np.float32)[..., None]
    phase = np.arange(d, dtype=np.float32)[None, None, :]
    return (np.sin(t * 0.1 + phase * 0.7) * 0.5).astype(np.float32)
