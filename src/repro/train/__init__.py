from repro.train.optim import OptConfig, OptState, adamw_update, init_opt_state
from repro.train.train_loop import make_train_step, train_many
