"""Training step construction: microbatch gradient accumulation, mixed
precision, AdamW, metrics. Remat happens inside the model (scan bodies)."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import lm
from repro.models.common import ModelConfig
from repro.train import optim


def make_train_step(cfg: ModelConfig, ocfg: optim.OptConfig,
                    microbatches: int = 1, mesh=None, param_specs=None,
                    acc_dtype=jnp.float32):
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics).

    With microbatches > 1 the global batch is split along dim 0 and gradients
    are accumulated in a lax.scan (bounds activation memory; XLA overlaps the
    per-microbatch grad all-reduce with the next microbatch's compute)."""

    def loss_fn(params, batch):
        return lm.lm_loss(params, cfg, batch)

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(params, opt_state, batch):
        if microbatches == 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            def split(x):
                b = x.shape[0]
                assert b % microbatches == 0
                out = x.reshape(microbatches, b // microbatches, *x.shape[1:])
                # keep the per-microbatch batch dim sharded over DP — without
                # this XLA reshards the (μ, B/μ) reshape so each device sees
                # the full local batch per μ-step (verified on the dry-run)
                if mesh is not None and "data" in mesh.axis_names:
                    dp = tuple(a for a in ("pod", "data")
                               if a in mesh.axis_names)
                    spec = jax.sharding.PartitionSpec(
                        None, dp, *([None] * (out.ndim - 2)))
                    out = jax.lax.with_sharding_constraint(
                        out, jax.sharding.NamedSharding(mesh, spec))
                return out
            micro = jax.tree_util.tree_map(split, batch)

            def constrain(tree):
                # keep the grad-accumulator scan carry sharded like the
                # params — XLA otherwise settles the while-loop carry on
                # replicated (a ~TB-scale regression on MoE dry-runs)
                if mesh is None or param_specs is None:
                    return tree
                return jax.tree_util.tree_map(
                    lambda x, s: jax.lax.with_sharding_constraint(
                        x, jax.sharding.NamedSharding(mesh, s)),
                    tree, param_specs)

            def acc_step(carry, mb):
                g_acc, l_acc = carry
                (l, _), g = grad_fn(params, mb)
                g_acc = constrain(jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(acc_dtype), g_acc, g))
                return (g_acc, l_acc + l), None

            g0 = constrain(jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, acc_dtype), params))
            (grads, loss), _ = jax.lax.scan(acc_step, (g0, 0.0), micro)
            grads = jax.tree_util.tree_map(lambda g: g / microbatches, grads)
            loss = loss / microbatches
            metrics = {}

        new_params, new_opt, opt_metrics = optim.adamw_update(
            grads, params, opt_state, ocfg)
        out_metrics = {"loss": loss, **opt_metrics}
        if metrics:
            out_metrics.update({k: v for k, v in metrics.items()})
        return new_params, new_opt, out_metrics

    return train_step


def train_many(params, opt_state, train_step, batches):
    """Simple host loop used by tests/examples."""
    history = []
    step = jax.jit(train_step)
    for batch in batches:
        params, opt_state, metrics = step(params, opt_state, batch)
        history.append({k: float(v) for k, v in metrics.items()
                        if jnp.ndim(v) == 0})
    return params, opt_state, history
