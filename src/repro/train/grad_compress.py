"""Int8 error-feedback gradient all-reduce (distributed-optimization trick).

Quantize local gradients to int8 (blockwise absmax), psum the int8 payload
(as int32 accumulators to avoid overflow), dequantize, and keep the
quantization residual as local error feedback added to the next step's
gradient. Cuts DP all-reduce bytes 4× (f32) / 2× (bf16) at equal asymptotic
convergence (error feedback makes the bias vanish).

Expressed with shard_map over the data axis so the collective payload is
explicit and shows up in the dry-run's collective-bytes accounting.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

QBLOCK = 256


def _quantize(g):
    flat = g.reshape(-1).astype(jnp.float32)
    n = flat.shape[0]
    nb = -(-n // QBLOCK)
    flat = jnp.pad(flat, (0, nb * QBLOCK - n)).reshape(nb, QBLOCK)
    scale = jnp.max(jnp.abs(flat), axis=1, keepdims=True) / 127.0
    q = jnp.round(flat / jnp.maximum(scale, 1e-12)).astype(jnp.int8)
    return q, scale, n


def _dequantize(q, scale, n, shape):
    flat = (q.astype(jnp.float32) * scale).reshape(-1)[:n]
    return flat.reshape(shape)


def compressed_psum_grads(grads, error_fb, axis_name: str):
    """All-reduce a gradient pytree in int8 with error feedback.

    Must run inside shard_map/pmap over `axis_name`. Returns
    (mean_grads, new_error_fb)."""
    from repro.utils.compat import axis_size
    n_dev = axis_size(axis_name)

    def one(g, e):
        g_fb = g.astype(jnp.float32) + e
        flat = g_fb.reshape(-1)
        n = flat.shape[0]
        nb = -(-n // QBLOCK)
        blocks = jnp.pad(flat, (0, nb * QBLOCK - n)).reshape(nb, QBLOCK)
        # shared per-block scale across the axis -> int8 sum is exact
        local_max = jnp.max(jnp.abs(blocks), axis=1, keepdims=True)
        scale = jax.lax.pmax(local_max, axis_name) / 127.0
        q = jnp.round(blocks / jnp.maximum(scale, 1e-12)).astype(jnp.int8)
        new_e = (blocks - q.astype(jnp.float32) * scale).reshape(-1)[:n] \
            .reshape(g.shape)
        summed = jax.lax.psum(q.astype(jnp.int32), axis_name)
        deq = _dequantize(summed, scale, n, g.shape) / n_dev
        return deq.astype(g.dtype), new_e

    flat_g, td = jax.tree_util.tree_flatten(grads)
    flat_e = td.flatten_up_to(error_fb)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return td.unflatten([o[0] for o in out]), td.unflatten([o[1] for o in out])


def init_error_feedback(grads_like):
    return jax.tree_util.tree_map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads_like)
