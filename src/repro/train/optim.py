"""In-house AdamW with optional int8-quantized moments.

The int8 moment store (blockwise absmax quantization, 128-element blocks)
cuts optimizer-state bytes from 8 to ~2 per parameter — the difference
between fitting and OOM for arctic-480b training on 16 GB/chip (DESIGN.md
§3, distributed-optimization tricks).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1
    int8_moments: bool = False


QBLOCK = 128


class Q8(NamedTuple):
    """Blockwise-int8 quantized tensor.

    Shape-preserving: ``q`` has the parameter's own shape (last dim padded
    to a QBLOCK multiple) and ``scale`` replaces the last dim by the block
    count — so the sharding spec of the parameter applies verbatim and the
    dequantized f32 temp stays sharded (no resharding/all-gather; this was
    a ~TB-scale difference on the arctic-480b dry-run)."""
    q: jax.Array        # (*shape[:-1], nb*QBLOCK) int8
    scale: jax.Array    # (*shape[:-1], nb) float32
    last: int           # original last-dim size (static)


def q8_quantize(x) -> Q8:
    x = x.astype(jnp.float32)
    if x.ndim == 0:
        x = x[None]
    last = x.shape[-1]
    nb = -(-last // QBLOCK)
    pad = nb * QBLOCK - last
    if pad:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    blocks = x.reshape(*x.shape[:-1], nb, QBLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=-1) / 127.0          # (..., nb)
    q = jnp.round(blocks / jnp.maximum(scale[..., None], 1e-12))
    return Q8(q=q.reshape(*x.shape[:-1], nb * QBLOCK).astype(jnp.int8),
              scale=scale, last=last)


def q8_dequantize(t: Q8) -> jax.Array:
    nb = t.scale.shape[-1]
    blocks = t.q.reshape(*t.q.shape[:-1], nb, QBLOCK).astype(jnp.float32)
    out = blocks * t.scale[..., None]
    return out.reshape(*t.q.shape[:-1], nb * QBLOCK)[..., :t.last]


jax.tree_util.register_pytree_with_keys(
    Q8,
    lambda t: (((jax.tree_util.GetAttrKey("q"), t.q),
                (jax.tree_util.GetAttrKey("scale"), t.scale)), (t.last,)),
    lambda aux, ch: Q8(ch[0], ch[1], aux[0]))


class OptState(NamedTuple):
    step: jax.Array
    m: object       # pytree of arrays or Q8
    v: object


def init_opt_state(params, cfg: OptConfig) -> OptState:
    def zero_like(x):
        z = jnp.zeros(x.shape, jnp.float32)
        return q8_quantize(z) if cfg.int8_moments else z
    return OptState(step=jnp.zeros((), jnp.int32),
                    m=jax.tree_util.tree_map(zero_like, params),
                    v=jax.tree_util.tree_map(zero_like, params))


def lr_at(step, cfg: OptConfig):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, (step + 1.0) / max(cfg.warmup_steps, 1))
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(np.pi * prog))
    frac = cfg.min_lr_frac + (1.0 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree_util.tree_leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(grads, params, state: OptState, cfg: OptConfig):
    """One AdamW step (with optional clip + quantized moments).

    Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.where(cfg.clip_norm > 0,
                      jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9)), 1.0)
    lr = lr_at(state.step, cfg)
    t = state.step.astype(jnp.float32) + 1.0
    bc1 = 1.0 - cfg.b1 ** t
    bc2 = 1.0 - cfg.b2 ** t

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m_f = q8_dequantize(m) if isinstance(m, Q8) else m
        v_f = q8_dequantize(v) if isinstance(v, Q8) else v
        m_new = cfg.b1 * m_f + (1 - cfg.b1) * g
        v_new = cfg.b2 * v_f + (1 - cfg.b2) * g * g
        update = (m_new / bc1) / (jnp.sqrt(v_new / bc2) + cfg.eps)
        p_new = p.astype(jnp.float32) - lr * (update
                                              + cfg.weight_decay * p.astype(jnp.float32))
        m_out = q8_quantize(m_new) if isinstance(m, Q8) else m_new
        v_out = q8_quantize(v_new) if isinstance(v, Q8) else v_new
        return p_new.astype(p.dtype), m_out, v_out

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v
           in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, OptState(step=state.step + 1, m=new_m, v=new_v), metrics
