from repro.configs.registry import (ARCHS, SHAPES, get_config, input_specs,
                                    list_archs, runnable, smoke_config)
