"""Architecture + shape registry: the assigned (arch × shape) grid.

``input_specs`` returns weak-type-correct ShapeDtypeStruct stand-ins for
every model input (dry-run pattern: shardable, no device allocation).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig, MoEConfig, SSMConfig

from repro.configs import (arctic_480b, deepseek_7b, internvl2_2b,
                           jamba_v0_1_52b, mamba2_2_7b, mixtral_8x22b,
                           musicgen_medium, qwen2_1_5b, qwen2_7b,
                           starcoder2_7b)

ARCHS: dict = {
    "mixtral-8x22b": mixtral_8x22b.CONFIG,
    "arctic-480b": arctic_480b.CONFIG,
    "qwen2-1.5b": qwen2_1_5b.CONFIG,
    "qwen2-7b": qwen2_7b.CONFIG,
    "deepseek-7b": deepseek_7b.CONFIG,
    "starcoder2-7b": starcoder2_7b.CONFIG,
    "musicgen-medium": musicgen_medium.CONFIG,
    "jamba-v0.1-52b": jamba_v0_1_52b.CONFIG,
    "internvl2-2b": internvl2_2b.CONFIG,
    "mamba2-2.7b": mamba2_2_7b.CONFIG,
}


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # 'train' | 'prefill' | 'decode'


SHAPES: dict = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def list_archs() -> list:
    return list(ARCHS)


def get_config(arch: str) -> ModelConfig:
    return ARCHS[arch]


def runnable(cfg: ModelConfig, shape: ShapeSpec) -> bool:
    """long_500k needs sub-quadratic attention (SSM / hybrid / SWA ring);
    pure full-attention archs skip it (DESIGN.md §4)."""
    if shape.name == "long_500k":
        return cfg.sub_quadratic
    return True


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    """ShapeDtypeStruct stand-ins for every data input of the step fn."""
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    emb_dt = jnp.dtype(cfg.compute_dtype)

    def tok(shape_):
        return jax.ShapeDtypeStruct(shape_, i32)

    if shape.kind in ("train", "prefill"):
        if cfg.frontend == "audio":
            specs = {"frame_embeds": jax.ShapeDtypeStruct((b, s, cfg.d_model),
                                                          emb_dt)}
            if shape.kind == "train":
                specs["targets"] = tok((b, s))
            return specs
        if cfg.frontend == "vision":
            p = cfg.vision_prefix
            specs = {"patch_embeds": jax.ShapeDtypeStruct((b, p, cfg.d_model),
                                                          emb_dt),
                     "tokens": tok((b, s - p))}
            if shape.kind == "train":
                specs["targets"] = tok((b, s - p))
            return specs
        specs = {"tokens": tok((b, s))}
        if shape.kind == "train":
            specs["targets"] = tok((b, s))
        return specs

    # decode: one new token against a seq_len cache (cache specs built via
    # eval_shape(init_caches) in the launcher)
    return {"tokens": tok((b, 1))}


# ---------------------------------------------------------------------------
# reduced configs for CPU smoke tests
# ---------------------------------------------------------------------------

def smoke_config(arch: str) -> ModelConfig:
    cfg = ARCHS[arch]
    segs = tuple((min(r, 2), period) for r, period in cfg.segments)
    moe = None
    if cfg.moe is not None:
        # high capacity factor -> drop-free routing, so decode == forward
        # exactly (capacity drops are exercised in test_moe.py instead)
        moe = MoEConfig(n_experts=min(cfg.moe.n_experts, 4),
                        top_k=min(cfg.moe.top_k, 2),
                        capacity_factor=8.0,
                        group_size=64, dispatch=cfg.moe.dispatch)
    ssm = None
    if cfg.ssm is not None:
        ssm = SSMConfig(d_state=16, head_dim=16, expand=2, chunk=16,
                        conv_width=cfg.ssm.conv_width, n_groups=1)
    n_layers = sum(r * len(p) for r, p in segs)
    return dataclasses.replace(
        cfg,
        n_layers=n_layers, d_model=64,
        n_heads=4, n_kv=max(1, min(cfg.n_kv, 2)), head_dim=16,
        d_ff=128 if cfg.d_ff else 0, vocab=512,
        segments=segs, moe=moe, ssm=ssm,
        vision_prefix=min(cfg.vision_prefix, 8),
        window=min(cfg.window, 32) if cfg.window else 0,
        attn_chunk_q=16, attn_chunk_kv=16, attn_chunk_threshold=64,
        param_dtype="float32", compute_dtype="float32")
