"""MusicGen-medium [arXiv:2306.05284; hf]: 48L, d=1536, 24H (MHA),
d_ff=6144 (4x GELU), vocab=2048 (EnCodec codebook). Decoder-only over
EnCodec tokens; the audio frontend is a stub — ``input_specs`` supplies
precomputed frame embeddings (B, S, D)."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    n_layers=48, d_model=1536, n_heads=24, n_kv=24, head_dim=64,
    d_ff=6144, vocab=2048,
    segments=((48, ("attn_mlp",)),),
    mlp_type="gelu", rope_theta=1e4,
    frontend="audio",
)
