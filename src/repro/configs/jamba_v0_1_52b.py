"""Jamba-v0.1 52B [arXiv:2403.19887; hf]: 32L, d=4096, 32H (GQA kv=8),
d_ff=14336, vocab=65536, MoE 16e top-2. Mamba:attention 7:1 interleave
(attention at layer index 4 of each period-8 block), MoE on every other
layer. Jamba's Mamba-1 layers are realized with the SSD (Mamba-2) dual form
here (d_state=16 as in the original) — see DESIGN.md §Arch-applicability."""
from repro.models.common import ModelConfig, MoEConfig, SSMConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    n_layers=32, d_model=4096, n_heads=32, n_kv=8, head_dim=128,
    d_ff=14336, vocab=65536,
    segments=((4, ("mamba_mlp", "mamba_moe", "mamba_mlp", "mamba_moe",
                   "attn_mlp", "mamba_moe", "mamba_mlp", "mamba_moe")),),
    mlp_type="swiglu", rope_theta=1e6,
    moe=MoEConfig(n_experts=16, top_k=2, group_size=16384),
    ssm=SSMConfig(d_state=16, head_dim=64, expand=2),
)
