"""Qwen2-1.5B [arXiv:2407.10671; hf]: 28L, d=1536, 12H (GQA kv=2),
d_ff=8960, vocab=151936, QKV bias."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-1.5b",
    n_layers=28, d_model=1536, n_heads=12, n_kv=2, head_dim=128,
    d_ff=8960, vocab=151936,
    segments=((28, ("attn_mlp",)),),
    mlp_type="swiglu", qkv_bias=True, rope_theta=1e6,
)
