"""StarCoder2-7B [arXiv:2402.19173; hf]: 32L, d=4608, 36H (GQA kv=4),
d_ff=18432 (non-gated 4x GELU FFN), vocab=49152, RoPE, bias."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-7b",
    n_layers=32, d_model=4608, n_heads=36, n_kv=4, head_dim=128,
    d_ff=18432, vocab=49152,
    segments=((32, ("attn_mlp",)),),
    mlp_type="gelu", qkv_bias=True, rope_theta=1e5,
)
