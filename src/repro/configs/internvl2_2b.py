"""InternVL2-2B [arXiv:2404.16821; hf]: InternLM2-1.8B backbone — 24L,
d=2048, 16H (GQA kv=8), d_ff=8192, vocab=92553 (padded to 92672 for lane/
mesh divisibility). The InternViT frontend is a stub: ``input_specs``
supplies precomputed patch embeddings prepended to the token stream."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b",
    n_layers=24, d_model=2048, n_heads=16, n_kv=8, head_dim=128,
    d_ff=8192, vocab=92672,            # actual 92553, padded (see DESIGN.md)
    segments=((24, ("attn_mlp",)),),
    mlp_type="swiglu", rope_theta=1e6,
    frontend="vision", vision_prefix=256,
)
