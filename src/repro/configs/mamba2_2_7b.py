"""Mamba2-2.7B [arXiv:2405.21060]: 64L, d=2560, attention-free SSD,
d_state=128, headdim=64, expand=2 (d_inner=5120, 80 heads),
vocab=50280 (padded to 50304)."""
from repro.models.common import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b",
    n_layers=64, d_model=2560, n_heads=1, n_kv=1, head_dim=64,  # attn unused
    d_ff=0, vocab=50304,               # actual 50280, padded
    segments=((64, ("mamba",)),),
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2),
    tie_embeddings=True,
)
