"""Qwen2-7B [arXiv:2407.10671; hf]: 28L, d=3584, 28H (GQA kv=4),
d_ff=18944, vocab=152064, QKV bias."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-7b",
    n_layers=28, d_model=3584, n_heads=28, n_kv=4, head_dim=128,
    d_ff=18944, vocab=152064,
    segments=((28, ("attn_mlp",)),),
    mlp_type="swiglu", qkv_bias=True, rope_theta=1e6,
)
