"""DeepSeek-7B [arXiv:2401.02954; hf]: 30L, d=4096, 32H (MHA: kv=32),
d_ff=11008, vocab=102400, llama architecture."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-7b",
    n_layers=30, d_model=4096, n_heads=32, n_kv=32, head_dim=128,
    d_ff=11008, vocab=102400,
    segments=((30, ("attn_mlp",)),),
    mlp_type="swiglu", rope_theta=1e4,
)
