"""Mixtral 8x22B [arXiv:2401.04088; hf]: 56L, d=6144, 48H (GQA kv=8),
d_ff=16384, vocab=32768, MoE 8 experts top-2, sliding-window attention."""
from repro.models.common import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    n_layers=56, d_model=6144, n_heads=48, n_kv=8, head_dim=128,
    d_ff=16384, vocab=32768,
    segments=((56, ("attn_moe",)),),
    mlp_type="swiglu", rope_theta=1e6,
    window=4096,                       # SWA -> long-context decode feasible
    moe=MoEConfig(n_experts=8, top_k=2, group_size=16384),
)
