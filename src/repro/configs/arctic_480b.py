"""Snowflake Arctic 480B [hf:Snowflake/snowflake-arctic-base]: 35L, d=7168,
56H (GQA kv=8), d_ff=4864, vocab=32000, MoE 128 experts top-2 with a dense
FFN residual running in parallel (dense-MoE hybrid)."""
from repro.models.common import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    n_layers=35, d_model=7168, n_heads=56, n_kv=8, head_dim=128,
    d_ff=4864, vocab=32000,
    segments=((35, ("arctic",)),),
    mlp_type="swiglu", rope_theta=1e6,
    moe=MoEConfig(n_experts=128, top_k=2, group_size=16384),
)
