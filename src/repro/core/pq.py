"""Product quantization: codebook training (k-means), encoding, ADC tables.

PQ-compressed vectors are the paper's in-memory tier: graph navigation compares
distances against PQ codes only; full-precision vectors are fetched from the
record store ("SSD") solely for re-ranking.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp


class PQCodebook(NamedTuple):
    centroids: jax.Array   # (M, ksub, dsub) float32
    dim: int               # original dimensionality (M * dsub, possibly padded)


def _kmeans_subspace(key, x, ksub: int, iters: int):
    """Plain Lloyd k-means for one subspace. x: (N, dsub)."""
    n = x.shape[0]
    idx = jax.random.choice(key, n, (ksub,), replace=n < ksub)
    cents = x[idx]

    def step(cents, _):
        # assign
        d = (jnp.sum(x * x, 1, keepdims=True)
             - 2.0 * x @ cents.T
             + jnp.sum(cents * cents, 1)[None, :])
        assign = jnp.argmin(d, axis=1)
        onehot = jax.nn.one_hot(assign, ksub, dtype=x.dtype)      # (N, ksub)
        counts = onehot.sum(0)                                    # (ksub,)
        sums = onehot.T @ x                                       # (ksub, dsub)
        new = jnp.where(counts[:, None] > 0, sums / jnp.maximum(counts, 1.0)[:, None],
                        cents)
        return new, None

    cents, _ = jax.lax.scan(step, cents, None, length=iters)
    return cents


@functools.partial(jax.jit, static_argnames=("m", "ksub", "iters"))
def train_pq(key, data, m: int, ksub: int = 256, iters: int = 8) -> PQCodebook:
    """Train M subspace codebooks of ksub centroids each. data: (N, D) float32.

    D must be divisible by m (callers pad otherwise).
    """
    n, d = data.shape
    assert d % m == 0, f"dim {d} not divisible by m {m}"
    dsub = d // m
    sub = data.reshape(n, m, dsub).transpose(1, 0, 2)   # (M, N, dsub)
    keys = jax.random.split(key, m)
    cents = jax.vmap(lambda k, x: _kmeans_subspace(k, x, ksub, iters))(keys, sub)
    return PQCodebook(centroids=cents, dim=d)


@jax.jit
def encode_pq(codebook: PQCodebook, data) -> jax.Array:
    """Encode vectors to PQ codes. Returns (N, M) uint8 (int32 when ksub>256)."""
    m, ksub, dsub = codebook.centroids.shape
    n = data.shape[0]
    sub = data.reshape(n, m, dsub)

    def enc(x_m, c_m):   # (N, dsub), (ksub, dsub)
        d = (jnp.sum(x_m * x_m, 1, keepdims=True)
             - 2.0 * x_m @ c_m.T
             + jnp.sum(c_m * c_m, 1)[None, :])
        return jnp.argmin(d, axis=1)

    codes = jax.vmap(enc, in_axes=(1, 0), out_axes=1)(sub, codebook.centroids)
    dt = jnp.uint8 if ksub <= 256 else jnp.int32
    return codes.astype(dt)


@jax.jit
def distance_table(codebook: PQCodebook, query) -> jax.Array:
    """Per-query ADC lookup table: (M, ksub) squared-L2 partial distances."""
    m, ksub, dsub = codebook.centroids.shape
    q = query.reshape(m, 1, dsub)
    diff = q - codebook.centroids            # (M, ksub, dsub)
    return jnp.sum(diff * diff, axis=-1)     # (M, ksub)


def adc_lookup(codes, table) -> jax.Array:
    """Reference ADC distance: sum_m table[m, codes[:, m]]. codes (N, M)."""
    idx = codes.astype(jnp.int32)                         # (N, M)
    cols = jnp.arange(table.shape[0])[None, :]            # (1, M)
    return jnp.sum(table[cols, idx], axis=1)


def decode_pq(codebook: PQCodebook, codes) -> jax.Array:
    """Reconstruct approximate vectors from codes (for tests)."""
    m, ksub, dsub = codebook.centroids.shape
    idx = codes.astype(jnp.int32)                         # (N, M)
    parts = codebook.centroids[jnp.arange(m)[None, :], idx]   # (N, M, dsub)
    return parts.reshape(codes.shape[0], m * dsub)
