"""Per-vector Bloom filters for approximate label membership (paper §4.3.1).

The paper uses a fixed 4 bytes (32 bits) per vector with k hash functions.
`is_member_approx` for a label set reduces to a single masked compare:
a vector passes iff all required bits are set in its 32-bit word — for a
LabelAnd query the union of every label's bit mask must be present, which is
exactly the AND of the individual checks.

No false negatives by construction: build ORs the exact bit positions.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

BLOOM_BITS = 32


def _hash_label(label: np.ndarray | int, seed: int) -> np.ndarray:
    """SplitMix64-style integer hash -> bit position in [0, 32)."""
    with np.errstate(over="ignore"):   # uint64 wraparound is intentional
        x = (np.asarray(label, dtype=np.uint64)
             + np.uint64(0x9E3779B97F4A7C15) * np.uint64(seed + 1))
        x = (x ^ (x >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
        x = (x ^ (x >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
        x = x ^ (x >> np.uint64(31))
    return (x % np.uint64(BLOOM_BITS)).astype(np.uint32)


def label_bits(labels, k_hashes: int = 2) -> np.ndarray:
    """Bit mask (uint32) with the k hash bits of each label set. labels: (...,)"""
    labels = np.asarray(labels)
    mask = np.zeros(labels.shape, dtype=np.uint32)
    for seed in range(k_hashes):
        mask |= (np.uint32(1) << _hash_label(labels, seed)).astype(np.uint32)
    return mask


def build_blooms(label_offsets: np.ndarray, label_flat: np.ndarray,
                 n_vectors: int, k_hashes: int = 2) -> np.ndarray:
    """Build per-vector 32-bit Bloom words from a CSR label store.

    label_offsets: (N+1,) int64; label_flat: (nnz,) int32 label ids.
    Returns (N,) uint32.
    """
    bits = label_bits(label_flat, k_hashes)                     # (nnz,)
    blooms = np.zeros(n_vectors, dtype=np.uint32)
    # segment-OR via np.bitwise_or.reduceat (empty segments handled below)
    counts = np.diff(label_offsets)
    nonempty = counts > 0
    if bits.size:
        starts = label_offsets[:-1][nonempty]
        blooms[nonempty] = np.bitwise_or.reduceat(bits, starts)
    return blooms


@jax.jit
def bloom_pass(blooms: jax.Array, required_mask) -> jax.Array:
    """Vectorized probe: True where all required bits are present.

    blooms: (N,) uint32 (or gathered subset); required_mask: scalar/broadcast
    uint32. required_mask == 0 means "no bloom constraint" -> all pass.
    """
    req = jnp.asarray(required_mask, dtype=jnp.uint32)
    return (blooms & req) == req


def bloom_fp_rate(avg_labels_per_vec: float, k_hashes: int = 2,
                  m_bits: int = BLOOM_BITS, n_query_labels: int = 1) -> float:
    """Analytic false-positive rate (paper §4.3.1 precision estimation).

    Probability a single absent label appears present:
        p1 = (1 - (1 - 1/m)^(k * n_labels))^k
    For a query of q independent labels that must all match (LabelAnd on
    absent labels), fp = p1 ** q.
    """
    fill = 1.0 - (1.0 - 1.0 / m_bits) ** (k_hashes * max(avg_labels_per_vec, 0.0))
    p1 = fill ** k_hashes
    return float(p1 ** n_query_labels)
