"""Range attribute store: on-"SSD" sorted index + in-memory quantized summaries.

Layout (paper §4.3.2), per numeric field:
  - on-SSD: flat array of <vector_id, value> pairs sorted by value; a range
    query scans one contiguous chunk (sequential reads, counted in pages);
  - in-memory: (a) 1-byte bucket code per vector against 256 global quantile
    bucket boundaries (drives is_member_approx), (b) a 1000-quantile summary
    for selectivity estimation.

``RangeStore`` holds one field; ``MultiRangeStore`` stacks F of them behind
an ``(n, F)`` value matrix so a query may carry predicates over several
numeric fields at once (the schema-first attribute surface). Engines always
hold a ``MultiRangeStore`` — single-field indexes are the F=1 special case.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.io_sim import PAGE_BYTES

N_BUCKETS = 256
N_QUANTILES = 1000
REFRESH_FRAC = 0.25   # re-derive bucket bounds once un-refreshed inserts
                      # exceed this fraction of the store


def _quantile_bounds(values: np.ndarray) -> np.ndarray:
    """Strictly-increasing global bucket boundaries from value quantiles."""
    qs = np.quantile(values, np.linspace(0.0, 1.0, N_BUCKETS + 1)) \
        if values.size else np.zeros(N_BUCKETS + 1)
    qs = np.maximum.accumulate(qs)
    bounds = qs.astype(np.float32)
    bounds[0] = -np.inf if values.size == 0 \
        else np.nextafter(bounds[0], -np.inf)
    return bounds


def _bucket_codes(values: np.ndarray, bounds: np.ndarray) -> np.ndarray:
    return np.clip(np.searchsorted(bounds, values, side="right") - 1,
                   0, N_BUCKETS - 1).astype(np.uint8)


@dataclasses.dataclass
class RangeStore:
    n_vectors: int
    values: np.ndarray           # (N,) float32 — row-wise copy (in records)
    # on-SSD sorted index
    sorted_values: np.ndarray    # (N,) float32
    sorted_ids: np.ndarray       # (N,) int32
    # in-memory summaries
    bucket_bounds: np.ndarray    # (N_BUCKETS+1,) float32 — global boundaries
    bucket_codes: np.ndarray     # (N,) uint8 — per-vector 1-byte code
    quantiles: np.ndarray        # (N_QUANTILES,) float32 — for selectivity
    # staleness tracking for skewed insert streams (not checkpointed:
    # the saved bounds are whatever the last refresh produced, and the
    # counter restarts — a loaded index is treated as freshly bucketed)
    inserted_since_refresh: int = 0
    bounds_refreshed: bool = False   # did the LAST append re-bucket?

    def selectivity(self, lo: float, hi: float) -> float:
        """Estimated fraction of vectors with value in [lo, hi)."""
        q = self.quantiles
        f_lo = np.searchsorted(q, lo, side="left") / q.size
        f_hi = np.searchsorted(q, hi, side="left") / q.size
        return float(max(0.0, f_hi - f_lo))

    def precision(self, lo: float, hi: float) -> float:
        """Estimated precision of the bucket-code is_member_approx (paper:
        true positives from quantiles ÷ positives from coarse buckets)."""
        true_pos = self.selectivity(lo, hi)
        blo, bhi = self.bucket_range(lo, hi)
        # fraction of vectors in overlapping coarse buckets, from quantiles
        cov_lo = float(self.bucket_bounds[blo])
        cov_hi = float(self.bucket_bounds[min(bhi + 1, N_BUCKETS)])
        total_pos = self.selectivity(cov_lo, np.nextafter(cov_hi, np.inf))
        return float(true_pos / max(total_pos, 1e-12))

    def bucket_range(self, lo: float, hi: float) -> tuple[int, int]:
        """Inclusive coarse-bucket id range overlapping [lo, hi)."""
        blo = int(np.clip(np.searchsorted(self.bucket_bounds, lo, side="right") - 1,
                          0, N_BUCKETS - 1))
        bhi = int(np.clip(np.searchsorted(self.bucket_bounds, hi, side="left") - 1,
                          0, N_BUCKETS - 1))
        return blo, max(blo, bhi)

    def scan(self, lo: float, hi: float) -> tuple[np.ndarray, int]:
        """Exact on-SSD scan: valid ids + pages read (sequential)."""
        s = int(np.searchsorted(self.sorted_values, lo, side="left"))
        e = int(np.searchsorted(self.sorted_values, hi, side="left"))
        pages = max(1, -(-max(e - s, 0) * 8 // PAGE_BYTES))
        return self.sorted_ids[s:e], pages

    def memory_bytes(self) -> dict:
        return {
            "bucket_codes_bytes": int(self.bucket_codes.nbytes),
            "bounds_bytes": int(self.bucket_bounds.nbytes + self.quantiles.nbytes),
            "ssd_sorted_index_bytes": int(self.sorted_values.nbytes
                                          + self.sorted_ids.nbytes),
        }


    def append(self, new_values: np.ndarray) -> "RangeStore":
        """Incremental insert-path extension (no re-sort; re-bucket only
        when stale).

        New <id, value> pairs merge into the sorted index at their
        searchsorted positions (one vectorized memcpy instead of an
        O(N log N) rebuild); bucket boundaries normally stay *fixed* so
        new codes remain comparable with existing ones — the
        no-false-negative contract of ``is_member_approx`` is anchored to
        one shared set of bounds. Quantiles are re-read from the merged
        sorted array (O(N_QUANTILES) indexing), so selectivity estimates
        track inserts.

        **Staleness guard (skewed streams):** once the rows inserted
        since the last refresh exceed ``REFRESH_FRAC`` of the store, the
        bounds no longer describe the distribution (e.g. a stream of
        values above the build-time max piles every new row into bucket
        255, collapsing ``is_member_approx`` precision over the new
        region). The append then re-derives the global bounds from the
        merged values and re-codes *every* row against them — bounds and
        codes move together, so the no-false-negative contract is
        preserved. ``bounds_refreshed`` flags the returned store so the
        engine re-uploads the full in-memory code column (a row-tail
        write would leave device codes inconsistent with the new bounds).
        """
        new_values = np.asarray(new_values, np.float32)
        m = new_values.size
        if m == 0:
            return self
        new_ids = np.arange(self.n_vectors, self.n_vectors + m, dtype=np.int32)
        order = np.argsort(new_values, kind="stable")
        sv, si = new_values[order], new_ids[order]
        pos = np.searchsorted(self.sorted_values, sv, side="left")
        sorted_values = np.insert(self.sorted_values, pos, sv)
        sorted_ids = np.insert(self.sorted_ids, pos, si)
        n = self.n_vectors + m
        values = np.concatenate([self.values, new_values])
        quantiles = sorted_values[
            np.minimum((np.linspace(0.0, 1.0, N_QUANTILES) * (n - 1))
                       .round().astype(np.int64), n - 1)]
        inserted = self.inserted_since_refresh + m
        if inserted > REFRESH_FRAC * n:
            bounds = _quantile_bounds(values)
            return RangeStore(
                n_vectors=n, values=values,
                sorted_values=sorted_values, sorted_ids=sorted_ids,
                bucket_bounds=bounds,
                bucket_codes=_bucket_codes(values, bounds),
                quantiles=quantiles,
                inserted_since_refresh=0, bounds_refreshed=True)
        new_codes = _bucket_codes(new_values, self.bucket_bounds)
        return RangeStore(
            n_vectors=n, values=values,
            sorted_values=sorted_values, sorted_ids=sorted_ids,
            bucket_bounds=self.bucket_bounds,
            bucket_codes=np.concatenate([self.bucket_codes, new_codes]),
            quantiles=quantiles,
            inserted_since_refresh=inserted, bounds_refreshed=False)


def build_range_store(values: np.ndarray) -> RangeStore:
    values = np.asarray(values, dtype=np.float32)
    n = values.size
    order = np.argsort(values, kind="stable")
    sorted_values = values[order]
    sorted_ids = order.astype(np.int32)

    # strictly increasing boundaries (dedupe plateaus)
    bucket_bounds = _quantile_bounds(values)
    codes = _bucket_codes(values, bucket_bounds)
    quantiles = np.quantile(values, np.linspace(0.0, 1.0, N_QUANTILES)) \
        .astype(np.float32)
    return RangeStore(n_vectors=n, values=values,
                      sorted_values=sorted_values, sorted_ids=sorted_ids,
                      bucket_bounds=bucket_bounds, bucket_codes=codes,
                      quantiles=quantiles)


@dataclasses.dataclass
class MultiRangeStore:
    """F numeric attribute fields behind one (n, F) matrix.

    Field identity is positional (the schema layer owns names); every
    per-field structure — sorted index, bucket bounds/codes, quantiles —
    lives in the wrapped per-field :class:`RangeStore`. The stacked
    ``values`` / ``bucket_codes`` matrices feed the record store and the
    in-memory device tier respectively.
    """
    stores: list            # F per-field RangeStore objects (F >= 1)

    @property
    def n_fields(self) -> int:
        return len(self.stores)

    @property
    def n_vectors(self) -> int:
        return self.stores[0].n_vectors

    @property
    def values(self) -> np.ndarray:
        """(n, F) float32 row-wise value matrix (record-store layout)."""
        return np.stack([s.values for s in self.stores], axis=1)

    @property
    def bucket_codes(self) -> np.ndarray:
        """(n, F) uint8 per-field 1-byte codes (in-memory tier layout)."""
        return np.stack([s.bucket_codes for s in self.stores], axis=1)

    def field_store(self, field: int) -> RangeStore:
        return self.stores[field]

    @property
    def bounds_refreshed(self) -> bool:
        """True when the last append re-bucketed any field — the engine
        must then re-upload the full device code matrix, not just the
        appended rows."""
        return any(s.bounds_refreshed for s in self.stores)

    def selectivity(self, lo: float, hi: float, field: int = 0) -> float:
        return self.stores[field].selectivity(lo, hi)

    def scan(self, lo: float, hi: float,
             field: int = 0) -> tuple[np.ndarray, int]:
        return self.stores[field].scan(lo, hi)

    def append(self, new_values: np.ndarray) -> "MultiRangeStore":
        """Incremental insert-path extension over all fields; ``new_values``
        is (m, F) (or (m,) for F=1)."""
        new_values = np.asarray(new_values, np.float32)
        if new_values.ndim == 1:
            new_values = new_values[:, None]
        assert new_values.shape[1] == self.n_fields
        return MultiRangeStore(
            [s.append(new_values[:, j]) for j, s in enumerate(self.stores)])

    def memory_bytes(self) -> dict:
        out: dict = {}
        for s in self.stores:
            for k, v in s.memory_bytes().items():
                out[k] = out.get(k, 0) + v
        return out


def build_multi_range_store(values: np.ndarray) -> MultiRangeStore:
    """(n, F) or (n,) value matrix -> per-field stores (F >= 1 enforced so
    device shapes stay uniform even for indexes with no numeric field)."""
    values = np.asarray(values, np.float32)
    if values.ndim == 1:
        values = values[:, None]
    if values.shape[1] == 0:
        values = np.zeros((values.shape[0], 1), np.float32)
    return MultiRangeStore(
        [build_range_store(values[:, j]) for j in range(values.shape[1])])
