"""Range attribute store: on-"SSD" sorted index + in-memory quantized summaries.

Layout (paper §4.3.2):
  - on-SSD: flat array of <vector_id, value> pairs sorted by value; a range
    query scans one contiguous chunk (sequential reads, counted in pages);
  - in-memory: (a) 1-byte bucket code per vector against 256 global quantile
    bucket boundaries (drives is_member_approx), (b) a 1000-quantile summary
    for selectivity estimation.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.io_sim import PAGE_BYTES

N_BUCKETS = 256
N_QUANTILES = 1000


@dataclasses.dataclass
class RangeStore:
    n_vectors: int
    values: np.ndarray           # (N,) float32 — row-wise copy (in records)
    # on-SSD sorted index
    sorted_values: np.ndarray    # (N,) float32
    sorted_ids: np.ndarray       # (N,) int32
    # in-memory summaries
    bucket_bounds: np.ndarray    # (N_BUCKETS+1,) float32 — global boundaries
    bucket_codes: np.ndarray     # (N,) uint8 — per-vector 1-byte code
    quantiles: np.ndarray        # (N_QUANTILES,) float32 — for selectivity

    def selectivity(self, lo: float, hi: float) -> float:
        """Estimated fraction of vectors with value in [lo, hi)."""
        q = self.quantiles
        f_lo = np.searchsorted(q, lo, side="left") / q.size
        f_hi = np.searchsorted(q, hi, side="left") / q.size
        return float(max(0.0, f_hi - f_lo))

    def precision(self, lo: float, hi: float) -> float:
        """Estimated precision of the bucket-code is_member_approx (paper:
        true positives from quantiles ÷ positives from coarse buckets)."""
        true_pos = self.selectivity(lo, hi)
        blo, bhi = self.bucket_range(lo, hi)
        # fraction of vectors in overlapping coarse buckets, from quantiles
        cov_lo = float(self.bucket_bounds[blo])
        cov_hi = float(self.bucket_bounds[min(bhi + 1, N_BUCKETS)])
        total_pos = self.selectivity(cov_lo, np.nextafter(cov_hi, np.inf))
        return float(true_pos / max(total_pos, 1e-12))

    def bucket_range(self, lo: float, hi: float) -> tuple[int, int]:
        """Inclusive coarse-bucket id range overlapping [lo, hi)."""
        blo = int(np.clip(np.searchsorted(self.bucket_bounds, lo, side="right") - 1,
                          0, N_BUCKETS - 1))
        bhi = int(np.clip(np.searchsorted(self.bucket_bounds, hi, side="left") - 1,
                          0, N_BUCKETS - 1))
        return blo, max(blo, bhi)

    def scan(self, lo: float, hi: float) -> tuple[np.ndarray, int]:
        """Exact on-SSD scan: valid ids + pages read (sequential)."""
        s = int(np.searchsorted(self.sorted_values, lo, side="left"))
        e = int(np.searchsorted(self.sorted_values, hi, side="left"))
        pages = max(1, -(-max(e - s, 0) * 8 // PAGE_BYTES))
        return self.sorted_ids[s:e], pages

    def memory_bytes(self) -> dict:
        return {
            "bucket_codes_bytes": int(self.bucket_codes.nbytes),
            "bounds_bytes": int(self.bucket_bounds.nbytes + self.quantiles.nbytes),
            "ssd_sorted_index_bytes": int(self.sorted_values.nbytes
                                          + self.sorted_ids.nbytes),
        }


def build_range_store(values: np.ndarray) -> RangeStore:
    values = np.asarray(values, dtype=np.float32)
    n = values.size
    order = np.argsort(values, kind="stable")
    sorted_values = values[order]
    sorted_ids = order.astype(np.int32)

    qs = np.quantile(values, np.linspace(0.0, 1.0, N_BUCKETS + 1))
    # strictly increasing boundaries (dedupe plateaus)
    qs = np.maximum.accumulate(qs)
    bucket_bounds = qs.astype(np.float32)
    bucket_bounds[0] = -np.inf if n == 0 else np.nextafter(bucket_bounds[0], -np.inf)
    codes = np.clip(np.searchsorted(bucket_bounds, values, side="right") - 1,
                    0, N_BUCKETS - 1).astype(np.uint8)
    quantiles = np.quantile(values, np.linspace(0.0, 1.0, N_QUANTILES)) \
        .astype(np.float32)
    return RangeStore(n_vectors=n, values=values,
                      sorted_values=sorted_values, sorted_ids=sorted_ids,
                      bucket_bounds=bucket_bounds, bucket_codes=codes,
                      quantiles=quantiles)
