"""SSD I/O accounting for the TPU-hosted record store.

The paper evaluates on SSD pages (4 KB). We keep the same accounting unit so the
paper's I/O-centric figures reproduce exactly, while the physical transport on a
TPU pod is an HBM/ICI record gather (see DESIGN.md §2).

All search routines thread integer page counters through their JAX loops; this
module centralizes the constants and the latency model used by benchmarks.
"""
from __future__ import annotations

import dataclasses
import math


PAGE_BYTES = 4096


@dataclasses.dataclass(frozen=True)
class IOModel:
    """Latency/throughput model applied to counted I/O.

    t_page_us: modeled latency of one random 4 KB read (NVMe incl. queueing).
    parallelism: in-flight reads the device sustains (SSD queue depth analogue;
        on TPU this is the coalesced-gather width).
    """
    page_bytes: int = PAGE_BYTES
    t_page_us: float = 100.0
    parallelism: int = 64

    def pages(self, nbytes: int) -> int:
        return max(1, math.ceil(nbytes / self.page_bytes))

    def latency_us(self, pages_sequentially_dependent: int,
                   pages_parallel: int = 0, prefetch_depth: int = 1,
                   compute_us: float = 0.0) -> float:
        """Modeled I/O latency: dependent pages serialize (graph hops),
        batched pages overlap up to ``parallelism``.

        ``prefetch_depth`` is the search loop's in-flight record-slab
        count (``SearchParams.prefetch_depth``) and ``compute_us`` the
        total per-query compute on the hop critical path. With depth ≥ 2
        (the double-buffered loop) the next hop's dependent read is
        issued before the current hop's distance/membership pass runs, so
        compute hides behind I/O (and vice versa): the serial term is
        ``max(read, compute)`` per the paper's pipeline, instead of their
        sum. Beam reads within a hop (``pages_parallel``) overlap through
        device parallelism either way; the dependent *chain length* never
        shrinks — hop t+1's target still comes out of hop t's merge.
        """
        par = math.ceil(pages_parallel / max(1, self.parallelism))
        read_us = pages_sequentially_dependent * self.t_page_us
        if prefetch_depth >= 2:
            serial_us = max(read_us, compute_us)
        else:
            serial_us = read_us + compute_us
        return serial_us + par * self.t_page_us

    @classmethod
    def calibrate_from_samples(cls, samples, page_bytes: int = PAGE_BYTES,
                               parallelism_grid=(1, 2, 4, 8, 16, 32, 64,
                                                 128, 256)) -> "IOModel":
        """Fit ``t_page_us`` / ``parallelism`` from measured slab reads.

        ``samples`` is an iterable of dicts (``storage.DiskRecordStore``
        emits them): ``{"pages": int, "us": float, "kind": "serial" |
        "batch"}``. Serial samples are single dependent pread runs —
        ``t_page_us`` is the median measured per-page latency (median, so
        one OS-cache outlier or compaction stall doesn't skew the fit).
        Batch samples are multi-record fetches whose pages overlap up to
        the device's queue depth: ``parallelism`` is the grid value
        minimizing relative error of ``ceil(pages / p) * t_page_us``
        against the measured batch times. Falls back to the class
        defaults for whichever family has no samples.
        """
        serial = [s for s in samples if s["kind"] == "serial"
                  and s["pages"] > 0 and s["us"] > 0]
        batch = [s for s in samples if s["kind"] == "batch"
                 and s["pages"] > 0 and s["us"] > 0]
        if not serial:
            return cls(page_bytes=page_bytes)
        per_page = sorted(s["us"] / s["pages"] for s in serial)
        t_page = per_page[len(per_page) // 2]
        parallelism = cls.parallelism          # dataclass default
        if batch:
            best = None
            for p in parallelism_grid:
                err = sum(
                    abs(math.ceil(s["pages"] / p) * t_page - s["us"])
                    / s["us"] for s in batch) / len(batch)
                if best is None or err < best[0]:
                    best = (err, p)
            parallelism = best[1]
        return cls(page_bytes=page_bytes, t_page_us=t_page,
                   parallelism=parallelism)

    def faulted_latency_us(self, pages_sequentially_dependent: int,
                           plan, faults: int = 0, retries: int = 0,
                           spikes: int = 0, pages_parallel: int = 0,
                           prefetch_depth: int = 1,
                           compute_us: float = 0.0) -> float:
        """Modeled latency of the same work under a fault plan.

        ``retries``/``spikes`` are the *measured* counters from a faulted
        run (``SearchResult.retries``; spikes ride ``faults`` when not
        broken out). Each retry re-reads its pages after a capped
        exponential backoff (``plan.backoff_us`` doubling up to
        ``plan.backoff_cap_us``); a hedged attempt overlaps the original
        read, so it costs no extra serial time beyond its page read; a
        spiked read stretches to ``plan.spike_factor`` × t_page_us. All
        accounting-only — results never depend on modeled time.
        """
        base = self.latency_us(pages_sequentially_dependent, pages_parallel,
                               prefetch_depth, compute_us)
        if plan is None or retries + spikes + faults == 0:
            return base
        backoff = 0.0
        b = plan.backoff_us
        # attribute the mean backoff ladder position to each retry
        for _ in range(max(1, plan.max_retries)):
            backoff += min(b, plan.backoff_cap_us)
            b *= 2.0
        backoff /= max(1, plan.max_retries)
        retry_us = retries * (self.t_page_us + backoff)
        spike_us = spikes * (plan.spike_factor - 1.0) * self.t_page_us
        return base + retry_us + spike_us


def record_bytes(dim: int, vec_dtype_size: int, n_neighbors: int,
                 max_labels: int, n_numeric: int) -> int:
    """Size of one co-located record: full vector + neighbor IDs + attributes.

    Mirrors the paper's layout: the attributes ride in the record's final-page
    slack, so verification costs no extra I/O beyond the re-rank fetch.
    """
    vec = dim * vec_dtype_size
    nbrs = 4 + n_neighbors * 4          # count + ids
    attrs = 4 + max_labels * 4 + n_numeric * 4
    return vec + nbrs + attrs


def record_pages(dim: int, vec_dtype_size: int, n_neighbors: int,
                 max_labels: int, n_numeric: int,
                 page_bytes: int = PAGE_BYTES) -> int:
    return max(1, math.ceil(
        record_bytes(dim, vec_dtype_size, n_neighbors, max_labels, n_numeric)
        / page_bytes))
