"""Speculative pre-filtering (paper §3 Fig. 3a): attribute-index scan →
in-memory PQ brute force over the superset → exact re-rank + verification.

The superset comes from ``Selector.pre_filter_approx`` (host side, pages
accounted): exact posting merges for labels, sequential sorted-index scans
for ranges, heavy-branch pruning for ANDs. The PQ scan runs on device in
fixed-size chunks (a ``lax.scan`` carrying a running top-(L+δ)) so any
selectivity fits a static shape.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import pq as pq_mod
from repro.core.records import RecordStore
from repro.core.selectors import (InMemory, QueryFilter, Selector, is_member,
                                  is_member_approx)

BIG = jnp.float32(1e30)
INVALID_PENALTY = jnp.float32(1e12)
SCAN_CHUNK = 4096        # full-corpus gated-scan chunk (scan_all_gated)


@dataclasses.dataclass(frozen=True)
class PrefilterParams:
    l_rerank: int            # L + δ: vectors fetched from SSD for re-ranking
    k: int = 10
    chunk: int = 8192        # PQ-scan chunk size (static)
    max_candidates: int = 1 << 20   # superset hard cap


class PrefilterResult(NamedTuple):
    ids: jax.Array           # (B, k) verified-valid top-k (-1 pad)
    dists: jax.Array         # (B, k)
    io_pages: jax.Array      # (B,) scan + re-rank pages
    dist_comps: jax.Array    # (B,)
    n_valid: jax.Array       # (B,)


@functools.partial(jax.jit, static_argnames=("l_rerank", "chunk", "distance_fn"))
def _pq_topl(codes, codebook, query, cand_ids, cand_len, l_rerank: int,
             chunk: int, distance_fn: Callable = pq_mod.adc_lookup):
    """Running top-l over a padded candidate id array, chunked scan.

    cand_ids: (C,) int32 padded with -1 (C divisible by chunk).
    Returns (top_ids (l,), top_dists (l,)).
    """
    table = pq_mod.distance_table(codebook, query)
    n_chunks = cand_ids.shape[0] // chunk

    def step(carry, ids_chunk):
        top_ids, top_d = carry
        live = ids_chunk >= 0
        d = distance_fn(codes[jnp.where(live, ids_chunk, 0)], table)
        d = jnp.where(live, d, BIG)
        all_ids = jnp.concatenate([top_ids, ids_chunk])
        all_d = jnp.concatenate([top_d, d])
        neg_d, idx = jax.lax.top_k(-all_d, l_rerank)
        return (all_ids[idx], -neg_d), None

    init = (jnp.full((l_rerank,), -1, jnp.int32),
            jnp.full((l_rerank,), BIG, jnp.float32))
    (top_ids, top_d), _ = jax.lax.scan(
        step, init, cand_ids.reshape(n_chunks, chunk))
    return top_ids, top_d


def _verify_core(qf: QueryFilter, query, top_ids, vecs, rl, rv, k: int,
                 pages_std):
    """Exact distance + exact verification over already-fetched record
    fields (dead rows carry arbitrary data — fully masked by ``live``)."""
    live = top_ids >= 0
    d = vecs - query[None, :]
    ex_d = jnp.where(live, jnp.sum(d * d, axis=-1), BIG)
    ok = is_member(qf, rl, rv) & live
    key = jnp.where(ok, ex_d, BIG)
    order = jnp.argsort(key)[:k]
    ids = jnp.where(ok[order], top_ids[order], -1)
    dists = jnp.where(ok[order], ex_d[order], jnp.inf)
    io = jnp.sum(live) * pages_std
    return ids, dists, io, jnp.sum(ok)


@functools.partial(jax.jit, static_argnames=("params", "pages_std"))
def _verify_fetched(qf: QueryFilter, query, top_ids, vecs, rl, rv,
                    params: PrefilterParams, pages_std: int):
    """Verification over records fetched outside the trace (disk tier)."""
    return _verify_core(qf, query, top_ids, vecs, rl, rv, params.k,
                        pages_std)


@functools.partial(jax.jit, static_argnames=("params",))
def _rerank_verify(store: RecordStore, qf: QueryFilter, query,
                   top_ids, params: PrefilterParams):
    """Fetch top-(L+δ) records, exact distance + exact verification."""
    safe = jnp.where(top_ids >= 0, top_ids, 0)
    return _verify_core(qf, query, top_ids, store.vectors[safe],
                        store.rec_labels[safe], store.rec_values[safe],
                        params.k, store.pages_std)


@functools.partial(jax.jit,
                   static_argnames=("l_rerank", "chunk", "distance_fn"))
def scan_all_gated(codes, codebook, mem: InMemory, qf: QueryFilter, query,
                   l_rerank: int, chunk: int,
                   distance_fn: Callable = pq_mod.adc_lookup):
    """Gated full-corpus ADC scan: the serve tier's last degrade rung.

    Every id is a candidate (no posting scan, no graph traversal — one
    fused pass over the in-memory code tier), ranked by ADC distance plus
    ``INVALID_PENALTY`` where the *approximate* membership gate rejects.
    The gate is a superset test (bloom / bucket words only over-admit),
    so no truly-valid record is ever pushed behind an invalid one — the
    no-false-negative contract holds structurally; exactness comes from
    the caller's fetch + exact verify of the returned top-``l_rerank``.

    Returns ``(top_ids (l_rerank,), top_keys)``; ids whose key carries
    the penalty are approx-invalid fill (the verifier drops them).
    """
    table = pq_mod.distance_table(codebook, query)
    n = codes.shape[0]
    n_chunks = -(-n // chunk)
    pad_n = n_chunks * chunk
    ids_all = jnp.arange(pad_n, dtype=jnp.int32)

    def step(carry, ids_chunk):
        top_ids, top_d = carry
        live = ids_chunk < n
        safe = jnp.where(live, ids_chunk, 0)
        d = distance_fn(codes[safe], table)
        ok = is_member_approx(qf, safe, mem)
        d = d + jnp.where(ok, 0.0, INVALID_PENALTY)
        d = jnp.where(live, d, BIG)
        all_ids = jnp.concatenate([top_ids, ids_chunk])
        all_d = jnp.concatenate([top_d, d])
        neg_d, idx = jax.lax.top_k(-all_d, l_rerank)
        return (all_ids[idx], -neg_d), None

    init = (jnp.full((l_rerank,), -1, jnp.int32),
            jnp.full((l_rerank,), BIG, jnp.float32))
    (top_ids, top_d), _ = jax.lax.scan(
        step, init, ids_all.reshape(n_chunks, chunk))
    return top_ids, top_d


def prefilter_search(store: RecordStore, codes, codebook, selectors, qfilters,
                     queries, params: PrefilterParams,
                     distance_fn: Callable = pq_mod.adc_lookup,
                     speculative: bool = True,
                     host_fetch: Callable | None = None) -> PrefilterResult:
    """Host-driven pre-filtering for a query batch.

    ``speculative=True`` uses Selector.pre_filter_approx (partial scans,
    heavy-branch pruning); ``False`` forces exact full-constraint scans
    (the strict baseline — implemented as evaluating every branch).

    ``host_fetch`` (disk backend: ``DiskRecordStore.fetch_host``) replaces
    the device-array record gather for the re-rank: the top-(L+δ) records
    are read from slab files instead — same fields, same verification,
    bit-identical output, but through the real page cache.
    """
    B = queries.shape[0]
    out_ids, out_d = [], []
    io_pages = np.zeros(B, np.int64)
    dist_comps = np.zeros(B, np.int64)
    n_valid = np.zeros(B, np.int64)

    for b in range(B):
        sel: Selector = selectors[b]
        if speculative:
            cand, pages = sel.pre_filter_approx()
        else:
            cand, pages = _strict_scan(sel)
        cand = cand[:params.max_candidates]
        pad = -(-max(cand.size, 1) // params.chunk) * params.chunk
        cand_padded = np.full(pad, -1, np.int32)
        cand_padded[:cand.size] = cand
        # index on the host: a device-side row gather is shape-keyed on the
        # raw batch width and would compile per distinct group composition
        qf = jax.tree_util.tree_map(lambda x: np.asarray(x)[b], qfilters)
        top_ids, _ = _pq_topl(codes, codebook, queries[b],
                              jnp.asarray(cand_padded), cand.size,
                              params.l_rerank, params.chunk, distance_fn)
        if host_fetch is None:
            ids, dists, io, nv = _rerank_verify(store, qf, queries[b],
                                                top_ids, params)
        else:
            tid = np.asarray(top_ids)
            rec = host_fetch(np.where(tid >= 0, tid, 0))
            ids, dists, io, nv = _verify_fetched(
                qf, queries[b], top_ids, jnp.asarray(rec["vectors"]),
                jnp.asarray(rec["rec_labels"]),
                jnp.asarray(rec["rec_values"]), params, store.pages_std)
        out_ids.append(ids)
        out_d.append(dists)
        io_pages[b] = pages + int(io)
        dist_comps[b] = cand.size
        n_valid[b] = int(nv)

    return PrefilterResult(
        ids=jnp.stack(out_ids), dists=jnp.stack(out_d),
        io_pages=jnp.asarray(io_pages), dist_comps=jnp.asarray(dist_comps),
        n_valid=jnp.asarray(n_valid))


def _strict_scan(sel: Selector) -> tuple[np.ndarray, int]:
    """Exact pre-filter: evaluate every branch (no pruning/speculation)."""
    from repro.core.selectors import (AndSelector, LabelAndSelector,
                                      LabelOrSelector, OrSelector,
                                      RangeSelector)
    if isinstance(sel, LabelAndSelector):
        merged, pages = sel._fetch_merged(sel.labels, "and")
        return merged.astype(np.int32), pages
    if isinstance(sel, LabelOrSelector):
        merged, pages = sel._fetch_merged(sel.labels, "or")
        return merged.astype(np.int32), pages
    if isinstance(sel, RangeSelector):
        ids, pages = sel._fs.scan(sel.lo, sel.hi)
        return ids.astype(np.int32), pages
    if isinstance(sel, AndSelector):
        # every branch (optional label + all range predicates), intersected
        ids, pages = _strict_scan(sel.children[0])
        for c in sel.children[1:]:
            more, p = _strict_scan(c)
            ids = np.intersect1d(ids, more)
            pages += p
        return ids.astype(np.int32), pages
    if isinstance(sel, OrSelector):
        a, pa = _strict_scan(sel.label_sel)
        b, pb = _strict_scan(sel.range_sel)
        return np.union1d(a, b).astype(np.int32), pa + pb
    return sel.pre_filter_approx()
