"""Batched best-first graph search with speculative / strict / post filtering.

This is the paper's §3–§4 search engine expressed as a shape-static JAX
program: a ``lax.while_loop`` advances every query's beam one hop per step,
so the record fetches of a whole query batch coalesce into one gather — the
TPU-native analogue of PipeANN's pipelined SSD reads (DESIGN.md §2).

Modes
-----
* ``post``      — plain traversal, dummy approx filter (always true); validity
                  checked only at verification (the loose extreme of §3).
* ``spec_in``   — speculative in-filtering: neighbors (direct + 2-hop) are
                  screened by ``is_member_approx`` against in-memory Bloom
                  words / bucket codes; up to R approx-valid neighbors are
                  kept per hop, back-filled with invalid *direct* neighbors
                  (bridge nodes). Exploration prefers possibly-valid nodes
                  even when invalid ones are geometrically closer.
* ``strict_in`` — the strict baseline (Filtered-DiskANN-like): every neighbor's
                  exact attributes are read from the record store before it may
                  enter the pool (+1 page per neighbor — the I/O bottleneck the
                  paper eliminates).

Exact verification piggybacks on the re-rank fetch: every explored record's
full vector *and* attributes arrive in the same (already-counted) pages.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import pq as pq_mod
from repro.core.records import RecordStore
from repro.core.selectors import InMemory, QueryFilter, is_member, is_member_approx

INVALID_PENALTY = jnp.float32(1e12)
BIG = jnp.float32(1e30)


@dataclasses.dataclass(frozen=True)
class SearchParams:
    l_search: int           # candidate pool length L
    k: int = 10
    beam_width: int = 1     # W records fetched per hop (pipelined I/O analogue)
    max_hops: int = 256
    mode: str = "spec_in"   # 'post' | 'spec_in' | 'strict_in'
    l_valid: int = 0        # early-exit once this many verified-valid found
                            # (0 -> defaults to l_search)

    def __post_init__(self):
        assert self.mode in ("post", "spec_in", "strict_in")


class SearchResult(NamedTuple):
    ids: jax.Array          # (B, k) int32 — verified-valid top-k (-1 pad)
    dists: jax.Array        # (B, k) float32 exact distances
    io_pages: jax.Array     # (B,) int32 pages fetched
    hops: jax.Array         # (B,) int32 beam-loop iterations
    dist_comps: jax.Array   # (B,) int32 PQ distance computations
    approx_checks: jax.Array  # (B,) int32 is_member_approx evaluations
    n_valid: jax.Array      # (B,) int32 verified-valid results found
    fp_explored: jax.Array  # (B,) int32 explored records that verified invalid
    explored: jax.Array     # (B,) int32 records fetched & exact-verified


def _exact_sq_dist(vecs, q):
    d = vecs - q[None, :]
    return jnp.sum(d * d, axis=-1)


def local_fetch(store: RecordStore, ids: jax.Array) -> dict:
    """Single-host record fetch: plain gathers. The distributed engine
    (core/distributed.py) swaps in a psum-combined sharded fetch."""
    return {
        "vectors": store.vectors[ids],
        "neighbors": store.neighbors[ids],
        "dense_neighbors": store.dense_neighbors[ids],
        "rec_labels": store.rec_labels[ids],
        "rec_values": store.rec_values[ids],
    }


@functools.partial(
    jax.jit,
    static_argnames=("params", "distance_fn", "fetch_fn"))
def filtered_search(store: RecordStore, codes: jax.Array,
                    codebook: pq_mod.PQCodebook, mem: InMemory,
                    qfilters: QueryFilter, queries: jax.Array, entry: int,
                    params: SearchParams,
                    distance_fn: Callable = pq_mod.adc_lookup,
                    fetch_fn: Callable = local_fetch,
                    entries: jax.Array | None = None) -> SearchResult:
    """Run the filtered beam search for a batch of queries.

    codes: (N, M) uint8 PQ codes (the replicated in-memory tier).
    qfilters: batched QueryFilter (leading dim B).
    entries: optional (B, E) int32 per-query entry seeds (-1 pad; each row
    must hold distinct ids). Defaults to the shared ``entry`` (medoid).
    Strict in-filtering passes exactly-valid seeds here — the query-time
    analogue of Filtered-DiskANN's precomputed per-label entry points —
    because its valid-only pool dies immediately when the medoid's
    neighborhood contains no valid record.
    """
    p = params
    l_valid = p.l_valid or p.l_search
    P, W = p.l_search, p.beam_width
    R = store.degree
    Rd = store.dense_degree if p.mode == "spec_in" else 0
    res_cap = p.max_hops * W                     # explored-record buffer
    rec_pages = store.pages_dense if p.mode == "spec_in" else store.pages_std
    if entries is None:
        entries = jnp.full((queries.shape[0], 1), entry, jnp.int32)

    def one(q, qf, ent):
        table = pq_mod.distance_table(codebook, q)            # (M, ksub)

        e_n = ent.shape[0]
        ent_valid = ent >= 0
        safe_ent = jnp.where(ent_valid, ent, 0)
        entry_d = distance_fn(codes[safe_ent], table)         # (E,)
        entry_ok = is_member_approx(qf, safe_ent, mem) & ent_valid
        entry_key = jnp.where(
            ent_valid, entry_d + jnp.where(entry_ok, 0.0, INVALID_PENALTY),
            BIG)

        pool_ids = jnp.full((P,), -1, jnp.int32).at[:e_n].set(
            jnp.where(ent_valid, ent, -1))
        pool_key = jnp.full((P,), BIG, jnp.float32).at[:e_n].set(entry_key)
        explored = jnp.ones((P,), jnp.bool_).at[:e_n].set(~ent_valid)

        res_ids = jnp.full((res_cap,), -1, jnp.int32)
        res_d = jnp.full((res_cap,), BIG, jnp.float32)
        res_valid = jnp.zeros((res_cap,), jnp.bool_)

        counters = jnp.zeros((4,), jnp.int32)    # io, dist_comps, approx, hops

        def cond(state):
            pool_ids, pool_key, explored, res_ids, res_d, res_valid, counters = state
            hops = counters[3]
            frontier = jnp.any(~explored[:P] & (pool_key[:P] < BIG))
            # paper early termination: top-l_valid verified & no closer frontier
            n_ok = jnp.sum(res_valid)
            kth = jnp.sort(jnp.where(res_valid, res_d, BIG))[
                jnp.minimum(l_valid, res_cap) - 1]
            best_unexp = jnp.min(jnp.where(explored, BIG, pool_key))
            settled = (n_ok >= l_valid) & (best_unexp > kth)
            return (hops < p.max_hops) & frontier & ~settled

        def body(state):
            pool_ids, pool_key, explored, res_ids, res_d, res_valid, counters = state
            # ---- 1. pick best-W unexplored (by priority key) ----
            masked = jnp.where(explored, BIG, pool_key)
            _, sel = jax.lax.top_k(-masked, W)
            cur_ids = pool_ids[sel]                            # (W,)
            cur_live = masked[sel] < BIG
            explored = explored.at[sel].set(True)
            safe_cur = jnp.where(cur_live, cur_ids, 0)

            # ---- 2. fetch records (vector + neighbors + attrs: one I/O) ----
            rec = fetch_fn(store, safe_cur)
            vecs = rec["vectors"]                              # (W, D)
            nbrs = rec["neighbors"]                            # (W, R)
            rl = rec["rec_labels"]                             # (W, ML)
            rv = rec["rec_values"]                             # (W, F)
            io = counters[0] + jnp.sum(cur_live) * rec_pages

            # ---- 3. re-rank + piggybacked exact verification ----
            ex_d = jnp.where(cur_live, _exact_sq_dist(vecs, q), BIG)
            ex_ok = is_member(qf, rl, rv) & cur_live
            hops = counters[3]
            start = hops * W
            res_ids = jax.lax.dynamic_update_slice(
                res_ids, jnp.where(cur_live, cur_ids, -1), (start,))
            res_d = jax.lax.dynamic_update_slice(res_d, ex_d, (start,))
            res_valid = jax.lax.dynamic_update_slice(res_valid, ex_ok, (start,))

            # ---- 4. candidate generation per mode ----
            if p.mode == "spec_in":
                dn = rec["dense_neighbors"]                    # (W, Rd)
                cand = jnp.concatenate([nbrs, dn], axis=1)     # (W, R+Rd)
                is_direct = jnp.concatenate(
                    [jnp.ones((W, R), bool), jnp.zeros((W, Rd), bool)], axis=1)
            else:
                cand = nbrs
                is_direct = jnp.ones((W, R), bool)
            cand = jnp.where(cur_live[:, None], cand, -1)
            live = cand >= 0
            safe_cand = jnp.where(live, cand, 0)

            # dedup vs pool, explored buffer, and within the row (the 2-hop
            # sample may repeat ids)
            dup_pool = jnp.any(cand[:, :, None] == pool_ids[None, None, :], -1)
            dup_res = jnp.any(cand[:, :, None] == res_ids[None, None, :], -1)
            c = cand.shape[1]
            tri = jnp.tril(jnp.ones((c, c), bool), -1)
            dup_row = jnp.any((cand[:, :, None] == cand[:, None, :]) & tri, -1)
            fresh = live & ~dup_pool & ~dup_res & ~dup_row

            approx_n = jnp.sum(live)
            if p.mode == "post":
                ok = fresh
                counters_approx = counters[2]
            elif p.mode == "spec_in":
                ok = is_member_approx(qf, safe_cand, mem) & fresh
                counters_approx = counters[2] + approx_n
            else:  # strict_in: read every fresh neighbor's attrs from "SSD"
                nrec = fetch_fn(store, safe_cand.reshape(-1))
                n_rl = nrec["rec_labels"].reshape(W, R, -1)    # (W, R, ML)
                n_rv = nrec["rec_values"].reshape(W, R, store.n_fields)
                ok = is_member(qf, n_rl, n_rv) & fresh
                io = io + jnp.sum(fresh)                       # 1 page / neighbor
                counters_approx = counters[2]

            # ---- 5. slot selection: up to R approx-valid, bridge back-fill ----
            if p.mode == "spec_in":
                # first-come order (cheap, matches Table-1 compute accounting)
                rank_ok = jnp.cumsum(ok.astype(jnp.int32), axis=1) - 1
                fill = fresh & ~ok & is_direct
                rank_fill = jnp.cumsum(fill.astype(jnp.int32), axis=1) - 1
                n_ok_row = jnp.sum(ok, axis=1, keepdims=True)
                order_key = jnp.where(
                    ok, rank_ok.astype(jnp.float32),
                    jnp.where(fill, (n_ok_row + rank_fill).astype(jnp.float32),
                              BIG))
                _, take = jax.lax.top_k(-order_key, R)          # (W, R)
                sel_ids = jnp.take_along_axis(cand, take, axis=1)
                sel_ok = jnp.take_along_axis(ok, take, axis=1)
                sel_fill = jnp.take_along_axis(fill, take, axis=1)
                sel_live = sel_ok | sel_fill
            else:
                sel_ids, sel_ok, sel_live = cand, ok, ok

            # ---- 6. PQ distances for selected candidates only ----
            flat_ids = sel_ids.reshape(-1)
            flat_live = sel_live.reshape(-1)
            flat_ok = sel_ok.reshape(-1)
            # cross-row dedup of the selected set (W > 1 beams may collide)
            nf = flat_ids.shape[0]
            trif = jnp.tril(jnp.ones((nf, nf), bool), -1)
            dupf = jnp.any((flat_ids[:, None] == flat_ids[None, :]) & trif, -1)
            flat_live = flat_live & ~dupf
            flat_ok = flat_ok & ~dupf
            pq_d = distance_fn(codes[jnp.where(flat_live, flat_ids, 0)], table)
            key = pq_d + jnp.where(flat_ok, 0.0, INVALID_PENALTY)
            key = jnp.where(flat_live, key, BIG)
            dist_comps = counters[1] + jnp.sum(flat_live)

            # ---- 7. merge into pool (sorted ascending by key) ----
            all_ids = jnp.concatenate([pool_ids, jnp.where(flat_live, flat_ids, -1)])
            all_key = jnp.concatenate([pool_key, key])
            all_exp = jnp.concatenate([explored,
                                       jnp.zeros_like(flat_live)])
            order = jnp.argsort(all_key)[:P]
            new_counters = jnp.stack([io, dist_comps, counters_approx, hops + 1])
            return (all_ids[order], all_key[order], all_exp[order],
                    res_ids, res_d, res_valid, new_counters)

        state = (pool_ids, pool_key, explored, res_ids, res_d, res_valid, counters)
        state = jax.lax.while_loop(cond, body, state)
        pool_ids, pool_key, explored, res_ids, res_d, res_valid, counters = state

        # ---- final: top-k verified-valid by exact distance ----
        final_key = jnp.where(res_valid, res_d, BIG)
        order = jnp.argsort(final_key)[:p.k]
        out_ids = jnp.where(res_valid[order], res_ids[order], -1)
        out_d = jnp.where(res_valid[order], res_d[order], jnp.inf)
        n_valid = jnp.sum(res_valid)
        n_explored = jnp.sum(res_ids >= 0)
        fp = jnp.sum((res_ids >= 0) & ~res_valid)
        return (out_ids, out_d, counters[0], counters[3], counters[1],
                counters[2], n_valid, fp, n_explored)

    outs = jax.vmap(one)(queries, qfilters, entries)
    return SearchResult(*outs)
