"""Batched best-first graph search with speculative / strict / post filtering.

This is the paper's §3–§4 search engine expressed as a shape-static JAX
program: a ``lax.while_loop`` advances every query's beam one hop per step,
so the record fetches of a whole query batch coalesce into one gather — the
TPU-native analogue of PipeANN's pipelined SSD reads (DESIGN.md §2).

Modes
-----
* ``post``      — plain traversal, dummy approx filter (always true); validity
                  checked only at verification (the loose extreme of §3).
* ``spec_in``   — speculative in-filtering: neighbors (direct + 2-hop) are
                  screened by ``is_member_approx`` against in-memory Bloom
                  words / bucket codes; up to R approx-valid neighbors are
                  kept per hop, back-filled with invalid *direct* neighbors
                  (bridge nodes). Exploration prefers possibly-valid nodes
                  even when invalid ones are geometrically closer.
* ``strict_in`` — the strict baseline (Filtered-DiskANN-like): every neighbor's
                  exact attributes are read from the record store before it may
                  enter the pool (+1 page per neighbor — the I/O bottleneck the
                  paper eliminates).

Exact verification piggybacks on the re-rank fetch: every explored record's
full vector *and* attributes arrive in the same (already-counted) pages.

Hop pipeline (docs/perf.md has the diagram)
-------------------------------------------
The hot loop is built from shape-static, near-linear primitives:

* **Probabilistic visited set** — a per-query hashed slot table (the
  device analogue of the paper's Bloom superset) replaces the pairwise
  dedup broadcasts against the pool and the explored buffer. Candidates
  are marked when *admitted* to the pool merge (entries at init); a slot
  collision only skips re-exploration of a node, it can never admit an
  invalid result (verification is exact). Below ``VISITED_SLOTS_MAX`` ids
  the table covers the id space and the set is exact.
* **Sorted-pool invariant** — the pool stays key-ascending, so the merge
  is a fixed-size concatenate + one ``top_k`` instead of a full argsort,
  and the early-termination bound (the l_valid-th verified distance) is
  tracked incrementally in a small sorted buffer instead of re-sorting
  the whole explored buffer every iteration.
* **Fused candidate pass** — PQ ADC distance + approximate membership +
  invalid-penalty key for the whole ``(B, W·(R+R_d))`` candidate slab in
  one kernel (``kernels/ops.hop_fused``); the loop itself runs genuinely
  batched (no ``vmap``) so the kernel amortizes across queries.

Pipelined execution (PR 5; docs/perf.md has the timeline)
----------------------------------------------------------
Two mechanisms restructure the loop into the paper's genuine pipeline:

* **Cross-hop prefetch (double-buffering)** — after the sorted-pool merge
  the next hop's best-W frontier is fully determined, so the loop selects
  it and issues its record fetch at the *end* of the body, carrying the
  fetched slab in loop state: hop t+1's gather overlaps hop t's fused
  candidate pass instead of heading the critical path. The fetch *set*
  and every counter are unchanged — only the issue time moves — so the
  oracle parity below still holds bit-exactly. ``SearchParams.
  prefetch_depth`` records the in-flight slab count for the modeled SSD
  latency (``io_sim.IOModel.latency_us``).
* **Straggler compaction** — :func:`run_hops` advances a batch by up to
  ``n_hops`` hops over an explicit :class:`HopState`; the host driver
  :func:`filtered_search_pipelined` re-checks the active set every chunk
  and compacts surviving queries into power-of-two buckets (B → B/2 → …,
  padded with inert rows), so late hops run at the active-set width
  instead of full B. No hop-loop op mixes query rows, so compaction is
  pure re-indexing: the driver's results are bit-identical to the
  single-shot :func:`filtered_search`.

Implementations sharing the semantics:

* :func:`filtered_search` — the fused batched pipeline in one jit
  (single-shot; also the distributed/shard_map entry).
* :func:`filtered_search_pipelined` — the bucketed host driver over
  :func:`init_search` / :func:`run_hops` / :func:`finalize_search`
  (the engine's production path).
* :func:`filtered_search_ref` — the jnp oracle: same dedup/admission
  semantics, naive primitives (``vmap`` over queries, full argsorts,
  unfused gathers). A/B parity: identical ``io_pages``/``explored``.
* :func:`filtered_search_legacy` — the pre-fused-pipeline implementation
  (pairwise dedup broadcasts, per-iteration result re-sort), kept as the
  baseline that ``benchmarks/bench_search.py`` measures speedups against.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import faults as faults_mod
from repro.core import io_sim
from repro.core import pq as pq_mod
from repro.core.faults import FaultPlan
from repro.core.records import RecordStore
from repro.core.selectors import (InMemory, QueryFilter, is_member,
                                  is_member_approx, kernel_filter_params,
                                  kernel_view, merged_table_words)
from repro.kernels import ops as kops
from repro.kernels.ref import INVALID_PENALTY   # single source (1e12)
from repro.utils.tree import tree_put_rows, tree_take_rows

BIG = jnp.float32(1e30)
VISITED_SLOTS_MAX = 1 << 20   # beyond this the visited set hashes (approx.)

DEFAULT_HOP_CHUNK = 32    # hops between the driver's compaction checks (K)
MIN_COMPACT_BUCKET = 8    # narrowest bucket worth a dedicated compile


@dataclasses.dataclass(frozen=True)
class SearchParams:
    l_search: int           # candidate pool length L
    k: int = 10
    beam_width: int = 1     # W records fetched per hop (pipelined I/O analogue)
    max_hops: int = 256
    mode: str = "spec_in"   # 'post' | 'spec_in' | 'strict_in'
    l_valid: int = 0        # early-exit once this many verified-valid found
                            # (0 -> defaults to l_search)
    prefetch_depth: int = 2  # record slabs in flight per query: 1 models
                            # the serial issue order, 2 the double-buffered
                            # loop (next hop's fetch issued behind the
                            # current hop's compute), >2 widens the disk
                            # tier's real read-ahead window
                            # (storage/disk.py). The executed fetch set is
                            # identical at any depth — the knob feeds
                            # io_sim.IOModel.latency_us and the cache
                            # warmer, never results.
    fault_plan: FaultPlan | None = None
                            # seeded fault injection on the frontier slab
                            # reads (core/faults.py): failed/corrupted
                            # reads walk the retry→hedge→degrade ladder.
                            # None (or an all-zero-rate plan) traces the
                            # unmodified hot path — the plan is static, so
                            # the clean compile carries zero fault ops.

    def __post_init__(self):
        assert self.mode in ("post", "spec_in", "strict_in")
        # depth is bounded by the modeled device queue depth: more slabs
        # in flight than the device sustains would claim overlap the
        # latency model (and the real read-ahead) cannot deliver
        assert 1 <= self.prefetch_depth <= io_sim.IOModel.parallelism, (
            f"prefetch_depth={self.prefetch_depth} outside "
            f"[1, IOModel.parallelism={io_sim.IOModel.parallelism}]")


class SearchResult(NamedTuple):
    ids: jax.Array          # (B, k) int32 — verified-valid top-k (-1 pad)
    dists: jax.Array        # (B, k) float32 exact distances
    io_pages: jax.Array     # (B,) int32 pages fetched
    hops: jax.Array         # (B,) int32 beam-loop iterations
    dist_comps: jax.Array   # (B,) int32 PQ distance computations
    approx_checks: jax.Array  # (B,) int32 is_member_approx evaluations
    n_valid: jax.Array      # (B,) int32 verified-valid results found
    fp_explored: jax.Array  # (B,) int32 explored records that verified invalid
    explored: jax.Array     # (B,) int32 records fetched & exact-verified
    faults: jax.Array       # (B,) int32 injected fault events encountered
                            # (failed/corrupted attempts + latency spikes)
    retries: jax.Array      # (B,) int32 extra read attempts issued
                            # (retries + hedged reads)
    degraded: jax.Array     # (B,) int32 rows that exhausted the ladder and
                            # fell back to PQ-approximate distance/validity


def _exact_sq_dist(vecs, q):
    d = vecs - q[None, :]
    return jnp.sum(d * d, axis=-1)


def local_fetch(store: RecordStore, ids: jax.Array) -> dict:
    """Single-host record fetch: plain gathers.

    ``ids`` may be any shape — the batched hop loop passes one flat
    ``(B·W,)`` vector per hop so the whole batch's reads coalesce. The
    distributed engine (core/distributed.py) swaps in a psum-combined
    sharded fetch honouring the same contract, ``cand_first`` included
    (stores without the precompute omit the key and the search falls
    back to the on-the-fly dedup). Unused keys cost nothing: XLA dead-code
    eliminates gathers whose results a mode never consumes."""
    rec = {
        "vectors": store.vectors[ids],
        "neighbors": store.neighbors[ids],
        "dense_neighbors": store.dense_neighbors[ids],
        "rec_labels": store.rec_labels[ids],
        "rec_values": store.rec_values[ids],
    }
    if store.cand_first is not None:
        rec["cand_first"] = store.cand_first[ids]
    return rec


# ---------------------------------------------------------------------------
# Hop-pipeline primitives
# ---------------------------------------------------------------------------

def _visited_spec(n_ids: int) -> tuple[int, int]:
    """(n_slots, shift) for the visited slot table over ``n_ids`` ids.

    Exact (identity-indexed) while the id space fits in VISITED_SLOTS_MAX
    slots; hashed (multiply-shift) beyond — false positives then skip
    re-exploration of the colliding node (Bloom-superset semantics), never
    break result validity."""
    bits = max(8, int(max(n_ids - 1, 1)).bit_length())
    bits = min(bits, VISITED_SLOTS_MAX.bit_length() - 1)
    return 1 << bits, 32 - bits


def _visited_slot(ids: jax.Array, n_ids: int) -> jax.Array:
    n_slots, shift = _visited_spec(n_ids)
    if n_slots >= n_ids:
        return ids
    h = ids.astype(jnp.uint32) * jnp.uint32(0x9E3779B1)
    return (h >> shift).astype(jnp.int32)


def _first_occurrence(cand: jax.Array, live: jax.Array,
                      n_ids: int) -> jax.Array:
    """True at the first slab-order occurrence of each id (last axis).

    Exact intra-slab dedup in O(C log C) — the 2-hop sample repeats ids
    and W beams collide; the legacy path paid an O(C²) pairwise tril
    broadcast for the same mask. ``(id, position)`` pairs pack into one
    int32 so a single-key sort + binary search replaces the variadic
    sort + argsort + invert dance (XLA's CPU variadic sort is a scalar
    loop — the packed form is ~7× faster there, and no worse on TPU);
    past ~2^31/C ids the packing would overflow and the exact two-key
    sort takes over (static branch)."""
    c = cand.shape[-1]
    key = jnp.where(live, cand, n_ids)
    pos = jnp.broadcast_to(jnp.arange(c, dtype=jnp.int32), key.shape)
    if (n_ids + 1) * c >= 2 ** 31:
        # packed key would overflow int32 (and int64 silently truncates
        # without jax_enable_x64): fall back to the exact two-key sort.
        # Slower per hop, but only reachable past ~2^31/C ids.
        skey, spos = jax.lax.sort((key, pos), num_keys=2)
        prev = jnp.concatenate(
            [jnp.full(skey.shape[:-1] + (1,), -2, skey.dtype),
             skey[..., :-1]], axis=-1)
        first_sorted = skey != prev
        inv = jnp.argsort(spos, axis=-1)
        return jnp.take_along_axis(first_sorted, inv, axis=-1)
    packed = key * c + pos
    sp = jnp.sort(packed, axis=-1)
    # leftmost occurrence of each key: unrolled binary search over the
    # packed keys (cheaper than vmapped searchsorted on CPU)
    tgt = key * c
    lo = jnp.zeros_like(tgt)
    hi = jnp.full_like(tgt, c)
    # c.bit_length() halvings collapse the [lo, hi) range of width c to
    # empty; one fewer leaves a 1-wide range when c is a power of two
    for _ in range(c.bit_length()):
        mid = (lo + hi) >> 1
        v = jnp.take_along_axis(sp, mid, axis=-1)
        right = v < tgt
        lo = jnp.where(right, mid + 1, lo)
        hi = jnp.where(right, hi, mid)
    firstpos = jnp.take_along_axis(sp, jnp.minimum(lo, c - 1), axis=-1) % c
    return firstpos == pos


def _slab_pq(codes: jax.Array, ids: jax.Array, tables: jax.Array) -> jax.Array:
    """Batched ADC distances for a gathered candidate slab.

    codes (N, M); ids (B, S); tables (B, M, K) -> (B, S) float32.
    Delegates to the single bitwise-pinned gather+reduce in
    ``kernels.ref.adc_slab_ref`` (== ``pq.adc_lookup`` values)."""
    from repro.kernels.ref import adc_slab_ref
    return adc_slab_ref(codes[ids], tables)


# ---------------------------------------------------------------------------
# Pipelined search state
# ---------------------------------------------------------------------------

class QueryCtx(NamedTuple):
    """Per-query constants of one search call (leading dim B).

    Built once by :func:`init_search`. The bucketed driver gathers query
    rows out of it when compacting stragglers, so every per-query input
    the hop loop reads must live here rather than be re-derived inside
    the loop."""
    queries: jax.Array        # (B, D) float32
    tables: jax.Array         # (B, M, ksub) ADC distance tables
    qf: QueryFilter           # batched filter pytree
    merged_tbl: jax.Array     # (B, ceil((n_ids+1)/32)) int32 word-packed
                              # rare-list bitmap ((B, 1) dummy outside
                              # spec_in) — see selectors.merged_table_words


class HopState(NamedTuple):
    """Per-query mutable search state carried across hops (leading dim B).

    ``cur_ids``/``cur_live`` hold the *already-selected* next frontier
    whose record fetch is in flight (cross-hop prefetch): the loop body
    consumes the carried slab, merges, selects the following frontier and
    issues its fetch at the END of the body. No hop-loop operation mixes
    query rows, so gathering/scattering rows of this pytree (straggler
    compaction) leaves each query's trajectory bit-identical."""
    pool_ids: jax.Array       # (B, P) int32
    pool_key: jax.Array       # (B, P) float32, key-ascending
    pool_exp: jax.Array       # (B, P) bool
    visited: jax.Array        # (B, n_slots // 32) int32 bit-words
                              # (kernels/or_scatter.py sets, shift+mask
                              # reads — 8× smaller than the former
                              # byte-per-slot bool table)
    res_ids: jax.Array        # (B, res_cap) int32
    res_d: jax.Array          # (B, res_cap) float32
    res_valid: jax.Array      # (B, res_cap) bool
    vtop: jax.Array           # (B, l_valid) float32 sorted valid top-l
    n_okc: jax.Array          # (B,) int32
    counters: jax.Array       # (B, 7) int32: io, dist, approx, hops,
                              #               faults, retries, degraded
    active: jax.Array         # (B,) bool
    cur_ids: jax.Array        # (B, W) int32 — prefetched frontier
    cur_live: jax.Array       # (B, W) bool


def _select_frontier(pool_ids, pool_key, pool_exp, active, W: int, P: int):
    """Best-W unexplored pool rows (sorted pool ⇒ one top_k), marked
    explored — gated by ``active`` exactly like the pre-pipelined loop
    head. Returns (cur_ids, cur_live, pool_exp')."""
    B = pool_ids.shape[0]
    bW = jnp.arange(B, dtype=jnp.int32)[:, None]
    masked = jnp.where(pool_exp, BIG, pool_key)
    negk, sel = jax.lax.top_k(-masked, W)                  # (B, W)
    cur_ids = jnp.take_along_axis(pool_ids, sel, 1)
    cur_live = (-negk < BIG) & active[:, None]
    pool_exp = pool_exp.at[
        bW, jnp.where(active[:, None], sel, P)].set(True, mode="drop")
    return cur_ids, cur_live, pool_exp


def _init(store, codes, codebook, mem, qfilters, queries, entry, params,
          distance_fn, entries):
    """Seed the pool/visited/result state and select the first frontier."""
    p = params
    l_valid = p.l_valid or p.l_search
    P, W = p.l_search, p.beam_width
    res_cap = p.max_hops * W                     # explored-record buffer
    B, D = queries.shape
    n_ids = codes.shape[0]
    n_slots, _ = _visited_spec(n_ids)
    if entries is None:
        entries = jnp.full((B, 1), entry, jnp.int32)
    E = entries.shape[1]
    assert E <= P, "entry seeds exceed the pool length"

    tables = jax.vmap(lambda q: pq_mod.distance_table(codebook, q))(queries)
    if p.mode == "spec_in":
        # rare-list membership as a per-query word-packed bitmap, built
        # once: one OR-scatter replaces a (B, W·C)-wide binary search
        # over the CAP-length merged list every hop
        # (selectors.merged_table_words)
        merged_tbl = merged_table_words(qfilters, n_ids)
    else:
        merged_tbl = jnp.zeros((B, 1), jnp.int32)

    # ---- entry seeding (pool kept key-ascending from the start) ----
    ent_valid = entries >= 0
    safe_ent = jnp.where(ent_valid, entries, 0)
    entry_d = jax.vmap(distance_fn)(codes[safe_ent], tables)       # (B, E)
    entry_ok = jax.vmap(is_member_approx, in_axes=(0, 0, None))(
        qfilters, safe_ent, mem) & ent_valid
    entry_key = jnp.where(
        ent_valid, entry_d + jnp.where(entry_ok, 0.0, INVALID_PENALTY), BIG)
    order0 = jnp.argsort(entry_key, axis=1)
    pool_ids = jnp.full((B, P), -1, jnp.int32).at[:, :E].set(
        jnp.take_along_axis(jnp.where(ent_valid, entries, -1), order0, 1))
    pool_key = jnp.full((B, P), BIG, jnp.float32).at[:, :E].set(
        jnp.take_along_axis(entry_key, order0, 1))
    pool_exp = jnp.ones((B, P), jnp.bool_).at[:, :E].set(
        jnp.take_along_axis(~ent_valid, order0, 1))

    # n_slots is 2^bits with bits >= 8, so the word table divides evenly;
    # the n_slots sentinel is out of range and drops in the OR-scatter
    visited = kops.or_scatter(
        jnp.zeros((B, n_slots // 32), jnp.int32),
        jnp.where(ent_valid, _visited_slot(safe_ent, n_ids), n_slots))

    res_ids = jnp.full((B, res_cap), -1, jnp.int32)
    res_d = jnp.full((B, res_cap), BIG, jnp.float32)
    res_valid = jnp.zeros((B, res_cap), jnp.bool_)
    vtop = jnp.full((B, l_valid), BIG, jnp.float32)   # sorted valid top-l
    n_okc = jnp.zeros((B,), jnp.int32)
    # io, dist_comps, approx, hops, faults, retries, degraded
    counters = jnp.zeros((B, 7), jnp.int32)
    active = jnp.any(~pool_exp & (pool_key < BIG), axis=1)

    cur_ids, cur_live, pool_exp = _select_frontier(
        pool_ids, pool_key, pool_exp, active, W, P)
    st = HopState(pool_ids, pool_key, pool_exp, visited, res_ids, res_d,
                  res_valid, vtop, n_okc, counters, active, cur_ids,
                  cur_live)
    return QueryCtx(queries, tables, qfilters, merged_tbl), st


def _hop_step(store, codes, mem, params, distance_fn, fetch_fn, ctx, mc,
              st, rec) -> "HopState":
    """Consume the in-flight record slab for one hop, merge, and select
    the next frontier. Steps keep the pre-pipelined numbering (the fetch
    that used to be step 2 now happens at the end of the previous
    iteration — same records, same counters, earlier issue)."""
    p = params
    l_valid = p.l_valid or p.l_search
    P, W = p.l_search, p.beam_width
    R = store.degree
    Rd = store.dense_degree if p.mode == "spec_in" else 0
    C = R + Rd                                   # candidates per beam row
    res_cap = p.max_hops * W
    rec_pages = store.pages_dense if p.mode == "spec_in" else store.pages_std
    n_ids = codes.shape[0]
    n_slots, _ = _visited_spec(n_ids)
    (pool_ids, pool_key, pool_exp, visited, res_ids, res_d, res_valid,
     vtop, n_okc, counters, active, cur_ids, cur_live) = st
    queries, tables, qfilters, merged_tbl = ctx
    B, D = queries.shape
    bW = jnp.arange(B, dtype=jnp.int32)[:, None]
    w_iota = jnp.arange(W, dtype=jnp.int32)[None, :]
    is_direct = jnp.concatenate(
        [jnp.ones((R,), bool), jnp.zeros((Rd,), bool)])
    hops = counters[:, 3]

    # ---- 2'. the carried slab (fetched at the end of the previous
    # iteration / by the loop prologue) ----
    vecs = rec["vectors"].reshape(B, W, D)
    nbrs = rec["neighbors"].reshape(B, W, R)
    rl = rec["rec_labels"].reshape(B, W, -1)
    rv = rec["rec_values"].reshape(B, W, -1)
    io = counters[:, 0] + jnp.sum(cur_live, axis=1) * rec_pages

    # the fused kernel computes the ADC distance itself (bitwise equal
    # to pq.adc_lookup); a non-default distance_fn routes every slab
    # through the caller's function instead, keeping A/B parity with
    # the oracle — resolved statically, no cost on the default path
    default_dist = distance_fn is pq_mod.adc_lookup

    def slab_dist(ids_slab):
        if default_dist:
            return _slab_pq(codes, ids_slab, tables)
        return jax.vmap(distance_fn)(codes[ids_slab], tables)

    # ---- 2''. fault ladder on the slab read (core/faults.py) ----
    # Retry → hedge → degrade. Every decision is a stateless hash of
    # (record id, that query's own hop counter, attempt), so the
    # bucketed compaction driver can gather rows into any order and no
    # draw changes — pipelined stays bit-identical to single-shot under
    # the same plan. Rows whose every attempt drew bad are "degraded".
    plan = p.fault_plan
    faults_c = counters[:, 4]
    retries_c = counters[:, 5]
    degraded_c = counters[:, 6]
    if plan is not None and plan.reads_faulty:
        ids_safe = jnp.where(cur_live, cur_ids, 0)
        hcol = hops[:, None]
        pending = (faults_mod.read_attempt_bad(ids_safe, hcol, 0, plan)
                   & cur_live)
        n_faults = jnp.sum(pending, axis=1)
        n_retries = jnp.zeros_like(n_faults)
        for a in range(1, plan.attempts):
            n_retries = n_retries + jnp.sum(pending, axis=1)
            pending = pending & faults_mod.read_attempt_bad(
                ids_safe, hcol, a, plan)
            n_faults = n_faults + jnp.sum(pending, axis=1)
        degraded_rows = pending
        spikes = faults_mod.read_spike(ids_safe, hcol, plan) & cur_live
        faults_c = faults_c + n_faults + jnp.sum(spikes, axis=1)
        retries_c = retries_c + n_retries
        degraded_c = degraded_c + jnp.sum(degraded_rows, axis=1)
        io = io + n_retries * rec_pages        # each retry re-reads pages
    else:
        degraded_rows = None

    # ---- 3. re-rank + piggybacked exact verification ----
    diff = vecs - queries[:, None, :]
    ex_d = jnp.where(cur_live, jnp.sum(diff * diff, axis=-1), BIG)
    ex_ok = jax.vmap(is_member)(qfilters, rl, rv) & cur_live
    if degraded_rows is not None:
        # a degraded row never saw its record: fall back to the
        # in-memory tier — ADC distance and approx membership, a
        # no-false-negative superset, so a valid result is approximated
        # rather than dropped (verification stays post-hoc per paper)
        deg_d = jnp.where(cur_live, slab_dist(ids_safe), BIG)
        deg_ok = jax.vmap(is_member_approx, in_axes=(0, 0, None))(
            qfilters, ids_safe, mem) & cur_live
        ex_d = jnp.where(degraded_rows, deg_d, ex_d)
        ex_ok = jnp.where(degraded_rows, deg_ok, ex_ok)
    pos = jnp.where(active[:, None], hops[:, None] * W + w_iota, res_cap)
    res_ids = res_ids.at[bW, pos].set(
        jnp.where(cur_live, cur_ids, -1), mode="drop")
    res_d = res_d.at[bW, pos].set(ex_d, mode="drop")
    res_valid = res_valid.at[bW, pos].set(ex_ok, mode="drop")
    # incremental early-termination bound: merge the W new verified
    # distances into the sorted top-l_valid buffer (no res re-sort)
    vtop = -jax.lax.top_k(
        -jnp.concatenate([vtop, jnp.where(ex_ok, ex_d, BIG)], axis=1),
        l_valid)[0]
    n_okc = n_okc + jnp.sum(ex_ok, axis=1)

    # ---- 4. candidate slab + visited-set dedup ----
    if p.mode == "spec_in":
        dn = rec["dense_neighbors"].reshape(B, W, Rd)
        cand = jnp.concatenate([nbrs, dn], axis=2)     # (B, W, C)
    else:
        cand = nbrs
    expand_live = (cur_live if degraded_rows is None
                   else cur_live & ~degraded_rows)
    cand = jnp.where(expand_live[:, :, None], cand, -1).reshape(B, W * C)
    live = cand >= 0
    safe_cand = jnp.where(live, cand, 0)
    slots = _visited_slot(safe_cand, n_ids)
    seen = ((jnp.take_along_axis(visited, slots >> 5, axis=1)
             >> (slots & 31)) & 1).astype(jnp.bool_)
    if W == 1 and "cand_first" in rec:
        # W=1: the slab is exactly one record's candidate list, whose
        # intra-slab duplicate structure is query-independent — read the
        # precomputed mask off the record (records.candidate_first_mask)
        # instead of paying the packed-sort dedup per hop. Bit-identical
        # to _first_occurrence on the one-row slab; the first C columns of
        # the [nbrs ++ dense] mask are the nbrs-only mask (prefix
        # property), so post/strict slice cleanly.
        first = rec["cand_first"].reshape(B, -1)[:, :C]
    else:
        first = _first_occurrence(cand, live, n_ids)
    fresh = live & ~seen & first

    # ---- 5. fused candidate pass (distance + membership + key) ----
    if p.mode == "post":
        ok = fresh
        key_slab = slab_dist(safe_cand)
        approx_c = counters[:, 2]
    elif p.mode == "spec_in":
        if default_dist:
            bl_i32, bc_i32, (f_scal, f_om, f_rf, f_blo, f_bhi) = mc
            in_merged = ((jnp.take_along_axis(merged_tbl, safe_cand >> 5,
                                              axis=1)
                          >> (safe_cand & 31)) & 1).astype(jnp.bool_)
            key_slab, ok_approx = kops.hop_fused(
                codes[safe_cand], bl_i32[safe_cand], bc_i32[safe_cand],
                in_merged, tables, f_scal, f_om, f_rf, f_blo, f_bhi)
        else:
            ok_approx = jax.vmap(is_member_approx, in_axes=(0, 0, None))(
                qfilters, safe_cand, mem)
            key_slab = slab_dist(safe_cand) + jnp.where(
                ok_approx, 0.0, INVALID_PENALTY)
        ok = ok_approx & fresh
        approx_c = counters[:, 2] + jnp.sum(live, axis=1)
    else:  # strict_in: read every fresh neighbor's attrs from "SSD"
        if getattr(fetch_fn, "wants_ctx", False):
            # disk tier: consult the device-resident bloom/bucket words
            # BEFORE any attribute page is read (paper's gated I/O). The
            # gate is a no-false-negative superset, so a gated-out row's
            # poisoned attributes (labels −1, values NaN) fail exact
            # membership exactly where the real attributes would —
            # bit-identical results, measurably fewer page reads
            # (snapshot counters: gated_skips / attr_probes)
            gate = jax.vmap(is_member_approx, in_axes=(0, 0, None))(
                qfilters, safe_cand, mem)
            nrec = fetch_fn(store, safe_cand.reshape(-1),
                            need=fresh.reshape(-1),
                            gate=gate.reshape(-1), attrs_only=True)
        else:
            nrec = fetch_fn(store, safe_cand.reshape(-1))
        n_rl = nrec["rec_labels"].reshape(B, W * C, -1)
        n_rv = nrec["rec_values"].reshape(B, W * C, store.n_fields)
        ok = jax.vmap(is_member)(qfilters, n_rl, n_rv) & fresh
        io = io + jnp.sum(fresh, axis=1)               # 1 page / neighbor
        key_slab = slab_dist(safe_cand)
        approx_c = counters[:, 2]

    # ---- 6. slot selection: up to R approx-valid, bridge back-fill ----
    if p.mode == "spec_in":
        okr = ok.reshape(B, W, C)
        fill = (fresh.reshape(B, W, C) & ~okr
                & is_direct[None, None, :])
        rank_ok = jnp.cumsum(okr.astype(jnp.int32), axis=2) - 1
        rank_fill = jnp.cumsum(fill.astype(jnp.int32), axis=2) - 1
        n_ok_row = jnp.sum(okr, axis=2, keepdims=True)
        order_key = jnp.where(
            okr, rank_ok.astype(jnp.float32),
            jnp.where(fill, (n_ok_row + rank_fill).astype(jnp.float32),
                      BIG))
        _, take = jax.lax.top_k(-order_key, R)         # (B, W, R)
        sel_ok = jnp.take_along_axis(okr, take, 2).reshape(B, W * R)
        sel_fill = jnp.take_along_axis(fill, take, 2).reshape(B, W * R)
        sel_live = sel_ok | sel_fill
        sel_ids = jnp.take_along_axis(
            cand.reshape(B, W, C), take, 2).reshape(B, W * R)
        sel_key = jnp.take_along_axis(
            key_slab.reshape(B, W, C), take, 2).reshape(B, W * R)
        new_ids = jnp.where(sel_live, sel_ids, -1)
        new_key = jnp.where(sel_live, sel_key, BIG)
    else:
        sel_live = ok
        new_ids = jnp.where(ok, cand, -1)
        new_key = jnp.where(ok, key_slab, BIG)
    dist_c = counters[:, 1] + jnp.sum(sel_live, axis=1)
    # mark *admitted* candidates visited (pool entries are marked from
    # init, explored ones were admitted earlier): a fresh candidate
    # that loses slot selection stays unmarked and may be re-proposed
    # through another parent — the legacy pool/explored-membership
    # dedup behaves the same way
    visited = kops.or_scatter(
        visited,
        jnp.where(sel_live,
                  _visited_slot(jnp.where(sel_live, new_ids, 0), n_ids),
                  n_slots))

    # ---- 7. sorted-pool merge: concatenate + one top_k ----
    all_key = jnp.concatenate([pool_key, new_key], axis=1)
    negm, midx = jax.lax.top_k(-all_key, P)
    pool_key = -negm
    pool_ids = jnp.take_along_axis(
        jnp.concatenate([pool_ids, new_ids], axis=1), midx, 1)
    pool_exp = jnp.take_along_axis(
        jnp.concatenate(
            [pool_exp, jnp.zeros(new_ids.shape, jnp.bool_)], axis=1),
        midx, 1)

    # ---- 8. per-query termination ----
    hops_new = hops + active.astype(jnp.int32)
    frontier = jnp.any(~pool_exp & (pool_key < BIG), axis=1)
    best_unexp = jnp.min(jnp.where(pool_exp, BIG, pool_key), axis=1)
    settled = (n_okc >= l_valid) & (best_unexp > vtop[:, l_valid - 1])
    active = active & (hops_new < p.max_hops) & frontier & ~settled
    counters = jnp.stack([io, dist_c, approx_c, hops_new, faults_c,
                          retries_c, degraded_c], axis=1)

    # ---- 1'. select the NEXT frontier (its fetch is issued right after
    # this step returns — the cross-hop prefetch) ----
    cur_ids, cur_live, pool_exp = _select_frontier(
        pool_ids, pool_key, pool_exp, active, W, P)
    return HopState(pool_ids, pool_key, pool_exp, visited, res_ids, res_d,
                    res_valid, vtop, n_okc, counters, active, cur_ids,
                    cur_live)


def _hop_loop(store, codes, mem, params, distance_fn, fetch_fn, ctx, st,
              n_hops, active_any=jnp.any) -> "HopState":
    """Run up to ``n_hops`` double-buffered hops over ``st``.

    The body consumes the carried slab, then issues the next frontier's
    fetch as its last action — the slab rides the loop carry, so hop
    t+1's gather sits behind hop t's candidate pass in program order
    (``prefetch_depth`` = 2 slabs in flight).

    ``active_any`` reduces the per-row active mask to the loop-level
    "keep hopping" scalar. It is evaluated in the loop *body* and carried
    (identical value to re-deriving it in the condition — the state is
    unchanged between body end and condition), because the sharded runner
    substitutes a psum-based global any and collectives are not legal in
    a ``while_loop`` condition: under ``shard_map`` every shard must take
    the same number of iterations, with settled shards hopping inertly
    (inactive rows are exact fixed points of the hop step) until the
    *global* active set drains."""
    p = params
    if p.mode == "spec_in" and distance_fn is pq_mod.adc_lookup:
        bl_i32, bc_i32 = kernel_view(mem)
        mc = (bl_i32, bc_i32, kernel_filter_params(ctx.qf))
    else:
        mc = None

    # extended fetch protocol (storage/disk.py): a fetch_fn marked
    # ``wants_ctx`` receives per-row hop counters (the disk tier's fault
    # draws must key on the same (id, hop) pairs as the traced ladder),
    # row liveness (dead rows skip real I/O), and the record flavor —
    # resolved statically, so the default local/distributed fetch traces
    # exactly as before
    ctx_fetch = getattr(fetch_fn, "wants_ctx", False)

    def issue(st):
        ids = jnp.where(st.cur_live, st.cur_ids, 0).reshape(-1)
        if ctx_fetch:
            return fetch_fn(store, ids,
                            hops=jnp.repeat(st.counters[:, 3],
                                            p.beam_width),
                            live=st.cur_live.reshape(-1),
                            dense=(p.mode == "spec_in"))
        return fetch_fn(store, ids)

    def cond(carry):
        st, _, i, g = carry
        return g & (i < n_hops)

    def body(carry):
        st, rec, i, _ = carry
        st = _hop_step(store, codes, mem, p, distance_fn, fetch_fn, ctx,
                       mc, st, rec)
        return st, issue(st), i + 1, active_any(st.active)

    st, _, _, _ = jax.lax.while_loop(
        cond, body, (st, issue(st), jnp.int32(0), active_any(st.active)))
    return st


def _finalize(st: "HopState", params: SearchParams) -> SearchResult:
    """Top-k verified-valid by exact distance (once, outside the loop)."""
    p = params
    final_key = jnp.where(st.res_valid, st.res_d, BIG)
    _, order = jax.lax.top_k(-final_key, p.k)
    top_valid = jnp.take_along_axis(st.res_valid, order, 1)
    out_ids = jnp.where(top_valid,
                        jnp.take_along_axis(st.res_ids, order, 1), -1)
    out_d = jnp.where(top_valid, jnp.take_along_axis(st.res_d, order, 1),
                      jnp.inf)
    n_valid = jnp.sum(st.res_valid, axis=1)
    n_explored = jnp.sum(st.res_ids >= 0, axis=1)
    fp = jnp.sum((st.res_ids >= 0) & ~st.res_valid, axis=1)
    c = st.counters
    return SearchResult(out_ids, out_d, c[:, 0], c[:, 3], c[:, 1], c[:, 2],
                        n_valid, fp, n_explored, c[:, 4], c[:, 5], c[:, 6])


# ---------------------------------------------------------------------------
# Fused batched pipeline (single-shot jit)
# ---------------------------------------------------------------------------

@functools.partial(
    jax.jit,
    static_argnames=("params", "distance_fn", "fetch_fn"))
def filtered_search(store: RecordStore, codes: jax.Array,
                    codebook: pq_mod.PQCodebook, mem: InMemory,
                    qfilters: QueryFilter, queries: jax.Array, entry: int,
                    params: SearchParams,
                    distance_fn: Callable = pq_mod.adc_lookup,
                    fetch_fn: Callable = local_fetch,
                    entries: jax.Array | None = None) -> SearchResult:
    """Run the filtered beam search for a batch of queries (one jit).

    codes: (N, M) uint8 PQ codes (the replicated in-memory tier — its
    leading dim, not the possibly-sharded record store's, defines the
    global id space).
    qfilters: batched QueryFilter (leading dim B).
    entries: optional (B, E) int32 per-query entry seeds (-1 pad; each row
    must hold distinct ids). Defaults to the shared ``entry`` (medoid).
    Strict in-filtering passes exactly-valid seeds here — the query-time
    analogue of Filtered-DiskANN's precomputed per-label entry points —
    because its valid-only pool dies immediately when the medoid's
    neighborhood contains no valid record.

    ``filtered_search_pipelined`` runs the same init/hop/finalize code
    through the chunked runner with straggler compaction (bit-identical
    results); this single-shot form stays the distributed/shard_map entry
    and the compaction-parity oracle.
    """
    ctx, st = _init(store, codes, codebook, mem, qfilters, queries, entry,
                    params, distance_fn, entries)
    st = _hop_loop(store, codes, mem, params, distance_fn, fetch_fn, ctx,
                   st, params.max_hops)
    return _finalize(st, params)


# ---------------------------------------------------------------------------
# Chunked runner + bucketed straggler-compaction driver
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("params", "distance_fn"))
def init_search(store: RecordStore, codes: jax.Array,
                codebook: pq_mod.PQCodebook, mem: InMemory,
                qfilters: QueryFilter, queries: jax.Array, entry: int,
                params: SearchParams,
                distance_fn: Callable = pq_mod.adc_lookup,
                entries: jax.Array | None = None):
    """Build ``(QueryCtx, HopState)`` for a batch — the seeding half of
    :func:`filtered_search`, exposed so the bucketed driver owns the hop
    loop. Compiles once per (shapes, params)."""
    return _init(store, codes, codebook, mem, qfilters, queries, entry,
                 params, distance_fn, entries)


@functools.partial(jax.jit,
                   static_argnames=("params", "distance_fn", "fetch_fn"),
                   donate_argnames=("st",))
def run_hops(store: RecordStore, codes: jax.Array, mem: InMemory,
             ctx: QueryCtx, st: HopState, n_hops, params: SearchParams,
             distance_fn: Callable = pq_mod.adc_lookup,
             fetch_fn: Callable = local_fetch):
    """Advance every active query by up to ``n_hops`` hops.

    ``n_hops`` is traced, so one compile covers every chunk length at a
    given batch width: the bucket jit cache is keyed only by (bucket
    shapes, params) — asserted by the compile-count test. ``st`` is
    donated: chunk t's state buffers are reused in place by chunk t+1.

    Returns ``(state, active_mask)``. The mask is an int8 *copy* of
    ``state.active`` in its own output buffer (the dtype change forbids
    any aliasing with the donated state), so the driver can dispatch the
    next chunk — consuming ``state`` — and only then read the mask back,
    overlapping the host sync with device work (the async readback)."""
    st = _hop_loop(store, codes, mem, params, distance_fn, fetch_fn, ctx,
                   st, n_hops)
    return st, st.active.astype(jnp.int8)


@functools.partial(jax.jit, static_argnames=("params",))
def finalize_search(st: HopState, params: SearchParams) -> SearchResult:
    """Extract the SearchResult from a settled (or hop-capped) state."""
    return _finalize(st, params)


def _pow2_at_least(n: int) -> int:
    return 1 << max(0, int(n) - 1).bit_length() if n > 1 else 1


def filtered_search_pipelined(store: RecordStore, codes: jax.Array,
                              codebook: pq_mod.PQCodebook, mem: InMemory,
                              qfilters: QueryFilter, queries: jax.Array,
                              entry: int, params: SearchParams,
                              distance_fn: Callable = pq_mod.adc_lookup,
                              fetch_fn: Callable = local_fetch,
                              entries: jax.Array | None = None,
                              hop_chunk: int = DEFAULT_HOP_CHUNK,
                              min_bucket: int = MIN_COMPACT_BUCKET,
                              collect_trace: bool = False,
                              async_readback: bool = True,
                              runner=None):
    """Bucketed host driver: chunked hops + straggler compaction.

    Runs :func:`run_hops` ``hop_chunk`` hops at a time; after every chunk
    the still-active queries are counted on the host and, when they fit a
    smaller power-of-two bucket (≥ ``min_bucket``), compacted into it —
    settled rows fold back into the full-width state, pads (repeats of a
    live row, forced inactive) fill the bucket. Late hops therefore run
    at the straggler-set width instead of full B, while every query's
    trajectory stays bit-identical to single-shot
    :func:`filtered_search` (no hop-loop op mixes rows). Each bucket
    width compiles once and is reused across calls/chunks (the Session
    repeat-search path).

    ``async_readback`` (the default) overlaps the per-chunk host sync
    with device work: the driver dispatches the *next* chunk before
    reading the previous chunk's active mask (``copy_to_host_async``),
    so settle/shrink decisions run one chunk late on a stale mask. This
    is safe bit-wise: ``active`` only ever shrinks, so the stale mask is
    a superset of the truly-active rows, and inactive rows are exact
    fixed points of the hop step — a speculative chunk over a partially
    settled bucket does identical work for live rows and none for
    settled ones. ``async_readback=False`` keeps the synchronous
    reference driver (one blocking readback per chunk).

    ``hop_chunk=0`` falls back to the single-shot jit. With
    ``collect_trace=True`` returns ``(SearchResult, trace)`` where trace
    lists ``{"hop", "active", "bucket"}`` per observed chunk boundary —
    the benchmark's ``--active-trace`` feed (in async mode the
    observations lag dispatch by one chunk).

    ``runner`` (a ``distributed.ShardedSearchRunner``) swaps the hop
    kernel for its shard_map'd equivalent over the mesh-sharded record
    store: init/finalize and this driver's whole compaction/bucket logic
    run unchanged on the replicated query state, only the chunked hop
    call crosses the mesh (``fetch_fn`` is then owned by the runner and
    ignored here). Bucket widths stay divisible by the shard count —
    both are powers of two and ``min_bucket`` is raised to ``n_shards``
    — so every bucket row-shards evenly. Results remain bit-identical to
    the single-device driver.
    """
    if runner is not None:
        min_bucket = max(min_bucket, runner.n_shards)
        if hop_chunk <= 0:
            # single-shot through the sharded runner: one max_hops chunk
            # of the same driver (bit-identical; the runner owns the only
            # sharded hop entry)
            hop_chunk = params.max_hops
    if hop_chunk <= 0:
        res = filtered_search(store, codes, codebook, mem, qfilters,
                              queries, entry, params,
                              distance_fn=distance_fn, fetch_fn=fetch_fn,
                              entries=entries)
        return (res, []) if collect_trace else res
    orig_b = int(queries.shape[0])
    # Quantize the top-level width to the same power-of-two bucket
    # ladder the compaction loop uses: compile keys stay bounded to the
    # widths ``Session.warmup`` tiles, so an arbitrary group size never
    # hits a fresh multi-second jit mid-serve. Pads duplicate row 0 but
    # start inactive — exact fixed points of the hop step, zero extra
    # hops — and their rows are sliced off the result. The padding runs
    # in numpy: eager device ops at the raw width would compile one tiny
    # executable per distinct composition, defeating the quantization.
    B = max(min_bucket, _pow2_at_least(orig_b))
    n_pad = B - orig_b
    if n_pad:
        def _pad(a):
            a = np.asarray(a)
            return np.concatenate(
                [a, np.broadcast_to(a[:1], (n_pad,) + a.shape[1:])], axis=0)
        queries = _pad(queries)
        qfilters = jax.tree_util.tree_map(_pad, qfilters)
        if entries is not None:
            entries = _pad(entries)
    full_ctx, full_st = init_search(store, codes, codebook, mem, qfilters,
                                    queries, entry, params,
                                    distance_fn=distance_fn,
                                    entries=entries)
    if n_pad:
        full_st = full_st._replace(
            active=full_st.active.at[orig_b:].set(False))
    work_ctx, work_st = full_ctx, full_st
    work_map: np.ndarray | None = None   # None ⇒ identity (full width)
    work_valid: np.ndarray | None = None  # non-pad rows of the bucket
    width = B
    hops_done = 0
    trace: list = []

    def hop(ctx, st):
        if runner is not None:
            return runner.run(ctx, st, hop_chunk, params, distance_fn)
        return run_hops(store, codes, mem, ctx, st, hop_chunk, params,
                        distance_fn=distance_fn, fetch_fn=fetch_fn)

    # act: host copy of an active mask; in async mode it may lag work_st
    # by one chunk (a superset of the truly-active rows — see docstring)
    act = np.asarray(work_st.active)     # init-state snapshot, pre-donation
    inflight = None                      # device mask of the newest chunk
    while True:
        n_act = int(act.sum())               # pads are inert (forced off)
        if collect_trace:
            trace.append({"hop": hops_done, "active": n_act,
                          "bucket": width})
        bucket = min(B, max(min_bucket, _pow2_at_least(max(n_act, 1))))
        if n_act and bucket >= width:
            # active set still fills the current bucket: keep hopping
            work_st, mask = hop(work_ctx, work_st)
            hops_done += hop_chunk
            if not async_readback:
                act = np.asarray(mask)
                continue
            mask.copy_to_host_async()
            if inflight is None:
                # prime the one-chunk pipeline: dispatch a second chunk so
                # there is device work to hide the first mask's readback
                work_st, inflight = hop(work_ctx, work_st)
                hops_done += hop_chunk
                inflight.copy_to_host_async()
                act = np.asarray(mask)
            else:
                # read the older in-flight mask while this chunk runs
                act, inflight = np.asarray(inflight), mask
            continue
        # settle or shrink: fold the working rows into the full state
        # (work_st may be one speculative chunk past the observed mask —
        # settled rows are bitwise unchanged by it)
        if work_map is None:
            full_st = work_st
        else:
            sidx = jnp.asarray(
                np.where(work_valid, work_map, B).astype(np.int32))
            full_st = tree_put_rows(full_st, work_st, sidx)
        if n_act == 0:
            break
        # compact the survivors into the next power-of-two bucket; the
        # stale mask over-admits at worst (rows that settled during the
        # speculative chunk ride along as inert valid rows)
        surv = np.flatnonzero(act)
        idx = (work_map[surv] if work_map is not None else surv) \
            .astype(np.int32)
        pads = np.full(bucket - idx.size, idx[0], np.int32)
        work_map = np.concatenate([idx, pads])
        work_valid = np.arange(bucket) < idx.size
        gidx = jnp.asarray(work_map)
        work_ctx = tree_take_rows(full_ctx, gidx)
        work_st = tree_take_rows(full_st, gidx)
        work_st = work_st._replace(
            active=work_st.active & jnp.asarray(work_valid))
        width = bucket
        inflight = None
        if async_readback:
            # don't block on the compacted state's mask: every carried
            # row was stale-active, so assume all live and let the next
            # iteration dispatch at this width (an all-settled carry makes
            # that chunk an immediate-exit no-op)
            act = work_valid.copy()
            continue
        work_st, mask = hop(work_ctx, work_st)
        hops_done += hop_chunk
        act = np.asarray(mask)
    res = finalize_search(full_st, params)
    if n_pad:
        # slice on the host: a device-side slice at the raw width would
        # compile per composition (same reason the padding is numpy)
        res = SearchResult(*(np.asarray(a)[:orig_b] for a in res))
    return (res, trace) if collect_trace else res


# ---------------------------------------------------------------------------
# jnp reference oracle (same semantics, naive primitives)
# ---------------------------------------------------------------------------

@functools.partial(
    jax.jit,
    static_argnames=("params", "distance_fn", "fetch_fn"))
def filtered_search_ref(store: RecordStore, codes: jax.Array,
                        codebook: pq_mod.PQCodebook, mem: InMemory,
                        qfilters: QueryFilter, queries: jax.Array, entry: int,
                        params: SearchParams,
                        distance_fn: Callable = pq_mod.adc_lookup,
                        fetch_fn: Callable = local_fetch,
                        entries: jax.Array | None = None) -> SearchResult:
    """The A/B oracle for :func:`filtered_search`.

    Same hop semantics — an *exact* ever-proposed visited set, the same
    admission keys and early termination — expressed with the naive
    primitives the fused path replaces: ``vmap`` over queries, a full
    argsort pool merge, a full re-sort of the explored buffer in the loop
    condition, and separate unfused distance/membership gathers. Parity
    bar: identical ``io_pages``/``explored`` counters, recall within 1%.
    """
    p = params
    l_valid = p.l_valid or p.l_search
    P, W = p.l_search, p.beam_width
    R = store.degree
    Rd = store.dense_degree if p.mode == "spec_in" else 0
    res_cap = p.max_hops * W
    rec_pages = store.pages_dense if p.mode == "spec_in" else store.pages_std
    n_ids = codes.shape[0]
    if entries is None:
        entries = jnp.full((queries.shape[0], 1), entry, jnp.int32)

    def one(q, qf, ent):
        table = pq_mod.distance_table(codebook, q)            # (M, ksub)

        e_n = ent.shape[0]
        ent_valid = ent >= 0
        safe_ent = jnp.where(ent_valid, ent, 0)
        entry_d = distance_fn(codes[safe_ent], table)         # (E,)
        entry_ok = is_member_approx(qf, safe_ent, mem) & ent_valid
        entry_key = jnp.where(
            ent_valid, entry_d + jnp.where(entry_ok, 0.0, INVALID_PENALTY),
            BIG)

        pool_ids = jnp.full((P,), -1, jnp.int32).at[:e_n].set(
            jnp.where(ent_valid, ent, -1))
        pool_key = jnp.full((P,), BIG, jnp.float32).at[:e_n].set(entry_key)
        explored = jnp.ones((P,), jnp.bool_).at[:e_n].set(~ent_valid)
        seen = jnp.zeros((n_ids,), jnp.bool_).at[
            jnp.where(ent_valid, safe_ent, n_ids)].set(True, mode="drop")

        res_ids = jnp.full((res_cap,), -1, jnp.int32)
        res_d = jnp.full((res_cap,), BIG, jnp.float32)
        res_valid = jnp.zeros((res_cap,), jnp.bool_)

        counters = jnp.zeros((4,), jnp.int32)    # io, dist_comps, approx, hops

        def cond(state):
            (pool_ids, pool_key, explored, seen, res_ids, res_d, res_valid,
             counters) = state
            hops = counters[3]
            frontier = jnp.any(~explored[:P] & (pool_key[:P] < BIG))
            # paper early termination: top-l_valid verified & no closer
            # frontier (full re-sort every iteration — the oracle keeps the
            # naive form the fused path's incremental bound replaces)
            n_ok = jnp.sum(res_valid)
            kth = jnp.sort(jnp.where(res_valid, res_d, BIG))[
                jnp.minimum(l_valid, res_cap) - 1]
            best_unexp = jnp.min(jnp.where(explored, BIG, pool_key))
            settled = (n_ok >= l_valid) & (best_unexp > kth)
            return (hops < p.max_hops) & frontier & ~settled

        def body(state):
            (pool_ids, pool_key, explored, seen, res_ids, res_d, res_valid,
             counters) = state
            # ---- 1. pick best-W unexplored (by priority key) ----
            masked = jnp.where(explored, BIG, pool_key)
            _, sel = jax.lax.top_k(-masked, W)
            cur_ids = pool_ids[sel]                            # (W,)
            cur_live = masked[sel] < BIG
            explored = explored.at[sel].set(True)
            safe_cur = jnp.where(cur_live, cur_ids, 0)

            # ---- 2. fetch records (vector + neighbors + attrs: one I/O) ----
            rec = fetch_fn(store, safe_cur)
            vecs = rec["vectors"]                              # (W, D)
            nbrs = rec["neighbors"]                            # (W, R)
            rl = rec["rec_labels"]                             # (W, ML)
            rv = rec["rec_values"]                             # (W, F)
            io = counters[0] + jnp.sum(cur_live) * rec_pages

            # ---- 3. re-rank + piggybacked exact verification ----
            ex_d = jnp.where(cur_live, _exact_sq_dist(vecs, q), BIG)
            ex_ok = is_member(qf, rl, rv) & cur_live
            hops = counters[3]
            start = hops * W
            res_ids = jax.lax.dynamic_update_slice(
                res_ids, jnp.where(cur_live, cur_ids, -1), (start,))
            res_d = jax.lax.dynamic_update_slice(res_d, ex_d, (start,))
            res_valid = jax.lax.dynamic_update_slice(res_valid, ex_ok,
                                                     (start,))

            # ---- 4. candidate generation per mode ----
            if p.mode == "spec_in":
                dn = rec["dense_neighbors"]                    # (W, Rd)
                cand = jnp.concatenate([nbrs, dn], axis=1)     # (W, R+Rd)
                is_direct = jnp.concatenate(
                    [jnp.ones((W, R), bool), jnp.zeros((W, Rd), bool)],
                    axis=1)
            else:
                cand = nbrs
                is_direct = jnp.ones((W, R), bool)
            cand = jnp.where(cur_live[:, None], cand, -1)
            live = cand >= 0
            safe_cand = jnp.where(live, cand, 0)

            # exact visited set (ever-admitted ∪ entries) + intra-slab
            # first-occurrence — the O(N)-memory oracle form of the fused
            # path's hashed slot table
            c = cand.shape[1]
            first = _first_occurrence(
                cand.reshape(-1), live.reshape(-1), n_ids).reshape(W, c)
            fresh = live & ~seen[safe_cand] & first

            approx_n = jnp.sum(live)
            if p.mode == "post":
                ok = fresh
                counters_approx = counters[2]
            elif p.mode == "spec_in":
                ok = is_member_approx(qf, safe_cand, mem) & fresh
                counters_approx = counters[2] + approx_n
            else:  # strict_in: read every fresh neighbor's attrs from "SSD"
                nrec = fetch_fn(store, safe_cand.reshape(-1))
                n_rl = nrec["rec_labels"].reshape(W, R, -1)    # (W, R, ML)
                n_rv = nrec["rec_values"].reshape(W, R, store.n_fields)
                ok = is_member(qf, n_rl, n_rv) & fresh
                io = io + jnp.sum(fresh)                       # 1 page / nbr
                counters_approx = counters[2]

            # ---- 5. slot selection: up to R approx-valid, bridge fill ----
            if p.mode == "spec_in":
                # first-come order (cheap, matches Table-1 compute accounting)
                rank_ok = jnp.cumsum(ok.astype(jnp.int32), axis=1) - 1
                fill = fresh & ~ok & is_direct
                rank_fill = jnp.cumsum(fill.astype(jnp.int32), axis=1) - 1
                n_ok_row = jnp.sum(ok, axis=1, keepdims=True)
                order_key = jnp.where(
                    ok, rank_ok.astype(jnp.float32),
                    jnp.where(fill,
                              (n_ok_row + rank_fill).astype(jnp.float32),
                              BIG))
                _, take = jax.lax.top_k(-order_key, R)          # (W, R)
                sel_ids = jnp.take_along_axis(cand, take, axis=1)
                sel_ok = jnp.take_along_axis(ok, take, axis=1)
                sel_fill = jnp.take_along_axis(fill, take, axis=1)
                sel_live = sel_ok | sel_fill
            else:
                sel_ids, sel_ok, sel_live = cand, ok, ok

            # ---- 6. PQ distances for selected candidates (unfused) ----
            flat_ids = sel_ids.reshape(-1)
            flat_live = sel_live.reshape(-1)
            flat_ok = sel_ok.reshape(-1)
            pq_d = distance_fn(codes[jnp.where(flat_live, flat_ids, 0)],
                               table)
            key = pq_d + jnp.where(flat_ok, 0.0, INVALID_PENALTY)
            key = jnp.where(flat_live, key, BIG)
            dist_comps = counters[1] + jnp.sum(flat_live)
            seen = seen.at[jnp.where(flat_live, flat_ids, n_ids)].set(
                True, mode="drop")

            # ---- 7. merge into pool (full argsort — the naive form) ----
            all_ids = jnp.concatenate(
                [pool_ids, jnp.where(flat_live, flat_ids, -1)])
            all_key = jnp.concatenate([pool_key, key])
            all_exp = jnp.concatenate([explored,
                                       jnp.zeros_like(flat_live)])
            order = jnp.argsort(all_key)[:P]
            new_counters = jnp.stack([io, dist_comps, counters_approx,
                                      hops + 1])
            return (all_ids[order], all_key[order], all_exp[order], seen,
                    res_ids, res_d, res_valid, new_counters)

        state = (pool_ids, pool_key, explored, seen, res_ids, res_d,
                 res_valid, counters)
        state = jax.lax.while_loop(cond, body, state)
        (pool_ids, pool_key, explored, seen, res_ids, res_d, res_valid,
         counters) = state

        # ---- final: top-k verified-valid by exact distance ----
        final_key = jnp.where(res_valid, res_d, BIG)
        order = jnp.argsort(final_key)[:p.k]
        out_ids = jnp.where(res_valid[order], res_ids[order], -1)
        out_d = jnp.where(res_valid[order], res_d[order], jnp.inf)
        n_valid = jnp.sum(res_valid)
        n_explored = jnp.sum(res_ids >= 0)
        fp = jnp.sum((res_ids >= 0) & ~res_valid)
        zero = jnp.int32(0)     # oracle has no fault plan: clean counters
        return (out_ids, out_d, counters[0], counters[3], counters[1],
                counters[2], n_valid, fp, n_explored, zero, zero, zero)

    outs = jax.vmap(one)(queries, qfilters, entries)
    return SearchResult(*outs)


# ---------------------------------------------------------------------------
# Pre-fused-pipeline implementation (benchmark baseline)
# ---------------------------------------------------------------------------

@functools.partial(
    jax.jit,
    static_argnames=("params", "distance_fn", "fetch_fn"))
def filtered_search_legacy(store: RecordStore, codes: jax.Array,
                           codebook: pq_mod.PQCodebook, mem: InMemory,
                           qfilters: QueryFilter, queries: jax.Array,
                           entry: int, params: SearchParams,
                           distance_fn: Callable = pq_mod.adc_lookup,
                           fetch_fn: Callable = local_fetch,
                           entries: jax.Array | None = None) -> SearchResult:
    """The pre-fused-pipeline search, kept verbatim as the benchmark
    baseline (``benchmarks/bench_search.py`` asserts the fused path's
    speedup against it). Its hop loop does quadratic work: pairwise dedup
    broadcasts against the pool and the whole explored buffer, a full
    argsort merge, and a full explored-buffer re-sort in the loop
    condition. Dedup semantics differ slightly from the fused path (a
    candidate dropped from the pool may be re-proposed), so counters are
    not comparable — use :func:`filtered_search_ref` for A/B parity.
    """
    p = params
    l_valid = p.l_valid or p.l_search
    P, W = p.l_search, p.beam_width
    R = store.degree
    Rd = store.dense_degree if p.mode == "spec_in" else 0
    res_cap = p.max_hops * W                     # explored-record buffer
    rec_pages = store.pages_dense if p.mode == "spec_in" else store.pages_std
    if entries is None:
        entries = jnp.full((queries.shape[0], 1), entry, jnp.int32)

    def one(q, qf, ent):
        table = pq_mod.distance_table(codebook, q)            # (M, ksub)

        e_n = ent.shape[0]
        ent_valid = ent >= 0
        safe_ent = jnp.where(ent_valid, ent, 0)
        entry_d = distance_fn(codes[safe_ent], table)         # (E,)
        entry_ok = is_member_approx(qf, safe_ent, mem) & ent_valid
        entry_key = jnp.where(
            ent_valid, entry_d + jnp.where(entry_ok, 0.0, INVALID_PENALTY),
            BIG)

        pool_ids = jnp.full((P,), -1, jnp.int32).at[:e_n].set(
            jnp.where(ent_valid, ent, -1))
        pool_key = jnp.full((P,), BIG, jnp.float32).at[:e_n].set(entry_key)
        explored = jnp.ones((P,), jnp.bool_).at[:e_n].set(~ent_valid)

        res_ids = jnp.full((res_cap,), -1, jnp.int32)
        res_d = jnp.full((res_cap,), BIG, jnp.float32)
        res_valid = jnp.zeros((res_cap,), jnp.bool_)

        counters = jnp.zeros((4,), jnp.int32)    # io, dist_comps, approx, hops

        def cond(state):
            pool_ids, pool_key, explored, res_ids, res_d, res_valid, counters = state
            hops = counters[3]
            frontier = jnp.any(~explored[:P] & (pool_key[:P] < BIG))
            # paper early termination: top-l_valid verified & no closer frontier
            n_ok = jnp.sum(res_valid)
            kth = jnp.sort(jnp.where(res_valid, res_d, BIG))[
                jnp.minimum(l_valid, res_cap) - 1]
            best_unexp = jnp.min(jnp.where(explored, BIG, pool_key))
            settled = (n_ok >= l_valid) & (best_unexp > kth)
            return (hops < p.max_hops) & frontier & ~settled

        def body(state):
            pool_ids, pool_key, explored, res_ids, res_d, res_valid, counters = state
            # ---- 1. pick best-W unexplored (by priority key) ----
            masked = jnp.where(explored, BIG, pool_key)
            _, sel = jax.lax.top_k(-masked, W)
            cur_ids = pool_ids[sel]                            # (W,)
            cur_live = masked[sel] < BIG
            explored = explored.at[sel].set(True)
            safe_cur = jnp.where(cur_live, cur_ids, 0)

            # ---- 2. fetch records (vector + neighbors + attrs: one I/O) ----
            rec = fetch_fn(store, safe_cur)
            vecs = rec["vectors"]                              # (W, D)
            nbrs = rec["neighbors"]                            # (W, R)
            rl = rec["rec_labels"]                             # (W, ML)
            rv = rec["rec_values"]                             # (W, F)
            io = counters[0] + jnp.sum(cur_live) * rec_pages

            # ---- 3. re-rank + piggybacked exact verification ----
            ex_d = jnp.where(cur_live, _exact_sq_dist(vecs, q), BIG)
            ex_ok = is_member(qf, rl, rv) & cur_live
            hops = counters[3]
            start = hops * W
            res_ids = jax.lax.dynamic_update_slice(
                res_ids, jnp.where(cur_live, cur_ids, -1), (start,))
            res_d = jax.lax.dynamic_update_slice(res_d, ex_d, (start,))
            res_valid = jax.lax.dynamic_update_slice(res_valid, ex_ok, (start,))

            # ---- 4. candidate generation per mode ----
            if p.mode == "spec_in":
                dn = rec["dense_neighbors"]                    # (W, Rd)
                cand = jnp.concatenate([nbrs, dn], axis=1)     # (W, R+Rd)
                is_direct = jnp.concatenate(
                    [jnp.ones((W, R), bool), jnp.zeros((W, Rd), bool)], axis=1)
            else:
                cand = nbrs
                is_direct = jnp.ones((W, R), bool)
            cand = jnp.where(cur_live[:, None], cand, -1)
            live = cand >= 0
            safe_cand = jnp.where(live, cand, 0)

            # dedup vs pool, explored buffer, and within the row (the 2-hop
            # sample may repeat ids)
            dup_pool = jnp.any(cand[:, :, None] == pool_ids[None, None, :], -1)
            dup_res = jnp.any(cand[:, :, None] == res_ids[None, None, :], -1)
            c = cand.shape[1]
            tri = jnp.tril(jnp.ones((c, c), bool), -1)
            dup_row = jnp.any((cand[:, :, None] == cand[:, None, :]) & tri, -1)
            fresh = live & ~dup_pool & ~dup_res & ~dup_row

            approx_n = jnp.sum(live)
            if p.mode == "post":
                ok = fresh
                counters_approx = counters[2]
            elif p.mode == "spec_in":
                ok = is_member_approx(qf, safe_cand, mem) & fresh
                counters_approx = counters[2] + approx_n
            else:  # strict_in: read every fresh neighbor's attrs from "SSD"
                nrec = fetch_fn(store, safe_cand.reshape(-1))
                n_rl = nrec["rec_labels"].reshape(W, R, -1)    # (W, R, ML)
                n_rv = nrec["rec_values"].reshape(W, R, store.n_fields)
                ok = is_member(qf, n_rl, n_rv) & fresh
                io = io + jnp.sum(fresh)                       # 1 page / neighbor
                counters_approx = counters[2]

            # ---- 5. slot selection: up to R approx-valid, bridge back-fill ----
            if p.mode == "spec_in":
                # first-come order (cheap, matches Table-1 compute accounting)
                rank_ok = jnp.cumsum(ok.astype(jnp.int32), axis=1) - 1
                fill = fresh & ~ok & is_direct
                rank_fill = jnp.cumsum(fill.astype(jnp.int32), axis=1) - 1
                n_ok_row = jnp.sum(ok, axis=1, keepdims=True)
                order_key = jnp.where(
                    ok, rank_ok.astype(jnp.float32),
                    jnp.where(fill, (n_ok_row + rank_fill).astype(jnp.float32),
                              BIG))
                _, take = jax.lax.top_k(-order_key, R)          # (W, R)
                sel_ids = jnp.take_along_axis(cand, take, axis=1)
                sel_ok = jnp.take_along_axis(ok, take, axis=1)
                sel_fill = jnp.take_along_axis(fill, take, axis=1)
                sel_live = sel_ok | sel_fill
            else:
                sel_ids, sel_ok, sel_live = cand, ok, ok

            # ---- 6. PQ distances for selected candidates only ----
            flat_ids = sel_ids.reshape(-1)
            flat_live = sel_live.reshape(-1)
            flat_ok = sel_ok.reshape(-1)
            # cross-row dedup of the selected set (W > 1 beams may collide)
            nf = flat_ids.shape[0]
            trif = jnp.tril(jnp.ones((nf, nf), bool), -1)
            dupf = jnp.any((flat_ids[:, None] == flat_ids[None, :]) & trif, -1)
            flat_live = flat_live & ~dupf
            flat_ok = flat_ok & ~dupf
            pq_d = distance_fn(codes[jnp.where(flat_live, flat_ids, 0)], table)
            key = pq_d + jnp.where(flat_ok, 0.0, INVALID_PENALTY)
            key = jnp.where(flat_live, key, BIG)
            dist_comps = counters[1] + jnp.sum(flat_live)

            # ---- 7. merge into pool (sorted ascending by key) ----
            all_ids = jnp.concatenate([pool_ids, jnp.where(flat_live, flat_ids, -1)])
            all_key = jnp.concatenate([pool_key, key])
            all_exp = jnp.concatenate([explored,
                                       jnp.zeros_like(flat_live)])
            order = jnp.argsort(all_key)[:P]
            new_counters = jnp.stack([io, dist_comps, counters_approx, hops + 1])
            return (all_ids[order], all_key[order], all_exp[order],
                    res_ids, res_d, res_valid, new_counters)

        state = (pool_ids, pool_key, explored, res_ids, res_d, res_valid, counters)
        state = jax.lax.while_loop(cond, body, state)
        pool_ids, pool_key, explored, res_ids, res_d, res_valid, counters = state

        # ---- final: top-k verified-valid by exact distance ----
        final_key = jnp.where(res_valid, res_d, BIG)
        order = jnp.argsort(final_key)[:p.k]
        out_ids = jnp.where(res_valid[order], res_ids[order], -1)
        out_d = jnp.where(res_valid[order], res_d[order], jnp.inf)
        n_valid = jnp.sum(res_valid)
        n_explored = jnp.sum(res_ids >= 0)
        fp = jnp.sum((res_ids >= 0) & ~res_valid)
        zero = jnp.int32(0)     # baseline has no fault plan: clean counters
        return (out_ids, out_d, counters[0], counters[3], counters[1],
                counters[2], n_valid, fp, n_explored, zero, zero, zero)

    outs = jax.vmap(one)(queries, qfilters, entries)
    return SearchResult(*outs)
