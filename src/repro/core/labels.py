"""Label attribute store: CSR per-vector labels + on-"SSD" inverted indexes.

Layout (paper §4.3.1):
  - on-SSD: one posting list per label (vector IDs ascending, contiguous)
    -> scanned by pre_filter_approx, I/O counted in 4 KB pages;
  - in-memory: per-label offsets + counts (selectivity estimation) and the
    per-vector Bloom words (bloom.py).

Vectors additionally carry a row-wise copy of their labels inside the record
store (records.py) for exact verification — the paper's duplicated layout.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import bloom
from repro.core.io_sim import PAGE_BYTES


@dataclasses.dataclass
class LabelStore:
    n_vectors: int
    n_labels: int
    # CSR over vectors (row-wise copy; "in the records")
    vec_offsets: np.ndarray        # (N+1,) int64
    vec_labels: np.ndarray         # (nnz,) int32
    # CSR over labels (inverted index; "on SSD")
    inv_offsets: np.ndarray        # (n_labels+1,) int64
    inv_postings: np.ndarray       # (nnz,) int32 vector ids, ascending per label
    # in-memory summaries
    label_counts: np.ndarray       # (n_labels,) int64
    blooms: np.ndarray             # (N,) uint32
    k_hashes: int = 2

    @property
    def avg_labels_per_vec(self) -> float:
        return float(self.vec_labels.size) / max(1, self.n_vectors)

    def selectivity(self, label: int) -> float:
        return float(self.label_counts[label]) / max(1, self.n_vectors)

    def posting_pages(self, label: int, page_bytes: int = PAGE_BYTES) -> int:
        """Pages read to scan one label's posting list from SSD."""
        nbytes = int(self.label_counts[label]) * 4
        return max(1, -(-nbytes // page_bytes))

    def postings(self, label: int) -> np.ndarray:
        s, e = int(self.inv_offsets[label]), int(self.inv_offsets[label + 1])
        return self.inv_postings[s:e]

    def labels_of(self, vec_id: int) -> np.ndarray:
        s, e = int(self.vec_offsets[vec_id]), int(self.vec_offsets[vec_id + 1])
        return self.vec_labels[s:e]

    def memory_bytes(self) -> dict:
        """Table-3 style accounting: in-memory filter size vs on-SSD index."""
        return {
            "bloom_bytes": int(self.blooms.nbytes),
            "counts_bytes": int(self.label_counts.nbytes + self.inv_offsets.nbytes),
            "ssd_inverted_index_bytes": int(self.inv_postings.nbytes),
        }


def build_label_store(vec_offsets: np.ndarray, vec_labels: np.ndarray,
                      n_labels: int, k_hashes: int = 2) -> LabelStore:
    n = vec_offsets.size - 1
    vec_offsets = vec_offsets.astype(np.int64)
    vec_labels = vec_labels.astype(np.int32)

    # dedupe (vector, label) pairs: repeated labels would inflate posting
    # lists and push selectivity estimates past 1.0
    vec_ids0 = np.repeat(np.arange(n, dtype=np.int64), np.diff(vec_offsets))
    pair = vec_ids0 * (n_labels + 1) + vec_labels
    keep = np.zeros(pair.size, bool)
    uniq_idx = np.unique(pair, return_index=True)[1]
    keep[uniq_idx] = True
    if not keep.all():
        vec_labels = vec_labels[keep]
        counts = np.bincount(vec_ids0[keep], minlength=n)
        vec_offsets = np.zeros(n + 1, np.int64)
        np.cumsum(counts, out=vec_offsets[1:])

    # invert: sort (label, vec) pairs by label then vec id
    vec_ids = np.repeat(np.arange(n, dtype=np.int32), np.diff(vec_offsets))
    order = np.lexsort((vec_ids, vec_labels))
    inv_postings = vec_ids[order]
    sorted_labels = vec_labels[order]
    label_counts = np.bincount(sorted_labels, minlength=n_labels).astype(np.int64)
    inv_offsets = np.zeros(n_labels + 1, dtype=np.int64)
    np.cumsum(label_counts, out=inv_offsets[1:])

    blooms = bloom.build_blooms(vec_offsets, vec_labels, n, k_hashes)
    return LabelStore(
        n_vectors=n, n_labels=n_labels,
        vec_offsets=vec_offsets, vec_labels=vec_labels,
        inv_offsets=inv_offsets, inv_postings=inv_postings,
        label_counts=label_counts, blooms=blooms, k_hashes=k_hashes,
    )


def padded_vec_labels(store: LabelStore, max_labels: int,
                      pad_value: int = -1) -> np.ndarray:
    """Dense (N, max_labels) int32 copy for the record store (exact verify)."""
    return padded_rows_from_csr(store.vec_offsets, store.vec_labels,
                                max_labels, pad_value)


def padded_rows_from_csr(offsets: np.ndarray, flat: np.ndarray,
                         max_labels: int, pad_value: int = -1) -> np.ndarray:
    """CSR labels -> dense (rows, max_labels) int32 (insert-path slices)."""
    n = offsets.size - 1
    out = np.full((n, max_labels), pad_value, dtype=np.int32)
    counts = np.diff(offsets)
    rows = np.repeat(np.arange(n), counts)
    pos = np.arange(flat.size) - np.repeat(offsets[:-1], counts)
    keep = pos < max_labels
    out[rows[keep], pos[keep]] = flat[keep]
    return out


def extend_label_store(store: LabelStore, new_offsets: np.ndarray,
                       new_flat: np.ndarray, n_labels: int) -> LabelStore:
    """Append a batch of vectors' labels without rebuilding the store.

    Inserted vector ids are all larger than existing ones, so each label's
    new postings land at the *end* of its run — one vectorized ``np.insert``
    merge instead of the build path's global lexsort; Bloom words are
    computed for the new rows only. ``n_labels`` may exceed the store's
    (vocabulary growth): new labels get empty runs extended in place.
    """
    new_offsets = np.asarray(new_offsets, np.int64)
    new_flat = np.asarray(new_flat, np.int32)
    m = new_offsets.size - 1
    n0 = store.n_vectors
    n_labels = max(store.n_labels, int(n_labels))

    # dedupe (vector, label) pairs within the batch (same rule as the build)
    vec_ids0 = np.repeat(np.arange(m, dtype=np.int64), np.diff(new_offsets))
    pair = vec_ids0 * (n_labels + 1) + new_flat
    keep = np.zeros(pair.size, bool)
    keep[np.unique(pair, return_index=True)[1]] = True
    if not keep.all():
        new_flat = new_flat[keep]
        counts = np.bincount(vec_ids0[keep], minlength=m)
        new_offsets = np.zeros(m + 1, np.int64)
        np.cumsum(counts, out=new_offsets[1:])

    vec_offsets = np.concatenate(
        [store.vec_offsets, store.vec_offsets[-1] + new_offsets[1:]])
    vec_labels = np.concatenate([store.vec_labels, new_flat])

    # inverted index: merge sorted-new-pairs at each label's old run end
    old_inv_off = store.inv_offsets
    if old_inv_off.size < n_labels + 1:
        old_inv_off = np.concatenate(
            [old_inv_off, np.full(n_labels + 1 - old_inv_off.size,
                                  old_inv_off[-1], np.int64)])
    vec_ids = np.repeat(np.arange(n0, n0 + m, dtype=np.int32),
                        np.diff(new_offsets))
    order = np.lexsort((vec_ids, new_flat))
    add_post, add_lab = vec_ids[order], new_flat[order]
    inv_postings = np.insert(store.inv_postings, old_inv_off[add_lab + 1],
                             add_post)
    label_counts = np.zeros(n_labels, np.int64)
    label_counts[:store.n_labels] = store.label_counts
    label_counts += np.bincount(add_lab, minlength=n_labels).astype(np.int64)
    inv_offsets = np.zeros(n_labels + 1, np.int64)
    np.cumsum(label_counts, out=inv_offsets[1:])

    blooms = np.concatenate(
        [store.blooms,
         bloom.build_blooms(new_offsets, new_flat, m, store.k_hashes)])
    return LabelStore(
        n_vectors=n0 + m, n_labels=n_labels,
        vec_offsets=vec_offsets, vec_labels=vec_labels,
        inv_offsets=inv_offsets, inv_postings=inv_postings,
        label_counts=label_counts, blooms=blooms, k_hashes=store.k_hashes)
