"""Label attribute store: CSR per-vector labels + on-"SSD" inverted indexes.

Layout (paper §4.3.1):
  - on-SSD: one posting list per label (vector IDs ascending, contiguous)
    -> scanned by pre_filter_approx, I/O counted in 4 KB pages;
  - in-memory: per-label offsets + counts (selectivity estimation) and the
    per-vector Bloom words (bloom.py).

Vectors additionally carry a row-wise copy of their labels inside the record
store (records.py) for exact verification — the paper's duplicated layout.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import bloom
from repro.core.io_sim import PAGE_BYTES


@dataclasses.dataclass
class LabelStore:
    n_vectors: int
    n_labels: int
    # CSR over vectors (row-wise copy; "in the records")
    vec_offsets: np.ndarray        # (N+1,) int64
    vec_labels: np.ndarray         # (nnz,) int32
    # CSR over labels (inverted index; "on SSD")
    inv_offsets: np.ndarray        # (n_labels+1,) int64
    inv_postings: np.ndarray       # (nnz,) int32 vector ids, ascending per label
    # in-memory summaries
    label_counts: np.ndarray       # (n_labels,) int64
    blooms: np.ndarray             # (N,) uint32
    k_hashes: int = 2

    @property
    def avg_labels_per_vec(self) -> float:
        return float(self.vec_labels.size) / max(1, self.n_vectors)

    def selectivity(self, label: int) -> float:
        return float(self.label_counts[label]) / max(1, self.n_vectors)

    def posting_pages(self, label: int, page_bytes: int = PAGE_BYTES) -> int:
        """Pages read to scan one label's posting list from SSD."""
        nbytes = int(self.label_counts[label]) * 4
        return max(1, -(-nbytes // page_bytes))

    def postings(self, label: int) -> np.ndarray:
        s, e = int(self.inv_offsets[label]), int(self.inv_offsets[label + 1])
        return self.inv_postings[s:e]

    def labels_of(self, vec_id: int) -> np.ndarray:
        s, e = int(self.vec_offsets[vec_id]), int(self.vec_offsets[vec_id + 1])
        return self.vec_labels[s:e]

    def memory_bytes(self) -> dict:
        """Table-3 style accounting: in-memory filter size vs on-SSD index."""
        return {
            "bloom_bytes": int(self.blooms.nbytes),
            "counts_bytes": int(self.label_counts.nbytes + self.inv_offsets.nbytes),
            "ssd_inverted_index_bytes": int(self.inv_postings.nbytes),
        }


def build_label_store(vec_offsets: np.ndarray, vec_labels: np.ndarray,
                      n_labels: int, k_hashes: int = 2) -> LabelStore:
    n = vec_offsets.size - 1
    vec_offsets = vec_offsets.astype(np.int64)
    vec_labels = vec_labels.astype(np.int32)

    # dedupe (vector, label) pairs: repeated labels would inflate posting
    # lists and push selectivity estimates past 1.0
    vec_ids0 = np.repeat(np.arange(n, dtype=np.int64), np.diff(vec_offsets))
    pair = vec_ids0 * (n_labels + 1) + vec_labels
    keep = np.zeros(pair.size, bool)
    uniq_idx = np.unique(pair, return_index=True)[1]
    keep[uniq_idx] = True
    if not keep.all():
        vec_labels = vec_labels[keep]
        counts = np.bincount(vec_ids0[keep], minlength=n)
        vec_offsets = np.zeros(n + 1, np.int64)
        np.cumsum(counts, out=vec_offsets[1:])

    # invert: sort (label, vec) pairs by label then vec id
    vec_ids = np.repeat(np.arange(n, dtype=np.int32), np.diff(vec_offsets))
    order = np.lexsort((vec_ids, vec_labels))
    inv_postings = vec_ids[order]
    sorted_labels = vec_labels[order]
    label_counts = np.bincount(sorted_labels, minlength=n_labels).astype(np.int64)
    inv_offsets = np.zeros(n_labels + 1, dtype=np.int64)
    np.cumsum(label_counts, out=inv_offsets[1:])

    blooms = bloom.build_blooms(vec_offsets, vec_labels, n, k_hashes)
    return LabelStore(
        n_vectors=n, n_labels=n_labels,
        vec_offsets=vec_offsets, vec_labels=vec_labels,
        inv_offsets=inv_offsets, inv_postings=inv_postings,
        label_counts=label_counts, blooms=blooms, k_hashes=k_hashes,
    )


def padded_vec_labels(store: LabelStore, max_labels: int,
                      pad_value: int = -1) -> np.ndarray:
    """Dense (N, max_labels) int32 copy for the record store (exact verify)."""
    out = np.full((store.n_vectors, max_labels), pad_value, dtype=np.int32)
    counts = np.diff(store.vec_offsets)
    rows = np.repeat(np.arange(store.n_vectors), counts)
    pos = np.arange(store.vec_labels.size) - np.repeat(store.vec_offsets[:-1], counts)
    keep = pos < max_labels
    out[rows[keep], pos[keep]] = store.vec_labels[keep]
    return out
