"""Distributed filtered search on a TPU pod mesh (DESIGN.md §2 mapping).

Tier mapping of the paper's memory hierarchy onto the pod:

  * **Record store ("SSD")** — vectors, adjacency (+2-hop), attributes —
    sharded by vector-ID range across ALL mesh devices (a LAION100M-scale
    store is ~0.5 TB: it only fits sharded). A record fetch is a
    masked-local-gather + psum: only the owning shard contributes nonzero
    rows, every device receives the full record. This is the TPU analogue
    of a batched SSD read, and its payload bytes are the collective term
    of the ANN roofline.
  * **Probabilistic tier ("DRAM")** — PQ codes, Bloom words, bucket codes —
    replicated per chip (small: ≤ bytes/vector), probed with zero
    communication inside the beam loop, exactly like the paper's in-memory
    structures.

Queries run replicated across the mesh (every device executes the same beam
control flow); batching coalesces the per-hop fetches of all queries into
one psum — the TPU-native form of PipeANN's pipelined I/O.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import pq as pq_mod
from repro.core import search as search_mod
from repro.core.records import RecordStore
from repro.core.selectors import InMemory, QueryFilter


@dataclasses.dataclass(frozen=True)
class ShardPlan:
    mesh: object
    shard_axes: tuple = ("data", "model")   # record store shards over these

    @property
    def n_shards(self) -> int:
        s = 1
        for a in self.shard_axes:
            s *= self.mesh.shape[a]
        return s


def pad_store(store: RecordStore, n_shards: int) -> RecordStore:
    """Pad N to a shard multiple (pad records are never reachable)."""
    n = store.n
    n_pad = -(-n // n_shards) * n_shards
    if n_pad == n:
        return store
    extra = n_pad - n

    def pad(arr, fill):
        widths = [(0, extra)] + [(0, 0)] * (arr.ndim - 1)
        return jnp.pad(arr, widths, constant_values=fill)

    return RecordStore(
        vectors=pad(store.vectors, 0.0),
        neighbors=pad(store.neighbors, -1),
        dense_neighbors=pad(store.dense_neighbors, -1),
        rec_labels=pad(store.rec_labels, -1),
        rec_values=pad(store.rec_values, 0.0),
        pages_std=store.pages_std, pages_dense=store.pages_dense)


def store_shardings(plan: ShardPlan, store: RecordStore) -> RecordStore:
    """NamedShardings: dim-0 (vector id) over the shard axes."""
    ax = plan.shard_axes

    def shard(arr):
        spec = P(ax, *([None] * (arr.ndim - 1)))
        return NamedSharding(plan.mesh, spec)

    return RecordStore(
        vectors=shard(store.vectors), neighbors=shard(store.neighbors),
        dense_neighbors=shard(store.dense_neighbors),
        rec_labels=shard(store.rec_labels), rec_values=shard(store.rec_values),
        pages_std=store.pages_std, pages_dense=store.pages_dense)


def make_sharded_fetch(plan: ShardPlan, n_total: int) -> Callable:
    """Fetch-by-global-id inside shard_map: masked local gather + psum.

    Fetch contract (shared with ``search.local_fetch``): ``ids`` may be
    any shape — the fused batched hop loop issues ONE flat ``(B·W,)``
    fetch per hop for the whole query batch (and one ``(B·W·R,)`` fetch
    in strict mode), so the psum coalesces every query's reads into a
    single collective; returned arrays are ``ids.shape + record_dims``.
    Inside the loop the search only consults the replicated in-memory
    tier (PQ codes, Bloom words, bucket codes, the visited slot table),
    so the id space is defined by ``codes.shape[0]``, never by the local
    shard size."""
    n_shards = plan.n_shards
    shard_size = n_total // n_shards
    axis_names = plan.shard_axes

    def fetch(store: RecordStore, ids: jax.Array) -> dict:
        # flatten the shard axes into a linear shard index
        idx = jax.lax.axis_index(axis_names)
        lo = idx * shard_size
        local = ids - lo
        mine = (local >= 0) & (local < shard_size)
        safe = jnp.where(mine, local, 0)

        def pull(arr, off=0):
            """psum-combine rows: only the owner contributes nonzero. For
            id-valued arrays (`off=1`) the pad -1 survives the psum by
            shifting to a non-negative domain first."""
            got = arr[safe] + off
            got = jnp.where(
                mine.reshape(mine.shape + (1,) * (got.ndim - mine.ndim)),
                got, 0)
            return jax.lax.psum(got, axis_names) - off

        return {
            "vectors": pull(store.vectors),
            "neighbors": pull(store.neighbors, off=1),
            "dense_neighbors": pull(store.dense_neighbors, off=1),
            "rec_labels": pull(store.rec_labels, off=1),
            "rec_values": pull(store.rec_values),
        }

    return fetch


def distributed_filtered_search(plan: ShardPlan, store: RecordStore,
                                codes, codebook, mem: InMemory,
                                qfilters: QueryFilter, queries, entry: int,
                                params: search_mod.SearchParams):
    """shard_map-wrapped beam search over the pod.

    Record-store arrays arrive sharded over plan.shard_axes; everything
    else replicated. Output replicated."""
    mesh = plan.mesh
    ax = plan.shard_axes
    n_total = store.n
    fetch = make_sharded_fetch(plan, n_total)
    pages_std, pages_dense = store.pages_std, store.pages_dense
    arrays = (store.vectors, store.neighbors, store.dense_neighbors,
              store.rec_labels, store.rec_values)

    def body(vecs, nbrs, dense, rlab, rval, codes_l, cents, mem_l, qf_l, q_l):
        store_l = RecordStore(vecs, nbrs, dense, rlab, rval,
                              pages_std, pages_dense)
        cb_l = pq_mod.PQCodebook(centroids=cents, dim=codebook.dim)
        return search_mod.filtered_search(
            store_l, codes_l, cb_l, mem_l, qf_l, q_l, entry, params,
            fetch_fn=fetch)

    def rep(tree):
        return jax.tree_util.tree_map(lambda l: P(*([None] * jnp.ndim(l))),
                                      tree)

    in_specs = ((P(ax, None), P(ax, None), P(ax, None), P(ax, None),
                 P(ax, None))
                + (rep(codes), rep(codebook.centroids), rep(mem),
                   rep(qfilters), rep(queries)))
    # output structure from the local variant (eval_shape must not trace the
    # sharded fetch: axis_index is only bound inside shard_map)
    out_shape = jax.eval_shape(
        lambda: search_mod.filtered_search(
            RecordStore(*arrays, pages_std, pages_dense), codes, codebook,
            mem, qfilters, queries, entry, params))
    out_specs = jax.tree_util.tree_map(lambda _: P(), out_shape)

    from repro.utils.compat import shard_map
    f = shard_map(body, mesh=mesh, in_specs=in_specs,
                  out_specs=out_specs, check_vma=False)
    return f(*arrays, codes, codebook.centroids, mem, qfilters, queries)
