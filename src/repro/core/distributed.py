"""Distributed filtered search + Vamana build on a TPU pod mesh
(DESIGN.md §2 mapping; docs/distributed.md has the diagrams).

Tier mapping of the paper's memory hierarchy onto the pod:

  * **Record store ("SSD")** — vectors, adjacency (+2-hop), attributes,
    and the precomputed ``cand_first`` dedup bits — sharded by vector-ID
    range across the mesh (a LAION100M-scale store is ~0.5 TB: it only
    fits sharded). A record fetch is a masked-local-gather + psum: only
    the owning shard contributes nonzero rows, every device receives the
    full record. This is the TPU analogue of a batched SSD read, and its
    payload bytes are the collective term of the ANN roofline.
  * **Probabilistic tier ("DRAM")** — PQ codes, Bloom words, bucket codes,
    the per-query visited/rare-list word bitmaps — replicated per chip
    (small: ≤ bytes/vector), probed with zero communication inside the
    beam loop, exactly like the paper's in-memory structures.

Two query layouts share that store layout:

  * :func:`distributed_filtered_search` (the original single-shot entry) —
    queries REPLICATED: every device executes the whole batch's beam
    control flow, one psum per hop coalesces the reads. Kept as the
    simplest mesh entry and the back-compat surface.
  * :class:`ShardedSearchRunner` (the production engine) — queries
    ROW-SHARDED: each shard runs the hop loop for its B/S contiguous
    query rows only, so hop compute ALSO scales with the mesh. Per hop
    each shard all-gathers the global frontier ids (S·B/S·W ids — tiny),
    answers the psum fetch from its store shard, and keeps its own rows'
    slabs; the loop terminates on the psum'd *global* active flag so every
    shard takes the same number of iterations (settled rows are exact
    fixed points of the hop step). The runner plugs into
    ``search.filtered_search_pipelined``'s ``runner=`` seam: init /
    finalize / straggler compaction / the bucket-jit cache all run
    unchanged on the host driver — only the chunked hop call crosses the
    mesh — so results stay bit-identical to the single-device driver.

The sharded Vamana build (:func:`build_vamana_sharded`) splits each
insertion batch's rows over the same axis: navigation (optionally on
PQ-approximate ADC distances) and the exact RobustPrune re-rank run per
shard, the pruned (B, R) rows are all-gathered, and the replicated
reverse-edge scatter + overflow rounds reuse the batched builder's host
half verbatim (``graph.apply_pruned_rows`` / ``graph._drain_overflow``).
Per batch that moves one (B, R) int32 all-gather and one replicated
adjacency update (~N·R·4 bytes) — small next to the O(B·ell·R·D)
navigation compute it divides by S.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import graph as graph_mod
from repro.core import pq as pq_mod
from repro.core import search as search_mod
from repro.core.records import RecordStore
from repro.core.selectors import InMemory, QueryFilter
from repro.utils.compat import shard_map


@dataclasses.dataclass(frozen=True)
class ShardPlan:
    mesh: object
    shard_axes: tuple = ("data", "model")   # record store shards over these

    @property
    def n_shards(self) -> int:
        s = 1
        for a in self.shard_axes:
            s *= self.mesh.shape[a]
        return s


def pad_store(store: RecordStore, n_shards: int) -> RecordStore:
    """Pad N to a shard multiple (pad records are never reachable)."""
    n = store.n
    n_pad = -(-n // n_shards) * n_shards
    if n_pad == n:
        return store
    extra = n_pad - n

    def pad(arr, fill):
        widths = [(0, extra)] + [(0, 0)] * (arr.ndim - 1)
        return jnp.pad(arr, widths, constant_values=fill)

    return RecordStore(
        vectors=pad(store.vectors, 0.0),
        neighbors=pad(store.neighbors, -1),
        dense_neighbors=pad(store.dense_neighbors, -1),
        rec_labels=pad(store.rec_labels, -1),
        rec_values=pad(store.rec_values, 0.0),
        pages_std=store.pages_std, pages_dense=store.pages_dense,
        cand_first=(None if store.cand_first is None
                    else pad(store.cand_first, False)))


def store_shardings(plan: ShardPlan, store: RecordStore) -> RecordStore:
    """NamedShardings: dim-0 (vector id) over the shard axes."""
    ax = plan.shard_axes

    def shard(arr):
        spec = P(ax, *([None] * (arr.ndim - 1)))
        return NamedSharding(plan.mesh, spec)

    return RecordStore(
        vectors=shard(store.vectors), neighbors=shard(store.neighbors),
        dense_neighbors=shard(store.dense_neighbors),
        rec_labels=shard(store.rec_labels), rec_values=shard(store.rec_values),
        pages_std=store.pages_std, pages_dense=store.pages_dense,
        cand_first=(None if store.cand_first is None
                    else shard(store.cand_first)))


def _owner_pulls(store: RecordStore, safe, mine, axis_names) -> dict:
    """The shared masked-local-gather + psum record assembly.

    ``safe``/``mine`` are shard-local row indices and ownership mask for
    some set of global ids (any shape). Only the owner contributes
    nonzero rows; the psum hands every shard the full records."""
    def pull(arr, off=0):
        """psum-combine rows: only the owner contributes nonzero. For
        id-valued arrays (`off=1`) the pad -1 survives the psum by
        shifting to a non-negative domain first."""
        got = arr[safe] + off
        got = jnp.where(
            mine.reshape(mine.shape + (1,) * (got.ndim - mine.ndim)),
            got, 0)
        return jax.lax.psum(got, axis_names) - off

    rec = {
        "vectors": pull(store.vectors),
        "neighbors": pull(store.neighbors, off=1),
        "dense_neighbors": pull(store.dense_neighbors, off=1),
        "rec_labels": pull(store.rec_labels, off=1),
        "rec_values": pull(store.rec_values),
    }
    if store.cand_first is not None:
        # bool words can't ride a psum: count in int32 (owner contributes
        # 0/1, everyone else 0) and compare back. Threading these
        # precomputed first-occurrence bits through keeps the sharded
        # W=1 hop loop off the packed-sort dedup fallback.
        got = store.cand_first[safe].astype(jnp.int32)
        got = jnp.where(mine[..., None], got, 0)
        rec["cand_first"] = jax.lax.psum(got, axis_names) > 0
    return rec


def make_sharded_fetch(plan: ShardPlan, n_total: int) -> Callable:
    """Fetch-by-global-id inside shard_map: masked local gather + psum.

    Fetch contract (shared with ``search.local_fetch``): ``ids`` may be
    any shape — the fused batched hop loop issues ONE flat ``(B·W,)``
    fetch per hop for the whole query batch (and one ``(B·W·R,)`` fetch
    in strict mode), so the psum coalesces every query's reads into a
    single collective; returned arrays are ``ids.shape + record_dims``,
    including the optional ``cand_first`` dedup bits when the store
    carries them. Inside the loop the search only consults the replicated
    in-memory tier (PQ codes, Bloom words, bucket codes, the visited word
    bitmap), so the id space is defined by ``codes.shape[0]``, never by
    the local shard size. This is the replicated-queries flavor: every
    shard issues the same global id vector."""
    n_shards = plan.n_shards
    shard_size = n_total // n_shards
    axis_names = plan.shard_axes

    def fetch(store: RecordStore, ids: jax.Array) -> dict:
        # flatten the shard axes into a linear shard index
        idx = jax.lax.axis_index(axis_names)
        lo = idx * shard_size
        local = ids - lo
        mine = (local >= 0) & (local < shard_size)
        safe = jnp.where(mine, local, 0)
        return _owner_pulls(store, safe, mine, axis_names)

    return fetch


def make_batch_sharded_fetch(plan: ShardPlan, n_total: int) -> Callable:
    """The row-sharded-queries flavor of :func:`make_sharded_fetch`.

    Each shard arrives with its own rows' flat frontier ids (any local
    length ``nl``). The shards all-gather their id vectors into the
    global batch-order frontier (``tiled`` concatenation over the shard
    axes matches the row-sharding's contiguous-block order), assemble the
    full records with the same owner-psum pull, and slice back their own
    ``nl``-row block. One all-gather of ids + one psum of records per
    hop — the coalesced batched "SSD read", now also splitting the hop
    compute S ways."""
    n_shards = plan.n_shards
    shard_size = n_total // n_shards
    axis_names = plan.shard_axes

    def fetch(store: RecordStore, ids: jax.Array) -> dict:
        nl = ids.shape[0]
        idx = jax.lax.axis_index(axis_names)
        gids = jax.lax.all_gather(ids, axis_names, tiled=True)  # (S·nl,)
        local = gids - idx * shard_size
        mine = (local >= 0) & (local < shard_size)
        safe = jnp.where(mine, local, 0)
        rec = _owner_pulls(store, safe, mine, axis_names)
        return {k: jax.lax.dynamic_slice_in_dim(v, idx * nl, nl, axis=0)
                for k, v in rec.items()}

    return fetch


class ShardedSearchRunner:
    """The mesh-sharded hop engine behind ``filtered_search_pipelined``.

    Owns a padded, ID-range-sharded device copy of the record store and a
    cache of shard_map'd hop kernels keyed like the single-device bucket
    jit cache — one entry per ``(params, distance_fn)``, with jax's shape
    cache covering the driver's power-of-two bucket widths underneath
    (the compile-once property the warmup ladder and the
    ``test_sharded_compile_once`` test pin).

    ``run(ctx, st, n_hops, params, distance_fn)`` mirrors
    ``search.run_hops``'s contract — returns ``(state, int8 active
    mask)`` with ``st`` donated — but row-shards ``ctx``/``st`` over the
    mesh, swaps in the all-gather batch fetch, and terminates on the
    global active flag so every shard steps in lockstep (inactive rows
    are exact fixed points, so lockstep extra hops keep bit-identity).
    The driver's compaction/fold logic runs on the host exactly as in
    the single-device path; bucket widths stay divisible by the shard
    count because both are powers of two and the driver raises
    ``min_bucket`` to ``n_shards``.
    """

    def __init__(self, plan: ShardPlan, store: RecordStore, codes,
                 codebook, mem: InMemory):
        n_shards = plan.n_shards
        if n_shards & (n_shards - 1):
            raise ValueError(
                f"shard count must be a power of two (got {n_shards}): the "
                "driver's bucket widths must divide evenly over the mesh")
        self.plan = plan
        self.n_shards = n_shards
        padded = pad_store(store, n_shards)
        sh = store_shardings(plan, padded)
        self.store = RecordStore(
            vectors=jax.device_put(padded.vectors, sh.vectors),
            neighbors=jax.device_put(padded.neighbors, sh.neighbors),
            dense_neighbors=jax.device_put(padded.dense_neighbors,
                                           sh.dense_neighbors),
            rec_labels=jax.device_put(padded.rec_labels, sh.rec_labels),
            rec_values=jax.device_put(padded.rec_values, sh.rec_values),
            pages_std=padded.pages_std, pages_dense=padded.pages_dense,
            cand_first=(None if padded.cand_first is None else
                        jax.device_put(padded.cand_first, sh.cand_first)))
        self.codes = codes
        self.codebook = codebook
        self.mem = mem
        self._fetch = make_batch_sharded_fetch(plan, self.store.n)
        self._store_arrays = tuple(
            a for a in (self.store.vectors, self.store.neighbors,
                        self.store.dense_neighbors, self.store.rec_labels,
                        self.store.rec_values, self.store.cand_first)
            if a is not None)
        self._run_cache: dict = {}

    # -- hop kernel ------------------------------------------------------
    def run(self, ctx, st, n_hops, params, distance_fn=pq_mod.adc_lookup):
        """``run_hops`` over the mesh: (ctx, st, n_hops) -> (st', mask)."""
        key = (params, distance_fn)
        fn = self._run_cache.get(key)
        if fn is None:
            fn = self._build_run(params, distance_fn, ctx, st)
            self._run_cache[key] = fn
        return fn(*self._store_arrays, self.codes, self.mem, ctx, st,
                  n_hops)

    def _build_run(self, params, distance_fn, ctx, st):
        ax = self.plan.shard_axes
        pages_std = self.store.pages_std
        pages_dense = self.store.pages_dense
        has_cf = self.store.cand_first is not None
        n_store = len(self._store_arrays)
        fetch = self._fetch

        def global_any(mask):
            return jax.lax.psum(jnp.any(mask).astype(jnp.int32), ax) > 0

        def body(*args):
            sl = args[:n_store]
            codes_l, mem_l, ctx_l, st_l, n_hops_l = args[n_store:]
            store_l = RecordStore(
                *sl[:5], pages_std, pages_dense,
                cand_first=sl[5] if has_cf else None)
            st_l = search_mod._hop_loop(
                store_l, codes_l, mem_l, params, distance_fn, fetch,
                ctx_l, st_l, n_hops_l, active_any=global_any)
            return st_l, st_l.active.astype(jnp.int8)

        def rows(tree):   # leading dim = query rows -> shard over the mesh
            return jax.tree_util.tree_map(
                lambda l: (P(ax, *([None] * (jnp.ndim(l) - 1)))
                           if jnp.ndim(l) else P()), tree)

        def rep(tree):
            return jax.tree_util.tree_map(
                lambda l: P(*([None] * jnp.ndim(l))), tree)

        in_specs = (tuple(P(ax, *([None] * (a.ndim - 1)))
                          for a in self._store_arrays)
                    + (rep(self.codes), rep(self.mem), rows(ctx), rows(st),
                       P()))
        out_specs = (rows(st), P(ax))
        f = shard_map(body, mesh=self.plan.mesh, in_specs=in_specs,
                      out_specs=out_specs, check_vma=False)
        # donate st (arg layout: store leaves, codes, mem, ctx, st, n_hops)
        return jax.jit(f, donate_argnums=(n_store + 3,))

    # -- introspection (compile-once test, server stats) ----------------
    def cache_size(self) -> int:
        return len(self._run_cache)


def distributed_filtered_search(plan: ShardPlan, store: RecordStore,
                                codes, codebook, mem: InMemory,
                                qfilters: QueryFilter, queries, entry: int,
                                params: search_mod.SearchParams):
    """shard_map-wrapped single-shot beam search over the pod.

    Record-store arrays arrive sharded over plan.shard_axes; everything
    else replicated (every shard executes the full batch's control flow).
    Output replicated. ``ShardedSearchRunner`` + the pipelined driver is
    the production path; this stays the minimal mesh entry and the
    replicated-query oracle."""
    mesh = plan.mesh
    ax = plan.shard_axes
    n_total = store.n
    fetch = make_sharded_fetch(plan, n_total)
    pages_std, pages_dense = store.pages_std, store.pages_dense
    has_cf = store.cand_first is not None
    arrays = (store.vectors, store.neighbors, store.dense_neighbors,
              store.rec_labels, store.rec_values) \
        + ((store.cand_first,) if has_cf else ())
    n_store = len(arrays)

    def body(*args):
        sl = args[:n_store]
        codes_l, cents, mem_l, qf_l, q_l = args[n_store:]
        store_l = RecordStore(*sl[:5], pages_std, pages_dense,
                              cand_first=sl[5] if has_cf else None)
        cb_l = pq_mod.PQCodebook(centroids=cents, dim=codebook.dim)
        return search_mod.filtered_search(
            store_l, codes_l, cb_l, mem_l, qf_l, q_l, entry, params,
            fetch_fn=fetch)

    def rep(tree):
        return jax.tree_util.tree_map(lambda l: P(*([None] * jnp.ndim(l))),
                                      tree)

    in_specs = (tuple(P(ax, *([None] * (a.ndim - 1))) for a in arrays)
                + (rep(codes), rep(codebook.centroids), rep(mem),
                   rep(qfilters), rep(queries)))
    # output structure from the local variant (eval_shape must not trace the
    # sharded fetch: axis_index is only bound inside shard_map)
    out_shape = jax.eval_shape(
        lambda: search_mod.filtered_search(
            RecordStore(*arrays[:5], pages_std, pages_dense,
                        cand_first=arrays[5] if has_cf else None),
            codes, codebook, mem, qfilters, queries, entry, params))
    out_specs = jax.tree_util.tree_map(lambda _: P(), out_shape)

    f = shard_map(body, mesh=mesh, in_specs=in_specs,
                  out_specs=out_specs, check_vma=False)
    return f(*arrays, codes, codebook.centroids, mem, qfilters, queries)


# ---------------------------------------------------------------------------
# Sharded Vamana build
# ---------------------------------------------------------------------------

def _make_nav_prune(plan: ShardPlan, medoid: int, pell: int, r: int,
                    alpha: float, use_pq: bool, width: int = 4):
    """shard_map'd navigate+prune over one insertion batch's rows.

    Args (data, adj_ext, codes, centroids, ids): everything replicated
    except ``ids`` (the batch's insert ids), row-sharded so each shard
    navigates and RobustPrunes B/S nodes. With ``use_pq`` the beam pool
    is steered by PQ-approximate ADC distances (the build-compute cut);
    the prune re-ranks with exact full-precision distances either way.
    Returns the all-gathered (B, R) pruned rows, replicated."""
    ax = plan.shard_axes

    def body(data_l, adj_l, codes_l, cents_l, ids_l):
        q_l = data_l[ids_l]                       # (B/S, D) insert vectors

        if use_pq:
            cb = pq_mod.PQCodebook(centroids=cents_l,
                                   dim=data_l.shape[1])

            def nav_one(q):
                table = pq_mod.distance_table(cb, q)
                return graph_mod._beam_pool(
                    adj_l, medoid, pell, pell, width,
                    lambda s: pq_mod.adc_lookup(codes_l[s], table))
        else:
            def nav_one(q):
                return graph_mod._beam_pool(
                    adj_l, medoid, pell, pell, width,
                    lambda s: jnp.sum((data_l[s] - q[None, :]) ** 2,
                                      axis=1))

        pool_ids, _ = jax.vmap(nav_one)(q_l)      # (B/S, ell)
        cand = jnp.concatenate([pool_ids, adj_l[ids_l]], axis=1)
        cand = graph_mod._dedup_ascending(cand, ids_l)
        rows_l = graph_mod.robust_prune_batch(data_l, ids_l, cand,
                                              r=r, alpha=alpha)
        return jax.lax.all_gather(rows_l, ax, tiled=True)   # (B, R)

    rep2 = P(None, None)
    f = shard_map(body, mesh=plan.mesh,
                  in_specs=(rep2, rep2, rep2, rep2, P(ax)),
                  out_specs=P(), check_vma=False)
    return jax.jit(f)


def build_vamana_sharded(data: np.ndarray, plan: ShardPlan, r: int = 32,
                         ell: int = 64, alpha: float = 1.2,
                         batch: int = 1024, seed: int = 0,
                         codes=None, codebook=None,
                         stage_times: dict | None = None
                         ) -> tuple[np.ndarray, int]:
    """Mesh-sharded batched Vamana build (same RNG stream / batch schedule
    as ``graph.build_vamana_batched``). Returns (adjacency, medoid).

    Each insertion batch's rows are split over the shard axes:
    navigation + RobustPrune run per shard (`_make_nav_prune`), the
    pruned rows are all-gathered, and the replicated reverse-edge scatter
    + overflow rounds reuse the single-device host half
    (``graph.apply_pruned_rows`` / ``graph._drain_overflow``) — so the
    only semantic deviation from the batched builder is the navigation
    distance when ``codes``/``codebook`` are given (PQ-approximate ADC
    pools; exact prune re-rank). The recall budget for that deviation is
    the same ±1% the builder-equivalence tests enforce.

    ``stage_times`` (optional dict) accumulates wall seconds into
    ``nav_prune_s`` (the sharded stage) and ``scatter_s`` (the replicated
    host stage) — the build benchmark's Amdahl decomposition feed.
    """
    rng = np.random.default_rng(seed)
    data = np.asarray(data, dtype=np.float32)
    n = data.shape[0]
    medoid = int(np.argmin(
        np.sum((data - data.mean(0, keepdims=True)) ** 2, 1)))

    adj0 = rng.integers(0, n, size=(n, r), dtype=np.int64).astype(np.int32)
    adj0[adj0 == np.arange(n, dtype=np.int32)[:, None]] = medoid

    data_dev = jnp.asarray(data)
    adj_ext = jnp.concatenate(
        [jnp.asarray(adj0), jnp.full((1, r), -1, jnp.int32)])
    batch = min(batch, graph_mod._pow2_pad(n))
    assert batch % plan.n_shards == 0, (
        f"batch={batch} must divide over {plan.n_shards} shards")
    use_pq = codes is not None
    if use_pq:
        assert codebook is not None
        codes_dev = jnp.asarray(codes)
        cents_dev = jnp.asarray(codebook.centroids)
    else:
        # 1-row placeholders keep one body signature (dead under !use_pq)
        codes_dev = jnp.zeros((1, 1), jnp.uint8)
        cents_dev = jnp.zeros((1, 1, 1), jnp.float32)

    for pass_i, alpha_pass in enumerate((1.0, alpha)):
        pell = ell if pass_i else max(16, (2 * ell) // 3)
        nav_prune = _make_nav_prune(plan, medoid, pell, r,
                                    float(alpha_pass), use_pq)
        order = rng.permutation(n)
        for start in range(0, n, batch):
            ids, live = graph_mod._pad_batch(
                order[start:start + batch].astype(np.int32), batch)
            t0 = time.perf_counter()
            rows = nav_prune(data_dev, adj_ext, codes_dev, cents_dev,
                             jnp.asarray(ids))
            if stage_times is not None:
                rows.block_until_ready()
                t1 = time.perf_counter()
                stage_times["nav_prune_s"] = (
                    stage_times.get("nav_prune_s", 0.0) + (t1 - t0))
            adj_ext, st, ss, overflow = graph_mod.apply_pruned_rows(
                adj_ext, jnp.asarray(ids), jnp.asarray(live), rows)
            adj_ext = graph_mod._drain_overflow(
                data_dev, adj_ext, st, ss, overflow, ids.shape[0], r,
                float(alpha_pass))
            if stage_times is not None:
                adj_ext.block_until_ready()
                stage_times["scatter_s"] = (
                    stage_times.get("scatter_s", 0.0)
                    + (time.perf_counter() - t1))
    return np.asarray(adj_ext[:-1]), medoid
