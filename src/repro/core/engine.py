"""FilteredANNEngine — the end-to-end system (paper §4 Fig. 4).

Query processing: per-query cost estimation routes to speculative
pre-filtering, speculative in-filtering, or post-filtering; queries are
grouped by (mechanism, pool-size bucket) and executed as batches; exact
verification piggybacks on re-ranking everywhere.

Baseline policies (paper §5.1 compared systems) are selectable:
  * ``speculative`` — the paper's system (cost-model routing).
  * ``basefilter``  — PipeANN-BaseFilter: strict pre-filtering when
                      selectivity < 1%, otherwise post-filtering.
  * ``strict_in``   — Filtered-DiskANN-like strict in-filtering.
  * ``strict_pre``  — Milvus-like always-pre-filtering.
  * ``post``        — always post-filtering.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cost_model, graph, pq as pq_mod, prefilter, search
from repro.core.labels import LabelStore, build_label_store, padded_vec_labels
from repro.core.ranges import RangeStore, build_range_store
from repro.core.records import RecordStore, make_record_store
from repro.core.selectors import (InMemory, Selector, stack_filters)


@dataclasses.dataclass(frozen=True)
class IndexConfig:
    r: int = 32               # Vamana out-degree
    r_dense: int = 480        # 2-hop sample size (10-20x R, paper §4.1)
    l_build: int = 64
    alpha: float = 1.2
    pq_m: int = 16            # PQ subquantizers
    pq_iters: int = 8
    max_labels: int = 16      # per-record label slots (exact verification)
    ql: int = 8               # max labels per query
    cap: int = 2048           # merged rare-list capacity
    seed: int = 0


@dataclasses.dataclass(frozen=True)
class SearchConfig:
    k: int = 10
    l: int = 32               # base pool length L (recall knob)
    beam_width: int = 1
    max_hops: int = 512
    alpha: float = 10.0       # cost-model IO weight
    beta: float = 1.0
    max_pool: int = 1024      # effective-L cap
    l_rerank_delta: int = 16  # δ extra re-ranked vectors for pre-filtering
    policy: str = "speculative"


@dataclasses.dataclass
class QueryStats:
    mechanism: list
    io_pages: np.ndarray
    est_io_pages: np.ndarray
    dist_comps: np.ndarray
    est_compute: np.ndarray
    hops: np.ndarray
    fp_explored: np.ndarray
    explored: np.ndarray
    n_valid: np.ndarray
    selectivity: np.ndarray
    precision_in: np.ndarray

    @classmethod
    def empty(cls) -> "QueryStats":
        return cls(mechanism=[], io_pages=np.zeros(0, np.int64),
                   est_io_pages=np.zeros(0), dist_comps=np.zeros(0, np.int64),
                   est_compute=np.zeros(0), hops=np.zeros(0, np.int64),
                   fp_explored=np.zeros(0, np.int64),
                   explored=np.zeros(0, np.int64),
                   n_valid=np.zeros(0, np.int64), selectivity=np.zeros(0),
                   precision_in=np.zeros(0))


class FilteredANNEngine:
    def __init__(self, store: RecordStore, codes, codebook, mem: InMemory,
                 label_store: LabelStore, range_store: RangeStore,
                 medoid: int, config: IndexConfig):
        self.store = store
        self.codes = codes
        self.codebook = codebook
        self.mem = mem
        self.label_store = label_store
        self.range_store = range_store
        self.medoid = medoid
        self.config = config

    # ------------------------------------------------------------------
    @classmethod
    def build(cls, vectors: np.ndarray, label_offsets: np.ndarray,
              label_flat: np.ndarray, n_labels: int, values: np.ndarray,
              config: IndexConfig = IndexConfig()) -> "FilteredANNEngine":
        vectors = np.asarray(vectors, np.float32)
        n, d = vectors.shape
        # pad dim to a multiple of pq_m
        if d % config.pq_m:
            pad = config.pq_m - d % config.pq_m
            vectors = np.pad(vectors, ((0, 0), (0, pad)))
            d += pad

        adj, medoid = graph.build_vamana(vectors, config.r, config.l_build,
                                         config.alpha, seed=config.seed)
        dense = graph.densify_2hop(adj, config.r_dense, seed=config.seed + 1)

        label_store = build_label_store(label_offsets, label_flat, n_labels)
        range_store = build_range_store(values)
        rec_labels = padded_vec_labels(label_store, config.max_labels)

        store = make_record_store(vectors, adj, dense, rec_labels,
                                  values.astype(np.float32))

        key = jax.random.PRNGKey(config.seed)
        codebook = pq_mod.train_pq(key, jnp.asarray(vectors), config.pq_m,
                                   iters=config.pq_iters)
        codes = pq_mod.encode_pq(codebook, jnp.asarray(vectors))
        mem = InMemory(blooms=jnp.asarray(label_store.blooms),
                       bucket_codes=jnp.asarray(range_store.bucket_codes))
        return cls(store, codes, codebook, mem, label_store, range_store,
                   medoid, config)

    # ------------------------------------------------------------------
    def _route(self, plan, scfg: SearchConfig) -> cost_model.Route:
        c = cost_model.CostInputs(
            n=self.store.n, l=scfg.l, s=plan.selectivity,
            p_pre=plan.precision_pre, p_in=plan.precision_in,
            x_pre=plan.pages_prescan, x_in=plan.pages_prefetch,
            r=self.store.degree,
            r_d=self.store.degree + self.store.dense_degree,
            s_r=self.store.pages_std, s_d=self.store.pages_dense)
        full = cost_model.route_query(c, scfg.alpha, scfg.beta, scfg.max_pool)
        if plan.force_mech is not None:
            # the selector cannot be expressed by the device filter algebra;
            # only the forced mechanism preserves correctness (MaskSelector)
            mech = plan.force_mech
        elif scfg.policy == "speculative":
            return full
        elif scfg.policy == "basefilter":
            mech = "pre" if plan.selectivity < 0.01 else "post"
        elif scfg.policy == "strict_in":
            mech = "in"
        elif scfg.policy == "strict_pre":
            mech = "pre"
        elif scfg.policy == "post":
            mech = "post"
        else:
            raise ValueError(scfg.policy)
        eff_l = full.effective_l if mech == full.mechanism else \
            cost_model.effective_l(mech, c, scfg.max_pool)
        return cost_model.Route(mech, full.costs, eff_l)

    # ------------------------------------------------------------------
    def execute(self, queries: np.ndarray, selectors: Sequence[Selector],
                scfgs: Sequence[SearchConfig]):
        """The batched request path (paper §4 Fig. 4, generalized).

        Each query carries its own ``SearchConfig``; queries are grouped by
        (mechanism, pool-size bucket, config) and executed as coalesced
        batches. Returns ``(ids_list, dists_list, QueryStats)`` where the
        i-th list entries are (k_i,) arrays — per-query k may differ.
        """
        queries = np.asarray(queries, np.float32)
        if queries.shape[1] != self.store.dim:
            pad = self.store.dim - queries.shape[1]
            queries = np.pad(queries, ((0, 0), (0, pad)))
        B = queries.shape[0]
        assert len(selectors) == B and len(scfgs) == B
        cfg = self.config

        plans = [s.plan(cfg.ql, cfg.cap) for s in selectors]
        routes = [self._route(p, sc) for p, sc in zip(plans, scfgs)]

        out_ids: list = [None] * B
        out_d: list = [None] * B
        stats = QueryStats(
            mechanism=[r.mechanism for r in routes],
            io_pages=np.zeros(B, np.int64),
            est_io_pages=np.array(
                [r.costs[r.mechanism].io_pages for r in routes]),
            dist_comps=np.zeros(B, np.int64),
            est_compute=np.array(
                [r.costs[r.mechanism].compute for r in routes]),
            hops=np.zeros(B, np.int64),
            fp_explored=np.zeros(B, np.int64),
            explored=np.zeros(B, np.int64),
            n_valid=np.zeros(B, np.int64),
            selectivity=np.array([p.selectivity for p in plans]),
            precision_in=np.array([p.precision_in for p in plans]),
        )

        groups: dict = {}
        for i, r in enumerate(routes):
            eff = 1 << max(5, math.ceil(math.log2(max(r.effective_l, 1))))
            eff = min(eff, scfgs[i].max_pool)
            groups.setdefault((r.mechanism, eff, scfgs[i]), []).append(i)

        for (mech, eff_l, scfg), idxs in groups.items():
            strict = scfg.policy in ("strict_in", "strict_pre", "basefilter")
            sub_q = jnp.asarray(queries[idxs])
            sub_sel = [selectors[i] for i in idxs]
            sub_qf = stack_filters([plans[i].qfilter for i in idxs])
            if mech == "pre":
                pp = prefilter.PrefilterParams(
                    l_rerank=scfg.l + scfg.l_rerank_delta, k=scfg.k)
                res = prefilter.prefilter_search(
                    self.store, self.codes, self.codebook, sub_sel, sub_qf,
                    sub_q, pp, speculative=not strict)
                for j, i in enumerate(idxs):
                    out_ids[i] = np.asarray(res.ids[j])
                    out_d[i] = np.asarray(res.dists[j])
                    stats.io_pages[i] = int(res.io_pages[j])
                    stats.dist_comps[i] = int(res.dist_comps[j])
                    stats.n_valid[i] = int(res.n_valid[j])
            else:
                mode = {"in": "strict_in" if scfg.policy == "strict_in"
                        else "spec_in", "post": "post"}[mech]
                sp = search.SearchParams(
                    l_search=eff_l, k=scfg.k, beam_width=scfg.beam_width,
                    max_hops=scfg.max_hops, mode=mode, l_valid=scfg.l)
                res = search.filtered_search(
                    self.store, self.codes, self.codebook, self.mem, sub_qf,
                    sub_q, self.medoid, sp)
                prefetch = np.array([plans[i].pages_prefetch for i in idxs]) \
                    if mode == "spec_in" else 0
                for j, i in enumerate(idxs):
                    out_ids[i] = np.asarray(res.ids[j])
                    out_d[i] = np.asarray(res.dists[j])
                    stats.io_pages[i] = int(res.io_pages[j]) + (
                        int(prefetch[j]) if mode == "spec_in" else 0)
                    stats.dist_comps[i] = int(res.dist_comps[j])
                    stats.hops[i] = int(res.hops[j])
                    stats.fp_explored[i] = int(res.fp_explored[j])
                    stats.explored[i] = int(res.explored[j])
                    stats.n_valid[i] = int(res.n_valid[j])
        return out_ids, out_d, stats

    # ------------------------------------------------------------------
    def search(self, queries: np.ndarray, selectors: Sequence[Selector],
               scfg: SearchConfig = SearchConfig()):
        """Returns (ids (B,k), dists (B,k), QueryStats).

        Thin wrapper over :meth:`execute` with one shared SearchConfig."""
        if len(selectors) == 0:
            return (np.zeros((0, scfg.k), np.int32),
                    np.zeros((0, scfg.k), np.float32), QueryStats.empty())
        ids, dists, stats = self.execute(queries, selectors,
                                         [scfg] * len(selectors))
        return (np.stack(ids).astype(np.int32),
                np.stack(dists).astype(np.float32), stats)


def brute_force_filtered(vectors: np.ndarray, rec_labels: np.ndarray,
                         rec_values: np.ndarray, qfilter, query: np.ndarray,
                         k: int) -> np.ndarray:
    """Exact ground truth: top-k valid ids by full-precision distance."""
    from repro.core.selectors import is_member
    ok = np.asarray(is_member(qfilter, jnp.asarray(rec_labels),
                              jnp.asarray(rec_values)))
    d = np.sum((vectors - query[None, :]) ** 2, axis=1)
    d = np.where(ok, d, np.inf)
    order = np.argsort(d)[:k]
    return order[np.isfinite(d[order])]


def recall_at_k(result_ids: np.ndarray, gt_ids: np.ndarray, k: int) -> float:
    gt = set(int(x) for x in gt_ids[:k])
    if not gt:
        return 1.0
    got = set(int(x) for x in result_ids[:k] if x >= 0)
    return len(got & gt) / len(gt)
