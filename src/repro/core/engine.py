"""FilteredANNEngine — the end-to-end system (paper §4 Fig. 4).

Query processing: per-query cost estimation routes to speculative
pre-filtering, speculative in-filtering, or post-filtering; queries are
grouped by (mechanism, pool-size bucket) and executed as batches; exact
verification piggybacks on re-ranking everywhere.

Baseline policies (paper §5.1 compared systems) are selectable:
  * ``speculative`` — the paper's system (cost-model routing).
  * ``basefilter``  — PipeANN-BaseFilter: strict pre-filtering when
                      selectivity < 1%, otherwise post-filtering.
  * ``strict_in``   — Filtered-DiskANN-like strict in-filtering.
  * ``strict_pre``  — Milvus-like always-pre-filtering.
  * ``post``        — always post-filtering.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cost_model, graph, io_sim, pq as pq_mod, \
    prefilter, search
from repro.core.faults import FaultPlan
from repro.core.labels import (LabelStore, build_label_store,
                               extend_label_store, padded_rows_from_csr,
                               padded_vec_labels)
from repro.core.ranges import (MultiRangeStore, RangeStore,
                               build_multi_range_store)
from repro.core import records as records_mod
from repro.core.records import RecordStore, make_record_store
from repro.core.selectors import (InMemory, Selector, stack_filters)


@dataclasses.dataclass(frozen=True)
class IndexConfig:
    r: int = 32               # Vamana out-degree
    r_dense: int = 480        # 2-hop sample size (10-20x R, paper §4.1)
    l_build: int = 64
    alpha: float = 1.2
    pq_m: int = 16            # PQ subquantizers
    pq_iters: int = 8
    max_labels: int = 16      # per-record label slots (exact verification)
    ql: int = 8               # max labels per query
    qr: int = 4               # range-predicate slots per query (NR)
    cap: int = 2048           # merged rare-list capacity
    seed: int = 0
    builder: str = "batched"  # 'batched' (device pipeline) | 'reference'


@dataclasses.dataclass(frozen=True)
class SearchConfig:
    k: int = 10
    l: int = 32               # base pool length L (recall knob)
    beam_width: int = 1
    max_hops: int = 512
    alpha: float = 10.0       # cost-model IO weight
    beta: float = 1.0
    max_pool: int = 1024      # effective-L cap
    l_rerank_delta: int = 16  # δ extra re-ranked vectors for pre-filtering
    policy: str = "speculative"
    hop_chunk: int = 32       # hops between straggler-compaction checks in
                              # the bucketed search driver (0 = single-shot
                              # jit, the pre-pipelined execution)
    prefetch_depth: int = 2   # record slabs in flight per query (feeds the
                              # modeled SSD latency; results are invariant)
    fault_plan: FaultPlan | None = None
                              # seeded fault injection on the record-read
                              # path (core/faults.py) — None serves the
                              # unmodified clean hot path


def apply_rung(scfg: SearchConfig,
               rung: "cost_model.DegradeRung") -> SearchConfig:
    """SearchConfig for one degrade-ladder rung (cost_model.DEGRADE_LADDER):
    scaled pool length / hop budget, overridden chunking and read-ahead.
    Floors keep k servable; the ``approx`` rung's config sizes its re-rank
    budget (the scan path ignores the traversal knobs)."""
    kw = dict(l=max(scfg.k, int(round(scfg.l * rung.l_scale))),
              max_hops=max(8, int(round(scfg.max_hops
                                        * rung.max_hops_scale))))
    if rung.hop_chunk is not None:
        kw["hop_chunk"] = rung.hop_chunk
    if rung.prefetch_depth is not None:
        kw["prefetch_depth"] = rung.prefetch_depth
    return dataclasses.replace(scfg, **kw)


def scan_rerank(scfg: SearchConfig,
                rung: "cost_model.DegradeRung | None" = None) -> int:
    """Re-rank budget of the gated full-scan path for a *base* config,
    optionally as scaled by ``rung`` — must match the sizing
    ``approx_scan`` applies to its (already rung-applied) configs so the
    admission controller prices exactly what would execute."""
    l = scfg.l if rung is None else max(scfg.k,
                                        int(round(scfg.l * rung.l_scale)))
    return int(min(scfg.max_pool, max(l + scfg.l_rerank_delta,
                                      2 * scfg.k)))


@dataclasses.dataclass
class QueryStats:
    mechanism: list
    io_pages: np.ndarray
    est_io_pages: np.ndarray
    dist_comps: np.ndarray
    est_compute: np.ndarray
    hops: np.ndarray
    fp_explored: np.ndarray
    explored: np.ndarray
    n_valid: np.ndarray
    selectivity: np.ndarray
    precision_in: np.ndarray
    faults: np.ndarray        # injected fault events (0 without a plan)
    retries: np.ndarray       # extra read attempts issued by the ladder
    degraded: np.ndarray      # rows answered from the in-memory fallback
    disk: dict | None = None  # disk-tier counter delta for this batch
                              # (cache hits/misses/hit_rate, pages_read,
                              # readahead, gated_skips, measured p50 page
                              # latency) — None on the device backend

    @classmethod
    def empty(cls) -> "QueryStats":
        return cls(mechanism=[], io_pages=np.zeros(0, np.int64),
                   est_io_pages=np.zeros(0), dist_comps=np.zeros(0, np.int64),
                   est_compute=np.zeros(0), hops=np.zeros(0, np.int64),
                   fp_explored=np.zeros(0, np.int64),
                   explored=np.zeros(0, np.int64),
                   n_valid=np.zeros(0, np.int64), selectivity=np.zeros(0),
                   precision_in=np.zeros(0), faults=np.zeros(0, np.int64),
                   retries=np.zeros(0, np.int64),
                   degraded=np.zeros(0, np.int64), disk=None)


class FilteredANNEngine:
    def __init__(self, store: RecordStore, codes, codebook, mem: InMemory,
                 label_store: LabelStore, range_store: MultiRangeStore,
                 medoid: int, config: IndexConfig):
        self.store = store
        self.codes = codes
        self.codebook = codebook
        self.mem = mem
        self.label_store = label_store
        self.range_store = range_store
        self.medoid = medoid
        self.config = config
        self.n = label_store.n_vectors  # valid records (store may hold pads)
        self._builder = None      # lazy IncrementalBuilder (insert path)
        self._runner = None       # ShardedSearchRunner when shard()ed
        self.calibration: cost_model.Calibration | None = None
        self.disk_store = None    # storage.DiskRecordStore when backend=disk
        self.io_model: io_sim.IOModel | None = None
                                  # fitted from measured reads (calibrate_io)

    def calibrate(self, source="BENCH_search.json") -> bool:
        """Swap the router's hardcoded per-hop compute constants for the
        fused pipeline's measured counters (dist_comps / approx_checks /
        hops per hop, from a BENCH_search.json payload or a prebuilt
        :class:`~repro.core.cost_model.Calibration`). Opt-in: routing
        stays analytic until called. Returns True when calibration data
        was found and installed; ``calibrate(None)`` reverts."""
        if source is None or isinstance(source, cost_model.Calibration):
            self.calibration = source
        elif isinstance(source, dict):
            try:
                self.calibration = cost_model.Calibration.from_bench(source)
            except (KeyError, TypeError, ValueError):
                # malformed/trimmed payload: degrade to uncalibrated, the
                # same contract as an unreadable path
                self.calibration = None
        else:
            self.calibration = cost_model.load_calibration(source)
        return self.calibration is not None

    @property
    def n_fields(self) -> int:
        return self.range_store.n_fields

    # ------------------------------------------------------------------
    @classmethod
    def build(cls, vectors: np.ndarray, label_offsets: np.ndarray,
              label_flat: np.ndarray, n_labels: int, values: np.ndarray,
              config: IndexConfig = IndexConfig(),
              shards: int = 0) -> "FilteredANNEngine":
        """``values`` is the numeric attribute matrix, (n, F) — a flat
        (n,) array is accepted as the single-field F=1 case.

        ``shards > 1`` builds AND serves over a local mesh of that many
        devices: the Vamana link phase runs per shard with PQ-approximate
        navigation (``distributed.build_vamana_sharded`` — the codebook is
        trained first so ADC distances can steer the beam pools; the
        RobustPrune re-rank stays exact, recall within the batched
        builder's ±1% envelope), and the returned engine is already
        :meth:`shard`-ed so ``execute`` routes the hop loop through the
        mesh."""
        vectors = np.asarray(vectors, np.float32)
        n, d = vectors.shape
        # pad dim to a multiple of pq_m
        if d % config.pq_m:
            pad = config.pq_m - d % config.pq_m
            vectors = np.pad(vectors, ((0, 0), (0, pad)))
            d += pad

        # PQ first: the sharded builder navigates on ADC distances
        key = jax.random.PRNGKey(config.seed)
        codebook = pq_mod.train_pq(key, jnp.asarray(vectors), config.pq_m,
                                   iters=config.pq_iters)
        codes = pq_mod.encode_pq(codebook, jnp.asarray(vectors))

        if shards > 1:
            if config.builder != "batched":
                raise ValueError(
                    "shards > 1 requires builder='batched' (the sharded "
                    f"link path), got {config.builder!r}")
            from repro.core.distributed import ShardPlan, \
                build_vamana_sharded
            from repro.launch.mesh import make_local_mesh
            plan = ShardPlan(mesh=make_local_mesh(1, shards),
                             shard_axes=("model",))
            adj, medoid = build_vamana_sharded(
                vectors, plan, config.r, config.l_build, config.alpha,
                seed=config.seed, codes=codes, codebook=codebook)
        elif config.builder == "batched":
            adj, medoid = graph.build_vamana_batched(
                vectors, config.r, config.l_build, config.alpha,
                seed=config.seed)
        elif config.builder == "reference":
            adj, medoid = graph.build_vamana(vectors, config.r,
                                             config.l_build, config.alpha,
                                             seed=config.seed)
        else:
            raise ValueError(f"unknown builder {config.builder!r}")
        dense = graph.densify_2hop(adj, config.r_dense, seed=config.seed + 1)

        label_store = build_label_store(label_offsets, label_flat, n_labels)
        range_store = build_multi_range_store(values)
        rec_labels = padded_vec_labels(label_store, config.max_labels)

        store = make_record_store(vectors, adj, dense, rec_labels,
                                  range_store.values)

        mem = InMemory(blooms=jnp.asarray(label_store.blooms),
                       bucket_codes=jnp.asarray(range_store.bucket_codes))
        eng = cls(store, codes, codebook, mem, label_store, range_store,
                  medoid, config)
        if shards > 1:
            eng.shard(shards)
        return eng

    # ------------------------------------------------------------------
    def shard(self, shards: int) -> "FilteredANNEngine":
        """Route the pipelined hop loop through a mesh of ``shards``
        devices (``distributed.ShardedSearchRunner``): the record store is
        ID-range-sharded over the mesh's model axis, queries row-shard per
        bucket, and results stay bit-identical to the single-device driver
        (docs/distributed.md). ``shards in (0, 1)`` reverts to local
        execution. In place; returns self. Requires the device backend —
        the disk tier already owns the fetch seam."""
        if shards in (0, 1):
            self._runner = None
            return self
        if self.disk_store is not None:
            raise ValueError(
                "sharded execution requires the device backend: the disk "
                "tier's host fetch already owns the fetch_fn seam "
                "(shard before to_disk, or serve from the device store)")
        from repro.core.distributed import ShardPlan, ShardedSearchRunner
        from repro.launch.mesh import make_local_mesh
        plan = ShardPlan(mesh=make_local_mesh(1, shards),
                         shard_axes=("model",))
        self._runner = ShardedSearchRunner(plan, self.store, self.codes,
                                           self.codebook, self.mem)
        return self

    @property
    def n_shards(self) -> int:
        """Mesh shards the hop loop spans (1 = local single-device)."""
        return self._runner.n_shards if self._runner is not None else 1

    # ------------------------------------------------------------------
    def to_disk(self, path: str, storage_config=None) -> "FilteredANNEngine":
        """Switch this engine to the disk backend (storage/disk.py).

        The record arrays are spilled to page-aligned slab files at
        ``path`` and replaced by a 1-row stub carrying only shapes and
        page counts — the device tier keeps PQ codes + bloom/bucket
        words, every record byte flows through the disk store's fetch
        callable. Results are bit-identical to the device backend (the
        slabs hold the exact same float32/int32 values). In place;
        returns self.
        """
        from repro.storage import DiskRecordStore, StorageConfig
        cfg = storage_config or StorageConfig()
        ds = DiskRecordStore.from_record_store(path, self.store, n=self.n,
                                               config=cfg)
        self.attach_disk_store(ds)
        return self

    def attach_disk_store(self, disk_store) -> None:
        """Adopt an already-open :class:`~repro.storage.DiskRecordStore`
        (e.g. from a restored checkpoint) and drop the device arrays."""
        self.disk_store = disk_store
        self.store = disk_store.stub_store()
        self._runner = None   # sharded runner holds device copies; disk owns
                              # the fetch seam now

    def calibrate_io(self) -> "io_sim.IOModel | None":
        """Fit :class:`io_sim.IOModel` from the disk tier's measured read
        samples, replacing the modeled constants for latency reporting.
        Returns the fitted model (None without a disk store or samples)."""
        if self.disk_store is None or not self.disk_store.samples:
            return None
        self.io_model = io_sim.IOModel.calibrate_from_samples(
            self.disk_store.samples,
            page_bytes=self.disk_store.layout.page_bytes)
        return self.io_model

    # ------------------------------------------------------------------
    def insert(self, vectors: np.ndarray, label_offsets: np.ndarray,
               label_flat: np.ndarray, n_labels: int,
               values: np.ndarray) -> np.ndarray:
        """Append records through the incremental batched build path.

        New nodes are linked by a single final-α pass (greedy search from
        the medoid → batched RobustPrune → reverse-edge scatter). Stores
        are **capacity-padded**: device arrays are allocated at the
        builder's geometric capacity (pad rows unreachable — no edge points
        at them, labels -1, values 0) and new rows are written in place, so
        steady-state inserts keep every array shape stable and the search
        path compiles once instead of re-specializing per insert. Host
        attribute summaries extend incrementally (label postings merge at
        run ends, per-field sorted indexes merge via searchsorted; bucket
        boundaries stay fixed so approx codes remain comparable). The PQ
        codebook is *not* retrained — inserted vectors are encoded against
        the build-time centroids. Inserts always link through the batched
        pipeline regardless of ``config.builder`` — a
        ``builder='reference'`` graph becomes mixed after the first insert
        (fine for serving; rebuild if you need a pure oracle graph for
        A/B comparisons). Returns the new record ids.
        """
        cfg = self.config
        if self.disk_store is not None:
            raise NotImplementedError(
                "insert is not supported on the disk backend: slab files "
                "are append-closed in this release — rebuild the index "
                "(or insert on the device backend, then to_disk)")
        vectors = np.asarray(vectors, np.float32)
        m = vectors.shape[0]
        if m == 0:
            return np.zeros(0, np.int64)
        # store.dim may exceed the build-time input dim only by the pq_m
        # alignment pad, so any narrower batch is a caller error, not a
        # padding case — reject it rather than storing zero-padded geometry
        if not (self.store.dim - cfg.pq_m < vectors.shape[1]
                <= self.store.dim):
            raise ValueError(
                f"vector dim {vectors.shape[1]} does not match index dim "
                f"{self.store.dim} (built from inputs of dim in "
                f"({self.store.dim - cfg.pq_m}, {self.store.dim}])")
        if vectors.shape[1] < self.store.dim:
            vectors = np.pad(
                vectors, ((0, 0), (0, self.store.dim - vectors.shape[1])))
        values = np.asarray(values, np.float32)
        if values.ndim == 1:
            values = values[:, None]
        if values.shape != (m, self.n_fields):
            raise ValueError(
                f"expected ({m}, {self.n_fields}) values, got {values.shape}")
        if self._builder is None:
            self._builder = graph.IncrementalBuilder(
                np.asarray(self.store.vectors)[:self.n],
                np.asarray(self.store.neighbors)[:self.n], self.medoid,
                ell=cfg.l_build, alpha=cfg.alpha)
        n0 = self.n
        ids = self._builder.add_batch(vectors)

        # host attribute summaries: incremental extension (no rebuild)
        self.label_store = extend_label_store(
            self.label_store, np.asarray(label_offsets, np.int64),
            np.asarray(label_flat, np.int32), int(n_labels))
        self.range_store = self.range_store.append(values)

        self._refresh_padded_stores(n0, m, vectors)
        self.n = n0 + m
        if self._runner is not None:
            # the runner holds its own padded device copy of the store —
            # rebuild it over the same mesh so sharded serving sees the
            # inserted records
            self.shard(self._runner.n_shards)
        return ids

    def _refresh_padded_stores(self, n0: int, m: int, new_vectors):
        """Sync the capacity-padded device tier after a host-store extend.

        When capacity is unchanged (the steady state) only the m new rows
        are written; a capacity growth reallocates every array once at the
        new capacity. ``dense_neighbors`` is resampled over the grown graph
        either way — edges of *existing* nodes change when inserts scatter
        reverse edges into them.
        """
        cfg = self.config
        cap = self._builder.capacity
        n_new = n0 + m
        adj_dev = self._builder.adjacency_device          # (cap, R)
        dense = graph.densify_2hop(np.asarray(adj_dev), cfg.r_dense,
                                   seed=cfg.seed + 1)
        # new rows come from the *extended* label store's CSR slice, which
        # has already deduped (vector, label) pairs — padding the raw input
        # instead could drop a real label past the max_labels slots that
        # the host inverted index still serves (false negatives)
        ls = self.label_store
        row_start = int(ls.vec_offsets[n0])
        new_rec_labels = padded_rows_from_csr(
            ls.vec_offsets[n0:] - row_start, ls.vec_labels[row_start:],
            cfg.max_labels)
        # slice per field, then stack: the MultiRangeStore matrix properties
        # materialize all N rows — O(m·F) here, not O(N·F) per insert
        new_values = np.stack([s.values[n0:n_new]
                               for s in self.range_store.stores], axis=1)
        new_codes = pq_mod.encode_pq(self.codebook, jnp.asarray(new_vectors))
        new_blooms = ls.blooms[n0:n_new]
        new_buckets = np.stack([s.bucket_codes[n0:n_new]
                                for s in self.range_store.stores], axis=1)
        # a skewed-stream quantile refresh re-derives the bucket bounds and
        # re-codes EVERY row (ranges.RangeStore.append): the device code
        # column must be replaced wholesale — writing only the new rows
        # would mix codes from two incompatible bounds generations and
        # break the no-false-negative contract of is_member_approx
        rebucketed = self.range_store.bounds_refreshed

        grown = self.store.vectors.shape[0] != cap
        if grown:
            def pad_to_cap(arr_np, fill, dtype):
                out = np.full((cap,) + arr_np.shape[1:], fill, dtype)
                out[:arr_np.shape[0]] = arr_np
                return jnp.asarray(out)

            rec_labels = pad_to_cap(
                np.asarray(self.store.rec_labels)[:n0], -1, np.int32)
            rec_values = pad_to_cap(
                np.asarray(self.store.rec_values)[:n0], 0.0, np.float32)
            codes = pad_to_cap(np.asarray(self.codes)[:n0], 0, np.uint8)
            blooms = pad_to_cap(
                np.asarray(self.mem.blooms)[:n0], 0, np.uint32)
            buckets = pad_to_cap(
                np.asarray(self.mem.bucket_codes)[:n0], 0, np.uint8)
        else:
            rec_labels = self.store.rec_labels
            rec_values = self.store.rec_values
            codes = self.codes
            blooms = self.mem.blooms
            buckets = self.mem.bucket_codes

        # donated row writes (graph.write_rows): steady-state inserts reuse
        # the capacity-padded buffers in place instead of paying the
        # O(capacity) functional-update copy per array (ROADMAP item). The
        # pre-insert arrays are consumed — holders of a stale
        # ``engine.store``/``engine.mem`` must re-read after insert.
        rec_labels = graph.write_rows(
            rec_labels, jnp.asarray(new_rec_labels, rec_labels.dtype), n0)
        rec_values = graph.write_rows(
            rec_values, jnp.asarray(new_values, rec_values.dtype), n0)
        self.codes = graph.write_rows(codes, new_codes.astype(codes.dtype),
                                      n0)
        if rebucketed:
            full_buckets = np.zeros((cap, self.n_fields), np.uint8)
            full_buckets[:n_new] = self.range_store.bucket_codes
            buckets_dev = jnp.asarray(full_buckets).astype(buckets.dtype)
        else:
            buckets_dev = graph.write_rows(
                buckets, jnp.asarray(new_buckets, buckets.dtype), n0)
        self.mem = InMemory(
            blooms=graph.write_rows(
                blooms, jnp.asarray(new_blooms, blooms.dtype), n0),
            bucket_codes=buckets_dev)
        self.store = RecordStore(
            vectors=self._builder.data_device, neighbors=adj_dev,
            dense_neighbors=jnp.asarray(dense), rec_labels=rec_labels,
            rec_values=rec_values, pages_std=self.store.pages_std,
            pages_dense=self.store.pages_dense,
            # the 2-hop sample was just resampled, so the per-record
            # first-occurrence mask is re-derived with it (pad rows are
            # all -1 ⇒ all-False, unreachable anyway)
            cand_first=jnp.asarray(records_mod.candidate_first_mask(
                np.asarray(adj_dev), dense)))

    # ------------------------------------------------------------------
    def cost_inputs(self, plan, scfg: SearchConfig) -> cost_model.CostInputs:
        """The router's CostInputs for one planned query — also the serve
        tier's admission/degrade-ladder pricing basis."""
        return cost_model.CostInputs(
            n=self.n, l=scfg.l, s=plan.selectivity,
            p_pre=plan.precision_pre, p_in=plan.precision_in,
            x_pre=plan.pages_prescan, x_in=plan.pages_prefetch,
            r=self.store.degree,
            r_d=self.store.degree + self.store.dense_degree,
            s_r=self.store.pages_std, s_d=self.store.pages_dense)

    def estimate_cost(self, selector: Selector,
                      scfg: SearchConfig = None,
                      rung: "cost_model.DegradeRung | None" = None) -> float:
        """Modeled service cost of one query (α·pages + β·comps) at the
        routed mechanism — the admission controller's per-request unit,
        scaled into µs by the server's measured EWMA. ``rung`` prices the
        query at a degrade-ladder step instead of full service."""
        scfg = scfg or SearchConfig()
        cfg = self.config
        plan = selector.plan(cfg.ql, cfg.cap, cfg.qr)
        c = self.cost_inputs(plan, scfg)
        if rung is not None:
            return cost_model.rung_cost(
                c, rung, scfg.alpha, scfg.beta, scfg.max_pool,
                base_prefetch=scfg.prefetch_depth,
                rerank=scan_rerank(scfg, rung), calib=self.calibration)
        route = self._route(plan, scfg)
        return route.costs[route.mechanism].total(scfg.alpha, scfg.beta)

    def _route(self, plan, scfg: SearchConfig) -> cost_model.Route:
        c = self.cost_inputs(plan, scfg)
        full = cost_model.route_query(c, scfg.alpha, scfg.beta,
                                      scfg.max_pool, calib=self.calibration)
        if plan.force_mech is not None:
            # the selector cannot be expressed by the device filter algebra;
            # only the forced mechanism preserves correctness (MaskSelector)
            mech = plan.force_mech
        elif scfg.policy == "speculative":
            return full
        elif scfg.policy == "basefilter":
            mech = "pre" if plan.selectivity < 0.01 else "post"
        elif scfg.policy == "strict_in":
            mech = "in"
        elif scfg.policy == "strict_pre":
            mech = "pre"
        elif scfg.policy == "post":
            mech = "post"
        else:
            raise ValueError(scfg.policy)
        # strict in-filtering traverses without bridge nodes, so its pool is
        # sized by the strict branch of the shared formula (ROADMAP: weak
        # recall at small L came from reusing the speculative bridge-regime
        # pool here)
        strict_in = scfg.policy == "strict_in" and mech == "in"
        eff_l = full.effective_l if (mech == full.mechanism
                                     and not strict_in) else \
            cost_model.effective_l(mech, c, scfg.max_pool, strict=strict_in)
        return cost_model.Route(mech, full.costs, eff_l)

    # ------------------------------------------------------------------
    def execute(self, queries: np.ndarray, selectors: Sequence[Selector],
                scfgs: Sequence[SearchConfig]):
        """The batched request path (paper §4 Fig. 4, generalized).

        Each query carries its own ``SearchConfig``; queries are grouped by
        (mechanism, pool-size bucket, config) and executed as coalesced
        batches. Returns ``(ids_list, dists_list, QueryStats)`` where the
        i-th list entries are (k_i,) arrays — per-query k may differ.
        """
        queries = np.asarray(queries, np.float32)
        if queries.shape[1] != self.store.dim:
            pad = self.store.dim - queries.shape[1]
            queries = np.pad(queries, ((0, 0), (0, pad)))
        B = queries.shape[0]
        assert len(selectors) == B and len(scfgs) == B
        cfg = self.config

        plans = [s.plan(cfg.ql, cfg.cap, cfg.qr) for s in selectors]
        routes = [self._route(p, sc) for p, sc in zip(plans, scfgs)]

        out_ids: list = [None] * B
        out_d: list = [None] * B
        stats = QueryStats(
            mechanism=[r.mechanism for r in routes],
            io_pages=np.zeros(B, np.int64),
            est_io_pages=np.array(
                [r.costs[r.mechanism].io_pages for r in routes]),
            dist_comps=np.zeros(B, np.int64),
            est_compute=np.array(
                [r.costs[r.mechanism].compute for r in routes]),
            hops=np.zeros(B, np.int64),
            fp_explored=np.zeros(B, np.int64),
            explored=np.zeros(B, np.int64),
            n_valid=np.zeros(B, np.int64),
            selectivity=np.array([p.selectivity for p in plans]),
            precision_in=np.array([p.precision_in for p in plans]),
            faults=np.zeros(B, np.int64),
            retries=np.zeros(B, np.int64),
            degraded=np.zeros(B, np.int64),
        )

        groups: dict = {}
        for i, r in enumerate(routes):
            eff = 1 << max(5, math.ceil(math.log2(max(r.effective_l, 1))))
            eff = min(eff, scfgs[i].max_pool)
            groups.setdefault((r.mechanism, eff, scfgs[i]), []).append(i)

        ds = self.disk_store
        disk_before = ds.snapshot() if ds is not None else None
        for (mech, eff_l, scfg), idxs in groups.items():
            strict = scfg.policy in ("strict_in", "strict_pre", "basefilter")
            # keep batch assembly on the host: the raw group width is
            # composition-dependent, and the pipelined driver pads it to a
            # power-of-two bucket before anything touches the device
            sub_q = np.ascontiguousarray(queries[idxs])
            sub_sel = [selectors[i] for i in idxs]
            sub_qf = stack_filters([plans[i].qfilter for i in idxs])
            if ds is not None:
                # arm the disk tier with this group's knobs: the fault
                # plan (host draws must mirror the traced ladder) and the
                # read-ahead window (depth − 1 scales it)
                ds.fault_plan = scfg.fault_plan
                ds.prefetch_depth = scfg.prefetch_depth
            if mech == "pre":
                # the re-rank pool scales with the superset's precision
                # (effective_l = L/p_pre + L): a speculative AND scans only
                # its cheapest branch, so only ~p_pre of the superset is
                # valid — L+δ alone would starve multi-predicate queries
                pp = prefilter.PrefilterParams(
                    l_rerank=eff_l + scfg.l_rerank_delta, k=scfg.k)
                res = prefilter.prefilter_search(
                    self.store, self.codes, self.codebook, sub_sel, sub_qf,
                    sub_q, pp, speculative=not strict,
                    host_fetch=ds.fetch_host if ds is not None else None)
                for j, i in enumerate(idxs):
                    out_ids[i] = np.asarray(res.ids[j])
                    out_d[i] = np.asarray(res.dists[j])
                    stats.io_pages[i] = int(res.io_pages[j])
                    stats.dist_comps[i] = int(res.dist_comps[j])
                    stats.n_valid[i] = int(res.n_valid[j])
            else:
                mode = {"in": "strict_in" if scfg.policy == "strict_in"
                        else "spec_in", "post": "post"}[mech]
                sp = search.SearchParams(
                    l_search=eff_l, k=scfg.k, beam_width=scfg.beam_width,
                    max_hops=scfg.max_hops, mode=mode, l_valid=scfg.l,
                    prefetch_depth=scfg.prefetch_depth,
                    fault_plan=scfg.fault_plan)
                entries = None
                seed_pages = np.zeros(len(idxs), np.int64)
                if mode == "strict_in":
                    # strict in-filtering needs exactly-valid entry seeds:
                    # its pool admits only valid records, so starting at the
                    # medoid strands the search whenever no valid record is
                    # reachable through valid nodes (the baseline's analogue
                    # of Filtered-DiskANN's per-label entry points). The
                    # seeds come from a query-time attribute-index scan, so
                    # its pages are charged to the query — arbitrary range /
                    # composite filters cannot be precomputed offline.
                    ents = np.full((len(idxs), 4), -1, np.int32)
                    for j, i in enumerate(idxs):
                        seeds, pages = _strict_seed_ids(sub_sel[j],
                                                        self.medoid, 4)
                        ents[j, :seeds.size] = seeds
                        seed_pages[j] = pages
                    entries = ents
                # the bucketed pipelined driver: chunked hops + straggler
                # compaction (search.filtered_search_pipelined); hop_chunk=0
                # falls back to the single-shot jit
                res = search.filtered_search_pipelined(
                    self.store, self.codes, self.codebook, self.mem, sub_qf,
                    sub_q, self.medoid, sp, entries=entries,
                    hop_chunk=scfg.hop_chunk,
                    **({"fetch_fn": ds.fetch_callable}
                       if ds is not None else
                       {"runner": self._runner}
                       if self._runner is not None else {}))
                prefetch = np.array([plans[i].pages_prefetch for i in idxs]) \
                    if mode == "spec_in" else 0
                for j, i in enumerate(idxs):
                    out_ids[i] = np.asarray(res.ids[j])
                    out_d[i] = np.asarray(res.dists[j])
                    stats.io_pages[i] = int(res.io_pages[j]) + int(
                        seed_pages[j]) + (
                        int(prefetch[j]) if mode == "spec_in" else 0)
                    stats.dist_comps[i] = int(res.dist_comps[j])
                    stats.hops[i] = int(res.hops[j])
                    stats.fp_explored[i] = int(res.fp_explored[j])
                    stats.explored[i] = int(res.explored[j])
                    stats.n_valid[i] = int(res.n_valid[j])
                    stats.faults[i] = int(res.faults[j])
                    stats.retries[i] = int(res.retries[j])
                    stats.degraded[i] = int(res.degraded[j])
        if ds is not None:
            stats.disk = ds.delta(disk_before, ds.snapshot())
        return out_ids, out_d, stats

    # ------------------------------------------------------------------
    def approx_scan(self, queries: np.ndarray,
                    selectors: Sequence[Selector],
                    scfgs: Sequence[SearchConfig]):
        """Last-rung degrade execution (serve overload ladder): a gated
        full-corpus ADC scan over the in-memory code tier, then exact
        fetch + verification of the top re-rank set — no graph traversal,
        no per-hop device round-trips, I/O bounded by the re-rank budget.

        Same return shape as :meth:`execute`. The contract matches PR 7's
        fault ladder: candidate generation is approximate (ADC order +
        superset membership gate over *every* id — no valid record can be
        excluded), results are exactly verified (no false positives), and
        served queries are flagged via ``stats.degraded``."""
        queries = np.asarray(queries, np.float32)
        if queries.shape[1] != self.store.dim:
            pad = self.store.dim - queries.shape[1]
            queries = np.pad(queries, ((0, 0), (0, pad)))
        B = queries.shape[0]
        assert len(selectors) == B and len(scfgs) == B
        cfg = self.config
        plans = [s.plan(cfg.ql, cfg.cap, cfg.qr) for s in selectors]
        out_ids: list = [None] * B
        out_d: list = [None] * B
        stats = QueryStats(
            mechanism=["scan"] * B,
            io_pages=np.zeros(B, np.int64), est_io_pages=np.zeros(B),
            dist_comps=np.zeros(B, np.int64), est_compute=np.zeros(B),
            hops=np.zeros(B, np.int64), fp_explored=np.zeros(B, np.int64),
            explored=np.zeros(B, np.int64), n_valid=np.zeros(B, np.int64),
            selectivity=np.array([p.selectivity for p in plans]),
            precision_in=np.array([p.precision_in for p in plans]),
            faults=np.zeros(B, np.int64), retries=np.zeros(B, np.int64),
            degraded=np.ones(B, np.int64))
        ds = self.disk_store
        disk_before = ds.snapshot() if ds is not None else None
        qjn = jnp.asarray(queries)
        for i in range(B):
            scfg = scfgs[i]
            rerank = int(min(scfg.max_pool,
                             max(scfg.l + scfg.l_rerank_delta,
                                 2 * scfg.k)))
            qf = plans[i].qfilter
            top_ids, _ = prefilter.scan_all_gated(
                self.codes, self.codebook, self.mem, qf, qjn[i], rerank,
                prefilter.SCAN_CHUNK)
            pp = prefilter.PrefilterParams(l_rerank=rerank, k=scfg.k)
            if ds is None:
                ids, dists, io, nv = prefilter._rerank_verify(
                    self.store, qf, qjn[i], top_ids, pp)
            else:
                tid = np.asarray(top_ids)
                rec = ds.fetch_host(np.where(tid >= 0, tid, 0))
                ids, dists, io, nv = prefilter._verify_fetched(
                    qf, qjn[i], top_ids, jnp.asarray(rec["vectors"]),
                    jnp.asarray(rec["rec_labels"]),
                    jnp.asarray(rec["rec_values"]), pp,
                    self.store.pages_std)
            est = cost_model.approx_scan_cost(
                self.cost_inputs(plans[i], scfg), rerank)
            out_ids[i] = np.asarray(ids)
            out_d[i] = np.asarray(dists)
            stats.io_pages[i] = int(io)
            stats.est_io_pages[i] = est.io_pages
            stats.dist_comps[i] = int(self.codes.shape[0])
            stats.est_compute[i] = est.compute
            stats.explored[i] = rerank
            stats.n_valid[i] = int(nv)
        if ds is not None:
            stats.disk = ds.delta(disk_before, ds.snapshot())
        return out_ids, out_d, stats

    # ------------------------------------------------------------------
    def search(self, queries: np.ndarray, selectors: Sequence[Selector],
               scfg: SearchConfig = SearchConfig()):
        """Returns (ids (B,k), dists (B,k), QueryStats).

        Thin wrapper over :meth:`execute` with one shared SearchConfig."""
        if len(selectors) == 0:
            return (np.zeros((0, scfg.k), np.int32),
                    np.zeros((0, scfg.k), np.float32), QueryStats.empty())
        ids, dists, stats = self.execute(queries, selectors,
                                         [scfg] * len(selectors))
        return (np.stack(ids).astype(np.int32),
                np.stack(dists).astype(np.float32), stats)


def _strict_seed_ids(sel: Selector, medoid: int,
                     e: int) -> tuple[np.ndarray, int]:
    """Entry seeds for strict in-filtering: up to ``e`` exactly-valid
    records, evenly spaced over the attribute index scan (diverse starting
    regions), plus the scan's page count. Falls back to the medoid when
    the filter matches nothing."""
    from repro.core.prefilter import _strict_scan
    ids, pages = _strict_scan(sel)
    ids = np.asarray(ids)
    ids = ids[ids >= 0]
    if ids.size == 0:
        return np.array([medoid], np.int32), int(pages)
    take = np.linspace(0, ids.size - 1, num=min(e, ids.size)).astype(np.int64)
    return np.unique(ids[take]).astype(np.int32), int(pages)


def brute_force_filtered(vectors: np.ndarray, rec_labels: np.ndarray,
                         rec_values: np.ndarray, qfilter, query: np.ndarray,
                         k: int) -> np.ndarray:
    """Exact ground truth: top-k valid ids by full-precision distance."""
    from repro.core.selectors import is_member
    ok = np.asarray(is_member(qfilter, jnp.asarray(rec_labels),
                              jnp.asarray(rec_values)))
    d = np.sum((vectors - query[None, :]) ** 2, axis=1)
    d = np.where(ok, d, np.inf)
    order = np.argsort(d)[:k]
    return order[np.isfinite(d[order])]


def recall_at_k(result_ids: np.ndarray, gt_ids: np.ndarray, k: int) -> float:
    gt = set(int(x) for x in gt_ids[:k])
    if not gt:
        return 1.0
    got = set(int(x) for x in result_ids[:k] if x >= 0)
    return len(got & gt) / len(gt)
