"""Record store: the on-"SSD" tier (see DESIGN.md §2).

Each logical record co-locates (paper Fig. 1 + §4.1):
    full-precision vector | out-neighbor IDs | [2-hop neighbor IDs] | attributes

Attributes ride in the record's final-page slack, so exact verification during
re-ranking costs no extra I/O. ``pages_std`` / ``pages_dense`` give the page
cost of one record fetch without / with the densified 2-hop list; in-filtering
reads the dense record, pre-/post-filtering the standard one.

On a TPU pod the arrays are sharded over the `model` mesh axis (see
core/distributed.py); here they are plain device arrays. On the disk
backend this exact layout is materialized as page-aligned slab files
(``storage/slab.py``, docs/storage.md) and the device tier holds only a
1-row stub carrying the shapes and page counts.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import io_sim


class RecordStore(NamedTuple):
    vectors: jax.Array        # (N, D) float32 — full precision
    neighbors: jax.Array      # (N, R) int32, padded -1
    dense_neighbors: jax.Array  # (N, R_d) int32, padded -1 (2-hop sample)
    rec_labels: jax.Array     # (N, ML) int32, padded -1
    rec_values: jax.Array     # (N, F) float32 — one column per numeric field
    pages_std: int            # pages per standard-record fetch
    pages_dense: int          # pages per densified-record fetch
    # (N, R+R_d) bool: first slab-order occurrence of each id within this
    # record's candidate list [neighbors ++ dense_neighbors] (-1 pads
    # False). Query-independent, so it is precomputed when the graph is
    # (re)built and rides the record like the other co-located fields —
    # R+R_d BITS in the final-page slack, no extra pages. The W=1 hop
    # loop reads it instead of paying a per-hop packed-sort dedup; when
    # absent (None: legacy checkpoints, sharded local stores) the search
    # falls back to computing first-occurrence on the fly.
    cand_first: jax.Array | None = None

    @property
    def n(self) -> int:
        return self.vectors.shape[0]

    @property
    def dim(self) -> int:
        return self.vectors.shape[1]

    @property
    def degree(self) -> int:
        return self.neighbors.shape[1]

    @property
    def dense_degree(self) -> int:
        return self.dense_neighbors.shape[1]

    @property
    def n_fields(self) -> int:
        return self.rec_values.shape[1]


def candidate_first_mask(neighbors: np.ndarray,
                         dense_neighbors: np.ndarray) -> np.ndarray:
    """(N, R+R_d) bool — True at the first occurrence of each id within
    one record's candidate list ``[neighbors ++ dense_neighbors]``; -1
    pads are False.

    The 2-hop sample repeats ids (and may repeat direct neighbors), so
    the hop loop needs an intra-record first-occurrence mask every time a
    record's candidates are proposed. The mask depends only on the graph
    rows — never on the query — so it is derived here once per (re)build
    instead of per hop. Row-wise stable argsort keeps equal ids in slab
    order, making "first in sorted run" ≡ "first in slab order"."""
    cand = np.concatenate([np.asarray(neighbors), np.asarray(dense_neighbors)],
                          axis=1)
    order = np.argsort(cand, axis=1, kind="stable")
    s = np.take_along_axis(cand, order, 1)
    first_sorted = np.concatenate(
        [np.ones((cand.shape[0], 1), bool), s[:, 1:] != s[:, :-1]], axis=1)
    out = np.zeros_like(first_sorted)
    np.put_along_axis(out, order, first_sorted, 1)
    return out & (cand >= 0)


def make_record_store(vectors: np.ndarray, neighbors: np.ndarray,
                      dense_neighbors: np.ndarray, rec_labels: np.ndarray,
                      rec_values: np.ndarray,
                      vec_dtype_size: int = 4) -> RecordStore:
    n, d = vectors.shape
    ml = rec_labels.shape[1]
    rec_values = np.asarray(rec_values, np.float32)
    if rec_values.ndim == 1:            # legacy single-field call sites
        rec_values = rec_values[:, None]
    n_fields = rec_values.shape[1]
    pages_std = io_sim.record_pages(d, vec_dtype_size, neighbors.shape[1],
                                    ml, n_fields)
    pages_dense = io_sim.record_pages(
        d, vec_dtype_size, neighbors.shape[1] + dense_neighbors.shape[1], ml,
        n_fields)
    return RecordStore(
        vectors=jnp.asarray(vectors, jnp.float32),
        neighbors=jnp.asarray(neighbors, jnp.int32),
        dense_neighbors=jnp.asarray(dense_neighbors, jnp.int32),
        rec_labels=jnp.asarray(rec_labels, jnp.int32),
        rec_values=jnp.asarray(rec_values, jnp.float32),
        pages_std=pages_std, pages_dense=pages_dense,
        cand_first=jnp.asarray(
            candidate_first_mask(neighbors, dense_neighbors)))
