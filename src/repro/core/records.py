"""Record store: the on-"SSD" tier (see DESIGN.md §2).

Each logical record co-locates (paper Fig. 1 + §4.1):
    full-precision vector | out-neighbor IDs | [2-hop neighbor IDs] | attributes

Attributes ride in the record's final-page slack, so exact verification during
re-ranking costs no extra I/O. ``pages_std`` / ``pages_dense`` give the page
cost of one record fetch without / with the densified 2-hop list; in-filtering
reads the dense record, pre-/post-filtering the standard one.

On a TPU pod the arrays are sharded over the `model` mesh axis (see
core/distributed.py); here they are plain device arrays.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import io_sim


class RecordStore(NamedTuple):
    vectors: jax.Array        # (N, D) float32 — full precision
    neighbors: jax.Array      # (N, R) int32, padded -1
    dense_neighbors: jax.Array  # (N, R_d) int32, padded -1 (2-hop sample)
    rec_labels: jax.Array     # (N, ML) int32, padded -1
    rec_values: jax.Array     # (N, F) float32 — one column per numeric field
    pages_std: int            # pages per standard-record fetch
    pages_dense: int          # pages per densified-record fetch

    @property
    def n(self) -> int:
        return self.vectors.shape[0]

    @property
    def dim(self) -> int:
        return self.vectors.shape[1]

    @property
    def degree(self) -> int:
        return self.neighbors.shape[1]

    @property
    def dense_degree(self) -> int:
        return self.dense_neighbors.shape[1]

    @property
    def n_fields(self) -> int:
        return self.rec_values.shape[1]


def make_record_store(vectors: np.ndarray, neighbors: np.ndarray,
                      dense_neighbors: np.ndarray, rec_labels: np.ndarray,
                      rec_values: np.ndarray,
                      vec_dtype_size: int = 4) -> RecordStore:
    n, d = vectors.shape
    ml = rec_labels.shape[1]
    rec_values = np.asarray(rec_values, np.float32)
    if rec_values.ndim == 1:            # legacy single-field call sites
        rec_values = rec_values[:, None]
    n_fields = rec_values.shape[1]
    pages_std = io_sim.record_pages(d, vec_dtype_size, neighbors.shape[1],
                                    ml, n_fields)
    pages_dense = io_sim.record_pages(
        d, vec_dtype_size, neighbors.shape[1] + dense_neighbors.shape[1], ml,
        n_fields)
    return RecordStore(
        vectors=jnp.asarray(vectors, jnp.float32),
        neighbors=jnp.asarray(neighbors, jnp.int32),
        dense_neighbors=jnp.asarray(dense_neighbors, jnp.int32),
        rec_labels=jnp.asarray(rec_labels, jnp.int32),
        rec_values=jnp.asarray(rec_values, jnp.float32),
        pages_std=pages_std, pages_dense=pages_dense)
