"""False-positive-aware cost estimation (paper §4.2, Table 1).

Two scaling principles over the candidate pool required to yield L valid
results: selectivity scaling (L/s) and precision scaling (L/p). During
speculative in-filtering at low selectivity (s·R_d/p_in ≤ R) the false
positives are pure bridge nodes — traversed anyway — so their overhead is
excluded; the traversal is equivalent to a standard search with effective
pool length (L/s)·(R/R_d).

Total cost = α·IO_pages + β·distance_comps, α=10, β=1 by default.

The analytic compute terms assume every admitted candidate costs one
distance comparison per out-edge (R, or R + γ·R_d with approximate
checks). The fused hop pipeline measures the real counters per query
(``SearchResult.dist_comps`` / ``approx_checks`` / ``hops``), and
``benchmarks/bench_search.py`` persists their per-mode means in
BENCH_search.json — a :class:`Calibration` built from that payload
replaces the hardcoded per-hop constants, so the router trades I/O
against *measured* compute (engine: ``FilteredANNEngine.calibrate``).
"""
from __future__ import annotations

import dataclasses
import json


GAMMA = 0.05   # relative cost of is_member_approx vs one distance comparison


def joint_and_selectivity(margins) -> float:
    """Joint selectivity of a conjunction from per-predicate marginals.

    Independence product clamped to [0, 1] — the ceiling guards inflated
    marginal estimates; the selectivity-scaled pool formulas (L/s) apply
    their own 1e-9 floor downstream. Used by AndSelector and the filter
    compiler for multi-field range conjunctions.
    """
    s = 1.0
    for m in margins:
        s *= float(m)
    return float(min(1.0, max(s, 0.0)))


@dataclasses.dataclass(frozen=True)
class CostInputs:
    n: int            # dataset size
    l: int            # target pool length L
    s: float          # estimated query selectivity
    p_pre: float      # precision of the pre-filter superset
    p_in: float       # precision of is_member_approx
    x_pre: int        # pages: attribute-index scan for pre-filtering
    x_in: int         # pages: initial rare-posting fetch for in-filtering
    r: int            # standard out-degree
    r_d: int          # densified out-degree (direct + 2-hop)
    s_r: int          # pages per standard record
    s_d: int          # pages per densified record
    gamma: float = GAMMA


@dataclasses.dataclass(frozen=True)
class MechanismCost:
    io_pages: float
    compute: float

    def total(self, alpha: float, beta: float) -> float:
        return alpha * self.io_pages + beta * self.compute


@dataclasses.dataclass(frozen=True)
class ModeCal:
    """Measured per-hop compute for one search mode."""
    dist_per_hop: float       # mean dist_comps / mean hops
    approx_per_hop: float     # mean approx_checks / mean hops


@dataclasses.dataclass(frozen=True)
class Calibration:
    """Per-hop compute constants measured by the fused search pipeline.

    Built from a BENCH_search.json payload (``from_bench``): the bench
    records mean ``dist_comps``/``approx_checks``/``hops`` per mode, and
    their per-hop ratios replace the analytic R / γ·R_d constants in the
    compute terms below. The analytic *hop-count* scaling (1/s, 1/p —
    Table 1) is untouched: calibration refines how much compute one hop
    costs, not how many hops a filter needs. I/O terms stay analytic too
    (page counters are exact by construction)."""
    spec_in: ModeCal
    post: ModeCal

    @classmethod
    def from_bench(cls, payload: dict) -> "Calibration":
        def mode(name: str) -> ModeCal:
            m = payload["modes"][name]
            hops = max(float(m["mean_hops"]), 1e-9)
            return ModeCal(
                dist_per_hop=float(m["mean_dist_comps"]) / hops,
                approx_per_hop=float(m.get("mean_approx_checks", 0.0))
                / hops)
        return cls(spec_in=mode("spec_in"), post=mode("post"))


def load_calibration(path: str = "BENCH_search.json") -> Calibration | None:
    """Calibration from a committed bench payload; None when the file is
    missing or predates the approx-checks counter era."""
    try:
        with open(path) as fh:
            payload = json.load(fh)
        return Calibration.from_bench(payload)
    except (OSError, KeyError, ValueError):
        return None


def pre_filtering_cost(c: CostInputs,
                       calib: Calibration | None = None) -> MechanismCost:
    p = max(c.p_pre, 1e-9)
    io = c.x_pre + (c.l / p) * c.s_r
    compute = c.s * c.n / p
    return MechanismCost(io, compute)


def in_filtering_cost(c: CostInputs,
                      calib: Calibration | None = None) -> MechanismCost:
    s = max(c.s, 1e-9)
    p = max(c.p_in, 1e-9)
    if s * c.r_d / p <= c.r:     # low selectivity: false positives = bridges
        hops = (c.l / s) * (c.r / max(c.r_d, 1))
        io = c.x_in + hops * c.s_d
        compute = (hops + c.gamma * (c.l / s)) * c.r
    else:                        # high selectivity: precision scaling
        hops = c.l / p
        io = c.x_in + hops * c.s_d
        compute = hops * (c.r + c.gamma * c.r_d)
    if calib is not None:
        m = calib.spec_in
        compute = hops * (m.dist_per_hop + c.gamma * m.approx_per_hop)
    return MechanismCost(io, compute)


def post_filtering_cost(c: CostInputs,
                        calib: Calibration | None = None) -> MechanismCost:
    s = max(c.s, 1e-9)
    hops = c.l / s
    io = hops * c.s_r
    compute = hops * c.r if calib is None else hops * calib.post.dist_per_hop
    return MechanismCost(io, compute)


def approx_scan_cost(c: CostInputs, rerank: int) -> MechanismCost:
    """The serving tier's last-rung degrade path: one gated ADC pass over
    the full in-memory code tier (every id is a candidate, approximate
    membership only penalizes the ranking), then exact fetch + verify of
    the top ``rerank`` ids. No graph traversal, no per-hop round-trips.

    I/O is only the re-rank fetch. The scan's per-id ADC is priced at γ —
    the same unit the in-path charges for its per-id table-lookup
    membership checks — because one fused full-corpus pass amortizes far
    better than the hop loop's small sequential gathers that the per-hop
    distance-comp unit was measured on."""
    io = rerank * c.s_r
    compute = c.gamma * c.n + rerank
    return MechanismCost(io, compute)


# ---------------------------------------------------------------------------
# Load-degrade ladder (serve tier) — the load-fault analogue of the PR 7
# I/O fault ladder: each rung trades recall headroom or read-ahead
# footprint for a strictly lower modeled service cost, and every rung
# preserves the no-false-negative contract (scaled-L rungs still verify
# exactly; the scan rung covers every id, its approximate gate only
# over-admits).
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DegradeRung:
    """One step of the overload ladder, as SearchConfig deltas."""
    name: str
    l_scale: float = 1.0          # scales the base pool length L
    max_hops_scale: float = 1.0   # scales the hop budget
    hop_chunk: int | None = None  # override (None keeps the config's)
    prefetch_depth: int | None = None
    approx: bool = False          # serve via the gated full-scan path


DEGRADE_LADDER: tuple = (
    DegradeRung("full"),
    # results-invariant first step: shed the speculative read-ahead
    # footprint and tighten the compaction cadence before touching recall
    DegradeRung("lean", prefetch_depth=1, hop_chunk=16),
    DegradeRung("reduced", l_scale=0.75, max_hops_scale=0.5,
                prefetch_depth=1, hop_chunk=16),
    DegradeRung("minimal", l_scale=0.5, max_hops_scale=0.25,
                prefetch_depth=1, hop_chunk=16),
    DegradeRung("scan", l_scale=0.5, approx=True),
)


def rung_inputs(c: CostInputs, rung: DegradeRung) -> CostInputs:
    return dataclasses.replace(
        c, l=max(1, int(round(c.l * rung.l_scale))))


def rung_cost(c: CostInputs, rung: DegradeRung, alpha: float = 10.0,
              beta: float = 1.0, max_pool: int = 1024,
              base_prefetch: int = 2, rerank: int = 64,
              calib: "Calibration | None" = None) -> float:
    """Raw modeled service cost of one query executed at ``rung``.

    This is the number the admission controller scales into µs. The
    *effective* ladder (``ladder_costs``, running minimum) is what must
    be — and is, by construction — monotone non-increasing: the
    scheduler serves at the cheapest rung its pressure level permits,
    never at a rung the model prices above a lighter one. Read-ahead is priced
    as (depth − 1) speculative slab fetches per query — the pages a
    settling query has in flight that overload turns into waste."""
    ci = rung_inputs(c, rung)
    if rung.approx:
        return approx_scan_cost(ci, rerank).total(alpha, beta)
    route = route_query(ci, alpha, beta, max_pool, calib=calib)
    depth = base_prefetch if rung.prefetch_depth is None \
        else rung.prefetch_depth
    overage = max(0, depth - 1) * c.s_d
    return route.costs[route.mechanism].total(alpha, beta) + alpha * overage


def ladder_costs(c: CostInputs, alpha: float = 10.0, beta: float = 1.0,
                 max_pool: int = 1024, base_prefetch: int = 2,
                 rerank: int = 64, calib: "Calibration | None" = None,
                 effective: bool = True) -> list:
    """[(rung, cost)] over DEGRADE_LADDER, in ladder order.

    With ``effective`` (the default) each entry is the *effective* cost
    at that degradation level — the running minimum over rungs 0..i.
    Pressure level i permits every rung up to i and the scheduler serves
    at the cheapest permitted rung (``serve/server.py``), so the
    effective ladder is monotone non-increasing by construction even
    where a raw rung cost inverts (e.g. the full-corpus scan rung is the
    cheapest escape hatch only when graph traversal is the expensive
    side — low selectivity, deep hop budgets — and the scheduler only
    takes it then). ``effective=False`` returns the raw per-rung costs.
    """
    raw = [rung_cost(c, r, alpha, beta, max_pool, base_prefetch,
                     rerank, calib) for r in DEGRADE_LADDER]
    if effective:
        run = []
        best = float("inf")
        for v in raw:
            best = min(best, v)
            run.append(best)
        raw = run
    return list(zip(DEGRADE_LADDER, raw))


@dataclasses.dataclass(frozen=True)
class Route:
    mechanism: str           # 'pre' | 'in' | 'post'
    costs: dict
    effective_l: int         # pool length the executor should use


def effective_l(mech: str, c: CostInputs, max_pool: int,
                strict: bool = False) -> int:
    """Pool length the executor should use for a mechanism (paper §4.2).

    The same selectivity/precision scaling that prices a mechanism also
    sizes its pool, so both the speculative router and the forced-policy
    baselines share this one implementation.

    ``strict`` applies to ``mech == "in"`` only: strict in-filtering
    (Filtered-DiskANN-like) admits only exactly-verified nodes to the pool
    and traverses without bridge nodes or the densified 2-hop edges, so the
    speculative bridge-regime scaling (L/s)·(R/R_d) badly *under*-sizes its
    pool at low selectivity. The valid sub-graph it walks is sparse and
    fragmented; keeping a 1/s-deep frontier of valid nodes is what lets the
    traversal escape local minima, exactly like post-filtering's pool.
    """
    s = max(c.s, 1e-9)
    if mech == "post":
        eff = int(c.l / s) + c.l
    elif mech == "in":
        p = max(c.p_in, 1e-9)
        if strict:                   # strict baseline: selectivity scaling
            eff = int(c.l / s) + c.l
        elif s * c.r_d / p <= c.r:   # low selectivity: bridge-node regime
            eff = int((c.l / s) * (c.r / max(c.r_d, 1))) + c.l
        else:                        # high selectivity: precision scaling
            eff = int(c.l / p) + c.l
    elif mech == "pre":
        eff = int(c.l / max(c.p_pre, 1e-9)) + c.l
    else:
        raise ValueError(mech)
    return max(c.l, min(max_pool, eff))


def route_query(c: CostInputs, alpha: float = 10.0, beta: float = 1.0,
                max_pool: int = 4096,
                calib: Calibration | None = None) -> Route:
    """Pick the cheapest mechanism and size its search parameters."""
    costs = {
        "pre": pre_filtering_cost(c, calib),
        "in": in_filtering_cost(c, calib),
        "post": post_filtering_cost(c, calib),
    }
    totals = {k: v.total(alpha, beta) for k, v in costs.items()}
    mech = min(totals, key=totals.get)
    return Route(mechanism=mech, costs=costs,
                 effective_l=effective_l(mech, c, max_pool))
