"""Deterministic fault injection for the simulated SSD I/O path.

Real SSD reads fail, stall, and return garbage; the engine must survive
all three without ever violating the paper's no-false-negative contract
(verification is post-hoc, so a lost record slab can always be *approx-
imated* — never silently dropped). This module is the single source of
fault decisions for the whole stack:

* **record reads** (the hop loop's frontier slab fetch): page-read
  failures, corrupted slabs, and latency spikes, drawn per
  ``(record id, hop, attempt)`` by a stateless hash so the same
  :class:`FaultPlan` reproduces the same fault pattern in any execution
  order — the bucketed pipelined driver compacts and re-orders query
  rows freely and stays bit-identical to the single-shot jit;
* **checkpoint writes** (:class:`FaultInjector`): flaky leaf writes,
  drawn per ``(step, leaf, attempt)`` on the host.

The search-side ladder on a failed or corrupted slab read is
**retry → hedge → degrade** (docs/robustness.md):

1. retry the read up to ``max_retries`` times (capped exponential
   backoff — accounted by ``io_sim.IOModel.faulted_latency_us``, never
   affecting results);
2. if still failing, issue one *hedged* read (``hedge=True``);
3. if every attempt failed, **degrade gracefully**: the affected row's
   exact distance is substituted with its PQ-approximate (ADC) distance
   from the in-memory tier, its validity with ``is_member_approx`` — a
   no-false-negative superset — and its neighbor expansion is skipped.
   The query completes with ``degraded > 0`` instead of crashing or
   dropping a possibly-valid result.

Every decision function is pure and jit-traceable; a plan with all
rates at zero draws no faults and (because the plan gates code at trace
time) a ``None`` plan compiles to exactly the pre-fault hot path.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

# decision streams: decorrelate the draw families sharing one seed
_STREAM_FAIL = 0x1
_STREAM_CORRUPT = 0x2
_STREAM_SPIKE = 0x3
_STREAM_CKPT = 0x4

_GOLDEN = 0x9E3779B9          # 2^32 / phi — the usual Weyl increments
_MIX_A = 0x7FEB352D           # splitmix32 finalizer constants
_MIX_B = 0x846CA68B


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """Seeded, reproducible fault schedule.

    Hashable and frozen so it rides ``SearchParams`` / ``SearchConfig``
    as a static jit argument: two searches with the same plan share one
    compile, and ``plan=None`` traces the unmodified hot path.

    Rates are per-*attempt* probabilities; a read permanently fails (and
    degrades) only when the initial read, every retry, and the hedge all
    draw bad — p_bad^(1+max_retries+hedge).
    """
    seed: int = 0
    read_fail_rate: float = 0.0    # P[page read fails] per attempt
    corrupt_rate: float = 0.0      # P[slab checksum mismatch] per attempt
    spike_rate: float = 0.0        # P[read latency spike] (accounting only)
    spike_factor: float = 8.0      # spiked read takes this × t_page_us
    ckpt_fail_rate: float = 0.0    # P[checkpoint leaf write fails]
    max_retries: int = 2           # extra read attempts before hedging
    hedge: bool = True             # one final hedged read after retries
    backoff_us: float = 50.0       # first-retry backoff (doubles per retry)
    backoff_cap_us: float = 800.0  # exponential backoff cap

    def __post_init__(self):
        for f in ("read_fail_rate", "corrupt_rate", "spike_rate",
                  "ckpt_fail_rate"):
            v = getattr(self, f)
            assert 0.0 <= v <= 1.0, f"{f}={v} outside [0, 1]"
        assert self.max_retries >= 0

    @property
    def reads_faulty(self) -> bool:
        """Whether the read path needs any fault logic traced at all."""
        return (self.read_fail_rate > 0.0 or self.corrupt_rate > 0.0
                or self.spike_rate > 0.0)

    @property
    def attempts(self) -> int:
        """Total read attempts in the ladder: 1 + retries (+ hedge)."""
        return 1 + self.max_retries + (1 if self.hedge else 0)

    def to_json(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_json(cls, d: dict) -> "FaultPlan":
        return cls(**d)


def parse_plan(spec: str) -> FaultPlan:
    """Parse a CLI plan spec: comma-separated ``key=value`` pairs.

    ``rate=`` is shorthand for ``read_fail_rate=``; booleans accept
    0/1/true/false. Example: ``rate=0.1,seed=7,max_retries=2,hedge=1``.
    """
    kw: dict = {}
    for part in spec.split(","):
        part = part.strip()
        if not part:
            continue
        key, _, val = part.partition("=")
        key = key.strip()
        if key == "rate":
            key = "read_fail_rate"
        field = {f.name: f for f in dataclasses.fields(FaultPlan)}.get(key)
        if field is None:
            raise ValueError(f"unknown FaultPlan field {key!r}")
        if field.type == "bool" or isinstance(field.default, bool):
            kw[key] = val.strip().lower() in ("1", "true", "yes")
        elif isinstance(field.default, int):
            kw[key] = int(val)
        else:
            kw[key] = float(val)
    return FaultPlan(**kw)


# ---------------------------------------------------------------------------
# Stateless decision hash (jnp and np twins — bit-identical)
# ---------------------------------------------------------------------------

def _mix32(x):
    """splitmix32 finalizer — works on jnp and np uint32 alike."""
    x = (x ^ (x >> 16)) * np.uint32(_MIX_A)
    x = (x ^ (x >> 15)) * np.uint32(_MIX_B)
    return x ^ (x >> 16)


def _uniform(ids: jax.Array, hops: jax.Array, seed: int, stream: int,
             attempt: int) -> jax.Array:
    """Deterministic uniform [0, 1) per (id, hop, stream, attempt).

    Depends only on row-local values (record id + that query's own hop
    counter), never on batch position — the compaction driver may gather
    rows into any bucket and every draw is unchanged.
    """
    key = np.uint32((seed * _GOLDEN + stream * _MIX_A + attempt * _MIX_B)
                    & 0xFFFFFFFF)
    u = _mix32(ids.astype(jnp.uint32) ^ key)
    u = _mix32(u ^ (hops.astype(jnp.uint32) * np.uint32(_GOLDEN)))
    return u.astype(jnp.float32) * jnp.float32(2.0 ** -32)


def read_attempt_bad(ids: jax.Array, hops: jax.Array, attempt: int,
                     plan: FaultPlan) -> jax.Array:
    """True where read ``attempt`` of these rows fails OR comes back
    corrupted (a detected checksum mismatch re-enters the same ladder)."""
    bad = _uniform(ids, hops, plan.seed, _STREAM_FAIL,
                   attempt) < plan.read_fail_rate
    if plan.corrupt_rate > 0.0:
        bad = bad | (_uniform(ids, hops, plan.seed, _STREAM_CORRUPT,
                              attempt) < plan.corrupt_rate)
    return bad


def read_spike(ids: jax.Array, hops: jax.Array,
               plan: FaultPlan) -> jax.Array:
    """True where the (eventually successful) read hits a latency spike.
    Accounting only — spikes feed the modeled latency, never results."""
    return _uniform(ids, hops, plan.seed, _STREAM_SPIKE,
                    0) < plan.spike_rate


# ---------------------------------------------------------------------------
# NumPy twins (host read path — storage/disk.py)
#
# The real disk tier draws its faults on the host, outside any trace, but
# the degraded-row substitution happens on the device: both sides MUST see
# the same draws or a host-degraded row's zeros would be consumed. The
# twins replicate _uniform exactly — same uint32 wraparound, same
# float32 rounding, same float32 threshold compare — and are asserted
# bit-identical to the traced draws in tests/test_storage.py.
# ---------------------------------------------------------------------------

def _uniform_np(ids: np.ndarray, hops: np.ndarray, seed: int, stream: int,
                attempt: int) -> np.ndarray:
    key = np.uint32((seed * _GOLDEN + stream * _MIX_A + attempt * _MIX_B)
                    & 0xFFFFFFFF)
    with np.errstate(over="ignore"):    # uint32 wraparound is the point
        u = _mix32(np.asarray(ids).astype(np.uint32) ^ key)
        u = _mix32(u ^ (np.asarray(hops).astype(np.uint32)
                        * np.uint32(_GOLDEN)))
    return u.astype(np.float32) * np.float32(2.0 ** -32)


def read_fail_np(ids, hops, attempt: int, plan: FaultPlan) -> np.ndarray:
    return (_uniform_np(ids, hops, plan.seed, _STREAM_FAIL, attempt)
            < np.float32(plan.read_fail_rate))


def read_corrupt_np(ids, hops, attempt: int, plan: FaultPlan) -> np.ndarray:
    if plan.corrupt_rate <= 0.0:
        return np.zeros(np.asarray(ids).shape, bool)
    return (_uniform_np(ids, hops, plan.seed, _STREAM_CORRUPT, attempt)
            < np.float32(plan.corrupt_rate))


def read_attempt_bad_np(ids, hops, attempt: int,
                        plan: FaultPlan) -> np.ndarray:
    """NumPy twin of :func:`read_attempt_bad` (fail OR corrupt)."""
    return read_fail_np(ids, hops, attempt, plan) | read_corrupt_np(
        ids, hops, attempt, plan)


# ---------------------------------------------------------------------------
# Host-side injector (checkpoint writes)
# ---------------------------------------------------------------------------

class FaultInjector:
    """Host-side fault oracle for non-traced I/O (checkpoint leaf writes).

    Same stateless hash as the device draws, so a given plan corrupts the
    same (step, leaf) pairs on every run. Counters record what fired."""

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self.n_write_faults = 0

    def ckpt_write_fails(self, step: int, leaf_index: int,
                         attempt: int = 0) -> bool:
        p = self.plan.ckpt_fail_rate
        if p <= 0.0:
            return False
        key = np.uint32((self.plan.seed * _GOLDEN + _STREAM_CKPT * _MIX_A
                         + attempt * _MIX_B) & 0xFFFFFFFF)
        with np.errstate(over="ignore"):        # uint32 wraparound is the point
            u = _mix32(np.uint32(leaf_index & 0xFFFFFFFF) ^ key)
            u = _mix32(u ^ (np.uint32(step & 0xFFFFFFFF)
                            * np.uint32(_GOLDEN)))
        fails = float(u) * 2.0 ** -32 < p
        if fails:
            self.n_write_faults += 1
        return bool(fails)
