"""Selector abstraction (paper §4.1/§4.3): composable filtering rules.

A Selector has two halves:

* **host half** (planning, per query): estimates selectivity & precision,
  decides which on-SSD attribute indexes to touch (rare-label posting lists,
  range scans), accounts the pages read, and emits a ``QueryFilter`` — a flat
  pytree of per-query device arrays.
* **device half** (module-level pure functions): ``is_member_approx`` (probes
  only in-memory structures: Bloom words, bucket codes, the pre-merged rare
  list) and ``is_member`` (exact, reads the record's co-located attributes).
  Both are shape-static, vmap-able over a query batch, and usable inside
  ``lax.while_loop`` search kernels.

``is_member_approx`` guarantees no false negatives; built-ins follow the
paper's hybrid design (rare labels resolved exactly from fetched postings,
frequent labels via Bloom filters; ranges via 1-byte bucket codes).
User-defined constraints subclass ``Selector`` and emit their own masks.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bloom
from repro.core.io_sim import PAGE_BYTES
from repro.core.labels import LabelStore
from repro.core.ranges import MultiRangeStore, RangeStore

INT_PAD = np.iinfo(np.int32).max

# label_mode / merged_mode values
L_NONE, L_AND, L_OR = 0, 1, 2
M_NONE, M_OR, M_AND = 0, 1, 2
C_AND, C_OR = 0, 1

NR_DEFAULT = 4   # range-predicate slots per query (IndexConfig.qr)


class QueryFilter(NamedTuple):
    """Per-query device data for the built-in selector algebra.

    Shapes: QL = max query labels, CAP = merged-list cap, NR = range-predicate
    slots (all static per batch). All fields are stackable along a leading
    batch dimension. The range half is a fixed-width vector of
    ``(field, lo, hi)`` predicates — a conjunction over up to NR numeric
    fields — with ``range_field = -1`` marking empty slots.
    """
    # --- approximate (in-memory) half ---
    merged_ids: jax.Array     # (CAP,) int32, sorted, padded with INT_PAD
    merged_len: jax.Array     # ()  int32
    merged_mode: jax.Array    # ()  int32: M_NONE / M_OR / M_AND
    bloom_or_masks: jax.Array # (QL,) uint32 per-frequent-label masks (0 = pad)
    bloom_and_mask: jax.Array # ()  uint32 union mask of frequent labels (0 = none)
    bucket_lo: jax.Array      # (NR,) int32 (per-predicate range approx; 0..255)
    bucket_hi: jax.Array      # (NR,) int32
    # --- exact half (verification against record attributes) ---
    q_labels: jax.Array       # (QL,) int32, padded with -1
    label_mode: jax.Array     # ()  int32: L_NONE / L_AND / L_OR
    range_field: jax.Array    # (NR,) int32 numeric-field index, -1 = empty slot
    range_lo: jax.Array       # (NR,) float32
    range_hi: jax.Array       # (NR,) float32
    combine: jax.Array        # ()  int32: C_AND / C_OR over (label, range) parts


class InMemory(NamedTuple):
    """The replicated in-memory tier probed by is_member_approx."""
    blooms: jax.Array         # (N,) uint32
    bucket_codes: jax.Array   # (N, F) uint8/int32 — one code column per field


def _range_parts(qf: QueryFilter, codes_or_values, lo, hi):
    """Shared AND-of-slots range evaluation.

    codes_or_values: (..., F) gathered per-field data; lo/hi: (NR,) bounds
    in the same domain (bucket codes or float values). Returns
    (range_ok (...,), range_present ())."""
    active = qf.range_field >= 0                           # (NR,)
    safe_f = jnp.where(active, qf.range_field, 0)
    v = codes_or_values[..., safe_f]                       # (..., NR)
    ok = (v >= lo) & (v < hi) if v.dtype.kind == "f" else \
        (v >= lo) & (v <= hi)
    range_ok = jnp.all(ok | ~active, axis=-1)
    return range_ok, jnp.any(active)


def is_member_approx(qf: QueryFilter, ids: jax.Array, mem: InMemory) -> jax.Array:
    """No-false-negative superset predicate. ids: (...,) int32 -> bool (...,)."""
    g_bloom = mem.blooms[ids]
    in_merged = merged_membership(qf, ids)
    # frequent-label Bloom probes
    masks = qf.bloom_or_masks                              # (QL,)
    hit_any = jnp.any((masks[None, :] != 0)
                      & ((g_bloom[..., None] & masks[None, :]) == masks[None, :]),
                      axis=-1)
    has_or_masks = jnp.any(masks != 0)
    and_ok = (g_bloom & qf.bloom_and_mask) == qf.bloom_and_mask

    label_or = jnp.where(qf.merged_mode == M_OR, in_merged | hit_any,
                         jnp.where(has_or_masks, hit_any, False))
    label_and = jnp.where(qf.merged_mode == M_AND, in_merged & and_ok, and_ok)
    label_ok = jnp.where(qf.label_mode == L_AND, label_and,
                         jnp.where(qf.label_mode == L_OR, label_or, True))
    label_present = qf.label_mode != L_NONE

    bc = mem.bucket_codes
    if bc.ndim == 1:                                       # legacy (N,) tier
        bc = bc[:, None]
    codes = bc[ids].astype(jnp.int32)                      # (..., F)
    range_ok, range_present = _range_parts(qf, codes, qf.bucket_lo,
                                           qf.bucket_hi)

    ok_and = (label_ok | ~label_present) & (range_ok | ~range_present)
    ok_or = (label_ok & label_present) | (range_ok & range_present)
    any_present = label_present | range_present
    return jnp.where(any_present,
                     jnp.where(qf.combine == C_OR, ok_or, ok_and), True)


def is_member(qf: QueryFilter, rec_labels: jax.Array,
              rec_values: jax.Array) -> jax.Array:
    """Exact verification against record-resident attributes.

    rec_labels: (..., ML) int32 padded -1; rec_values: (..., F) float32
    (a flat (...,) array is accepted as the single-field F=1 case).
    """
    if rec_values.ndim == rec_labels.ndim - 1:             # legacy flat values
        rec_values = rec_values[..., None]
    ql = qf.q_labels                                       # (QL,)
    present = (rec_labels[..., None, :] == ql[:, None]) & (ql[:, None] >= 0)
    contains = jnp.any(present, axis=-1)                   # (..., QL)
    is_pad = ql < 0
    lab_and = jnp.all(contains | is_pad, axis=-1)
    lab_or = jnp.any(contains & ~is_pad, axis=-1)
    label_ok = jnp.where(qf.label_mode == L_AND, lab_and,
                         jnp.where(qf.label_mode == L_OR, lab_or, True))
    label_present = qf.label_mode != L_NONE

    range_ok, range_present = _range_parts(qf, rec_values, qf.range_lo,
                                           qf.range_hi)

    ok_and = (label_ok | ~label_present) & (range_ok | ~range_present)
    ok_or = (label_ok & label_present) | (range_ok & range_present)
    any_present = label_present | range_present
    return jnp.where(any_present,
                     jnp.where(qf.combine == C_OR, ok_or, ok_and), True)


def merged_membership(qf: QueryFilter, ids: jax.Array) -> jax.Array:
    """Rare-list membership of ``ids`` for ONE query (vmap for a batch).

    The binary-search half of :func:`is_member_approx`, split out so the
    fused hop kernel can consume it as a precomputed mask: searchsorted
    does not vectorize inside a Pallas tile, but it is cheap in XLA
    (O(c log CAP)) and the bloom/bucket half fuses on-chip.
    """
    pos = jnp.searchsorted(qf.merged_ids, ids)
    pos = jnp.clip(pos, 0, qf.merged_ids.shape[-1] - 1)
    return (jnp.take(qf.merged_ids, pos) == ids) & (pos < qf.merged_len)


def merged_table(qf: QueryFilter, n_ids: int) -> jax.Array:
    """Batched rare-list membership as a pre-scattered per-query table.

    Returns ``(B, n_ids+1)`` bool — row ``b`` true at the ids in
    ``qf.merged_ids[b]``; pad ids (INT_PAD) clip into the sentinel column
    ``n_ids``, which the hop loop never gathers (candidate ids are
    < n_ids). One scatter per search call replaces a (B, W·C)-wide binary
    search over the CAP-length merged list every hop. One BYTE per id per
    query (``jnp.bool_`` is byte-backed; jnp has no OR-scatter to pack
    words) — kept as the readable oracle for
    :func:`merged_table_words`, the word-packed form the search loop
    actually carries."""
    b = jnp.arange(qf.merged_ids.shape[0], dtype=jnp.int32)[:, None]
    return jnp.zeros((qf.merged_ids.shape[0], n_ids + 1), jnp.bool_).at[
        b, jnp.minimum(qf.merged_ids, n_ids)].set(True)


def merged_table_words(qf: QueryFilter, n_ids: int) -> jax.Array:
    """:func:`merged_table` packed 32 ids per int32 word.

    Returns ``(B, ceil((n_ids+1)/32))`` int32 — bit ``i`` of row ``b``
    set iff ``merged_table(qf, n_ids)[b, i]``. Pad ids clip into the
    sentinel bit ``n_ids`` exactly like the bool form (never gathered:
    candidate ids are < n_ids). Built with the OR-scatter kernel
    (kernels/or_scatter.py), so the replicated per-query rare-list state
    shrinks 8× before the sharded driver multiplies it per shard."""
    from repro.kernels import ops as kops
    n_words = (n_ids + 1 + 31) // 32
    return kops.or_scatter(
        jnp.zeros((qf.merged_ids.shape[0], n_words), jnp.int32),
        jnp.minimum(qf.merged_ids, n_ids))


def kernel_view(mem: InMemory) -> tuple[jax.Array, jax.Array]:
    """The in-memory tier in the fused-kernel layout.

    Returns ``(blooms_i32 (N,), bucket_codes_i32 (N, F))`` — bit-exact
    int32 views (Pallas TPU tiles have no uint32 lanes; bitwise ops on the
    reinterpreted words are identical). Hoist the conversion out of the
    hop loop: it is a one-time relayout per search call, not per hop.
    """
    bl = mem.blooms
    if bl.dtype == jnp.uint32:
        bl = jax.lax.bitcast_convert_type(bl, jnp.int32)
    else:
        bl = bl.astype(jnp.int32)
    bc = mem.bucket_codes
    if bc.ndim == 1:                                       # legacy (N,) tier
        bc = bc[:, None]
    return bl, bc.astype(jnp.int32)


def kernel_filter_params(qf: QueryFilter) -> tuple:
    """Flatten the approx half of a (possibly batched) QueryFilter into the
    fused hop kernel's parameter block:

    ``(scalars (..., 4) int32 [bloom_and_mask, label_mode, merged_mode,
    combine], or_masks (..., QL) int32, range_field (..., NR) int32,
    bucket_lo (..., NR) int32, bucket_hi (..., NR) int32)``.

    uint32 masks are reinterpreted (not value-converted) so bit 31
    survives.
    """
    def as_i32(x):
        x = jnp.asarray(x)
        if x.dtype == jnp.uint32:
            return jax.lax.bitcast_convert_type(x, jnp.int32)
        return x.astype(jnp.int32)

    scalars = jnp.stack(
        [as_i32(qf.bloom_and_mask), as_i32(qf.label_mode),
         as_i32(qf.merged_mode), as_i32(qf.combine)], axis=-1)
    return (scalars, as_i32(qf.bloom_or_masks), as_i32(qf.range_field),
            as_i32(qf.bucket_lo), as_i32(qf.bucket_hi))


def always_true_filter(ql: int, cap: int, nr: int = NR_DEFAULT) -> QueryFilter:
    """The post-filtering extreme: is_member_approx ≡ True (paper §3)."""
    return QueryFilter(
        merged_ids=np.full(cap, INT_PAD, np.int32), merged_len=np.int32(0),
        merged_mode=np.int32(M_NONE),
        bloom_or_masks=np.zeros(ql, np.uint32), bloom_and_mask=np.uint32(0),
        bucket_lo=np.zeros(nr, np.int32),
        bucket_hi=np.full(nr, 255, np.int32),
        q_labels=np.full(ql, -1, np.int32), label_mode=np.int32(L_NONE),
        range_field=np.full(nr, -1, np.int32),
        range_lo=np.full(nr, -np.inf, np.float32),
        range_hi=np.full(nr, np.inf, np.float32),
        combine=np.int32(C_AND))


def stack_filters(filters: Sequence[QueryFilter]) -> QueryFilter:
    """Stack per-query filters into a batched pytree (leading dim = batch).

    Stacks on the host: the batch width here is the raw group size, and
    eager device ops at that width would compile one tiny executable per
    distinct composition. The jitted search entry converts the (padded,
    power-of-two-width) tree in one transfer instead.
    """
    return jax.tree_util.tree_map(
        lambda *xs: np.stack([np.asarray(x) for x in xs]), *filters)


# ---------------------------------------------------------------------------
# Host-side planning
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Plan:
    """Result of Selector.plan(): device data + planning statistics."""
    qfilter: QueryFilter
    selectivity: float
    precision_in: float     # precision of is_member_approx during in-filtering
    precision_pre: float    # precision of the pre-filter superset
    pages_prefetch: int     # X_in: pages read before traversal (rare postings)
    pages_prescan: int      # X_pre: pages a speculative pre-filter scan reads
    force_mech: str | None = None   # bypass the cost model ('pre'|'in'|'post'):
                                    # required when the QueryFilter algebra
                                    # cannot express the constraint and only
                                    # one mechanism preserves correctness


class Selector:
    """Base class. Subclasses implement plan()/pre_filter_approx()."""

    def plan(self, ql: int, cap: int, nr: int = NR_DEFAULT) -> Plan:
        raise NotImplementedError

    def pre_filter_approx(self) -> tuple[np.ndarray, int]:
        """Batched superset scan: (superset vector ids, pages read)."""
        raise NotImplementedError

    def selectivity(self) -> float:
        raise NotImplementedError


def _fill_label_fields(base: QueryFilter, **kw) -> QueryFilter:
    return base._replace(**kw)


class LabelSelectorBase(Selector):
    def __init__(self, store: LabelStore, labels: Sequence[int],
                 rare_fetch_cap: int = 2048):
        self.store = store
        self.labels = [int(l) for l in labels]
        self.rare_fetch_cap = int(rare_fetch_cap)
        self._counts = np.array([store.label_counts[l] for l in self.labels],
                                dtype=np.int64)

    def _split_rare(self, cap: int):
        """Greedily mark labels rare (fetch their postings) within the cap."""
        order = np.argsort(self._counts, kind="stable")
        rare, freq, budget = [], [], min(cap, self.rare_fetch_cap)
        for i in order:
            c = int(self._counts[i])
            if c <= budget:
                rare.append(self.labels[i])
                budget -= c
            else:
                freq.append(self.labels[i])
        return rare, freq

    def _fetch_merged(self, rare, op: str):
        pages = 0
        merged = None
        for l in rare:
            post = self.store.postings(l)
            pages += self.store.posting_pages(l)
            if merged is None:
                merged = post
            elif op == "or":
                merged = np.union1d(merged, post)
            else:
                merged = np.intersect1d(merged, post, assume_unique=True)
        return (np.array([], np.int32) if merged is None else merged), pages

    def _bloom_fp1(self) -> float:
        return bloom.bloom_fp_rate(self.store.avg_labels_per_vec,
                                   self.store.k_hashes)


class LabelOrSelector(LabelSelectorBase):
    """Vector passes if it contains at least one query label."""

    def selectivity(self) -> float:
        s = 1.0
        for c in self._counts:
            s *= 1.0 - float(c) / max(1, self.store.n_vectors)
        return 1.0 - s

    def plan(self, ql: int, cap: int, nr: int = NR_DEFAULT) -> Plan:
        rare, freq = self._split_rare(cap)
        merged, pages = self._fetch_merged(rare, "or")
        merged = merged[:cap]
        qf = always_true_filter(ql, cap, nr)
        ids = np.full(cap, INT_PAD, np.int32)
        ids[:merged.size] = np.sort(merged)
        or_masks = np.zeros(ql, np.uint32)
        for j, l in enumerate(freq[:ql]):
            or_masks[j] = bloom.label_bits(l, self.store.k_hashes)
        q_labels = np.full(ql, -1, np.int32)
        q_labels[:min(len(self.labels), ql)] = self.labels[:ql]
        qf = qf._replace(
            merged_ids=ids, merged_len=np.int32(merged.size),
            merged_mode=np.int32(M_OR if rare else M_NONE),
            bloom_or_masks=or_masks,
            q_labels=q_labels, label_mode=np.int32(L_OR))

        s = self.selectivity()
        fp1 = self._bloom_fp1()
        # P(pass) ≈ P(in rare union) + P(not) * P(any frequent bloom hit)
        s_rare = 1.0 - np.prod([1.0 - self.store.selectivity(l) for l in rare]) \
            if rare else 0.0
        p_freq_hit = 1.0 - np.prod(
            [1.0 - (self.store.selectivity(l) + (1 - self.store.selectivity(l)) * fp1)
             for l in freq]) if freq else 0.0
        p_pass = s_rare + (1.0 - s_rare) * p_freq_hit
        prec = s / max(p_pass, 1e-12)
        return Plan(qf, s, min(1.0, prec), 1.0, pages, self._prescan_pages())

    def _prescan_pages(self) -> int:
        # OR pre-filtering must scan every label's postings.
        return sum(self.store.posting_pages(l) for l in self.labels)

    def pre_filter_approx(self) -> tuple[np.ndarray, int]:
        merged, pages = self._fetch_merged(self.labels, "or")
        return merged.astype(np.int32), pages


class LabelAndSelector(LabelSelectorBase):
    """Vector passes if it contains all query labels."""

    def selectivity(self) -> float:
        s = 1.0
        for c in self._counts:
            s *= float(c) / max(1, self.store.n_vectors)
        return s

    def plan(self, ql: int, cap: int, nr: int = NR_DEFAULT) -> Plan:
        rare, freq = self._split_rare(cap)
        merged, pages = self._fetch_merged(rare, "and")
        merged = merged[:cap]
        qf = always_true_filter(ql, cap, nr)
        ids = np.full(cap, INT_PAD, np.int32)
        ids[:merged.size] = np.sort(merged)
        and_mask = np.uint32(0)
        for l in freq:
            and_mask |= bloom.label_bits(l, self.store.k_hashes)
        q_labels = np.full(ql, -1, np.int32)
        q_labels[:min(len(self.labels), ql)] = self.labels[:ql]
        qf = qf._replace(
            merged_ids=ids, merged_len=np.int32(merged.size),
            merged_mode=np.int32(M_AND if rare else M_NONE),
            bloom_and_mask=and_mask,
            q_labels=q_labels, label_mode=np.int32(L_AND))

        s = self.selectivity()
        fp1 = self._bloom_fp1()
        p_pass = 1.0
        if rare:
            p_pass *= np.prod([self.store.selectivity(l) for l in rare])
        for l in freq:
            sl = self.store.selectivity(l)
            p_pass *= sl + (1.0 - sl) * fp1
        prec_in = s / max(p_pass, 1e-12)
        # speculative pre-filter scans only rare labels (paper: skip frequent)
        p_pre_pass = np.prod([self.store.selectivity(l) for l in rare]) if rare \
            else 1.0
        prec_pre = s / max(float(p_pre_pass), 1e-12)
        return Plan(qf, s, min(1.0, float(prec_in)), min(1.0, float(prec_pre)),
                    pages, self._prescan_pages())

    def _prescan_pages(self) -> int:
        rare, _ = self._split_rare(self.rare_fetch_cap)
        labels = rare if rare else [self.labels[int(np.argmin(self._counts))]]
        return sum(self.store.posting_pages(l) for l in labels)

    def pre_filter_approx(self) -> tuple[np.ndarray, int]:
        # paper §4.3.1: intersect rare labels only, defer frequent to verify
        rare, _ = self._split_rare(self.rare_fetch_cap)
        if not rare:
            rare = [self.labels[int(np.argmin(self._counts))]]
        merged, pages = self._fetch_merged(rare, "and")
        return merged.astype(np.int32), pages


class RangeSelector(Selector):
    """Vector passes if numeric field ``field`` falls in [lo, hi).

    ``store`` may be a :class:`MultiRangeStore` (``field`` picks the
    column) or a bare per-field :class:`RangeStore` (legacy single-field
    call sites; ``field`` is then the column the emitted predicate refers
    to inside the engine's value matrix, 0 by default).
    """

    def __init__(self, store, lo: float, hi: float, field: int = 0):
        self.store = store
        self.lo, self.hi = float(lo), float(hi)
        self.field = int(field)
        self._fs: RangeStore = store.field_store(self.field) \
            if isinstance(store, MultiRangeStore) else store

    def selectivity(self) -> float:
        return self._fs.selectivity(self.lo, self.hi)

    def plan(self, ql: int, cap: int, nr: int = NR_DEFAULT) -> Plan:
        qf = _fill_range_slots(always_true_filter(ql, cap, nr), [self])
        s = self.selectivity()
        prec = self._fs.precision(self.lo, self.hi)
        _, pages = self._fs.scan(self.lo, self.hi)
        return Plan(qf, s, prec, 1.0, 0, pages)

    def pre_filter_approx(self) -> tuple[np.ndarray, int]:
        ids, pages = self._fs.scan(self.lo, self.hi)
        return ids.astype(np.int32), pages


def _fill_range_slots(qf: QueryFilter, range_sels) -> QueryFilter:
    """Write a conjunction of range predicates into the NR filter slots."""
    nr = qf.range_field.shape[-1]
    if len(range_sels) > nr:
        raise ValueError(
            f"{len(range_sels)} range predicates exceed the filter's "
            f"{nr} slots (IndexConfig.qr)")
    field = np.full(nr, -1, np.int32)
    lo = np.full(nr, -np.inf, np.float32)
    hi = np.full(nr, np.inf, np.float32)
    blo = np.zeros(nr, np.int32)
    bhi = np.full(nr, 255, np.int32)
    for j, rs in enumerate(range_sels):
        field[j] = rs.field
        lo[j], hi[j] = np.float32(rs.lo), np.float32(rs.hi)
        blo[j], bhi[j] = rs._fs.bucket_range(rs.lo, rs.hi)
    return qf._replace(range_field=field, range_lo=lo, range_hi=hi,
                       bucket_lo=blo, bucket_hi=bhi)


class _Combinator(Selector):
    """Label × range composition shared by And/Or.

    AND accepts one optional label selector plus any number of range
    predicates (a multi-field conjunction — the schema-first query shape);
    OR keeps the two-way (one label + one range) form the approximate
    algebra can express.
    """

    _max_ranges: int | None = None
    _label_required = True

    def __init__(self, children: Sequence[Selector]):
        self.children = list(children)
        lab = [c for c in self.children if isinstance(c, LabelSelectorBase)]
        rng = [c for c in self.children if isinstance(c, RangeSelector)]
        assert len(lab) + len(rng) == len(self.children) and len(lab) <= 1, \
            "built-in combinators compose ≤1 label selector with range " \
            "selectors; fuse or subclass Selector for other trees"
        assert rng, "built-in combinators need ≥1 range selector"
        if self._label_required:
            assert len(lab) == 1, \
                f"{type(self).__name__} needs exactly one label selector"
        if self._max_ranges is not None:
            assert len(rng) <= self._max_ranges, \
                f"{type(self).__name__} takes ≤{self._max_ranges} ranges"
        self.label_sel = lab[0] if lab else None
        self.range_sels: list = rng

    @property
    def range_sel(self) -> RangeSelector:
        """First range child (legacy two-way accessor)."""
        return self.range_sels[0]

    def _merge_plans(self, ql, cap, nr, combine_code):
        if self.label_sel is not None:
            lp = self.label_sel.plan(ql, cap, nr)
        else:
            lp = Plan(always_true_filter(ql, cap, nr), 1.0, 1.0, 1.0, 0, 0)
        rps = [r.plan(ql, cap, nr) for r in self.range_sels]
        qf = _fill_range_slots(lp.qfilter, self.range_sels)
        qf = qf._replace(combine=np.int32(combine_code))
        return lp, rps, qf


class AndSelector(_Combinator):
    """AND of children; pre-filtering prunes the heavy branch (paper §4.3.3).

    Joint selectivity is the clamped product of per-child marginals
    (cost_model.joint_and_selectivity) — the independence estimate that
    keeps route choice and ``effective_l`` sane for multi-field filters.
    """

    _label_required = False

    def selectivity(self) -> float:
        from repro.core import cost_model
        margins = [c.selectivity() for c in self.children]
        return cost_model.joint_and_selectivity(margins)

    def plan(self, ql: int, cap: int, nr: int = NR_DEFAULT) -> Plan:
        lp, rps, qf = self._merge_plans(ql, cap, nr, C_AND)
        s = self.selectivity()
        p_pass = lp.selectivity / max(lp.precision_in, 1e-12)
        for rp in rps:
            p_pass *= rp.selectivity / max(rp.precision_in, 1e-12)
        prec_in = s / max(p_pass, 1e-12)
        # pre-filter: scan only the lowest-selectivity child
        cheap = min([lp] + rps, key=lambda p: p.selectivity) \
            if self.label_sel is not None else min(rps,
                                                   key=lambda p: p.selectivity)
        prec_pre = s / max(cheap.selectivity / max(cheap.precision_pre, 1e-12),
                           1e-12)
        return Plan(qf, s, min(1.0, prec_in), min(1.0, prec_pre),
                    lp.pages_prefetch, cheap.pages_prescan)

    def pre_filter_approx(self) -> tuple[np.ndarray, int]:
        cheap = min(self.children, key=lambda c: c.selectivity())
        return cheap.pre_filter_approx()


class MatchAllSelector(Selector):
    """No constraint: every record is valid (unfiltered top-k search)."""

    def __init__(self, n_vectors: int):
        self.n_vectors = int(n_vectors)

    def selectivity(self) -> float:
        return 1.0

    def plan(self, ql: int, cap: int, nr: int = NR_DEFAULT) -> Plan:
        pages = max(1, self.n_vectors * 4 // PAGE_BYTES)
        return Plan(always_true_filter(ql, cap, nr), 1.0, 1.0, 1.0, 0, pages)

    def pre_filter_approx(self) -> tuple[np.ndarray, int]:
        pages = max(1, self.n_vectors * 4 // PAGE_BYTES)
        return np.arange(self.n_vectors, dtype=np.int32), pages


class MaskSelector(Selector):
    """Exact-membership fallback for constraints the built-in QueryFilter
    algebra cannot express (arbitrary AND/OR trees, >QL label slots, range
    predicates over more fields than the NR slots, …).

    The valid-id set is computed exactly on the host (attribute-index
    scans, pages accounted by the caller) and the query is *forced* down
    the pre-filtering path: the candidate superset IS the exact valid set,
    so there are no false negatives (completeness) and no false positives
    (the always-true QueryFilter never rejects a candidate, but only valid
    ids ever enter the pool). In-/post-filtering would consult the vacuous
    device filter and return invalid results, hence ``force_mech='pre'``.
    """

    def __init__(self, valid_ids: np.ndarray, n_vectors: int, pages: int):
        self.valid_ids = np.asarray(valid_ids, np.int32)
        self.n_vectors = int(n_vectors)
        self.pages = int(pages)

    def selectivity(self) -> float:
        return self.valid_ids.size / max(1, self.n_vectors)

    def plan(self, ql: int, cap: int, nr: int = NR_DEFAULT) -> Plan:
        return Plan(always_true_filter(ql, cap, nr), self.selectivity(),
                    1.0, 1.0, 0, self.pages, force_mech="pre")

    def pre_filter_approx(self) -> tuple[np.ndarray, int]:
        return self.valid_ids, self.pages


class OrSelector(_Combinator):
    """OR of children; pre-filtering must evaluate every branch."""

    _max_ranges = 1
    _label_required = True

    def selectivity(self) -> float:
        sl = self.label_sel.selectivity()
        sr = self.range_sel.selectivity()
        return 1.0 - (1.0 - sl) * (1.0 - sr)

    def plan(self, ql: int, cap: int, nr: int = NR_DEFAULT) -> Plan:
        lp, rps, qf = self._merge_plans(ql, cap, nr, C_OR)
        rp = rps[0]
        s = self.selectivity()
        pl = lp.selectivity / max(lp.precision_in, 1e-12)
        pr = rp.selectivity / max(rp.precision_in, 1e-12)
        p_pass = 1.0 - (1.0 - pl) * (1.0 - pr)
        prec_in = s / max(p_pass, 1e-12)
        return Plan(qf, s, min(1.0, prec_in), 1.0,
                    lp.pages_prefetch, lp.pages_prescan + rp.pages_prescan)

    def pre_filter_approx(self) -> tuple[np.ndarray, int]:
        a, pa = self.label_sel.pre_filter_approx()
        b, pb = self.range_sel.pre_filter_approx()
        return np.union1d(a, b).astype(np.int32), pa + pb
