"""Vamana graph construction (paper §5.1) + ACORN-style 2-hop densification
(paper §4.1).

Two builders share the same batched on-device greedy search:

* ``build_vamana`` — the sequential numpy reference (robust pruning and
  reverse-edge insertion in Python loops). Kept as the correctness oracle.
* ``build_vamana_batched`` / ``IncrementalBuilder`` — the device-resident
  batched pipeline used by the engine.

DESIGN — batched prune/scatter formulation
------------------------------------------
The batched builder processes an insertion batch of B nodes per jitted step:

1. **Vectorized RobustPrune** (``robust_prune_batch``): each node's candidate
   set (search pool ∪ old out-edges, deduped and id-sorted like the numpy
   ``np.unique`` path) is stable-sorted by distance to the insert point; a
   masked domination scan (``kernels.ops.prune_scan`` — a fori_loop on CPU,
   a Pallas kernel on TPU) walks the sorted candidates keeping ≤ R survivors,
   where survivor i prunes every j with α²·d(i, j) ≤ d(p, j). Per node this
   is the *identical* keep sequence as the sequential reference (same stable
   tie-breaking, same α²-domination test); the only deviation is float
   associativity in the distance computations.
2. **Scatter reverse edges** (``_scatter_pairs``): the whole batch's
   (target, source) reverse edges are resolved at once — pairs are
   segment-sorted by target (stable in batch order), ranked within each
   target run, and the first ``free_slots(target)`` ranks are written with a
   single scatter into the rank-th free slot. Conflicts between sources of
   one target are therefore resolved in the same first-come order as the
   sequential loop.
3. **Overflow rows** re-enter the same batched prune: targets whose free
   slots are exhausted are pruned once over (old row ∪ pending sources)
   instead of once per incoming edge. This is the one *semantic* deviation
   from sequential Vamana — overflow sources are grouped per target rather
   than interleaved — and it is recall-neutral (the α²-domination objective
   is order-independent over the same candidate set; equivalence is enforced
   by test against the reference builder). Sources beyond the per-round cap
   are carried into another scatter/prune round, so no edge is dropped.

Within a batch all nodes see the adjacency snapshot from the batch start
(the reference updates it node by node); with two passes this stays within
the ±1% recall-parity budget the equivalence test enforces.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops


# ---------------------------------------------------------------------------
# Batched greedy (beam) search over an adjacency array — build-time navigator.
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("ell", "max_hops"))
def greedy_search(data, adj, entry: int, queries, ell: int, max_hops: int):
    """Best-first search with a size-`ell` pool; exact (full-precision) dists.

    data: (N, D) f32; adj: (N, R) i32 (-1 pad); queries: (B, D).
    ``adj`` may carry extra scratch rows beyond N (the batched builder's
    dump row) — they are never reachable because no stored edge points at
    them. Returns (pool_ids, pool_dists): (B, ell) each, sorted ascending.
    """
    r = adj.shape[1]

    def one(q):
        d0 = jnp.sum((data[entry] - q) ** 2)
        pool_ids = jnp.full((ell,), -1, jnp.int32).at[0].set(entry)
        pool_d = jnp.full((ell,), jnp.inf, jnp.float32).at[0].set(d0)
        explored = jnp.zeros((ell,), jnp.bool_)

        def cond(state):
            _, pool_d, explored, hops = state
            has_frontier = jnp.any(~explored & jnp.isfinite(pool_d))
            return has_frontier & (hops < max_hops)

        def body(state):
            pool_ids, pool_d, explored, hops = state
            # pick best unexplored
            masked = jnp.where(explored, jnp.inf, pool_d)
            i = jnp.argmin(masked)
            explored = explored.at[i].set(True)
            cur = pool_ids[i]
            nbrs = adj[cur]                                    # (R,)
            valid = nbrs >= 0
            nv = jnp.where(valid, nbrs, 0)
            nd = jnp.sum((data[nv] - q[None, :]) ** 2, axis=1)
            nd = jnp.where(valid, nd, jnp.inf)
            # dedup against pool
            dup = jnp.any(nbrs[:, None] == pool_ids[None, :], axis=1)
            nd = jnp.where(dup, jnp.inf, nd)
            # merge: keep ell best of pool ∪ neighbors
            all_ids = jnp.concatenate([pool_ids, nbrs])
            all_d = jnp.concatenate([pool_d, nd])
            all_exp = jnp.concatenate([explored, jnp.zeros((r,), jnp.bool_)])
            order = jnp.argsort(all_d)[:ell]
            return (all_ids[order], all_d[order], all_exp[order], hops + 1)

        pool_ids, pool_d, explored, _ = jax.lax.while_loop(
            cond, body, (pool_ids, pool_d, explored, jnp.int32(0)))
        return pool_ids, pool_d

    return jax.vmap(one)(queries)


def _beam_pool(adj, entry: int, ell: int, max_hops: int, width: int,
               dist_fn):
    """One query's beam-pool navigation over ``adj`` with a pluggable
    distance: ``dist_fn(ids (C,) int32) -> (C,) float32`` (ids are safe,
    i.e. already clamped non-negative; invalid lanes are masked to +inf by
    this navigator). Shared by :func:`greedy_search_beam` (exact
    full-precision distances) and the sharded builder's PQ-approximate
    navigation (core/distributed.py — ADC distances steer the pool, the
    RobustPrune re-rank stays exact). Returns (pool_ids, pool_d), each
    (ell,) ascending."""
    r = adj.shape[1]
    w = width
    d0 = dist_fn(jnp.full((1,), entry, jnp.int32))[0]
    pool_ids0 = jnp.full((ell,), -1, jnp.int32).at[0].set(entry)
    pool_d0 = jnp.full((ell,), jnp.inf, jnp.float32).at[0].set(d0)
    explored0 = jnp.zeros((ell,), jnp.bool_)

    def cond(state):
        _, pool_d, explored, hops = state
        has_frontier = jnp.any(~explored & jnp.isfinite(pool_d))
        return has_frontier & (hops < max_hops)

    def body(state):
        pool_ids, pool_d, explored, hops = state
        masked = jnp.where(explored, jnp.inf, pool_d)
        _, sel = jax.lax.top_k(-masked, w)
        cur_live = jnp.isfinite(masked[sel])
        explored = explored.at[sel].set(True)
        cur = jnp.where(cur_live, pool_ids[sel], 0)
        nbrs = adj[cur]                                  # (W, R)
        nbrs = jnp.where(cur_live[:, None], nbrs, -1).reshape(-1)
        valid = nbrs >= 0
        nv = jnp.where(valid, nbrs, 0)
        nd = dist_fn(nv)
        nd = jnp.where(valid, nd, jnp.inf)
        # dedup against pool and across the W beams' rows
        dup = jnp.any(nbrs[:, None] == pool_ids[None, :], axis=1)
        c = nbrs.shape[0]
        tri = jnp.tril(jnp.ones((c, c), jnp.bool_), -1)
        dup |= jnp.any((nbrs[:, None] == nbrs[None, :]) & tri, axis=1)
        nd = jnp.where(dup, jnp.inf, nd)
        all_ids = jnp.concatenate([pool_ids, nbrs])
        all_d = jnp.concatenate([pool_d, nd])
        all_exp = jnp.concatenate([explored, jnp.zeros((c,), jnp.bool_)])
        # top_k merge: ~4x cheaper than a full argsort on CPU/TPU
        neg_d, order = jax.lax.top_k(-all_d, ell)
        return (all_ids[order], -neg_d, all_exp[order], hops + 1)

    pool_ids, pool_d, _, _ = jax.lax.while_loop(
        cond, body, (pool_ids0, pool_d0, explored0, jnp.int32(0)))
    return pool_ids, pool_d


@functools.partial(jax.jit, static_argnames=("ell", "max_hops", "width"))
def greedy_search_beam(data, adj, entry: int, queries, ell: int,
                       max_hops: int, width: int = 4):
    """Beam variant of :func:`greedy_search`: explores the ``width`` best
    unexplored pool entries per iteration, so the sequential hop count drops
    ~width× while each step stays one coalesced gather. Used by the batched
    builder as its candidate generator (same pool semantics, coarser
    exploration order). Returns (pool_ids, pool_dists): (B, ell) ascending.
    """
    def one(q):
        return _beam_pool(
            adj, entry, ell, max_hops, width,
            lambda ids: jnp.sum((data[ids] - q[None, :]) ** 2, axis=1))

    return jax.vmap(one)(queries)


# ---------------------------------------------------------------------------
# Robust prune (numpy reference, squared distances -> alpha^2 domination)
# ---------------------------------------------------------------------------

def robust_prune(p_vec: np.ndarray, cand_ids: np.ndarray,
                 cand_vecs: np.ndarray, r: int, alpha: float) -> np.ndarray:
    """Vamana RobustPrune: keep ≤ r diverse candidates."""
    if cand_ids.size == 0:
        return cand_ids
    d_p = np.sum((cand_vecs - p_vec[None, :]) ** 2, axis=1)
    order = np.argsort(d_p, kind="stable")
    a2 = alpha * alpha
    pruned = np.zeros(cand_ids.size, dtype=bool)
    keep: list[int] = []
    for idx in order:
        if pruned[idx]:
            continue
        keep.append(idx)
        if len(keep) >= r:
            break
        d_kc = np.sum((cand_vecs - cand_vecs[idx][None, :]) ** 2, axis=1)
        pruned |= a2 * d_kc <= d_p
        pruned[idx] = True
    return cand_ids[np.array(keep, dtype=np.int64)]


def build_vamana(data: np.ndarray, r: int = 32, ell: int = 64,
                 alpha: float = 1.2, batch: int = 1024,
                 seed: int = 0) -> tuple[np.ndarray, int]:
    """Sequential reference build. Returns (adjacency (N, r) int32, medoid).

    Robust pruning and reverse-edge insertion run in numpy Python loops;
    use :func:`build_vamana_batched` for the fast device-resident path.
    """
    rng = np.random.default_rng(seed)
    data = np.asarray(data, dtype=np.float32)
    n = data.shape[0]
    medoid = int(np.argmin(np.sum((data - data.mean(0, keepdims=True)) ** 2, 1)))

    # random initial graph
    adj = rng.integers(0, n, size=(n, r), dtype=np.int64).astype(np.int32)
    adj[adj == np.arange(n, dtype=np.int32)[:, None]] = medoid

    data_dev = jnp.asarray(data)

    for alpha_pass in (1.0, alpha):
        order = rng.permutation(n)
        for start in range(0, n, batch):
            ids = order[start:start + batch]
            adj_dev = jnp.asarray(adj)
            pool_ids, _ = greedy_search(data_dev, adj_dev, medoid,
                                        data_dev[ids], ell, max_hops=ell)
            pool_ids = np.asarray(pool_ids)
            for k, p in enumerate(ids):
                cands = np.concatenate([pool_ids[k], adj[p]])
                cands = np.unique(cands[(cands >= 0) & (cands != p)])
                kept = robust_prune(data[p], cands, data[cands], r, alpha_pass)
                row = np.full(r, -1, np.int32)
                row[:kept.size] = kept
                adj[p] = row
                # reverse edges
                for q in kept:
                    qrow = adj[q]
                    if p in qrow:
                        continue
                    slot = np.where(qrow < 0)[0]
                    if slot.size:
                        adj[q, slot[0]] = p
                    else:
                        rc = np.unique(np.concatenate([qrow, [p]]))
                        rc = rc[(rc >= 0) & (rc != q)]
                        kept_q = robust_prune(data[q], rc, data[rc], r, alpha_pass)
                        qnew = np.full(r, -1, np.int32)
                        qnew[:kept_q.size] = kept_q
                        adj[q] = qnew
    return adj, medoid


# ---------------------------------------------------------------------------
# Batched device-resident build (see DESIGN note in the module docstring)
# ---------------------------------------------------------------------------

_INT_MAX = np.iinfo(np.int32).max


def _dedup_ascending(cands: jax.Array, self_ids: jax.Array) -> jax.Array:
    """Row-wise unique ascending ids; drops negatives and the row's own id.

    cands (B, C) int32 -> (B, C) int32 with valid ids ascending and -1
    right-padding — the device analogue of the reference's ``np.unique``.
    """
    big = jnp.int32(_INT_MAX)
    x = jnp.where((cands < 0) | (cands == self_ids[:, None]), big, cands)
    x = jnp.sort(x, axis=1)
    dup = jnp.concatenate(
        [jnp.zeros_like(x[:, :1], jnp.bool_), x[:, 1:] == x[:, :-1]], axis=1)
    x = jnp.sort(jnp.where(dup, big, x), axis=1)
    return jnp.where(x == big, -1, x)


@functools.partial(jax.jit, static_argnames=("r", "alpha"))
def robust_prune_batch(data: jax.Array, p_ids: jax.Array, cand_ids: jax.Array,
                       r: int, alpha: float) -> jax.Array:
    """Vectorized RobustPrune for a whole insertion batch.

    data (N, D); p_ids (B,) int32; cand_ids (B, C) int32 — unique ascending
    with -1 right-padding, self id excluded (see ``_dedup_ascending``).
    Returns (B, r) int32 rows, survivors in keep (distance) order, -1 pad —
    matching the sequential reference's output row layout.
    """
    a2 = float(alpha) * float(alpha)
    b, c = cand_ids.shape
    valid = cand_ids >= 0
    cv = data[jnp.where(valid, cand_ids, 0)]                 # (B, C, D)
    pv = data[p_ids]                                         # (B, D)
    d_p = jnp.sum((cv - pv[:, None, :]) ** 2, axis=-1)
    d_p = jnp.where(valid, d_p, jnp.inf)
    order = jnp.argsort(d_p, axis=1)                         # stable
    dp_s = jnp.take_along_axis(d_p, order, axis=1)
    ids_s = jnp.take_along_axis(cand_ids, order, axis=1)
    cv_s = jnp.take_along_axis(cv, order[:, :, None], axis=1)
    # pairwise candidate distances in sorted space (norm expansion)
    sq = jnp.sum(cv_s * cv_s, axis=-1)                       # (B, C)
    dcc = sq[:, :, None] + sq[:, None, :] \
        - 2.0 * jnp.einsum("bcd,bed->bce", cv_s, cv_s)
    dcc = jnp.maximum(dcc, 0.0)
    keep_s = ops.prune_scan(dp_s, dcc, a2, r)                # (B, C) bool
    rank = jnp.cumsum(keep_s.astype(jnp.int32), axis=1) - 1
    rows = jnp.full((b, r), -1, jnp.int32)
    rows = rows.at[jnp.arange(b)[:, None],
                   jnp.where(keep_s, rank, r)].set(
        jnp.where(keep_s, ids_s, -1), mode="drop")
    return rows


@jax.jit
def _scatter_pairs(adj_ext: jax.Array, tgt: jax.Array, src: jax.Array):
    """Batched reverse-edge insertion: one scatter for all (tgt, src) pairs.

    adj_ext: (N+1, R) int32 — row N is an all(-1) dump row for masked
    writes (the invariant "dump row stays -1" is preserved by every caller).
    Pairs are segment-sorted by target (stable in pair order) and ranked;
    rank k lands in the target's k-th free slot. Returns
    (adj_ext, sorted_tgt, sorted_src, overflow_mask) where overflow pairs
    are valid pairs whose target had no free slot left.
    """
    n1, r = adj_ext.shape
    dump = n1 - 1
    p = tgt.shape[0]
    valid = (tgt >= 0) & (src >= 0) & (tgt != src)
    safe_t = jnp.where(valid, tgt, dump)
    # skip pairs whose edge already exists
    valid &= ~jnp.any(adj_ext[safe_t] == src[:, None], axis=1)
    pos = jnp.arange(p)
    # stable sort by target keeps pairs of one target in batch order;
    # invalid pairs sort to the dump-row run at the end (ids < dump)
    order = jnp.argsort(jnp.where(valid, safe_t, dump))
    st, ss, sv = safe_t[order], src[order], valid[order]
    # rank within each target run (runs are contiguous after the sort)
    is_first = jnp.concatenate([jnp.ones((1,), jnp.bool_), st[1:] != st[:-1]])
    seg_start = jax.lax.associative_scan(
        jnp.maximum, jnp.where(is_first, pos, -1))
    rank = pos - seg_start
    rowq = adj_ext[st]                                       # (P, R)
    free = rowq < 0
    n_free = jnp.sum(free, axis=1)
    colpos = jnp.broadcast_to(jnp.arange(r)[None, :], rowq.shape)
    slot_order = jnp.argsort(jnp.where(free, colpos, r + colpos), axis=1)
    slot = jnp.take_along_axis(
        slot_order, jnp.minimum(rank, r - 1)[:, None], axis=1)[:, 0]
    do = sv & (rank < n_free)
    adj_ext = adj_ext.at[jnp.where(do, st, dump),
                         jnp.where(do, slot, 0)].set(jnp.where(do, ss, -1))
    overflow = sv & (rank >= n_free)
    return adj_ext, st, ss, overflow


@functools.partial(jax.jit, donate_argnums=(0,))
def write_rows(buf: jax.Array, rows: jax.Array, start) -> jax.Array:
    """Donated in-place row write: ``buf[start:start+len(rows)] = rows``.

    The capacity-padded insert path funnels every device-array row write
    through this jitted helper so XLA reuses the input buffer
    (``donate_argnums``) instead of materializing the O(capacity)
    functional-update copy a bare ``.at[...].set`` outside jit pays.
    ``start`` is traced (dynamic), so steady-state inserts of one batch
    shape compile exactly once. The donated input is DELETED — callers
    must own ``buf`` exclusively and rebind the result.
    """
    idx = (jnp.asarray(start, jnp.int32),) + (0,) * (buf.ndim - 1)
    return jax.lax.dynamic_update_slice(buf, rows, idx)


@functools.partial(jax.jit, static_argnames=("r", "alpha"),
                   donate_argnums=(1,))
def _link_batch(data: jax.Array, adj_ext: jax.Array, ids: jax.Array,
                live: jax.Array, pool_ids: jax.Array, r: int, alpha: float):
    """Prune an insertion batch's rows and scatter their reverse edges.
    ``adj_ext`` is donated: the row set + reverse scatter reuse its buffer
    (callers rebind the returned array)."""
    dump = adj_ext.shape[0] - 1
    cand = jnp.concatenate([pool_ids, adj_ext[ids]], axis=1)
    cand = _dedup_ascending(cand, ids)
    rows = robust_prune_batch(data, ids, cand, r=r, alpha=alpha)
    rows = jnp.where(live[:, None], rows, -1)
    adj_ext = adj_ext.at[jnp.where(live, ids, dump)].set(rows)
    tgt = rows.reshape(-1)
    src = jnp.repeat(ids, r)
    return _scatter_pairs(adj_ext, tgt, src)


def _pow2_pad(m: int, lo: int = 32) -> int:
    return max(lo, 1 << (max(m, 1) - 1).bit_length())


def _pad_batch(ids: np.ndarray, width: int) -> tuple[np.ndarray, np.ndarray]:
    """Right-pad an insertion-id batch to ``width``, repeating the last id;
    the returned live mask marks pads dead so ``_link_batch`` routes their
    rows and reverse edges to the dump row."""
    live = np.ones(width, bool)
    if ids.size < width:
        live[ids.size:] = False
        ids = np.concatenate(
            [ids, np.full(width - ids.size, ids[-1], np.int32)])
    return ids.astype(np.int32), live


def _prune_rows(data_dev, adj_ext, targets: np.ndarray, srcs: np.ndarray,
                r: int, alpha: float, chunk: int = 4096):
    """Re-prune overflowing rows over (old row ∪ pending sources)."""
    dump = adj_ext.shape[0] - 1
    for s in range(0, targets.shape[0], chunk):
        t = targets[s:s + chunk]
        sc = srcs[s:s + chunk]
        pad = _pow2_pad(t.shape[0]) - t.shape[0]
        if pad:
            # padded targets resolve to the dump row: their candidate set is
            # empty, so the prune writes an all(-1) row back into it,
            # preserving the dump invariant.
            t = np.concatenate([t, np.full(pad, dump, t.dtype)])
            sc = np.concatenate(
                [sc, np.full((pad, sc.shape[1]), -1, sc.dtype)])
        t_dev = jnp.asarray(t)
        cand = jnp.concatenate([adj_ext[t_dev], jnp.asarray(sc)], axis=1)
        cand = _dedup_ascending(cand, t_dev)
        rows = robust_prune_batch(data_dev, t_dev, cand, r=r, alpha=alpha)
        adj_ext = adj_ext.at[t_dev].set(rows)
    return adj_ext


def _group_overflow(st, ss, overflow, ov_cap: int):
    """Host-side: group overflow pairs by target (already target-sorted).

    Returns (targets (T,), srcs (T, ov_cap) -1-padded, leftover (tgt, src))
    where leftover holds each target's sources beyond ``ov_cap`` for the
    next scatter/prune round.
    """
    ov = np.asarray(overflow)
    t = np.asarray(st)[ov]
    s = np.asarray(ss)[ov]
    if t.size == 0:
        return None
    uniq, start, cnt = np.unique(t, return_index=True, return_counts=True)
    gidx = np.repeat(np.arange(uniq.size), cnt)
    posg = np.arange(t.size) - np.repeat(start, cnt)
    take = posg < ov_cap
    srcs = np.full((uniq.size, ov_cap), -1, np.int32)
    srcs[gidx[take], posg[take]] = s[take]
    return uniq.astype(np.int32), srcs, (t[~take], s[~take])


@functools.partial(jax.jit, donate_argnums=(0,))
def apply_pruned_rows(adj_ext: jax.Array, ids: jax.Array, live: jax.Array,
                      rows: jax.Array):
    """Row set + reverse-edge scatter for externally pruned rows — the
    replicated host half of the sharded build's link step
    (core/distributed.py): navigation + RobustPrune run per shard under
    shard_map and the all-gathered (B, R) rows land here. Identical to
    the back half of :func:`_link_batch` (which fuses the prune in)."""
    dump = adj_ext.shape[0] - 1
    rows = jnp.where(live[:, None], rows, -1)
    adj_ext = adj_ext.at[jnp.where(live, ids, dump)].set(rows)
    tgt = rows.reshape(-1)
    src = jnp.repeat(ids, rows.shape[1])
    return _scatter_pairs(adj_ext, tgt, src)


def _drain_overflow(data_dev, adj_ext, st, ss, overflow, n_rows: int,
                    r: int, alpha: float):
    """Drain a batch's pending reverse-edge overflow rounds."""
    # small per-round source cap: overflow counts are heavy-tailed (most
    # targets receive a handful of pending edges), so a narrow candidate
    # width r+8 keeps the O(C²·D) prune cheap; rare hot targets just take
    # extra rounds, each consuming another 8 sources
    ov_cap = 8
    # every round consumes ≥ ov_cap pending sources per remaining target
    # (or scatters them into freed slots), so ceil(B/ov_cap) rounds is a
    # hard upper bound — a target receives at most one edge per batch node.
    # Exceeding it means a logic bug: fail loudly, never drop edges.
    max_rounds = -(-n_rows // ov_cap) + 2
    for _ in range(max_rounds):
        grouped = _group_overflow(st, ss, overflow, ov_cap=ov_cap)
        if grouped is None:
            break
        targets, srcs, (lt, ls) = grouped
        adj_ext = _prune_rows(data_dev, adj_ext, targets, srcs, r, alpha)
        if lt.size == 0:
            break
        pad = _pow2_pad(lt.size) - lt.size
        tgt = np.concatenate([lt, np.full(pad, -1, lt.dtype)]).astype(np.int32)
        src = np.concatenate([ls, np.full(pad, -1, ls.dtype)]).astype(np.int32)
        adj_ext, st, ss, overflow = _scatter_pairs(
            adj_ext, jnp.asarray(tgt), jnp.asarray(src))
    else:
        raise RuntimeError(
            "reverse-edge overflow failed to drain within the round bound; "
            "this indicates a bug in the scatter/overflow bookkeeping")
    return adj_ext


def _apply_batch(data_dev, adj_ext, ids: np.ndarray, live: np.ndarray,
                 pool_ids, r: int, alpha: float):
    """One insertion batch: prune + row set + reverse scatter + overflow."""
    adj_ext, st, ss, overflow = _link_batch(
        data_dev, adj_ext, jnp.asarray(ids), jnp.asarray(live), pool_ids,
        r=r, alpha=alpha)
    return _drain_overflow(data_dev, adj_ext, st, ss, overflow,
                           ids.shape[0], r, alpha)


def build_vamana_batched(data: np.ndarray, r: int = 32, ell: int = 64,
                         alpha: float = 1.2, batch: int = 1024,
                         seed: int = 0) -> tuple[np.ndarray, int]:
    """Device-resident batched Vamana build (same signature/RNG stream as
    the reference). Returns (adjacency (N, r) int32 padded -1, medoid)."""
    rng = np.random.default_rng(seed)
    data = np.asarray(data, dtype=np.float32)
    n = data.shape[0]
    medoid = int(np.argmin(np.sum((data - data.mean(0, keepdims=True)) ** 2, 1)))

    adj0 = rng.integers(0, n, size=(n, r), dtype=np.int64).astype(np.int32)
    adj0[adj0 == np.arange(n, dtype=np.int32)[:, None]] = medoid

    data_dev = jnp.asarray(data)
    adj_ext = jnp.concatenate(
        [jnp.asarray(adj0), jnp.full((1, r), -1, jnp.int32)])
    batch = min(batch, _pow2_pad(n))

    for pass_i, alpha_pass in enumerate((1.0, alpha)):
        # the α=1 bootstrap pass only seeds the final α-pass with a usable
        # graph; a ⅔-width pool there cuts ~40% of navigation time with no
        # measurable recall cost (the equivalence test gates the result)
        pell = ell if pass_i else max(16, (2 * ell) // 3)
        order = rng.permutation(n)
        for start in range(0, n, batch):
            ids, live = _pad_batch(order[start:start + batch].astype(
                np.int32), batch)
            pool_ids, _ = greedy_search_beam(data_dev, adj_ext, medoid,
                                             data_dev[jnp.asarray(ids)],
                                             pell, max_hops=pell)
            adj_ext = _apply_batch(data_dev, adj_ext, ids, live, pool_ids,
                                   r=r, alpha=float(alpha_pass))
    return np.asarray(adj_ext[:-1]), medoid


class IncrementalBuilder:
    """Appends batches of new nodes to a live Vamana graph on device.

    Wraps (data, adjacency, medoid) with geometric capacity growth so the
    jitted search/prune/scatter steps recompile only on capacity changes,
    not on every insert. ``add_batch`` links each new node with a single
    final-α pass (greedy search from the medoid → batched RobustPrune →
    batched reverse-edge scatter) — the streaming-insert half of the
    batched pipeline. Unreached capacity rows hold zero vectors and empty (-1)
    adjacency — no stored edge ever points at them, so searches cannot
    reach them.
    """

    def __init__(self, data: np.ndarray, adj: np.ndarray, medoid: int,
                 ell: int = 64, alpha: float = 1.2, batch: int = 1024):
        data = np.asarray(data, np.float32)
        adj = np.asarray(adj, np.int32)
        assert data.shape[0] == adj.shape[0]
        self.n = data.shape[0]
        self.r = adj.shape[1]
        self.ell = ell
        self.alpha = float(alpha)
        self.batch = batch
        self.medoid = int(medoid)
        self._cap = self.n
        self._data_host = data
        self._data_dev = jnp.asarray(data)
        self._adj_ext = jnp.concatenate(
            [jnp.asarray(adj), jnp.full((1, self.r), -1, jnp.int32)])

    @classmethod
    def build(cls, data: np.ndarray, r: int = 32, ell: int = 64,
              alpha: float = 1.2, batch: int = 1024,
              seed: int = 0) -> "IncrementalBuilder":
        adj, medoid = build_vamana_batched(data, r, ell, alpha, batch, seed)
        return cls(data, adj, medoid, ell=ell, alpha=alpha, batch=batch)

    # -- state ----------------------------------------------------------
    @property
    def adjacency(self) -> np.ndarray:
        return np.asarray(self._adj_ext[:self.n])

    @property
    def data(self) -> np.ndarray:
        return self._data_host[:self.n]

    @property
    def capacity(self) -> int:
        """Allocated rows; grows geometrically, ≥ n."""
        return self._cap

    @property
    def data_device(self) -> "jax.Array":
        """(capacity, D) device vectors — rows ≥ n are zero pads."""
        return self._data_dev

    @property
    def adjacency_device(self) -> "jax.Array":
        """(capacity, R) device adjacency — rows ≥ n are -1 pads.

        Shared with the engine's capacity-padded record store so
        steady-state inserts keep one stable array shape (no per-insert
        jit re-specialization downstream)."""
        return self._adj_ext[:self._cap]

    def _grow(self, need: int):
        cap = self._cap
        while cap < need:
            cap = max(cap + self.batch, int(cap * 1.5))
        if cap == self._cap:
            return
        d = self._data_host.shape[1]
        data = np.zeros((cap, d), np.float32)
        data[:self.n] = self._data_host[:self.n]
        self._data_host = data
        self._data_dev = jnp.asarray(data)
        body = self._adj_ext[:self.n]
        pad = jnp.full((cap + 1 - self.n, self.r), -1, jnp.int32)
        self._adj_ext = jnp.concatenate([body, pad])
        self._cap = cap

    # -- streaming insert ----------------------------------------------
    def add_batch(self, vectors: np.ndarray) -> np.ndarray:
        """Insert new vectors; returns their assigned ids (contiguous)."""
        vectors = np.asarray(vectors, np.float32)
        if vectors.ndim != 2 or vectors.shape[1] != self._data_host.shape[1]:
            raise ValueError(
                f"expected (M, {self._data_host.shape[1]}) vectors, got "
                f"{vectors.shape}")
        m = vectors.shape[0]
        if m == 0:
            return np.zeros(0, np.int64)
        self._grow(self.n + m)
        new_ids = np.arange(self.n, self.n + m, dtype=np.int64)
        self._data_host[self.n:self.n + m] = vectors
        self._data_dev = write_rows(self._data_dev, jnp.asarray(vectors),
                                    self.n)
        for s in range(0, m, self.batch):
            ids = new_ids[s:s + self.batch].astype(np.int32)
            ids, live = _pad_batch(
                ids, min(_pow2_pad(ids.size, lo=8), self.batch))
            pool_ids, _ = greedy_search_beam(
                self._data_dev, self._adj_ext, self.medoid,
                self._data_dev[jnp.asarray(ids)], self.ell,
                max_hops=self.ell)
            self._adj_ext = _apply_batch(
                self._data_dev, self._adj_ext, ids, live, pool_ids,
                r=self.r, alpha=self.alpha)
        self.n += m
        return new_ids


# ---------------------------------------------------------------------------
# 2-hop densification + stats
# ---------------------------------------------------------------------------

def densify_2hop(adj: np.ndarray, r_dense: int, seed: int = 0) -> np.ndarray:
    """Random 2-hop sample per node (paper §4.1: ~10–20× direct degree).

    Vectorized: pick random (first-hop, second-hop) slot pairs; duplicates and
    occasional self-references are tolerated (search dedups), matching the
    paper's random-subset semantics.
    """
    rng = np.random.default_rng(seed)
    n, r = adj.shape
    i1 = rng.integers(0, r, size=(n, r_dense))
    i2 = rng.integers(0, r, size=(n, r_dense))
    hop1 = np.take_along_axis(adj, i1, axis=1)               # (N, R_d)
    hop1_safe = np.where(hop1 >= 0, hop1, 0)
    hop2 = adj[hop1_safe, i2]                                # (N, R_d)
    hop2 = np.where(hop1 >= 0, hop2, -1)
    hop2 = np.where(hop2 == np.arange(n)[:, None], -1, hop2)
    return hop2.astype(np.int32)


def graph_stats(adj: np.ndarray) -> dict:
    valid = adj >= 0
    deg = valid.sum(1)
    return {"avg_degree": float(deg.mean()), "min_degree": int(deg.min()),
            "max_degree": int(deg.max())}


def greedy_recall_at_k(data: np.ndarray, adj: np.ndarray, medoid: int,
                       queries: np.ndarray, ell: int = 64, k: int = 10,
                       max_hops: int = 200) -> float:
    """Unfiltered recall@k of greedy search over a graph vs exact top-k —
    the graph-quality metric shared by the build benchmark and the
    builder-equivalence tests."""
    ids, _ = greedy_search(jnp.asarray(data), jnp.asarray(adj), medoid,
                           jnp.asarray(queries), ell=ell, max_hops=max_hops)
    ids = np.asarray(ids)
    recalls = []
    for i, q in enumerate(queries):
        exact = np.argsort(np.sum((data - q[None]) ** 2, axis=1))[:k]
        got = set(ids[i, :k].tolist())
        recalls.append(len(got & set(exact.tolist())) / k)
    return float(np.mean(recalls))
