"""Vamana graph construction (unmodified algorithm, paper §5.1) + ACORN-style
2-hop densification (paper §4.1).

Build is an offline path: a JAX batched greedy search drives candidate
generation on-device; robust pruning and reverse-edge insertion run in numpy.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# Batched greedy (beam) search over an adjacency array — build-time navigator.
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("ell", "max_hops"))
def greedy_search(data, adj, entry: int, queries, ell: int, max_hops: int):
    """Best-first search with a size-`ell` pool; exact (full-precision) dists.

    data: (N, D) f32; adj: (N, R) i32 (-1 pad); queries: (B, D).
    Returns (pool_ids, pool_dists): (B, ell) each, sorted ascending by dist.
    """
    r = adj.shape[1]

    def one(q):
        d0 = jnp.sum((data[entry] - q) ** 2)
        pool_ids = jnp.full((ell,), -1, jnp.int32).at[0].set(entry)
        pool_d = jnp.full((ell,), jnp.inf, jnp.float32).at[0].set(d0)
        explored = jnp.zeros((ell,), jnp.bool_)

        def cond(state):
            _, pool_d, explored, hops = state
            has_frontier = jnp.any(~explored & jnp.isfinite(pool_d))
            return has_frontier & (hops < max_hops)

        def body(state):
            pool_ids, pool_d, explored, hops = state
            # pick best unexplored
            masked = jnp.where(explored, jnp.inf, pool_d)
            i = jnp.argmin(masked)
            explored = explored.at[i].set(True)
            cur = pool_ids[i]
            nbrs = adj[cur]                                    # (R,)
            valid = nbrs >= 0
            nv = jnp.where(valid, nbrs, 0)
            nd = jnp.sum((data[nv] - q[None, :]) ** 2, axis=1)
            nd = jnp.where(valid, nd, jnp.inf)
            # dedup against pool
            dup = jnp.any(nbrs[:, None] == pool_ids[None, :], axis=1)
            nd = jnp.where(dup, jnp.inf, nd)
            # merge: keep ell best of pool ∪ neighbors
            all_ids = jnp.concatenate([pool_ids, nbrs])
            all_d = jnp.concatenate([pool_d, nd])
            all_exp = jnp.concatenate([explored, jnp.zeros((r,), jnp.bool_)])
            order = jnp.argsort(all_d)[:ell]
            return (all_ids[order], all_d[order], all_exp[order], hops + 1)

        pool_ids, pool_d, explored, _ = jax.lax.while_loop(
            cond, body, (pool_ids, pool_d, explored, jnp.int32(0)))
        return pool_ids, pool_d

    return jax.vmap(one)(queries)


# ---------------------------------------------------------------------------
# Robust prune (numpy, squared distances -> alpha^2 domination test)
# ---------------------------------------------------------------------------

def robust_prune(p_vec: np.ndarray, cand_ids: np.ndarray,
                 cand_vecs: np.ndarray, r: int, alpha: float) -> np.ndarray:
    """Vamana RobustPrune: keep ≤ r diverse candidates."""
    if cand_ids.size == 0:
        return cand_ids
    d_p = np.sum((cand_vecs - p_vec[None, :]) ** 2, axis=1)
    order = np.argsort(d_p, kind="stable")
    a2 = alpha * alpha
    pruned = np.zeros(cand_ids.size, dtype=bool)
    keep: list[int] = []
    for idx in order:
        if pruned[idx]:
            continue
        keep.append(idx)
        if len(keep) >= r:
            break
        d_kc = np.sum((cand_vecs - cand_vecs[idx][None, :]) ** 2, axis=1)
        pruned |= a2 * d_kc <= d_p
        pruned[idx] = True
    return cand_ids[np.array(keep, dtype=np.int64)]


def build_vamana(data: np.ndarray, r: int = 32, ell: int = 64,
                 alpha: float = 1.2, batch: int = 1024,
                 seed: int = 0) -> tuple[np.ndarray, int]:
    """Build a Vamana graph. Returns (adjacency (N, r) int32 padded -1, medoid)."""
    rng = np.random.default_rng(seed)
    data = np.asarray(data, dtype=np.float32)
    n = data.shape[0]
    medoid = int(np.argmin(np.sum((data - data.mean(0, keepdims=True)) ** 2, 1)))

    # random initial graph
    adj = rng.integers(0, n, size=(n, r), dtype=np.int64).astype(np.int32)
    adj[adj == np.arange(n, dtype=np.int32)[:, None]] = medoid

    data_dev = jnp.asarray(data)

    for alpha_pass in (1.0, alpha):
        order = rng.permutation(n)
        for start in range(0, n, batch):
            ids = order[start:start + batch]
            adj_dev = jnp.asarray(adj)
            pool_ids, _ = greedy_search(data_dev, adj_dev, medoid,
                                        data_dev[ids], ell, max_hops=ell)
            pool_ids = np.asarray(pool_ids)
            for k, p in enumerate(ids):
                cands = np.concatenate([pool_ids[k], adj[p]])
                cands = np.unique(cands[(cands >= 0) & (cands != p)])
                kept = robust_prune(data[p], cands, data[cands], r, alpha_pass)
                row = np.full(r, -1, np.int32)
                row[:kept.size] = kept
                adj[p] = row
                # reverse edges
                for q in kept:
                    qrow = adj[q]
                    if p in qrow:
                        continue
                    slot = np.where(qrow < 0)[0]
                    if slot.size:
                        adj[q, slot[0]] = p
                    else:
                        rc = np.unique(np.concatenate([qrow, [p]]))
                        rc = rc[(rc >= 0) & (rc != q)]
                        kept_q = robust_prune(data[q], rc, data[rc], r, alpha_pass)
                        qnew = np.full(r, -1, np.int32)
                        qnew[:kept_q.size] = kept_q
                        adj[q] = qnew
    return adj, medoid


def densify_2hop(adj: np.ndarray, r_dense: int, seed: int = 0) -> np.ndarray:
    """Random 2-hop sample per node (paper §4.1: ~10–20× direct degree).

    Vectorized: pick random (first-hop, second-hop) slot pairs; duplicates and
    occasional self-references are tolerated (search dedups), matching the
    paper's random-subset semantics.
    """
    rng = np.random.default_rng(seed)
    n, r = adj.shape
    i1 = rng.integers(0, r, size=(n, r_dense))
    i2 = rng.integers(0, r, size=(n, r_dense))
    hop1 = np.take_along_axis(adj, i1, axis=1)               # (N, R_d)
    hop1_safe = np.where(hop1 >= 0, hop1, 0)
    hop2 = adj[hop1_safe, i2]                                # (N, R_d)
    hop2 = np.where(hop1 >= 0, hop2, -1)
    hop2 = np.where(hop2 == np.arange(n)[:, None], -1, hop2)
    return hop2.astype(np.int32)


def graph_stats(adj: np.ndarray) -> dict:
    valid = adj >= 0
    deg = valid.sum(1)
    return {"avg_degree": float(deg.mean()), "min_degree": int(deg.min()),
            "max_degree": int(deg.max())}
