# The paper's primary contribution: speculative filtering for on-SSD
# filtered ANNS, expressed as a JAX system (see DESIGN.md).
from repro.core.engine import (FilteredANNEngine, IndexConfig, SearchConfig,
                               brute_force_filtered, recall_at_k)
from repro.core.selectors import (AndSelector, InMemory, LabelAndSelector,
                                  LabelOrSelector, OrSelector, QueryFilter,
                                  RangeSelector, Selector, is_member,
                                  is_member_approx)
