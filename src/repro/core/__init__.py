# The paper's primary contribution: speculative filtering for on-SSD
# filtered ANNS, expressed as a JAX system (see DESIGN.md).
from repro.core.engine import (FilteredANNEngine, IndexConfig, QueryStats,
                               SearchConfig, brute_force_filtered,
                               recall_at_k)
from repro.core.selectors import (AndSelector, InMemory, LabelAndSelector,
                                  LabelOrSelector, MaskSelector,
                                  MatchAllSelector, OrSelector, QueryFilter,
                                  RangeSelector, Selector, is_member,
                                  is_member_approx)
