"""Serving entry points: prefill + decode step builders, generation loop."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import lm
from repro.models.common import ModelConfig


def make_prefill(cfg: ModelConfig, max_t: int):
    @jax.jit
    def prefill(params, batch):
        return lm.lm_prefill(params, cfg, batch, max_t)
    return prefill


def make_decode_step(cfg: ModelConfig):
    @jax.jit
    def step(params, caches, tokens):
        return lm.lm_decode_step(params, caches, cfg, tokens)
    return step


def sample_token(logits, key, temperature: float = 0.0):
    """logits: (B, 1, V). Greedy when temperature == 0."""
    if temperature <= 0.0:
        return jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
    scaled = logits[:, -1].astype(jnp.float32) / temperature
    return jax.random.categorical(key, scaled, axis=-1) \
        .astype(jnp.int32)[:, None]


def generate(params, cfg: ModelConfig, prompt_tokens, n_new: int,
             temperature: float = 0.0, seed: int = 0,
             max_t: Optional[int] = None):
    """Batched generation: prefill the prompt, decode n_new tokens."""
    b, s = prompt_tokens.shape
    max_t = max_t or (s + n_new + 8)
    prefill = make_prefill(cfg, max_t)
    step = make_decode_step(cfg)
    logits, caches = prefill(params, {"tokens": prompt_tokens})
    key = jax.random.PRNGKey(seed)
    out = []
    tok = sample_token(logits, key, temperature)
    out.append(tok)
    for i in range(n_new - 1):
        key, sub = jax.random.split(key)
        logits, caches = step(params, caches, tok)
        tok = sample_token(logits, sub, temperature)
        out.append(tok)
    return jnp.concatenate(out, axis=1)
