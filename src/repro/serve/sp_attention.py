"""Sequence-parallel (split-K / flash-decoding) decode attention.

For decode shapes the KV cache dominates memory (e.g. qwen2-7b decode_32k:
~240 GB of KV) and must shard its *sequence* dimension over the `model`
mesh axis. A single softmax over a sharded axis is expressed explicitly:
each shard computes a partial (max, sum-exp, weighted-V) over its KV slice,
then a psum-based logsumexp merge combines them — 2 small collectives of
O(B·Hq·Dh) instead of XLA's default all-gather of the O(B·T) score row.

Used inside shard_map (launch/shardings.py builds the specs); the cache
update (one token) lands on the owning shard only.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def sp_decode_attention_local(q, k_shard, v_shard, pos, n_kv: int,
                              axis_name: str):
    """Body to run inside shard_map, sharded over `axis_name` on the KV
    sequence dim.

    q: (B, 1, Hq, Dh) replicated over the axis.
    k_shard/v_shard: (B, T_shard, Hkv, Dh) — this shard's KV slice.
    pos: () int32 — current absolute position (k/v already updated).
    Returns (B, 1, Hq, Dh), replicated (psum-combined).
    """
    b, _, hq, dh = q.shape
    t_shard = k_shard.shape[1]
    g = hq // n_kv
    idx = jax.lax.axis_index(axis_name)
    kpos = idx * t_shard + jnp.arange(t_shard)
    valid = kpos <= pos                                     # (T_shard,)

    qg = q.reshape(b, 1, n_kv, g, dh)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg, k_shard) / jnp.sqrt(dh)
    scores = scores.astype(jnp.float32) + jnp.where(valid, 0.0, NEG_INF)[
        None, None, None, None, :]
    m_loc = scores.max(axis=-1)                             # (B,Hkv,G,1)
    p = jnp.exp(scores - m_loc[..., None])
    s_loc = p.sum(axis=-1)
    o_loc = jnp.einsum("bkgst,btkd->bskgd", p.astype(q.dtype), v_shard) \
        .astype(jnp.float32)                                # (B,1,Hkv,G,Dh)

    m_glob = jax.lax.pmax(m_loc, axis_name)
    alpha = jnp.exp(m_loc - m_glob)                         # (B,Hkv,G,1)
    s_glob = jax.lax.psum(alpha * s_loc, axis_name)
    o_glob = jax.lax.psum(o_loc * alpha.transpose(0, 3, 1, 2)[..., None],
                          axis_name)
    out = o_glob / jnp.maximum(s_glob, 1e-30).transpose(0, 3, 1, 2)[..., None]
    return out.reshape(b, 1, hq, dh).astype(q.dtype)


def sp_cache_update(k_cache, v_cache, k_new, v_new, pos, axis_name: str):
    """Write the new token's K/V into the owning shard's slice.

    k_cache: (B, T_shard, Hkv, Dh) local shard; k_new: (B, 1, Hkv, Dh)
    replicated. Non-owners write nothing (masked update)."""
    t_shard = k_cache.shape[1]
    idx = jax.lax.axis_index(axis_name)
    owner = pos // t_shard
    local_slot = pos - owner * t_shard
    is_mine = owner == idx
    slot = jnp.where(is_mine, local_slot, 0)
    upd_k = jax.lax.dynamic_update_slice(k_cache, k_new, (0, slot, 0, 0))
    upd_v = jax.lax.dynamic_update_slice(v_cache, v_new, (0, slot, 0, 0))
    k_out = jnp.where(is_mine, upd_k, k_cache)
    v_out = jnp.where(is_mine, upd_v, v_cache)
    return k_out, v_out


def reference_decode_attention(q, k, v, pos, n_kv: int):
    """Single-device oracle for the split-K path (tests)."""
    b, _, hq, dh = q.shape
    t = k.shape[1]
    g = hq // n_kv
    valid = jnp.arange(t) <= pos
    qg = q.reshape(b, 1, n_kv, g, dh)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg, k) / jnp.sqrt(dh)
    scores = scores.astype(jnp.float32) + jnp.where(valid, 0.0, NEG_INF)[
        None, None, None, None, :]
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v)
    return out.reshape(b, 1, hq, dh)
