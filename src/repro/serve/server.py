"""Resilient serving tier: deadline-aware admission, backpressure,
graceful load degradation, and overload shedding over the batched engine.

The ``Session`` batches requests; ``SearchServer`` turns that into a
*service*: flushes run on a dedicated worker thread (callers never block
on device work they didn't ask for), admission is deadline- and
SLO-aware, and overload walks the load-degrade ladder before anything is
dropped — the load-fault analogue of the PR 7 I/O fault ladder.

Admission pipeline (``submit``):

1. **Backpressure** — the queue is bounded (``max_queue``); a full queue
   rejects with :class:`~repro.api.types.Overloaded`, carrying a
   ``retry_after_s`` hint equal to the predicted backlog drain time.
2. **Deadline feasibility** — a request with ``deadline_us`` is priced by
   the PR 5 cost model (``engine.estimate_cost`` per the compiled
   filter's plan) and its completion predicted as queue-wait + service
   under an *affine* service model fitted on measured flushes:
   ``wall ≈ overhead_us + us_per_cost × batch_cost``. The fixed per-flush
   overhead term matters — dispatch dominates small flushes, so a single
   µs-per-cost ratio learned from small batches overprices large ones
   (and vice versa), which under-batches the worker at low load. If even
   the cheapest ladder rung cannot make the deadline, the request is
   shed at admission with :class:`~repro.api.types.DeadlineExceeded`.
3. **Enqueue** — otherwise the request joins the queue and its handle is
   returned immediately (``PendingSearch.result(timeout=...)`` waits).

The worker cuts batches on the p99 *budget*, not just size: entries are
taken while the predicted batch service time fits both ``slo_p99_us``
and the tightest queued deadline's headroom. Queue pressure (and
deadline infeasibility at the current rung) selects the degrade rung —
``cost_model.DEGRADE_LADDER``: full → lean (drop read-ahead, results
invariant) → reduced/minimal (scaled L and hop budget, still exactly
verified) → scan (gated full-corpus ADC + exact verify; approximate
candidate generation, never a false negative). Expired entries are shed
(their handles fail with ``DeadlineExceeded``); everything admitted to a
batch resolves through the session's poisoned-batch isolation.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
import time
from typing import Optional, Sequence

import numpy as np

from repro.api.session import PendingSearch, Session, SessionConfig
from repro.api.types import (DeadlineExceeded, Overloaded, SearchRequest,
                             ServeError)
from repro.core import cost_model
from repro.core.engine import apply_rung, scan_rerank


def _now_us() -> float:
    return time.monotonic() * 1e6


def _is_degraded(rung: cost_model.DegradeRung) -> bool:
    """True when the rung alters service at all (any config delta or the
    approximate path) — ladder *position* is irrelevant, so custom
    ladders count correctly."""
    return (rung.approx or rung.l_scale != 1.0
            or rung.max_hops_scale != 1.0
            or rung.hop_chunk is not None
            or rung.prefetch_depth is not None)


@dataclasses.dataclass(frozen=True)
class ServerConfig:
    max_queue: int = 256         # bounded admission queue (backpressure)
    max_batch: int = 32          # batch-size cut (upper bound)
    max_delay_s: float = 0.002   # batching window for a non-full batch
    slo_p99_us: float = 500_000.0
    # p99 service budget: the worker stops growing a batch when its
    # predicted service time would exceed this (or a queued deadline)
    degrade_at: tuple = (0.25, 0.45, 0.65, 0.85)
    # queue-fill fractions stepping the degrade rung: below the first
    # the server runs full service, past the last it serves rung 4
    seed_us_per_cost: float = 1.0
    # µs per cost-model unit before the first measured flush
    fit_window: int = 64         # (batch_cost, wall) pairs the affine
                                 # service model is refitted over
    tail_quantile: float = 0.9   # quantile of observed (actual − predicted)
    # flush-wall error added to deadline-facing predictions: the mean
    # model admits requests that a p90-slow flush pushes past their
    # deadline, so SLO comparisons carry an additive tail guard. The
    # guard is additive, not multiplicative — flush jitter here is
    # dispatch noise that doesn't scale with batch cost, and a ratio
    # learned on small overhead-dominated flushes would overpenalize
    # large predictions and over-shed at moderate load
    window: int = 512            # rolling completion-latency window
    isolate_failures: bool = True
    flush_retry_budget: int = 8


@dataclasses.dataclass
class ServerStats:
    """Health/readiness probe snapshot (all counters cumulative)."""
    queue_depth: int
    in_flight: int
    degrade_rung: int            # ladder index the last batch ran at
    rung_name: str
    p50_us: float                # rolling completion latency (admitted)
    p99_us: float
    admitted: int
    completed: int
    rejected_overload: int       # backpressured at admission
    shed_deadline: int           # shed at admission or expired in queue
    deadline_misses: int         # completed, but past their deadline
    degraded_served: int         # completed at any service-altering rung
    us_per_cost: float           # fitted marginal cost→µs scale (slope)
    overhead_us: float           # fitted fixed per-flush wall (intercept)
    tail_guard_us: float         # p-tail prediction-error margin added
                                 # to deadline-facing predictions
    healthy: bool                # worker thread alive
    ready: bool                  # healthy ∧ accepting (not stopping)
    warmed: bool                 # warmup() has run
    shards: int = 1              # mesh shards the hop loop spans
                                 # (engine.n_shards; 1 = single-device)


@dataclasses.dataclass
class _Entry:
    handle: PendingSearch
    admit_us: float
    deadline_abs_us: Optional[float]     # absolute µs (monotonic clock)
    ci: cost_model.CostInputs
    scfg: object                         # resolved base SearchConfig
    cost_full: float                     # rung-0 modeled cost
    cost_cheapest: Optional[float] = None   # min over the ladder (only
    # priced for deadline-carrying requests; drives predictive shedding)


class SearchServer:
    """Threaded serving frontend over an :class:`~repro.api.index.Index`.

    Sharded indexes (``Index.build(shards=…)``) serve through the same
    path with zero server-side changes: the engine routes each flushed
    bucket's hop loop through its mesh runner, and :meth:`warmup` covers
    the sharded bucket-jit ladder because the runner's kernels sit behind
    the exact same (params, width) cache keys. ``stats().shards`` reports
    the mesh width."""

    def __init__(self, index, config: ServerConfig = ServerConfig(),
                 ladder: tuple = cost_model.DEGRADE_LADDER):
        self.index = index
        self.config = config
        self.ladder = ladder
        self.session = Session(index, SessionConfig(
            auto_flush=False,
            isolate_failures=config.isolate_failures,
            flush_retry_budget=config.flush_retry_budget))
        self._lock = threading.Lock()
        self._work = threading.Condition(self._lock)
        self._queue: collections.deque = collections.deque()
        self._queued_cost = 0.0
        self._inflight_cost = 0.0
        self._in_flight = 0
        self._rung_idx = 0
        self._us_per_cost = float(config.seed_us_per_cost)
        self._overhead_us = 0.0
        self._obs: collections.deque = collections.deque(
            maxlen=config.fit_window)
        self._err: collections.deque = collections.deque(
            maxlen=config.fit_window)
        self._tail_guard_us = 0.0   # grows as prediction errors accumulate
        self._lat_window: collections.deque = collections.deque(
            maxlen=config.window)
        self._admitted = 0
        self._completed = 0
        self._rejected = 0
        self._shed = 0
        self._misses = 0
        self._degraded = 0
        self._warmed = False
        self._stop = False
        self._worker = threading.Thread(target=self._run, daemon=True,
                                        name="search-server-worker")
        self._worker.start()

    # -- lifecycle -------------------------------------------------------
    def warmup(self, requests: Sequence[SearchRequest], **kw) -> None:
        """Pre-compile the bucket-jit ladder and the degrade-rung config
        variants (``Session.warmup``) so first-request compile stalls
        don't masquerade as deadline misses."""
        self.session.warmup(requests, **kw)
        with self._lock:
            self._warmed = True

    def calibrate_service_model(self, requests: Sequence[SearchRequest]):
        """Seed the affine service model with two measured flushes — a
        single query and a full batch — run directly through the engine
        (bypassing admission). Two observations at well-separated batch
        costs pin both terms, so the very first admitted request is
        priced by measurement instead of ``seed_us_per_cost``; without
        this, a cold server under-batches (and over-sheds) until enough
        live flushes accumulate to fit the model. Returns the fitted
        ``(overhead_us, us_per_cost)``."""
        reqs = list(requests)[: max(2, self.config.max_batch)]
        if len(reqs) < 2:
            raise ValueError("need at least 2 requests to calibrate")
        costs = [self._price(r)[1] for r in reqs]
        self.index.search_batch(reqs, with_metadata=False)      # warm
        pairs = []
        for sub in (reqs[:1], reqs):
            t0 = _now_us()
            self.index.search_batch(sub, with_metadata=False)
            pairs.append((float(sum(costs[: len(sub)])), _now_us() - t0))
        with self._lock:
            for p in pairs:
                self._refit_locked(*p)
            return self._overhead_us, self._us_per_cost

    def stop(self, timeout: float = 30.0) -> None:
        """Stop accepting, drain the queue, join the worker."""
        with self._work:
            self._stop = True
            self._work.notify_all()
        self._worker.join(timeout)

    close = stop

    def __enter__(self) -> "SearchServer":
        return self

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- admission -------------------------------------------------------
    def _price(self, request: SearchRequest):
        sel = self.index.compile_filter(request.filter)
        scfg = self.index._resolve_scfg(request)
        eng = self.index.engine
        cfg = eng.config
        plan = sel.plan(cfg.ql, cfg.cap, cfg.qr)
        ci = eng.cost_inputs(plan, scfg)
        route = eng._route(plan, scfg)
        full = route.costs[route.mechanism].total(scfg.alpha, scfg.beta)
        return ci, full, scfg

    def _rung_cost(self, e: _Entry, rung: cost_model.DegradeRung) -> float:
        sc = e.scfg
        return cost_model.rung_cost(
            e.ci, rung, sc.alpha, sc.beta, sc.max_pool,
            base_prefetch=sc.prefetch_depth,
            rerank=scan_rerank(sc, rung),
            calib=self.index.engine.calibration)

    def _predict_us(self, cost: float, flushes: int = 1) -> float:
        """Predicted wall µs to serve ``cost`` model units spread over
        ``flushes`` flushes: fixed per-flush overhead + marginal cost.
        The two-term shape is what keeps the scheduler sane at both ends
        of the load curve — cutting a batch smaller does *not* make its
        flush finish much sooner."""
        return flushes * self._overhead_us + cost * self._us_per_cost

    def _predict_tail_us(self, cost: float, flushes: int = 1) -> float:
        """Tail-guarded prediction for deadline/SLO comparisons: the
        mean model is right on average but a p90-slow flush pushes a
        just-fits request past its deadline, so anything compared against
        a deadline carries the observed tail error margin on top."""
        return self._predict_us(cost, flushes) + self._tail_guard_us

    def _backlog_us_locked(self) -> float:
        flushes = (1 if self._in_flight else 0) + int(
            -(-len(self._queue) // max(1, self.config.max_batch)))
        return self._predict_us(
            self._queued_cost + self._inflight_cost, flushes)

    def _refit_locked(self, batch_cost: float, wall_us: float) -> None:
        """Refit the affine service model on the observation window.
        With degenerate cost spread (every batch the same size) the
        slope/intercept split is unidentifiable, so fall back to the
        amortized ratio with zero overhead — conservative, and correct
        at exactly the operating point being observed."""
        pred = self._predict_us(batch_cost)
        if len(self._obs) >= 2 and pred > 0.0:
            # error vs the model that actually priced this flush (the
            # pre-refit fit); skipped while only the config seed is live
            self._err.append(wall_us - pred)
            if len(self._err) >= 4:
                self._tail_guard_us = max(0.0, float(np.quantile(
                    np.fromiter(self._err, np.float64),
                    self.config.tail_quantile)))
        self._obs.append((batch_cost, wall_us))
        x = np.fromiter((o[0] for o in self._obs), np.float64)
        y = np.fromiter((o[1] for o in self._obs), np.float64)
        slope = None
        if x.size >= 2 and float(np.ptp(x)) > 0.05 * float(x.mean()):
            slope, intercept = np.polyfit(x, y, 1)
        if slope is None or slope <= 0.0:
            self._us_per_cost = float(y.sum() / max(float(x.sum()), 1e-9))
            self._overhead_us = 0.0
        else:
            self._us_per_cost = float(slope)
            self._overhead_us = float(max(0.0, intercept))

    def submit(self, request: SearchRequest) -> PendingSearch:
        """Admit one request; returns its handle or raises
        ``Overloaded`` / ``DeadlineExceeded`` (shed at admission)."""
        ci, full, scfg = self._price(request)       # host-side, lock-free
        handle = PendingSearch(self.session, request)
        # the server owns scheduling: mark the handle claimed so
        # result() waits on the worker instead of forcing a session flush
        handle._claimed = True
        handle.rung = None
        now = _now_us()
        with self._work:
            if self._stop:
                raise ServeError("server is stopped")
            if len(self._queue) >= self.config.max_queue:
                self._rejected += 1
                raise Overloaded(
                    f"admission queue full "
                    f"({len(self._queue)}/{self.config.max_queue})",
                    retry_after_s=self._backlog_us_locked() / 1e6)
            entry = _Entry(handle, now, None, ci, scfg, full)
            if request.deadline_us is not None:
                entry.deadline_abs_us = now + float(request.deadline_us)
                entry.cost_cheapest = min(self._rung_cost(entry, r)
                                          for r in self.ladder)
                predicted = self._backlog_us_locked() \
                    + self._predict_tail_us(entry.cost_cheapest)
                if predicted > float(request.deadline_us):
                    self._shed += 1
                    raise DeadlineExceeded(
                        f"predicted completion {predicted:.0f}µs exceeds "
                        f"deadline {request.deadline_us:.0f}µs even at "
                        f"the cheapest degrade rung")
            self._queue.append(entry)
            self._queued_cost += full
            self._admitted += 1
            self._work.notify()
        return handle

    def submit_many(self, requests: Sequence[SearchRequest]) -> list:
        return [self.submit(r) for r in requests]

    # -- scheduling ------------------------------------------------------
    def _pick_rung_locked(self, now: float) -> int:
        """Queue pressure *permits* rungs 0..i (``degrade_at``
        thresholds); the batch executes at the cheapest permitted rung
        for the head-of-queue request, so the effective service cost is
        monotone non-increasing in pressure even where a raw rung cost
        inverts. A queued deadline that cannot hold at that choice
        escalates the permission (degradation before shedding)."""
        pressure = len(self._queue) / max(1, self.config.max_queue)
        permit = min(sum(pressure >= f for f in self.config.degrade_at),
                     len(self.ladder) - 1)
        head = self._queue[0]
        tight = None              # (headroom_us, entry) of tightest deadline
        for e in self._queue:
            if e.deadline_abs_us is not None:
                room = e.deadline_abs_us - now
                if tight is None or room < tight[0]:
                    tight = (room, e)

        def pick(limit: int) -> int:
            costs = [self._rung_cost(head, self.ladder[j])
                     for j in range(limit + 1)]
            return min(range(limit + 1), key=costs.__getitem__)

        idx = pick(permit)
        while tight is not None and permit < len(self.ladder) - 1:
            c = self._rung_cost(tight[1], self.ladder[idx])
            if self._predict_tail_us(c) <= tight[0]:
                break
            permit += 1
            idx = pick(permit)
        return idx

    def _cut_batch_locked(self, now: float):
        """Pop a batch: expired or provably-late entries shed, the rest
        taken while the predicted batch service time fits the
        p99/deadline budget. Shedding a doomed entry instead of letting
        it through matters twice over — it would waste service, and its
        collapsed headroom would strangle the batch budget for healthy
        batchmates."""
        rung_idx = self._pick_rung_locked(now)
        rung = self.ladder[rung_idx]
        batch: list = []
        batch_cost = 0.0
        budget = self.config.slo_p99_us
        shed: list = []
        while self._queue and len(batch) < self.config.max_batch:
            e = self._queue[0]
            c = self._rung_cost(e, rung)
            if e.deadline_abs_us is not None:
                room = e.deadline_abs_us - now
                # doomed: expired, or misses even riding this batch at
                # its ladder-cheapest cost (FIFO — waiting only worsens)
                late = self._predict_tail_us(
                    batch_cost + min(c, e.cost_cheapest))
                if room <= 0 or late > room:
                    self._queue.popleft()
                    self._queued_cost -= e.cost_full
                    shed.append(e)
                    continue
                head = min(budget, room)
            else:
                head = budget
            if batch and self._predict_tail_us(batch_cost + c) > head:
                break          # p99-budget cut, not size
            budget = head
            self._queue.popleft()
            self._queued_cost -= e.cost_full
            batch.append(e)
            batch_cost += c
        self._rung_idx = rung_idx
        self._in_flight = len(batch)
        self._inflight_cost = batch_cost
        return batch, batch_cost, rung_idx, shed

    def _run(self) -> None:
        cfg = self.config
        while True:
            with self._work:
                while not self._stop and not self._queue:
                    self._work.wait(0.1)
                if not self._queue:
                    if self._stop:
                        return
                    continue
                # batching window: give the batch a chance to fill
                while (not self._stop
                       and len(self._queue) < cfg.max_batch):
                    age_s = (_now_us() - self._queue[0].admit_us) / 1e6
                    if age_s >= cfg.max_delay_s:
                        break
                    self._work.wait(cfg.max_delay_s - age_s)
                batch, batch_cost, rung_idx, shed = \
                    self._cut_batch_locked(_now_us())
            for e in shed:
                e.handle._fail(DeadlineExceeded(
                    "deadline expired while queued"))
            with self._lock:
                self._shed += len(shed)
            if not batch:
                continue
            self._execute(batch, batch_cost, rung_idx)

    def _execute(self, batch: list, batch_cost: float,
                 rung_idx: int) -> None:
        cfg = self.config
        rung = self.ladder[rung_idx]
        scfgs = [apply_rung(self.index._resolve_scfg(e.handle.request),
                            rung) for e in batch]
        if rung.approx:
            def executor(reqs, cfgs):
                return self.index.approx_scan_batch(reqs, scfgs=cfgs)
        else:
            def executor(reqs, cfgs):
                return self.index.search_batch(reqs, scfgs=cfgs)
        # stamp the rung before execution: a result() waiter wakes the
        # instant its handle resolves and must see which rung served it
        for e in batch:
            e.handle.rung = rung.name
        t0 = _now_us()
        budget = [max(1, cfg.flush_retry_budget)]
        try:
            self.session._execute_isolated(
                [e.handle for e in batch], budget, scfgs, executor)
        finally:
            for e in batch:
                if not e.handle._done:
                    e.handle._fail(RuntimeError(
                        "serve batch aborted before resolving this "
                        "handle"))
        done = _now_us()
        with self._lock:
            self._refit_locked(batch_cost, done - t0)
            degraded = _is_degraded(rung)
            for e in batch:
                self._lat_window.append(done - e.admit_us)
                self._completed += 1
                if degraded:
                    self._degraded += 1
                if (e.deadline_abs_us is not None
                        and done > e.deadline_abs_us):
                    self._misses += 1
            self._in_flight = 0
            self._inflight_cost = 0.0

    # -- observability ---------------------------------------------------
    def stats(self) -> ServerStats:
        with self._lock:
            lat = np.asarray(self._lat_window, np.float64)
            alive = self._worker.is_alive()
            return ServerStats(
                queue_depth=len(self._queue),
                in_flight=self._in_flight,
                degrade_rung=self._rung_idx,
                rung_name=self.ladder[self._rung_idx].name,
                p50_us=float(np.percentile(lat, 50)) if lat.size else 0.0,
                p99_us=float(np.percentile(lat, 99)) if lat.size else 0.0,
                admitted=self._admitted,
                completed=self._completed,
                rejected_overload=self._rejected,
                shed_deadline=self._shed,
                deadline_misses=self._misses,
                degraded_served=self._degraded,
                us_per_cost=self._us_per_cost,
                overhead_us=self._overhead_us,
                tail_guard_us=self._tail_guard_us,
                healthy=alive,
                ready=alive and not self._stop,
                warmed=self._warmed,
                shards=getattr(self.index.engine, "n_shards", 1))
