"""Filtered-retrieval frontend for the serve path.

Wires the ``repro.api`` Session scheduler into retrieve-then-generate
serving: callers submit (embedding, filter) requests one at a time as
they arrive; the session batches them across callers and flushes by
batch-size/deadline, so concurrent requests share one grouped engine
call (the serving analogue of the paper's query batching, §4).
"""
from __future__ import annotations

from typing import Optional, Sequence

import numpy as np

from repro.api.session import PendingSearch, Session, SessionConfig
from repro.api.types import SearchRequest, SearchResult


class RetrievalFrontend:
    """Batched filtered retrieval for serving loops.

    Filters are ``Tag``/``Num`` expressions over the index
    :class:`~repro.api.schema.Schema` — multi-field conjunctions like
    ``(Tag("lang") == "en") & (Num("price") < 50) & (Num("year") >= 2020)``
    compile onto the device verification path; unknown field names fail at
    admission (compile time), not in the flush.
    """

    def __init__(self, index, session_config: SessionConfig = SessionConfig()):
        self.index = index
        self.session = Session(index, session_config)

    @property
    def schema(self):
        """The served index's attribute schema (field discovery for
        request validation / UI layers)."""
        return self.index.schema

    def submit(self, query_embedding: np.ndarray, filter=None,
               k: Optional[int] = None, **overrides) -> PendingSearch:
        """Admit one retrieval request; returns a handle that resolves at
        the next flush (``handle.result()`` forces it)."""
        req = SearchRequest(query=query_embedding, filter=filter, k=k,
                            **overrides)
        return self.session.submit(req)

    def retrieve(self, query_embedding: np.ndarray, filter=None,
                 k: Optional[int] = None, **overrides) -> SearchResult:
        """Synchronous single retrieval (still rides the shared batch)."""
        return self.submit(query_embedding, filter, k, **overrides).result()

    def flush(self) -> int:
        return self.session.flush()

    def poll(self) -> int:
        return self.session.poll()

    @staticmethod
    def context_tokens(result: SearchResult, docs: np.ndarray,
                       per_doc: int = 8) -> np.ndarray:
        """Concatenate the leading tokens of each retrieved doc — the
        prompt-context assembly used by the RAG example."""
        hit_ids = [i for i, _, _ in result.matches]
        if not hit_ids:
            return np.zeros(per_doc, np.int64)
        return np.concatenate([np.asarray(docs[h][:per_doc]) for h in hit_ids])
