from repro.serve.decode import generate, make_decode_step, make_prefill
