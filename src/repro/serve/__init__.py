from repro.serve.decode import generate, make_decode_step, make_prefill
from repro.serve.server import (SearchServer, ServerConfig, ServerStats)

__all__ = [
    "generate", "make_decode_step", "make_prefill",
    "SearchServer", "ServerConfig", "ServerStats",
]
