"""Sharding rules: map parameter/optimizer/cache/data pytrees to
PartitionSpecs on the production mesh.

Conventions (DESIGN.md §3):
  * DP: batch over ('pod','data');
  * TP: attention heads / d_ff / SSM inner dim over 'model';
  * EP: expert dim over 'model' when n_experts divides the axis
    (arctic 128e, jamba 16e), d_ff TP fallback otherwise (mixtral 8e);
  * FSDP: parameter dim-0 (d_model) + optimizer moments over 'data' when
    enabled (required for arctic-480b training);
  * vocab over 'model' for embed/lm_head;
  * decode KV caches shard their sequence dim over 'model' (split-K
    attention); mamba states shard heads over 'model'.

Every sharded dim is divisibility-checked; non-divisible dims fall back to
replication, so any (arch × mesh) combination lowers.
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch.mesh import dp_axes, dp_size
from repro.models.common import ModelConfig
from repro.train.optim import Q8


@dataclasses.dataclass(frozen=True)
class Rules:
    mesh: object
    fsdp: bool = False

    @property
    def dp(self):
        return dp_axes(self.mesh)

    @property
    def mp(self):
        return "model" if "model" in self.mesh.axis_names else None

    def ax(self, dim: int, axis):
        """axis if dim divides the axis size, else None (replicate)."""
        if axis is None:
            return None
        size = 1
        for a in (axis if isinstance(axis, tuple) else (axis,)):
            size *= self.mesh.shape[a]
        return axis if dim % size == 0 else None

    def fsdp_ax(self, dim: int):
        if not self.fsdp:
            return None
        return self.ax(dim, "data" if "data" in self.mesh.axis_names else None)


def _param_spec(rules: Rules, keystr: str, shape: tuple) -> P:
    r = rules
    mp = r.mp
    stacked = "['segments']" in keystr        # leading scan/repeat dim
    lead = (None,) if stacked else ()
    s = shape[1:] if stacked else shape

    def out(*axes):
        return P(*(lead + tuple(axes)))

    name = keystr.split(".")[-1] if "." in keystr else keystr
    if name.endswith("']"):                   # dict key like ['embed']
        name = keystr.rsplit("['", 1)[-1].rstrip("']")

    if name == "embed":
        return P(r.ax(s[0], mp), r.fsdp_ax(s[1]))
    if name == "lm_head":
        return P(r.fsdp_ax(s[0]), r.ax(s[1], mp))
    if name == "final_norm":
        return P(None)
    if name in ("wq", "wk", "wv"):
        return out(r.fsdp_ax(s[0]), r.ax(s[1], mp))
    if name == "wo":
        return out(r.ax(s[0], mp), r.fsdp_ax(s[1]))
    if name in ("bq", "bk", "bv"):
        return out(r.ax(s[0], mp))
    if name in ("w_gate", "w_up"):
        if len(s) == 3:                        # (E, D, F) expert weights
            if r.ax(s[0], mp):
                return out(mp, r.fsdp_ax(s[1]), None)
            return out(None, r.fsdp_ax(s[1]), r.ax(s[2], mp))
        return out(r.fsdp_ax(s[0]), r.ax(s[1], mp))
    if name == "w_down":
        if len(s) == 3:                        # (E, F, D)
            if r.ax(s[0], mp):
                return out(mp, None, r.fsdp_ax(s[2]))
            return out(None, r.ax(s[1], mp), r.fsdp_ax(s[2]))
        return out(r.ax(s[0], mp), r.fsdp_ax(s[1]))
    if name == "w_router":
        return out(None, None)
    if name in ("w_z", "w_x"):
        return out(r.fsdp_ax(s[0]), r.ax(s[1], mp))
    if name in ("w_b", "w_c"):
        return out(r.fsdp_ax(s[0]), None)
    if name == "w_dt":
        return out(r.fsdp_ax(s[0]), r.ax(s[1], mp))
    if name == "conv_x":
        return out(None, r.ax(s[1], mp))
    if name in ("conv_x_b", "norm_scale"):
        return out(r.ax(s[0], mp))
    if name in ("conv_bc", "conv_bc_b"):
        return out(*([None] * len(s)))
    if name in ("a_log", "dt_bias", "d_skip"):
        return out(r.ax(s[0], mp))
    if name == "w_out":
        return out(r.ax(s[0], mp), r.fsdp_ax(s[1]))
    if name in ("ln1", "ln2"):
        return out(None)
    # default: replicate
    return P(*([None] * len(shape)))


def param_specs(rules: Rules, params_shapes) -> object:
    """PartitionSpec pytree matching a params shape tree."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_shapes)
    specs = []
    for path, leaf in flat:
        ks = jax.tree_util.keystr(path)
        specs.append(_param_spec(rules, ks, tuple(leaf.shape)))
    return jax.tree_util.tree_unflatten(treedef, specs)


def opt_specs(rules: Rules, opt_shapes, params_shapes) -> object:
    """Optimizer-state specs: float moments follow their parameter's spec;
    Q8 moment blocks shard over all mesh axes combined (pure FSDP-style)."""
    all_axes = tuple(rules.mesh.axis_names)
    pflat, _ = jax.tree_util.tree_flatten_with_path(params_shapes)
    by_key = {jax.tree_util.keystr(p): tuple(l.shape) for p, l in pflat}

    def spec_for(path, leaf):
        ks = jax.tree_util.keystr(path)
        if ks.startswith(".step") or ks == "[0]":
            return P()
        # strip the leading ".m" / ".v" OptState field
        base = ks
        for prefix in (".m", ".v"):
            if base.startswith(prefix):
                base = base[len(prefix):]
                break
        # shape-preserving Q8: q/scale inherit the parameter's spec (the
        # scale's block-count last dim replicates unless divisible)
        q8_field = None
        for suffix in (".q", ".scale"):
            if base.endswith(suffix):
                q8_field = suffix
                base = base[:-len(suffix)]
                break
        pshape = by_key.get(base)
        if pshape is None:
            return P(*([None] * len(leaf.shape)))
        spec = _param_spec(rules, base, pshape)
        if q8_field is None:
            return spec
        axes = list(spec) + [None] * (len(leaf.shape) - len(spec))
        axes = axes[:len(leaf.shape)]
        last = axes[-1]
        if last is not None and leaf.shape[-1] % _axis_size(rules.mesh, last):
            axes[-1] = None
        return P(*axes)

    flat, treedef = jax.tree_util.tree_flatten_with_path(opt_shapes)
    return jax.tree_util.tree_unflatten(
        treedef, [spec_for(p, l) for p, l in flat])


def data_specs(rules: Rules, specs: dict, global_batch: int) -> dict:
    """Batch inputs: dim 0 over DP axes when divisible."""
    b_ax = rules.ax(global_batch, rules.dp)
    out = {}
    for k, v in specs.items():
        out[k] = P(*((b_ax,) + (None,) * (len(v.shape) - 1)))
    return out


def cache_specs(rules: Rules, cache_shapes, batch: int) -> object:
    """Decode caches: KV seq over 'model', batch over DP, SSM heads over
    'model'. Leaves carry a leading stacked-repeat dim."""
    b_ax = rules.ax(batch, rules.dp)
    mp = rules.mp

    def spec_for(path, leaf):
        ks = jax.tree_util.keystr(path)
        s = tuple(leaf.shape)
        if ".k" in ks or ".v" in ks:          # (R, B, T, Hkv, Dh)
            return P(None, b_ax, rules.ax(s[2], mp), None, None)
        if ".pos" in ks:
            return P(*([None] * len(s)))
        if ks.endswith(".s"):                  # (R, B, G, HG, P, N)
            return P(None, b_ax, None, rules.ax(s[3], mp), None, None)
        if ".conv_x" in ks:                    # (R, B, W-1, di)
            return P(None, b_ax, None, rules.ax(s[3], mp))
        if ".conv_bc" in ks:
            return P(None, b_ax, None, None)
        return P(*([None] * len(s)))

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_shapes)
    return jax.tree_util.tree_unflatten(
        treedef, [spec_for(p, l) for p, l in flat])


def _axis_size(mesh, axis) -> int:
    s = 1
    for a in (axis if isinstance(axis, tuple) else (axis,)):
        s *= mesh.shape[a]
    return s


def named(mesh, spec_tree):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


def sharded_bytes(shapes, specs, mesh) -> int:
    """Static per-device bytes of a sharded pytree (memory sanity)."""
    flat_s = jax.tree_util.tree_leaves(shapes)
    flat_p = jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: isinstance(x, P))
    total = 0
    for sh, sp in zip(flat_s, flat_p):
        n = int(np.prod(sh.shape)) if sh.shape else 1
        denom = 1
        for axis in sp:
            if axis is None:
                continue
            for a in (axis if isinstance(axis, tuple) else (axis,)):
                denom *= mesh.shape[a]
        total += n * jnp_dtype_size(sh.dtype) // denom
    return total


def jnp_dtype_size(dt) -> int:
    return int(np.dtype(dt).itemsize)
