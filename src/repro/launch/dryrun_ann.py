"""Dry-run of the paper's distributed filtered-search step at LAION100M
scale on the production mesh (DESIGN.md §2 tier mapping).

  PYTHONPATH=src python -m repro.launch.dryrun_ann [--mesh single|multi|both]

Record store (the "SSD" tier) is ShapeDtypeStruct-sharded over all mesh
axes; PQ codes / Bloom words / bucket codes (the "DRAM" tier) replicate.
"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import distributed as D
from repro.core import pq as pq_mod
from repro.core import search as S
from repro.core.records import RecordStore
from repro.core.selectors import QueryFilter, InMemory
from repro.launch import roofline
from repro.launch.mesh import make_production_mesh

# LAION100M-scale parameters (paper §5.1)
N = 100_000_000
DIM = 192
R = 96
R_DENSE = 1100
PQ_M = 32
MAX_LABELS = 16
QL, CAP = 8, 4096
NF = 2                     # numeric attribute fields (schema nums)
NR = 4                     # range-predicate slots per query (IndexConfig.qr)
BATCH = int(os.environ.get("REPRO_ANN_BATCH", "64"))  # coalesced queries
L_SEARCH = 128


def specs(n_shards: int):
    n = -(-N // n_shards) * n_shards
    f32, i32 = jnp.float32, jnp.int32
    store = RecordStore(
        vectors=jax.ShapeDtypeStruct((n, DIM), f32),
        neighbors=jax.ShapeDtypeStruct((n, R), i32),
        dense_neighbors=jax.ShapeDtypeStruct((n, R_DENSE), i32),
        rec_labels=jax.ShapeDtypeStruct((n, MAX_LABELS), i32),
        rec_values=jax.ShapeDtypeStruct((n, NF), f32),
        pages_std=1, pages_dense=2)
    codes = jax.ShapeDtypeStruct((n, PQ_M), jnp.uint8)
    codebook = pq_mod.PQCodebook(
        centroids=jax.ShapeDtypeStruct((PQ_M, 256, DIM // PQ_M), f32),
        dim=DIM)
    mem = InMemory(blooms=jax.ShapeDtypeStruct((n,), jnp.uint32),
                   bucket_codes=jax.ShapeDtypeStruct((n, NF), jnp.uint8))
    qf = QueryFilter(
        merged_ids=jax.ShapeDtypeStruct((BATCH, CAP), i32),
        merged_len=jax.ShapeDtypeStruct((BATCH,), i32),
        merged_mode=jax.ShapeDtypeStruct((BATCH,), i32),
        bloom_or_masks=jax.ShapeDtypeStruct((BATCH, QL), jnp.uint32),
        bloom_and_mask=jax.ShapeDtypeStruct((BATCH,), jnp.uint32),
        bucket_lo=jax.ShapeDtypeStruct((BATCH, NR), i32),
        bucket_hi=jax.ShapeDtypeStruct((BATCH, NR), i32),
        q_labels=jax.ShapeDtypeStruct((BATCH, QL), i32),
        label_mode=jax.ShapeDtypeStruct((BATCH,), i32),
        range_field=jax.ShapeDtypeStruct((BATCH, NR), i32),
        range_lo=jax.ShapeDtypeStruct((BATCH, NR), f32),
        range_hi=jax.ShapeDtypeStruct((BATCH, NR), f32),
        combine=jax.ShapeDtypeStruct((BATCH,), i32))
    queries = jax.ShapeDtypeStruct((BATCH, DIM), f32)
    return store, codes, codebook, mem, qf, queries


def run(mesh_kind: str, out_dir: str) -> dict:
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_chips = int(np.prod(list(mesh.shape.values())))
    plan = D.ShardPlan(mesh=mesh, shard_axes=tuple(mesh.axis_names))
    store, codes, codebook, mem, qf, queries = specs(plan.n_shards)
    params = S.SearchParams(l_search=L_SEARCH, k=10, max_hops=192,
                            mode="spec_in")
    result = {"arch": "pipeann-filter-100m", "shape": f"search_b{BATCH}",
              "mesh": mesh_kind, "kind": "ann_search", "status": "error",
              "n_chips": n_chips}
    t0 = time.time()
    try:
        def step(vecs, nbrs, dense, rlab, rval, codes_a, cents, mem_a, qf_a,
                 q_a):
            st = RecordStore(vecs, nbrs, dense, rlab, rval, 1, 2)
            cb = pq_mod.PQCodebook(centroids=cents, dim=DIM)
            return D.distributed_filtered_search(
                plan, st, codes_a, cb, mem_a, qf_a, q_a, 0, params)

        from jax.sharding import NamedSharding, PartitionSpec as P
        ax = plan.shard_axes
        shard1 = lambda spec: NamedSharding(mesh, spec)
        in_sh = (shard1(P(ax, None)), shard1(P(ax, None)),
                 shard1(P(ax, None)), shard1(P(ax, None)),
                 shard1(P(ax, None)),
                 shard1(P(None, None)), shard1(P(None, None, None)),
                 jax.tree_util.tree_map(lambda _: shard1(P(None)), mem),
                 jax.tree_util.tree_map(
                     lambda l: shard1(P(*([None] * len(l.shape)))), qf),
                 shard1(P(None, None)))
        lowered = jax.jit(step, in_shardings=in_sh).lower(
            store.vectors, store.neighbors, store.dense_neighbors,
            store.rec_labels, store.rec_values, codes, codebook.centroids,
            mem, qf, queries)
        compiled = lowered.compile()
        ma = compiled.memory_analysis()
        ca = compiled.cost_analysis() or {}
        hlo = compiled.as_text()
        stats = roofline.analyze_hlo(hlo)
        coll = roofline.weighted_collective_bytes(stats.collective_bytes)
        terms = roofline.roofline_terms(stats.dot_flops,
                                        float(ca.get("bytes accessed", 0)),
                                        coll)
        result.update({
            "status": "ok",
            "compile_s": round(time.time() - t0, 1),
            "memory": {
                "argument_bytes": ma.argument_size_in_bytes,
                "temp_bytes": ma.temp_size_in_bytes,
                "peak_estimate_bytes": ma.argument_size_in_bytes
                + ma.temp_size_in_bytes + ma.output_size_in_bytes
                - ma.alias_size_in_bytes,
            },
            "cost_analysis": {"flops_raw": float(ca.get("flops", 0)),
                              "bytes_accessed": float(
                                  ca.get("bytes accessed", 0))},
            "hlo": {"dot_flops_per_chip": stats.dot_flops,
                    "collective_bytes": stats.collective_bytes,
                    "collective_bytes_weighted": coll,
                    "loop_trip_counts": stats.loop_trip_counts},
            "roofline": terms,
        })
    except Exception as e:                                 # noqa: BLE001
        result.update({"error": f"{type(e).__name__}: {e}",
                       "traceback": traceback.format_exc()[-3000:]})
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir,
                           f"ann_search_{mesh_kind}.json"), "w") as f:
        json.dump(result, f, indent=1)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()
    for mk in (["single", "multi"] if args.mesh == "both" else [args.mesh]):
        r = run(mk, args.out)
        extra = ""
        if r["status"] == "ok":
            extra = (f" peak={r['memory']['peak_estimate_bytes']/2**30:.2f}GiB"
                     f" dom={r['roofline']['bottleneck']}")
        else:
            extra = " " + r.get("error", "")[:150]
        print(f"[ann-search × {mk}] {r['status']}"
              f" ({r.get('compile_s', 0)}s){extra}", flush=True)


if __name__ == "__main__":
    main()
