"""Multi-pod dry-run (DESIGN.md §5): .lower().compile() every
(architecture × input shape) cell on the production mesh, dump
memory/cost/collective analysis to experiments/dryrun/*.json.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-7b \
      --shape train_4k --mesh single [--out experiments/dryrun]
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()
# ^ MUST precede any jax import: jax locks the device count on first init.

import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import SHAPES, get_config, input_specs, list_archs, runnable
from repro.launch import roofline, shardings
from repro.launch.mesh import dp_axes, make_production_mesh
from repro.models import lm
from repro.models.common import ModelConfig, set_activation_sharding
from repro.train import optim, train_loop

# archs whose training state needs int8 moments + FSDP to fit 16 GB/chip
BIG_TRAIN = {"arctic-480b", "mixtral-8x22b", "jamba-v0.1-52b"}
# sequence parallelism conflicts with the MoE token reshape in backward
# (XLA involuntary full remat) -> MoE archs use batch-only sharding with
# more microbatches instead
MOE_ARCHS = {"arctic-480b", "mixtral-8x22b", "jamba-v0.1-52b"}


def build_cfg(arch: str, kind: str) -> ModelConfig:
    cfg = get_config(arch)
    if kind == "train":
        # bf16 params + int8 moments for the biggest configs
        if arch in BIG_TRAIN:
            cfg = dataclasses.replace(cfg, param_dtype="bfloat16")
        return cfg
    # serving: bf16 weights, no remat
    return dataclasses.replace(cfg, param_dtype="bfloat16", remat=False)


def _specs_train(cfg, arch, shape, mesh):
    # FSDP everywhere: at 256+ chips, sharding params/opt over the data
    # axis is strictly better (non-divisible dims fall back to replication)
    rules = shardings.Rules(mesh=mesh, fsdp=True)
    params_sh = jax.eval_shape(lambda k: lm.init_lm(cfg, k),
                               jax.random.PRNGKey(0))
    ocfg = optim.OptConfig(int8_moments=arch in BIG_TRAIN)
    opt_sh = jax.eval_shape(lambda p: optim.init_opt_state(p, ocfg), params_sh)
    pspec = shardings.param_specs(rules, params_sh)
    ospec = shardings.opt_specs(rules, opt_sh, params_sh)
    dspec = shardings.data_specs(rules, input_specs(cfg, shape),
                                 shape.global_batch)
    return rules, params_sh, opt_sh, ocfg, pspec, ospec, dspec


def lower_train(arch: str, shape, mesh):
    cfg = build_cfg(arch, "train")
    # sequence parallelism: residual stream sharded (dp, model) between
    # blocks -> remat-saved layer inputs shrink by the TP degree
    seq_axis = None if arch in MOE_ARCHS else "model"
    set_activation_sharding(mesh, dp_axes(mesh), seq_axis=seq_axis)
    rules, params_sh, opt_sh, ocfg, pspec, ospec, dspec = _specs_train(
        cfg, arch, shape, mesh)
    # microbatching bounds activation temps; XLA overlaps the per-
    # microbatch grad reduction with the next microbatch's compute
    micro = 8 if arch in MOE_ARCHS else 4
    # bf16 grad accumulation for the largest states (arctic: the f32
    # accumulator alone is 7.3 GB/chip)
    acc_dt = jnp.bfloat16 if arch in BIG_TRAIN else jnp.float32
    step_fn = train_loop.make_train_step(cfg, ocfg, microbatches=micro,
                                         mesh=mesh, param_specs=pspec,
                                         acc_dtype=acc_dt)
    in_sh = (shardings.named(mesh, pspec), shardings.named(mesh, ospec),
             {k: jax.NamedSharding(mesh, s) for k, s in dspec.items()})
    out_sh = (shardings.named(mesh, pspec), shardings.named(mesh, ospec),
              None)
    jitted = jax.jit(step_fn, in_shardings=in_sh, out_shardings=out_sh,
                     donate_argnums=(0, 1))
    batch_specs = {k: v for k, v in input_specs(cfg, shape).items()}
    return jitted.lower(params_sh, opt_sh, batch_specs), cfg, params_sh


def lower_prefill(arch: str, shape, mesh):
    cfg = build_cfg(arch, "serve")
    if shape.global_batch % np.prod([mesh.shape[a] for a in dp_axes(mesh)]) \
            == 0:
        set_activation_sharding(mesh, dp_axes(mesh))
    # weights shard over the data axis too (an all-gather per layer beats
    # 16x-replicated expert weights: arctic serve was 177 GiB/chip without)
    rules = shardings.Rules(mesh=mesh, fsdp=True)
    params_sh = jax.eval_shape(lambda k: lm.init_lm(cfg, k),
                               jax.random.PRNGKey(0))
    pspec = shardings.param_specs(rules, params_sh)
    dspec = shardings.data_specs(rules, input_specs(cfg, shape),
                                 shape.global_batch)

    def prefill_fn(params, batch):
        logits, caches = lm.lm_prefill(params, cfg, batch, max_t=shape.seq_len)
        return logits, caches

    cache_sh = jax.eval_shape(
        lambda: lm.init_caches(cfg, shape.global_batch, shape.seq_len))
    cspec = [shardings.cache_specs(rules, c, shape.global_batch)
             for c in cache_sh]
    in_sh = (shardings.named(mesh, pspec),
             {k: jax.NamedSharding(mesh, s) for k, s in dspec.items()})
    out_sh = (None, [shardings.named(mesh, c) for c in cspec])
    jitted = jax.jit(prefill_fn, in_shardings=in_sh, out_shardings=out_sh)
    return jitted.lower(params_sh, input_specs(cfg, shape)), cfg, params_sh


def lower_decode(arch: str, shape, mesh):
    cfg = build_cfg(arch, "serve")
    if os.environ.get("REPRO_SP_DECODE"):        # §Perf split-K variant
        cfg = dataclasses.replace(cfg, sp_decode=True)
        set_activation_sharding(mesh, dp_axes(mesh))
    elif os.environ.get("REPRO_DECODE_UNROLL"):  # §Perf unroll variant
        cfg = dataclasses.replace(cfg, decode_unroll=True)
        set_activation_sharding(mesh, dp_axes(mesh))
    elif shape.global_batch % np.prod(
            [mesh.shape[a] for a in dp_axes(mesh)]) == 0:
        set_activation_sharding(mesh, dp_axes(mesh))
    rules = shardings.Rules(mesh=mesh, fsdp=True)
    params_sh = jax.eval_shape(lambda k: lm.init_lm(cfg, k),
                               jax.random.PRNGKey(0))
    pspec = shardings.param_specs(rules, params_sh)
    cache_sh = jax.eval_shape(
        lambda: lm.init_caches(cfg, shape.global_batch, shape.seq_len))
    cspec = [shardings.cache_specs(rules, c, shape.global_batch)
             for c in cache_sh]

    def decode_fn(params, caches, tokens):
        return lm.lm_decode_step(params, caches, cfg, tokens)

    tok_spec = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
    b_ax = rules.ax(shape.global_batch, rules.dp)
    in_sh = (shardings.named(mesh, pspec),
             [shardings.named(mesh, c) for c in cspec],
             jax.NamedSharding(mesh, jax.sharding.PartitionSpec(b_ax, None)))
    out_sh = (None, [shardings.named(mesh, c) for c in cspec])
    jitted = jax.jit(decode_fn, in_shardings=in_sh, out_shardings=out_sh,
                     donate_argnums=(1,))
    return jitted.lower(params_sh, cache_sh, tok_spec), cfg, params_sh


def run_cell(arch: str, shape_name: str, mesh_kind: str, out_dir: str) -> dict:
    shape = SHAPES[shape_name]
    cfg0 = get_config(arch)
    result = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
              "kind": shape.kind, "status": "skipped"}
    if not runnable(cfg0, shape):
        result["reason"] = "full-attention arch: long_500k not sub-quadratic"
        _dump(result, out_dir)
        return result

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_chips = int(np.prod(list(mesh.shape.values())))
    t0 = time.time()
    try:
        if shape.kind == "train":
            lowered, cfg, params_sh = lower_train(arch, shape, mesh)
        elif shape.kind == "prefill":
            lowered, cfg, params_sh = lower_prefill(arch, shape, mesh)
        else:
            lowered, cfg, params_sh = lower_decode(arch, shape, mesh)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        ca = compiled.cost_analysis() or {}
        ma = compiled.memory_analysis()
        hlo = compiled.as_text()
        stats = roofline.analyze_hlo(hlo)

        n_params = sum(int(np.prod(l.shape)) for l in
                       jax.tree_util.tree_leaves(params_sh))
        n_active = _active_params(cfg, n_params)
        mflops = roofline.model_flops(cfg, n_params, n_active, shape)

        coll_bytes = roofline.weighted_collective_bytes(
            stats.collective_bytes)
        hlo_flops = stats.dot_flops          # per chip, trip-count weighted
        hbm_bytes = float(ca.get("bytes accessed", 0.0))
        terms = roofline.roofline_terms(hlo_flops, hbm_bytes, coll_bytes)

        result.update({
            "status": "ok",
            "n_chips": n_chips,
            "n_params": n_params,
            "n_active_params": n_active,
            "lower_s": round(t_lower, 1),
            "compile_s": round(t_compile, 1),
            "memory": {
                "argument_bytes": ma.argument_size_in_bytes,
                "output_bytes": ma.output_size_in_bytes,
                "temp_bytes": ma.temp_size_in_bytes,
                "alias_bytes": ma.alias_size_in_bytes,
                "peak_estimate_bytes": ma.argument_size_in_bytes
                + ma.temp_size_in_bytes + ma.output_size_in_bytes
                - ma.alias_size_in_bytes,
            },
            "cost_analysis": {
                "flops_raw": float(ca.get("flops", 0.0)),
                "bytes_accessed": hbm_bytes,
            },
            "hlo": {
                "dot_flops_per_chip": hlo_flops,
                "collective_bytes": stats.collective_bytes,
                "collective_bytes_weighted": coll_bytes,
                "n_collectives": stats.n_collectives,
                "loop_trip_counts": stats.loop_trip_counts,
            },
            "model_flops_global": mflops,
            "model_flops_per_chip": mflops / n_chips,
            "useful_flops_ratio": (mflops / n_chips) / hlo_flops
            if hlo_flops else 0.0,
            "roofline": terms,
        })
    except Exception as e:                                 # noqa: BLE001
        result.update({"status": "error", "error": f"{type(e).__name__}: {e}",
                       "traceback": traceback.format_exc()[-4000:]})
    finally:
        from repro.models.common import clear_activation_sharding
        clear_activation_sharding()
    _dump(result, out_dir)
    return result


def _active_params(cfg: ModelConfig, n_params: int) -> int:
    """Active params per token (MoE: only top-k experts count)."""
    if cfg.moe is None:
        return n_params
    shapes = jax.eval_shape(lambda k: lm.init_lm(cfg, k),
                            jax.random.PRNGKey(0))
    flat = jax.tree_util.tree_flatten_with_path(shapes)[0]
    expert_total = 0
    for path, leaf in flat:
        ks = jax.tree_util.keystr(path)
        if any(t in ks for t in (".w_gate", ".w_up", ".w_down")) \
                and "moe" in ks:
            expert_total += int(np.prod(leaf.shape))
    active = n_params - expert_total \
        + expert_total * cfg.moe.top_k // cfg.moe.n_experts
    return active


def _dump(result: dict, out_dir: str):
    os.makedirs(out_dir, exist_ok=True)
    name = f"{result['arch']}_{result['shape']}_{result['mesh']}.json"
    with open(os.path.join(out_dir, name), "w") as f:
        json.dump(result, f, indent=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    archs = list_archs() if args.all or not args.arch else [args.arch]
    shapes = list(SHAPES) if args.all or not args.shape else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    for arch in archs:
        for shape in shapes:
            for mesh_kind in meshes:
                t0 = time.time()
                r = run_cell(arch, shape, mesh_kind, args.out)
                status = r["status"]
                extra = ""
                if status == "ok":
                    peak = r["memory"]["peak_estimate_bytes"] / 2**30
                    extra = (f" peak={peak:.2f}GiB "
                             f"dom={r['roofline']['bottleneck']}")
                elif status == "error":
                    extra = " " + r["error"][:120]
                print(f"[{arch} × {shape} × {mesh_kind}] {status}"
                      f" ({time.time()-t0:.0f}s){extra}", flush=True)


if __name__ == "__main__":
    main()
