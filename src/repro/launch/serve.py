"""Serving launcher: batched prefill+decode driver with request batching.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --smoke \
      --requests 8 --prompt-len 32 --new-tokens 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, smoke_config
from repro.models import lm
from repro.serve.decode import generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    params = lm.init_lm(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab, (args.requests, args.prompt_len)),
        dtype=jnp.int32)

    t0 = time.time()
    out = generate(params, cfg, prompts, args.new_tokens,
                   temperature=args.temperature)
    dt = time.time() - t0
    total_new = args.requests * args.new_tokens
    print(f"[serve] {args.arch} ({'smoke' if args.smoke else 'full'}): "
          f"{args.requests} requests × {args.new_tokens} tokens "
          f"in {dt:.2f}s ({total_new / dt:.1f} tok/s incl. compile)")
    print("first request:", np.asarray(out)[0].tolist())


if __name__ == "__main__":
    main()
