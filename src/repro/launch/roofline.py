"""Roofline analysis from compiled HLO (DESIGN.md §5).

``compiled.cost_analysis()`` visits while-loop bodies ONCE (verified on this
jax build), so scanned-layer programs undercount by the trip count. This
module re-walks the post-SPMD HLO text, extracts per-computation collective
bytes and dot FLOPs, and multiplies by loop trip counts read from XLA's
``backend_config={"known_trip_count":{"n":...}}`` annotations.

Hardware model (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import re

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_COMP_RE = re.compile(r"^(?:ENTRY )?%?([\w\.\-]+)[^\n]*\{", re.M)
_WHILE_RE = re.compile(
    r"while\(.*?\), condition=%?([\w\.\-]+), body=%?([\w\.\-]+)"
    r"(?:.*?\"known_trip_count\":\{\"n\":\"(\d+)\"\})?")
_COLL_RE = re.compile(
    r"= ([a-z0-9]+)\[([\d,]*)\][^=]*?"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
# variadic collectives produce tuple results: `= (f32[..], s32[..]) all-reduce(`
_COLL_TUPLE_RE = re.compile(
    r"= \(([^)]*)\) "
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
# CPU post-optimization HLO prints operands by name only; shapes come from
# the defining lines, collected into a per-computation table.
_DEF_RE = re.compile(r"%([\w\.\-]+) = ([a-z0-9]+)\[([\d,]*)\]")
_DOT_RE = re.compile(
    r"= ([a-z0-9]+)\[([\d,]*)\][^\n]*? dot\("
    r"\s*%([\w\.\-]+),[^\n]*?lhs_contracting_dims=\{([\d,]*)\}")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _shape_elems(dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n


def split_computations(hlo: str) -> dict:
    """Split HLO text into named computation bodies.

    A computation header is a non-indented line ending in '{' whose first
    token is the (possibly ENTRY-prefixed) %name."""
    comps: dict = {}
    cur, buf = None, []
    for line in hlo.split("\n"):
        stripped = line.rstrip()
        is_header = (stripped.endswith("{") and line[:1] not in (" ", "\t", "")
                     and ("(" in stripped or stripped.startswith(("ENTRY",
                                                                  "%"))))
        if is_header:
            if cur is not None:
                comps[cur] = "\n".join(buf)
            toks = stripped.split()
            name = toks[1] if toks[0] == "ENTRY" and len(toks) > 1 else toks[0]
            cur = name.lstrip("%").split("(")[0].rstrip(",")
            buf = [line]
        elif cur is not None:
            buf.append(line)
    if cur is not None:
        comps[cur] = "\n".join(buf)
    return comps


@dataclasses.dataclass
class HLOStats:
    collective_bytes: dict          # per op kind, trip-count weighted
    dot_flops: float                # trip-count weighted
    n_collectives: int
    loop_trip_counts: list


def analyze_hlo(hlo: str) -> HLOStats:
    comps = split_computations(hlo)

    # map body-computation -> trip count; parent -> children
    trip: dict = {}
    children: dict = {name: [] for name in comps}
    for name, body in comps.items():
        for m in _WHILE_RE.finditer(body):
            cond, loop_body, n = m.group(1), m.group(2), m.group(3)
            count = int(n) if n else _trip_from_cond(comps.get(cond, ""))
            trip[loop_body] = count
            trip[cond] = count
            children[name].append(loop_body)
        # multiplier-1 edges: calls / to_apply / conditional branches
        for cm in re.finditer(
                r"(?:calls=|to_apply=|branch_computations=\{)%?"
                r"([\w\.\-]+(?:,\s*%?[\w\.\-]+)*)", body):
            for ref in re.split(r",\s*%?", cm.group(1)):
                ref = ref.strip().rstrip("}")
                if ref in comps and ref != name:
                    children[name].append(ref)

    # multiplier per computation: product of enclosing trip counts
    mult: dict = {}

    def resolve(name, m):
        if name in mult:
            mult[name] = max(mult[name], m)
        else:
            mult[name] = m
        for child in children.get(name, []):
            resolve(child, m * trip.get(child, 1))

    entry = _find_entry(hlo, comps)
    resolve(entry, 1)
    # computations not reached from entry (e.g. fusions listed separately or
    # reduce/scatter helper comps): multiplier 1, but they contain no
    # collectives/dots of interest in practice
    for name in comps:
        mult.setdefault(name, 1 if name == entry else 0)

    coll: dict = {}
    n_coll = 0
    flops = 0.0
    for name, body in comps.items():
        m = mult.get(name, 0)
        if m == 0:
            continue
        for cm in _COLL_RE.finditer(body):
            dtype, dims, kind = cm.group(1), cm.group(2), cm.group(3)
            nbytes = _shape_bytes(dtype, dims) * m
            coll[kind] = coll.get(kind, 0) + nbytes
            n_coll += 1
        for cm in _COLL_TUPLE_RE.finditer(body):
            kind = cm.group(2)
            nbytes = sum(_shape_bytes(dt, dims) for dt, dims
                         in _SHAPE_RE.findall(cm.group(1))) * m
            coll[kind] = coll.get(kind, 0) + nbytes
            n_coll += 1
        shape_table = {nm: dims for nm, _, dims in _DEF_RE.findall(body)}
        for dm in _DOT_RE.finditer(body):
            out_elems = _shape_elems(dm.group(2))
            lhs_name = dm.group(3)
            lhs_shape = shape_table.get(lhs_name, "")
            lhs_dims = [int(d) for d in lhs_shape.split(",") if d]
            contracting = [int(i) for i in dm.group(4).split(",") if i]
            k = 1
            for i in contracting:
                if i < len(lhs_dims):
                    k *= lhs_dims[i]
            flops += 2.0 * out_elems * k * m
    return HLOStats(collective_bytes=coll, dot_flops=flops,
                    n_collectives=n_coll,
                    loop_trip_counts=sorted(set(trip.values())))


def _trip_from_cond(cond_body: str) -> int:
    # dynamic loops (convergence conditions): bound by the largest compare
    # constant (e.g. the max_hops cap); fall back to 1
    consts = re.findall(r"constant\((\d+)\)", cond_body)
    return max((int(c) for c in consts), default=1) or 1


def _find_entry(hlo: str, comps: dict) -> str:
    m = re.search(r"^ENTRY\s+%?([\w\.\-]+)", hlo, re.M)
    if m:
        return m.group(1)
    return next(iter(comps)) if comps else ""


def weighted_collective_bytes(coll: dict) -> float:
    """Per-chip bytes on the wire: all-reduce ≈ 2× payload (RS+AG);
    others ≈ 1× output payload."""
    total = 0.0
    for kind, b in coll.items():
        total += (2.0 if kind == "all-reduce" else 1.0) * b
    return total


def roofline_terms(flops_per_chip: float, bytes_per_chip: float,
                   coll_bytes_per_chip: float) -> dict:
    compute_s = flops_per_chip / PEAK_FLOPS
    memory_s = bytes_per_chip / HBM_BW
    coll_s = coll_bytes_per_chip / ICI_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": coll_s}
    dom = max(terms, key=terms.get)
    bound = max(terms.values())
    terms["bottleneck"] = dom
    terms["roofline_fraction"] = compute_s / bound if bound > 0 else 0.0
    return terms


def model_flops(cfg, n_params: int, n_active: int, shape) -> float:
    """MODEL_FLOPS per the assignment: 6·N·D (train) / 2·N_active·D (serve)."""
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_params * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch
