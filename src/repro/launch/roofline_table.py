"""Aggregate experiments/dryrun/*.json into the EXPERIMENTS.md §Roofline
table (single-pod baselines) + §Dry-run summary.

  PYTHONPATH=src python -m repro.launch.roofline_table [--dir experiments/dryrun]
"""
from __future__ import annotations

import argparse
import glob
import json
import os


ARCH_ORDER = ["mixtral-8x22b", "arctic-480b", "qwen2-1.5b", "qwen2-7b",
              "deepseek-7b", "starcoder2-7b", "musicgen-medium",
              "jamba-v0.1-52b", "internvl2-2b", "mamba2-2.7b",
              "pipeann-filter-100m"]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k",
               "search_b64"]


def load(dir_: str) -> list:
    rows = []
    for fn in glob.glob(os.path.join(dir_, "*.json")):
        with open(fn) as f:
            rows.append(json.load(f))
    rows.sort(key=lambda r: (ARCH_ORDER.index(r["arch"])
                             if r["arch"] in ARCH_ORDER else 99,
                             SHAPE_ORDER.index(r["shape"])
                             if r["shape"] in SHAPE_ORDER else 99,
                             r["mesh"]))
    return rows


def fmt_s(x: float) -> str:
    if x == 0:
        return "0"
    if x < 1e-3:
        return f"{x*1e6:.0f}µs"
    if x < 1:
        return f"{x*1e3:.1f}ms"
    return f"{x:.2f}s"


def roofline_table(rows: list, mesh: str = "single") -> str:
    out = ["| arch | shape | compute | memory | collective | bottleneck | "
           "roofline frac | useful/HLO flops | peak GiB |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r["mesh"] != mesh:
            continue
        if r["status"] == "skipped":
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                       f"skipped (full attention) | — | — | — |")
            continue
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | ERROR | | | | | | |")
            continue
        t = r["roofline"]
        peak = r["memory"]["peak_estimate_bytes"] / 2**30
        ratio = r.get("useful_flops_ratio", 0.0)
        out.append(
            f"| {r['arch']} | {r['shape']} | {fmt_s(t['compute_s'])} | "
            f"{fmt_s(t['memory_s'])} | {fmt_s(t['collective_s'])} | "
            f"{t['bottleneck'].replace('_s','')} | "
            f"{t['roofline_fraction']:.3f} | {ratio:.2f} | {peak:.1f} |")
    return "\n".join(out)


def dryrun_table(rows: list) -> str:
    out = ["| arch | shape | mesh | status | peak GiB | HLO flops/chip | "
           "coll bytes/chip | collectives |",
           "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r["status"] == "skipped":
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                       f"skipped | — | — | — | — |")
            continue
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | ERROR "
                       f"| | | | {r.get('error','')[:60]} |")
            continue
        peak = r["memory"]["peak_estimate_bytes"] / 2**30
        fl = r["hlo"]["dot_flops_per_chip"]
        cb = r["hlo"]["collective_bytes_weighted"]
        kinds = "+".join(sorted(r["hlo"]["collective_bytes"]))
        out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok | "
                   f"{peak:.1f} | {fl:.2e} | {cb:.2e} | {kinds} |")
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--what", default="both",
                    choices=["roofline", "dryrun", "both"])
    args = ap.parse_args()
    rows = load(args.dir)
    if args.what in ("roofline", "both"):
        print("## Roofline (single-pod, 256 chips)\n")
        print(roofline_table(rows, "single"))
        print()
    if args.what in ("dryrun", "both"):
        print("## Dry-run (both meshes)\n")
        print(dryrun_table(rows))


if __name__ == "__main__":
    main()
