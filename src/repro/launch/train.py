"""Production training launcher: mesh setup, sharded state, fault-tolerant
step loop with retry, checkpoint/restart, straggler watchdog.

Real-cluster entry point (this container exercises it at reduced scale):
  PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b \
      --steps 100 --smoke --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt import CheckpointManager
from repro.configs import get_config, smoke_config
from repro.data.pipeline import Prefetcher, StepWatchdog
from repro.data.tokens import lm_batch
from repro.launch import shardings
from repro.launch.mesh import dp_axes, make_local_mesh, make_production_mesh
from repro.models import lm
from repro.train import OptConfig, init_opt_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--mesh", default="local",
                    choices=["local", "single", "multi"])
    ap.add_argument("--ckpt-dir", default="/tmp/repro_launch_train")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--max-retries", type=int, default=3)
    args = ap.parse_args()

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if args.mesh == "local":
        mesh = make_local_mesh(1, jax.device_count())
    else:
        mesh = make_production_mesh(multi_pod=args.mesh == "multi")
    rules = shardings.Rules(mesh=mesh, fsdp=not args.smoke)

    ocfg = OptConfig(lr=1e-3, warmup_steps=10, total_steps=args.steps)
    params = lm.init_lm(cfg, jax.random.PRNGKey(0))
    opt = init_opt_state(params, ocfg)

    pspec = shardings.param_specs(rules, jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params))
    params = jax.device_put(params, shardings.named(mesh, pspec))
    step_fn = jax.jit(make_train_step(cfg, ocfg, mesh=mesh,
                                      param_specs=pspec))

    mgr = CheckpointManager(args.ckpt_dir)
    start = 0
    if mgr.latest() is not None:
        target = jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
            {"params": params, "opt": opt})
        start, restored = mgr.restore(target)
        params, opt = restored["params"], restored["opt"]
        print(f"[launcher] resumed from step {start}", flush=True)

    # fault-tolerant loop: a failing step triggers restore-and-retry
    retries = 0
    while True:
        pf = Prefetcher(lambda s: lm_batch(cfg, args.batch, args.seq, s),
                        start_step=start)
        wd = StepWatchdog()
        try:
            for step, batch in pf:
                if step >= args.steps:
                    break
                wd.start()
                params, opt, metrics = step_fn(params, opt, batch)
                wd.stop(step)
                if step % 10 == 0:
                    print(f"[launcher] step {step} "
                          f"loss={float(metrics['loss']):.4f}", flush=True)
                if step and step % args.ckpt_every == 0:
                    mgr.save(step, {"params": params, "opt": opt})
                start = step + 1
            break
        except Exception as e:                            # noqa: BLE001
            retries += 1
            print(f"[launcher] step failed ({e}); retry {retries}",
                  flush=True)
            if retries > args.max_retries or mgr.latest() is None:
                raise
            target = jax.tree_util.tree_map(
                lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                {"params": params, "opt": opt})
            start, restored = mgr.restore(target)
            params, opt = restored["params"], restored["opt"]
        finally:
            pf.stop()
    mgr.wait()
    print(f"[launcher] finished at step {start}; stragglers: "
          f"{len(wd.flagged)}", flush=True)


if __name__ == "__main__":
    main()
