"""Production mesh definitions.

A function, not a module-level constant — importing this module never
touches jax device state."""
from __future__ import annotations

import jax

try:  # AxisType landed in jax 0.4.34; older versions default to Auto anyway
    from jax.sharding import AxisType
    _AXIS_KW = lambda n: {"axis_types": (AxisType.Auto,) * n}
except ImportError:
    _AXIS_KW = lambda n: {}


def _make_mesh(shape, axes):
    if hasattr(jax, "make_mesh"):
        return jax.make_mesh(shape, axes, **_AXIS_KW(len(axes)))
    import numpy as np
    devices = np.asarray(jax.devices()[:int(np.prod(shape))]).reshape(shape)
    return jax.sharding.Mesh(devices, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many real/fake devices exist (tests)."""
    return _make_mesh((data, model), ("data", "model"))


def dp_axes(mesh) -> tuple:
    """The data-parallel axes of a mesh ('pod' folds into DP)."""
    names = mesh.axis_names
    return tuple(n for n in names if n in ("pod", "data"))


def dp_size(mesh) -> int:
    s = 1
    for n in dp_axes(mesh):
        s *= mesh.shape[n]
    return s
