"""TransformerLM: segment-scanned decoder with heterogeneous layer periods.

Layers are organized as ``cfg.segments = ((repeat, (kind, ...)), ...)``:
homogeneous models are one segment of a 1-kind period; hybrids (jamba) scan
over a multi-kind period. The scan keeps HLO size O(period) instead of
O(n_layers) — essential for 512-device SPMD compile times — and the scan
body is rematerialized (``jax.checkpoint``) during training.

Frontends (DESIGN.md §4): ``audio`` consumes precomputed frame embeddings;
``vision`` prepends precomputed patch embeddings to the token embeddings.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import blocks
from repro.models.common import (ModelConfig, cdtype, dense_init, pdtype,
                                 rms_norm, shard_batch_dim)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_lm(cfg: ModelConfig, key) -> dict:
    ks = jax.random.split(key, len(cfg.segments) + 3)
    dt = pdtype(cfg)
    params = {
        "embed": dense_init(ks[0], (cfg.vocab, cfg.d_model), dt),
        "final_norm": jnp.ones((cfg.d_model,), dt),
        "segments": [],
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(ks[1], (cfg.d_model, cfg.vocab), dt)
    for i, (repeat, period) in enumerate(cfg.segments):
        seg_key = ks[2 + i]
        layers = []
        for r in range(repeat):
            rk = jax.random.fold_in(seg_key, r)
            pks = jax.random.split(rk, len(period))
            layers.append(tuple(
                blocks.init_block(pks[j], kind, cfg)
                for j, kind in enumerate(period)))
        stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *layers)
        params["segments"].append(stacked)
    return params


# ---------------------------------------------------------------------------
# forward / loss
# ---------------------------------------------------------------------------

def _embed_inputs(params, cfg: ModelConfig, batch: dict):
    dt = cdtype(cfg)
    emb = params["embed"].astype(dt)
    if cfg.frontend == "audio":
        x = batch["frame_embeds"].astype(dt)          # (B, S, D) stub frontend
    else:
        x = emb[batch["tokens"]]
        if cfg.frontend == "vision" and "patch_embeds" in batch:
            x = jnp.concatenate(
                [batch["patch_embeds"].astype(dt), x], axis=1)
    return x


def lm_forward(params, cfg: ModelConfig, batch: dict):
    """Full-sequence forward. Returns (logits (B,S,V), aux)."""
    x = _embed_inputs(params, cfg, batch)
    aux_total = blocks.zero_aux()

    for seg_idx, (repeat, period) in enumerate(cfg.segments):
        stacked = params["segments"][seg_idx]

        def body(x, layer_params, period=period):
            aux = blocks.zero_aux()
            for j, kind in enumerate(period):
                x, a = blocks.block_forward(kind, layer_params[j], x, cfg)
                x = shard_batch_dim(x)        # keep batch on the DP axes
                aux = blocks._add_aux(aux, a)
            return x, aux

        if cfg.remat:
            body = jax.checkpoint(body)
        x, auxs = jax.lax.scan(body, x, stacked)
        aux_total = {k: aux_total[k] + auxs[k].sum() for k in aux_total}

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params.get("lm_head", None)
    w_out = head if head is not None else params["embed"].T
    logits = x @ w_out.astype(x.dtype)
    return logits, aux_total


def lm_loss(params, cfg: ModelConfig, batch: dict,
            lb_weight: float = 0.01, z_weight: float = 1e-3):
    """Cross-entropy (+ MoE aux) loss. batch: tokens/targets/(mask)."""
    logits, aux = lm_forward(params, cfg, batch)
    targets = batch["targets"]
    if cfg.frontend == "vision" and "patch_embeds" in batch:
        # loss only over the text region (prefix positions carry no targets)
        prefix = batch["patch_embeds"].shape[1]
        logits = logits[:, prefix:]
    # one-hot contraction instead of take_along_axis: keeps the vocab dim
    # sharded (no f32 logit all-gather/transpose buffers on the mesh)
    logits32 = logits.astype(jnp.float32)
    m = jax.lax.stop_gradient(jnp.max(logits32, axis=-1, keepdims=True))
    logz = jnp.log(jnp.sum(jnp.exp(logits32 - m), axis=-1)) + m[..., 0]
    onehot = jax.nn.one_hot(targets, logits.shape[-1], dtype=logits.dtype)
    gold = jnp.einsum("bsv,bsv->bs", logits, onehot).astype(jnp.float32)
    nll = logz - gold
    mask = batch.get("mask", jnp.ones_like(nll))
    loss = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    total = loss + lb_weight * aux["lb_loss"] + z_weight * aux["z_loss"]
    metrics = {"nll": loss, **aux}
    return total, metrics


# ---------------------------------------------------------------------------
# serving: prefill + decode
# ---------------------------------------------------------------------------

def init_caches(cfg: ModelConfig, batch: int, max_t: int, dtype=None):
    dtype = dtype or cdtype(cfg)
    caches = []
    for repeat, period in cfg.segments:
        single = tuple(blocks.init_block_cache(k, cfg, batch, max_t, dtype)
                       for k in period)
        stacked = jax.tree_util.tree_map(
            lambda a: jnp.zeros((repeat,) + a.shape, a.dtype), single)
        caches.append(stacked)
    return caches


def lm_prefill(params, cfg: ModelConfig, batch: dict, max_t: int):
    """Process the prompt, build decode caches. Returns (logits, caches)."""
    x = _embed_inputs(params, cfg, batch)
    dtype = cdtype(cfg)
    caches = []
    for seg_idx, (repeat, period) in enumerate(cfg.segments):
        stacked = params["segments"][seg_idx]

        def body(x, layer_params, period=period):
            cs = []
            for j, kind in enumerate(period):
                x, _, c = blocks.block_prefill(kind, layer_params[j], x, cfg,
                                               max_t, dtype)
                cs.append(c)
            return x, tuple(cs)

        x, seg_caches = jax.lax.scan(body, x, stacked)
        caches.append(seg_caches)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params.get("lm_head", None)
    w_out = head if head is not None else params["embed"].T
    logits = x[:, -1:] @ w_out.astype(x.dtype)
    return logits, caches


def lm_decode_step(params, caches, cfg: ModelConfig, tokens):
    """One decode step. tokens: (B, 1) int32. Returns (logits, new caches).

    Caches thread through the scan *carry* (updated in place by layer
    index) rather than as xs→ys streams: a while-loop carry aliases its
    buffers across iterations, so the multi-GB KV store is read once and
    written one token-slice per layer — scan ys would double-buffer the
    whole cache every step (8× HBM traffic on the deepseek decode_32k
    dry-run; see EXPERIMENTS.md §Perf)."""
    dt = cdtype(cfg)
    x = params["embed"].astype(dt)[tokens]
    new_caches = []
    for seg_idx, (repeat, period) in enumerate(cfg.segments):
        stacked = params["segments"][seg_idx]

        if cfg.decode_unroll:
            # python-unrolled layers: every cache update is a trivially
            # aliasable DUS (larger HLO, less cache traffic)
            cache_stk = caches[seg_idx]
            for i in range(repeat):
                lp = jax.tree_util.tree_map(lambda a: a[i], stacked)
                lc = jax.tree_util.tree_map(lambda c: c[i], cache_stk)
                new_cs = []
                for j, kind in enumerate(period):
                    x, nc = blocks.block_decode(kind, lp[j], x, lc[j], cfg)
                    new_cs.append(nc)
                cache_stk = jax.tree_util.tree_map(
                    lambda stk, nc: jax.lax.dynamic_update_index_in_dim(
                        stk, nc.astype(stk.dtype), i, 0),
                    cache_stk, tuple(new_cs))
            new_caches.append(cache_stk)
            continue

        def body(carry, layer_params, period=period):
            x, cache_stk, i = carry
            layer_cache = jax.tree_util.tree_map(
                lambda c: jax.lax.dynamic_index_in_dim(c, i, 0,
                                                       keepdims=False),
                cache_stk)
            new_cs = []
            for j, kind in enumerate(period):
                x, nc = blocks.block_decode(kind, layer_params[j], x,
                                            layer_cache[j], cfg)
                new_cs.append(nc)
            cache_stk = jax.tree_util.tree_map(
                lambda stk, nc: jax.lax.dynamic_update_index_in_dim(
                    stk, nc.astype(stk.dtype), i, 0),
                cache_stk, tuple(new_cs))
            return (x, cache_stk, i + 1), None

        (x, seg_new, _), _ = jax.lax.scan(
            body, (x, caches[seg_idx], jnp.int32(0)), stacked)
        new_caches.append(seg_new)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params.get("lm_head", None)
    w_out = head if head is not None else params["embed"].T
    logits = x @ w_out.astype(x.dtype)
    return logits, new_caches


def param_count(cfg: ModelConfig) -> int:
    """Analytic parameter count via eval_shape (no allocation)."""
    shapes = jax.eval_shape(functools.partial(init_lm, cfg),
                            jax.random.PRNGKey(0))
    import numpy as np
    return sum(int(np.prod(l.shape))
               for l in jax.tree_util.tree_leaves(shapes))
