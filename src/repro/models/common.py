"""Model configuration + shared building blocks (norms, rotary, init)."""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int = 2
    capacity_factor: float = 1.25
    group_size: int = 1024          # dispatch group (memory bound)
    dispatch: str = "dense"         # 'dense' (GShard einsum) | 'sort' (ragged)


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    chunk: int = 128
    conv_width: int = 4
    n_groups: int = 1


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    head_dim: int
    d_ff: int
    vocab: int
    # layer pattern: segments of (repeat, (block kinds...)) — scanned over
    # `repeat` with the heterogeneous period unrolled inside the scan body.
    # kinds: 'attn' | 'moe' (attn+moe ffn) | 'mamba' | 'mamba_moe' | 'arctic'
    segments: tuple = ()
    mlp_type: str = "swiglu"        # 'swiglu' | 'gelu'
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    window: int = 0                 # sliding-window size (0 = full attention)
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    frontend: str = "none"          # 'none' | 'audio' | 'vision'
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    remat: bool = True
    # attention execution knobs
    attn_chunk_q: int = 1024        # blockwise (flash-style) prefill chunks
    attn_chunk_kv: int = 1024
    attn_chunk_threshold: int = 2048   # use blockwise above this seq len
    vision_prefix: int = 0          # vlm: number of patch-embedding positions
    sp_decode: bool = False         # split-K decode attention over 'model'
    decode_unroll: bool = False     # unroll decode layer loop (alias-friendly)

    @property
    def sub_quadratic(self) -> bool:
        """True if long-context decode is feasible (SSM/hybrid/SWA ring).

        Hybrids (jamba) count as sub-quadratic: their few full-attention
        layers keep an O(T) KV cache but no O(T²) compute at decode."""
        kinds = [k for _, period in self.segments for k in period]
        has_attn = any(k.startswith("attn") or k == "arctic" for k in kinds)
        all_attn = all(k.startswith("attn") or k == "arctic" for k in kinds)
        if not has_attn:
            return True                      # pure SSM
        if self.window > 0:
            return True                      # SWA ring cache
        return not all_attn                  # hybrid: attn minority

    @property
    def layer_kinds(self) -> list:
        out = []
        for repeat, period in self.segments:
            out.extend(list(period) * repeat)
        return out


def cdtype(cfg: ModelConfig):
    return jnp.dtype(cfg.compute_dtype)


def pdtype(cfg: ModelConfig):
    return jnp.dtype(cfg.param_dtype)


# ---------------------------------------------------------------------------
# shared ops
# ---------------------------------------------------------------------------

def rms_norm(x, scale, eps: float = 1e-5):
    # f32 accumulation without materializing an f32 copy of x (XLA hoists a
    # whole-tensor convert of the remat-saved residual out of the backward
    # loop otherwise — a 2× stacked-activation copy on the dry-run)
    dt = x.dtype
    var = (jnp.einsum("...d,...d->...", x, x,
                      preferred_element_type=jnp.float32)[..., None]
           / x.shape[-1])
    inv = jax.lax.rsqrt(var + eps).astype(dt)
    return x * inv * scale.astype(dt)


def rotary_embed(x, positions, theta: float):
    """Apply RoPE. x: (..., S, H, Dh); positions: (..., S)."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    angles = positions[..., None].astype(jnp.float32) * freqs   # (..., S, half)
    cos = jnp.cos(angles)[..., None, :]                         # (..., S, 1, half)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def dense_init(key, shape, dtype, scale: float = 0.02):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# activation-sharding context (set by the launcher before tracing)
# ---------------------------------------------------------------------------

_ACT_CTX = {"mesh": None, "dp": None, "sp": None}


def set_activation_sharding(mesh, dp_axes, seq_axis=None):
    """Install the mesh used for activation sharding constraints. XLA's
    propagation otherwise drops batch sharding around MoE token reshapes
    (verified: 16× replicated dispatch on the mixtral dry-run).
    ``seq_axis`` enables Megatron-style sequence parallelism: the residual
    stream between blocks shards its sequence dim over the model axis, so
    remat-saved layer inputs shrink by the TP degree."""
    _ACT_CTX["mesh"] = mesh
    _ACT_CTX["dp"] = dp_axes
    _ACT_CTX["sp"] = seq_axis


def clear_activation_sharding():
    _ACT_CTX["mesh"] = None
    _ACT_CTX["dp"] = None
    _ACT_CTX["sp"] = None


def _resolve(mesh, axis_kind):
    if axis_kind == "dp":
        return _ACT_CTX["dp"]
    if axis_kind == "mp":
        return "model" if "model" in mesh.axis_names else None
    if axis_kind == "sp":
        return _ACT_CTX["sp"]
    if axis_kind == "all":      # fully-sharded token dims (dp × model)
        dp = _ACT_CTX["dp"] or ()
        mp = ("model",) if "model" in mesh.axis_names else ()
        return tuple(dp) + mp if (dp or mp) else None
    return None


def constrain_dims(x, *axis_kinds):
    """with_sharding_constraint by per-dim kind ('dp'|'mp'|'sp'|None);
    non-divisible dims fall back to replication; no-op without context."""
    mesh = _ACT_CTX["mesh"]
    if mesh is None:
        return x
    spec = []
    for dim, kind in enumerate(axis_kinds[:x.ndim]):
        axes = _resolve(mesh, kind)
        if axes is None:
            spec.append(None)
            continue
        size = 1
        for a in (axes if isinstance(axes, tuple) else (axes,)):
            size *= mesh.shape[a]
        spec.append(axes if x.shape[dim] % size == 0 else None)
    spec += [None] * (x.ndim - len(spec))
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(mesh,
                                      jax.sharding.PartitionSpec(*spec)))


def shard_batch_dim(x, dim: int = 0):
    """Constrain dim 0 to DP (and, when enabled, the next dim to SP)."""
    kinds = [None] * x.ndim
    kinds[dim] = "dp"
    if dim + 1 < x.ndim and _ACT_CTX["sp"] is not None and x.ndim >= 3:
        kinds[dim + 1] = "sp"
    return constrain_dims(x, *kinds)
