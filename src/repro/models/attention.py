"""GQA attention: RoPE, optional QKV bias, sliding window, blockwise
(flash-style) prefill for long sequences, KV-cache decode.

Layouts: activations (B, S, D); q (B, S, Hq, Dh); k/v (B, T, Hkv, Dh).
GQA is expressed with an explicit group dim in einsums (no repeat_kv
materialization) so tensor-parallel sharding over heads stays clean.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig, cdtype, dense_init, pdtype, rotary_embed

NEG_INF = -1e30


class AttnParams(NamedTuple):
    wq: jax.Array      # (D, Hq*Dh)
    wk: jax.Array      # (D, Hkv*Dh)
    wv: jax.Array      # (D, Hkv*Dh)
    wo: jax.Array      # (Hq*Dh, D)
    bq: Optional[jax.Array]
    bk: Optional[jax.Array]
    bv: Optional[jax.Array]


def init_attn(key, cfg: ModelConfig) -> AttnParams:
    d, hq, hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.head_dim
    dt = pdtype(cfg)
    ks = jax.random.split(key, 4)
    bias = (lambda n: jnp.zeros((n,), dt)) if cfg.qkv_bias else (lambda n: None)
    return AttnParams(
        wq=dense_init(ks[0], (d, hq * dh), dt),
        wk=dense_init(ks[1], (d, hkv * dh), dt),
        wv=dense_init(ks[2], (d, hkv * dh), dt),
        wo=dense_init(ks[3], (hq * dh, d), dt),
        bq=bias(hq * dh), bk=bias(hkv * dh), bv=bias(hkv * dh))


def _project_qkv(p: AttnParams, x, cfg: ModelConfig, positions):
    b, s, _ = x.shape
    hq, hkv, dh = cfg.n_heads, cfg.n_kv, cfg.head_dim
    dt = cdtype(cfg)
    q = x @ p.wq.astype(dt)
    k = x @ p.wk.astype(dt)
    v = x @ p.wv.astype(dt)
    if p.bq is not None:
        q, k, v = q + p.bq.astype(dt), k + p.bk.astype(dt), v + p.bv.astype(dt)
    q = q.reshape(b, s, hq, dh)
    k = k.reshape(b, s, hkv, dh)
    v = v.reshape(b, s, hkv, dh)
    q = rotary_embed(q, positions, cfg.rope_theta)
    k = rotary_embed(k, positions, cfg.rope_theta)
    return q, k, v


def _gqa_scores(q, k, scale):
    """q: (B,S,Hkv,G,Dh), k: (B,T,Hkv,Dh) -> (B,Hkv,G,S,T)."""
    return jnp.einsum("bskgd,btkd->bkgst", q, k) * scale


def _gqa_out(probs, v):
    """probs: (B,Hkv,G,S,T), v: (B,T,Hkv,Dh) -> (B,S,Hkv,G,Dh)."""
    return jnp.einsum("bkgst,btkd->bskgd", probs, v)


def _causal_window_mask(s, t, q_offset, window):
    """(S, T) additive mask: causal + optional sliding window."""
    qpos = jnp.arange(s)[:, None] + q_offset
    kpos = jnp.arange(t)[None, :]
    ok = kpos <= qpos
    if window > 0:
        ok &= kpos > qpos - window
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def full_attention(q, k, v, cfg: ModelConfig, q_offset=0):
    """Materialized-scores attention (short sequences)."""
    b, s, hq, dh = q.shape
    t = k.shape[1]
    g = hq // cfg.n_kv
    qg = q.reshape(b, s, cfg.n_kv, g, dh)
    scores = _gqa_scores(qg, k, 1.0 / jnp.sqrt(dh).astype(jnp.float32))
    scores = scores.astype(jnp.float32) + _causal_window_mask(
        s, t, q_offset, cfg.window)[None, None, None]
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = _gqa_out(probs, v)
    return out.reshape(b, s, hq, dh)


def blockwise_attention(q, k, v, cfg: ModelConfig, q_offset=0):
    """Flash-style two-level blocking in pure JAX: the (S × T) score matrix
    is never materialized; a scan over KV chunks carries running
    (max, sum, acc) per query chunk. Causally-dead KV chunks still execute
    (shape-static) but are fully masked.
    """
    b, s, hq, dh = q.shape
    t = k.shape[1]
    g = hq // cfg.n_kv
    cq, ckv = min(cfg.attn_chunk_q, s), min(cfg.attn_chunk_kv, t)
    assert s % cq == 0 and t % ckv == 0
    nq, nkv = s // cq, t // ckv
    scale = 1.0 / jnp.sqrt(dh).astype(jnp.float32)

    qg = q.reshape(b, nq, cq, cfg.n_kv, g, dh)
    kc = k.reshape(b, nkv, ckv, cfg.n_kv, dh)
    vc = v.reshape(b, nkv, ckv, cfg.n_kv, dh)

    def q_block(qi, q_blk):
        def kv_step(carry, inp):
            m, l, acc = carry
            ki, k_blk, v_blk = inp
            sc = jnp.einsum("bskgd,btkd->bkgst", q_blk, k_blk) * scale
            sc = sc.astype(jnp.float32)
            qpos = qi * cq + jnp.arange(cq)[:, None] + q_offset
            kpos = ki * ckv + jnp.arange(ckv)[None, :]
            ok = kpos <= qpos
            if cfg.window > 0:
                ok &= kpos > qpos - cfg.window
            sc = sc + jnp.where(ok, 0.0, NEG_INF)[None, None, None]
            m_new = jnp.maximum(m, sc.max(axis=-1))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(sc - m_new[..., None])
            l_new = l * alpha + p.sum(axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bkgst,btkd->bkgsd", p.astype(q.dtype), v_blk).astype(jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, cfg.n_kv, g, cq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, cfg.n_kv, g, cq), jnp.float32)
        a0 = jnp.zeros((b, cfg.n_kv, g, cq, dh), jnp.float32)
        ks_idx = jnp.arange(nkv)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0),
            (ks_idx, kc.transpose(1, 0, 2, 3, 4), vc.transpose(1, 0, 2, 3, 4)))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return out.transpose(0, 3, 1, 2, 4).astype(q.dtype)  # (b,cq,kv,g,dh)

    outs = jax.lax.map(lambda args: q_block(*args),
                       (jnp.arange(nq), qg.transpose(1, 0, 2, 3, 4, 5)))
    # outs: (nq, b, cq, kv, g, dh) -> (b, s, hq, dh)
    return outs.transpose(1, 0, 2, 3, 4, 5).reshape(b, s, hq, dh)


class KVCache(NamedTuple):
    k: jax.Array        # (B, T, Hkv, Dh) — T = window size when windowed
    v: jax.Array
    pos: jax.Array      # () int32 — absolute next position


def init_kv_cache(cfg: ModelConfig, batch: int, max_t: int, dtype) -> KVCache:
    t = min(max_t, cfg.window) if cfg.window > 0 else max_t
    shape = (batch, t, cfg.n_kv, cfg.head_dim)
    return KVCache(k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype),
                   pos=jnp.zeros((), jnp.int32))


def _sp_decode_core(cfg: ModelConfig, q, k_new, v_new, cache: KVCache):
    """Split-K (flash-decoding) path: KV sequence sharded over 'model',
    partial-softmax psum combine — replaces XLA's default KV all-gather
    (the dominant memory/collective term of long-cache decode)."""
    from repro.models import common
    from repro.serve import sp_attention as SP
    from jax.sharding import PartitionSpec as P

    mesh = common._ACT_CTX["mesh"]
    dp = common._ACT_CTX["dp"] or ()
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    b_ax = dp if (dp and cache.k.shape[0] % dp_size == 0) else None

    def body(q_l, kn, vn, kc, vc, pos):
        kc, vc = SP.sp_cache_update(kc, vc, kn, vn, pos, "model")
        out = SP.sp_decode_attention_local(q_l, kc, vc, pos, cfg.n_kv,
                                           "model")
        return out, kc, vc

    rep = P(b_ax, None, None, None)
    seq = P(b_ax, "model", None, None)
    from repro.utils.compat import shard_map
    f = shard_map(body, mesh=mesh,
                  in_specs=(rep, rep, rep, seq, seq, P()),
                  out_specs=(rep, seq, seq), check_vma=False)
    out, k, v = f(q, k_new, v_new, cache.k, cache.v, cache.pos)
    return out, KVCache(k=k, v=v, pos=cache.pos + 1)


def decode_attention(p: AttnParams, x, cache: KVCache, cfg: ModelConfig):
    """One-token decode. x: (B, 1, D). Returns (out (B,1,D), new cache).

    Sliding-window caches are ring buffers indexed by pos % window.
    """
    b = x.shape[0]
    hq, hkv, dh = cfg.n_heads, cfg.n_kv, cfg.head_dim
    g = hq // hkv
    pos = cache.pos
    positions = jnp.full((b, 1), pos, jnp.int32)
    q, k_new, v_new = _project_qkv(p, x, cfg, positions)

    if cfg.sp_decode and cfg.window == 0:
        from repro.models import common
        mesh = common._ACT_CTX["mesh"]
        if mesh is not None and "model" in mesh.axis_names \
                and cache.k.shape[1] % mesh.shape["model"] == 0:
            out, new_cache = _sp_decode_core(cfg, q, k_new, v_new, cache)
            out = out.reshape(b, 1, hq * dh) @ p.wo.astype(x.dtype)
            return out, new_cache

    t_cache = cache.k.shape[1]
    slot = pos % t_cache if cfg.window > 0 else pos
    k = jax.lax.dynamic_update_slice(cache.k, k_new, (0, slot, 0, 0))
    v = jax.lax.dynamic_update_slice(cache.v, v_new, (0, slot, 0, 0))

    # validity of cache slots (absolute position per slot)
    slots = jnp.arange(t_cache)
    if cfg.window > 0:
        # ring: slot holds absolute position p where p % t_cache == slot and
        # p <= pos and p > pos - t_cache
        abs_pos = pos - ((pos - slots) % t_cache)
        valid = (abs_pos >= 0) & (abs_pos <= pos) & (abs_pos > pos - cfg.window)
    else:
        valid = slots <= pos

    qg = q.reshape(b, 1, hkv, g, dh)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg, k) / jnp.sqrt(dh)
    scores = scores.astype(jnp.float32) + jnp.where(valid, 0.0, NEG_INF)[
        None, None, None, None, :]
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v).reshape(b, 1, hq * dh)
    out = out @ p.wo.astype(x.dtype)
    return out, KVCache(k=k, v=v, pos=pos + 1)


def attention_forward(p: AttnParams, x, cfg: ModelConfig, positions=None,
                      cache: Optional[KVCache] = None):
    """Training / prefill forward. x: (B, S, D). If cache given, fills it."""
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    q, k, v = _project_qkv(p, x, cfg, positions)
    if s > cfg.attn_chunk_threshold:
        out = blockwise_attention(q, k, v, cfg)
    else:
        out = full_attention(q, k, v, cfg)
    out = out.reshape(b, s, cfg.n_heads * cfg.head_dim) @ p.wo.astype(x.dtype)
    if cache is not None:
        t_cache = cache.k.shape[1]
        if cfg.window > 0 and s >= t_cache:
            # keep the last `window` positions, ring-aligned
            tail_k, tail_v = k[:, -t_cache:], v[:, -t_cache:]
            shift = s % t_cache
            k_c = jnp.roll(tail_k, shift=shift, axis=1)
            v_c = jnp.roll(tail_v, shift=shift, axis=1)
        else:
            k_c = jnp.zeros_like(cache.k).at[:, :s].set(k[:, :cache.k.shape[1]])
            v_c = jnp.zeros_like(cache.v).at[:, :s].set(v[:, :cache.v.shape[1]])
        cache = KVCache(k=k_c, v=v_c, pos=jnp.asarray(s, jnp.int32))
    return out, cache
