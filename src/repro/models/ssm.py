"""Mamba-2 (SSD, state-space duality) block: chunked dual form for
train/prefill, constant-state recurrence for decode.

Follows the Mamba-2 formulation [arXiv:2405.21060]:
    S_t = exp(dt_t · A_h) · S_{t-1} + dt_t · B_t ⊗ x_t
    y_t = C_t · S_t + D_h · x_t
with per-head scalar decay A_h, grouped B/C (G groups), depthwise causal
conv on the (x, B, C) streams, and a gated RMSNorm before out-projection.

The chunked dual form computes intra-chunk interactions as a masked
attention-like matmul (MXU-friendly) and carries inter-chunk state through a
``lax.scan`` — O(T·Q) live memory instead of O(T²).

Projections are split (z/x/B/C/dt) instead of one fused in_proj so the inner
dimension (heads) shards cleanly over the `model` mesh axis; B/C are small
and stay replicated. The depthwise conv splits likewise (per-channel weights
make the split exactly equivalent to the fused conv).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig, dense_init, pdtype, rms_norm


class SSMParams(NamedTuple):
    w_z: jax.Array        # (D, di) gate branch
    w_x: jax.Array        # (D, di)
    w_b: jax.Array        # (D, G*N)
    w_c: jax.Array        # (D, G*N)
    w_dt: jax.Array       # (D, H)
    conv_x: jax.Array     # (W, di) depthwise
    conv_x_b: jax.Array   # (di,)
    conv_bc: jax.Array    # (W, 2*G*N)
    conv_bc_b: jax.Array  # (2*G*N,)
    a_log: jax.Array      # (H,)
    dt_bias: jax.Array    # (H,)
    d_skip: jax.Array     # (H,)
    norm_scale: jax.Array # (di,)
    w_out: jax.Array      # (di, D)


class SSMState(NamedTuple):
    s: jax.Array          # (B, G, HG, P, N) — ssm state
    conv_x: jax.Array     # (B, W-1, di) pre-activation ring
    conv_bc: jax.Array    # (B, W-1, 2*G*N)
    pos: jax.Array        # ()


def _dims(cfg: ModelConfig):
    s = cfg.ssm
    di = s.expand * cfg.d_model
    h = di // s.head_dim
    return di, h, s.n_groups, s.d_state, s.head_dim


def init_ssm(key, cfg: ModelConfig) -> SSMParams:
    s = cfg.ssm
    di, h, g, n, p = _dims(cfg)
    dt = pdtype(cfg)
    ks = jax.random.split(key, 8)
    a_init = jax.random.uniform(ks[5], (h,), minval=1.0, maxval=16.0)
    dt_floor, dt_ceil = 1e-3, 1e-1
    dt_init = jnp.exp(jax.random.uniform(ks[6], (h,))
                      * (jnp.log(dt_ceil) - jnp.log(dt_floor))
                      + jnp.log(dt_floor))
    return SSMParams(
        w_z=dense_init(ks[0], (cfg.d_model, di), dt),
        w_x=dense_init(ks[1], (cfg.d_model, di), dt),
        w_b=dense_init(ks[2], (cfg.d_model, g * n), dt),
        w_c=dense_init(ks[3], (cfg.d_model, g * n), dt),
        w_dt=dense_init(ks[4], (cfg.d_model, h), dt),
        conv_x=dense_init(ks[7], (s.conv_width, di), dt, scale=0.3),
        conv_x_b=jnp.zeros((di,), dt),
        conv_bc=dense_init(jax.random.fold_in(key, 11),
                           (s.conv_width, 2 * g * n), dt, scale=0.3),
        conv_bc_b=jnp.zeros((2 * g * n,), dt),
        a_log=jnp.log(a_init).astype(jnp.float32),
        dt_bias=jnp.log(jnp.expm1(dt_init)).astype(jnp.float32),
        d_skip=jnp.ones((h,), jnp.float32),
        norm_scale=jnp.ones((di,), dt),
        w_out=dense_init(jax.random.fold_in(key, 9), (di, cfg.d_model), dt))


def _causal_conv(x, w, b):
    """Depthwise causal conv via shifted adds (width small & static).
    x: (B, T, C); w: (W, C); b: (C,)."""
    width = w.shape[0]
    out = x * w[width - 1][None, None, :].astype(x.dtype)
    for i in range(1, width):
        shifted = jnp.pad(x, ((0, 0), (i, 0), (0, 0)))[:, :x.shape[1]]
        out = out + shifted * w[width - 1 - i][None, None, :].astype(x.dtype)
    return jax.nn.silu(out + b.astype(x.dtype))


def ssm_forward(p: SSMParams, x, cfg: ModelConfig,
                return_state: bool = False):
    """Chunked SSD forward. x: (B, T, D) -> (B, T, D)."""
    scfg = cfg.ssm
    di, h, g, n, pp = _dims(cfg)
    hg = h // g
    b, t, _ = x.shape
    q = min(scfg.chunk, t)
    t_pad = -(-t // q) * q
    nc = t_pad // q

    dtc = x.dtype
    z = x @ p.w_z.astype(dtc)
    xs_raw = x @ p.w_x.astype(dtc)
    bc_raw = jnp.concatenate([x @ p.w_b.astype(dtc), x @ p.w_c.astype(dtc)],
                             axis=-1)
    dt_raw = x @ p.w_dt.astype(dtc)

    xs = _causal_conv(xs_raw, p.conv_x, p.conv_x_b)
    bc = _causal_conv(bc_raw, p.conv_bc, p.conv_bc_b)
    bs, cs = bc[..., :g * n], bc[..., g * n:]

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p.dt_bias[None, None, :])          # (B, T, H)
    a = -jnp.exp(p.a_log)                                     # (H,)

    if t_pad != t:
        # zero-pad to a chunk multiple; dt=0 at pad positions makes the
        # state update an exact identity there (decay 1, contribution 0)
        pad = ((0, 0), (0, t_pad - t), (0, 0))
        xs, bs, cs, dt = (jnp.pad(arr, pad) for arr in (xs, bs, cs, dt))

    # chunked views, scanned chunk-by-chunk (bounds live memory to one chunk)
    xs_c = xs.reshape(b, nc, q, g, hg, pp).transpose(1, 0, 2, 3, 4, 5)
    bs_c = bs.reshape(b, nc, q, g, n).transpose(1, 0, 2, 3, 4)
    cs_c = cs.reshape(b, nc, q, g, n).transpose(1, 0, 2, 3, 4)
    dt_c = dt.reshape(b, nc, q, g, hg).transpose(1, 0, 2, 3, 4)
    mask = jnp.tril(jnp.ones((q, q), bool))

    def chunk_step(s_prev, inp):
        x_k, b_k, c_k, d_k = inp                   # (B,Q,G,HG,P) (B,Q,G,N) ..
        x_k = x_k.astype(jnp.float32)
        b_k = b_k.astype(jnp.float32)
        c_k = c_k.astype(jnp.float32)
        la = d_k * a.reshape(g, hg)[None, None]    # (B,Q,G,HG) log-decay
        cum = jnp.cumsum(la, axis=1)
        # intra: scores[i,j] = (C_i·B_j)·exp(cum_i − cum_j)·dt_j, j<=i
        cb = jnp.einsum("bign,bjgn->bijg", c_k, b_k)          # (B,Q,Q,G)
        li = cum[:, :, None] - cum[:, None]                   # (B,Q,Q,G,HG)
        decay = jnp.where(mask[None, :, :, None, None], jnp.exp(li), 0.0)
        w_ij = cb[..., None] * decay * d_k[:, None]           # dt_j at axis 2
        y_intra = jnp.einsum("bijgh,bjghp->bighp", w_ij, x_k)
        # inter: y_i += exp(cum_i)·(C_i · S_prev)
        y_inter = jnp.einsum("bign,bghpn->bighp", c_k, s_prev) \
            * jnp.exp(cum)[..., None]
        # state: S_new = exp(cum_Q)·S_prev + Σ_j exp(cum_Q − cum_j)·dt_j·B_j⊗x_j
        dec_end = jnp.exp(cum[:, -1:] - cum)                  # (B,Q,G,HG)
        s_loc = jnp.einsum("bjgn,bjghp,bjgh->bghpn", b_k, x_k, d_k * dec_end)
        s_new = s_prev * jnp.exp(cum[:, -1])[..., None, None] + s_loc
        return s_new, (y_intra + y_inter).astype(dtc)

    s0 = jnp.zeros((b, g, hg, pp, n), jnp.float32)
    s_final, y_chunks = jax.lax.scan(chunk_step, s0, (xs_c, bs_c, cs_c, dt_c))
    y = y_chunks.transpose(1, 0, 2, 3, 4, 5).reshape(b, t_pad, g, hg, pp)[:, :t] \
        .astype(jnp.float32)
    y = y + xs[:, :t].reshape(b, t, g, hg, pp).astype(jnp.float32) \
        * p.d_skip.reshape(g, hg)[None, None, :, :, None]
    y = y.reshape(b, t, di).astype(dtc)

    y = rms_norm(y * jax.nn.silu(z), p.norm_scale, cfg.norm_eps)
    out = y @ p.w_out.astype(dtc)
    if return_state:
        w = p.conv_x.shape[0]
        def tail(arr):
            if t >= w - 1:
                return arr[:, t - (w - 1):]
            return jnp.pad(arr, ((0, 0), (w - 1 - t, 0), (0, 0)))
        state = SSMState(s=s_final, conv_x=tail(xs_raw), conv_bc=tail(bc_raw),
                         pos=jnp.asarray(t, jnp.int32))
        return out, state
    return out


def init_ssm_state(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16) -> SSMState:
    scfg = cfg.ssm
    di, h, g, n, pp = _dims(cfg)
    return SSMState(
        s=jnp.zeros((batch, g, h // g, pp, n), jnp.float32),
        conv_x=jnp.zeros((batch, scfg.conv_width - 1, di), dtype),
        conv_bc=jnp.zeros((batch, scfg.conv_width - 1, 2 * g * n), dtype),
        pos=jnp.zeros((), jnp.int32))


def ssm_decode(p: SSMParams, x, state: SSMState, cfg: ModelConfig):
    """One-token decode. x: (B, 1, D) -> (out (B,1,D), new state)."""
    di, h, g, n, pp = _dims(cfg)
    hg = h // g
    b = x.shape[0]
    dtc = x.dtype
    xt = x[:, 0]
    z = xt @ p.w_z.astype(dtc)
    xs_raw = xt @ p.w_x.astype(dtc)
    bc_raw = jnp.concatenate([xt @ p.w_b.astype(dtc), xt @ p.w_c.astype(dtc)],
                             axis=-1)
    dt_raw = xt @ p.w_dt.astype(dtc)

    def ring_conv(ring, new, w, bias):
        win = jnp.concatenate([ring, new[:, None]], axis=1)   # (B, W, C)
        out = jnp.einsum("bwc,wc->bc", win.astype(jnp.float32),
                         w.astype(jnp.float32))
        return jax.nn.silu(out + bias.astype(jnp.float32)).astype(dtc), win[:, 1:]

    xs, new_cx = ring_conv(state.conv_x, xs_raw, p.conv_x, p.conv_x_b)
    bc, new_cbc = ring_conv(state.conv_bc, bc_raw, p.conv_bc, p.conv_bc_b)
    bs = bc[..., :g * n].reshape(b, g, n).astype(jnp.float32)
    cs = bc[..., g * n:].reshape(b, g, n).astype(jnp.float32)
    xh = xs.reshape(b, g, hg, pp).astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p.dt_bias[None, :]) \
        .reshape(b, g, hg)
    a = -jnp.exp(p.a_log).reshape(g, hg)

    decay = jnp.exp(dt * a[None])                             # (B,G,HG)
    s_new = state.s * decay[..., None, None] + jnp.einsum(
        "bgn,bghp,bgh->bghpn", bs, xh, dt)
    y = jnp.einsum("bgn,bghpn->bghp", cs, s_new) \
        + xh * p.d_skip.reshape(g, hg)[None, :, :, None]
    y = y.reshape(b, 1, di).astype(dtc)
    y = rms_norm(y * jax.nn.silu(z[:, None]), p.norm_scale, cfg.norm_eps)
    out = y @ p.w_out.astype(dtc)
    return out, SSMState(s=s_new, conv_x=new_cx, conv_bc=new_cbc,
                         pos=state.pos + 1)
