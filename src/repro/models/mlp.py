"""Dense FFN blocks: SwiGLU (llama-family) and GELU (starcoder2-style)."""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig, cdtype, dense_init, pdtype


class MLPParams(NamedTuple):
    w_gate: Optional[jax.Array]   # (D, F) — None for non-gated
    w_up: jax.Array               # (D, F)
    w_down: jax.Array             # (F, D)


def init_mlp(key, cfg: ModelConfig, d_ff: Optional[int] = None) -> MLPParams:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    dt = pdtype(cfg)
    ks = jax.random.split(key, 3)
    gated = cfg.mlp_type == "swiglu"
    return MLPParams(
        w_gate=dense_init(ks[0], (d, f), dt) if gated else None,
        w_up=dense_init(ks[1], (d, f), dt),
        w_down=dense_init(ks[2], (f, d), dt))


def mlp_forward(p: MLPParams, x, cfg: ModelConfig):
    dt = x.dtype
    up = x @ p.w_up.astype(dt)
    if p.w_gate is not None:
        h = jax.nn.silu(x @ p.w_gate.astype(dt)) * up
    else:
        h = jax.nn.gelu(up)
    return h @ p.w_down.astype(dt)
