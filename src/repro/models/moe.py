"""Mixture-of-experts FFN: top-k routing, GShard-style capacity dispatch.

Dispatch strategy 'dense' (default, robust under SPMD partitioning):
tokens are processed in fixed-size groups (a lax.scan bounds the
(S, E, C) dispatch tensor); within each group, one-hot dispatch/combine
einsums move tokens to per-expert capacity slots. Expert weights carry an
explicit leading E dim so expert parallelism shards them over the `model`
mesh axis when E divides the axis (configs fall back to d_ff tensor
parallelism otherwise — see launch/shardings.py).

Aux losses: load-balancing (Switch) + router z-loss, returned to the caller.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.common import (ModelConfig, MoEConfig, constrain_dims,
                                 dense_init, pdtype)


class MoEParams(NamedTuple):
    w_router: jax.Array    # (D, E)
    w_gate: jax.Array      # (E, D, F)
    w_up: jax.Array        # (E, D, F)
    w_down: jax.Array      # (E, F, D)


def init_moe(key, cfg: ModelConfig) -> MoEParams:
    assert cfg.moe is not None
    e, d, f = cfg.moe.n_experts, cfg.d_model, cfg.d_ff
    dt = pdtype(cfg)
    ks = jax.random.split(key, 4)
    return MoEParams(
        w_router=dense_init(ks[0], (d, e), jnp.float32),
        w_gate=dense_init(ks[1], (e, d, f), dt),
        w_up=dense_init(ks[2], (e, d, f), dt),
        w_down=dense_init(ks[3], (e, f, d), dt))


def _capacity(mcfg: MoEConfig, group: int) -> int:
    c = int(group * mcfg.top_k * mcfg.capacity_factor / mcfg.n_experts)
    return max(4, -(-c // 4) * 4)


def _f_split(e: int, f: int) -> int:
    """Smallest s with (e·s) divisible by the model axis and f % s == 0.

    Gated OFF by default: splitting inside the layer scan re-shards the
    expert weights on every layer execution (measured 48 TB/chip/step on
    mixtral train_4k — see EXPERIMENTS.md §Perf, refuted iteration 5).
    The validated follow-up is to store the weights pre-split; enable via
    REPRO_MOE_FSPLIT=1 to reproduce the refutation."""
    import os
    if not os.environ.get("REPRO_MOE_FSPLIT"):
        return 1
    from repro.models import common
    mesh = common._ACT_CTX["mesh"]
    if mesh is None or "model" not in mesh.axis_names:
        return 1
    mp = mesh.shape["model"]
    if e % mp == 0:
        return 1
    for s in range(2, mp + 1):
        if (e * s) % mp == 0 and f % s == 0:
            return s
    return 1


def _group_moe(p: MoEParams, x, mcfg: MoEConfig, compute_dtype):
    """One dispatch group. x: (B, S, D) -> (out (B, S, D), aux dict).

    The batch dim is never merged with other dims (XLA SPMD falls back to
    involuntary full rematerialization on reshapes that regroup a sharded
    dim — a 10×-memory regression on the MoE dry-runs). Only small int32
    routing tensors flatten (B·S·k·E ints; replication harmless).

    Sharding: B over DP, capacity C over DP, expert dim E over the model
    axis when divisible (EP) else d_ff over model (TP). Dispatch/combine
    einsums contract the sharded B -> psum, the TPU-native stand-in for
    GShard's all-to-all."""
    b, s, d = x.shape
    e, k = mcfg.n_experts, mcfg.top_k
    c = _capacity(mcfg, b * s)

    # f32 router accumulation without an f32 copy of x (avoids a hoisted
    # whole-buffer convert of the remat-saved residual)
    logits = jnp.einsum("bsd,de->bse", x, p.w_router.astype(x.dtype),
                        preferred_element_type=jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, idx = jax.lax.top_k(probs, k)                 # (B, S, k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    # per-slot one-hot and capacity positions (priority: slot-major, then
    # token order) — computed on a small flattened int tensor
    oh = jax.nn.one_hot(idx, e, dtype=jnp.int32)             # (B, S, k, E)
    prio = oh.transpose(2, 0, 1, 3).reshape(k * b * s, e)
    pos_prio = jnp.cumsum(prio, axis=0) - prio
    pos = pos_prio.reshape(k, b, s, e).transpose(1, 2, 0, 3)  # (B, S, k, E)
    within = (pos < c) & (oh > 0)
    pos_c = jnp.where(within, pos, 0)

    disp = (jax.nn.one_hot(pos_c, c, dtype=compute_dtype)
            * within[..., None].astype(compute_dtype))       # (B, S, k, E, C)
    disp = constrain_dims(disp, "dp", None, None, None, None)
    dispatch = disp.sum(2)                                   # (B, S, E, C)
    combine = (disp * gate_vals[..., None, None].astype(compute_dtype)).sum(2)

    # expert f-splitting: when E doesn't divide the model axis, split each
    # expert's d_ff into `split` halves so (E·split) does — exact for gated
    # FFNs (f is elementwise in gate/up, summed in down) and it turns the
    # dispatch psum broadcast into true EP sharding (16× fewer collective
    # bytes on the mixtral train_4k dry-run; see EXPERIMENTS.md §Perf)
    split = _f_split(e, p.w_gate.shape[-1])
    wg, wu, wd = p.w_gate, p.w_up, p.w_down
    if split > 1:
        e2, f2 = e * split, p.w_gate.shape[-1] // split
        d_model = wg.shape[1]
        wg = wg.reshape(e, d_model, split, f2).transpose(0, 2, 1, 3) \
            .reshape(e2, d_model, f2)
        wu = wu.reshape(e, d_model, split, f2).transpose(0, 2, 1, 3) \
            .reshape(e2, d_model, f2)
        wd = wd.reshape(e, split, f2, d_model).reshape(e2, f2, d_model)
        dispatch = jnp.repeat(dispatch, split, axis=2)       # (B, S, E2, C)
        combine = jnp.repeat(combine, split, axis=2)

    xin = jnp.einsum("bsec,bsd->ecd", dispatch, x.astype(compute_dtype))
    xin = constrain_dims(xin, "mp", "dp", None)              # EP × capacity-DP
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xin, wg.astype(compute_dtype))) \
        * jnp.einsum("ecd,edf->ecf", xin, wu.astype(compute_dtype))
    hout = jnp.einsum("ecf,efd->ecd", h, wd.astype(compute_dtype))
    hout = constrain_dims(hout, "mp", "dp", None)
    out = jnp.einsum("bsec,ecd->bsd", combine, hout)
    out = constrain_dims(out, "dp", None, None)

    # aux: load-balance (mean prob * mean assignment) + z-loss
    me = probs.reshape(-1, e).mean(0)                        # (E,)
    ce = oh.reshape(-1, e).astype(jnp.float32).mean(0) * e / k
    lb = jnp.sum(me * ce) * e
    z = jnp.mean(jax.scipy.special.logsumexp(logits, axis=-1) ** 2)
    dropped = 1.0 - within.astype(jnp.float32).sum() / (b * s * k)
    return out.astype(x.dtype), {"lb_loss": lb, "z_loss": z,
                                 "drop_frac": dropped}


def moe_forward(p: MoEParams, x, cfg: ModelConfig):
    """x: (B, S, D) -> (out, aux).

    The sequence dim is chunked via lax.scan (bounds dispatch memory); the
    batch dim stays intact and DP-sharded throughout."""
    mcfg = cfg.moe
    b, s, d = x.shape
    s_c = max(1, min(s, mcfg.group_size // max(b, 1)))
    while s % s_c:
        s_c -= 1
    n_chunks = s // s_c
    compute_dtype = x.dtype

    if n_chunks == 1:
        return _group_moe(p, x, mcfg, compute_dtype)

    chunks = x.reshape(b, n_chunks, s_c, d).transpose(1, 0, 2, 3)

    def body(_, grp):
        out, aux = _group_moe(p, grp, mcfg, compute_dtype)
        return None, (out, aux["lb_loss"], aux["z_loss"], aux["drop_frac"])

    _, (outs, lb, z, drop) = jax.lax.scan(body, None, chunks)
    out = outs.transpose(1, 0, 2, 3).reshape(b, s, d)
    aux = {"lb_loss": lb.mean(), "z_loss": z.mean(), "drop_frac": drop.mean()}
    return out, aux
