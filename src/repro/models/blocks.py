"""Block composition: pre-norm residual blocks of each kind.

Kinds:
  attn_mlp   — attention + dense FFN (llama/qwen/starcoder/musicgen/internlm)
  attn_moe   — attention + MoE FFN (mixtral)
  mamba      — pure Mamba-2 (mamba2 arch: no separate FFN)
  mamba_mlp  — Mamba-2 + dense FFN (jamba non-MoE layers)
  mamba_moe  — Mamba-2 + MoE FFN (jamba MoE layers)
  arctic     — attention + (dense FFN ∥ MoE) residual (snowflake-arctic)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention as A
from repro.models import mlp as M
from repro.models import moe as MOE
from repro.models import ssm as S
from repro.models.common import ModelConfig, pdtype, rms_norm

KINDS = ("attn_mlp", "attn_moe", "mamba", "mamba_mlp", "mamba_moe", "arctic")


def zero_aux():
    return {"lb_loss": jnp.zeros((), jnp.float32),
            "z_loss": jnp.zeros((), jnp.float32),
            "drop_frac": jnp.zeros((), jnp.float32)}


def _add_aux(a, b):
    return {k: a[k] + b[k] for k in a}


def init_block(key, kind: str, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    dt = pdtype(cfg)
    ks = jax.random.split(key, 4)
    p = {"ln1": jnp.ones((d,), dt)}
    if kind in ("attn_mlp", "attn_moe", "arctic"):
        p["attn"] = A.init_attn(ks[0], cfg)
    else:
        p["ssm"] = S.init_ssm(ks[0], cfg)
    if kind in ("attn_mlp", "mamba_mlp", "arctic"):
        p["ln2"] = jnp.ones((d,), dt)
        p["mlp"] = M.init_mlp(ks[1], cfg)
    if kind in ("attn_moe", "mamba_moe", "arctic"):
        p["ln2"] = jnp.ones((d,), dt)
        p["moe"] = MOE.init_moe(ks[2], cfg)
    return p


def block_forward(kind: str, p: dict, x, cfg: ModelConfig):
    """Train/prefill forward without cache. Returns (x, aux)."""
    aux = zero_aux()
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    if kind in ("attn_mlp", "attn_moe", "arctic"):
        out, _ = A.attention_forward(p["attn"], h, cfg)
    else:
        out = S.ssm_forward(p["ssm"], h, cfg)
    x = x + out
    if kind in ("attn_mlp", "mamba_mlp"):
        x = x + M.mlp_forward(p["mlp"], rms_norm(x, p["ln2"], cfg.norm_eps), cfg)
    elif kind in ("attn_moe", "mamba_moe"):
        mo, maux = MOE.moe_forward(p["moe"], rms_norm(x, p["ln2"], cfg.norm_eps),
                                   cfg)
        x = x + mo
        aux = _add_aux(aux, maux)
    elif kind == "arctic":
        h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
        mo, maux = MOE.moe_forward(p["moe"], h2, cfg)
        x = x + M.mlp_forward(p["mlp"], h2, cfg) + mo
        aux = _add_aux(aux, maux)
    return x, aux


def init_block_cache(kind: str, cfg: ModelConfig, batch: int, max_t: int,
                     dtype) -> dict:
    if kind in ("attn_mlp", "attn_moe", "arctic"):
        return {"attn": A.init_kv_cache(cfg, batch, max_t, dtype)}
    return {"ssm": S.init_ssm_state(cfg, batch, dtype)}


def block_prefill(kind: str, p: dict, x, cfg: ModelConfig, max_t: int, dtype):
    """Prefill: forward + produce the decode cache. Returns (x, aux, cache)."""
    aux = zero_aux()
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    if kind in ("attn_mlp", "attn_moe", "arctic"):
        cache0 = A.init_kv_cache(cfg, x.shape[0], max_t, dtype)
        out, cache_kv = A.attention_forward(p["attn"], h, cfg, cache=cache0)
        cache = {"attn": cache_kv}
    else:
        out, st = S.ssm_forward(p["ssm"], h, cfg, return_state=True)
        cache = {"ssm": st}
    x = x + out
    if kind in ("attn_mlp", "mamba_mlp"):
        x = x + M.mlp_forward(p["mlp"], rms_norm(x, p["ln2"], cfg.norm_eps), cfg)
    elif kind in ("attn_moe", "mamba_moe"):
        mo, maux = MOE.moe_forward(p["moe"], rms_norm(x, p["ln2"], cfg.norm_eps),
                                   cfg)
        x = x + mo
        aux = _add_aux(aux, maux)
    elif kind == "arctic":
        h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
        mo, maux = MOE.moe_forward(p["moe"], h2, cfg)
        x = x + M.mlp_forward(p["mlp"], h2, cfg) + mo
        aux = _add_aux(aux, maux)
    return x, aux, cache


def block_decode(kind: str, p: dict, x, cache: dict, cfg: ModelConfig):
    """One-token decode. Returns (x, new_cache)."""
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    if kind in ("attn_mlp", "attn_moe", "arctic"):
        out, new_kv = A.decode_attention(p["attn"], h, cache["attn"], cfg)
        new_cache = {"attn": new_kv}
    else:
        out, new_st = S.ssm_decode(p["ssm"], h, cache["ssm"], cfg)
        new_cache = {"ssm": new_st}
    x = x + out
    if kind in ("attn_mlp", "mamba_mlp"):
        x = x + M.mlp_forward(p["mlp"], rms_norm(x, p["ln2"], cfg.norm_eps), cfg)
    elif kind in ("attn_moe", "mamba_moe"):
        mo, _ = MOE.moe_forward(p["moe"], rms_norm(x, p["ln2"], cfg.norm_eps),
                                cfg)
        x = x + mo
    elif kind == "arctic":
        h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
        mo, _ = MOE.moe_forward(p["moe"], h2, cfg)
        x = x + M.mlp_forward(p["mlp"], h2, cfg) + mo
    return x, new_cache
