"""Typed request/result surface of the unified query layer.

``SearchRequest`` carries one query vector plus optional per-request
overrides of the index-level search defaults; ``SearchResult`` replaces
the engine's positional ``(ids, dists, QueryStats)`` tuple with ids,
distances, resolved record metadata, and the per-query slice of the
execution statistics.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np


class ServeError(RuntimeError):
    """Base of the serving tier's admission errors (serve/server.py)."""


class Overloaded(ServeError):
    """Rejected with backpressure: the bounded admission queue is full.

    ``retry_after_s`` is the server's predicted drain time for the
    current backlog — a usable client backoff hint."""

    def __init__(self, msg: str, retry_after_s: float = 0.0):
        super().__init__(msg)
        self.retry_after_s = float(retry_after_s)


class DeadlineExceeded(ServeError):
    """Shed: the request's ``deadline_us`` cannot (or did not) hold —
    predicted completion past the deadline at admission, or the deadline
    expired while queued."""


@dataclasses.dataclass
class SearchRequest:
    """One filtered top-k query.

    ``filter`` may be a DSL expression (``repro.api.Tag``/``Num`` algebra),
    a raw engine ``Selector`` (escape hatch), or None for unfiltered
    search. Unset overrides inherit the index defaults.

    ``deadline_us`` is a *serving* attribute, not a search override: a
    relative completion budget (µs from submission) that the admission
    controller enforces (serve/server.py). ``None`` — the default — opts
    out of deadline handling entirely; such requests execute bit-identically
    to the pre-serving path.
    """
    query: np.ndarray
    filter: object = None
    k: Optional[int] = None
    l: Optional[int] = None
    policy: Optional[str] = None
    max_hops: Optional[int] = None
    beam_width: Optional[int] = None
    prefetch_depth: Optional[int] = None
    deadline_us: Optional[float] = None

    def overrides(self) -> dict:
        # deadline_us deliberately excluded: it shapes admission and
        # scheduling, never the resolved SearchConfig
        out = {}
        for f in ("k", "l", "policy", "max_hops", "beam_width",
                  "prefetch_depth"):
            v = getattr(self, f)
            if v is not None:
                out[f] = v
        return out


@dataclasses.dataclass(frozen=True)
class RequestStats:
    """Per-query slice of the engine's batched QueryStats."""
    mechanism: str
    io_pages: int
    est_io_pages: float
    dist_comps: int
    est_compute: float
    hops: int
    explored: int
    fp_explored: int
    n_valid: int
    selectivity: float
    precision_in: float
    faults: int = 0           # injected fault events (0 without a plan)
    retries: int = 0          # extra read attempts issued by the ladder
    degraded: int = 0         # rows answered from the in-memory fallback

    @classmethod
    def from_query_stats(cls, stats, i: int) -> "RequestStats":
        return cls(
            mechanism=stats.mechanism[i],
            io_pages=int(stats.io_pages[i]),
            est_io_pages=float(stats.est_io_pages[i]),
            dist_comps=int(stats.dist_comps[i]),
            est_compute=float(stats.est_compute[i]),
            hops=int(stats.hops[i]),
            explored=int(stats.explored[i]),
            fp_explored=int(stats.fp_explored[i]),
            n_valid=int(stats.n_valid[i]),
            selectivity=float(stats.selectivity[i]),
            precision_in=float(stats.precision_in[i]),
            faults=int(stats.faults[i]),
            retries=int(stats.retries[i]),
            degraded=int(stats.degraded[i]),
        )


@dataclasses.dataclass
class SearchResult:
    """Verified-valid top-k for one request. ``ids`` is (k,) int32 padded
    with -1; ``metadata[i]`` is the resolved record dict (None for pads)."""
    ids: np.ndarray
    dists: np.ndarray
    metadata: list
    stats: RequestStats

    @property
    def matches(self) -> Sequence[tuple]:
        """(id, dist, metadata) triples for the non-pad results."""
        return [(int(i), float(d), m)
                for i, d, m in zip(self.ids, self.dists, self.metadata)
                if i >= 0]

    def __len__(self) -> int:
        return int(np.sum(self.ids >= 0))
