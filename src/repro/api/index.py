"""``Index`` — the public facade over the filtered-ANN engine.

Callers hand over vectors plus one plain metadata dict per record; the
facade owns the attribute :class:`~repro.api.schema.Schema`, the tag
vocabulary, CSR label arrays, attribute stores, and the engine build.
Categorical values (str/int/bool, or lists thereof) become labels in a
per-field namespace; every ``Schema.nums`` field becomes one column of
the dense ``(n, F)`` numeric value matrix — queries may then AND range
predicates over several numeric fields and still compile onto the device
verification path.

The facade is also the DSL compiler's catalog: ``Tag``/``Num`` expressions
resolve against its schema/vocabulary, and results come back with metadata
re-resolved from the attribute stores (so ``save``/``load`` round-trips
need no sidecar record storage).
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Optional, Sequence

import jax.numpy as jnp
import numpy as np

from repro.api.filters import (FilterExpr, _check_fields, compile_expr,
                               eval_mask)
from repro.api.schema import Schema
from repro.api.types import RequestStats, SearchRequest, SearchResult
from repro.ckpt import checkpoint as ckpt
from repro.core import pq as pq_mod
from repro.core.engine import (FilteredANNEngine, IndexConfig, QueryStats,
                               SearchConfig)
from repro.core.labels import LabelStore, build_label_store
from repro.core.ranges import MultiRangeStore, RangeStore
from repro.core.records import RecordStore
from repro.core.selectors import (InMemory, MaskSelector, MatchAllSelector,
                                  Selector)

_META_FILE = "index_meta.json"
_FORMAT = 2          # checkpoint format: 2 = schema-first multi-field


def _is_numeric(v) -> bool:
    return isinstance(v, (float, np.floating)) and not isinstance(v, bool)


def _norm_tag(v):
    """Canonical (hashable, JSON-able) form of a tag value."""
    if isinstance(v, (bool, np.bool_)):
        return bool(v)
    if isinstance(v, (int, np.integer)):
        return int(v)
    if isinstance(v, str):
        return v
    raise TypeError(f"unsupported tag value {v!r} "
                    "(tags must be str/int/bool)")


def _ingest_metadata(metadata: Sequence[dict], schema: Schema,
                     vocab: Optional[dict] = None):
    """Plain per-record dicts -> (vocab, CSR labels, (n, F) values).

    Pass an existing ``vocab`` to extend it in place (the insert path:
    unseen (field, value) pairs get fresh label ids appended after the
    build-time vocabulary). The schema is strict: every record must carry
    every numeric field (the value matrix is dense), tag fields may be
    sparse, and keys outside the schema are rejected — a live index cannot
    grow an attribute column retroactively.
    """
    if vocab is None:
        vocab = {}              # (field, value) -> label id
    num_col = {f: j for j, f in enumerate(schema.nums)}
    flat: list = []
    offsets = np.zeros(len(metadata) + 1, np.int64)
    values = np.zeros((len(metadata), schema.n_fields), np.float32)
    for i, d in enumerate(metadata):
        n_tags = 0
        seen: set = set()       # dedupe repeated tags within one record
        for key, v in d.items():
            if key in num_col:
                if not _is_numeric(v) and not isinstance(v, (int, np.integer)) \
                        or isinstance(v, bool):
                    raise ValueError(
                        f"record {i}: numeric field {key!r} holds "
                        f"non-numeric value {v!r}")
                values[i, num_col[key]] = float(v)
                continue
            if key not in schema.tags:
                kind = "numeric" if _is_numeric(v) else "tag"
                raise ValueError(
                    f"record {i}: field {key!r} is not in the index schema "
                    f"(tags={list(schema.tags)}, nums={list(schema.nums)}); "
                    f"a new {kind} field cannot be added to a built index")
            for tag in (v if isinstance(v, (list, tuple, set, frozenset))
                        else (v,)):
                if _is_numeric(tag):
                    raise ValueError(
                        f"record {i}: float value in tag field {key!r} "
                        f"(numeric fields: {list(schema.nums)})")
                pair = (key, _norm_tag(tag))
                if pair in seen:
                    continue
                seen.add(pair)
                lab = vocab.setdefault(pair, len(vocab))
                flat.append(lab)
                n_tags += 1
        for f in schema.nums:
            if f not in d:
                raise ValueError(
                    f"record {i} is missing the numeric field "
                    f"{f!r}; every record needs a value "
                    "(the range store is dense)")
        offsets[i + 1] = offsets[i] + n_tags
    label_flat = np.asarray(flat, np.int32)
    return vocab, offsets, label_flat, values


class Index:
    """Filtered vector index with a declarative, schema-first query surface."""

    def __init__(self, engine: FilteredANNEngine, vocab: dict,
                 schema: Schema,
                 defaults: SearchConfig = SearchConfig()):
        self.engine = engine
        self.vocab = vocab                      # (field, value) -> label id
        self.schema = schema
        self.defaults = defaults
        self._label_names = [None] * len(vocab)  # label id -> (field, value)
        for (field, value), lab in vocab.items():
            self._label_names[lab] = (field, value)

    # -- construction ---------------------------------------------------
    @classmethod
    def build(cls, vectors: np.ndarray, metadata: Sequence[dict],
              config: IndexConfig = IndexConfig(),
              schema: Optional[Schema] = None,
              numeric_field: Optional[str] = None,
              defaults: SearchConfig = SearchConfig(),
              store: str = "device",
              storage_dir: Optional[str] = None,
              storage_config=None,
              shards: int = 0) -> "Index":
        """Build an index over ``vectors`` + per-record metadata dicts.

        ``schema`` declares the attribute fields explicitly; when omitted
        it is inferred from the metadata (float values ⇒ numeric fields,
        everything else ⇒ tag fields). ``numeric_field`` is the deprecated
        single-field spelling — it pins ``Schema.nums`` to that one field
        and will be removed after one release; pass a Schema instead.

        ``store="disk"`` spills the built records to page-aligned slab
        files (docs/storage.md) at ``storage_dir`` (a temp dir when
        omitted) and serves every record read through the disk tier's
        page cache — results are bit-identical to the device backend.
        ``storage_config`` is a :class:`repro.storage.StorageConfig`
        (cache size, read-ahead, device budget). Inserts require the
        device backend.

        ``shards > 1`` builds and serves over a local mesh of that many
        devices (docs/distributed.md): the Vamana link phase shards with
        PQ-approximate navigation and the engine comes back pre-sharded,
        so :class:`~repro.api.session.Session` / the serve tier run the
        hop loop through the mesh transparently — results bit-identical
        to ``shards=0``'s search (build graphs differ within the ±1%
        recall envelope). Mutually exclusive with ``store="disk"``.
        """
        if store not in ("device", "disk"):
            raise ValueError(f"unknown store backend {store!r} "
                             "(expected 'device' or 'disk')")
        if shards > 1 and store == "disk":
            raise ValueError("shards > 1 requires the device backend: "
                             "the disk tier owns the fetch seam")
        vectors = np.asarray(vectors, np.float32)
        if len(metadata) != vectors.shape[0]:
            raise ValueError(f"{vectors.shape[0]} vectors but "
                             f"{len(metadata)} metadata dicts")
        if schema is None:
            if numeric_field is not None:
                # legacy spelling: skip inference entirely (as the
                # pre-schema path did) — the named field is the one
                # numeric column, every other key is a tag field
                fields = {k for d in metadata for k in d}
                schema = Schema(tags=tuple(sorted(fields
                                                  - {numeric_field})),
                                nums=(numeric_field,))
            else:
                schema = Schema.infer(metadata)
        elif numeric_field is not None:
            raise ValueError("pass either schema= or the deprecated "
                             "numeric_field=, not both")
        vocab, offsets, label_flat, values = _ingest_metadata(metadata,
                                                              schema)
        engine = FilteredANNEngine.build(
            vectors, offsets, label_flat, max(1, len(vocab)), values, config,
            shards=shards)
        if store == "disk":
            if storage_dir is None:
                import tempfile
                storage_dir = tempfile.mkdtemp(prefix="repro_slabs_")
            engine.to_disk(storage_dir, storage_config)
        return cls(engine, vocab, schema, defaults)

    def insert(self, vectors: np.ndarray,
               metadata: Sequence[dict]) -> np.ndarray:
        """Append records to a live index (streaming inserts).

        New nodes are linked through the engine's incremental batched build
        path; tag values unseen at build time extend the vocabulary (the
        *schema* is fixed — records must carry every ``Schema.nums`` field
        and may not introduce new fields). Returns the assigned record ids
        (contiguous, ``len(index)`` before the call onward). Previously
        compiled ``Selector`` objects hold the pre-insert attribute stores
        — recompile filters (or go through the DSL, which compiles per
        search) after inserting.
        """
        vectors = np.asarray(vectors, np.float32)
        if vectors.ndim != 2:
            raise ValueError(f"expected (M, D) vectors, got {vectors.shape}")
        if len(metadata) != vectors.shape[0]:
            raise ValueError(f"{vectors.shape[0]} vectors but "
                             f"{len(metadata)} metadata dicts")
        if vectors.shape[0] == 0:
            return np.zeros(0, np.int64)
        new_vocab = dict(self.vocab)
        new_vocab, offsets, label_flat, values = _ingest_metadata(
            metadata, self.schema, vocab=new_vocab)
        ids = self.engine.insert(vectors, offsets, label_flat,
                                 max(1, len(new_vocab)), values)
        # commit the vocabulary only after the engine accepted the batch
        self.vocab = new_vocab
        self._label_names.extend([None] * (len(new_vocab)
                                           - len(self._label_names)))
        for (field, value), lab in new_vocab.items():
            if self._label_names[lab] is None:
                self._label_names[lab] = (field, value)
        return ids

    # -- catalog duck type (used by the filter compiler) ----------------
    @property
    def label_store(self) -> LabelStore:
        return self.engine.label_store

    @property
    def range_store(self) -> MultiRangeStore:
        return self.engine.range_store

    @property
    def store(self) -> RecordStore:
        return self.engine.store

    @property
    def config(self) -> IndexConfig:
        return self.engine.config

    @property
    def n_vectors(self) -> int:
        return self.engine.n

    @property
    def ql(self) -> int:
        return self.engine.config.ql

    @property
    def qr(self) -> int:
        return self.engine.config.qr

    @property
    def numeric_field(self) -> Optional[str]:
        """Deprecated single-field accessor: the first schema numeric
        field (None when the index has none). Use ``index.schema.nums``."""
        return self.schema.nums[0] if self.schema.nums else None

    def label_id(self, field: str, value) -> Optional[int]:
        try:
            return self.vocab.get((field, _norm_tag(value)))
        except TypeError:
            return None

    def __len__(self) -> int:
        return self.n_vectors

    @property
    def dim(self) -> int:
        return self.engine.store.dim

    # -- metadata resolution --------------------------------------------
    def record_metadata(self, rec_id: int) -> dict:
        """Re-resolve one record's metadata dict from the attribute stores.

        Multi-valued tag fields come back as sorted lists."""
        out: dict = {}
        for lab in self.label_store.labels_of(rec_id):
            field, value = self._label_names[int(lab)]
            if field in out:
                prev = out[field] if isinstance(out[field], list) \
                    else [out[field]]
                out[field] = sorted(prev + [value], key=repr)
            else:
                out[field] = value
        for j, field in enumerate(self.schema.nums):
            out[field] = float(
                self.range_store.field_store(j).values[rec_id])
        return out

    # -- query path ------------------------------------------------------
    def compile_filter(self, f) -> Selector:
        if f is None:
            return MatchAllSelector(self.n_vectors)
        if isinstance(f, Selector):
            return f
        return compile_expr(f, self)

    def _resolve_scfg(self, request: SearchRequest) -> SearchConfig:
        over = request.overrides()
        return dataclasses.replace(self.defaults, **over) if over \
            else self.defaults

    def search_batch(self, requests: Sequence[SearchRequest],
                     with_stats: bool = False,
                     with_metadata: bool = True,
                     scfgs: Optional[Sequence[SearchConfig]] = None):
        """Execute a batch through the grouped request path.

        Returns list[SearchResult] (plus the raw batched QueryStats when
        ``with_stats``). ``with_metadata=False`` skips the host-side
        per-hit metadata resolution (benchmark timing paths). ``scfgs``
        replaces the per-request config resolution wholesale — the serve
        tier's degrade ladder passes rung-adjusted configs here while the
        requests themselves stay untouched."""
        if not requests:
            return ([], QueryStats.empty()) if with_stats else []
        queries, selectors, scfgs = self._prepare(requests, scfgs)
        ids, dists, stats = self.engine.execute(queries, selectors, scfgs)
        return self._assemble(requests, ids, dists, stats, with_stats,
                              with_metadata)

    def approx_scan_batch(self, requests: Sequence[SearchRequest],
                          with_stats: bool = False,
                          with_metadata: bool = True,
                          scfgs: Optional[Sequence[SearchConfig]] = None):
        """Execute a batch through the last-rung degrade path (gated
        full-corpus ADC scan + exact verify — ``engine.approx_scan``).
        Same surface as :meth:`search_batch`; results are flagged via
        ``stats.degraded``."""
        if not requests:
            return ([], QueryStats.empty()) if with_stats else []
        queries, selectors, scfgs = self._prepare(requests, scfgs)
        ids, dists, stats = self.engine.approx_scan(queries, selectors,
                                                    scfgs)
        return self._assemble(requests, ids, dists, stats, with_stats,
                              with_metadata)

    def _prepare(self, requests, scfgs):
        queries = np.stack([np.asarray(r.query, np.float32).reshape(-1)
                            for r in requests])
        if queries.shape[1] > self.dim:
            raise ValueError(f"query dim {queries.shape[1]} exceeds index "
                             f"dim {self.dim}")
        selectors = [self.compile_filter(r.filter) for r in requests]
        if scfgs is None:
            scfgs = [self._resolve_scfg(r) for r in requests]
        else:
            scfgs = list(scfgs)
            assert len(scfgs) == len(requests)
        return queries, selectors, scfgs

    def _assemble(self, requests, ids, dists, stats, with_stats,
                  with_metadata):
        results = []
        for i in range(len(requests)):
            meta = [self.record_metadata(int(x))
                    if with_metadata and x >= 0 else None
                    for x in ids[i]]
            results.append(SearchResult(
                ids=np.asarray(ids[i]), dists=np.asarray(dists[i]),
                metadata=meta,
                stats=RequestStats.from_query_stats(stats, i)))
        return (results, stats) if with_stats else results

    def search(self, request: SearchRequest) -> SearchResult:
        return self.search_batch([request])[0]

    def ground_truth(self, request: SearchRequest) -> np.ndarray:
        """Exact filtered top-k ids by brute force (for recall evaluation).

        Store arrays are trimmed to the valid record count — after inserts
        the capacity-padded device arrays carry unreachable pad rows that
        must not enter the host scan."""
        from repro.core.engine import brute_force_filtered
        k = request.k if request.k is not None else self.defaults.k
        n = self.n_vectors
        q = np.asarray(request.query, np.float32).reshape(-1)
        if q.shape[0] > self.dim:
            raise ValueError(f"query dim {q.shape[0]} exceeds index "
                             f"dim {self.dim}")
        if q.shape[0] != self.dim:
            q = np.pad(q, (0, self.dim - q.shape[0]))
        if self.engine.disk_store is not None:
            # disk backend: the device tier is a stub — stream the records
            # off the slab files (cache-bypassing scan)
            recs = self.engine.disk_store.scan_records(0, n)
            vecs, rl, rv = (recs["vectors"], recs["rec_labels"],
                            recs["rec_values"])
        else:
            vecs = np.asarray(self.store.vectors)[:n]
            rl = np.asarray(self.store.rec_labels)[:n]
            rv = np.asarray(self.store.rec_values)[:n]
        f = request.filter
        if f is None or isinstance(f, FilterExpr):
            if f is not None:
                _check_fields(f, self)
            mask, _ = eval_mask(f, self)
        elif isinstance(f, MaskSelector):
            mask = np.zeros(n, bool)
            mask[f.valid_ids] = True
        elif isinstance(f, Selector):
            plan = f.plan(self.config.ql, self.config.cap, self.config.qr)
            return brute_force_filtered(vecs, rl, rv, plan.qfilter, q, k)
        else:
            raise TypeError(f"unsupported filter {f!r}")
        d = np.sum((vecs - q[None, :]) ** 2, axis=1)
        d = np.where(mask, d, np.inf)
        order = np.argsort(d)[:k]
        return order[np.isfinite(d[order])]

    # -- persistence -----------------------------------------------------
    def _array_tree(self) -> dict:
        """Checkpoint leaves (format 2). Device arrays are trimmed to the
        valid record count — capacity pads are a live-index artifact, not
        index state. Per-field range structures save stacked: (F, n) sorted
        indexes, (F, B+1) bounds, (F, Q) quantiles, (n, F) values/codes."""
        e = self.engine
        n = e.n
        ls, rs = e.label_store, e.range_store
        if e.disk_store is not None:
            # disk backend: record data lives in the slab files (copied
            # alongside the step by ``save``), not in checkpoint leaves —
            # the device tier holds only a shape stub
            store_leaves = {}
        else:
            store_leaves = {
                "store_vectors": np.asarray(e.store.vectors)[:n],
                "store_neighbors": np.asarray(e.store.neighbors)[:n],
                "store_dense_neighbors":
                    np.asarray(e.store.dense_neighbors)[:n],
                "store_rec_labels": np.asarray(e.store.rec_labels)[:n],
                "store_rec_values": np.asarray(e.store.rec_values)[:n],
            }
        return {
            **store_leaves,
            "pq_codes": np.asarray(e.codes)[:n],
            "pq_centroids": np.asarray(e.codebook.centroids),
            "ls_vec_offsets": ls.vec_offsets, "ls_vec_labels": ls.vec_labels,
            "ls_inv_offsets": ls.inv_offsets,
            "ls_inv_postings": ls.inv_postings,
            "ls_label_counts": ls.label_counts, "ls_blooms": ls.blooms,
            "rs_values": rs.values,
            "rs_sorted_values": np.stack([s.sorted_values
                                          for s in rs.stores]),
            "rs_sorted_ids": np.stack([s.sorted_ids for s in rs.stores]),
            "rs_bucket_bounds": np.stack([s.bucket_bounds
                                          for s in rs.stores]),
            "rs_bucket_codes": rs.bucket_codes,
            "rs_quantiles": np.stack([s.quantiles for s in rs.stores]),
        }

    def save(self, path: str, injector=None):
        """Persist via the ckpt subsystem (atomic step dir + manifest) plus
        a JSON sidecar for the schema, vocabulary, and static config.

        Steps increment per save and the last two are kept, so a save that
        lands corrupted (bit rot, injected faults) still leaves the
        previous intact step for ``load`` to fall back to. The sidecar is
        written both at the root (back-compat, newest wins) and inside the
        step dir — array shapes may differ across steps after inserts, so
        fallback must read the meta that matches the step it restores."""
        tree = self._array_tree()
        prev = ckpt.latest_step(path)
        step = 0 if prev is None else prev + 1
        ckpt.save(path, step=step, tree=tree, async_write=False,
                  keep_last=2, injector=injector)
        e = self.engine
        slab_meta = {}
        if e.disk_store is not None:
            # slab files ride inside the step dir so the keep-last GC and
            # quarantine fallback govern them with the array leaves; meta
            # (carrying their digest) is written after the copy, so a
            # crash mid-copy leaves a step without meta → load falls
            # through to the previous intact step
            import shutil
            from repro.storage import slab as slab_mod
            slab_dir = os.path.join(path, f"step_{step}", "slabs")
            os.makedirs(slab_dir, exist_ok=True)
            for fn in (slab_mod.SLAB_FILE, slab_mod.META_FILE):
                shutil.copy2(os.path.join(e.disk_store.path, fn),
                             os.path.join(slab_dir, fn))
            slab_meta = {
                "backend": "disk",
                "slab_sha256": ckpt.file_digest(
                    os.path.join(slab_dir, slab_mod.SLAB_FILE)),
            }
        meta = {
            "format": _FORMAT,
            **slab_meta,
            "config": dataclasses.asdict(e.config),
            "defaults": dataclasses.asdict(self.defaults),
            "medoid": int(e.medoid),
            "schema": self.schema.to_json(),
            "codebook_dim": int(e.codebook.dim),
            "pages_std": int(e.store.pages_std),
            "pages_dense": int(e.store.pages_dense),
            "n_labels": int(e.label_store.n_labels),
            "k_hashes": int(e.label_store.k_hashes),
            "vocab": [[f, v, lab] for (f, v), lab in self.vocab.items()],
            "arrays": {k: {"shape": list(a.shape), "dtype": str(a.dtype)}
                       for k, a in tree.items()},
        }
        for meta_path in (os.path.join(path, _META_FILE),
                          os.path.join(path, f"step_{step}", _META_FILE)):
            with open(meta_path, "w") as fh:
                json.dump(meta, fh)

    @classmethod
    def load(cls, path: str, shards: int = 0) -> "Index":
        """Load a saved index, recovering from corrupted steps.

        ``shards > 1`` re-shards the restored device-backend engine over a
        local mesh (:meth:`FilteredANNEngine.shard`) — checkpoints carry
        no mesh state, so the shard count is a load-time serving choice.
        Rejected for disk-backend checkpoints (the disk tier owns the
        fetch seam).

        Startup first reaps stale ``step_K.tmp`` dirs (a killed writer's
        leftovers are never valid — publishes are atomic renames). Steps
        are then tried newest-first: one that fails integrity
        verification (checksum mismatch, truncated leaf, shape/dtype
        drift) is quarantined as ``step_K.quarantined`` and the previous
        step is restored instead; only when no intact step remains does
        the corruption error propagate.

        Format-1 checkpoints (the pre-schema single-numeric-field layout:
        flat ``(n,)`` range arrays + a ``numeric_field`` name) are mapped
        onto the F=1 case of the multi-field layout by a one-release
        back-compat shim — a legacy index loads and answers unchanged.
        """
        import jax
        ckpt.reap_tmp(path)
        steps = sorted(ckpt._list_steps(path), reverse=True)
        if not steps:
            raise FileNotFoundError(f"no checkpoint steps in {path}")
        t = meta = None
        for n_try, step in enumerate(steps):
            # per-step sidecar when present (array shapes track the step);
            # the root sidecar only describes the newest save
            meta_fn = os.path.join(path, f"step_{step}", _META_FILE)
            if not os.path.exists(meta_fn):
                meta_fn = os.path.join(path, _META_FILE)
            try:
                with open(meta_fn) as fh:
                    meta = json.load(fh)
                target = {k: jax.ShapeDtypeStruct(tuple(v["shape"]),
                                                  np.dtype(v["dtype"]))
                          for k, v in meta["arrays"].items()}
                t = ckpt.restore(path, step, target)
                if meta.get("backend") == "disk":
                    # the slab file is checkpoint payload too: digest it
                    # against the sidecar before serving from it
                    from repro.storage import slab as slab_mod
                    sl = os.path.join(path, f"step_{step}", "slabs",
                                      slab_mod.SLAB_FILE)
                    if ckpt.file_digest(sl) != meta.get("slab_sha256"):
                        raise ckpt.CheckpointCorruptionError(
                            f"step {step}: slab file checksum mismatch")
                break
            except (ckpt.CheckpointCorruptionError, json.JSONDecodeError,
                    OSError):
                ckpt.quarantine(path, step)
                if n_try == len(steps) - 1:
                    raise
        t = {k: np.asarray(v) for k, v in t.items()}
        legacy = meta.get("format", 1) < 2
        if legacy:
            t, meta = _shim_legacy_checkpoint(t, meta)

        from repro.core.records import candidate_first_mask
        disk = meta.get("backend") == "disk"
        if disk:
            from repro.storage import DiskRecordStore
            ds = DiskRecordStore(os.path.join(path, f"step_{step}",
                                              "slabs"))
            # record data (incl. the precomputed cand_first bits) serves
            # from the restored slabs; the device tier gets the stub
            store = ds.stub_store()
            n_rec = ds.n
        else:
            ds = None
            store = RecordStore(
                vectors=jnp.asarray(t["store_vectors"]),
                neighbors=jnp.asarray(t["store_neighbors"]),
                dense_neighbors=jnp.asarray(t["store_dense_neighbors"]),
                rec_labels=jnp.asarray(t["store_rec_labels"]),
                rec_values=jnp.asarray(t["store_rec_values"]),
                pages_std=meta["pages_std"],
                pages_dense=meta["pages_dense"],
                # derived, not checkpointed: re-precompute the per-record
                # dedup mask from the loaded graph rows
                cand_first=jnp.asarray(candidate_first_mask(
                    t["store_neighbors"], t["store_dense_neighbors"])))
            n_rec = store.n
        label_store = LabelStore(
            n_vectors=n_rec, n_labels=meta["n_labels"],
            vec_offsets=t["ls_vec_offsets"], vec_labels=t["ls_vec_labels"],
            inv_offsets=t["ls_inv_offsets"],
            inv_postings=t["ls_inv_postings"],
            label_counts=t["ls_label_counts"], blooms=t["ls_blooms"],
            k_hashes=meta["k_hashes"])
        range_store = MultiRangeStore([
            RangeStore(
                n_vectors=n_rec, values=t["rs_values"][:, j],
                sorted_values=t["rs_sorted_values"][j],
                sorted_ids=t["rs_sorted_ids"][j],
                bucket_bounds=t["rs_bucket_bounds"][j],
                bucket_codes=t["rs_bucket_codes"][:, j],
                quantiles=t["rs_quantiles"][j])
            for j in range(t["rs_values"].shape[1])])
        codebook = pq_mod.PQCodebook(
            centroids=jnp.asarray(t["pq_centroids"]),
            dim=meta["codebook_dim"])
        mem = InMemory(blooms=jnp.asarray(label_store.blooms),
                       bucket_codes=jnp.asarray(range_store.bucket_codes))
        engine = FilteredANNEngine(
            store, jnp.asarray(t["pq_codes"]), codebook, mem, label_store,
            range_store, meta["medoid"], IndexConfig(**meta["config"]))
        if ds is not None:
            engine.attach_disk_store(ds)
        if shards > 1:
            engine.shard(shards)   # raises on the disk backend
        vocab = {(f, v): lab for f, v, lab in meta["vocab"]}
        defaults = dict(meta["defaults"])
        if isinstance(defaults.get("fault_plan"), dict):
            # dataclasses.asdict flattened the plan into a nested dict
            from repro.core.faults import FaultPlan
            defaults["fault_plan"] = FaultPlan(**defaults["fault_plan"])
        return cls(engine, vocab, Schema.from_json(meta["schema"]),
                   SearchConfig(**defaults))


def _shim_legacy_checkpoint(t: dict, meta: dict) -> tuple[dict, dict]:
    """Map a format-1 (single numeric field) checkpoint onto F=1 arrays.

    Legacy layout: ``store_rec_values``/``rs_values``/``rs_bucket_codes``
    are flat ``(n,)``, per-field structures have no leading F axis, and the
    sidecar names a ``numeric_field`` instead of a schema. Tag fields are
    reconstructed from the vocabulary (legacy metas stored no field list).
    """
    t = dict(t)
    meta = dict(meta)
    for key in ("store_rec_values", "rs_values", "rs_bucket_codes"):
        if t[key].ndim == 1:
            t[key] = t[key][:, None]
    for key in ("rs_sorted_values", "rs_sorted_ids", "rs_bucket_bounds",
                "rs_quantiles"):
        if t[key].ndim == 1:
            t[key] = t[key][None]
    numeric_field = meta.pop("numeric_field", None)
    tag_fields = sorted({f for f, _, _ in meta["vocab"]})
    meta["schema"] = {"tags": tag_fields,
                      "nums": [numeric_field] if numeric_field else []}
    meta["format"] = _FORMAT
    return t, meta
