"""``Index`` — the public facade over the filtered-ANN engine.

Callers hand over vectors plus one plain metadata dict per record;
the facade owns the tag vocabulary, CSR label arrays, attribute stores,
and the engine build. Categorical values (str/int/bool, or lists thereof)
become labels in a per-field namespace; at most one float field becomes
the numeric range attribute.

The facade is also the DSL compiler's catalog: ``Tag``/``Num`` expressions
resolve against its vocabulary, and results come back with metadata
re-resolved from the attribute stores (so ``save``/``load`` round-trips
need no sidecar record storage).
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Optional, Sequence

import jax.numpy as jnp
import numpy as np

from repro.api.filters import (FilterExpr, _check_numeric_field,
                               compile_expr, eval_mask)
from repro.api.types import RequestStats, SearchRequest, SearchResult
from repro.ckpt import checkpoint as ckpt
from repro.core import pq as pq_mod
from repro.core.engine import (FilteredANNEngine, IndexConfig, QueryStats,
                               SearchConfig)
from repro.core.labels import LabelStore, build_label_store
from repro.core.ranges import RangeStore, build_range_store
from repro.core.records import RecordStore
from repro.core.selectors import (InMemory, MaskSelector, MatchAllSelector,
                                  Selector)

_META_FILE = "index_meta.json"


def _is_numeric(v) -> bool:
    return isinstance(v, (float, np.floating)) and not isinstance(v, bool)


def _norm_tag(v):
    """Canonical (hashable, JSON-able) form of a tag value."""
    if isinstance(v, (bool, np.bool_)):
        return bool(v)
    if isinstance(v, (int, np.integer)):
        return int(v)
    if isinstance(v, str):
        return v
    raise TypeError(f"unsupported tag value {v!r} "
                    "(tags must be str/int/bool)")


def _ingest_metadata(metadata: Sequence[dict], numeric_field: Optional[str],
                     vocab: Optional[dict] = None,
                     infer_numeric: bool = True):
    """Plain per-record dicts -> (vocab, CSR labels, values, numeric_field).

    Pass an existing ``vocab`` to extend it in place (the insert path:
    unseen (field, value) pairs get fresh label ids appended after the
    build-time vocabulary). With ``infer_numeric=False`` the numeric field
    is taken as given — records introducing new float fields then fail the
    float-in-tag-field check below, which is exactly what a live index
    needs (its dense range store cannot grow a column retroactively).
    """
    if infer_numeric and numeric_field is None:
        numeric = set()
        for d in metadata:
            for key, v in d.items():
                if _is_numeric(v):
                    numeric.add(key)
        if len(numeric) > 1:
            raise ValueError(
                f"multiple float fields {sorted(numeric)}: pass "
                "numeric_field= to pick the range attribute")
        numeric_field = numeric.pop() if numeric else None

    if vocab is None:
        vocab = {}              # (field, value) -> label id
    flat: list = []
    offsets = np.zeros(len(metadata) + 1, np.int64)
    values = np.zeros(len(metadata), np.float32)
    for i, d in enumerate(metadata):
        n_tags = 0
        seen: set = set()       # dedupe repeated tags within one record
        for key, v in d.items():
            if key == numeric_field:
                values[i] = float(v)
                continue
            for tag in (v if isinstance(v, (list, tuple, set, frozenset))
                        else (v,)):
                if _is_numeric(tag):
                    raise ValueError(
                        f"record {i}: float value in tag field {key!r} "
                        f"(numeric field is {numeric_field!r})")
                pair = (key, _norm_tag(tag))
                if pair in seen:
                    continue
                seen.add(pair)
                lab = vocab.setdefault(pair, len(vocab))
                flat.append(lab)
                n_tags += 1
        if numeric_field is not None and numeric_field not in d:
            raise ValueError(
                f"record {i} is missing the numeric field "
                f"{numeric_field!r}; every record needs a value "
                "(the range store is dense)")
        offsets[i + 1] = offsets[i] + n_tags
    label_flat = np.asarray(flat, np.int32)
    return vocab, offsets, label_flat, values, numeric_field


class Index:
    """Filtered vector index with a declarative query surface."""

    def __init__(self, engine: FilteredANNEngine, vocab: dict,
                 numeric_field: Optional[str],
                 defaults: SearchConfig = SearchConfig()):
        self.engine = engine
        self.vocab = vocab                      # (field, value) -> label id
        self.numeric_field = numeric_field
        self.defaults = defaults
        self._label_names = [None] * len(vocab)  # label id -> (field, value)
        for (field, value), lab in vocab.items():
            self._label_names[lab] = (field, value)

    # -- construction ---------------------------------------------------
    @classmethod
    def build(cls, vectors: np.ndarray, metadata: Sequence[dict],
              config: IndexConfig = IndexConfig(),
              numeric_field: Optional[str] = None,
              defaults: SearchConfig = SearchConfig()) -> "Index":
        vectors = np.asarray(vectors, np.float32)
        if len(metadata) != vectors.shape[0]:
            raise ValueError(f"{vectors.shape[0]} vectors but "
                             f"{len(metadata)} metadata dicts")
        vocab, offsets, label_flat, values, numeric_field = \
            _ingest_metadata(metadata, numeric_field)
        engine = FilteredANNEngine.build(
            vectors, offsets, label_flat, max(1, len(vocab)), values, config)
        return cls(engine, vocab, numeric_field, defaults)

    def insert(self, vectors: np.ndarray,
               metadata: Sequence[dict]) -> np.ndarray:
        """Append records to a live index (streaming inserts).

        New nodes are linked through the engine's incremental batched build
        path; tag values unseen at build time extend the vocabulary. If the
        index has a numeric range field every inserted record must carry
        it; an index built without one rejects float metadata values.
        Returns the assigned record ids (contiguous, ``len(index)`` before
        the call onward). Previously compiled ``Selector`` objects hold the
        pre-insert attribute stores — recompile filters (or go through the
        DSL, which compiles per search) after inserting.
        """
        vectors = np.asarray(vectors, np.float32)
        if vectors.ndim != 2:
            raise ValueError(f"expected (M, D) vectors, got {vectors.shape}")
        if len(metadata) != vectors.shape[0]:
            raise ValueError(f"{vectors.shape[0]} vectors but "
                             f"{len(metadata)} metadata dicts")
        if vectors.shape[0] == 0:
            return np.zeros(0, np.int64)
        new_vocab = dict(self.vocab)
        new_vocab, offsets, label_flat, values, _ = _ingest_metadata(
            metadata, self.numeric_field, vocab=new_vocab,
            infer_numeric=False)
        ids = self.engine.insert(vectors, offsets, label_flat,
                                 max(1, len(new_vocab)), values)
        # commit the vocabulary only after the engine accepted the batch
        self.vocab = new_vocab
        self._label_names.extend([None] * (len(new_vocab)
                                           - len(self._label_names)))
        for (field, value), lab in new_vocab.items():
            if self._label_names[lab] is None:
                self._label_names[lab] = (field, value)
        return ids

    # -- catalog duck type (used by the filter compiler) ----------------
    @property
    def label_store(self) -> LabelStore:
        return self.engine.label_store

    @property
    def range_store(self) -> RangeStore:
        return self.engine.range_store

    @property
    def store(self) -> RecordStore:
        return self.engine.store

    @property
    def config(self) -> IndexConfig:
        return self.engine.config

    @property
    def n_vectors(self) -> int:
        return self.engine.store.n

    @property
    def ql(self) -> int:
        return self.engine.config.ql

    def label_id(self, field: str, value) -> Optional[int]:
        try:
            return self.vocab.get((field, _norm_tag(value)))
        except TypeError:
            return None

    def __len__(self) -> int:
        return self.n_vectors

    @property
    def dim(self) -> int:
        return self.engine.store.dim

    # -- metadata resolution --------------------------------------------
    def record_metadata(self, rec_id: int) -> dict:
        """Re-resolve one record's metadata dict from the attribute stores.

        Multi-valued tag fields come back as sorted lists."""
        out: dict = {}
        for lab in self.label_store.labels_of(rec_id):
            field, value = self._label_names[int(lab)]
            if field in out:
                prev = out[field] if isinstance(out[field], list) \
                    else [out[field]]
                out[field] = sorted(prev + [value], key=repr)
            else:
                out[field] = value
        if self.numeric_field is not None:
            out[self.numeric_field] = float(self.range_store.values[rec_id])
        return out

    # -- query path ------------------------------------------------------
    def compile_filter(self, f) -> Selector:
        if f is None:
            return MatchAllSelector(self.n_vectors)
        if isinstance(f, Selector):
            return f
        return compile_expr(f, self)

    def _resolve_scfg(self, request: SearchRequest) -> SearchConfig:
        over = request.overrides()
        return dataclasses.replace(self.defaults, **over) if over \
            else self.defaults

    def search_batch(self, requests: Sequence[SearchRequest],
                     with_stats: bool = False,
                     with_metadata: bool = True):
        """Execute a batch through the grouped request path.

        Returns list[SearchResult] (plus the raw batched QueryStats when
        ``with_stats``). ``with_metadata=False`` skips the host-side
        per-hit metadata resolution (benchmark timing paths)."""
        if not requests:
            return ([], QueryStats.empty()) if with_stats else []
        queries = np.stack([np.asarray(r.query, np.float32).reshape(-1)
                            for r in requests])
        if queries.shape[1] > self.dim:
            raise ValueError(f"query dim {queries.shape[1]} exceeds index "
                             f"dim {self.dim}")
        selectors = [self.compile_filter(r.filter) for r in requests]
        scfgs = [self._resolve_scfg(r) for r in requests]
        ids, dists, stats = self.engine.execute(queries, selectors, scfgs)
        results = []
        for i in range(len(requests)):
            meta = [self.record_metadata(int(x))
                    if with_metadata and x >= 0 else None
                    for x in ids[i]]
            results.append(SearchResult(
                ids=np.asarray(ids[i]), dists=np.asarray(dists[i]),
                metadata=meta,
                stats=RequestStats.from_query_stats(stats, i)))
        return (results, stats) if with_stats else results

    def search(self, request: SearchRequest) -> SearchResult:
        return self.search_batch([request])[0]

    def ground_truth(self, request: SearchRequest) -> np.ndarray:
        """Exact filtered top-k ids by brute force (for recall evaluation)."""
        from repro.core.engine import brute_force_filtered
        k = request.k if request.k is not None else self.defaults.k
        q = np.asarray(request.query, np.float32).reshape(-1)
        if q.shape[0] > self.dim:
            raise ValueError(f"query dim {q.shape[0]} exceeds index "
                             f"dim {self.dim}")
        if q.shape[0] != self.dim:
            q = np.pad(q, (0, self.dim - q.shape[0]))
        vecs = np.asarray(self.store.vectors)
        f = request.filter
        if f is None or isinstance(f, FilterExpr):
            if f is not None:
                _check_numeric_field(f, self)
            mask, _ = eval_mask(f, self)
        elif isinstance(f, MaskSelector):
            mask = np.zeros(self.n_vectors, bool)
            mask[f.valid_ids] = True
        elif isinstance(f, Selector):
            plan = f.plan(self.config.ql, self.config.cap)
            return brute_force_filtered(
                vecs, np.asarray(self.store.rec_labels),
                np.asarray(self.store.rec_values), plan.qfilter, q, k)
        else:
            raise TypeError(f"unsupported filter {f!r}")
        d = np.sum((vecs - q[None, :]) ** 2, axis=1)
        d = np.where(mask, d, np.inf)
        order = np.argsort(d)[:k]
        return order[np.isfinite(d[order])]

    # -- persistence -----------------------------------------------------
    def _array_tree(self) -> dict:
        e = self.engine
        ls, rs = e.label_store, e.range_store
        return {
            "store_vectors": np.asarray(e.store.vectors),
            "store_neighbors": np.asarray(e.store.neighbors),
            "store_dense_neighbors": np.asarray(e.store.dense_neighbors),
            "store_rec_labels": np.asarray(e.store.rec_labels),
            "store_rec_values": np.asarray(e.store.rec_values),
            "pq_codes": np.asarray(e.codes),
            "pq_centroids": np.asarray(e.codebook.centroids),
            "ls_vec_offsets": ls.vec_offsets, "ls_vec_labels": ls.vec_labels,
            "ls_inv_offsets": ls.inv_offsets,
            "ls_inv_postings": ls.inv_postings,
            "ls_label_counts": ls.label_counts, "ls_blooms": ls.blooms,
            "rs_values": rs.values, "rs_sorted_values": rs.sorted_values,
            "rs_sorted_ids": rs.sorted_ids,
            "rs_bucket_bounds": rs.bucket_bounds,
            "rs_bucket_codes": rs.bucket_codes, "rs_quantiles": rs.quantiles,
        }

    def save(self, path: str):
        """Persist via the ckpt subsystem (atomic step dir + manifest) plus
        a JSON sidecar for the vocabulary and static config."""
        tree = self._array_tree()
        ckpt.save(path, step=0, tree=tree, async_write=False, keep_last=1)
        e = self.engine
        meta = {
            "format": 1,
            "config": dataclasses.asdict(e.config),
            "defaults": dataclasses.asdict(self.defaults),
            "medoid": int(e.medoid),
            "numeric_field": self.numeric_field,
            "codebook_dim": int(e.codebook.dim),
            "pages_std": int(e.store.pages_std),
            "pages_dense": int(e.store.pages_dense),
            "n_labels": int(e.label_store.n_labels),
            "k_hashes": int(e.label_store.k_hashes),
            "vocab": [[f, v, lab] for (f, v), lab in self.vocab.items()],
            "arrays": {k: {"shape": list(a.shape), "dtype": str(a.dtype)}
                       for k, a in tree.items()},
        }
        with open(os.path.join(path, _META_FILE), "w") as fh:
            json.dump(meta, fh)

    @classmethod
    def load(cls, path: str) -> "Index":
        with open(os.path.join(path, _META_FILE)) as fh:
            meta = json.load(fh)
        import jax
        target = {k: jax.ShapeDtypeStruct(tuple(v["shape"]),
                                          np.dtype(v["dtype"]))
                  for k, v in meta["arrays"].items()}
        t = ckpt.restore(path, 0, target)
        t = {k: np.asarray(v) for k, v in t.items()}

        store = RecordStore(
            vectors=jnp.asarray(t["store_vectors"]),
            neighbors=jnp.asarray(t["store_neighbors"]),
            dense_neighbors=jnp.asarray(t["store_dense_neighbors"]),
            rec_labels=jnp.asarray(t["store_rec_labels"]),
            rec_values=jnp.asarray(t["store_rec_values"]),
            pages_std=meta["pages_std"], pages_dense=meta["pages_dense"])
        label_store = LabelStore(
            n_vectors=store.n, n_labels=meta["n_labels"],
            vec_offsets=t["ls_vec_offsets"], vec_labels=t["ls_vec_labels"],
            inv_offsets=t["ls_inv_offsets"],
            inv_postings=t["ls_inv_postings"],
            label_counts=t["ls_label_counts"], blooms=t["ls_blooms"],
            k_hashes=meta["k_hashes"])
        range_store = RangeStore(
            n_vectors=store.n, values=t["rs_values"],
            sorted_values=t["rs_sorted_values"],
            sorted_ids=t["rs_sorted_ids"],
            bucket_bounds=t["rs_bucket_bounds"],
            bucket_codes=t["rs_bucket_codes"], quantiles=t["rs_quantiles"])
        codebook = pq_mod.PQCodebook(
            centroids=jnp.asarray(t["pq_centroids"]),
            dim=meta["codebook_dim"])
        mem = InMemory(blooms=jnp.asarray(label_store.blooms),
                       bucket_codes=jnp.asarray(range_store.bucket_codes))
        engine = FilteredANNEngine(
            store, jnp.asarray(t["pq_codes"]), codebook, mem, label_store,
            range_store, meta["medoid"], IndexConfig(**meta["config"]))
        vocab = {(f, v): lab for f, v, lab in meta["vocab"]}
        return cls(engine, vocab, meta["numeric_field"],
                   SearchConfig(**meta["defaults"]))
