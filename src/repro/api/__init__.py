# The unified public query layer: a schema-first metadata surface,
# declarative filters compiled onto the paper's speculative-filtering
# engine, a metadata-dict index facade, and a batched session scheduler
# (see docs/api.md).
from repro.api.filters import (And, FilterExpr, Num, NumRange, Or, Tag,
                               TagIs, compile_expr)
from repro.api.index import Index
from repro.api.schema import Schema, UnknownFieldError
from repro.api.session import PendingSearch, Session, SessionConfig
from repro.api.types import (DeadlineExceeded, Overloaded, RequestStats,
                             SearchRequest, SearchResult, ServeError)
from repro.core.engine import IndexConfig, SearchConfig, recall_at_k

__all__ = [
    "And", "FilterExpr", "Num", "NumRange", "Or", "Tag", "TagIs",
    "compile_expr", "Index", "IndexConfig", "SearchConfig",
    "Schema", "UnknownFieldError",
    "PendingSearch", "Session", "SessionConfig",
    "RequestStats", "SearchRequest", "SearchResult", "recall_at_k",
    "ServeError", "Overloaded", "DeadlineExceeded",
]
