"""Schema — the explicit attribute surface of an :class:`~repro.api.Index`.

A schema names the categorical (``tags``) and numeric (``nums``) metadata
fields an index stores. Numeric fields are positional: ``nums`` order is
the column order of the engine's ``(n, F)`` value matrix, so a compiled
``Num("price") < 50`` predicate carries ``(field_idx, lo, hi)`` straight
onto the device verification path.

Build either with an explicit schema::

    Index.build(vectors, metadata,
                schema=Schema(tags=["cat"], nums=["price", "year"]))

or let :meth:`Schema.infer` derive one from the metadata dicts (every
float-valued key becomes a numeric field, everything else a tag field).
Records must carry *every* numeric field (the value matrix is dense); tag
fields may be sparse.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence


class UnknownFieldError(KeyError, ValueError):
    """A filter references a field the index schema does not contain.

    Raised at *compile* time (not at device dispatch) so typos surface
    before any engine work. Subclasses both ``KeyError`` (lookup flavor)
    and ``ValueError`` (pre-rename call sites caught the latter).
    """

    def __init__(self, kind: str, field: str, known: Sequence[str]):
        msg = (f"{kind} field {field!r} is not indexed "
               f"(schema {kind} fields: {sorted(known)!r})")
        super().__init__(msg)
        self.field = field

    def __str__(self) -> str:          # KeyError would repr()-quote the msg
        return self.args[0]


def _is_numeric_value(v) -> bool:
    import numpy as np
    return isinstance(v, (float, np.floating)) and not isinstance(v, bool)


@dataclasses.dataclass(frozen=True)
class Schema:
    """Declared attribute fields of an index.

    ``tags``: categorical fields (str/int/bool values, or lists thereof).
    ``nums``: numeric fields; order fixes the value-matrix columns.
    """
    tags: tuple = ()
    nums: tuple = ()

    def __post_init__(self):
        tags = tuple(dict.fromkeys(self.tags))      # dedupe, keep order
        nums = tuple(dict.fromkeys(self.nums))
        object.__setattr__(self, "tags", tags)
        object.__setattr__(self, "nums", nums)
        overlap = set(tags) & set(nums)
        if overlap:
            raise ValueError(f"fields {sorted(overlap)} declared both "
                             "tag and numeric")
        for f in tags + nums:
            if not isinstance(f, str):
                raise TypeError(f"field names must be str, got {f!r}")

    # -- lookups ---------------------------------------------------------
    @property
    def n_fields(self) -> int:
        """Numeric value-matrix width (≥1: indexes with no numeric field
        still carry one zero column so device shapes stay uniform)."""
        return max(1, len(self.nums))

    def num_index(self, field: str) -> int:
        """Column of ``field`` in the value matrix; UnknownFieldError if
        the schema does not declare it."""
        try:
            return self.nums.index(field)
        except ValueError:
            raise UnknownFieldError("numeric", field, self.nums) from None

    def check_tag(self, field: str) -> str:
        if field not in self.tags:
            raise UnknownFieldError("tag", field, self.tags)
        return field

    # -- construction ----------------------------------------------------
    @classmethod
    def infer(cls, metadata: Sequence[dict]) -> "Schema":
        """Derive a schema from metadata dicts: a field holding any float
        becomes numeric (plain ints are numeric-compatible, so mixed
        int/float columns stay numeric), everything else a tag field
        (names sorted for a deterministic column order). A field mixing
        floats with tag-only values (str/bool/lists) is ambiguous and
        needs an explicit Schema."""
        import numpy as np
        has_float, has_tag_only = set(), set()
        for d in metadata:
            for key, v in d.items():
                if _is_numeric_value(v):
                    has_float.add(key)
                elif not isinstance(v, (int, np.integer)) \
                        or isinstance(v, bool):
                    has_tag_only.add(key)     # str / bool / list / …
        clash = has_float & has_tag_only
        if clash:
            raise ValueError(
                f"fields {sorted(clash)} hold both float and tag values; "
                "pass an explicit Schema to disambiguate")
        tags = {k for d in metadata for k in d} - has_float
        return cls(tags=tuple(sorted(tags)), nums=tuple(sorted(has_float)))

    def to_json(self) -> dict:
        return {"tags": list(self.tags), "nums": list(self.nums)}

    @classmethod
    def from_json(cls, obj: dict) -> "Schema":
        return cls(tags=tuple(obj.get("tags", ())),
                   nums=tuple(obj.get("nums", ())))
