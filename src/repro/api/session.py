"""``Session`` — incremental request admission with batched execution.

The engine's request path groups a batch by (mechanism, pool bucket,
config) and runs each group as one coalesced device call; a Session
generalizes that batching *across callers*: requests are admitted one at
a time (e.g. by a serving frontend), accumulate in a pending queue, and
flush together when the batch fills, the oldest request exceeds the
flush deadline, or a result is demanded.

Thread-safe since the serving tier (serve/server.py) landed: submits,
flushes, and ``result()`` waits may race from any number of threads. The
pending queue swaps under a lock, handles resolve through per-handle
events, and executions serialize on a separate lock so concurrent
flushes never interleave device work. Deadlines are still checked at
admission and at ``poll()`` — the single-threaded serve-loop tick stays
deterministic; the threaded server owns its *own* scheduling on top.
"""
from __future__ import annotations

import dataclasses
import math
import threading
import time
from typing import Optional, Sequence

from repro.api.types import SearchRequest, SearchResult


@dataclasses.dataclass(frozen=True)
class SessionConfig:
    max_batch: int = 32          # flush when this many requests are pending
    max_delay_s: float = 0.01    # flush when the oldest pending is this old
    auto_flush: bool = True      # admission/poll may trigger flushes
    isolate_failures: bool = True
    # a failed flush bisects the batch so only the offending request's
    # handle fails (poisoned-batch isolation); False restores the legacy
    # all-handles-fail contract
    flush_retry_budget: int = 8
    # max execution attempts one flush may spend isolating bad requests
    # before the unexecuted remainder is failed wholesale


class PendingSearch:
    """Handle for a submitted request; resolves at flush time.

    Safe to wait on from any thread: resolution signals an event, so
    ``result(timeout=...)`` blocks only until the flush that *claimed*
    this handle (possibly on another thread) finishes with it.
    """

    def __init__(self, session: "Session", request: SearchRequest):
        self._session = session
        self.request = request
        self._result: Optional[SearchResult] = None
        self._error: Optional[BaseException] = None
        self._done = False
        self._claimed = False        # a flush owns this handle's batch
        self._event = threading.Event()

    @property
    def done(self) -> bool:
        return self._done

    def _resolve(self, result: SearchResult):
        self._result = result
        self._done = True
        self._event.set()

    def _fail(self, error: BaseException):
        self._error = error
        self._done = True
        self._event.set()

    def result(self, timeout: Optional[float] = None) -> SearchResult:
        """The SearchResult; forces a flush if still pending. Re-raises
        the batch's execution error if its flush failed.

        ``timeout`` (seconds) bounds the wait when *another* thread's
        flush holds this handle's batch — raises ``TimeoutError`` on
        expiry with the handle still in flight (a later call may
        succeed)."""
        if not self._done:
            try:
                self._session.flush()
            except Exception:
                # if the flush failed *this* handle, its _fail below
                # carries the cause; swallow the duplicate here
                if not self._done:
                    raise
        if not self._done:
            if not self._claimed:
                # a flush ran but never touched this handle (e.g.
                # submitted to a different session than the one flushed)
                # — surface a real error instead of tripping a bare assert
                raise RuntimeError(
                    "PendingSearch never resolved: flush() completed "
                    "without executing this handle's request")
            # another thread's flush owns the batch: wait for it
            if not self._event.wait(timeout):
                raise TimeoutError(
                    f"PendingSearch.result timed out after {timeout}s "
                    "with the request still in flight")
        if self._error is not None:
            raise self._error
        if self._result is None:
            raise RuntimeError(
                "PendingSearch never resolved: flush() completed without "
                "executing this handle's request")
        return self._result


class Session:
    """Batched scheduler over an :class:`~repro.api.index.Index`."""

    def __init__(self, index, config: SessionConfig = SessionConfig()):
        self.index = index
        self.config = config
        self._pending: list = []          # (PendingSearch, t_admitted)
        self._lock = threading.Lock()     # guards _pending + counters
        self._exec_lock = threading.Lock()  # serializes engine execution
        self.n_requests = 0
        self.n_batches = 0
        self.n_flushed = 0

    # -- admission -------------------------------------------------------
    def submit(self, request: SearchRequest) -> PendingSearch:
        handle = PendingSearch(self, request)
        with self._lock:
            self._pending.append((handle, time.monotonic()))
            self.n_requests += 1
            should = self.config.auto_flush and self._should_flush()
        if should:
            self.flush()
        return handle

    def submit_many(self, requests: Sequence[SearchRequest]) -> list:
        return [self.submit(r) for r in requests]

    def warmup(self, requests: Sequence[SearchRequest],
               ladder: bool = True,
               rungs: Optional[Sequence] = None) -> None:
        """Pre-compile the search jit caches before serving traffic.

        The engine's pipelined search compiles one artifact per
        (mechanism, pool bucket, GROUP WIDTH) and per power-of-two
        compaction bucket (``search.run_hops``). One pass at the given
        mix only covers the widths that pass happens to form, so with
        ``ladder`` (the default) the warmup *also* groups the requests
        exactly as the engine will and re-runs each group tiled to every
        power-of-two width from ``MIN_COMPACT_BUCKET`` up to the group's
        rounded-up size — the full bucket-jit ladder a production flush
        of any power-of-two width (or any compaction event) can reach.
        The pipelined driver pads every group up to this same ladder
        (``max(MIN_COMPACT_BUCKET, next_pow2)``), so after warmup *no*
        group size triggers a fresh compile — pass a mix whose group
        sizes match production flushes (e.g. a full ``max_batch`` of
        each filter family).

        ``rungs`` warms the serve tier's degrade-ladder config variants
        (default: every non-base rung of ``cost_model.DEGRADE_LADDER``,
        including the approximate-scan path). Every non-approx rung gets
        the same per-group width tiling as the base configs — a rung's
        params are part of the jit key, so a lean flush at a width only
        the full config was warmed at would still stall mid-serve. Pass
        ``()`` to skip. Results are discarded; counters untouched."""
        requests = list(requests)
        if not requests:
            return
        from repro.core import cost_model, search as search_mod
        from repro.core.engine import apply_rung

        idx = self.index
        idx.search_batch(requests, with_metadata=False)
        scfgs = [idx._resolve_scfg(r) for r in requests]
        eng = idx.engine
        cfg = eng.config
        mb = search_mod.MIN_COMPACT_BUCKET

        def ladder_pass(cfgs) -> None:
            """Group exactly as the engine will under ``cfgs`` and run
            each group at every power-of-two width the padded driver
            can compile (``mb`` .. next_pow2(group size))."""
            groups: dict = {}
            for i, r in enumerate(requests):
                sel = idx.compile_filter(r.filter)
                plan = sel.plan(cfg.ql, cfg.cap, cfg.qr)
                route = eng._route(plan, cfgs[i])
                eff = 1 << max(5, math.ceil(
                    math.log2(max(route.effective_l, 1))))
                eff = min(eff, cfgs[i].max_pool)
                groups.setdefault((route.mechanism, eff, cfgs[i]),
                                  []).append(i)
            for members in groups.values():
                n = len(members)
                w = mb
                top = max(w, search_mod._pow2_at_least(n))
                while w <= top:
                    tiled = [members[j % n] for j in range(w)]
                    idx.search_batch([requests[j] for j in tiled],
                                     scfgs=[cfgs[j] for j in tiled],
                                     with_metadata=False)
                    w *= 2

        if ladder:
            ladder_pass(scfgs)
            # sub-min widths pad up to ``mb`` inside the driver but keep
            # their own (globally cached) host-glue shapes — warm each
            # once, against any mix
            for w in range(1, mb):
                idx.search_batch(requests[: min(w, len(requests))],
                                 with_metadata=False)
        if rungs is None:
            rungs = cost_model.DEGRADE_LADDER[1:]
        for rung in rungs:
            rcfgs = [apply_rung(sc, rung) for sc in scfgs]
            if rung.approx:
                idx.approx_scan_batch(requests, scfgs=rcfgs,
                                      with_metadata=False)
            elif ladder:
                ladder_pass(rcfgs)
            else:
                idx.search_batch(requests, scfgs=rcfgs,
                                 with_metadata=False)

    def _should_flush(self) -> bool:
        if len(self._pending) >= self.config.max_batch:
            return True
        if self._pending and (time.monotonic() - self._pending[0][1]
                              >= self.config.max_delay_s):
            return True
        return False

    def poll(self) -> int:
        """Serve-loop tick: flush if the deadline expired. Returns the
        number of requests executed."""
        with self._lock:
            should = self.config.auto_flush and self._should_flush()
        if should:
            return self.flush()
        return 0

    # -- execution -------------------------------------------------------
    def flush(self) -> int:
        """Execute every pending request as one grouped batch.

        With ``isolate_failures`` (the default) an execution error (e.g.
        a malformed filter in the batch) triggers poisoned-batch
        isolation: the batch is bisected and re-executed so only the
        offending request's handle fails — every well-formed request in
        the same flush still resolves, and the flush itself returns
        normally. Re-execution is bounded by ``flush_retry_budget``
        failing attempts; past it the not-yet-isolated remainder fails
        wholesale (no request is ever silently lost either way).

        With ``isolate_failures=False`` the legacy contract holds: every
        handle in the batch fails with the execution error and the error
        propagates to the flush caller.

        Concurrent flushes are safe: each atomically claims the pending
        batch under the lock (late flushes see an empty queue and return
        0), and every claimed handle either resolves or fails — a waiter
        on another thread is always woken."""
        with self._lock:
            if not self._pending:
                return 0
            batch, self._pending = self._pending, []
            for h, _ in batch:
                h._claimed = True
        handles = [h for h, _ in batch]
        try:
            if self.config.isolate_failures:
                budget = [max(1, self.config.flush_retry_budget)]
                self._execute_isolated(handles, budget)
            else:
                requests = [h.request for h in handles]
                try:
                    with self._exec_lock:
                        results = self.index.search_batch(requests)
                except Exception as e:
                    for handle in handles:
                        handle._fail(e)
                    raise
                for handle, result in zip(handles, results):
                    handle._resolve(result)
        finally:
            # no handle may be left claimed-but-unresolved (a waiter
            # would hang): fail any straggler from an unexpected escape
            for h in handles:
                if not h._done:
                    h._fail(RuntimeError(
                        "flush aborted before resolving this handle"))
        with self._lock:
            self.n_batches += 1
            self.n_flushed += len(batch)
        return len(batch)

    def _execute_isolated(self, handles: list, budget: list,
                          scfgs: Optional[list] = None,
                          executor=None) -> None:
        """Execute ``handles`` as one batch, bisecting on failure.

        ``budget`` is the flush's shared mutable count of *failing*
        attempts still allowed: a clean sub-batch costs nothing, so one
        poisoned request in a batch of ``n`` is isolated in
        ``log2(n) + 1`` failures.

        ``scfgs`` (optional, aligned with ``handles``) carries explicit
        per-request configs through the bisection — the serve tier's
        degrade rungs; ``executor`` overrides the execution callable
        (signature ``(requests, scfgs) -> results``, default the index's
        grouped ``search_batch``)."""
        if not handles:
            return
        if executor is None:
            def executor(reqs, cfgs):
                return self.index.search_batch(reqs, scfgs=cfgs)
        try:
            with self._exec_lock:
                results = executor([h.request for h in handles], scfgs)
        except Exception as e:
            budget[0] -= 1
            if len(handles) == 1:
                handles[0]._fail(e)
                return
            if budget[0] <= 0:
                err = RuntimeError(
                    "flush retry budget exhausted isolating a poisoned "
                    f"batch of {len(handles)} requests")
                err.__cause__ = e
                for h in handles:
                    h._fail(err)
                return
            mid = len(handles) // 2
            self._execute_isolated(handles[:mid], budget,
                                   scfgs[:mid] if scfgs else None,
                                   executor)
            self._execute_isolated(handles[mid:], budget,
                                   scfgs[mid:] if scfgs else None,
                                   executor)
            return
        for h, r in zip(handles, results):
            h._resolve(r)

    @property
    def pending(self) -> int:
        return len(self._pending)

    # -- observability ---------------------------------------------------
    def disk_stats(self) -> Optional[dict]:
        """Cumulative disk-tier snapshot (page cache hit/miss/readahead
        counters, measured page latency) when the index serves from the
        disk backend; None on the device backend. See docs/storage.md
        for the counters glossary."""
        ds = getattr(self.index.engine, "disk_store", None)
        return None if ds is None else ds.snapshot()

    # -- context manager -------------------------------------------------
    def __enter__(self) -> "Session":
        return self

    def __exit__(self, *exc) -> None:
        self.flush()
