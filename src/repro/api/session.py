"""``Session`` — incremental request admission with batched execution.

The engine's request path groups a batch by (mechanism, pool bucket,
config) and runs each group as one coalesced device call; a Session
generalizes that batching *across callers*: requests are admitted one at
a time (e.g. by a serving frontend), accumulate in a pending queue, and
flush together when the batch fills, the oldest request exceeds the
flush deadline, or a result is demanded.

Single-threaded by design: deadlines are checked at admission and at
``poll()`` — the serve loop's tick — rather than by a background thread,
so scheduling stays deterministic and test-able.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional, Sequence

from repro.api.types import SearchRequest, SearchResult


@dataclasses.dataclass(frozen=True)
class SessionConfig:
    max_batch: int = 32          # flush when this many requests are pending
    max_delay_s: float = 0.01    # flush when the oldest pending is this old
    auto_flush: bool = True      # admission/poll may trigger flushes
    isolate_failures: bool = True
    # a failed flush bisects the batch so only the offending request's
    # handle fails (poisoned-batch isolation); False restores the legacy
    # all-handles-fail contract
    flush_retry_budget: int = 8
    # max execution attempts one flush may spend isolating bad requests
    # before the unexecuted remainder is failed wholesale


class PendingSearch:
    """Handle for a submitted request; resolves at flush time."""

    def __init__(self, session: "Session", request: SearchRequest):
        self._session = session
        self.request = request
        self._result: Optional[SearchResult] = None
        self._error: Optional[BaseException] = None
        self._done = False

    @property
    def done(self) -> bool:
        return self._done

    def _resolve(self, result: SearchResult):
        self._result = result
        self._done = True

    def _fail(self, error: BaseException):
        self._error = error
        self._done = True

    def result(self) -> SearchResult:
        """The SearchResult; forces a flush if still pending. Re-raises
        the batch's execution error if its flush failed."""
        if not self._done:
            try:
                self._session.flush()
            except Exception:
                # if the flush failed *this* handle, its _fail below
                # carries the cause; swallow the duplicate here
                if not self._done:
                    raise
        if self._error is not None:
            raise self._error
        if self._result is None:
            # a flush ran but never touched this handle (e.g. submitted
            # to a different session than the one flushed) — surface a
            # real error instead of tripping a bare assert
            raise RuntimeError(
                "PendingSearch never resolved: flush() completed without "
                "executing this handle's request")
        return self._result


class Session:
    """Batched scheduler over an :class:`~repro.api.index.Index`."""

    def __init__(self, index, config: SessionConfig = SessionConfig()):
        self.index = index
        self.config = config
        self._pending: list = []          # (PendingSearch, t_admitted)
        self.n_requests = 0
        self.n_batches = 0
        self.n_flushed = 0

    # -- admission -------------------------------------------------------
    def submit(self, request: SearchRequest) -> PendingSearch:
        handle = PendingSearch(self, request)
        self._pending.append((handle, time.monotonic()))
        self.n_requests += 1
        if self.config.auto_flush and self._should_flush():
            self.flush()
        return handle

    def submit_many(self, requests: Sequence[SearchRequest]) -> list:
        return [self.submit(r) for r in requests]

    def warmup(self, requests: Sequence[SearchRequest]) -> None:
        """Run a throwaway batch to populate the search jit caches before
        serving traffic.

        The engine's pipelined search compiles one artifact per
        (mechanism, pool bucket, GROUP WIDTH) and per power-of-two
        compaction bucket (``search.run_hops``); repeat flushes reuse
        every entry — asserted by the compile-count test. Caches are
        keyed by batch width, so warm with request mixes whose *group
        sizes* match production flushes (e.g. a full ``max_batch`` of
        each filter family), not just one of each shape — widths the
        warmup never formed still compile on their first real flush.
        Results are discarded; session counters are untouched."""
        if requests:
            self.index.search_batch(list(requests), with_metadata=False)

    def _should_flush(self) -> bool:
        if len(self._pending) >= self.config.max_batch:
            return True
        if self._pending and (time.monotonic() - self._pending[0][1]
                              >= self.config.max_delay_s):
            return True
        return False

    def poll(self) -> int:
        """Serve-loop tick: flush if the deadline expired. Returns the
        number of requests executed."""
        if self.config.auto_flush and self._should_flush():
            return self.flush()
        return 0

    # -- execution -------------------------------------------------------
    def flush(self) -> int:
        """Execute every pending request as one grouped batch.

        With ``isolate_failures`` (the default) an execution error (e.g.
        a malformed filter in the batch) triggers poisoned-batch
        isolation: the batch is bisected and re-executed so only the
        offending request's handle fails — every well-formed request in
        the same flush still resolves, and the flush itself returns
        normally. Re-execution is bounded by ``flush_retry_budget``
        failing attempts; past it the not-yet-isolated remainder fails
        wholesale (no request is ever silently lost either way).

        With ``isolate_failures=False`` the legacy contract holds: every
        handle in the batch fails with the execution error and the error
        propagates to the flush caller."""
        if not self._pending:
            return 0
        batch, self._pending = self._pending, []
        if self.config.isolate_failures:
            budget = [max(1, self.config.flush_retry_budget)]
            self._execute_isolated([h for h, _ in batch], budget)
        else:
            requests = [h.request for h, _ in batch]
            try:
                results = self.index.search_batch(requests)
            except Exception as e:
                for handle, _ in batch:
                    handle._fail(e)
                raise
            for (handle, _), result in zip(batch, results):
                handle._resolve(result)
        self.n_batches += 1
        self.n_flushed += len(batch)
        return len(batch)

    def _execute_isolated(self, handles: list, budget: list) -> None:
        """Execute ``handles`` as one batch, bisecting on failure.

        ``budget`` is the flush's shared mutable count of *failing*
        attempts still allowed: a clean sub-batch costs nothing, so one
        poisoned request in a batch of ``n`` is isolated in
        ``log2(n) + 1`` failures."""
        if not handles:
            return
        try:
            results = self.index.search_batch([h.request for h in handles])
        except Exception as e:
            budget[0] -= 1
            if len(handles) == 1:
                handles[0]._fail(e)
                return
            if budget[0] <= 0:
                err = RuntimeError(
                    "flush retry budget exhausted isolating a poisoned "
                    f"batch of {len(handles)} requests")
                err.__cause__ = e
                for h in handles:
                    h._fail(err)
                return
            mid = len(handles) // 2
            self._execute_isolated(handles[:mid], budget)
            self._execute_isolated(handles[mid:], budget)
            return
        for h, r in zip(handles, results):
            h._resolve(r)

    @property
    def pending(self) -> int:
        return len(self._pending)

    # -- observability ---------------------------------------------------
    def disk_stats(self) -> Optional[dict]:
        """Cumulative disk-tier snapshot (page cache hit/miss/readahead
        counters, measured page latency) when the index serves from the
        disk backend; None on the device backend. See docs/storage.md
        for the counters glossary."""
        ds = getattr(self.index.engine, "disk_store", None)
        return None if ds is None else ds.snapshot()

    # -- context manager -------------------------------------------------
    def __enter__(self) -> "Session":
        return self

    def __exit__(self, *exc) -> None:
        self.flush()
