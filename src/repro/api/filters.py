"""Declarative filter-expression DSL compiled onto the engine's Selector
algebra (paper §4.1/§4.3 exposed redisvl-style).

Expressions are built from two field handles::

    Tag("topic") == 5                       # categorical equality
    Tag("topic").isin([3, 5, 9])            # membership (OR of equalities)
    Num("price").between(10.0, 90.0)        # numeric range [lo, hi)
    Num("year") >= 2020                     # open-ended ranges

and composed with ``&`` / ``|`` into an AND/OR tree; field names resolve
against the index :class:`~repro.api.schema.Schema` (unknown names raise
:class:`~repro.api.schema.UnknownFieldError` at compile time).
``compile_expr`` normalizes the tree and lowers it onto the built-in
selectors (``LabelAndSelector`` / ``LabelOrSelector`` / ``RangeSelector``
and their combinators) whenever the shape fits the approximate QueryFilter
algebra — so a compiled filter is bit-identical to the hand-built
equivalent. Conjunctions may mix one tag group with ranges over up to
``qr`` distinct numeric fields (same-field ranges intersect into one
interval first); these compile natively onto the device verification path.
Shapes the algebra cannot express (nested AND-of-OR trees, more labels
than the QL query slots, more range fields than the qr predicate slots,
unions of disjoint ranges) fall back to an exact host-evaluated
:class:`~repro.core.selectors.MaskSelector`, which forces the
pre-filtering route and thereby preserves the no-false-negative guarantee
end to end.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import numpy as np

from repro.api.schema import UnknownFieldError
from repro.core.selectors import (AndSelector, LabelAndSelector,
                                  LabelOrSelector, MaskSelector, OrSelector,
                                  RangeSelector, Selector)


# ---------------------------------------------------------------------------
# Expression tree
# ---------------------------------------------------------------------------

class FilterExpr:
    """Base class for filter expression nodes."""

    def __and__(self, other: "FilterExpr") -> "FilterExpr":
        return And.of(self, other)

    def __or__(self, other: "FilterExpr") -> "FilterExpr":
        return Or.of(self, other)


@dataclasses.dataclass(frozen=True)
class TagIs(FilterExpr):
    """Record has tag ``value`` in categorical field ``field``."""
    field: str
    value: object

    def __repr__(self):
        return f"Tag({self.field!r}) == {self.value!r}"


@dataclasses.dataclass(frozen=True)
class NumRange(FilterExpr):
    """Record's numeric field falls in the half-open interval [lo, hi)."""
    field: str
    lo: float
    hi: float

    def __repr__(self):
        return f"Num({self.field!r}).between({self.lo!r}, {self.hi!r})"


def _flatten(cls, children: Sequence[FilterExpr]) -> tuple:
    out: list = []
    for c in children:
        if not isinstance(c, FilterExpr):
            raise TypeError(f"filter operands must be FilterExpr, got {c!r}")
        if isinstance(c, cls):
            out.extend(c.children)
        else:
            out.append(c)
    return tuple(out)


@dataclasses.dataclass(frozen=True)
class And(FilterExpr):
    children: tuple

    @classmethod
    def of(cls, *children: FilterExpr) -> FilterExpr:
        flat = _flatten(cls, children)
        return flat[0] if len(flat) == 1 else cls(flat)

    def __repr__(self):
        return "(" + " & ".join(repr(c) for c in self.children) + ")"


@dataclasses.dataclass(frozen=True)
class Or(FilterExpr):
    children: tuple

    @classmethod
    def of(cls, *children: FilterExpr) -> FilterExpr:
        flat = _flatten(cls, children)
        return flat[0] if len(flat) == 1 else cls(flat)

    def __repr__(self):
        return "(" + " | ".join(repr(c) for c in self.children) + ")"


class Tag:
    """Handle for a categorical metadata field."""

    def __init__(self, field: str):
        self.field = field

    def __eq__(self, value) -> TagIs:                    # type: ignore[override]
        return TagIs(self.field, value)

    def __hash__(self):
        return hash(("Tag", self.field))

    def isin(self, values: Sequence) -> FilterExpr:
        vals = list(values)
        if not vals:
            raise ValueError(f"Tag({self.field!r}).isin() needs ≥1 value")
        return Or.of(*[TagIs(self.field, v) for v in vals])


def _next_up_f32(x: float) -> float:
    """Smallest float32 strictly greater than x.

    Boundary nudges must happen in float32: the stores hold float32
    values and QueryFilter casts bounds to float32, where a float64
    nextafter collapses back onto x and empties the interval."""
    return float(np.nextafter(np.float32(x), np.float32(np.inf)))


class Num:
    """Handle for a numeric metadata field (one per ``Schema.nums`` entry)."""

    def __init__(self, field: str):
        self.field = field

    def between(self, lo: float, hi: float) -> NumRange:
        """Half-open interval [lo, hi) — the engine's native range shape."""
        return NumRange(self.field, float(lo), float(hi))

    def __lt__(self, x: float) -> NumRange:
        return NumRange(self.field, -math.inf, float(x))

    def __le__(self, x: float) -> NumRange:
        return NumRange(self.field, -math.inf, _next_up_f32(x))

    def __ge__(self, x: float) -> NumRange:
        return NumRange(self.field, float(x), math.inf)

    def __gt__(self, x: float) -> NumRange:
        return NumRange(self.field, _next_up_f32(x), math.inf)

    def __eq__(self, x) -> NumRange:                     # type: ignore[override]
        return NumRange(self.field, float(x), _next_up_f32(x))

    def __hash__(self):
        return hash(("Num", self.field))


# ---------------------------------------------------------------------------
# Compiler: expression tree -> Selector
# ---------------------------------------------------------------------------
# The catalog duck type (implemented by api.Index) provides:
#   label_id(field, value) -> int | None
#   schema, label_store, range_store (MultiRangeStore), n_vectors, ql, qr


def _check_fields(expr: FilterExpr, catalog):
    """Compile-time field resolution: every referenced field must exist in
    the index schema (UnknownFieldError — *not* an empty result or a
    device-dispatch failure). Unknown tag *values* are legitimate (they
    match nothing); unknown *fields* are query bugs."""
    schema = catalog.schema
    for node in _walk(expr):
        if isinstance(node, NumRange):
            schema.num_index(node.field)
        elif isinstance(node, TagIs):
            schema.check_tag(node.field)


def _walk(expr: FilterExpr):
    yield expr
    if isinstance(expr, (And, Or)):
        for c in expr.children:
            yield from _walk(c)


def _merge_ranges_and(ranges: Sequence[NumRange]) -> list:
    """Intersect same-field intervals; one NumRange per distinct field,
    in first-appearance order."""
    by_field: dict = {}
    for r in ranges:
        if r.field in by_field:
            prev = by_field[r.field]
            by_field[r.field] = NumRange(r.field, max(prev.lo, r.lo),
                                         min(prev.hi, r.hi))
        else:
            by_field[r.field] = r
    return list(by_field.values())


def _label_selector(labels: Sequence[int], mode: str, catalog):
    if mode == "or" or len(labels) == 1:
        return LabelOrSelector(catalog.label_store, labels)
    return LabelAndSelector(catalog.label_store, labels)


def _range_selector(catalog, rng: NumRange) -> RangeSelector:
    return RangeSelector(catalog.range_store, rng.lo, rng.hi,
                         field=catalog.schema.num_index(rng.field))


def _try_builtin(expr: FilterExpr, catalog) -> Selector | None:
    """Lower onto the built-in selector algebra; None if inexpressible."""
    ql = catalog.ql
    if isinstance(expr, TagIs):
        lab = catalog.label_id(expr.field, expr.value)
        return None if lab is None else \
            LabelOrSelector(catalog.label_store, [lab])
    if isinstance(expr, NumRange):
        return _range_selector(catalog, expr)

    if isinstance(expr, (And, Or)):
        tags = [c for c in expr.children if isinstance(c, TagIs)]
        ranges = [c for c in expr.children if isinstance(c, NumRange)]
        if len(tags) + len(ranges) != len(expr.children):
            return None                        # nested And/Or: inexpressible
        labels = [catalog.label_id(t.field, t.value) for t in tags]

        if isinstance(expr, And):
            if any(l is None for l in labels):
                return None                    # unknown tag: matches nothing
            if len(labels) > ql:
                return None                    # exceeds QL exact-verify slots
            rngs = _merge_ranges_and(ranges)
            if any(r.lo >= r.hi for r in rngs):
                return None                    # empty interval
            if len(rngs) > catalog.qr:
                return None                    # exceeds NR predicate slots
            if labels and not rngs:
                return _label_selector(labels, "and", catalog)
            range_sels = [_range_selector(catalog, r) for r in rngs]
            if not labels:
                return range_sels[0] if len(range_sels) == 1 else \
                    AndSelector(range_sels)
            return AndSelector([_label_selector(labels, "and", catalog)]
                               + range_sels)

        # Or — unknown-tag arms match nothing and drop out of the union
        known = [l for l in labels if l is not None]
        if len(known) > ql:
            return None
        if len(ranges) == 0:
            return None if not known else \
                _label_selector(known, "or", catalog)
        if len(ranges) > 1:
            return None                        # unions of multiple ranges
        if not known:
            return _range_selector(catalog, ranges[0])
        return OrSelector([_label_selector(known, "or", catalog),
                           _range_selector(catalog, ranges[0])])
    return None


def eval_mask(expr: FilterExpr | None, catalog) -> tuple[np.ndarray, int]:
    """Exact host evaluation over the attribute indexes.

    Returns ``(mask (N,) bool, pages)`` with the attribute-index pages a
    pre-filter scan of this tree would read.
    """
    n = catalog.n_vectors
    if expr is None:
        return np.ones(n, bool), 0
    if isinstance(expr, TagIs):
        lab = catalog.label_id(expr.field, expr.value)
        mask = np.zeros(n, bool)
        if lab is None:
            return mask, 0
        mask[catalog.label_store.postings(lab)] = True
        return mask, catalog.label_store.posting_pages(lab)
    if isinstance(expr, NumRange):
        ids, pages = catalog.range_store.scan(
            expr.lo, expr.hi, field=catalog.schema.num_index(expr.field))
        mask = np.zeros(n, bool)
        mask[ids] = True
        return mask, pages
    if isinstance(expr, (And, Or)):
        op = np.logical_and if isinstance(expr, And) else np.logical_or
        mask, pages = eval_mask(expr.children[0], catalog)
        for c in expr.children[1:]:
            m, p = eval_mask(c, catalog)
            mask = op(mask, m)
            pages += p
        return mask, pages
    raise TypeError(f"not a FilterExpr: {expr!r}")


def compile_expr(expr: FilterExpr, catalog) -> Selector:
    """Compile a filter expression into an engine Selector.

    Expressible shapes lower onto the built-in algebra (identical plans to
    hand-built selectors); everything else becomes an exact
    ``MaskSelector`` forced down the pre-filtering route.
    """
    if isinstance(expr, (Tag, Num)):
        raise TypeError(f"{expr!r} is a field handle, not an expression — "
                        "compare it (==, .isin, .between, <, >=, …) first")
    if not isinstance(expr, FilterExpr):
        raise TypeError(f"cannot compile {expr!r}")
    _check_fields(expr, catalog)
    sel = _try_builtin(expr, catalog)
    if sel is not None:
        return sel
    mask, pages = eval_mask(expr, catalog)
    return MaskSelector(np.flatnonzero(mask), catalog.n_vectors, pages)
