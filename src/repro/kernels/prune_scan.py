"""Pallas TPU kernel: RobustPrune domination scan — the Vamana-build hot loop.

One program per candidate row. Inputs arrive pre-sorted by distance to the
insert point (stable sort on host/XLA side), so the kernel walks lanes left
to right: lane i survives iff it was not dominated by an earlier survivor,
and each survivor prunes every lane j with α²·d(i, j) ≤ d(p, j). The scan is
inherently sequential in i but fully vectorized across the C lanes of each
step, so the VPU processes one (1, C) mask row per iteration.

Scalar extraction from the running masks uses a broadcasted-iota compare +
masked sum (TPU has no 1-D iota and no cheap dynamic scalar reads from VMEM
vectors); the pairwise row d(i, ·) is a dynamic row slice of the (C, C)
distance block resident in VMEM.

VMEM per program (C=128): dcc 64 KB + a handful of (1, C) vectors — far
under budget; the grid streams rows.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _prune_scan_kernel(dp_ref, dcc_ref, keep_ref, *, a2: float, r: int):
    dp = dp_ref[...]                                    # (1, C)
    dcc = dcc_ref[...][0]                               # (C, C)
    c = dp.shape[1]
    lane = jax.lax.broadcasted_iota(jnp.int32, (1, c), 1)

    def body(i, state):
        pruned, keep, nk = state
        sel = lane == i                                 # (1, C) one-hot
        dp_i = jnp.sum(jnp.where(sel, dp, 0.0))
        pruned_i = jnp.sum(jnp.where(sel, pruned.astype(jnp.int32), 0))
        act = (pruned_i == 0) & (nk < r) & jnp.isfinite(dp_i)
        row_i = jax.lax.dynamic_slice(dcc, (i, 0), (1, c))   # (1, C)
        newly = act & (a2 * row_i <= dp)
        pruned = pruned | newly | (sel & act)
        keep = keep | (sel & act)
        return (pruned, keep, nk + act.astype(jnp.int32))

    init = (jnp.zeros((1, c), jnp.bool_), jnp.zeros((1, c), jnp.bool_),
            jnp.int32(0))
    _, keep, _ = jax.lax.fori_loop(0, c, body, init)
    keep_ref[...] = keep.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("a2", "r", "interpret"))
def prune_scan(dp_s: jax.Array, dcc_s: jax.Array, a2: float, r: int, *,
               interpret: bool = False) -> jax.Array:
    """Batched domination scan. dp_s (B, C) ascending (+inf pads);
    dcc_s (B, C, C) pairwise distances in the same order. Returns a
    (B, C) bool keep mask (≤ r survivors per row)."""
    b, c = dp_s.shape
    out = pl.pallas_call(
        functools.partial(_prune_scan_kernel, a2=float(a2), r=int(r)),
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, c), lambda i: (i, 0)),
            pl.BlockSpec((1, c, c), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, c), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, c), jnp.int32),
        interpret=interpret,
    )(dp_s.astype(jnp.float32), dcc_s.astype(jnp.float32))
    return out != 0
