"""Jit'd dispatch wrappers: compiled Pallas on TPU, interpret-mode on CPU.

The search engine takes ``distance_fn=ops.adc_distance`` so the hot PQ scan
runs through the Pallas kernel on TPU; on CPU the default stays the fused
XLA reference (interpret-mode Pallas is a correctness tool, not a fast path).
"""
from __future__ import annotations

import functools

import jax

from repro.kernels import approx_probe as _probe
from repro.kernels import hop_fused as _hop
from repro.kernels import l2_rerank as _l2
from repro.kernels import or_scatter as _orsc
from repro.kernels import pq_scan as _pq
from repro.kernels import prune_scan as _prune
from repro.kernels import ref


@functools.cache
def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def pq_scan(codes, table):
    """ADC distances (N, M) x (M, K) -> (N,)."""
    if on_tpu():
        return _pq.pq_scan(codes, table, interpret=False)
    return ref.pq_scan_ref(codes, table)


def pq_scan_interpret(codes, table):
    """Force the Pallas kernel in interpret mode (tests)."""
    return _pq.pq_scan(codes, table, interpret=True)


def hop_fused(codes_slab, blooms, buckets, in_merged, table, scalars,
              or_masks, range_field, bucket_lo, bucket_hi):
    """Fused hop candidate pass (B, C) slab -> (key, ok).

    The speculative in-filtering hot path: PQ ADC distance + bloom/bucket
    approximate membership + invalid-penalty key in one pass (see
    kernels/hop_fused.py)."""
    if on_tpu():
        return _hop.hop_fused(codes_slab, blooms, buckets, in_merged, table,
                              scalars, or_masks, range_field, bucket_lo,
                              bucket_hi, interpret=False)
    return ref.hop_fused_ref(codes_slab, blooms, buckets, in_merged, table,
                             scalars, or_masks, range_field, bucket_lo,
                             bucket_hi)


def hop_fused_interpret(codes_slab, blooms, buckets, in_merged, table,
                        scalars, or_masks, range_field, bucket_lo,
                        bucket_hi):
    """Force the Pallas kernel in interpret mode (tests)."""
    return _hop.hop_fused(codes_slab, blooms, buckets, in_merged, table,
                          scalars, or_masks, range_field, bucket_lo,
                          bucket_hi, interpret=True)


def approx_probe(blooms, buckets, or_masks, params):
    if on_tpu():
        return _probe.approx_probe(blooms, buckets, or_masks, params,
                                   interpret=False)
    return ref.approx_probe_ref(blooms, buckets, or_masks, params)


def approx_probe_interpret(blooms, buckets, or_masks, params):
    return _probe.approx_probe(blooms, buckets, or_masks, params,
                               interpret=True)


def l2_rerank(vecs, query):
    if on_tpu():
        return _l2.l2_rerank(vecs, query, interpret=False)
    return ref.l2_rerank_ref(vecs, query)


def l2_rerank_interpret(vecs, query):
    return _l2.l2_rerank(vecs, query, interpret=True)


def or_scatter(words, slots):
    """Word-packed bitmap OR-scatter (B, NW) x (B, C) -> (B, NW).

    Sets bit ``slots[b, j]`` in the int32 word table; out-of-range slots
    (< 0 or >= NW*32) are dropped — the search loop's "skip" sentinel."""
    if on_tpu():
        return _orsc.or_scatter(words, slots, interpret=False)
    return ref.or_scatter_ref(words, slots)


def or_scatter_interpret(words, slots):
    return _orsc.or_scatter(words, slots, interpret=True)


def prune_scan(dp_s, dcc_s, a2: float, r: int):
    """RobustPrune domination scan (B, C)+(B, C, C) -> (B, C) keep mask."""
    if on_tpu():
        return _prune.prune_scan(dp_s, dcc_s, float(a2), int(r),
                                 interpret=False)
    return ref.prune_scan_ref(dp_s, dcc_s, float(a2), int(r))


def prune_scan_interpret(dp_s, dcc_s, a2: float, r: int):
    return _prune.prune_scan(dp_s, dcc_s, float(a2), int(r), interpret=True)
