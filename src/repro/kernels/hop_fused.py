"""Pallas TPU kernel: fused per-hop candidate pass for filtered search.

One VMEM pass over a whole ``(B, W·C)`` candidate slab computes, per
candidate, everything the hop loop needs before the pool merge:

  * **PQ ADC distance** — ``sum_m table[m, codes[c, m]]`` via the one-hot
    compare + select + lane-reduction trick of ``kernels/pq_scan.py`` (the
    gather rephrased so it vectorizes on the VPU).
  * **approximate membership** — the bloom-word AND/OR probes plus the
    NR-slot bucket-code range test of ``selectors.is_member_approx``. The
    rare-list binary search arrives precomputed as the ``in_merged`` input
    (see ``selectors.merged_membership``): searchsorted does not tile, the
    bitwise half does.
  * **invalid-penalty key** — ``distance + INVALID_PENALTY·(¬ok)``, the
    pool-admission priority of speculative in-filtering.

Per-query parameters (distance table, bloom masks, range slots) index by
the grid's batch coordinate, so one launch serves the whole query batch —
this is what makes the batched hop loop amortize: B queries × W beams ×
(R+R_d) candidates in a single kernel instead of 3 unfused gathers per
query under ``vmap``.

Grid: ``(B, WC_pad // tile_c)``. VMEM per program (tile_c=512, M=16,
K=256, F=4): codes 32 KB + table 16 KB + one-hot temp 512 KB — far under
the ~16 MB v5e budget. The jnp oracle is ``kernels/ref.hop_fused_ref``;
dispatch lives in ``kernels/ops.hop_fused`` (compiled on TPU,
reference on CPU, interpret mode for tests).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.ref import INVALID_PENALTY

TILE_C = 512
_PENALTY_LITERAL = 1e12   # the kernel body's copy: bodies cannot capture
import numpy as _np                                 # traced array constants
assert float(INVALID_PENALTY) == float(_np.float32(_PENALTY_LITERAL))


def _hop_fused_kernel(codes_ref, blooms_ref, buckets_ref, merged_ref,
                      table_ref, scal_ref, om_ref, rf_ref, blo_ref, bhi_ref,
                      key_ref, ok_ref):
    codes = codes_ref[0].astype(jnp.int32)            # (T, M)
    blooms = blooms_ref[0]                            # (T,)
    buckets = buckets_ref[0]                          # (T, F)
    in_merged = merged_ref[0] != 0                    # (T,)
    table = table_ref[0]                              # (M, K)
    scal = scal_ref[0]                                # (4,)
    om = om_ref[0]                                    # (QL,)
    rf, blo, bhi = rf_ref[0], blo_ref[0], bhi_ref[0]  # (NR,)

    t, f = buckets.shape
    m, k = table.shape

    # --- PQ ADC distance: one-hot gather, unrolled over static M ---
    lanes = jax.lax.broadcasted_iota(jnp.int32, (t, k), 1)
    d = jnp.zeros((t,), jnp.float32)
    for sub in range(m):
        onehot = codes[:, sub][:, None] == lanes      # (T, K)
        d = d + jnp.sum(jnp.where(onehot, table[sub, :][None, :], 0.0),
                        axis=1)

    # --- frequent-label Bloom probes ---
    and_mask, label_mode = scal[0], scal[1]
    merged_mode, combine = scal[2], scal[3]
    and_ok = (blooms & and_mask) == and_mask          # (T,)
    hit_any = jnp.zeros((t,), jnp.bool_)
    for j in range(om.shape[0]):                      # QL static: unrolled
        mask = om[j]
        hit_any = hit_any | ((mask != 0) & ((blooms & mask) == mask))
    has_or = jnp.any(om != 0)

    label_or = jnp.where(merged_mode == 1, in_merged | hit_any,    # M_OR
                         jnp.where(has_or, hit_any, False))
    label_and = jnp.where(merged_mode == 2, in_merged & and_ok,    # M_AND
                          and_ok)
    label_ok = jnp.where(label_mode == 1, label_and,               # L_AND
                         jnp.where(label_mode == 2, label_or, True))
    label_present = label_mode != 0

    # --- NR bucket-range slots: one-hot field select, unrolled ---
    fields = jax.lax.broadcasted_iota(jnp.int32, (t, f), 1)
    range_ok = jnp.ones((t,), jnp.bool_)
    range_present = False
    for j in range(rf.shape[0]):                      # NR static: unrolled
        fj = rf[j]
        v = jnp.sum(jnp.where(fields == fj, buckets, 0), axis=1)   # (T,)
        ok_j = (v >= blo[j]) & (v <= bhi[j])
        range_ok = range_ok & jnp.where(fj >= 0, ok_j, True)
        range_present = range_present | (fj >= 0)

    ok_and = (label_ok | ~label_present) & (range_ok | ~range_present)
    ok_or = (label_ok & label_present) | (range_ok & range_present)
    any_present = label_present | range_present
    ok = jnp.where(any_present,
                   jnp.where(combine == 1, ok_or, ok_and), True)   # C_OR

    # _PENALTY_LITERAL (== ref.INVALID_PENALTY, asserted at import):
    # pallas_call kernels cannot capture traced array constants
    key_ref[0] = d + jnp.where(ok, 0.0, _PENALTY_LITERAL).astype(jnp.float32)
    ok_ref[0] = ok.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("interpret", "tile_c"))
def hop_fused(codes_slab: jax.Array, blooms: jax.Array, buckets: jax.Array,
              in_merged: jax.Array, table: jax.Array, scalars: jax.Array,
              or_masks: jax.Array, range_field: jax.Array,
              bucket_lo: jax.Array, bucket_hi: jax.Array, *,
              interpret: bool = False,
              tile_c: int = TILE_C) -> tuple[jax.Array, jax.Array]:
    """Fused hop pass over a (B, C) candidate slab.

    codes_slab (B, C, M) uint8/int32; blooms (B, C) int32; buckets
    (B, C, F) int32; in_merged (B, C) bool; table (B, M, K) float32;
    scalars (B, 4) / or_masks (B, QL) / range_field, bucket_lo, bucket_hi
    (B, NR) int32 — the ``selectors.kernel_filter_params`` layout.
    Returns (key (B, C) float32, ok (B, C) bool).
    """
    b, c, m = codes_slab.shape
    k = table.shape[-1]
    f = buckets.shape[-1]
    tile = min(tile_c, max(128, 1 << max(c - 1, 1).bit_length()))
    c_pad = -(-c // tile) * tile

    def pad(arr, fill=0):
        if arr.shape[1] == c_pad:
            return arr
        widths = [(0, 0), (0, c_pad - c)] + [(0, 0)] * (arr.ndim - 2)
        return jnp.pad(arr, widths, constant_values=fill)

    codes_p = pad(codes_slab.astype(jnp.int32))
    blooms_p = pad(blooms.astype(jnp.int32))
    buckets_p = pad(buckets.astype(jnp.int32))
    merged_p = pad(in_merged.astype(jnp.int32))

    grid = (b, c_pad // tile)
    key, ok = pl.pallas_call(
        _hop_fused_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, tile, m), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, tile), lambda i, j: (i, j)),
            pl.BlockSpec((1, tile, f), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, tile), lambda i, j: (i, j)),
            pl.BlockSpec((1, m, k), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, 4), lambda i, j: (i, 0)),
            pl.BlockSpec((1, or_masks.shape[-1]), lambda i, j: (i, 0)),
            pl.BlockSpec((1, range_field.shape[-1]), lambda i, j: (i, 0)),
            pl.BlockSpec((1, bucket_lo.shape[-1]), lambda i, j: (i, 0)),
            pl.BlockSpec((1, bucket_hi.shape[-1]), lambda i, j: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, tile), lambda i, j: (i, j)),
            pl.BlockSpec((1, tile), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, c_pad), jnp.float32),
            jax.ShapeDtypeStruct((b, c_pad), jnp.int32),
        ],
        interpret=interpret,
    )(codes_p, blooms_p, buckets_p, merged_p, table.astype(jnp.float32),
      scalars.astype(jnp.int32), or_masks.astype(jnp.int32),
      range_field.astype(jnp.int32), bucket_lo.astype(jnp.int32),
      bucket_hi.astype(jnp.int32))
    return key[:, :c], ok[:, :c].astype(jnp.bool_)
