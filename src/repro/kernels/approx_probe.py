"""Pallas TPU kernel: fused ``is_member_approx`` probe (Bloom ∧/∨ bucket).

Fuses the two in-memory probes of the paper's speculative filter —
32-bit Bloom word check and 1-byte range-bucket check — over a tile of
candidate vectors, with the query's masks/bounds passed as a small scalar
parameter block. This is the per-neighbor hot path of speculative
in-filtering (≈ R + R_d evaluations per hop).

Scalar params layout (int32[8], bitwise-compatible with uint32 masks):
  0: and_mask   1: n_or_masks  2: bucket_lo  3: bucket_hi
  4: label_mode (0 none / 1 and / 2 or)      5: range_on
  6: combine    (0 and / 1 or)               7: unused

NOTE: this kernel models the *single-field* probe (one scalar
bucket_lo/bucket_hi pair + range_on flag). The production
``selectors.is_member_approx`` has since moved to a fixed-width vector of
per-field ``(range_field, bucket_lo, bucket_hi)`` predicate slots over
``(N, F)`` bucket codes — wiring this kernel into the search loop would
need its param block widened to the NR-slot layout first. It remains the
micro-benchmark / Pallas-idiom reference for the fused probe shape.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_N = 1024
MAX_OR_MASKS = 8


def _probe_kernel(blooms_ref, buckets_ref, or_masks_ref, params_ref, out_ref):
    blooms = blooms_ref[...]                           # (TN,) int32 bits
    buckets = buckets_ref[...].astype(jnp.int32)       # (TN,)
    or_masks = or_masks_ref[...]                       # (QL,) int32 bits
    prm = params_ref[...]                              # (8,) int32

    and_mask = prm[0]
    and_ok = (blooms & and_mask) == and_mask           # (TN,)

    hit_any = jnp.zeros(blooms.shape, jnp.bool_)
    for j in range(or_masks.shape[0]):                 # QL static: unrolled
        mask = or_masks[j]
        hit = (mask != 0) & ((blooms & mask) == mask)
        hit_any = hit_any | hit

    label_mode = prm[4]
    label_ok = jnp.where(label_mode == 1, and_ok,
                         jnp.where(label_mode == 2, hit_any, True))
    label_present = label_mode != 0

    range_ok = (buckets >= prm[2]) & (buckets <= prm[3])
    range_present = prm[5] == 1

    ok_and = (label_ok | ~label_present) & (range_ok | ~range_present)
    ok_or = (label_ok & label_present) | (range_ok & range_present)
    any_present = label_present | range_present
    out = jnp.where(any_present,
                    jnp.where(prm[6] == 1, ok_or, ok_and), True)
    out_ref[...] = out.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("interpret", "tile_n"))
def approx_probe(blooms: jax.Array, buckets: jax.Array, or_masks: jax.Array,
                 params: jax.Array, *, interpret: bool = False,
                 tile_n: int = TILE_N) -> jax.Array:
    """Fused approx-filter probe over N candidates.

    blooms (N,) uint32|int32; buckets (N,) uint8|int32;
    or_masks (QL<=8,) uint32|int32; params (8,) int32. Returns (N,) bool.
    """
    n = blooms.shape[0]
    n_pad = -(-max(n, 1) // tile_n) * tile_n
    bl = jnp.zeros((n_pad,), jnp.int32).at[:n].set(
        blooms.astype(jnp.uint32).view(jnp.int32) if blooms.dtype == jnp.uint32
        else blooms.astype(jnp.int32))
    bk = jnp.zeros((n_pad,), jnp.int32).at[:n].set(buckets.astype(jnp.int32))
    om = or_masks.astype(jnp.uint32).view(jnp.int32) \
        if or_masks.dtype == jnp.uint32 else or_masks.astype(jnp.int32)

    out = pl.pallas_call(
        _probe_kernel,
        grid=(n_pad // tile_n,),
        in_specs=[
            pl.BlockSpec((tile_n,), lambda i: (i,)),
            pl.BlockSpec((tile_n,), lambda i: (i,)),
            pl.BlockSpec((om.shape[0],), lambda i: (0,)),
            pl.BlockSpec((8,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((tile_n,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n_pad,), jnp.int32),
        interpret=interpret,
    )(bl, bk, om, params.astype(jnp.int32))
    return out[:n].astype(jnp.bool_)
