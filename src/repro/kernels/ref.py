"""Pure-jnp oracles for every Pallas kernel (the correctness references)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def pq_scan_ref(codes: jax.Array, table: jax.Array) -> jax.Array:
    """sum_m table[m, codes[:, m]] — gather formulation."""
    idx = codes.astype(jnp.int32)
    cols = jnp.arange(table.shape[0])[None, :]
    return jnp.sum(table[cols, idx], axis=1).astype(jnp.float32)


def approx_probe_ref(blooms: jax.Array, buckets: jax.Array,
                     or_masks: jax.Array, params: jax.Array) -> jax.Array:
    blooms = blooms.astype(jnp.uint32)
    om = or_masks.astype(jnp.uint32)
    prm = params.astype(jnp.int32)
    and_mask = prm[0].astype(jnp.uint32)
    and_ok = (blooms & and_mask) == and_mask
    hit_any = jnp.any((om[None, :] != 0)
                      & ((blooms[:, None] & om[None, :]) == om[None, :]),
                      axis=1)
    label_mode = prm[4]
    label_ok = jnp.where(label_mode == 1, and_ok,
                         jnp.where(label_mode == 2, hit_any, True))
    label_present = label_mode != 0
    bk = buckets.astype(jnp.int32)
    range_ok = (bk >= prm[2]) & (bk <= prm[3])
    range_present = prm[5] == 1
    ok_and = (label_ok | ~label_present) & (range_ok | ~range_present)
    ok_or = (label_ok & label_present) | (range_ok & range_present)
    any_present = label_present | range_present
    return jnp.where(any_present,
                     jnp.where(prm[6] == 1, ok_or, ok_and), True)


def l2_rerank_ref(vecs: jax.Array, query: jax.Array) -> jax.Array:
    d = vecs.astype(jnp.float32) - query.astype(jnp.float32)[None, :]
    return jnp.sum(d * d, axis=1)
