"""Pure-jnp oracles for every Pallas kernel (the correctness references)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def pq_scan_ref(codes: jax.Array, table: jax.Array) -> jax.Array:
    """sum_m table[m, codes[:, m]] — gather formulation."""
    idx = codes.astype(jnp.int32)
    cols = jnp.arange(table.shape[0])[None, :]
    return jnp.sum(table[cols, idx], axis=1).astype(jnp.float32)


def approx_probe_ref(blooms: jax.Array, buckets: jax.Array,
                     or_masks: jax.Array, params: jax.Array) -> jax.Array:
    blooms = blooms.astype(jnp.uint32)
    om = or_masks.astype(jnp.uint32)
    prm = params.astype(jnp.int32)
    and_mask = prm[0].astype(jnp.uint32)
    and_ok = (blooms & and_mask) == and_mask
    hit_any = jnp.any((om[None, :] != 0)
                      & ((blooms[:, None] & om[None, :]) == om[None, :]),
                      axis=1)
    label_mode = prm[4]
    label_ok = jnp.where(label_mode == 1, and_ok,
                         jnp.where(label_mode == 2, hit_any, True))
    label_present = label_mode != 0
    bk = buckets.astype(jnp.int32)
    range_ok = (bk >= prm[2]) & (bk <= prm[3])
    range_present = prm[5] == 1
    ok_and = (label_ok | ~label_present) & (range_ok | ~range_present)
    ok_or = (label_ok & label_present) | (range_ok & range_present)
    any_present = label_present | range_present
    return jnp.where(any_present,
                     jnp.where(prm[6] == 1, ok_or, ok_and), True)


def l2_rerank_ref(vecs: jax.Array, query: jax.Array) -> jax.Array:
    d = vecs.astype(jnp.float32) - query.astype(jnp.float32)[None, :]
    return jnp.sum(d * d, axis=1)


def prune_scan_ref(dp_s: jax.Array, dcc_s: jax.Array, a2: float,
                   r: int) -> jax.Array:
    """RobustPrune domination scan over distance-sorted candidates.

    dp_s:  (B, C) float32 candidate→insert-point distances, ascending per
           row, +inf right-padding for invalid slots.
    dcc_s: (B, C, C) float32 pairwise candidate distances, both axes in the
           same sorted order.
    Walks each row in sorted order keeping at most ``r`` survivors; keeping
    candidate i prunes every j with a2·d(i, j) <= d(p, j) — the exact update
    of the sequential numpy reference (graph.robust_prune), expressed as a
    masked fori_loop. Returns a (B, C) bool keep mask in sorted space.
    """
    def one(dp, dcc):
        c = dp.shape[0]

        def body(i, st):
            pruned, keep, nk = st
            act = (~pruned[i]) & (nk < r) & jnp.isfinite(dp[i])
            keep = keep.at[i].set(act)
            newly = act & (a2 * dcc[i] <= dp)
            pruned = (pruned | newly).at[i].set(pruned[i] | act)
            return (pruned, keep, nk + act.astype(jnp.int32))

        _, keep, _ = jax.lax.fori_loop(
            0, c, body,
            (jnp.zeros((c,), jnp.bool_), jnp.zeros((c,), jnp.bool_),
             jnp.int32(0)))
        return keep

    return jax.vmap(one)(dp_s, dcc_s)
