"""Pure-jnp oracles for every Pallas kernel (the correctness references)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def pq_scan_ref(codes: jax.Array, table: jax.Array) -> jax.Array:
    """sum_m table[m, codes[:, m]] — gather formulation."""
    idx = codes.astype(jnp.int32)
    cols = jnp.arange(table.shape[0])[None, :]
    return jnp.sum(table[cols, idx], axis=1).astype(jnp.float32)


def approx_probe_ref(blooms: jax.Array, buckets: jax.Array,
                     or_masks: jax.Array, params: jax.Array) -> jax.Array:
    blooms = blooms.astype(jnp.uint32)
    om = or_masks.astype(jnp.uint32)
    prm = params.astype(jnp.int32)
    and_mask = prm[0].astype(jnp.uint32)
    and_ok = (blooms & and_mask) == and_mask
    hit_any = jnp.any((om[None, :] != 0)
                      & ((blooms[:, None] & om[None, :]) == om[None, :]),
                      axis=1)
    label_mode = prm[4]
    label_ok = jnp.where(label_mode == 1, and_ok,
                         jnp.where(label_mode == 2, hit_any, True))
    label_present = label_mode != 0
    bk = buckets.astype(jnp.int32)
    range_ok = (bk >= prm[2]) & (bk <= prm[3])
    range_present = prm[5] == 1
    ok_and = (label_ok | ~label_present) & (range_ok | ~range_present)
    ok_or = (label_ok & label_present) | (range_ok & range_present)
    any_present = label_present | range_present
    return jnp.where(any_present,
                     jnp.where(prm[6] == 1, ok_or, ok_and), True)


# The single source of the invalid-candidate admission penalty;
# core.search imports it, and kernels/hop_fused.py asserts its in-kernel
# literal against it at import time (Pallas bodies cannot capture traced
# constants).
INVALID_PENALTY = jnp.float32(1e12)


def adc_slab_ref(codes_slab: jax.Array, table: jax.Array) -> jax.Array:
    """ADC distances for a pre-gathered code slab.

    codes_slab (..., C, M) uint8/int32; table (..., M, K) float32 ->
    (..., C) float32. Flattened-table gather (one 1-D gather per batch
    row instead of a 4-D take_along_axis) + M-axis reduction —
    bitwise-identical to ``pq.adc_lookup`` (pinned by
    tests/test_kernels.py); the single copy of that invariant, shared by
    ``hop_fused_ref`` and the search loop's post/strict slab pass."""
    m, k = table.shape[-2:]
    c = codes_slab.shape[-2]
    idx = codes_slab.astype(jnp.int32)                     # (..., C, M)
    flat = idx + (jnp.arange(m, dtype=jnp.int32) * k)
    t = jnp.take_along_axis(
        table.reshape(table.shape[:-2] + (m * k,)),
        flat.reshape(flat.shape[:-2] + (c * m,)), axis=-1)
    return jnp.sum(t.reshape(flat.shape), axis=-1).astype(jnp.float32)


def hop_fused_ref(codes_slab: jax.Array, blooms: jax.Array,
                  buckets: jax.Array, in_merged: jax.Array,
                  table: jax.Array, scalars: jax.Array, or_masks: jax.Array,
                  range_field: jax.Array, bucket_lo: jax.Array,
                  bucket_hi: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Fused per-hop candidate pass: PQ ADC distance + approximate
    membership + invalid-penalty key over a pre-gathered candidate slab.

    codes_slab (..., C, M) uint8/int32; blooms (..., C) int32 bit-words;
    buckets (..., C, F) int32; in_merged (..., C) bool (rare-list half,
    precomputed — see selectors.merged_membership); table (..., M, K)
    float32; scalars (..., 4) int32 [and_mask, label_mode, merged_mode,
    combine]; or_masks (..., QL); range_field/bucket_lo/bucket_hi (..., NR)
    (see selectors.kernel_filter_params).

    Returns ``(key, ok)``: key (..., C) = pq_distance + INVALID_PENALTY
    where not ok; ok (..., C) bool — identical to
    ``selectors.is_member_approx`` on the same ids. The PQ sum matches
    ``pq.adc_lookup`` bitwise (same gather + same reduction axis).
    """
    d = adc_slab_ref(codes_slab, table)

    # --- frequent-label Bloom probes ---
    and_mask = scalars[..., 0:1]
    label_mode = scalars[..., 1:2]
    merged_mode = scalars[..., 2:3]
    combine = scalars[..., 3:4]
    and_ok = (blooms & and_mask) == and_mask               # (..., C)
    om = or_masks                                          # (..., QL)
    hit_any = jnp.any((om[..., None, :] != 0)
                      & ((blooms[..., None] & om[..., None, :])
                         == om[..., None, :]), axis=-1)
    has_or = jnp.any(om != 0, axis=-1, keepdims=True)

    label_or = jnp.where(merged_mode == 1, in_merged | hit_any,    # M_OR
                         jnp.where(has_or, hit_any, False))
    label_and = jnp.where(merged_mode == 2, in_merged & and_ok,    # M_AND
                          and_ok)
    label_ok = jnp.where(label_mode == 1, label_and,               # L_AND
                         jnp.where(label_mode == 2, label_or, True))
    label_present = label_mode != 0

    # --- bucket-code range slots (AND over NR predicates) ---
    active = range_field >= 0                              # (..., NR)
    safe_f = jnp.where(active, range_field, 0)
    bsel = jnp.broadcast_to(safe_f[..., None, :],
                            buckets.shape[:-1] + safe_f.shape[-1:])
    v = jnp.take_along_axis(buckets, bsel, axis=-1)        # (..., C, NR)
    rok = (v >= bucket_lo[..., None, :]) & (v <= bucket_hi[..., None, :])
    range_ok = jnp.all(rok | ~active[..., None, :], axis=-1)
    range_present = jnp.any(active, axis=-1, keepdims=True)

    ok_and = (label_ok | ~label_present) & (range_ok | ~range_present)
    ok_or = (label_ok & label_present) | (range_ok & range_present)
    any_present = label_present | range_present
    ok = jnp.where(any_present,
                   jnp.where(combine == 1, ok_or, ok_and), True)   # C_OR
    key = d + jnp.where(ok, jnp.float32(0.0), INVALID_PENALTY)
    return key, ok


def or_scatter_ref(words: jax.Array, slots: jax.Array) -> jax.Array:
    """Row-wise bitmap OR-scatter: set bit ``slots[b, j]`` in the int32
    word table ``words[b, slots[b, j] >> 5]`` for every in-range slot;
    slots < 0 or >= NW*32 are dropped (the caller's "skip" sentinel).

    jnp's only scatter-combiner is add, which corrupts a bitmap when a bit
    is contributed twice or is already set. Exact-OR is recovered by making
    every contribution carry-free first: sort each row's slots (out-of-range
    mapped past the end so they sort last), drop exact duplicates via the
    sorted-neighbor compare, and AND-NOT each bit against the word it
    targets so already-set bits contribute 0. What remains is a sum of
    distinct unset bits — addition IS bitwise OR. Bitwise-identical to the
    Pallas kernel for any input (pinned by tests/test_kernels.py)."""
    b, nw = words.shape
    n_bits = nw * 32
    words = words.astype(jnp.int32)
    s = slots.astype(jnp.int32)
    s = jnp.where((s >= 0) & (s < n_bits), s, n_bits)
    s = jnp.sort(s, axis=1)
    dup = jnp.concatenate(
        [jnp.zeros((b, 1), jnp.bool_), s[:, 1:] == s[:, :-1]], axis=1)
    keep = (s < n_bits) & ~dup
    w = jnp.where(keep, s >> 5, nw)
    bit = jax.lax.shift_left(jnp.int32(1), s & 31)
    cur = jnp.take_along_axis(words, jnp.minimum(w, nw - 1), axis=1)
    add = jnp.where(keep, bit & ~cur, 0)
    rows = jnp.arange(b, dtype=jnp.int32)[:, None]
    return words.at[rows, w].add(add, mode="drop")


def l2_rerank_ref(vecs: jax.Array, query: jax.Array) -> jax.Array:
    d = vecs.astype(jnp.float32) - query.astype(jnp.float32)[None, :]
    return jnp.sum(d * d, axis=1)


def prune_scan_ref(dp_s: jax.Array, dcc_s: jax.Array, a2: float,
                   r: int) -> jax.Array:
    """RobustPrune domination scan over distance-sorted candidates.

    dp_s:  (B, C) float32 candidate→insert-point distances, ascending per
           row, +inf right-padding for invalid slots.
    dcc_s: (B, C, C) float32 pairwise candidate distances, both axes in the
           same sorted order.
    Walks each row in sorted order keeping at most ``r`` survivors; keeping
    candidate i prunes every j with a2·d(i, j) <= d(p, j) — the exact update
    of the sequential numpy reference (graph.robust_prune), expressed as a
    masked fori_loop. Returns a (B, C) bool keep mask in sorted space.
    """
    def one(dp, dcc):
        c = dp.shape[0]

        def body(i, st):
            pruned, keep, nk = st
            act = (~pruned[i]) & (nk < r) & jnp.isfinite(dp[i])
            keep = keep.at[i].set(act)
            newly = act & (a2 * dcc[i] <= dp)
            pruned = (pruned | newly).at[i].set(pruned[i] | act)
            return (pruned, keep, nk + act.astype(jnp.int32))

        _, keep, _ = jax.lax.fori_loop(
            0, c, body,
            (jnp.zeros((c,), jnp.bool_), jnp.zeros((c,), jnp.bool_),
             jnp.int32(0)))
        return keep

    return jax.vmap(one)(dp_s, dcc_s)
