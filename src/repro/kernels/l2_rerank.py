"""Pallas TPU kernel: exact L2 re-rank of fetched records.

Computes squared distances between one query and a tile of full-precision
vectors via the MXU-friendly decomposition |v|² − 2·v·q + |q|²: the dominant
term is a (TILE_B × D) @ (D × 1)… reshaped to a lane-aligned (TILE_B × D) ⊙
broadcast-q reduction, which Mosaic maps onto the VPU/MXU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_B = 256


def _l2_kernel(vecs_ref, q_ref, out_ref):
    vecs = vecs_ref[...]                      # (TB, D)
    q = q_ref[...]                            # (1, D)
    diff_dot = jnp.sum(vecs * q, axis=1)      # (TB,)
    vv = jnp.sum(vecs * vecs, axis=1)
    qq = jnp.sum(q * q)
    out_ref[...] = vv - 2.0 * diff_dot + qq


@functools.partial(jax.jit, static_argnames=("interpret", "tile_b"))
def l2_rerank(vecs: jax.Array, query: jax.Array, *, interpret: bool = False,
              tile_b: int = TILE_B) -> jax.Array:
    """Squared L2 distances. vecs (B, D) f32; query (D,) f32 -> (B,) f32."""
    b, d = vecs.shape
    b_pad = -(-max(b, 1) // tile_b) * tile_b
    vp = jnp.zeros((b_pad, d), vecs.dtype).at[:b].set(vecs)
    out = pl.pallas_call(
        _l2_kernel,
        grid=(b_pad // tile_b,),
        in_specs=[
            pl.BlockSpec((tile_b, d), lambda i: (i, 0)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tile_b,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((b_pad,), jnp.float32),
        interpret=interpret,
    )(vp.astype(jnp.float32), query.astype(jnp.float32)[None, :])
    return out[:b]
