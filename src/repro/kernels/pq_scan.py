"""Pallas TPU kernel: PQ ADC scan — the paper's distance-comparison hot loop.

For each code row, accumulate sum_m table[m, codes[n, m]]. On TPU the gather
is rephrased as a one-hot compare + select + lane reduction, which vectorizes
on the VPU (and the compare against a broadcasted iota avoids 1-D iota
restrictions). The per-query lookup table (M × 256 floats ≈ 32 KB for M=32)
lives wholly in VMEM; code tiles stream through.

Grid: one program per tile of TILE_N code rows.
VMEM per program (TILE_N=512, M=32): codes 64 KB + table 32 KB + one-hot
temp 512 KB — comfortably under the ~16 MB v5e VMEM budget.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_N = 512


def _pq_scan_kernel(codes_ref, table_ref, out_ref):
    codes = codes_ref[...].astype(jnp.int32)          # (TN, M)
    table = table_ref[...]                            # (M, K)
    tn = codes.shape[0]
    m, k = table.shape
    acc = jnp.zeros((tn,), jnp.float32)
    lanes = jax.lax.broadcasted_iota(jnp.int32, (tn, k), 1)
    for sub in range(m):                              # M is static: unrolled
        onehot = codes[:, sub][:, None] == lanes      # (TN, K)
        acc = acc + jnp.sum(
            jnp.where(onehot, table[sub, :][None, :], 0.0), axis=1)
    out_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("interpret", "tile_n"))
def pq_scan(codes: jax.Array, table: jax.Array, *, interpret: bool = False,
            tile_n: int = TILE_N) -> jax.Array:
    """ADC distances for all code rows. codes (N, M) uint8/int32;
    table (M, K) float32 -> (N,) float32."""
    n, m = codes.shape
    k = table.shape[1]
    n_pad = -(-max(n, 1) // tile_n) * tile_n
    codes_p = jnp.zeros((n_pad, m), codes.dtype).at[:n].set(codes)

    out = pl.pallas_call(
        _pq_scan_kernel,
        grid=(n_pad // tile_n,),
        in_specs=[
            pl.BlockSpec((tile_n, m), lambda i: (i, 0)),
            pl.BlockSpec((m, k), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tile_n,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n_pad,), jnp.float32),
        interpret=interpret,
    )(codes_p, table.astype(jnp.float32))
    return out[:n]
