"""Pallas TPU kernel: word-packed OR-scatter — the visited/rare-list setter.

jnp has no OR-scatter, so the byte-per-slot bitmap tables (search visited
set, merged rare-list table) historically stayed bool to keep `.at[].set`
usable. This kernel ORs bit ``1 << (slot & 31)`` into word ``slot >> 5`` of
an int32 word table, letting those tables shrink 32× (8× vs bool bytes on
host, 32× vs the int8 lanes bools occupy on TPU) before they get multiplied
by shard-replicated query state.

Words are int32, not uint32: TPU vector lanes are signed and the rest of the
repo already bitcasts its uint32 bit-words to int32 at the kernel boundary
(see selectors.kernel_view). Shifts are defined modulo the word width, and
``(w >> k) & 1`` extracts bits correctly even for the sign bit, so signed
words are bitwise-equivalent for set/test.

One program per batch row. The C slot lanes are walked with a fori_loop;
the scalar slot is pulled out of the (1, C) vector with the broadcasted-iota
one-hot + masked-sum idiom (same as prune_scan — TPU has no cheap dynamic
scalar reads from VMEM vectors). Each step ORs a one-hot-by-word
contribution row into a (1, NW) accumulator initialized from the input
words, so duplicate slots and already-set bits are naturally idempotent.
Out-of-range slots (< 0 or >= NW*32) contribute nothing — callers encode
"skip this lane" as any such sentinel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _or_scatter_kernel(words_ref, slots_ref, out_ref):
    words = words_ref[...]                              # (1, NW) int32
    slots = slots_ref[...]                              # (1, C) int32
    nw = words.shape[1]
    c = slots.shape[1]
    lane = jax.lax.broadcasted_iota(jnp.int32, (1, c), 1)
    word_ix = jax.lax.broadcasted_iota(jnp.int32, (1, nw), 1)

    def body(j, acc):
        sel = lane == j                                 # (1, C) one-hot
        s = jnp.sum(jnp.where(sel, slots, 0))
        valid = (s >= 0) & (s < nw * 32)
        bit = jnp.where(valid, jax.lax.shift_left(jnp.int32(1), s & 31), 0)
        return acc | jnp.where(word_ix == (s >> 5), bit, 0)

    out_ref[...] = jax.lax.fori_loop(0, c, body, words)


@functools.partial(jax.jit, static_argnames=("interpret",))
def or_scatter(words: jax.Array, slots: jax.Array, *,
               interpret: bool = False) -> jax.Array:
    """Row-wise bitmap OR-scatter. words (B, NW) int32 bit-words; slots
    (B, C) int32 bit indices into [0, NW*32) — out-of-range lanes are
    dropped. Returns words with bit ``slots[b, j]`` set for every in-range
    slot of row b."""
    b, nw = words.shape
    c = slots.shape[1]
    return pl.pallas_call(
        _or_scatter_kernel,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, nw), lambda i: (i, 0)),
            pl.BlockSpec((1, c), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, nw), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, nw), jnp.int32),
        interpret=interpret,
    )(words.astype(jnp.int32), slots.astype(jnp.int32))
