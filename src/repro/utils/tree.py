"""Small pytree helpers used across the framework."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def tree_bytes(tree) -> int:
    """Total bytes of all array leaves in a pytree."""
    leaves = jax.tree_util.tree_leaves(tree)
    total = 0
    for leaf in leaves:
        if hasattr(leaf, "dtype") and hasattr(leaf, "shape"):
            total += int(np.prod(leaf.shape)) * jnp.dtype(leaf.dtype).itemsize
    return total


def tree_cast(tree, dtype):
    """Cast all inexact (float) leaves of a pytree to ``dtype``."""
    def _cast(x):
        if hasattr(x, "dtype") and jnp.issubdtype(x.dtype, jnp.inexact):
            return x.astype(dtype)
        return x
    return jax.tree_util.tree_map(_cast, tree)


def tree_zeros_like(tree):
    return jax.tree_util.tree_map(jnp.zeros_like, tree)


def tree_count_params(tree) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(tree)
               if hasattr(x, "shape"))


@jax.jit
def tree_take_rows(tree, idx):
    """Gather rows ``idx`` along the leading axis of every array leaf.

    The batch-compaction primitive: every leaf must carry the batch as
    its leading dimension (e.g. search ``HopState``/``QueryCtx``,
    batched ``QueryFilter``). ``idx`` may repeat rows (padding)."""
    return jax.tree_util.tree_map(lambda a: jnp.asarray(a)[idx], tree)


@jax.jit
def tree_put_rows(full, part, idx):
    """Scatter ``part``'s rows into ``full`` at leading-axis ``idx``.

    Out-of-range indices are dropped — the compaction driver points pad
    rows past the batch so duplicated padding never overwrites a real
    row."""
    return jax.tree_util.tree_map(
        lambda f, p: f.at[idx].set(p, mode="drop"), full, part)
