from repro.utils.tree import tree_bytes, tree_cast, tree_zeros_like
