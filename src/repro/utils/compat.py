"""Version-compatibility shims for the pinned container toolchain.

The repo targets the modern jax surface (``jax.shard_map``,
``jax.sharding.AxisType``); the container may pin an older release where
those live under ``jax.experimental`` or don't exist. Centralising the
fallbacks here keeps every call site on one spelling.
"""
from __future__ import annotations


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool | None = None):
    """``jax.shard_map`` with fallback to ``jax.experimental.shard_map``.

    ``check_vma`` maps onto the old API's ``check_rep`` flag.
    """
    try:
        from jax import shard_map as _sm
        kw = {} if check_vma is None else {"check_vma": check_vma}
    except ImportError:
        from jax.experimental.shard_map import shard_map as _sm
        kw = {} if check_vma is None else {"check_rep": check_vma}
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)


def axis_size(axis_name):
    """``jax.lax.axis_size`` with a psum(1) fallback for older jax."""
    import jax
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)
