"""DiskRecordStore — the disk tier behind the search loop's fetch hook.

Tiers (docs/storage.md):

* **device**: PQ codes, bloom/bucket words (``InMemory``) and the search
  state — everything the hop loop touches per candidate *before* paying
  a page read;
* **host**: the page cache (``cache.PageCache``) + attribute summaries
  (label postings, sorted range indexes);
* **disk**: page-aligned record slabs (``slab.py``), read with
  ``os.pread`` and timed per run — the samples feed
  ``IOModel.calibrate_from_samples``.

The search loop never sees this class directly: it calls a *fetch
callable* (:attr:`DiskRecordStore.fetch_callable`) whose ``wants_ctx``
attribute opts it into the extended fetch protocol of ``core/search.py``
— per-row hop counters (for fault draws), liveness (dead rows skip
I/O), and, on strict-mode attribute probes, a **bloom/bucket gate
computed on the device tier before any page is read**: a candidate whose
approximate membership is already False returns poisoned attributes
(labels −1, values NaN) without touching disk. The gate is a
no-false-negative superset, so exact verification would have rejected
the row anyway — results stay bit-identical to the all-resident backend
while ``gated_skips / attr_probes`` measures the paper's saved I/O.

Fault routing: when a :class:`~repro.core.faults.FaultPlan` is armed,
frontier reads draw the *same* stateless (record id, hop, attempt)
hashes as the jitted retry→hedge→degrade ladder (``read_attempt_bad_np``
is the bit-identical NumPy twin), so a drawn failure here raises a real
``InjectedReadError`` / CRC mismatch, the retry genuinely re-reads the
pages (cache invalidated first), and a row that exhausts the ladder
returns zeros exactly where the device ladder substitutes its ADC
fallback — degraded rows never have their disk bytes consumed.
"""
from __future__ import annotations

import dataclasses
import functools
import os
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import faults as faults_mod
from repro.core.faults import FaultPlan
from repro.core.records import RecordStore
from repro.storage import slab as slab_mod
from repro.storage.cache import PageCache
from repro.storage.slab import (InjectedReadError, SlabChecksumError,
                                SlabLayout, SLAB_FILE, read_meta)

_MAX_SAMPLES = 4096


@dataclasses.dataclass(frozen=True)
class StorageConfig:
    """Knobs for the disk tier (facade: ``Index.build(store="disk")``)."""
    cache_pages: int = 4096            # page-cache capacity (4 KB frames)
    readahead_per_record: int = 4      # neighbor slabs prefetched per
                                       # fetched record, × (depth − 1)
    readahead_batch_cap: int = 64      # max read-ahead pages per fetch call
    device_budget_bytes: Optional[int] = None
                                       # declared device-resident budget for
                                       # record data; None = unchecked


class _Counters:
    FIELDS = ("pages_read", "preads", "records_fetched", "attr_probes",
              "attr_reads", "gated_skips", "readahead_pages", "faults",
              "retries", "degraded")

    def __init__(self):
        for f in self.FIELDS:
            setattr(self, f, 0)

    def as_dict(self) -> dict:
        return {f: getattr(self, f) for f in self.FIELDS}


class _DiskFetch:
    """The jit-side fetch callable: hashable, stable per store instance
    (it is a static jit argument), marked ``wants_ctx`` so the hop loop
    threads hops/liveness/gate context through."""
    wants_ctx = True

    def __init__(self, ds: "DiskRecordStore"):
        self._ds = ds

    def __call__(self, store: RecordStore, ids: jax.Array, *, hops=None,
                 live=None, dense: bool = True, need=None, gate=None,
                 attrs_only: bool = False):
        from jax.experimental import io_callback
        ds = self._ds
        lo = ds.layout
        n = int(ids.shape[0])
        if attrs_only:
            shapes = {
                "rec_labels": jax.ShapeDtypeStruct((n, lo.max_labels),
                                                   jnp.int32),
                "rec_values": jax.ShapeDtypeStruct((n, lo.n_fields),
                                                   jnp.float32),
            }
            return io_callback(ds._cb_attrs, shapes, ids, need, gate,
                               ordered=False)
        shapes = {
            "vectors": jax.ShapeDtypeStruct((n, lo.dim), jnp.float32),
            "neighbors": jax.ShapeDtypeStruct((n, lo.r), jnp.int32),
            "dense_neighbors": jax.ShapeDtypeStruct((n, lo.r_dense),
                                                    jnp.int32),
            "rec_labels": jax.ShapeDtypeStruct((n, lo.max_labels),
                                               jnp.int32),
            "rec_values": jax.ShapeDtypeStruct((n, lo.n_fields),
                                               jnp.float32),
            "cand_first": jax.ShapeDtypeStruct((n, lo.r + lo.r_dense),
                                               jnp.bool_),
        }
        if hops is None:
            hops = jnp.zeros(ids.shape, jnp.int32)
        if live is None:
            live = jnp.ones(ids.shape, jnp.bool_)
        cb = functools.partial(ds._cb_fetch, bool(dense))
        return io_callback(cb, shapes, ids, hops, live, ordered=False)


class DiskRecordStore:
    """Slab-file record store with a clock page cache and measured I/O."""

    def __init__(self, path: str, config: StorageConfig = StorageConfig()):
        self.path = path
        self.config = config
        meta = read_meta(path)
        self.meta = meta
        self.layout: SlabLayout = SlabLayout.from_json(meta["layout"])
        self.n = int(meta["n"])
        self.pages_std = int(meta["pages_std"])
        self.pages_dense = int(meta["pages_dense"])
        self._fd = os.open(os.path.join(path, SLAB_FILE), os.O_RDONLY)
        self.cache = PageCache(config.cache_pages)
        self.counters = _Counters()
        self.samples: list = []        # {"pages", "us", "kind"} measurements
        self.fault_plan: FaultPlan | None = None
        self.prefetch_depth: int = 2
        self.fetch_callable = _DiskFetch(self)

    # -- lifecycle -------------------------------------------------------
    @classmethod
    def create(cls, path: str, vectors, neighbors, dense_neighbors,
               rec_labels, rec_values, cand_first, pages_std: int,
               pages_dense: int,
               config: StorageConfig = StorageConfig()) -> "DiskRecordStore":
        slab_mod.write_slab_file(
            path, np.asarray(vectors, np.float32),
            np.asarray(neighbors, np.int32),
            np.asarray(dense_neighbors, np.int32),
            np.asarray(rec_labels, np.int32),
            np.asarray(rec_values, np.float32),
            np.asarray(cand_first, bool), pages_std, pages_dense)
        return cls(path, config)

    @classmethod
    def from_record_store(cls, path: str, store: RecordStore,
                          n: int | None = None,
                          config: StorageConfig = StorageConfig()
                          ) -> "DiskRecordStore":
        """Spill an in-memory :class:`RecordStore` to slabs (rows may be
        capacity-padded; ``n`` trims to the live prefix)."""
        n = store.n if n is None else n
        cf = store.cand_first
        if cf is None:
            from repro.core.records import candidate_first_mask
            cf = candidate_first_mask(np.asarray(store.neighbors)[:n],
                                      np.asarray(store.dense_neighbors)[:n])
        return cls.create(
            path, np.asarray(store.vectors)[:n],
            np.asarray(store.neighbors)[:n],
            np.asarray(store.dense_neighbors)[:n],
            np.asarray(store.rec_labels)[:n],
            np.asarray(store.rec_values)[:n], np.asarray(cf)[:n],
            store.pages_std, store.pages_dense, config)

    def close(self):
        if self._fd is not None:
            os.close(self._fd)
            self._fd = None

    def __del__(self):                          # pragma: no cover
        try:
            self.close()
        except Exception:
            pass

    # -- device-tier stub ------------------------------------------------
    def stub_store(self) -> RecordStore:
        """A 1-row :class:`RecordStore` carrying only shapes and the
        modeled page counts — the device tier holds no record data; every
        record byte the search consumes flows through the fetch callable."""
        lo = self.layout
        return RecordStore(
            vectors=jnp.zeros((1, lo.dim), jnp.float32),
            neighbors=jnp.full((1, lo.r), -1, jnp.int32),
            dense_neighbors=jnp.full((1, lo.r_dense), -1, jnp.int32),
            rec_labels=jnp.full((1, lo.max_labels), -1, jnp.int32),
            rec_values=jnp.zeros((1, lo.n_fields), jnp.float32),
            pages_std=self.pages_std, pages_dense=self.pages_dense,
            cand_first=jnp.zeros((1, lo.r + lo.r_dense), jnp.bool_))

    @property
    def file_bytes(self) -> int:
        return int(self.meta["file_bytes"])

    def stub_bytes(self) -> int:
        """Device-resident record bytes under the disk backend (the stub)."""
        s = self.stub_store()
        return sum(int(np.asarray(a).nbytes) for a in
                   (s.vectors, s.neighbors, s.dense_neighbors, s.rec_labels,
                    s.rec_values, s.cand_first))

    # -- page I/O --------------------------------------------------------
    def _read_run(self, first_pid: int, n_pages: int, readahead: bool,
                  record_sample: bool = True) -> bytes:
        pb = self.layout.page_bytes
        t0 = time.perf_counter()
        data = os.pread(self._fd, n_pages * pb, first_pid * pb)
        us = (time.perf_counter() - t0) * 1e6
        self.counters.preads += 1
        self.counters.pages_read += n_pages
        if record_sample and len(self.samples) < _MAX_SAMPLES:
            self.samples.append({"pages": n_pages, "us": us,
                                 "kind": "serial"})
        if len(data) != n_pages * pb:
            raise IOError(f"short read at page {first_pid}")
        for i in range(n_pages):
            self.cache.put(first_pid + i, data[i * pb:(i + 1) * pb],
                           readahead=readahead)
        return data

    def _get_pages(self, pids: list, readahead: bool = False) -> dict:
        """pid → page bytes, filling misses with contiguous pread runs."""
        out, missing = {}, []
        for pid in pids:
            hit = self.cache.get(pid)
            if hit is None:
                missing.append(pid)
            else:
                out[pid] = hit
        missing.sort()
        i = 0
        while i < len(missing):
            j = i
            while j + 1 < len(missing) and missing[j + 1] == missing[j] + 1:
                j += 1
            run = self._read_run(missing[i], j - i + 1, readahead)
            pb = self.layout.page_bytes
            for k, pid in enumerate(missing[i:j + 1]):
                out[pid] = run[k * pb:(k + 1) * pb]
            i = j + 1
        return out

    def _slab_page_ids(self, rid: int, dense: bool) -> list:
        lo = self.layout
        base = rid * lo.slab_pages
        n = lo.slab_pages if (dense and lo.dense_pages) else lo.std_pages
        return [base + i for i in range(n)]

    def _read_record(self, rid: int, dense: bool,
                     corrupt: bool = False) -> dict:
        """One record through the cache; CRC-verified decode. ``corrupt``
        flips a byte post-read (in-flight corruption) so the checksum
        path genuinely fires."""
        lo = self.layout
        pids = self._slab_page_ids(rid, dense)
        pages = self._get_pages(pids)
        std = b"".join(pages[p] for p in pids[:lo.std_pages])
        if corrupt:
            std = bytes([std[0] ^ 0xFF]) + std[1:]
        rec = slab_mod.decode_std(lo, std)
        if dense and lo.dense_pages:
            dblk = b"".join(pages[p] for p in pids[lo.std_pages:])
            rec["dense_neighbors"] = slab_mod.decode_dense(lo, dblk)
        else:
            rec["dense_neighbors"] = np.full(lo.r_dense, -1, np.int32)
        return rec

    # -- fetch (frontier records) ---------------------------------------
    def fetch(self, ids: np.ndarray, hops: np.ndarray | None = None,
              live: np.ndarray | None = None, dense: bool = True,
              track: bool = True) -> dict:
        """Batch record fetch with the fault ladder and read-ahead.

        Dead rows (``live`` False) are skipped — the hop loop fully masks
        them downstream, so zeros are never consumed. Returns a dict of
        np arrays matching ``search.local_fetch``'s contract.
        """
        ids = np.asarray(ids, np.int64).reshape(-1)
        n = ids.size
        lo = self.layout
        out = {
            "vectors": np.zeros((n, lo.dim), np.float32),
            "neighbors": np.full((n, lo.r), -1, np.int32),
            "dense_neighbors": np.full((n, lo.r_dense), -1, np.int32),
            "rec_labels": np.full((n, lo.max_labels), -1, np.int32),
            "rec_values": np.zeros((n, lo.n_fields), np.float32),
            "cand_first": np.zeros((n, lo.r + lo.r_dense), bool),
        }
        live = np.ones(n, bool) if live is None else \
            np.asarray(live, bool).reshape(-1)
        plan = self.fault_plan
        faulted = (plan is not None and plan.reads_faulty
                   and hops is not None)
        if faulted:
            hops = np.asarray(hops, np.int64).reshape(-1)
            fail, corrupt = _attempt_draws(ids, hops, plan)
        pages_before = self.counters.pages_read
        t0 = time.perf_counter()
        n_live = 0
        for i in range(n):
            if not live[i]:
                continue
            n_live += 1
            rid = int(ids[i])
            rec = None
            if not faulted:
                rec = self._read_record(rid, dense)
            else:
                for a in range(plan.attempts):
                    if a > 0:
                        self.counters.retries += 1
                        self.cache.invalidate(self._slab_page_ids(rid,
                                                                  dense))
                    try:
                        if fail[a, i]:
                            # the read was issued and the pages transferred
                            # before the device reported failure — charge
                            # them, then walk the ladder
                            self._read_record(rid, dense)
                            raise InjectedReadError(
                                f"injected read failure: record {rid}")
                        rec = self._read_record(rid, dense,
                                                corrupt=bool(corrupt[a, i]))
                        break
                    except (InjectedReadError, SlabChecksumError):
                        self.counters.faults += 1
                        self.cache.invalidate(self._slab_page_ids(rid,
                                                                  dense))
                        rec = None
                if rec is None:
                    # ladder exhausted: the device ladder substitutes ADC
                    # distance/approx membership and skips expansion for
                    # this row, so these zeros are never consumed
                    self.counters.degraded += 1
                    continue
            out["vectors"][i] = rec["vector"]
            out["neighbors"][i] = rec["neighbors"]
            out["dense_neighbors"][i] = rec["dense_neighbors"]
            out["rec_labels"][i] = rec["rec_labels"]
            out["rec_values"][i] = rec["rec_values"]
            out["cand_first"][i] = rec["cand_first"]
        if track:
            self.counters.records_fetched += n_live
            batch_pages = self.counters.pages_read - pages_before
            if n_live > 1 and batch_pages > 0 and \
                    len(self.samples) < _MAX_SAMPLES:
                self.samples.append(
                    {"pages": batch_pages,
                     "us": (time.perf_counter() - t0) * 1e6,
                     "kind": "batch"})
            if self.prefetch_depth >= 2:
                self._readahead(out["neighbors"], live, dense)
        return out

    def _readahead(self, neighbors: np.ndarray, live: np.ndarray,
                   dense: bool):
        """Real read-ahead driven by ``prefetch_depth``: warm the cache
        with the just-fetched records' nearest out-neighbors — the ids
        most likely to be the next frontier. Depth scales the per-record
        window; correctness is cache-transparent either way."""
        cfg = self.config
        per = cfg.readahead_per_record * (self.prefetch_depth - 1)
        if per <= 0:
            return
        budget = cfg.readahead_batch_cap
        for i in range(neighbors.shape[0]):
            if budget <= 0:
                break
            if not live[i]:
                continue
            taken = 0
            for nid in neighbors[i]:
                if taken >= per or budget <= 0:
                    break
                if nid < 0:
                    continue
                pids = [p for p in self._slab_page_ids(int(nid), dense)
                        if not self.cache.contains(p)]
                if not pids:
                    continue
                before = self.counters.pages_read
                self._get_pages(pids, readahead=True)
                got = self.counters.pages_read - before
                self.counters.readahead_pages += got
                budget -= got
                taken += 1

    # -- attribute probes (strict in-filtering) --------------------------
    def read_attrs(self, ids: np.ndarray, need: np.ndarray,
                   gate: np.ndarray) -> dict:
        """Bloom-gated attribute page reads.

        ``need`` marks rows the strict hop actually verifies; ``gate`` is
        the device-tier approximate membership computed *before* this
        call. A needed row whose gate is False skips its page read and
        returns poisoned attributes (labels −1, values NaN) — exact
        verification would reject it anyway (no-false-negative superset),
        so results are bit-identical while the page read is saved.
        """
        ids = np.asarray(ids, np.int64).reshape(-1)
        need = np.asarray(need, bool).reshape(-1)
        gate = np.asarray(gate, bool).reshape(-1)
        n = ids.size
        lo = self.layout
        labels = np.full((n, lo.max_labels), -1, np.int32)
        values = np.full((n, lo.n_fields), np.nan, np.float32)
        self.counters.attr_probes += int(need.sum())
        self.counters.gated_skips += int((need & ~gate).sum())
        for i in np.nonzero(need & gate)[0]:
            rid = int(ids[i])
            pid = rid * lo.slab_pages + lo.attr_page
            page = self._get_pages([pid])[pid]
            attrs = slab_mod.decode_attrs(lo, page)
            labels[i] = attrs["rec_labels"]
            values[i] = attrs["rec_values"]
            self.counters.attr_reads += 1
        return {"rec_labels": labels, "rec_values": values}

    # -- io_callback endpoints ------------------------------------------
    def _cb_fetch(self, dense: bool, ids, hops, live) -> dict:
        return self.fetch(np.asarray(ids), np.asarray(hops),
                          np.asarray(live), dense=dense)

    def _cb_attrs(self, ids, need, gate) -> dict:
        return self.read_attrs(np.asarray(ids), np.asarray(need),
                               np.asarray(gate))

    # -- host-side readers (prefilter re-rank, ground truth) -------------
    def fetch_host(self, ids: np.ndarray, track: bool = True) -> dict:
        """Plain std-block fetch for host-driven paths (no faults)."""
        return self.fetch(ids, hops=None, live=None, dense=False,
                          track=track)

    def read_vectors(self, ids: np.ndarray, track: bool = False
                     ) -> np.ndarray:
        return self.fetch(ids, dense=False, track=track)["vectors"]

    def scan_records(self, start: int = 0, stop: int | None = None) -> dict:
        """Sequential full scan for evaluation paths (ground truth): reads
        std blocks straight off the file, bypassing cache and counters so
        an offline scan doesn't evict the serving working set."""
        stop = self.n if stop is None else min(stop, self.n)
        lo = self.layout
        m = max(0, stop - start)
        out = {"vectors": np.zeros((m, lo.dim), np.float32),
               "rec_labels": np.full((m, lo.max_labels), -1, np.int32),
               "rec_values": np.zeros((m, lo.n_fields), np.float32)}
        sb = lo.slab_pages * lo.page_bytes
        for i in range(m):
            blk = os.pread(self._fd, lo.std_bytes, (start + i) * sb)
            rec = slab_mod.decode_std(lo, blk)
            out["vectors"][i] = rec["vector"]
            out["rec_labels"][i] = rec["rec_labels"]
            out["rec_values"][i] = rec["rec_values"]
        return out

    # -- observability ---------------------------------------------------
    def snapshot(self) -> dict:
        c = self.counters.as_dict()
        c.update(self.cache.counters())
        tot = c["hits"] + c["misses"]
        c["hit_rate"] = c["hits"] / tot if tot else 0.0
        per_page = sorted(s["us"] / s["pages"] for s in self.samples
                          if s["kind"] == "serial")
        if per_page:
            c["p50_page_us"] = per_page[len(per_page) // 2]
            c["p95_page_us"] = per_page[min(len(per_page) - 1,
                                            int(len(per_page) * 0.95))]
        else:
            c["p50_page_us"] = c["p95_page_us"] = 0.0
        c["n_samples"] = len(self.samples)
        return c

    @staticmethod
    def delta(before: dict, after: dict) -> dict:
        """Counter delta between two snapshots (rates recomputed)."""
        keys = _Counters.FIELDS + ("hits", "misses", "evictions",
                                   "readahead_hits")
        d = {k: after.get(k, 0) - before.get(k, 0) for k in keys}
        tot = d["hits"] + d["misses"]
        d["hit_rate"] = d["hits"] / tot if tot else 0.0
        d["p50_page_us"] = after.get("p50_page_us", 0.0)
        return d

    def reset_counters(self):
        self.counters = _Counters()
        self.cache.hits = self.cache.misses = 0
        self.cache.evictions = self.cache.readahead_hits = 0
        self.samples = []


def _attempt_draws(ids: np.ndarray, hops: np.ndarray,
                   plan: FaultPlan) -> tuple[np.ndarray, np.ndarray]:
    """(attempts, n) bool draws — fail / corrupt — via the NumPy twin of
    the device ladder's stateless hash, so the host read path and the
    jitted counter/degrade logic see the same fault pattern."""
    fail = np.stack([faults_mod.read_fail_np(ids, hops, a, plan)
                     for a in range(plan.attempts)])
    corrupt = np.stack([faults_mod.read_corrupt_np(ids, hops, a, plan)
                        for a in range(plan.attempts)])
    return fail, corrupt
