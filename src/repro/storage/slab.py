"""Page-aligned slab files: the real on-disk record tier.

One record occupies one *slab* — a fixed run of 4 KB pages whose layout
mirrors the modeled record of ``core/records.py`` (paper §4.1):

    std block  (pages [0, std_pages))       dense block (pages [std_pages, ..))
    ┌──────────┬───────────┬─── slack ──┬──────┐ ┌──────────────────┬───────┐
    │ vector   │ neighbors │            │ tail │ │ dense neighbors  │ crc_d │
    └──────────┴───────────┴────────────┴──────┘ └──────────────────┴───────┘
                              tail = labels | values | cand_first bits
                                     | crc_std | crc_tail

Attributes ride in the **final-page slack of the std block**, so exact
verification costs no extra page beyond the record fetch, and a
strict-mode attribute probe touches exactly one page (the std block's
last). A standard fetch reads the std block; a densified fetch reads the
whole slab; both end on a CRC32 check per region, which is what turns an
injected bit-flip into a *detected* checksum failure that re-enters the
retry ladder (docs/robustness.md).

The physical page counts here (``std_pages`` / ``slab_pages``) may differ
by ±1 from the modeled ``RecordStore.pages_std/pages_dense`` (the model
packs count-prefixed fields contiguously; the file aligns the dense block
to a page boundary). Search counters keep the modeled accounting — that
is what bit-identity with the in-memory backend requires — while the disk
tier reports its own *measured* page reads alongside.
"""
from __future__ import annotations

import json
import math
import os
import zlib

import numpy as np

from repro.core.io_sim import PAGE_BYTES

SLAB_FILE = "records.slab"
META_FILE = "slab_meta.json"
_FORMAT = 1


class SlabChecksumError(IOError):
    """A slab region failed its CRC32 — corrupted read."""


class InjectedReadError(IOError):
    """A fault-plan draw failed this read attempt before completion."""


class SlabLayout:
    """Byte/page geometry of one slab, derived from the field widths."""

    def __init__(self, dim: int, r: int, r_dense: int, max_labels: int,
                 n_fields: int, page_bytes: int = PAGE_BYTES):
        self.dim, self.r, self.r_dense = dim, r, r_dense
        self.max_labels, self.n_fields = max_labels, n_fields
        self.page_bytes = page_bytes
        self.vec_bytes = dim * 4
        self.nbr_bytes = r * 4
        self.cf_bytes = math.ceil((r + r_dense) / 8)
        # tail: labels | values | cand_first bits | crc_std | crc_tail
        self.tail_bytes = (max_labels * 4 + n_fields * 4 + self.cf_bytes
                           + 4 + 4)
        assert self.tail_bytes <= page_bytes, \
            "attribute tail must fit one page (final-page slack layout)"
        head = self.vec_bytes + self.nbr_bytes
        self.std_pages = max(1, math.ceil((head + self.tail_bytes)
                                          / page_bytes))
        self.std_bytes = self.std_pages * page_bytes
        self.tail_off = self.std_bytes - self.tail_bytes
        # dense block: ids + trailing crc, page-aligned after the std block
        self.dense_bytes_payload = r_dense * 4 + 4
        self.dense_pages = (math.ceil(self.dense_bytes_payload / page_bytes)
                            if r_dense > 0 else 0)
        self.slab_pages = self.std_pages + self.dense_pages
        self.slab_bytes = self.slab_pages * page_bytes
        self.attr_page = self.std_pages - 1    # the one page a probe reads

    def to_json(self) -> dict:
        return {"dim": self.dim, "r": self.r, "r_dense": self.r_dense,
                "max_labels": self.max_labels, "n_fields": self.n_fields,
                "page_bytes": self.page_bytes}

    @classmethod
    def from_json(cls, d: dict) -> "SlabLayout":
        return cls(d["dim"], d["r"], d["r_dense"], d["max_labels"],
                   d["n_fields"], d.get("page_bytes", PAGE_BYTES))


def _pack_bits(mask: np.ndarray, nbytes: int) -> bytes:
    bits = np.packbits(mask.astype(np.uint8), bitorder="little")
    out = np.zeros(nbytes, np.uint8)
    out[:bits.size] = bits
    return out.tobytes()


def _unpack_bits(raw: bytes, n: int) -> np.ndarray:
    bits = np.unpackbits(np.frombuffer(raw, np.uint8), bitorder="little")
    return bits[:n].astype(bool)


def encode_slab(layout: SlabLayout, vector: np.ndarray, nbrs: np.ndarray,
                dense: np.ndarray, labels: np.ndarray, values: np.ndarray,
                cand_first: np.ndarray) -> bytes:
    """One record → its page-aligned slab bytes (std block + dense block)."""
    lo = layout
    buf = bytearray(lo.slab_bytes)
    head = (np.asarray(vector, np.float32).tobytes()
            + np.asarray(nbrs, np.int32).tobytes())
    buf[0:len(head)] = head
    tail = (np.asarray(labels, np.int32).tobytes()
            + np.asarray(values, np.float32).tobytes()
            + _pack_bits(np.asarray(cand_first, bool), lo.cf_bytes))
    crc_std = zlib.crc32(head) & 0xFFFFFFFF
    crc_tail = zlib.crc32(tail) & 0xFFFFFFFF
    tail += np.array([crc_std, crc_tail], np.uint32).tobytes()
    buf[lo.tail_off:lo.tail_off + lo.tail_bytes] = tail
    if lo.r_dense > 0:
        dpay = np.asarray(dense, np.int32).tobytes()
        crc_d = np.array([zlib.crc32(dpay) & 0xFFFFFFFF], np.uint32).tobytes()
        buf[lo.std_bytes:lo.std_bytes + len(dpay) + 4] = dpay + crc_d
    return bytes(buf)


def decode_std(layout: SlabLayout, blk: bytes) -> dict:
    """std block bytes → field arrays. Raises :class:`SlabChecksumError`
    on a CRC mismatch (the genuine corruption-detection path)."""
    lo = layout
    head = blk[:lo.vec_bytes + lo.nbr_bytes]
    tail = blk[lo.tail_off:lo.tail_off + lo.tail_bytes]
    crc_std, crc_tail = np.frombuffer(tail[-8:], np.uint32)
    if zlib.crc32(head) & 0xFFFFFFFF != crc_std:
        raise SlabChecksumError("std-block checksum mismatch")
    if zlib.crc32(tail[:-8]) & 0xFFFFFFFF != crc_tail:
        raise SlabChecksumError("tail checksum mismatch")
    off = 0
    vec = np.frombuffer(head, np.float32, lo.dim, off); off += lo.vec_bytes
    nbrs = np.frombuffer(head, np.int32, lo.r, off)
    t = 0
    labels = np.frombuffer(tail, np.int32, lo.max_labels, t)
    t += lo.max_labels * 4
    values = np.frombuffer(tail, np.float32, lo.n_fields, t)
    t += lo.n_fields * 4
    cf = _unpack_bits(tail[t:t + lo.cf_bytes], lo.r + lo.r_dense)
    return {"vector": vec, "neighbors": nbrs, "rec_labels": labels,
            "rec_values": values, "cand_first": cf}


def decode_dense(layout: SlabLayout, blk: bytes) -> np.ndarray:
    """dense block bytes → (r_dense,) int32 ids, CRC-checked."""
    lo = layout
    pay = blk[:lo.r_dense * 4]
    crc = np.frombuffer(blk, np.uint32, 1, lo.r_dense * 4)[0]
    if zlib.crc32(pay) & 0xFFFFFFFF != crc:
        raise SlabChecksumError("dense-block checksum mismatch")
    return np.frombuffer(pay, np.int32, lo.r_dense)


def decode_attrs(layout: SlabLayout, page: bytes) -> dict:
    """The attr page (std block's last) → labels/values, CRC-checked."""
    lo = layout
    off = lo.tail_off - (lo.attr_page * lo.page_bytes)
    tail = page[off:off + lo.tail_bytes]
    crc_tail = np.frombuffer(tail[-8:], np.uint32)[1]
    if zlib.crc32(tail[:-8]) & 0xFFFFFFFF != crc_tail:
        raise SlabChecksumError("tail checksum mismatch")
    labels = np.frombuffer(tail, np.int32, lo.max_labels, 0)
    values = np.frombuffer(tail, np.float32, lo.n_fields, lo.max_labels * 4)
    return {"rec_labels": labels, "rec_values": values}


def write_slab_file(path: str, vectors: np.ndarray, neighbors: np.ndarray,
                    dense_neighbors: np.ndarray, rec_labels: np.ndarray,
                    rec_values: np.ndarray, cand_first: np.ndarray,
                    pages_std: int, pages_dense: int,
                    page_bytes: int = PAGE_BYTES) -> SlabLayout:
    """Write every record's slab plus the sidecar meta JSON.

    ``pages_std``/``pages_dense`` are the *modeled* per-fetch page counts
    (``RecordStore``); they ride the meta so a reopened store can rebuild
    the search-visible accounting without the original arrays.
    """
    n, dim = vectors.shape
    layout = SlabLayout(dim, neighbors.shape[1], dense_neighbors.shape[1],
                        rec_labels.shape[1], rec_values.shape[1], page_bytes)
    slab_path = os.path.join(path, SLAB_FILE)
    os.makedirs(path, exist_ok=True)
    with open(slab_path, "wb") as f:
        for i in range(n):
            f.write(encode_slab(layout, vectors[i], neighbors[i],
                                dense_neighbors[i], rec_labels[i],
                                rec_values[i], cand_first[i]))
    meta = {"format": _FORMAT, "n": int(n), "layout": layout.to_json(),
            "pages_std": int(pages_std), "pages_dense": int(pages_dense),
            "slab_bytes": layout.slab_bytes,
            "file_bytes": n * layout.slab_bytes}
    with open(os.path.join(path, META_FILE), "w") as f:
        json.dump(meta, f, indent=1)
    return layout


def read_meta(path: str) -> dict:
    with open(os.path.join(path, META_FILE)) as f:
        return json.load(f)
