"""Clock page cache for the disk record tier.

Frames are whole 4 KB pages keyed by *global page index* (record slab ×
page-in-slab); eviction is the classic second-chance clock — a hit sets
the frame's reference bit, the hand clears bits until it finds a cold
frame. Pages brought in by read-ahead carry a provenance flag so the
``readahead_hits`` counter can tell a useful prefetch from a wasted one
(the flag clears on first demand hit).

Correctness never depends on the cache: a frame holds the exact bytes of
its page, so any eviction order returns bit-identical data — property-
tested in tests/test_storage.py by sweeping capacities from
eviction-heavy to all-resident.
"""
from __future__ import annotations


class PageCache:
    def __init__(self, capacity_pages: int):
        self.capacity = max(1, int(capacity_pages))
        self._frames: dict = {}     # page id -> [bytes, ref, readahead]
        self._ring: list = []       # clock order of page ids (may go stale)
        self._hand = 0
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.readahead_hits = 0

    def __len__(self) -> int:
        return len(self._frames)

    def get(self, pid: int):
        """Cached page bytes or None (counts the hit/miss)."""
        f = self._frames.get(pid)
        if f is None:
            self.misses += 1
            return None
        self.hits += 1
        f[1] = True
        if f[2]:                    # first demand hit on a prefetched page
            self.readahead_hits += 1
            f[2] = False
        return f[0]

    def contains(self, pid: int) -> bool:
        """Presence probe without touching counters or ref bits."""
        return pid in self._frames

    def put(self, pid: int, data: bytes, readahead: bool = False):
        f = self._frames.get(pid)
        if f is not None:           # refresh in place, keep clock position
            f[0] = data
            return
        while len(self._frames) >= self.capacity:
            self._evict_one()
        self._frames[pid] = [data, not readahead, readahead]
        self._ring.append(pid)

    def _evict_one(self):
        # second-chance sweep; invalidated ids linger in the ring as stale
        # entries and are reaped (slot reused) as the hand passes them
        while True:
            if not self._ring:      # all frames invalidated underneath us
                return
            self._hand %= len(self._ring)
            pid = self._ring[self._hand]
            f = self._frames.get(pid)
            if f is None:           # stale ring slot — reap it
                self._ring.pop(self._hand)
                continue
            if f[1]:
                f[1] = False
                self._hand += 1
                continue
            del self._frames[pid]
            self._ring.pop(self._hand)
            self.evictions += 1
            return

    def invalidate(self, pids) -> None:
        """Drop pages (e.g. after a failed/corrupted read attempt, so the
        retry goes back to the device instead of re-serving bad frames)."""
        for pid in pids:
            self._frames.pop(pid, None)

    def counters(self) -> dict:
        return {"hits": self.hits, "misses": self.misses,
                "evictions": self.evictions,
                "readahead_hits": self.readahead_hits,
                "resident_pages": len(self._frames),
                "capacity_pages": self.capacity}
