"""Tiered record storage: page-aligned slab files, a clock page cache,
and bloom-gated reads with measured per-page latency (docs/storage.md).
"""
from repro.storage.cache import PageCache
from repro.storage.disk import DiskRecordStore, StorageConfig
from repro.storage.slab import (InjectedReadError, SlabChecksumError,
                                SlabLayout, read_meta, write_slab_file)

__all__ = ["PageCache", "DiskRecordStore", "StorageConfig",
           "InjectedReadError", "SlabChecksumError", "SlabLayout",
           "read_meta", "write_slab_file"]
