#!/usr/bin/env bash
# Inner-loop test run: only tests marked `fast`, skipping the
# Vamana-build-heavy suites. The tier-1 gate stays the full
# `PYTHONPATH=src python -m pytest -x -q`.
set -euo pipefail
cd "$(dirname "$0")/.."
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} exec python -m pytest -q -m fast "$@"
