#!/usr/bin/env bash
# Inner-loop test run: only tests marked `fast`, skipping the
# Vamana-build-heavy suites, plus a tiny end-to-end smoke of the build
# benchmark (catches benchmark-script bitrot without paying the full
# 12K-corpus run). The tier-1 gate stays the full
# `PYTHONPATH=src python -m pytest -x -q`.
set -euo pipefail
cd "$(dirname "$0")/.."
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m benchmarks.bench_build --smoke
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m benchmarks.bench_search --smoke --active-trace
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} exec python -m pytest -q -m fast "$@"
