#!/usr/bin/env bash
# Inner-loop test run: only tests marked `fast`, skipping the
# Vamana-build-heavy suites, plus a tiny end-to-end smoke of the build
# benchmark (catches benchmark-script bitrot without paying the full
# 12K-corpus run). The tier-1 gate stays the full
# `PYTHONPATH=src python -m pytest -x -q`.
set -euo pipefail
cd "$(dirname "$0")/.."
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m benchmarks.bench_build --smoke
# the search smoke doubles as the seeded fault-injection smoke: the
# default --fault-plan (10% page-fault rate, seed 7) re-runs every mode
# under injection and asserts the degraded-mode recall floor
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m benchmarks.bench_search --smoke --active-trace --store disk
# serving-tier smoke: degrade-rung calibration + a tiny Poisson
# open-loop sweep through the threaded SearchServer (no floors)
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m benchmarks.bench_serve --smoke
# multi-device mesh leg: the dist suite launches its own subprocesses
# with fake CPU devices, but setting the flag here too keeps any
# in-process jax usage on the same 4-device topology the tests assume
XLA_FLAGS="${XLA_FLAGS:-} --xla_force_host_platform_device_count=4" \
    PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -q -m dist
# light chaos tests (deterministic fault hash, injector, latency model)
# are marked fast+chaos and ride the -m fast run below; the full chaos
# property suite is `pytest -m chaos` (tier-1 runs it unmarked too)
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} exec python -m pytest -q -m fast "$@"
