"""End-to-end driver: train a ~100M-param qwen2-family model on the synthetic
motif stream for a few hundred steps, with checkpoint/restart.

    PYTHONPATH=src python examples/train_lm.py --steps 300

On this CPU container a reduced width is used; pass --full for the real
config (TPU-scale).
"""
import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.ckpt import CheckpointManager
from repro.data.pipeline import Prefetcher, StepWatchdog
from repro.data.tokens import lm_batch
from repro.models import lm
from repro.models.common import ModelConfig
from repro.train import OptConfig, init_opt_state, make_train_step


def hundred_m_config() -> ModelConfig:
    """~100M params: qwen2-style, 12 layers, d=512."""
    base = get_config("qwen2-1.5b")
    return dataclasses.replace(
        base, n_layers=12, d_model=512, n_heads=8, n_kv=2, head_dim=64,
        d_ff=2048, vocab=8192, segments=((12, ("attn_mlp",)),),
        param_dtype="float32", compute_dtype="float32",
        attn_chunk_threshold=4096)


def tiny_config() -> ModelConfig:
    base = hundred_m_config()
    return dataclasses.replace(
        base, n_layers=4, d_model=128, n_heads=4, n_kv=2, head_dim=32,
        d_ff=512, vocab=2048, segments=((4, ("attn_mlp",)),))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args()

    cfg = hundred_m_config() if args.full else tiny_config()
    ocfg = OptConfig(lr=3e-3, warmup_steps=20, total_steps=args.steps,
                     weight_decay=0.01)
    n_params = lm.param_count(cfg)
    print(f"model: {cfg.name} ({n_params/1e6:.1f}M params)")

    params = lm.init_lm(cfg, jax.random.PRNGKey(0))
    opt = init_opt_state(params, ocfg)
    step_fn = jax.jit(make_train_step(cfg, ocfg))
    mgr = CheckpointManager(args.ckpt_dir)

    start = 0
    if mgr.latest() is not None:                       # fault-tolerant resume
        target = jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
            {"params": params, "opt": opt})
        start, restored = mgr.restore(target)
        params, opt = restored["params"], restored["opt"]
        print(f"resumed from step {start}")

    pf = Prefetcher(lambda s: lm_batch(cfg, args.batch, args.seq, s),
                    start_step=start)
    wd = StepWatchdog()
    t0 = time.time()
    try:
        for step, batch in pf:
            if step >= args.steps:
                break
            wd.start()
            params, opt, metrics = step_fn(params, opt, batch)
            slow = wd.stop(step)
            if step % 20 == 0 or step == args.steps - 1:
                print(f"step {step:4d} loss={float(metrics['loss']):.4f} "
                      f"lr={float(metrics['lr']):.2e} "
                      f"gnorm={float(metrics['grad_norm']):.2f}"
                      + ("  [straggler]" if slow else ""))
            if step and step % args.ckpt_every == 0:
                mgr.save(step, {"params": params, "opt": opt})
    finally:
        pf.stop()
        mgr.wait()
    print(f"done in {time.time()-t0:.0f}s; stragglers flagged: "
          f"{len(wd.flagged)}")


if __name__ == "__main__":
    main()
