"""RAG-style serving: filtered vector retrieval (the paper's engine) feeding
a decoder-only LM — the integration path of DESIGN.md §4.

A corpus of synthetic "documents" is embedded (stub projector) and indexed
through the ``repro.api`` facade from plain metadata dicts (topic label +
freshness value). Requests are admitted one at a time to a batched
retrieval frontend (``serve.retrieval``): the session groups them across
callers and flushes once, so all four retrievals share one grouped engine
call before generation.

    PYTHONPATH=src python examples/rag_serve.py
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import IndexConfig, Num, SearchConfig, Index, Tag
from repro.api.session import SessionConfig
from repro.configs import smoke_config
from repro.models import lm
from repro.serve.decode import generate
from repro.serve.retrieval import RetrievalFrontend


def embed_docs(docs: np.ndarray, d_embed: int, seed: int = 0) -> np.ndarray:
    """Stub embedding: random projection of token histograms."""
    rng = np.random.default_rng(seed)
    vocab = int(docs.max()) + 1
    proj = rng.normal(0, 1 / np.sqrt(vocab), (vocab, d_embed))
    hist = np.zeros((len(docs), vocab), np.float32)
    for i, doc in enumerate(docs):
        np.add.at(hist[i], doc, 1.0)
    return (hist @ proj).astype(np.float32)


def main():
    rng = np.random.default_rng(0)
    n_docs, doc_len, vocab = 2000, 24, 512
    docs = rng.integers(0, vocab, (n_docs, doc_len))
    topics = rng.integers(0, 20, n_docs)                 # one topic label
    freshness = rng.uniform(0, 100, n_docs).astype(np.float32)

    # index the corpus from plain metadata dicts
    embeds = embed_docs(docs, d_embed=32)
    metadata = [{"topic": int(t), "freshness": float(f)}
                for t, f in zip(topics, freshness)]
    index = Index.build(embeds, metadata,
                        IndexConfig(r=16, r_dense=160, l_build=32, pq_m=8),
                        defaults=SearchConfig(k=4, l=24))
    print(f"indexed {n_docs} docs")

    # a tiny LM as the generator
    cfg = smoke_config("qwen2-1.5b")
    cfg = dataclasses.replace(cfg, vocab=vocab)
    params = lm.init_lm(cfg, jax.random.PRNGKey(0))

    # serve a batch of filtered retrieve->generate requests: admit all four
    # to the frontend, then flush once — one grouped engine call
    frontend = RetrievalFrontend(
        index, SessionConfig(max_batch=8, max_delay_s=10.0))
    queries = embed_docs(docs[rng.integers(0, n_docs, 4)], 32, seed=1)
    req_topics = [int(rng.integers(0, 20)) for _ in range(4)]
    handles = [
        frontend.submit(queries[i],
                        (Tag("topic") == t) &
                        Num("freshness").between(25.0, 90.0))
        for i, t in enumerate(req_topics)]
    n = frontend.flush()
    print(f"flushed {n} requests in {frontend.session.n_batches} batch")

    for i, (topic, h) in enumerate(zip(req_topics, handles)):
        res = h.result()
        # verify the filter held against the source arrays (ground truth,
        # independent of the index's own metadata resolution)
        assert all(topics[j] == topic and 25 <= freshness[j] < 90
                   for j, _, _ in res.matches)
        assert all(m["topic"] == topic for _, _, m in res.matches)
        context = RetrievalFrontend.context_tokens(res, docs, per_doc=8)
        prompt = np.concatenate([context, docs[0][:8]])[None, :] \
            .astype(np.int32)
        out = generate(params, cfg, jnp.asarray(prompt), n_new=8)
        hit_ids = [j for j, _, _ in res.matches]
        print(f"req {i}: topic={topic} mech={res.stats.mechanism} "
              f"retrieved={hit_ids} io={res.stats.io_pages} "
              f"generated={np.asarray(out)[0].tolist()}")
    print("all retrievals satisfied their attribute constraints")


if __name__ == "__main__":
    main()
