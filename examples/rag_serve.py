"""RAG-style serving: filtered vector retrieval (the paper's engine) feeding
a decoder-only LM — the integration path of DESIGN.md §4.

A corpus of synthetic "documents" is embedded (stub projector), indexed with
attributes (topic labels + a freshness value); each request runs a filtered
top-k search (e.g. "topic X AND published in range") and the retrieved
motifs are prepended to the prompt before generation.

    PYTHONPATH=src python examples/rag_serve.py
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import smoke_config
from repro.core import (AndSelector, FilteredANNEngine, IndexConfig,
                        LabelOrSelector, RangeSelector, SearchConfig)
from repro.models import lm
from repro.serve.decode import generate


def embed_docs(docs: np.ndarray, d_embed: int, seed: int = 0) -> np.ndarray:
    """Stub embedding: random projection of token histograms."""
    rng = np.random.default_rng(seed)
    vocab = int(docs.max()) + 1
    proj = rng.normal(0, 1 / np.sqrt(vocab), (vocab, d_embed))
    hist = np.zeros((len(docs), vocab), np.float32)
    for i, doc in enumerate(docs):
        np.add.at(hist[i], doc, 1.0)
    return (hist @ proj).astype(np.float32)


def main():
    rng = np.random.default_rng(0)
    n_docs, doc_len, vocab = 2000, 24, 512
    docs = rng.integers(0, vocab, (n_docs, doc_len))
    topics = rng.integers(0, 20, n_docs)                 # one topic label
    freshness = rng.uniform(0, 100, n_docs).astype(np.float32)

    # index the corpus with attributes
    embeds = embed_docs(docs, d_embed=32)
    offsets = np.arange(n_docs + 1, dtype=np.int64)
    engine = FilteredANNEngine.build(
        embeds, offsets, topics.astype(np.int32), 20, freshness,
        IndexConfig(r=16, r_dense=160, l_build=32, pq_m=8))
    print(f"indexed {n_docs} docs")

    # a tiny LM as the generator
    cfg = smoke_config("qwen2-1.5b")
    cfg = dataclasses.replace(cfg, vocab=vocab)
    params = lm.init_lm(cfg, jax.random.PRNGKey(0))

    # serve a batch of filtered retrieve->generate requests
    queries = embed_docs(docs[rng.integers(0, n_docs, 4)], 32, seed=1)
    for i in range(4):
        topic = int(rng.integers(0, 20))
        sel = AndSelector([
            LabelOrSelector(engine.label_store, [topic]),
            RangeSelector(engine.range_store, 25.0, 90.0)])
        ids, dists, stats = engine.search(
            queries[i:i + 1], [sel], SearchConfig(k=4, l=24))
        hit_ids = [int(x) for x in ids[0] if x >= 0]
        # verify the filter held
        assert all(topics[h] == topic and 25 <= freshness[h] < 90
                   for h in hit_ids)
        context = np.concatenate([docs[h][:8] for h in hit_ids]) \
            if hit_ids else np.zeros(8, np.int64)
        prompt = np.concatenate([context, docs[0][:8]])[None, :].astype(np.int32)
        out = generate(params, cfg, jnp.asarray(prompt), n_new=8)
        print(f"req {i}: topic={topic} mech={stats.mechanism[0]} "
              f"retrieved={hit_ids} io={int(stats.io_pages[0])} "
              f"generated={np.asarray(out)[0].tolist()}")
    print("all retrievals satisfied their attribute constraints")


if __name__ == "__main__":
    main()
