"""Quickstart: build a filtered vector index and run the paper's three
mechanisms on it.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import (FilteredANNEngine, IndexConfig, LabelOrSelector,
                        RangeSelector, SearchConfig, brute_force_filtered,
                        recall_at_k)
from repro.data.synth import make_filtered_dataset


def main():
    print("== PipeANN-Filter quickstart ==")
    ds = make_filtered_dataset(n=4000, d=32, n_queries=8, n_labels=50, seed=1)
    engine = FilteredANNEngine.build(
        ds.vectors, ds.label_offsets, ds.label_flat, ds.n_labels, ds.values,
        IndexConfig(r=20, r_dense=200, l_build=40, pq_m=8))
    print(f"built index: N={engine.store.n} R={engine.store.degree} "
          f"R_d={engine.store.dense_degree} "
          f"pages/record std={engine.store.pages_std} "
          f"dense={engine.store.pages_dense}")

    # one label query + one range query per vector batch
    selectors = []
    for i in range(8):
        if i % 2 == 0:
            selectors.append(LabelOrSelector(engine.label_store,
                                             ds.query_labels[i][:1]))
        else:
            lo, hi = ds.query_ranges[i]
            selectors.append(RangeSelector(engine.range_store,
                                           float(lo), float(hi)))

    ids, dists, stats = engine.search(ds.queries, selectors,
                                      SearchConfig(k=10, l=32))
    vecs = np.asarray(engine.store.vectors)
    rl = np.asarray(engine.store.rec_labels)
    rv = np.asarray(engine.store.rec_values)
    for i, sel in enumerate(selectors):
        plan = sel.plan(engine.config.ql, engine.config.cap)
        q = np.pad(ds.queries[i], (0, vecs.shape[1] - ds.queries.shape[1]))
        gt = brute_force_filtered(vecs, rl, rv, plan.qfilter, q, 10)
        r = recall_at_k(ids[i], gt, 10)
        print(f"query {i}: mech={stats.mechanism[i]:4s} "
              f"sel={stats.selectivity[i]:.4f} io={stats.io_pages[i]:4d} "
              f"recall@10={r:.2f}")
    print("routes:", {m: stats.mechanism.count(m)
                      for m in set(stats.mechanism)})


if __name__ == "__main__":
    main()
