"""Quickstart: build a filtered vector index from plain metadata dicts and
query it through the declarative, schema-first ``repro.api`` surface.

The index is built from per-record metadata (no CSR arrays, no Selector
subclasses) against an explicit ``Schema`` with *two* numeric fields;
filters are `Tag`/`Num` expressions compiled onto the paper's three
mechanisms, routed per query by the cost model — multi-field range
conjunctions included.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.api import (Index, IndexConfig, Num, Schema, SearchConfig,
                       SearchRequest, Tag, recall_at_k)
from repro.data.synth import make_filtered_dataset


def main():
    print("== PipeANN-Filter quickstart ==")
    ds = make_filtered_dataset(n=4000, d=32, n_queries=8, n_labels=50, seed=1)

    # plain per-record metadata dicts: topic tags + two numeric fields
    # (freshness from the dataset, price synthesized here)
    rng = np.random.default_rng(3)
    prices = rng.lognormal(3.0, 0.7, len(ds.vectors)).astype(np.float32)
    metadata = [
        {**d, "price": float(p)}
        for d, p in zip(ds.metadata(tag_field="topic", num_field="freshness"),
                        prices)
    ]
    schema = Schema(tags=["topic"], nums=["freshness", "price"])
    index = Index.build(ds.vectors, metadata,
                        IndexConfig(r=20, r_dense=200, l_build=40, pq_m=8),
                        schema=schema,
                        defaults=SearchConfig(k=10, l=32))
    e = index.engine
    print(f"built index: N={len(index)} R={e.store.degree} "
          f"R_d={e.store.dense_degree} schema={schema.tags}+{schema.nums} "
          f"pages/record std={e.store.pages_std} "
          f"dense={e.store.pages_dense}")

    # alternate single-field filters with a tag ∧ two-numeric-field AND
    requests = []
    for i in range(8):
        if i % 3 == 0:
            f = Tag("topic") == int(ds.query_labels[i][0])
        elif i % 3 == 1:
            lo, hi = ds.query_ranges[i]
            f = Num("freshness").between(float(lo), float(hi))
        else:
            lo, hi = ds.query_ranges[i]
            f = ((Tag("topic") == int(ds.query_labels[i][0]))
                 & Num("freshness").between(float(lo), float(hi))
                 & (Num("price") < 40.0))
        requests.append(SearchRequest(query=ds.queries[i], filter=f))

    results = index.search_batch(requests)
    for i, (req, res) in enumerate(zip(requests, results)):
        gt = index.ground_truth(req)
        r = recall_at_k(res.ids, gt, 10)
        print(f"query {i}: mech={res.stats.mechanism:4s} "
              f"sel={res.stats.selectivity:.4f} io={res.stats.io_pages:4d} "
              f"recall@10={r:.2f}")
    mechs = [r.stats.mechanism for r in results]
    print("routes:", {m: mechs.count(m) for m in set(mechs)})

    # streaming inserts: append fresh records through the incremental
    # batched builder and query them immediately (schema stays fixed —
    # every record carries both numeric fields)
    rng = np.random.default_rng(7)
    new_vecs = ds.vectors[:16] + rng.normal(0, 0.01, (16, 32)) \
        .astype(np.float32)
    new_meta = [{"topic": "breaking", "freshness": 99.0, "price": 12.5}
                for _ in range(16)]
    new_ids = index.insert(new_vecs, new_meta)
    res = index.search(SearchRequest(
        query=new_vecs[0],
        filter=(Tag("topic") == "breaking") & (Num("price") < 20.0), k=5))
    hit = int(new_ids[0]) in res.ids.tolist()
    print(f"inserted {len(new_ids)} records (ids {new_ids[0]}..{new_ids[-1]});"
          f" nearest under its new tag ∧ price filter found={hit}")


if __name__ == "__main__":
    main()
