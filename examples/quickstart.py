"""Quickstart: build a filtered vector index from plain metadata dicts and
query it through the declarative ``repro.api`` surface.

The index is built from per-record metadata (no CSR arrays, no Selector
subclasses); filters are `Tag`/`Num` expressions compiled onto the
paper's three mechanisms, routed per query by the cost model.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.api import (Index, IndexConfig, Num, SearchConfig, SearchRequest,
                       Tag, recall_at_k)
from repro.data.synth import make_filtered_dataset


def main():
    print("== PipeANN-Filter quickstart ==")
    ds = make_filtered_dataset(n=4000, d=32, n_queries=8, n_labels=50, seed=1)

    # plain per-record metadata dicts: topic tags + a freshness value
    metadata = ds.metadata(tag_field="topic", num_field="freshness")
    index = Index.build(ds.vectors, metadata,
                        IndexConfig(r=20, r_dense=200, l_build=40, pq_m=8),
                        defaults=SearchConfig(k=10, l=32))
    e = index.engine
    print(f"built index: N={len(index)} R={e.store.degree} "
          f"R_d={e.store.dense_degree} "
          f"pages/record std={e.store.pages_std} "
          f"dense={e.store.pages_dense}")

    # one tag filter + one range filter per query, alternating
    requests = []
    for i in range(8):
        if i % 2 == 0:
            f = Tag("topic") == int(ds.query_labels[i][0])
        else:
            lo, hi = ds.query_ranges[i]
            f = Num("freshness").between(float(lo), float(hi))
        requests.append(SearchRequest(query=ds.queries[i], filter=f))

    results = index.search_batch(requests)
    for i, (req, res) in enumerate(zip(requests, results)):
        gt = index.ground_truth(req)
        r = recall_at_k(res.ids, gt, 10)
        print(f"query {i}: mech={res.stats.mechanism:4s} "
              f"sel={res.stats.selectivity:.4f} io={res.stats.io_pages:4d} "
              f"recall@10={r:.2f}")
    mechs = [r.stats.mechanism for r in results]
    print("routes:", {m: mechs.count(m) for m in set(mechs)})

    # streaming inserts: append fresh records through the incremental
    # batched builder and query them immediately
    rng = np.random.default_rng(7)
    new_vecs = ds.vectors[:16] + rng.normal(0, 0.01, (16, 32)) \
        .astype(np.float32)
    new_meta = [{"topic": "breaking", "freshness": 99.0} for _ in range(16)]
    new_ids = index.insert(new_vecs, new_meta)
    res = index.search(SearchRequest(
        query=new_vecs[0], filter=(Tag("topic") == "breaking"), k=5))
    hit = int(new_ids[0]) in res.ids.tolist()
    print(f"inserted {len(new_ids)} records (ids {new_ids[0]}..{new_ids[-1]});"
          f" nearest under its new tag found={hit}")


if __name__ == "__main__":
    main()
