"""Quickstart: build a filtered vector index from plain metadata dicts and
query it through the declarative ``repro.api`` surface.

The index is built from per-record metadata (no CSR arrays, no Selector
subclasses); filters are `Tag`/`Num` expressions compiled onto the
paper's three mechanisms, routed per query by the cost model.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.api import (Index, IndexConfig, Num, SearchConfig, SearchRequest,
                       Tag, recall_at_k)
from repro.data.synth import make_filtered_dataset


def main():
    print("== PipeANN-Filter quickstart ==")
    ds = make_filtered_dataset(n=4000, d=32, n_queries=8, n_labels=50, seed=1)

    # plain per-record metadata dicts: topic tags + a freshness value
    metadata = ds.metadata(tag_field="topic", num_field="freshness")
    index = Index.build(ds.vectors, metadata,
                        IndexConfig(r=20, r_dense=200, l_build=40, pq_m=8),
                        defaults=SearchConfig(k=10, l=32))
    e = index.engine
    print(f"built index: N={len(index)} R={e.store.degree} "
          f"R_d={e.store.dense_degree} "
          f"pages/record std={e.store.pages_std} "
          f"dense={e.store.pages_dense}")

    # one tag filter + one range filter per query, alternating
    requests = []
    for i in range(8):
        if i % 2 == 0:
            f = Tag("topic") == int(ds.query_labels[i][0])
        else:
            lo, hi = ds.query_ranges[i]
            f = Num("freshness").between(float(lo), float(hi))
        requests.append(SearchRequest(query=ds.queries[i], filter=f))

    results = index.search_batch(requests)
    for i, (req, res) in enumerate(zip(requests, results)):
        gt = index.ground_truth(req)
        r = recall_at_k(res.ids, gt, 10)
        print(f"query {i}: mech={res.stats.mechanism:4s} "
              f"sel={res.stats.selectivity:.4f} io={res.stats.io_pages:4d} "
              f"recall@10={r:.2f}")
    mechs = [r.stats.mechanism for r in results]
    print("routes:", {m: mechs.count(m) for m in set(mechs)})


if __name__ == "__main__":
    main()
