"""Reproduce the shape of paper Fig. 2 interactively: how the cost model
routes queries as selectivity moves from 0.1% to 50%.

    PYTHONPATH=src python examples/selectivity_sweep.py
"""
from repro.api import Num
from benchmarks.common import get_engine, modeled_qps, run_policy


def main():
    ds, e, build_s = get_engine(n=8000)
    print(f"engine built in {build_s:.0f}s")
    values = e.range_store.field_store(0).sorted_values
    n = values.size
    print(f"{'selectivity':>12} {'route':>6} {'io/q':>7} {'recall':>7} "
          f"{'QPS(model)':>11}")
    for frac in (0.001, 0.005, 0.02, 0.1, 0.3, 0.5):
        lo = int(0.2 * n)
        hi = min(n - 1, lo + max(1, int(frac * n)))
        sels = [Num("value").between(float(values[lo]), float(values[hi]))
                for _ in range(8)]
        r = run_policy(ds, e, sels, "speculative", l=32)
        route = max(r["mech_counts"], key=r["mech_counts"].get)
        qps = modeled_qps(r["io_pages"], r["cpu_us"])
        print(f"{frac:12.3f} {route:>6} {r['io_pages']:7.0f} "
              f"{r['recall']:7.3f} {qps:11.0f}")


if __name__ == "__main__":
    main()
