"""Fused/pipelined hop pipeline A/B suite (docs/perf.md).

* Parity: the fused batched ``filtered_search`` against the jnp oracle
  ``filtered_search_ref`` across all three modes × three selectivities —
  recall@10 within 1%, identical ``io_pages``/``explored`` counters.
* Compaction parity: the bucketed driver ``filtered_search_pipelined``
  (chunked hops + straggler compaction) returns a bit-identical
  ``SearchResult`` vs the single-shot jit across the same grid, and its
  per-bucket jit cache compiles once per bucket.
* Compile artifacts: the hop bodies (single-shot AND chunked runner)
  contain no op that broadcasts against the ``res_cap`` explored buffer,
  and their loop conditions never sort it (the incremental-bound
  invariant). The legacy baseline is walked too, as a canary that the
  checker actually catches the pathology it guards against.
* Session-driven repeat searches hit the bucketed search jit caches
  (compile once).
"""
import functools

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import engine as eng
from repro.core import search as search_mod
from repro.core.selectors import stack_filters

pytestmark = pytest.mark.fast   # build shared via the session-scoped cache


# ---------------------------------------------------------------------------
# A/B parity: fused vs reference oracle
# ---------------------------------------------------------------------------

SELECTIVITIES = (0.05, 0.30, 0.80)


def _range_selectors(e, selectivity: float, n_queries: int):
    from repro.data.synth import make_sliding_range_selectors
    return make_sliding_range_selectors(e, selectivity, n_queries)


def _run_mode(e, ds, mode, selectivity, impl):
    sels = _range_selectors(e, selectivity, ds.queries.shape[0])
    qf = stack_filters([s.plan(e.config.ql, e.config.cap).qfilter
                        for s in sels])
    queries = jnp.asarray(ds.queries)
    params = search_mod.SearchParams(l_search=48, k=10, max_hops=200,
                                     beam_width=2, mode=mode, l_valid=32)
    entries = None
    if mode == "strict_in":
        ents = np.full((len(sels), 4), -1, np.int32)
        for j, s in enumerate(sels):
            seeds, _ = eng._strict_seed_ids(s, e.medoid, 4)
            ents[j, :seeds.size] = seeds
        entries = jnp.asarray(ents)
    res = impl(e.store, e.codes, e.codebook, e.mem, qf, queries, e.medoid,
               params, entries=entries)
    return sels, res


def _recalls(ds, e, sels, res, k=10):
    vectors = np.asarray(e.store.vectors)
    rl = np.asarray(e.store.rec_labels)
    rv = np.asarray(e.store.rec_values)
    out = []
    for i, s in enumerate(sels):
        plan = s.plan(e.config.ql, e.config.cap)
        q = ds.queries[i]
        if q.shape[0] != vectors.shape[1]:
            q = np.pad(q, (0, vectors.shape[1] - q.shape[0]))
        gt = eng.brute_force_filtered(vectors, rl, rv, plan.qfilter, q, k)
        out.append(eng.recall_at_k(np.asarray(res.ids[i]), gt, k))
    return np.array(out)


@pytest.mark.parametrize("mode", ["post", "spec_in", "strict_in"])
@pytest.mark.parametrize("selectivity", SELECTIVITIES)
def test_fused_matches_reference(shared_ds, shared_engine, mode,
                                 selectivity):
    ds, e = shared_ds, shared_engine
    sels, fused = _run_mode(e, ds, mode, selectivity,
                            search_mod.filtered_search)
    _, ref = _run_mode(e, ds, mode, selectivity,
                       search_mod.filtered_search_ref)
    # identical exploration: the paper's algorithmic counters must agree
    # exactly — the fused pipeline is an implementation, not an algorithm
    # change (visited-set table is exact at this corpus size)
    np.testing.assert_array_equal(np.asarray(fused.io_pages),
                                  np.asarray(ref.io_pages))
    np.testing.assert_array_equal(np.asarray(fused.explored),
                                  np.asarray(ref.explored))
    np.testing.assert_array_equal(np.asarray(fused.hops),
                                  np.asarray(ref.hops))
    np.testing.assert_array_equal(np.asarray(fused.n_valid),
                                  np.asarray(ref.n_valid))
    r_f = _recalls(ds, e, sels, fused)
    r_r = _recalls(ds, e, sels, ref)
    assert abs(r_f.mean() - r_r.mean()) <= 0.01, (r_f.mean(), r_r.mean())


@pytest.mark.parametrize("mode", ["post", "spec_in", "strict_in"])
@pytest.mark.parametrize("selectivity", SELECTIVITIES)
def test_pipelined_matches_single_shot(shared_ds, shared_engine, mode,
                                       selectivity):
    """Compaction parity: the bucketed driver is pure batch re-indexing —
    every SearchResult field must match the single-shot jit bit-for-bit.
    Small chunk + min_bucket force several compaction generations."""
    ds, e = shared_ds, shared_engine
    impl = functools.partial(search_mod.filtered_search_pipelined,
                             hop_chunk=8, min_bucket=2)
    _, pipe = _run_mode(e, ds, mode, selectivity, impl)
    _, single = _run_mode(e, ds, mode, selectivity,
                          search_mod.filtered_search)
    for field in search_mod.SearchResult._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(pipe, field)),
            np.asarray(getattr(single, field)),
            err_msg=f"{mode}@{selectivity}: {field}")


def test_bucket_jit_cache_compiles_once_per_bucket(shared_ds,
                                                   shared_engine):
    """The chunked runner is keyed only by (bucket shapes, params):
    repeating the exact same search must not add cache entries, and the
    first run may compile at most one artifact per power-of-two bucket
    (+ the full width)."""
    ds, e = shared_ds, shared_engine
    B = ds.queries.shape[0]
    impl = functools.partial(search_mod.filtered_search_pipelined,
                             hop_chunk=8, min_bucket=2)
    c_before = search_mod.run_hops._cache_size()
    _, res1 = _run_mode(e, ds, "spec_in", 0.30, impl)
    c_first = search_mod.run_hops._cache_size()
    n_buckets = B.bit_length() + 2   # pow-2 widths in [2, B] + full width
    assert c_first - c_before <= n_buckets, \
        f"{c_first - c_before} compiles for ≤{n_buckets} possible buckets"
    _, res2 = _run_mode(e, ds, "spec_in", 0.30, impl)
    assert search_mod.run_hops._cache_size() == c_first, \
        "repeating an identical search re-compiled a bucket"
    np.testing.assert_array_equal(np.asarray(res1.ids),
                                  np.asarray(res2.ids))


def test_fused_results_are_valid(shared_ds, shared_engine):
    """Visited-set false positives may skip exploration but can never
    leak an invalid or duplicate result."""
    from repro.core.selectors import is_member
    ds, e = shared_ds, shared_engine
    sels, res = _run_mode(e, ds, "spec_in", 0.30,
                          search_mod.filtered_search)
    ids = np.asarray(res.ids)
    for i, s in enumerate(sels):
        got = ids[i][ids[i] >= 0]
        assert got.size == np.unique(got).size, f"query {i} duplicated ids"
        if got.size == 0:
            continue
        plan = s.plan(e.config.ql, e.config.cap)
        ok = np.asarray(is_member(plan.qfilter,
                                  e.store.rec_labels[jnp.asarray(got)],
                                  e.store.rec_values[jnp.asarray(got)]))
        assert np.all(ok), f"query {i} returned invalid ids"


@pytest.mark.parametrize("c", [2, 8, 24, 64, 128, 384])
def test_first_occurrence_matches_scan(c):
    """The packed-sort + binary-search dedup against a python scan —
    power-of-two widths included (the unrolled search once ran one
    iteration short exactly there)."""
    rng = np.random.default_rng(c)
    for n_ids in (50, 1000, 2 ** 21):
        cand = rng.integers(-1, min(n_ids, 40), (5, c)).astype(np.int32)
        live = cand >= 0
        got = np.asarray(search_mod._first_occurrence(
            jnp.asarray(cand), jnp.asarray(live), n_ids))
        for b in range(cand.shape[0]):
            seen = set()
            for i in range(c):
                if live[b, i]:
                    assert got[b, i] == (cand[b, i] not in seen), (c, b, i)
                    seen.add(cand[b, i])


def test_custom_distance_fn_keeps_parity(shared_ds, shared_engine):
    """A non-default distance_fn must route every slab through the
    caller's function (not the fused ADC kernel) so fused == ref holds
    for it too."""
    import jax.numpy as jnp
    ds, e = shared_ds, shared_engine

    def scaled_adc(codes, table):          # distinct fn identity + values
        from repro.core import pq as pq_mod
        return pq_mod.adc_lookup(codes, table) * jnp.float32(2.0)

    sels = _range_selectors(e, 0.3, ds.queries.shape[0])
    qf = stack_filters([s.plan(e.config.ql, e.config.cap).qfilter
                        for s in sels])
    queries = jnp.asarray(ds.queries)
    params = search_mod.SearchParams(l_search=32, k=10, max_hops=120,
                                     mode="spec_in")
    fused = search_mod.filtered_search(
        e.store, e.codes, e.codebook, e.mem, qf, queries, e.medoid, params,
        distance_fn=scaled_adc)
    ref = search_mod.filtered_search_ref(
        e.store, e.codes, e.codebook, e.mem, qf, queries, e.medoid, params,
        distance_fn=scaled_adc)
    np.testing.assert_array_equal(np.asarray(fused.ids), np.asarray(ref.ids))
    np.testing.assert_array_equal(np.asarray(fused.io_pages),
                                  np.asarray(ref.io_pages))
    np.testing.assert_array_equal(np.asarray(fused.explored),
                                  np.asarray(ref.explored))


# ---------------------------------------------------------------------------
# Compile artifacts: no res_cap-shaped work inside the hop loop
# ---------------------------------------------------------------------------

RES_CAP_HOPS = 77     # max_hops·W == 77: a dim no other array in the trace has


def _sub_jaxprs(v):
    if isinstance(v, jax.core.ClosedJaxpr):
        yield v.jaxpr
    elif isinstance(v, jax.core.Jaxpr):
        yield v
    elif isinstance(v, (list, tuple)):
        for x in v:
            yield from _sub_jaxprs(x)


def _iter_eqns(jaxpr):
    for eqn in jaxpr.eqns:
        yield eqn
        for v in eqn.params.values():
            for sub in _sub_jaxprs(v):
                yield from _iter_eqns(sub)


def _find_whiles(jaxpr):
    return [e for e in _iter_eqns(jaxpr) if e.primitive.name == "while"]


def _eqn_avals(eqn):
    for var in list(eqn.invars) + list(eqn.outvars):
        aval = getattr(var, "aval", None)
        if aval is not None and getattr(aval, "shape", None) is not None:
            yield aval


def _res_cap_violations(jaxpr, res_cap: int, batch: int):
    """Ops whose operands pair the explored buffer with another axis —
    i.e. anything bigger than the (B, res_cap) buffer itself. Catches the
    legacy O(candidates · res_cap) dedup broadcast."""
    bad = []
    for eqn in _iter_eqns(jaxpr):
        for aval in _eqn_avals(eqn):
            if res_cap in aval.shape and np.prod(aval.shape) > batch * res_cap:
                bad.append((eqn.primitive.name, tuple(aval.shape)))
    return bad


def _cond_sorts_res_cap(jaxpr, res_cap: int):
    return [e for e in _iter_eqns(jaxpr)
            if e.primitive.name == "sort"
            and any(res_cap in a.shape for a in _eqn_avals(e))]


def _trace(impl, e, qf, queries, params):
    def fn(store, codes, centroids, mem, qf, q):
        cb = type(e.codebook)(centroids=centroids, dim=e.codebook.dim)
        return impl(store, codes, cb, mem, qf, q, e.medoid, params)
    return jax.make_jaxpr(fn)(e.store, e.codes, e.codebook.centroids,
                              e.mem, qf, queries)


def test_hop_body_has_no_res_cap_broadcasts(shared_ds, shared_engine):
    ds, e = shared_ds, shared_engine
    B = 3
    sels = _range_selectors(e, 0.3, B)
    qf = stack_filters([s.plan(e.config.ql, e.config.cap).qfilter
                        for s in sels])
    queries = jnp.asarray(ds.queries[:B])
    params = search_mod.SearchParams(l_search=16, k=5, beam_width=1,
                                     max_hops=RES_CAP_HOPS, mode="spec_in")
    res_cap = RES_CAP_HOPS * params.beam_width

    closed = _trace(search_mod.filtered_search, e, qf, queries, params)
    whiles = _find_whiles(closed.jaxpr)
    assert whiles, "fused search lost its while loop?"
    for w in whiles:
        body = w.params["body_jaxpr"].jaxpr
        cond = w.params["cond_jaxpr"].jaxpr
        bad = _res_cap_violations(body, res_cap, B)
        assert not bad, f"res_cap-shaped work in hop body: {bad}"
        assert not _cond_sorts_res_cap(cond, res_cap), \
            "hop condition re-sorts the explored buffer"

    # canary: the checker must flag the legacy pipeline's pathology
    closed_l = _trace(search_mod.filtered_search_legacy, e, qf, queries,
                      params)
    legacy_bad = []
    legacy_sorts = []
    for w in _find_whiles(closed_l.jaxpr):
        legacy_bad += _res_cap_violations(w.params["body_jaxpr"].jaxpr,
                                          res_cap, B)
        legacy_sorts += _cond_sorts_res_cap(w.params["cond_jaxpr"].jaxpr,
                                            res_cap)
    assert legacy_bad, "checker failed to flag the legacy dedup broadcast"
    assert legacy_sorts, "checker failed to flag the legacy cond re-sort"


def test_chunked_runner_has_no_res_cap_broadcasts(shared_ds,
                                                  shared_engine):
    """The chunked hop runner (run_hops) passes the same compile-artifact
    bar as the single-shot loop: no op pairs the res_cap axis with
    another axis, and the (now hop-budgeted) condition never sorts the
    explored buffer."""
    ds, e = shared_ds, shared_engine
    B = 3
    sels = _range_selectors(e, 0.3, B)
    qf = stack_filters([s.plan(e.config.ql, e.config.cap).qfilter
                        for s in sels])
    queries = jnp.asarray(ds.queries[:B])
    params = search_mod.SearchParams(l_search=16, k=5, beam_width=1,
                                     max_hops=RES_CAP_HOPS, mode="spec_in")
    res_cap = RES_CAP_HOPS * params.beam_width
    ctx, st = search_mod.init_search(e.store, e.codes, e.codebook, e.mem,
                                     qf, queries, e.medoid, params)

    def fn(store, codes, mem, ctx, st):
        return search_mod.run_hops(store, codes, mem, ctx, st, 16, params)

    closed = jax.make_jaxpr(fn)(e.store, e.codes, e.mem, ctx, st)
    whiles = _find_whiles(closed.jaxpr)
    assert whiles, "chunked runner lost its while loop?"
    for w in whiles:
        body = w.params["body_jaxpr"].jaxpr
        cond = w.params["cond_jaxpr"].jaxpr
        bad = _res_cap_violations(body, res_cap, B)
        assert not bad, f"res_cap-shaped work in chunked hop body: {bad}"
        assert not _cond_sorts_res_cap(cond, res_cap), \
            "chunked hop condition re-sorts the explored buffer"


# ---------------------------------------------------------------------------
# Session-driven repeat searches compile once
# ---------------------------------------------------------------------------

def test_session_repeat_search_compiles_once():
    from repro.api import (Index, Num, SearchRequest, Session,
                           SessionConfig, Tag)
    rng = np.random.default_rng(7)
    vecs = rng.normal(0, 1, (500, 16)).astype(np.float32)
    meta = [{"cat": int(rng.integers(0, 4)), "v": float(rng.uniform(0, 50))}
            for _ in range(500)]
    idx = Index.build(vecs, meta,
                      eng.IndexConfig(r=8, r_dense=48, l_build=16, pq_m=4),
                      defaults=eng.SearchConfig(k=5, l=32, max_hops=100))

    def reqs(seed):
        r = np.random.default_rng(seed)
        qs = r.normal(0, 1, (3, 16)).astype(np.float32)
        return [SearchRequest(query=qs[0]),
                SearchRequest(query=qs[1], filter=Tag("cat") == 2),
                SearchRequest(query=qs[2], filter=Num("v").between(5., 30.))]

    def caches():
        # the engine's production path: init → chunked runner → finalize
        return (search_mod.init_search._cache_size(),
                search_mod.run_hops._cache_size(),
                search_mod.finalize_search._cache_size())

    with Session(idx, SessionConfig(auto_flush=False)) as sess:
        sess.warmup(reqs(0))               # warm every (mode, pool) group
        c0 = caches()
        for seed in (1, 2):
            sess.submit_many(reqs(seed))
            sess.flush()
        assert caches() == c0, \
            "repeat Session flushes re-specialized the search jit"
