"""Chaos suite for the fault-injected I/O path (docs/robustness.md).

Property under test: for ANY seeded :class:`FaultPlan`, across all three
mechanisms (post / spec_in / strict_in),

* search never crashes;
* the no-false-negative contract holds — a query with ``degraded == 0``
  returns only exactly-valid records, and degraded rows substitute the
  approx-membership *superset* (results are approximated, never dropped);
* recall degrades monotonically with the injected fault rate;
* a plan that draws no faults (``faults == 0``) is bit-identical to the
  clean ``filtered_search_pipelined``;
* at the committed 10% page-fault rate the retry→hedge→degrade ladder
  keeps recall@10 within 5 points of the fault-free run.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine as eng
from repro.core import io_sim
from repro.core import search as search_mod
from repro.core.faults import FaultInjector, FaultPlan, parse_plan
from repro.core.selectors import is_member, stack_filters

pytestmark = pytest.mark.chaos

MODES = ("post", "spec_in", "strict_in")

# three seeded plans, mild to brutal: the mild one exercises the retry
# ladder (nothing should degrade), the brutal ones force real degradation
PLANS = (
    FaultPlan(seed=1, read_fail_rate=0.10, spike_rate=0.05),
    FaultPlan(seed=2, read_fail_rate=0.30, corrupt_rate=0.10,
              max_retries=1, hedge=False),
    FaultPlan(seed=3, read_fail_rate=0.60, corrupt_rate=0.20,
              spike_rate=0.30, max_retries=0, hedge=False),
)

# the committed operating point for the recall floor (bench + CI smoke)
PLAN_10PCT = FaultPlan(seed=7, read_fail_rate=0.10)


def _run(e, ds, mode, plan, selectivity=0.30):
    from repro.data.synth import make_sliding_range_selectors
    sels = make_sliding_range_selectors(e, selectivity,
                                        ds.queries.shape[0])
    qf = stack_filters([s.plan(e.config.ql, e.config.cap).qfilter
                        for s in sels])
    params = search_mod.SearchParams(l_search=48, k=10, max_hops=200,
                                     beam_width=2, mode=mode, l_valid=32,
                                     fault_plan=plan)
    entries = None
    if mode == "strict_in":
        ents = np.full((len(sels), 4), -1, np.int32)
        for j, s in enumerate(sels):
            seeds, _ = eng._strict_seed_ids(s, e.medoid, 4)
            ents[j, :seeds.size] = seeds
        entries = jnp.asarray(ents)
    res = search_mod.filtered_search_pipelined(
        e.store, e.codes, e.codebook, e.mem, qf, jnp.asarray(ds.queries),
        e.medoid, params, entries=entries)
    return sels, qf, res


def _mean_recall(ds, e, sels, res, k=10):
    vectors = np.asarray(e.store.vectors)
    rl = np.asarray(e.store.rec_labels)
    rv = np.asarray(e.store.rec_values)
    out = []
    for i, s in enumerate(sels):
        plan = s.plan(e.config.ql, e.config.cap)
        q = ds.queries[i]
        if q.shape[0] != vectors.shape[1]:
            q = np.pad(q, (0, vectors.shape[1] - q.shape[0]))
        gt = eng.brute_force_filtered(vectors, rl, rv, plan.qfilter, q, k)
        out.append(eng.recall_at_k(np.asarray(res.ids[i]), gt, k))
    return float(np.mean(out))


@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize("plan", PLANS, ids=lambda p: f"seed{p.seed}")
def test_chaos_never_crashes_no_false_negatives(shared_engine, shared_ds,
                                                mode, plan):
    """Any plan: search completes, counters are sane, and undegraded
    queries return only exactly-valid records."""
    e = shared_engine
    sels, qf, res = _run(e, shared_ds, mode, plan)
    ids = np.asarray(res.ids)
    faults = np.asarray(res.faults)
    retries = np.asarray(res.retries)
    degraded = np.asarray(res.degraded)
    assert np.all(faults >= 0) and np.all(degraded >= 0)
    assert np.all(retries <= faults)        # a retry follows a fault
    if plan.max_retries or plan.hedge:
        assert retries.sum() > 0            # the ladder actually engaged
    import jax
    safe = jnp.maximum(jnp.asarray(ids), 0)
    ok = np.asarray(jax.vmap(is_member)(
        qf, e.store.rec_labels[safe], e.store.rec_values[safe]))
    for i in range(ids.shape[0]):
        returned = ids[i] >= 0
        if degraded[i] == 0:
            # clean queries: every returned record is exactly valid
            assert np.all(ok[i][returned]), (mode, plan.seed, i)


@pytest.mark.parametrize("mode", MODES)
def test_recall_within_5_points_at_10pct(shared_engine, shared_ds, mode):
    """The committed operating point: at a 10% per-attempt page-fault rate
    the full ladder holds recall@10 within 5 points of fault-free."""
    e = shared_engine
    sels, _, clean = _run(e, shared_ds, mode, None)
    _, _, faulted = _run(e, shared_ds, mode, PLAN_10PCT)
    r_clean = _mean_recall(shared_ds, e, sels, clean)
    r_fault = _mean_recall(shared_ds, e, sels, faulted)
    assert np.asarray(faulted.faults).sum() > 0
    assert r_fault >= r_clean - 0.05, (r_clean, r_fault)


@pytest.mark.parametrize("mode", MODES)
def test_recall_degrades_monotonically(shared_engine, shared_ds, mode):
    """With the ladder disabled (no retries, no hedge), recall must be
    non-increasing in the injected fault rate."""
    e = shared_engine
    recalls = []
    for rate in (0.0, 0.5, 0.9):
        plan = (None if rate == 0.0 else
                FaultPlan(seed=11, read_fail_rate=rate, max_retries=0,
                          hedge=False))
        sels, _, res = _run(e, shared_ds, mode, plan)
        recalls.append(_mean_recall(shared_ds, e, sels, res))
    assert recalls[0] >= recalls[1] - 0.02 >= recalls[2] - 0.04, recalls
    assert recalls[2] < recalls[0]          # brutal rate really hurts


@pytest.mark.parametrize("mode", MODES)
def test_zero_fault_plan_bit_identical(shared_engine, shared_ds, mode):
    """faults == 0 ⇒ bit-identical to the clean pipelined path: a plan
    whose rates are all zero must not change one bit of any field."""
    e = shared_engine
    _, _, clean = _run(e, shared_ds, mode, None)
    _, _, zeroed = _run(e, shared_ds, mode, FaultPlan(seed=42))
    for f in search_mod.SearchResult._fields:
        np.testing.assert_array_equal(np.asarray(getattr(clean, f)),
                                      np.asarray(getattr(zeroed, f)),
                                      err_msg=f"{mode}:{f}")
    assert int(np.asarray(zeroed.faults).sum()) == 0


@pytest.mark.fast
def test_fault_draws_deterministic_and_seed_sensitive():
    """The stateless hash: same (ids, hops, plan) ⇒ same draws; a
    different seed decorrelates them."""
    from repro.core import faults as faults_mod
    ids = jnp.arange(512, dtype=jnp.int32).reshape(8, 64)
    hops = jnp.tile(jnp.arange(8, dtype=jnp.int32)[:, None], (1, 64))
    p1 = FaultPlan(seed=5, read_fail_rate=0.3)
    a = np.asarray(faults_mod.read_attempt_bad(ids, hops, 0, p1))
    b = np.asarray(faults_mod.read_attempt_bad(ids, hops, 0, p1))
    np.testing.assert_array_equal(a, b)
    c = np.asarray(faults_mod.read_attempt_bad(
        ids, hops, 0, FaultPlan(seed=6, read_fail_rate=0.3)))
    assert (a != c).any()
    # rate sanity: the empirical hit rate tracks the plan's probability
    assert 0.2 < a.mean() < 0.4
    # attempts decorrelate: a retry is not doomed to repeat its failure
    d = np.asarray(faults_mod.read_attempt_bad(ids, hops, 1, p1))
    assert (a != d).any()


@pytest.mark.fast
def test_ckpt_injector_deterministic():
    plan = FaultPlan(seed=9, ckpt_fail_rate=0.5)
    a = [FaultInjector(plan).ckpt_write_fails(s, l)
         for s in range(4) for l in range(8)]
    b = [FaultInjector(plan).ckpt_write_fails(s, l)
         for s in range(4) for l in range(8)]
    assert a == b and any(a) and not all(a)
    inj = FaultInjector(plan)
    n = sum(inj.ckpt_write_fails(0, l) for l in range(8))
    assert inj.n_write_faults == n


@pytest.mark.fast
def test_parse_plan_cli_spec():
    p = parse_plan("rate=0.25,seed=7,max_retries=1,hedge=0,corrupt_rate=0.1")
    assert p == FaultPlan(seed=7, read_fail_rate=0.25, corrupt_rate=0.1,
                          max_retries=1, hedge=False)
    with pytest.raises(ValueError, match="unknown FaultPlan field"):
        parse_plan("nope=1")
    with pytest.raises(AssertionError):
        FaultPlan(read_fail_rate=1.5)


def test_counters_surface_through_engine_and_api(shared_engine, shared_ds):
    """SearchConfig.fault_plan flows into QueryStats/RequestStats."""
    e = shared_engine
    from repro.data.synth import make_sliding_range_selectors
    sels = make_sliding_range_selectors(e, 0.3, 6)
    scfg = eng.SearchConfig(policy="post", fault_plan=PLAN_10PCT)
    ids, dists, stats = e.search(shared_ds.queries[:6], sels, scfg)
    assert stats.faults.sum() > 0
    assert stats.retries.sum() > 0
    assert ids.shape == (6, 10)
    clean_ids, _, clean_stats = e.search(
        shared_ds.queries[:6], sels, eng.SearchConfig(policy="post"))
    assert clean_stats.faults.sum() == 0 and clean_stats.degraded.sum() == 0


@pytest.mark.fast
def test_faulted_latency_model():
    m = io_sim.IOModel()
    base = m.latency_us(10, pages_parallel=32, prefetch_depth=2,
                        compute_us=100.0)
    # no plan / no measured faults: identical to the clean model
    assert m.faulted_latency_us(10, None, pages_parallel=32,
                                prefetch_depth=2, compute_us=100.0) == base
    plan = PLAN_10PCT
    assert m.faulted_latency_us(10, plan, pages_parallel=32,
                                prefetch_depth=2, compute_us=100.0) == base
    # retries add page reads + backoff; spikes stretch reads
    with_faults = m.faulted_latency_us(
        10, plan, faults=4, retries=3, spikes=1, pages_parallel=32,
        prefetch_depth=2, compute_us=100.0)
    assert with_faults > base
