import time

import numpy as np

from repro.data.pipeline import Prefetcher, StepWatchdog
from repro.data.tokens import lm_batch
from repro.configs import smoke_config


def test_prefetcher_ordered_and_deterministic():
    cfg = smoke_config("qwen2-1.5b")
    make = lambda s: lm_batch(cfg, 2, 16, s)
    pf = Prefetcher(make, start_step=3, prefetch=2)
    got = []
    for step, batch in pf:
        got.append((step, batch["tokens"].copy()))
        if len(got) == 4:
            break
    pf.stop()
    assert [s for s, _ in got] == [3, 4, 5, 6]
    for s, toks in got:
        np.testing.assert_array_equal(toks, lm_batch(cfg, 2, 16, s)["tokens"])


def test_batches_differ_across_steps_and_shards():
    cfg = smoke_config("qwen2-1.5b")
    a = lm_batch(cfg, 2, 16, step=1, shard=0)
    b = lm_batch(cfg, 2, 16, step=2, shard=0)
    c = lm_batch(cfg, 2, 16, step=1, shard=1, n_shards=2)
    assert not np.array_equal(a["tokens"], b["tokens"])
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_motif_stream_is_learnable_structure():
    """Targets repeat with the motif period -> next-token is predictable."""
    cfg = smoke_config("qwen2-1.5b")
    b = lm_batch(cfg, 1, 100, step=0, motif_len=16)
    stream = np.concatenate([b["tokens"][0], b["targets"][0][-1:]])
    assert np.array_equal(stream[:16], stream[16:32])


def test_watchdog_flags_stragglers():
    wd = StepWatchdog(factor=5.0, warmup=3)
    for i in range(5):
        wd.start()
        time.sleep(0.01)
        wd.stop(i)
    wd.start()
    time.sleep(0.2)                    # straggler
    assert wd.stop(5)
    assert len(wd.flagged) == 1
