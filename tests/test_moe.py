import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.models.moe as MOE
from repro.models.common import ModelConfig, MoEConfig


def _cfg(cf=8.0, e=4, k=2, group=64):
    return ModelConfig(
        name="t", n_layers=1, d_model=32, n_heads=4, n_kv=2, head_dim=8,
        d_ff=48, vocab=64, segments=((1, ("attn_moe",)),),
        moe=MoEConfig(n_experts=e, top_k=k, capacity_factor=cf,
                      group_size=group),
        param_dtype="float32", compute_dtype="float32")


def test_fsplit_exact(monkeypatch):
    """Expert f-splitting is numerically identical to the unsplit FFN."""
    cfg = _cfg()
    p = MOE.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32))
    base, _ = MOE.moe_forward(p, x, cfg)
    monkeypatch.setattr(MOE, "_f_split", lambda e, f: 3)
    split, _ = MOE.moe_forward(p, x, cfg)
    np.testing.assert_allclose(np.asarray(base), np.asarray(split),
                               rtol=2e-5, atol=2e-5)


def test_capacity_drops_tokens():
    """With a tight capacity factor some assignments are dropped; with a
    loose one none are."""
    tight = _cfg(cf=0.3)
    loose = _cfg(cf=8.0)
    p = MOE.init_moe(jax.random.PRNGKey(0), tight)
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 32, 32))
    _, aux_t = MOE.moe_forward(p, x, tight)
    _, aux_l = MOE.moe_forward(p, x, loose)
    assert float(aux_t["drop_frac"]) > 0.0
    assert float(aux_l["drop_frac"]) == 0.0


def test_aux_losses_sane():
    cfg = _cfg()
    p = MOE.init_moe(jax.random.PRNGKey(3), cfg)
    x = jax.random.normal(jax.random.PRNGKey(4), (2, 32, 32))
    _, aux = MOE.moe_forward(p, x, cfg)
    lb = float(aux["lb_loss"])
    # Switch lb loss: 1.0 at perfect balance, <= E at total collapse
    assert np.isfinite(lb) and 0.5 <= lb <= cfg.moe.n_experts + 0.1
    assert float(aux["z_loss"]) >= 0.0


def test_chunking_invariance():
    """Chunked scan == single-group processing (same capacity per token)."""
    import dataclasses
    p_cfg = _cfg(cf=8.0, group=16)      # forces multiple chunks for s=32
    one_cfg = dataclasses.replace(
        p_cfg, moe=dataclasses.replace(p_cfg.moe, group_size=1 << 20))
    p = MOE.init_moe(jax.random.PRNGKey(5), p_cfg)
    x = jax.random.normal(jax.random.PRNGKey(6), (2, 32, 32))
    a, _ = MOE.moe_forward(p, x, p_cfg)
    b, _ = MOE.moe_forward(p, x, one_cfg)
    # same routing decisions; only capacity bookkeeping differs, and with
    # cf=8 nothing drops -> outputs identical
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5,
                               atol=2e-5)


def test_grad_flows_through_router():
    cfg = _cfg()
    p = MOE.init_moe(jax.random.PRNGKey(7), cfg)
    x = jax.random.normal(jax.random.PRNGKey(8), (1, 16, 32))

    def loss(params):
        out, aux = MOE.moe_forward(params, x, cfg)
        return jnp.sum(out ** 2) + aux["lb_loss"]

    g = jax.grad(loss)(p)
    assert float(jnp.sum(jnp.abs(g.w_router))) > 0.0
    assert float(jnp.sum(jnp.abs(g.w_gate))) > 0.0
