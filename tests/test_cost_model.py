import numpy as np
import pytest

from repro.core import cost_model as CM

pytestmark = pytest.mark.fast


def _inputs(**kw):
    base = dict(n=1_000_000, l=32, s=0.1, p_pre=1.0, p_in=0.8,
                x_pre=50, x_in=10, r=64, r_d=640, s_r=1, s_d=2)
    base.update(kw)
    return CM.CostInputs(**base)


def test_router_prefers_pre_at_low_selectivity():
    r = CM.route_query(_inputs(s=0.0005))
    assert r.mechanism == "pre"


def test_router_prefers_post_or_in_at_high_selectivity():
    r = CM.route_query(_inputs(s=0.6))
    assert r.mechanism in ("post", "in")


def test_in_filter_regimes():
    """Table 1: below s·R_d/p ≤ R false positives are free bridges (cost
    follows 1/s); above, precision scaling takes over (cost follows 1/p)."""
    lo = CM.in_filtering_cost(_inputs(s=0.01))      # 0.01*640/0.8 = 8 <= 64
    lo2 = CM.in_filtering_cost(_inputs(s=0.005))
    assert lo2.io_pages > lo.io_pages                # 1/s scaling

    hi = CM.in_filtering_cost(_inputs(s=0.5, p_in=0.8))
    hi2 = CM.in_filtering_cost(_inputs(s=0.5, p_in=0.4))
    assert hi2.io_pages > hi.io_pages                # 1/p scaling
    hi3 = CM.in_filtering_cost(_inputs(s=0.9, p_in=0.8))
    assert abs(hi3.io_pages - hi.io_pages) < 1e-6    # s-independent regime


def test_post_filter_matches_table1():
    c = _inputs(s=0.25)
    mc = CM.post_filtering_cost(c)
    assert abs(mc.io_pages - (c.l / c.s) * c.s_r) < 1e-9
    assert abs(mc.compute - (c.l / c.s) * c.r) < 1e-9


def test_alpha_beta_weighting():
    """Raising the I/O weight must never flip toward a higher-I/O plan."""
    c = _inputs(s=0.02)
    r1 = CM.route_query(c, alpha=1.0, beta=1.0)
    r10 = CM.route_query(c, alpha=100.0, beta=1.0)
    io1 = r1.costs[r1.mechanism].io_pages
    io10 = r10.costs[r10.mechanism].io_pages
    assert io10 <= io1 + 1e-9


def test_effective_l_bounded():
    r = CM.route_query(_inputs(s=1e-6), max_pool=512)
    assert r.effective_l <= 512
