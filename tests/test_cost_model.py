import numpy as np
import pytest

from repro.core import cost_model as CM

pytestmark = pytest.mark.fast


def _inputs(**kw):
    base = dict(n=1_000_000, l=32, s=0.1, p_pre=1.0, p_in=0.8,
                x_pre=50, x_in=10, r=64, r_d=640, s_r=1, s_d=2)
    base.update(kw)
    return CM.CostInputs(**base)


def test_router_prefers_pre_at_low_selectivity():
    r = CM.route_query(_inputs(s=0.0005))
    assert r.mechanism == "pre"


def test_router_prefers_post_or_in_at_high_selectivity():
    r = CM.route_query(_inputs(s=0.6))
    assert r.mechanism in ("post", "in")


def test_in_filter_regimes():
    """Table 1: below s·R_d/p ≤ R false positives are free bridges (cost
    follows 1/s); above, precision scaling takes over (cost follows 1/p)."""
    lo = CM.in_filtering_cost(_inputs(s=0.01))      # 0.01*640/0.8 = 8 <= 64
    lo2 = CM.in_filtering_cost(_inputs(s=0.005))
    assert lo2.io_pages > lo.io_pages                # 1/s scaling

    hi = CM.in_filtering_cost(_inputs(s=0.5, p_in=0.8))
    hi2 = CM.in_filtering_cost(_inputs(s=0.5, p_in=0.4))
    assert hi2.io_pages > hi.io_pages                # 1/p scaling
    hi3 = CM.in_filtering_cost(_inputs(s=0.9, p_in=0.8))
    assert abs(hi3.io_pages - hi.io_pages) < 1e-6    # s-independent regime


def test_post_filter_matches_table1():
    c = _inputs(s=0.25)
    mc = CM.post_filtering_cost(c)
    assert abs(mc.io_pages - (c.l / c.s) * c.s_r) < 1e-9
    assert abs(mc.compute - (c.l / c.s) * c.r) < 1e-9


def test_alpha_beta_weighting():
    """Raising the I/O weight must never flip toward a higher-I/O plan."""
    c = _inputs(s=0.02)
    r1 = CM.route_query(c, alpha=1.0, beta=1.0)
    r10 = CM.route_query(c, alpha=100.0, beta=1.0)
    io1 = r1.costs[r1.mechanism].io_pages
    io10 = r10.costs[r10.mechanism].io_pages
    assert io10 <= io1 + 1e-9


def test_effective_l_bounded():
    r = CM.route_query(_inputs(s=1e-6), max_pool=512)
    assert r.effective_l <= 512


# ---------------------------------------------------------------------------
# Measured-counter calibration (BENCH_search.json -> compute terms)
# ---------------------------------------------------------------------------

def _payload(spec_dist=560.0, spec_approx=24_000.0, post_dist=300.0):
    return {"modes": {
        "spec_in": {"mean_hops": 80.0, "mean_dist_comps": spec_dist,
                    "mean_approx_checks": spec_approx},
        "post": {"mean_hops": 50.0, "mean_dist_comps": post_dist,
                 "mean_approx_checks": 0.0},
    }}


def test_calibration_from_bench_ratios():
    cal = CM.Calibration.from_bench(_payload())
    assert abs(cal.spec_in.dist_per_hop - 7.0) < 1e-9      # 560 / 80
    assert abs(cal.spec_in.approx_per_hop - 300.0) < 1e-9  # 24000 / 80
    assert abs(cal.post.dist_per_hop - 6.0) < 1e-9         # 300 / 50
    assert abs(cal.post.approx_per_hop) < 1e-9


def test_calibrated_compute_uses_measured_per_hop_constants():
    """Calibration swaps the per-hop compute constants (R, γ·R_d) for the
    measured ratios; hop-count scaling and every I/O term stay analytic."""
    cal = CM.Calibration.from_bench(_payload())
    c = _inputs(s=0.5, p_in=0.8)            # precision regime: hops = L/p
    mc = CM.in_filtering_cost(c, cal)
    hops = c.l / c.p_in
    assert abs(mc.compute - hops * (7.0 + c.gamma * 300.0)) < 1e-6
    assert mc.io_pages == CM.in_filtering_cost(c).io_pages
    c_lo = _inputs(s=0.001)                 # bridge regime: hops = L/s·R/R_d
    hops_lo = (c_lo.l / c_lo.s) * (c_lo.r / c_lo.r_d)
    mlo = CM.in_filtering_cost(c_lo, cal)
    assert abs(mlo.compute - hops_lo * (7.0 + c_lo.gamma * 300.0)) < 1e-3
    mp = CM.post_filtering_cost(c, cal)
    assert abs(mp.compute - (c.l / c.s) * 6.0) < 1e-6
    # pre-filtering has no fused counters: calibration is a no-op there
    assert CM.pre_filtering_cost(c, cal) == CM.pre_filtering_cost(c)


def test_calibration_none_is_identity():
    c = _inputs(s=0.07)
    for fn in (CM.in_filtering_cost, CM.post_filtering_cost,
               CM.pre_filtering_cost):
        assert fn(c, None) == fn(c)


def test_calibration_can_flip_route():
    """Measured counters that contradict the analytic estimate must be
    able to change the routing decision — the point of calibrating."""
    c = _inputs(s=0.02)
    analytic = CM.route_query(c)
    assert analytic.mechanism == "in"
    # measured: spec_in pays enormous approx-check cost per hop, post is
    # far cheaper per hop than the analytic R
    cal = CM.Calibration.from_bench(
        _payload(spec_approx=240_000.0, post_dist=50.0))
    calibrated = CM.route_query(c, calib=cal)
    assert calibrated.mechanism == "post"


def test_load_calibration_missing_file(tmp_path):
    assert CM.load_calibration(str(tmp_path / "nope.json")) is None
