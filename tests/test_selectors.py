import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.labels import build_label_store, padded_vec_labels
from repro.core.ranges import build_range_store
from repro.core import selectors as S

pytestmark = pytest.mark.fast


@pytest.fixture(scope="module")
def stores():
    rng = np.random.default_rng(0)
    n, n_labels = 800, 20
    counts = rng.integers(1, 5, n)
    flat = rng.integers(0, n_labels, counts.sum()).astype(np.int32)
    offsets = np.zeros(n + 1, np.int64)
    np.cumsum(counts, out=offsets[1:])
    ls = build_label_store(offsets, flat, n_labels)
    values = rng.uniform(0, 100, n).astype(np.float32)
    rs = build_range_store(values)
    mem = S.InMemory(blooms=jnp.asarray(ls.blooms),
                     bucket_codes=jnp.asarray(rs.bucket_codes))
    rec_labels = jnp.asarray(padded_vec_labels(ls, 8))
    rec_values = jnp.asarray(values)
    return ls, rs, mem, rec_labels, rec_values


def _exact_label_or(ls, labels, vec):
    mine = set(ls.labels_of(vec).tolist())
    return bool(mine & set(labels))


def _exact_label_and(ls, labels, vec):
    mine = set(ls.labels_of(vec).tolist())
    return set(labels) <= mine


def test_label_or_no_false_negatives(stores):
    ls, rs, mem, rec_labels, rec_values = stores
    sel = S.LabelOrSelector(ls, [3, 7])
    plan = sel.plan(ql=8, cap=2048)
    ids = jnp.arange(ls.n_vectors)
    approx = np.asarray(S.is_member_approx(plan.qfilter, ids, mem))
    exact = np.asarray(S.is_member(plan.qfilter, rec_labels, rec_values))
    for v in range(ls.n_vectors):
        truth = _exact_label_or(ls, [3, 7], v)
        assert exact[v] == truth
        if truth:
            assert approx[v], f"false negative at {v}"


def test_label_and_no_false_negatives(stores):
    ls, rs, mem, rec_labels, rec_values = stores
    sel = S.LabelAndSelector(ls, [1, 2])
    plan = sel.plan(ql=8, cap=2048)
    ids = jnp.arange(ls.n_vectors)
    approx = np.asarray(S.is_member_approx(plan.qfilter, ids, mem))
    exact = np.asarray(S.is_member(plan.qfilter, rec_labels, rec_values))
    for v in range(ls.n_vectors):
        truth = _exact_label_and(ls, [1, 2], v)
        assert exact[v] == truth
        if truth:
            assert approx[v]


def test_range_no_false_negatives(stores):
    ls, rs, mem, rec_labels, rec_values = stores
    sel = S.RangeSelector(rs, 20.0, 40.0)
    plan = sel.plan(ql=8, cap=2048)
    ids = jnp.arange(rs.n_vectors)
    approx = np.asarray(S.is_member_approx(plan.qfilter, ids, mem))
    exact = np.asarray(S.is_member(plan.qfilter, rec_labels, rec_values))
    vals = np.asarray(rec_values)
    truth = (vals >= 20.0) & (vals < 40.0)
    np.testing.assert_array_equal(exact, truth)
    assert np.all(approx[truth])
    # approx must be a reasonably tight superset (bucket granularity)
    assert approx.sum() <= truth.sum() + 2 * (rs.n_vectors / 256) + 16


def test_selectivity_estimates(stores):
    ls, rs, *_ = stores
    sel = S.RangeSelector(rs, 20.0, 40.0)
    est = sel.selectivity()
    actual = float(np.mean((rs.values >= 20) & (rs.values < 40)))
    assert abs(est - actual) < 0.05

    lsel = S.LabelOrSelector(ls, [0, 1])
    actual_l = np.mean([_exact_label_or(ls, [0, 1], v)
                        for v in range(ls.n_vectors)])
    assert abs(lsel.selectivity() - actual_l) < 0.12


def test_combinators(stores):
    ls, rs, mem, rec_labels, rec_values = stores
    for comb, op in ((S.AndSelector, np.logical_and),
                     (S.OrSelector, np.logical_or)):
        sel = comb([S.LabelOrSelector(ls, [3]), S.RangeSelector(rs, 10., 60.)])
        plan = sel.plan(ql=8, cap=2048)
        exact = np.asarray(S.is_member(plan.qfilter, rec_labels, rec_values))
        lab = np.array([_exact_label_or(ls, [3], v)
                        for v in range(ls.n_vectors)])
        vals = np.asarray(rec_values)
        rng_ok = (vals >= 10) & (vals < 60)
        np.testing.assert_array_equal(exact, op(lab, rng_ok))
        approx = np.asarray(S.is_member_approx(
            plan.qfilter, jnp.arange(ls.n_vectors), mem))
        assert np.all(approx[op(lab, rng_ok)])   # no false negatives


def test_prefilter_supersets(stores):
    ls, rs, *_ = stores
    sel = S.LabelAndSelector(ls, [0, 1])
    ids, pages = sel.pre_filter_approx()
    assert pages >= 1
    truth = {v for v in range(ls.n_vectors) if _exact_label_and(ls, [0, 1], v)}
    assert truth <= set(ids.tolist())   # superset guarantee

    rsel = S.RangeSelector(rs, 20.0, 40.0)
    ids, pages = rsel.pre_filter_approx()
    vals = rs.values
    truth_r = set(np.where((vals >= 20) & (vals < 40))[0].tolist())
    assert truth_r == set(ids.tolist())   # range scan is exact


# ---------------------------------------------------------------------------
# Quantile/bucket staleness on skewed insert streams (ranges.REFRESH_FRAC)
# ---------------------------------------------------------------------------

def test_skewed_stream_triggers_bucket_refresh():
    """Inserting a large batch far outside the build-time distribution
    must re-derive the global bucket bounds and re-code every row —
    fixed bounds would pile the whole new region into bucket 255 and
    collapse is_member_approx precision there."""
    from repro.core.ranges import REFRESH_FRAC, build_range_store
    rng = np.random.default_rng(5)
    rs = build_range_store(rng.normal(0, 1, 2000).astype(np.float32))
    big = rng.normal(100, 5, 1200).astype(np.float32)   # > REFRESH_FRAC·n
    assert big.size > REFRESH_FRAC * (rs.n_vectors + big.size)
    rs2 = rs.append(big)
    assert rs2.bounds_refreshed and rs2.inserted_since_refresh == 0
    # bounds/codes move together: every true member passes the approx test
    blo, bhi = rs2.bucket_range(95.0, 105.0)
    truth = (rs2.values >= 95.0) & (rs2.values < 105.0)
    codes = rs2.bucket_codes.astype(np.int32)
    assert not np.any(truth & ~((codes >= blo) & (codes <= bhi)))
    # the refreshed buckets discriminate inside the new region
    assert bhi - blo > 4
    assert rs2.precision(95.0, 105.0) > 0.5
    # selectivity estimate tracks the merged distribution
    est = rs2.selectivity(95.0, 105.0)
    assert abs(est - truth.mean()) < 0.05


def test_small_appends_keep_bounds_until_threshold():
    """Below the refresh fraction the bounds stay fixed (codes remain
    comparable without a device re-upload) and the staleness counter
    accumulates across appends until it trips."""
    from repro.core.ranges import REFRESH_FRAC, build_range_store
    rng = np.random.default_rng(6)
    rs = build_range_store(rng.uniform(0, 100, 1000).astype(np.float32))
    rs1 = rs.append(rng.uniform(200, 210, 100).astype(np.float32))
    assert not rs1.bounds_refreshed and rs1.inserted_since_refresh == 100
    np.testing.assert_array_equal(rs1.bucket_bounds, rs.bucket_bounds)
    # stale bounds: the whole new region shares one bucket (no refresh yet)
    blo, bhi = rs1.bucket_range(200.0, 210.0)
    assert bhi == blo
    # keep appending: the counter accumulates and eventually trips
    cur = rs1
    for _ in range(10):
        cur = cur.append(rng.uniform(200, 210, 100).astype(np.float32))
        if cur.bounds_refreshed:
            break
    assert cur.bounds_refreshed, "accumulated inserts never re-bucketed"
    blo2, bhi2 = cur.bucket_range(200.0, 210.0)
    assert bhi2 - blo2 > 4    # refreshed bounds discriminate the region


def test_multi_range_store_propagates_refresh_flag():
    from repro.core.ranges import build_multi_range_store
    rng = np.random.default_rng(7)
    ms = build_multi_range_store(
        rng.uniform(0, 1, (500, 2)).astype(np.float32))
    ms2 = ms.append(rng.uniform(50, 51, (400, 2)).astype(np.float32))
    assert ms2.bounds_refreshed
    assert all(s.bounds_refreshed for s in ms2.stores)
