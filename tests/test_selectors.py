import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.labels import build_label_store, padded_vec_labels
from repro.core.ranges import build_range_store
from repro.core import selectors as S

pytestmark = pytest.mark.fast


@pytest.fixture(scope="module")
def stores():
    rng = np.random.default_rng(0)
    n, n_labels = 800, 20
    counts = rng.integers(1, 5, n)
    flat = rng.integers(0, n_labels, counts.sum()).astype(np.int32)
    offsets = np.zeros(n + 1, np.int64)
    np.cumsum(counts, out=offsets[1:])
    ls = build_label_store(offsets, flat, n_labels)
    values = rng.uniform(0, 100, n).astype(np.float32)
    rs = build_range_store(values)
    mem = S.InMemory(blooms=jnp.asarray(ls.blooms),
                     bucket_codes=jnp.asarray(rs.bucket_codes))
    rec_labels = jnp.asarray(padded_vec_labels(ls, 8))
    rec_values = jnp.asarray(values)
    return ls, rs, mem, rec_labels, rec_values


def _exact_label_or(ls, labels, vec):
    mine = set(ls.labels_of(vec).tolist())
    return bool(mine & set(labels))


def _exact_label_and(ls, labels, vec):
    mine = set(ls.labels_of(vec).tolist())
    return set(labels) <= mine


def test_label_or_no_false_negatives(stores):
    ls, rs, mem, rec_labels, rec_values = stores
    sel = S.LabelOrSelector(ls, [3, 7])
    plan = sel.plan(ql=8, cap=2048)
    ids = jnp.arange(ls.n_vectors)
    approx = np.asarray(S.is_member_approx(plan.qfilter, ids, mem))
    exact = np.asarray(S.is_member(plan.qfilter, rec_labels, rec_values))
    for v in range(ls.n_vectors):
        truth = _exact_label_or(ls, [3, 7], v)
        assert exact[v] == truth
        if truth:
            assert approx[v], f"false negative at {v}"


def test_label_and_no_false_negatives(stores):
    ls, rs, mem, rec_labels, rec_values = stores
    sel = S.LabelAndSelector(ls, [1, 2])
    plan = sel.plan(ql=8, cap=2048)
    ids = jnp.arange(ls.n_vectors)
    approx = np.asarray(S.is_member_approx(plan.qfilter, ids, mem))
    exact = np.asarray(S.is_member(plan.qfilter, rec_labels, rec_values))
    for v in range(ls.n_vectors):
        truth = _exact_label_and(ls, [1, 2], v)
        assert exact[v] == truth
        if truth:
            assert approx[v]


def test_range_no_false_negatives(stores):
    ls, rs, mem, rec_labels, rec_values = stores
    sel = S.RangeSelector(rs, 20.0, 40.0)
    plan = sel.plan(ql=8, cap=2048)
    ids = jnp.arange(rs.n_vectors)
    approx = np.asarray(S.is_member_approx(plan.qfilter, ids, mem))
    exact = np.asarray(S.is_member(plan.qfilter, rec_labels, rec_values))
    vals = np.asarray(rec_values)
    truth = (vals >= 20.0) & (vals < 40.0)
    np.testing.assert_array_equal(exact, truth)
    assert np.all(approx[truth])
    # approx must be a reasonably tight superset (bucket granularity)
    assert approx.sum() <= truth.sum() + 2 * (rs.n_vectors / 256) + 16


def test_selectivity_estimates(stores):
    ls, rs, *_ = stores
    sel = S.RangeSelector(rs, 20.0, 40.0)
    est = sel.selectivity()
    actual = float(np.mean((rs.values >= 20) & (rs.values < 40)))
    assert abs(est - actual) < 0.05

    lsel = S.LabelOrSelector(ls, [0, 1])
    actual_l = np.mean([_exact_label_or(ls, [0, 1], v)
                        for v in range(ls.n_vectors)])
    assert abs(lsel.selectivity() - actual_l) < 0.12


def test_combinators(stores):
    ls, rs, mem, rec_labels, rec_values = stores
    for comb, op in ((S.AndSelector, np.logical_and),
                     (S.OrSelector, np.logical_or)):
        sel = comb([S.LabelOrSelector(ls, [3]), S.RangeSelector(rs, 10., 60.)])
        plan = sel.plan(ql=8, cap=2048)
        exact = np.asarray(S.is_member(plan.qfilter, rec_labels, rec_values))
        lab = np.array([_exact_label_or(ls, [3], v)
                        for v in range(ls.n_vectors)])
        vals = np.asarray(rec_values)
        rng_ok = (vals >= 10) & (vals < 60)
        np.testing.assert_array_equal(exact, op(lab, rng_ok))
        approx = np.asarray(S.is_member_approx(
            plan.qfilter, jnp.arange(ls.n_vectors), mem))
        assert np.all(approx[op(lab, rng_ok)])   # no false negatives


def test_prefilter_supersets(stores):
    ls, rs, *_ = stores
    sel = S.LabelAndSelector(ls, [0, 1])
    ids, pages = sel.pre_filter_approx()
    assert pages >= 1
    truth = {v for v in range(ls.n_vectors) if _exact_label_and(ls, [0, 1], v)}
    assert truth <= set(ids.tolist())   # superset guarantee

    rsel = S.RangeSelector(rs, 20.0, 40.0)
    ids, pages = rsel.pre_filter_approx()
    vals = rs.values
    truth_r = set(np.where((vals >= 20) & (vals < 40))[0].tolist())
    assert truth_r == set(ids.tolist())   # range scan is exact
