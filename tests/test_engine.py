"""End-to-end behaviour of the filtered ANN engine — the paper's system."""
import numpy as np
import pytest

from repro.core import engine as eng
from repro.core.selectors import stack_filters
from repro.data.synth import make_selectors


pytestmark = pytest.mark.fast   # build shared via the session-scoped cache


@pytest.fixture(scope="module")
def built(shared_ds, shared_engine):
    return shared_ds, shared_engine


def _gt_for(ds, e, selectors, k=10):
    vectors = np.asarray(e.store.vectors)
    rl = np.asarray(e.store.rec_labels)
    rv = np.asarray(e.store.rec_values)
    gts = []
    for i, sel in enumerate(selectors):
        plan = sel.plan(e.config.ql, e.config.cap)
        q = ds.queries[i]
        if q.shape[0] != vectors.shape[1]:
            q = np.pad(q, (0, vectors.shape[1] - q.shape[0]))
        gts.append(eng.brute_force_filtered(vectors, rl, rv, plan.qfilter,
                                            q, k))
    return gts


@pytest.mark.parametrize("workload", ["label_or", "label_and", "range",
                                      "hybrid"])
def test_speculative_recall(built, workload):
    ds, e = built
    sels = make_selectors(ds, e, workload)
    scfg = eng.SearchConfig(k=10, l=48, max_hops=400, max_pool=512)
    ids, dists, stats = e.search(ds.queries, sels, scfg)
    gts = _gt_for(ds, e, sels)
    recalls = [eng.recall_at_k(ids[i], gts[i], 10) for i in range(len(sels))]
    assert np.mean(recalls) >= 0.85, \
        f"{workload}: recall {np.mean(recalls):.3f} routes {stats.mechanism}"


def test_results_are_valid(built):
    """Every returned id must satisfy the exact constraint (verification)."""
    ds, e = built
    sels = make_selectors(ds, e, "label_or")
    ids, dists, stats = e.search(ds.queries, sels,
                                 eng.SearchConfig(k=10, l=32))
    from repro.core.selectors import is_member
    import jax.numpy as jnp
    for i, sel in enumerate(sels):
        plan = sel.plan(e.config.ql, e.config.cap)
        got = ids[i][ids[i] >= 0]
        if got.size == 0:
            continue
        ok = np.asarray(is_member(plan.qfilter,
                                  e.store.rec_labels[jnp.asarray(got)],
                                  e.store.rec_values[jnp.asarray(got)]))
        assert np.all(ok), f"query {i} returned invalid ids"


def test_io_accounting_positive(built):
    ds, e = built
    sels = make_selectors(ds, e, "range")
    ids, dists, stats = e.search(ds.queries, sels,
                                 eng.SearchConfig(k=10, l=32))
    assert np.all(stats.io_pages > 0)
    assert np.all(stats.est_io_pages > 0)


def test_policies_agree_on_results_quality(built):
    """Baselines find valid results too; speculative reads fewer pages than
    strict in-filtering (the paper's core claim)."""
    ds, e = built
    sels = make_selectors(ds, e, "label_or")
    gts = _gt_for(ds, e, sels)

    spec_cfg = eng.SearchConfig(k=10, l=48, max_hops=400, policy="speculative")
    _, _, spec_stats = e.search(ds.queries, sels, spec_cfg)

    strict_cfg = eng.SearchConfig(k=10, l=48, max_hops=400, policy="strict_in")
    sids, _, strict_stats = e.search(ds.queries, sels, strict_cfg)

    spec_io = spec_stats.io_pages.sum()
    strict_io = strict_stats.io_pages.sum()
    assert spec_io < strict_io, (spec_io, strict_io)


def test_route_distribution_sane(built):
    ds, e = built
    sels = make_selectors(ds, e, "hybrid")
    _, _, stats = e.search(ds.queries, sels, eng.SearchConfig(k=10, l=32))
    assert set(stats.mechanism) <= {"pre", "in", "post"}


def test_engine_calibrate_roundtrip(built):
    """engine.calibrate installs measured per-hop constants for _route
    (cost_model.Calibration) and cleanly reverts/refuses."""
    ds, e = built
    assert e.calibration is None
    payload = {"modes": {
        "spec_in": {"mean_hops": 80.0, "mean_dist_comps": 560.0,
                    "mean_approx_checks": 24_000.0},
        "post": {"mean_hops": 50.0, "mean_dist_comps": 300.0,
                 "mean_approx_checks": 0.0}}}
    try:
        assert e.calibrate(payload)
        assert abs(e.calibration.spec_in.dist_per_hop - 7.0) < 1e-9
        # routing still works end-to-end with calibration installed
        sels = make_selectors(ds, e, "range")[:4]
        ids, _, stats = e.search(ds.queries[:4], sels,
                                 eng.SearchConfig(k=5, l=16, max_hops=60,
                                                  max_pool=128))
        assert ids.shape == (4, 5)
        assert not e.calibrate("/nonexistent/BENCH_search.json")
        # malformed payloads degrade to uncalibrated, like unreadable paths
        assert not e.calibrate({"modes": {"spec_in": {"mean_hops": 1.0}}})
        assert e.calibration is None
    finally:
        e.calibrate(None)          # shared engine: leave no state behind
    assert e.calibration is None
