"""Resilient serving tier tests: thread-safe Session handles, the
degrade ladder's cost/recall contracts, deadline-aware admission,
backpressure, shedding, and the threaded SearchServer end-to-end.

The load-degrade contract under test mirrors PR 7's fault ladder: every
admitted request — including ones served at a degraded rung or through
the approximate full-scan path — returns only exactly-verified results
(no false positives) and the approximate gating only over-admits (no
false negatives), so shedding/degradation trades latency and recall
headroom, never correctness.
"""
import dataclasses
import threading
import time

import numpy as np
import pytest

from repro.api import (DeadlineExceeded, Index, IndexConfig, Num, Overloaded,
                       SearchConfig, SearchRequest, Session, SessionConfig,
                       Tag)
from repro.api.session import PendingSearch
from repro.core import cost_model, search as search_mod
from repro.core.engine import apply_rung, scan_rerank
from repro.serve.server import SearchServer, ServerConfig

pytestmark = [pytest.mark.serve, pytest.mark.fast]

N = 900
N_CAT = 12


@pytest.fixture(scope="module")
def corpus():
    rng = np.random.default_rng(11)
    vectors = rng.normal(0, 1, (N, 24)).astype(np.float32)
    cats = [sorted(set(int(x) for x in
                       rng.integers(0, N_CAT, rng.integers(1, 4))))
            for _ in range(N)]
    values = rng.uniform(0, 100, N).astype(np.float32)
    metadata = [{"cat": c, "value": float(v)}
                for c, v in zip(cats, values)]
    return vectors, metadata, cats, values


@pytest.fixture(scope="module")
def index(corpus):
    vectors, metadata, *_ = corpus
    return Index.build(
        vectors, metadata,
        IndexConfig(r=12, r_dense=64, l_build=24, pq_m=8),
        defaults=SearchConfig(k=10, l=32, max_hops=128))


def make_requests(corpus, n=8, seed=3, **kw):
    vectors, _, cats, _ = corpus
    rng = np.random.default_rng(seed)
    idxs = rng.integers(0, N, n)
    return [SearchRequest(query=vectors[i], filter=Tag("cat") == cats[i][0],
                          **kw) for i in idxs]


def brute_valid(corpus, cat):
    _, _, cats, _ = corpus
    return {i for i, c in enumerate(cats) if cat in c}


# ---------------------------------------------------------------------------
# Degrade ladder: cost model
# ---------------------------------------------------------------------------

def test_effective_ladder_monotone(index):
    req = SearchRequest(query=np.zeros(24, np.float32),
                        filter=Tag("cat") == 3)
    sel = index.compile_filter(req.filter)
    eng = index.engine
    plan = sel.plan(eng.config.ql, eng.config.cap, eng.config.qr)
    ci = eng.cost_inputs(plan, index.defaults)
    eff = [c for _, c in cost_model.ladder_costs(ci)]
    assert all(a >= b - 1e-9 for a, b in zip(eff, eff[1:]))
    # the non-approx prefix is monotone even in raw cost: L shrinks and
    # read-ahead only tightens rung over rung
    raw = [c for _, c in cost_model.ladder_costs(ci, effective=False)]
    k = sum(not r.approx for r in cost_model.DEGRADE_LADDER)
    assert all(a >= b - 1e-9 for a, b in zip(raw[:k], raw[1:k]))
    # effective = running min of raw, and never above raw
    assert all(e <= r + 1e-9 for e, r in zip(eff, raw))


def test_estimate_cost_matches_routed_total(index):
    req = SearchRequest(query=np.zeros(24, np.float32),
                        filter=Tag("cat") == 3)
    sel = index.compile_filter(req.filter)
    full = index.engine.estimate_cost(sel, index.defaults)
    r0 = index.engine.estimate_cost(sel, index.defaults,
                                    rung=cost_model.DEGRADE_LADDER[0])
    assert full > 0
    # rung 0 adds only the read-ahead overage term on top of the route
    assert r0 >= full


def test_apply_rung_floors():
    scfg = SearchConfig(k=10, l=32, max_hops=128)
    for rung in cost_model.DEGRADE_LADDER:
        rc = apply_rung(scfg, rung)
        assert rc.l >= scfg.k
        assert rc.max_hops >= 8
    lean = apply_rung(scfg, cost_model.DEGRADE_LADDER[1])
    assert (lean.l, lean.max_hops) == (scfg.l, scfg.max_hops)
    assert lean.prefetch_depth == 1 and lean.hop_chunk == 16


# ---------------------------------------------------------------------------
# Approximate full-scan rung: no false negatives, no false positives
# ---------------------------------------------------------------------------

def test_approx_scan_no_false_positives(index, corpus):
    reqs = make_requests(corpus, n=6, k=10)
    for req, res in zip(reqs, index.approx_scan_batch(reqs)):
        valid = brute_valid(corpus, req.filter.value)
        for i, _, m in res.matches:
            assert i in valid
            assert m is not None


def test_approx_scan_no_false_negatives_exhaustive(index, corpus):
    """A filter with ≤ rerank valid records: the gated scan must return
    the *exact* valid top-k — the approximate gate only over-admits, the
    verifier restores exactness, so nothing valid can be lost."""
    vectors, _, _, values = corpus
    vs = np.sort(values)
    lo, hi = float(vs[0]), float(vs[14])     # 15 valid records « rerank
    valid = [i for i, v in enumerate(values) if lo <= v <= hi]
    assert len(valid) <= scan_rerank(index.defaults)
    q = vectors[5]
    exact = sorted(valid, key=lambda i: float(
        np.sum((vectors[i] - q) ** 2)))[:index.defaults.k]
    req = SearchRequest(query=q, filter=Num("value").between(lo, hi))
    res = index.approx_scan_batch([req])[0]
    got = [i for i, _, _ in res.matches]
    assert got == exact


def test_scan_rung_server_serves_verified_results(index, corpus):
    """A server pinned to the scan rung (singleton ladder) still returns
    only exactly-verified matches."""
    reqs = make_requests(corpus, n=5, seed=9, k=10)
    ladder = (cost_model.DEGRADE_LADDER[-1],)
    with SearchServer(index, ServerConfig(max_batch=8, max_delay_s=0.001),
                      ladder=ladder) as srv:
        handles = [srv.submit(r) for r in reqs]
        for req, h in zip(reqs, handles):
            res = h.result(timeout=60)
            assert h.rung == "scan"
            valid = brute_valid(corpus, req.filter.value)
            for i, _, _ in res.matches:
                assert i in valid
        assert srv.stats().degraded_served >= len(reqs)


# ---------------------------------------------------------------------------
# deadline_us is inert on the search path
# ---------------------------------------------------------------------------

def test_deadline_none_bit_identical(index, corpus):
    reqs = make_requests(corpus, n=8, seed=5, k=10)
    base = index.search_batch(reqs)
    tagged = [dataclasses.replace(r, deadline_us=None) for r in reqs]
    again = index.search_batch(tagged)
    for a, b in zip(base, again):
        assert np.array_equal(a.ids, b.ids)
        assert np.array_equal(a.dists, b.dists)
    # deadline_us never leaks into the resolved SearchConfig
    with_dl = dataclasses.replace(reqs[0], deadline_us=5e6)
    assert "deadline_us" not in with_dl.overrides()
    assert index._resolve_scfg(with_dl) == index._resolve_scfg(reqs[0])


def test_server_unloaded_bit_identical_to_direct(index, corpus):
    """At zero pressure the server runs the full rung — results must be
    bitwise what a direct batched search returns."""
    reqs = make_requests(corpus, n=8, seed=7, k=10)
    direct = index.search_batch(reqs)
    with SearchServer(index, ServerConfig(max_batch=8,
                                          max_delay_s=0.05)) as srv:
        handles = [srv.submit(r) for r in reqs]
        served = [h.result(timeout=60) for h in handles]
    for h in handles:
        assert h.rung == "full"
    for a, b in zip(direct, served):
        assert np.array_equal(a.ids, b.ids)
        assert np.array_equal(a.dists, b.dists)


# ---------------------------------------------------------------------------
# Admission: backpressure + shedding
# ---------------------------------------------------------------------------

def test_overloaded_carries_retry_after(index, corpus):
    reqs = make_requests(corpus, n=4, seed=13)
    # a long batching window holds the worker while the tiny queue fills
    with SearchServer(index, ServerConfig(max_queue=2, max_batch=64,
                                          max_delay_s=5.0)) as srv:
        srv.submit(reqs[0])
        srv.submit(reqs[1])
        with pytest.raises(Overloaded) as ei:
            srv.submit(reqs[2])
        assert ei.value.retry_after_s > 0
        assert srv.stats().rejected_overload == 1
    # stop() drained the queue: both admitted requests resolved


def test_infeasible_deadline_shed_at_admission(index, corpus):
    req = make_requests(corpus, n=1, seed=17)[0]
    with SearchServer(index, ServerConfig()) as srv:
        with pytest.raises(DeadlineExceeded):
            srv.submit(dataclasses.replace(req, deadline_us=1e-3))
        st = srv.stats()
        assert st.shed_deadline == 1 and st.admitted == 0


def test_deadline_expires_in_queue_sheds_handle(index, corpus):
    req = make_requests(corpus, n=1, seed=19)[0]
    cfg = ServerConfig(max_batch=64, max_delay_s=0.25,
                       seed_us_per_cost=1e-3)
    with SearchServer(index, cfg) as srv:
        h = srv.submit(dataclasses.replace(req, deadline_us=2e3))
        with pytest.raises(DeadlineExceeded):
            h.result(timeout=60)
        assert srv.stats().shed_deadline == 1


def test_stats_probe_shape(index, corpus):
    with SearchServer(index, ServerConfig()) as srv:
        st = srv.stats()
        assert st.healthy and st.ready and not st.warmed
        assert st.queue_depth == 0 and st.in_flight == 0
        h = srv.submit(make_requests(corpus, n=1)[0])
        h.result(timeout=60)
        st = srv.stats()
        assert st.completed == 1 and st.p50_us > 0 and st.p99_us > 0
    assert not srv.stats().ready     # stopped servers fail readiness


def test_calibrate_service_model(index, corpus):
    with SearchServer(index, ServerConfig()) as srv:
        overhead, slope = srv.calibrate_service_model(
            make_requests(corpus, n=8))
        assert slope > 0 and overhead >= 0
        st = srv.stats()
        assert st.us_per_cost == pytest.approx(slope)
        assert st.overhead_us == pytest.approx(overhead)
        # a seeded model prices any nonzero work at a positive wall
        assert srv._predict_us(1.0) > 0


def test_tail_guard_tracks_slow_flushes(index):
    with SearchServer(index, ServerConfig()) as srv:
        with srv._lock:
            # fit a clean 100µs/unit line, then feed flushes that land
            # 2x over it: the tail guard must pick up the overrun
            for c in (10.0, 20.0, 30.0, 40.0):
                srv._refit_locked(c, c * 100.0)
            for c in (12.0, 22.0, 32.0, 42.0):
                srv._refit_locked(c, c * 200.0)
            guard = srv._tail_guard_us
            assert guard > 0.0
            # deadline-facing predictions carry exactly that margin
            assert srv._predict_tail_us(5.0) == pytest.approx(
                srv._predict_us(5.0) + guard)
        assert srv.stats().tail_guard_us == pytest.approx(guard)


# ---------------------------------------------------------------------------
# Thread-safe Session handles
# ---------------------------------------------------------------------------

def test_result_timeout_on_inflight_handle(index, corpus):
    sess = Session(index, SessionConfig(auto_flush=False))
    h = PendingSearch(sess, make_requests(corpus, n=1)[0])
    h._claimed = True       # simulate another thread's flush owning it
    t0 = time.monotonic()
    with pytest.raises(TimeoutError):
        h.result(timeout=0.05)
    assert time.monotonic() - t0 < 5


def test_result_waits_across_threads(index, corpus):
    sess = Session(index, SessionConfig(auto_flush=False))
    handles = sess.submit_many(make_requests(corpus, n=4, seed=23))
    got = {}

    def waiter():
        got["res"] = handles[-1].result(timeout=60)

    t = threading.Thread(target=waiter)
    # claim the batch before the waiter runs so its flush() sees an
    # empty queue and falls through to the event wait
    with sess._lock:
        batch, sess._pending = sess._pending, []
        for hh, _ in batch:
            hh._claimed = True
    t.start()
    time.sleep(0.05)
    sess._execute_isolated([hh for hh, _ in batch],
                           [sess.config.flush_retry_budget])
    t.join(60)
    assert not t.is_alive() and len(got["res"]) > 0


def test_concurrent_submit_result_threads(index, corpus):
    sess = Session(index, SessionConfig(max_batch=4, max_delay_s=0.0))
    reqs = make_requests(corpus, n=16, seed=29, k=10)
    direct = index.search_batch(reqs)
    errors = []
    results = [None] * len(reqs)

    def worker(i):
        try:
            results[i] = sess.submit(reqs[i]).result(timeout=120)
        except Exception as e:      # noqa: BLE001 - collected for assert
            errors.append(e)

    threads = [threading.Thread(target=worker, args=(i,))
               for i in range(len(reqs))]
    for t in threads:
        t.start()
    for t in threads:
        t.join(120)
    assert not errors
    for a, b in zip(direct, results):
        assert np.array_equal(a.ids, b.ids)
        assert np.array_equal(a.dists, b.dists)


def test_poisoned_batch_isolated_under_contention(index, corpus):
    sess = Session(index, SessionConfig(max_batch=6, max_delay_s=0.0))
    good = make_requests(corpus, n=10, seed=31)
    bad = SearchRequest(query=np.zeros(24, np.float32),
                        filter=Tag("no_such_field") == 1)
    outcomes = [None] * 11

    def worker(i, req):
        try:
            outcomes[i] = ("ok", sess.submit(req).result(timeout=120))
        except Exception as e:      # noqa: BLE001
            outcomes[i] = ("err", e)

    threads = [threading.Thread(target=worker, args=(i, r))
               for i, r in enumerate(good + [bad])]
    for t in threads:
        t.start()
    for t in threads:
        t.join(120)
    kinds = [o[0] for o in outcomes]
    assert kinds[:10] == ["ok"] * 10       # every good request resolved
    assert kinds[10] == "err"


# ---------------------------------------------------------------------------
# Warmup: rung variants pre-compiled
# ---------------------------------------------------------------------------

def test_warmup_covers_degrade_rungs(index, corpus):
    reqs = make_requests(corpus, n=4, seed=37)
    sess = Session(index, SessionConfig(auto_flush=False))
    sess.warmup(reqs)
    sizes = (search_mod.init_search._cache_size(),
             search_mod.run_hops._cache_size(),
             search_mod.finalize_search._cache_size())
    # re-serving the same mix at every rung must hit only warm caches
    scfgs = [index._resolve_scfg(r) for r in reqs]
    for rung in cost_model.DEGRADE_LADDER:
        rcfgs = [apply_rung(sc, rung) for sc in scfgs]
        if rung.approx:
            index.approx_scan_batch(reqs, scfgs=rcfgs, with_metadata=False)
        else:
            index.search_batch(reqs, scfgs=rcfgs, with_metadata=False)
    after = (search_mod.init_search._cache_size(),
             search_mod.run_hops._cache_size(),
             search_mod.finalize_search._cache_size())
    assert after == sizes


# ---------------------------------------------------------------------------
# Async active-count readback
# ---------------------------------------------------------------------------

def test_async_readback_bit_identical(index, corpus, monkeypatch):
    reqs = make_requests(corpus, n=6, seed=41, k=10)
    base = index.search_batch(reqs)
    orig = search_mod.filtered_search_pipelined

    def sync_driver(*args, **kw):
        kw["async_readback"] = False
        return orig(*args, **kw)

    monkeypatch.setattr(search_mod, "filtered_search_pipelined",
                        sync_driver)
    sync = index.search_batch(reqs)
    for a, b in zip(base, sync):
        assert np.array_equal(a.ids, b.ids)
        assert np.array_equal(a.dists, b.dists)
        assert a.stats.hops == b.stats.hops
        assert a.stats.dist_comps == b.stats.dist_comps
