import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import bloom
from repro.core.labels import build_label_store, padded_vec_labels

pytestmark = pytest.mark.fast


def _toy_store():
    # 5 vectors, labels: [0], [0,1], [2], [1,2,3], []
    offsets = np.array([0, 1, 3, 4, 7, 7], np.int64)
    flat = np.array([0, 0, 1, 2, 1, 2, 3], np.int32)
    return build_label_store(offsets, flat, n_labels=4)


def test_inverted_index():
    s = _toy_store()
    np.testing.assert_array_equal(s.postings(0), [0, 1])
    np.testing.assert_array_equal(s.postings(1), [1, 3])
    np.testing.assert_array_equal(s.postings(2), [2, 3])
    np.testing.assert_array_equal(s.postings(3), [3])
    assert s.label_counts.tolist() == [2, 2, 2, 1]


def test_bloom_no_false_negatives():
    s = _toy_store()
    for vec in range(5):
        for l in s.labels_of(vec):
            req = bloom.label_bits(int(l), s.k_hashes)
            assert bool(bloom.bloom_pass(jnp.asarray(s.blooms[vec:vec + 1]),
                                         req)[0])


def test_bloom_empty_vector_rejects():
    s = _toy_store()
    # vector 4 has no labels -> bloom word is 0; any nonzero mask fails
    req = bloom.label_bits(0, s.k_hashes)
    assert not bool(bloom.bloom_pass(jnp.asarray(s.blooms[4:5]), req)[0])


def test_padded_labels():
    s = _toy_store()
    padded = padded_vec_labels(s, max_labels=4)
    assert padded.shape == (5, 4)
    assert set(padded[3].tolist()) == {1, 2, 3, -1}
    assert padded[4].tolist() == [-1, -1, -1, -1]


def test_fp_rate_monotone_in_labels():
    lo = bloom.bloom_fp_rate(2.0)
    hi = bloom.bloom_fp_rate(12.0)
    assert 0.0 <= lo < hi < 1.0
