"""Tests for the unified ``repro.api`` query layer: filter-DSL compilation
(property-style agreement with a brute-force evaluator + no-false-negative
checks), Index save/load round-trips, per-request overrides, and the
Session batch scheduler."""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (Index, IndexConfig, Num, SearchConfig, SearchRequest,
                       Session, SessionConfig, Tag, compile_expr)
from repro.api.filters import And, NumRange, Or, TagIs, eval_mask
from repro.core.selectors import (AndSelector, LabelAndSelector,
                                  LabelOrSelector, MaskSelector, OrSelector,
                                  RangeSelector, is_member, is_member_approx)

pytestmark = pytest.mark.fast

N = 2500
N_CAT = 14
LANGS = ["en", "de", "fr", "ja"]


@pytest.fixture(scope="module")
def corpus():
    rng = np.random.default_rng(7)
    vectors = rng.normal(0, 1, (N, 24)).astype(np.float32)
    cats = [sorted(set(int(x) for x in
                       rng.integers(0, N_CAT, rng.integers(1, 4))))
            for _ in range(N)]
    langs = [str(rng.choice(LANGS)) for _ in range(N)]
    values = rng.uniform(0, 100, N).astype(np.float32)
    metadata = [{"cat": c, "lang": l, "value": float(v)}
                for c, l, v in zip(cats, langs, values)]
    return vectors, metadata, cats, langs, values


@pytest.fixture(scope="module")
def index(corpus):
    vectors, metadata, *_ = corpus
    return Index.build(
        vectors, metadata,
        IndexConfig(r=16, r_dense=120, l_build=32, pq_m=8),
        defaults=SearchConfig(k=10, l=32, max_hops=250))


# ---------------------------------------------------------------------------
# Filter DSL: compilation targets
# ---------------------------------------------------------------------------

def test_compile_targets(index):
    cases = [
        (Tag("cat") == 3, LabelOrSelector),
        (Tag("cat").isin([1, 2, 5]), LabelOrSelector),
        ((Tag("cat") == 1) & (Tag("cat") == 2), LabelAndSelector),
        (Num("value").between(10, 50), RangeSelector),
        ((Tag("cat") == 3) & Num("value").between(10, 50), AndSelector),
        ((Tag("cat") == 3) | Num("value").between(10, 50), OrSelector),
        # inexpressible: OR of AND groups -> exact mask fallback
        (((Tag("cat") == 1) & (Tag("lang") == "en"))
         | ((Tag("cat") == 2) & (Tag("lang") == "de")), MaskSelector),
        # disjoint range union -> fallback
        (Num("value").between(0, 10) | Num("value").between(60, 70),
         MaskSelector),
    ]
    for expr, want in cases:
        sel = compile_expr(expr, index)
        assert isinstance(sel, want), (expr, type(sel).__name__)


def test_compile_rejects_unknown_numeric_field(index):
    with pytest.raises(ValueError, match="not indexed"):
        compile_expr(Num("nope") < 5.0, index)
    # ground_truth must validate the field too, not silently evaluate
    with pytest.raises(ValueError, match="not indexed"):
        index.ground_truth(SearchRequest(query=np.zeros(24, np.float32),
                                         filter=Num("nope") < 5.0))


def test_num_boundary_exact_in_float32(index, corpus):
    """<=, >, == nudge boundaries in float32 space: a point query on an
    exactly-stored value must agree between the device exact-verify path
    and the host scan (policies post vs strict_pre)."""
    _, _, _, _, values = corpus
    x = float(values[42])                    # an exactly-stored float32
    expr = Num("value") == x
    sel = compile_expr(expr, index)
    plan = sel.plan(index.config.ql, index.config.cap)
    got = np.asarray(is_member(plan.qfilter, index.store.rec_labels,
                               index.store.rec_values))
    want = np.asarray(values) == np.float32(x)
    np.testing.assert_array_equal(got, want)
    assert got.sum() >= 1
    # <= boundary record included, > excludes it
    le = compile_expr(Num("value") <= x, index) \
        .plan(index.config.ql, index.config.cap)
    gt_ = compile_expr(Num("value") > x, index) \
        .plan(index.config.ql, index.config.cap)
    le_mask = np.asarray(is_member(le.qfilter, index.store.rec_labels,
                                   index.store.rec_values))
    gt_mask = np.asarray(is_member(gt_.qfilter, index.store.rec_labels,
                                   index.store.rec_values))
    assert le_mask[42] and not gt_mask[42]
    np.testing.assert_array_equal(le_mask | gt_mask, np.ones(N, bool))


def test_compile_rejects_field_handle(index):
    with pytest.raises(TypeError, match="field handle"):
        compile_expr(Tag("cat"), index)


# ---------------------------------------------------------------------------
# Property-style: random trees vs numpy brute force
# ---------------------------------------------------------------------------

def _brute_eval(expr, cats, langs, values):
    """Independent evaluator over the raw metadata (no engine structures)."""
    if isinstance(expr, TagIs):
        if expr.field == "cat":
            return np.array([expr.value in c for c in cats])
        return np.array([l == expr.value for l in langs])
    if isinstance(expr, NumRange):
        return (values >= expr.lo) & (values < expr.hi)
    masks = [_brute_eval(c, cats, langs, values) for c in expr.children]
    out = masks[0]
    for m in masks[1:]:
        out = (out & m) if isinstance(expr, And) else (out | m)
    return out


def _random_expr(rng, depth=0):
    r = rng.random()
    if depth >= 2 or r < 0.45:
        kind = rng.integers(0, 3)
        if kind == 0:
            return Tag("cat") == int(rng.integers(0, N_CAT + 2))  # may miss
        if kind == 1:
            return Tag("lang") == str(rng.choice(LANGS + ["xx"]))
        lo = float(rng.uniform(0, 90))
        return Num("value").between(lo, lo + float(rng.uniform(1, 60)))
    n_children = int(rng.integers(2, 4))
    children = [_random_expr(rng, depth + 1) for _ in range(n_children)]
    op = And.of if rng.random() < 0.5 else Or.of
    return op(*children)


def test_random_trees_exact_and_no_false_negative(index, corpus):
    """Compiled filters agree with brute force; approx is a superset."""
    _, _, cats, langs, values = corpus
    rng = np.random.default_rng(11)
    ids = jnp.arange(N, dtype=jnp.int32)
    rl = index.store.rec_labels
    rv = index.store.rec_values
    n_fallback = 0
    for trial in range(30):
        expr = _random_expr(rng)
        want = _brute_eval(expr, cats, langs, values)
        sel = compile_expr(expr, index)
        if isinstance(sel, MaskSelector):
            n_fallback += 1
            got = np.zeros(N, bool)
            got[sel.valid_ids] = True
            np.testing.assert_array_equal(got, want, err_msg=repr(expr))
            continue
        plan = sel.plan(index.config.ql, index.config.cap)
        got = np.asarray(is_member(plan.qfilter, rl, rv))
        np.testing.assert_array_equal(got, want, err_msg=repr(expr))
        approx = np.asarray(is_member_approx(plan.qfilter, ids,
                                             index.engine.mem))
        assert np.all(approx[want]), f"false negative in approx: {expr!r}"
    assert n_fallback > 0, "random trees never exercised the mask fallback"


def test_eval_mask_matches_brute(index, corpus):
    _, _, cats, langs, values = corpus
    rng = np.random.default_rng(3)
    for _ in range(10):
        expr = _random_expr(rng)
        mask, pages = eval_mask(expr, index)
        want = _brute_eval(expr, cats, langs, values)
        np.testing.assert_array_equal(mask, want, err_msg=repr(expr))
        assert pages >= 0


# ---------------------------------------------------------------------------
# DSL vs hand-built selectors: identical top-k across all five policies
# ---------------------------------------------------------------------------

POLICIES = ("speculative", "basefilter", "strict_in", "strict_pre", "post")


def test_dsl_matches_handbuilt_all_policies(index):
    rng = np.random.default_rng(5)
    q = rng.normal(0, 1, 24).astype(np.float32)
    ls, rs = index.label_store, index.range_store
    c3 = index.label_id("cat", 3)
    c5 = index.label_id("cat", 5)
    pairs = [
        (Tag("cat") == 3, LabelOrSelector(ls, [c3])),
        ((Tag("cat") == 3) & (Tag("cat") == 5),
         LabelAndSelector(ls, [c3, c5])),
        (Num("value").between(20, 70), RangeSelector(rs, 20.0, 70.0)),
        ((Tag("cat") == 3) & Num("value").between(20, 70),
         AndSelector([LabelOrSelector(ls, [c3]),
                      RangeSelector(rs, 20.0, 70.0)])),
        ((Tag("cat") == 3) | Num("value").between(20, 70),
         OrSelector([LabelOrSelector(ls, [c3]),
                     RangeSelector(rs, 20.0, 70.0)])),
    ]
    for policy in POLICIES:
        for expr, hand in pairs:
            r_dsl = index.search(SearchRequest(query=q, filter=expr,
                                               policy=policy))
            r_hand = index.search(SearchRequest(query=q, filter=hand,
                                                policy=policy))
            np.testing.assert_array_equal(
                r_dsl.ids, r_hand.ids,
                err_msg=f"{policy}: {expr!r}")


# ---------------------------------------------------------------------------
# Results: metadata resolution + validity
# ---------------------------------------------------------------------------

def test_result_metadata_and_validity(index, corpus):
    _, metadata, *_ = corpus
    rng = np.random.default_rng(9)
    q = rng.normal(0, 1, 24).astype(np.float32)
    expr = (Tag("lang") == "en") & Num("value").between(25, 75)
    res = index.search(SearchRequest(query=q, filter=expr))
    assert len(res) > 0
    for rec_id, dist, meta in res.matches:
        assert meta["lang"] == "en"
        assert 25 <= meta["value"] < 75
        assert meta["lang"] == metadata[rec_id]["lang"]
        assert np.isclose(meta["value"], metadata[rec_id]["value"])


def test_unfiltered_request(index):
    rng = np.random.default_rng(13)
    q = rng.normal(0, 1, 24).astype(np.float32)
    res = index.search(SearchRequest(query=q, k=5))
    assert len(res) == 5
    gt = index.ground_truth(SearchRequest(query=q, k=5))
    assert len(set(int(x) for x in res.ids) & set(int(x) for x in gt)) >= 4


# ---------------------------------------------------------------------------
# Per-request overrides
# ---------------------------------------------------------------------------

def test_per_request_overrides(index):
    rng = np.random.default_rng(17)
    qs = rng.normal(0, 1, (3, 24)).astype(np.float32)
    reqs = [
        SearchRequest(query=qs[0], filter=Tag("cat") == 2, k=3),
        SearchRequest(query=qs[1], filter=Tag("cat") == 2, k=7, l=64),
        SearchRequest(query=qs[2], filter=Tag("cat") == 2, policy="post"),
    ]
    results = index.search_batch(reqs)
    assert results[0].ids.shape == (3,)
    assert results[1].ids.shape == (7,)
    assert results[2].ids.shape == (10,)        # index default k
    assert results[2].stats.mechanism == "post"


# ---------------------------------------------------------------------------
# Save / load round-trip
# ---------------------------------------------------------------------------

def test_empty_batch(index):
    assert index.search_batch([]) == []
    results, stats = index.search_batch([], with_stats=True)
    assert results == [] and stats.mechanism == []


def test_build_rejects_missing_numeric_value():
    vecs = np.zeros((3, 8), np.float32)
    with pytest.raises(ValueError, match="missing the numeric field"):
        Index.build(vecs, [{"v": 1.0}, {"cat": 2}, {"v": 3.0}])


def test_build_dedupes_repeated_tags():
    rng = np.random.default_rng(0)
    vecs = rng.normal(0, 1, (40, 8)).astype(np.float32)
    meta = [{"cat": [1, 1, 2]} for _ in range(40)]
    idx = Index.build(vecs, meta,
                      IndexConfig(r=4, r_dense=16, l_build=8, pq_m=4))
    assert int(idx.label_store.label_counts[idx.label_id("cat", 1)]) == 40
    assert idx.record_metadata(0) == {"cat": [1, 2]}


def test_save_load_roundtrip(index, tmp_path):
    path = str(tmp_path / "idx")
    index.save(path)
    loaded = Index.load(path)
    assert loaded.vocab == index.vocab
    assert loaded.numeric_field == index.numeric_field
    assert loaded.defaults == index.defaults
    rng = np.random.default_rng(21)
    q = rng.normal(0, 1, 24).astype(np.float32)
    expr = (Tag("cat") == 4) | Num("value").between(5, 15)
    for policy in ("speculative", "post"):
        req = SearchRequest(query=q, filter=expr, policy=policy)
        a = index.search(req)
        b = loaded.search(req)
        np.testing.assert_array_equal(a.ids, b.ids)
        np.testing.assert_allclose(a.dists, b.dists, rtol=1e-6)


# ---------------------------------------------------------------------------
# Session scheduler
# ---------------------------------------------------------------------------

def _requests(n, seed=0):
    rng = np.random.default_rng(seed)
    qs = rng.normal(0, 1, (n, 24)).astype(np.float32)
    return [SearchRequest(query=qs[i],
                          filter=Tag("cat") == int(rng.integers(0, N_CAT)),
                          k=4)
            for i in range(n)]


def test_session_flushes_on_batch_size(index):
    s = Session(index, SessionConfig(max_batch=4, max_delay_s=1e9))
    handles = [s.submit(r) for r in _requests(4)]
    assert s.pending == 0 and s.n_batches == 1
    assert all(h.done for h in handles)


def test_session_result_forces_flush(index):
    s = Session(index, SessionConfig(max_batch=100, max_delay_s=1e9))
    handles = s.submit_many(_requests(3, seed=1))
    assert s.pending == 3 and not handles[0].done
    res = handles[0].result()                   # demand -> flush
    assert s.pending == 0 and all(h.done for h in handles)
    assert res.ids.shape == (4,)


def test_session_deadline_flush(index):
    s = Session(index, SessionConfig(max_batch=100, max_delay_s=0.0))
    s.submit(_requests(1, seed=2)[0])
    # zero deadline: the next admission sees the expired deadline
    s.submit(_requests(1, seed=3)[0])
    assert s.pending <= 1
    s.flush()
    assert s.pending == 0


def test_session_context_manager_flushes(index):
    with Session(index, SessionConfig(max_batch=100, max_delay_s=1e9)) as s:
        handles = s.submit_many(_requests(2, seed=4))
    assert all(h.done for h in handles)
    assert s.n_flushed == 2


def test_session_poisoned_batch_isolated(index):
    """A bad request fails alone: every other handle in its flush still
    resolves (bisect isolation), and the flush itself returns normally."""
    s = Session(index, SessionConfig(max_batch=100, max_delay_s=1e9))
    good = s.submit_many(_requests(2, seed=6))
    bad = s.submit(SearchRequest(query=_requests(1)[0].query,
                                 filter=Tag("cat")))      # bare handle
    assert s.flush() == 3
    assert s.pending == 0
    for h in good:
        assert h.done
        assert h.result().ids.shape == (4,)
    assert bad.done
    with pytest.raises(TypeError, match="field handle"):
        bad.result()
    # the session stays usable afterwards
    h2 = s.submit(_requests(1, seed=8)[0])
    s.flush()
    assert h2.result().ids.shape == (4,)


def test_session_failed_batch_fails_every_handle_legacy(index):
    """isolate_failures=False keeps the old contract: the whole batch
    fails with the execution error and flush propagates it."""
    s = Session(index, SessionConfig(max_batch=100, max_delay_s=1e9,
                                     isolate_failures=False))
    good = s.submit_many(_requests(2, seed=6))
    bad = s.submit(SearchRequest(query=_requests(1)[0].query,
                                 filter=Tag("cat")))      # bare handle
    with pytest.raises(TypeError, match="field handle"):
        s.flush()
    assert s.pending == 0
    for h in (*good, bad):
        assert h.done
        with pytest.raises(TypeError, match="field handle"):
            h.result()


def test_session_flush_retry_budget_exhaustion(index):
    """A budget of 1 is spent by the first failing attempt: the batch is
    failed wholesale with the budget error (chained to the cause) rather
    than re-executing without bound."""
    s = Session(index, SessionConfig(max_batch=100, max_delay_s=1e9,
                                     flush_retry_budget=1))
    handles = s.submit_many(_requests(2, seed=6))
    s.submit(SearchRequest(query=_requests(1)[0].query,
                           filter=Tag("cat")))
    s.flush()
    for h in handles:
        assert h.done
        with pytest.raises(RuntimeError, match="retry budget exhausted"):
            h.result()


def test_pending_result_reraises_unresolved_flush_error(index, monkeypatch):
    """If the flush raises without resolving this handle, result() must
    re-raise that error instead of tripping a bare assert."""
    s = Session(index, SessionConfig(max_batch=100, max_delay_s=1e9,
                                     auto_flush=False))
    h = s.submit(_requests(1, seed=9)[0])

    def boom():
        raise RuntimeError("flush exploded before executing")

    monkeypatch.setattr(s, "flush", boom)
    with pytest.raises(RuntimeError, match="flush exploded"):
        h.result()
    assert not h.done

    # a flush that completes without ever executing the handle surfaces a
    # real error too (never a bare assert)
    s2 = Session(index, SessionConfig(max_batch=100, max_delay_s=1e9,
                                      auto_flush=False))
    h2 = s2.submit(_requests(1, seed=10)[0])
    s2._pending.clear()                   # simulate a lost request
    with pytest.raises(RuntimeError, match="never resolved"):
        h2.result()


def test_make_selectors_resolves_renumbered_labels():
    """Dataset label values must resolve through the Index vocabulary
    (Index.build renumbers tags by first appearance), so a workload
    selector's posting count must equal the dataset's true frequency."""
    from repro.data.synth import make_filtered_dataset, make_selectors
    ds = make_filtered_dataset(n=300, d=8, n_queries=8, n_labels=40,
                               seed=2)
    sub = Index.build(ds.vectors, ds.metadata(),
                      IndexConfig(r=8, r_dense=40, l_build=16, pq_m=4))
    rec_sets = [set(ds.label_flat[s:e]) for s, e in
                zip(ds.label_offsets[:-1], ds.label_offsets[1:])]
    for i, sel in enumerate(make_selectors(ds, sub, "label")):
        lab_val = ds.query_labels[i][0]
        want = sum(1 for rs in rec_sets if lab_val in rs)
        if sel.labels:
            assert int(sel._counts[0]) == want, (i, lab_val)
        else:
            assert want == 0       # unseen label resolved to empty selector


def test_session_groups_mixed_mechanisms(index):
    """Requests routed to different mechanisms batch in one flush."""
    rng = np.random.default_rng(23)
    qs = rng.normal(0, 1, (4, 24)).astype(np.float32)
    reqs = [
        SearchRequest(query=qs[0], filter=Tag("cat") == 1, k=4),
        SearchRequest(query=qs[1], filter=None, k=4),
        SearchRequest(query=qs[2],
                      filter=Num("value").between(40, 41), k=4),
        SearchRequest(query=qs[3],
                      filter=((Tag("cat") == 1) & (Tag("lang") == "en"))
                      | ((Tag("cat") == 2) & (Tag("lang") == "de")), k=4),
    ]
    s = Session(index, SessionConfig(max_batch=4, max_delay_s=1e9))
    handles = s.submit_many(reqs)
    assert s.n_batches == 1
    mechs = [h.result().stats.mechanism for h in handles]
    assert set(mechs) <= {"pre", "in", "post"}
    assert handles[3].result().stats.mechanism == "pre"   # forced fallback
