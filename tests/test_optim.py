import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train import optim


def _toy_params(key):
    k1, k2 = jax.random.split(key)
    return {"w": jax.random.normal(k1, (32, 48)),
            "b": jnp.zeros((48,)),
            "nested": {"u": jax.random.normal(k2, (17, 5))}}


def _quad_loss(params, x):
    y = jnp.tanh(x @ params["w"]) + params["b"]
    z = y[:, :5] @ params["nested"]["u"].T
    return jnp.mean(z ** 2)


@pytest.mark.parametrize("int8", [False, True])
def test_adamw_converges(int8):
    cfg = optim.OptConfig(lr=3e-2, warmup_steps=5, total_steps=200,
                          weight_decay=0.0, int8_moments=int8)
    params = _toy_params(jax.random.PRNGKey(0))
    state = optim.init_opt_state(params, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (64, 32))

    @jax.jit
    def step(params, state):
        loss, grads = jax.value_and_grad(_quad_loss)(params, x)
        params, state, m = optim.adamw_update(grads, params, state, cfg)
        return params, state, loss

    losses = []
    for _ in range(100):
        params, state, loss = step(params, state)
        losses.append(float(loss))
    assert losses[-1] < 0.2 * losses[0], (losses[0], losses[-1])


def test_q8_roundtrip_accuracy():
    rng = np.random.default_rng(0)
    for shape in [(128,), (7, 130), (3, 4, 257), (100,)]:
        x = jnp.asarray(rng.normal(0, 2.0, shape).astype(np.float32))
        q = optim.q8_quantize(x)
        back = optim.q8_dequantize(q)
        assert back.shape == x.shape
        err = np.abs(np.asarray(back) - np.asarray(x))
        tol = np.abs(np.asarray(x)).max() / 127 * 1.01
        assert err.max() <= tol + 1e-6


def test_q8_preserves_leading_shape():
    x = jnp.ones((5, 6, 200))
    q = optim.q8_quantize(x)
    assert q.q.shape[:2] == (5, 6)
    assert q.q.shape[-1] % optim.QBLOCK == 0
    assert q.scale.shape == (5, 6, q.q.shape[-1] // optim.QBLOCK)


def test_grad_clip():
    cfg = optim.OptConfig(lr=1e-3, clip_norm=1.0)
    params = {"w": jnp.zeros((4,))}
    state = optim.init_opt_state(params, cfg)
    grads = {"w": jnp.full((4,), 100.0)}
    new_params, state, metrics = optim.adamw_update(grads, params, state, cfg)
    assert float(metrics["grad_norm"]) > 1.0
    # post-clip effective step is bounded by ~lr
    assert np.all(np.abs(np.asarray(new_params["w"])) < 2 * cfg.lr)


def test_lr_schedule_shape():
    cfg = optim.OptConfig(lr=1.0, warmup_steps=10, total_steps=100,
                          min_lr_frac=0.1)
    lrs = [float(optim.lr_at(jnp.asarray(s), cfg)) for s in range(0, 100, 5)]
    assert lrs[0] < 0.2                      # warmup starts low
    assert max(lrs) <= 1.0 + 1e-6
    assert lrs[-1] < 0.35                    # decays toward min_lr_frac
    assert abs(lrs[2] - 1.0) < 0.1           # peak after warmup
