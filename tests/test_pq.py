import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import pq


@pytest.fixture(scope="module")
def data():
    rng = np.random.default_rng(0)
    return rng.normal(0, 1, (2000, 32)).astype(np.float32)


def test_train_encode_shapes(data):
    cb = pq.train_pq(jax.random.PRNGKey(0), jnp.asarray(data), m=8)
    assert cb.centroids.shape == (8, 256, 4)
    codes = pq.encode_pq(cb, jnp.asarray(data))
    assert codes.shape == (2000, 8)
    assert codes.dtype == jnp.uint8


def test_reconstruction_reduces_error(data):
    cb = pq.train_pq(jax.random.PRNGKey(0), jnp.asarray(data), m=8, iters=10)
    codes = pq.encode_pq(cb, jnp.asarray(data))
    recon = np.asarray(pq.decode_pq(cb, codes))
    err = np.mean(np.sum((recon - data) ** 2, 1))
    base = np.mean(np.sum(data ** 2, 1))
    assert err < 0.7 * base  # quantization must beat the zero predictor


def test_adc_matches_reconstructed_distance(data):
    cb = pq.train_pq(jax.random.PRNGKey(0), jnp.asarray(data), m=8)
    codes = pq.encode_pq(cb, jnp.asarray(data))
    q = data[0]
    table = pq.distance_table(cb, jnp.asarray(q))
    adc = np.asarray(pq.adc_lookup(codes, table))
    recon = np.asarray(pq.decode_pq(cb, codes))
    exact = np.sum((recon - q[None, :]) ** 2, 1)
    np.testing.assert_allclose(adc, exact, rtol=1e-4, atol=1e-3)


def test_adc_ranks_near_neighbors_first(data):
    cb = pq.train_pq(jax.random.PRNGKey(1), jnp.asarray(data), m=8, iters=10)
    codes = pq.encode_pq(cb, jnp.asarray(data))
    q = data[123] + 0.01
    table = pq.distance_table(cb, jnp.asarray(q))
    adc = np.asarray(pq.adc_lookup(codes, table))
    exact = np.sum((data - q[None, :]) ** 2, 1)
    top_adc = set(np.argsort(adc)[:50].tolist())
    top_exact = set(np.argsort(exact)[:10].tolist())
    assert len(top_adc & top_exact) >= 5  # coarse agreement
