import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import checkpoint as ckpt


def _tree(key):
    k1, k2 = jax.random.split(key)
    return {"a": jax.random.normal(k1, (16, 8)),
            "b": {"c": jnp.arange(10, dtype=jnp.int32),
                  "d": jax.random.normal(k2, (3,))}}


def test_save_restore_roundtrip(tmp_path):
    tree = _tree(jax.random.PRNGKey(0))
    ckpt.save(str(tmp_path), 7, tree)
    assert ckpt.latest_step(str(tmp_path)) == 7
    target = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
    back = ckpt.restore(str(tmp_path), 7, target)
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_save_and_gc(tmp_path):
    tree = _tree(jax.random.PRNGKey(1))
    mgr = ckpt.CheckpointManager(str(tmp_path), keep_last=2)
    for step in (1, 2, 3, 4):
        mgr.save(step, tree)
    mgr.wait()
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(tmp_path)
                   if d.startswith("step_"))
    assert steps == [3, 4]


def test_checksum_detects_corruption(tmp_path):
    tree = _tree(jax.random.PRNGKey(2))
    ckpt.save(str(tmp_path), 1, tree)
    # corrupt one leaf
    leaf = os.path.join(tmp_path, "step_1", "leaf_00000.npy")
    with open(leaf, "r+b") as f:
        f.seek(100)
        f.write(b"\xff\xff\xff\xff")
    target = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
    with pytest.raises(AssertionError, match="checksum"):
        ckpt.restore(str(tmp_path), 1, target)


def test_crash_mid_save_preserves_previous(tmp_path):
    """A .tmp directory (simulated crash) never shadows a published step."""
    tree = _tree(jax.random.PRNGKey(3))
    ckpt.save(str(tmp_path), 1, tree)
    os.makedirs(os.path.join(tmp_path, "step_2.tmp"))   # crashed save
    assert ckpt.latest_step(str(tmp_path)) == 1


def test_elastic_restore_across_mesh(tmp_path):
    """Checkpoint written unsharded restores under any device layout."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.mesh import make_local_mesh
    tree = {"w": jnp.arange(32, dtype=jnp.float32).reshape(8, 4)}
    ckpt.save(str(tmp_path), 5, tree)
    mesh = make_local_mesh(1, 1)
    target = {"w": jax.ShapeDtypeStruct((8, 4), jnp.float32)}
    shardings = {"w": NamedSharding(mesh, P("data", None))}
    back = ckpt.restore(str(tmp_path), 5, target, shardings)
    np.testing.assert_array_equal(np.asarray(back["w"]),
                                  np.asarray(tree["w"]))


def test_resume_exact_training(tmp_path):
    """Crash/restart from a checkpoint reproduces the uninterrupted run
    bit-for-bit (deterministic data skipping)."""
    from repro.configs import smoke_config
    from repro.data.tokens import lm_batch
    from repro.models import lm
    from repro.train import optim, train_loop

    cfg = smoke_config("qwen2-1.5b")
    ocfg = optim.OptConfig(lr=1e-3, warmup_steps=2, total_steps=20)
    params = lm.init_lm(cfg, jax.random.PRNGKey(0))
    state = optim.init_opt_state(params, ocfg)
    step_fn = jax.jit(train_loop.make_train_step(cfg, ocfg))

    def batch_at(s):
        return {k: jnp.asarray(v) for k, v in
                lm_batch(cfg, batch=2, seq=16, step=s).items()}

    # uninterrupted: 6 steps
    p1, s1 = params, state
    for s in range(6):
        p1, s1, _ = step_fn(p1, s1, batch_at(s))

    # interrupted at step 3 + restore + resume
    p2, s2 = params, state
    for s in range(3):
        p2, s2, _ = step_fn(p2, s2, batch_at(s))
    ckpt.save(str(tmp_path), 3, {"params": p2, "opt": s2})
    target = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
        {"params": p2, "opt": s2})
    restored = ckpt.restore(str(tmp_path), 3, target)
    p2, s2 = restored["params"], restored["opt"]
    for s in range(3, 6):
        p2, s2, _ = step_fn(p2, s2, batch_at(s))

    for a, b in zip(jax.tree_util.tree_leaves(p1),
                    jax.tree_util.tree_leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# Crash-safety & recovery (PR 7: fault-injected I/O path)
# ---------------------------------------------------------------------------

def _injector(rate=1.0, seed=3):
    from repro.core.faults import FaultInjector, FaultPlan
    return FaultInjector(FaultPlan(seed=seed, ckpt_fail_rate=rate))


def test_crash_mid_save_reaped_and_previous_step_intact(tmp_path):
    """A killed/failed writer leaves step_K.tmp behind: it must never be
    listed, reap_tmp must remove it, and restore must land on the last
    intact step."""
    tree = _tree(jax.random.PRNGKey(4))
    ckpt.save(str(tmp_path), 1, tree)
    with pytest.raises(IOError, match="injected write fault"):
        ckpt.save(str(tmp_path), 2, tree, injector=_injector())
    assert os.path.isdir(tmp_path / "step_2.tmp")
    assert ckpt.latest_step(str(tmp_path)) == 1          # tmp never listed
    assert ckpt.reap_tmp(str(tmp_path)) == ["step_2.tmp"]
    assert not os.path.exists(tmp_path / "step_2.tmp")
    target = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
    back = ckpt.restore(str(tmp_path), 1, target)
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_writer_error_surfaces_from_wait(tmp_path):
    """The async writer's exception must not vanish with the daemon
    thread — wait() (and thus the next save()) re-raises it."""
    tree = _tree(jax.random.PRNGKey(5))
    mgr = ckpt.CheckpointManager(str(tmp_path), async_write=True)
    mgr.save(1, tree, injector=_injector())
    with pytest.raises(IOError, match="injected write fault"):
        mgr.wait()
    mgr.save(2, tree)                    # manager stays usable afterwards
    mgr.wait()
    assert mgr.latest() == 2


def test_restore_verifies_dtype(tmp_path):
    tree = {"a": jnp.arange(8, dtype=jnp.int32)}
    ckpt.save(str(tmp_path), 1, tree)
    target = {"a": jax.ShapeDtypeStruct((8,), jnp.float32)}
    with pytest.raises(ckpt.CheckpointCorruptionError, match="dtype"):
        ckpt.restore(str(tmp_path), 1, target)


def test_truncated_leaf_detected(tmp_path):
    tree = _tree(jax.random.PRNGKey(6))
    ckpt.save(str(tmp_path), 1, tree)
    leaf = os.path.join(tmp_path, "step_1", "leaf_00000.npy")
    with open(leaf, "r+b") as f:
        f.truncate(os.path.getsize(leaf) // 2)
    target = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
    with pytest.raises(ckpt.CheckpointCorruptionError, match="checksum"):
        ckpt.restore(str(tmp_path), 1, target)


def test_md5_manifest_back_compat(tmp_path):
    """Pre-sha256 manifests (md5 digests) still verify and restore."""
    import hashlib
    import json
    tree = _tree(jax.random.PRNGKey(7))
    ckpt.save(str(tmp_path), 1, tree)
    mf = os.path.join(tmp_path, "step_1", "manifest.json")
    with open(mf) as f:
        manifest = json.load(f)
    for meta in manifest["leaves"]:
        del meta["sha256"]
        with open(os.path.join(tmp_path, "step_1", meta["file"]), "rb") as f:
            meta["md5"] = hashlib.md5(f.read()).hexdigest()
    with open(mf, "w") as f:
        json.dump(manifest, f)
    target = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
    back = ckpt.restore(str(tmp_path), 1, target)
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_quarantine_excluded_from_listing(tmp_path):
    tree = _tree(jax.random.PRNGKey(8))
    ckpt.save(str(tmp_path), 1, tree)
    ckpt.save(str(tmp_path), 2, tree)
    ckpt.quarantine(str(tmp_path), 2)
    assert os.path.isdir(tmp_path / "step_2.quarantined")
    assert ckpt.latest_step(str(tmp_path)) == 1


def _tiny_index():
    from repro.api import Index, IndexConfig
    from repro.data.synth import make_filtered_dataset
    ds = make_filtered_dataset(n=300, d=8, n_queries=4, n_labels=10, seed=5)
    idx = Index.build(ds.vectors, ds.metadata(),
                      IndexConfig(r=8, r_dense=40, l_build=16, pq_m=4))
    return ds, idx


def test_index_load_corrupted_leaf_falls_back(tmp_path):
    """Index.load with a corrupted newest step quarantines it and loads
    the previous intact step; a stale tmp dir is reaped on the way."""
    from repro.api import Index, SearchRequest
    ds, idx = _tiny_index()
    path = str(tmp_path / "idx")
    idx.save(path)                                       # step 0
    idx.save(path)                                       # step 1
    os.makedirs(os.path.join(path, "step_9.tmp"))        # crashed writer
    leaf = os.path.join(path, "step_1", "leaf_00000.npy")
    with open(leaf, "r+b") as f:
        f.seek(80)
        f.write(b"\xde\xad\xbe\xef")
    loaded = Index.load(path)
    assert os.path.isdir(os.path.join(path, "step_1.quarantined"))
    assert not os.path.exists(os.path.join(path, "step_9.tmp"))
    res = loaded.search(SearchRequest(query=ds.queries[0], k=4))
    assert res.ids.shape == (4,)
    a = idx.search(SearchRequest(query=ds.queries[1], k=4))
    b = loaded.search(SearchRequest(query=ds.queries[1], k=4))
    np.testing.assert_array_equal(a.ids, b.ids)


def test_index_load_all_steps_corrupted_raises(tmp_path):
    ds, idx = _tiny_index()
    path = str(tmp_path / "idx")
    idx.save(path)                                       # step 0 only
    leaf = os.path.join(path, "step_0", "leaf_00000.npy")
    with open(leaf, "r+b") as f:
        f.seek(80)
        f.write(b"\xde\xad\xbe\xef")
    from repro.api import Index
    with pytest.raises(ckpt.CheckpointCorruptionError):
        Index.load(path)
