"""Hypothesis property tests for the system's core invariants."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import bloom, pq
from repro.core.labels import build_label_store
from repro.core.ranges import build_range_store
from repro.core import selectors as S
from repro.core import cost_model as CM


@settings(max_examples=25, deadline=None)
@given(st.lists(st.lists(st.integers(0, 30), min_size=0, max_size=6),
                min_size=3, max_size=40),
       st.lists(st.integers(0, 30), min_size=1, max_size=3))
def test_bloom_never_false_negative(vec_labels, query_labels):
    """INVARIANT (paper §3): is_member_approx has no false negatives."""
    counts = np.array([len(v) for v in vec_labels])
    offsets = np.zeros(len(vec_labels) + 1, np.int64)
    np.cumsum(counts, out=offsets[1:])
    flat = np.array([l for v in vec_labels for l in v], np.int32)
    store = build_label_store(offsets, flat, n_labels=31)
    for v, labels in enumerate(vec_labels):
        mine = set(labels)
        if set(query_labels) <= mine:        # AND-query true member
            req = bloom.label_bits(np.array(query_labels, np.int64),
                                   store.k_hashes)
            mask = np.uint32(0)
            for m in req:
                mask |= m
            assert bool(bloom.bloom_pass(
                jnp.asarray(store.blooms[v:v + 1]), mask)[0])


@settings(max_examples=20, deadline=None)
@given(st.lists(st.floats(-1e4, 1e4, allow_nan=False), min_size=8,
                max_size=200),
       st.floats(-1e4, 1e4, allow_nan=False),
       st.floats(0.01, 1e4, allow_nan=False))
def test_range_bucket_superset(values, lo, width):
    """INVARIANT: bucket-code approx check is a superset of the true range."""
    rs = build_range_store(np.array(values, np.float32))
    hi = lo + width
    blo, bhi = rs.bucket_range(lo, hi)
    codes = rs.bucket_codes.astype(int)
    approx = (codes >= blo) & (codes <= bhi)
    truth = (rs.values >= lo) & (rs.values < hi)
    assert np.all(approx[truth]), "false negative in bucket approx"


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 400), st.integers(1, 8))
def test_pq_adc_is_exact_for_codebook_points(n, m):
    """ADC distance of an encoded centroid to itself decomposes exactly."""
    rng = np.random.default_rng(n * 13 + m)
    d = m * 4
    data = rng.normal(0, 1, (max(n, 4), d)).astype(np.float32)
    import jax
    cb = pq.train_pq(jax.random.PRNGKey(0), jnp.asarray(data), m=m, iters=2)
    codes = pq.encode_pq(cb, jnp.asarray(data))
    recon = np.asarray(pq.decode_pq(cb, codes))
    q = data[0]
    table = pq.distance_table(cb, jnp.asarray(q))
    adc = np.asarray(pq.adc_lookup(codes, table))
    exact = np.sum((recon - q[None]) ** 2, axis=1)
    np.testing.assert_allclose(adc, exact, rtol=1e-3, atol=1e-3)


@settings(max_examples=50, deadline=None)
@given(st.floats(1e-6, 1.0), st.floats(1e-3, 1.0), st.floats(1e-3, 1.0),
       st.integers(8, 256))
def test_cost_model_unifies_extremes(s, p_pre, p_in, l):
    """Paper §3: strict filtering and post-filtering are the two extremes of
    speculative filtering; costs must be finite, positive, and post-filter
    I/O must scale 1/s."""
    c = CM.CostInputs(n=1_000_000, l=l, s=s, p_pre=p_pre, p_in=p_in,
                      x_pre=10, x_in=5, r=64, r_d=640, s_r=1, s_d=2)
    for mech in (CM.pre_filtering_cost, CM.in_filtering_cost,
                 CM.post_filtering_cost):
        mc = mech(c)
        assert np.isfinite(mc.io_pages) and mc.io_pages > 0
        assert np.isfinite(mc.compute) and mc.compute > 0
    post = CM.post_filtering_cost(c)
    post_half = CM.post_filtering_cost(
        CM.CostInputs(**{**c.__dict__, "s": s / 2}))
    assert post_half.io_pages >= post.io_pages


@settings(max_examples=15, deadline=None)
@given(st.integers(2, 64), st.integers(1, 5))
def test_q8_roundtrip_bounded_error(rows, cols_blocks):
    from repro.train import optim
    rng = np.random.default_rng(rows)
    x = jnp.asarray(rng.normal(0, 3, (rows, cols_blocks * 37))
                    .astype(np.float32))
    back = optim.q8_dequantize(optim.q8_quantize(x))
    err = np.abs(np.asarray(back) - np.asarray(x))
    assert err.max() <= np.abs(np.asarray(x)).max() / 127 + 1e-6
