"""Multi-device correctness: runs subprocesses with 8 fake CPU devices
(XLA_FLAGS can't change after jax init, so each scenario is a script)."""
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(script: str, devices: int = 8, timeout: int = 420):
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + f" --xla_force_host_platform_device_count={devices}").strip()
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=timeout)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


def test_sp_decode_attention_matches_reference():
    _run("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.launch.mesh import make_local_mesh
from repro.serve import sp_attention as SP

mesh = make_local_mesh(1, 8)
b, t, hq, hkv, dh = 2, 64, 8, 4, 16
rng = np.random.default_rng(0)
q = jnp.asarray(rng.normal(0, 1, (b, 1, hq, dh)).astype(np.float32))
k = jnp.asarray(rng.normal(0, 1, (b, t, hkv, dh)).astype(np.float32))
v = jnp.asarray(rng.normal(0, 1, (b, t, hkv, dh)).astype(np.float32))
pos = jnp.asarray(40, jnp.int32)

def body(q, k, v, pos):
    return SP.sp_decode_attention_local(q, k, v, pos, n_kv=hkv,
                                        axis_name="model")

from repro.utils.compat import shard_map
f = jax.jit(shard_map(
    body, mesh=mesh,
    in_specs=(P(), P(None, "model", None, None), P(None, "model", None, None),
              P()),
    out_specs=P(), check_vma=False))
got = f(q, k, v, pos)
want = SP.reference_decode_attention(q, k, v, pos, n_kv=hkv)
np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                           rtol=2e-5, atol=2e-5)
print("SP-ATTN-OK")
""")


def test_sp_cache_update_owner_only():
    _run("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.launch.mesh import make_local_mesh
from repro.serve import sp_attention as SP

mesh = make_local_mesh(1, 8)
b, t, hkv, dh = 1, 32, 2, 4
k_cache = jnp.zeros((b, t, hkv, dh))
v_cache = jnp.zeros((b, t, hkv, dh))
k_new = jnp.ones((b, 1, hkv, dh))
v_new = jnp.full((b, 1, hkv, dh), 2.0)
pos = jnp.asarray(13, jnp.int32)

def body(kc, vc, kn, vn, pos):
    return SP.sp_cache_update(kc, vc, kn, vn, pos, axis_name="model")

from repro.utils.compat import shard_map
f = jax.jit(shard_map(
    body, mesh=mesh,
    in_specs=(P(None, "model", None, None), P(None, "model", None, None),
              P(), P(), P()),
    out_specs=(P(None, "model", None, None), P(None, "model", None, None)),
    check_vma=False))
k_out, v_out = f(k_cache, v_cache, k_new, v_new, pos)
k_np = np.asarray(k_out)
assert np.all(k_np[0, 13] == 1.0)
mask = np.ones(t, bool); mask[13] = False
assert np.all(k_np[0, mask] == 0.0)
assert np.all(np.asarray(v_out)[0, 13] == 2.0)
print("SP-CACHE-OK")
""")


def test_distributed_search_matches_local():
    _run("""
import jax, jax.numpy as jnp, numpy as np
from repro.core import engine as eng
from repro.core import distributed as D
from repro.core import search as S
from repro.core.selectors import stack_filters
from repro.data.synth import make_filtered_dataset, make_selectors
from repro.launch.mesh import make_local_mesh

ds = make_filtered_dataset(n=2048, d=16, n_queries=4, n_labels=30, seed=0)
cfg = eng.IndexConfig(r=12, r_dense=96, l_build=24, pq_m=8, max_labels=16)
e = eng.FilteredANNEngine.build(ds.vectors, ds.label_offsets, ds.label_flat,
                                ds.n_labels, ds.values, cfg)
sels = make_selectors(ds, e, "label_or")
plans = [s.plan(cfg.ql, cfg.cap) for s in sels]
qf = stack_filters([p.qfilter for p in plans])
queries = jnp.asarray(np.pad(ds.queries, ((0, 0), (0, 0))))
params = S.SearchParams(l_search=32, k=10, max_hops=128, mode="spec_in")

local = S.filtered_search(e.store, e.codes, e.codebook, e.mem, qf,
                          queries, e.medoid, params)

mesh = make_local_mesh(2, 4)
plan = D.ShardPlan(mesh=mesh, shard_axes=("data", "model"))
store = D.pad_store(e.store, plan.n_shards)
dist = D.distributed_filtered_search(plan, store, e.codes, e.codebook,
                                     e.mem, qf, queries, e.medoid, params)
np.testing.assert_array_equal(np.asarray(local.ids), np.asarray(dist.ids))
np.testing.assert_allclose(np.asarray(local.dists), np.asarray(dist.dists),
                           rtol=1e-5)
np.testing.assert_array_equal(np.asarray(local.io_pages),
                              np.asarray(dist.io_pages))
print("DIST-SEARCH-OK")
""", timeout=600)


def test_compressed_psum_matches_mean():
    _run("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.launch.mesh import make_local_mesh
from repro.train import grad_compress as GC

mesh = make_local_mesh(8, 1)
rng = np.random.default_rng(0)
grads = {"w": jnp.asarray(rng.normal(0, 1, (8, 64, 40)).astype(np.float32))}
err = {"w": jnp.zeros((64, 40), jnp.float32)}

def body(g, e):
    mean, new_e = GC.compressed_psum_grads(
        {"w": g["w"][0]}, {"w": e["w"]}, "data")
    return mean, {"w": new_e["w"][None]}     # stack per-device error states

from repro.utils.compat import shard_map
f = jax.jit(shard_map(
    body, mesh=mesh,
    in_specs=({"w": P("data", None, None)}, {"w": P()}),
    out_specs=({"w": P()}, {"w": P("data", None, None)}),
    check_vma=False))
mean, new_e = f(grads, err)
want = np.asarray(grads["w"]).mean(0)
got = np.asarray(mean["w"])
# int8-quantized mean within block-scale tolerance
tol = np.abs(np.asarray(grads["w"])).max() / 127 * 1.5
assert np.abs(got - want).max() < tol, np.abs(got - want).max()
# error feedback carries the residual
assert np.asarray(new_e["w"]).shape == (8, 64, 40)
print("GC-OK")
""")
