"""Multi-device correctness: runs subprocesses with fake CPU devices
(XLA_FLAGS can't change after jax init, so each scenario is a script).

The whole module is marked ``dist`` — scripts/test_fast.sh runs it as its
own leg under ``--xla_force_host_platform_device_count=4``; tier-1 runs
it unmarked too."""
import os
import subprocess
import sys

import pytest

pytestmark = pytest.mark.dist

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(script: str, devices: int = 8, timeout: int = 420):
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + f" --xla_force_host_platform_device_count={devices}").strip()
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=timeout)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


def test_sp_decode_attention_matches_reference():
    _run("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.launch.mesh import make_local_mesh
from repro.serve import sp_attention as SP

mesh = make_local_mesh(1, 8)
b, t, hq, hkv, dh = 2, 64, 8, 4, 16
rng = np.random.default_rng(0)
q = jnp.asarray(rng.normal(0, 1, (b, 1, hq, dh)).astype(np.float32))
k = jnp.asarray(rng.normal(0, 1, (b, t, hkv, dh)).astype(np.float32))
v = jnp.asarray(rng.normal(0, 1, (b, t, hkv, dh)).astype(np.float32))
pos = jnp.asarray(40, jnp.int32)

def body(q, k, v, pos):
    return SP.sp_decode_attention_local(q, k, v, pos, n_kv=hkv,
                                        axis_name="model")

from repro.utils.compat import shard_map
f = jax.jit(shard_map(
    body, mesh=mesh,
    in_specs=(P(), P(None, "model", None, None), P(None, "model", None, None),
              P()),
    out_specs=P(), check_vma=False))
got = f(q, k, v, pos)
want = SP.reference_decode_attention(q, k, v, pos, n_kv=hkv)
np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                           rtol=2e-5, atol=2e-5)
print("SP-ATTN-OK")
""")


def test_sp_cache_update_owner_only():
    _run("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.launch.mesh import make_local_mesh
from repro.serve import sp_attention as SP

mesh = make_local_mesh(1, 8)
b, t, hkv, dh = 1, 32, 2, 4
k_cache = jnp.zeros((b, t, hkv, dh))
v_cache = jnp.zeros((b, t, hkv, dh))
k_new = jnp.ones((b, 1, hkv, dh))
v_new = jnp.full((b, 1, hkv, dh), 2.0)
pos = jnp.asarray(13, jnp.int32)

def body(kc, vc, kn, vn, pos):
    return SP.sp_cache_update(kc, vc, kn, vn, pos, axis_name="model")

from repro.utils.compat import shard_map
f = jax.jit(shard_map(
    body, mesh=mesh,
    in_specs=(P(None, "model", None, None), P(None, "model", None, None),
              P(), P(), P()),
    out_specs=(P(None, "model", None, None), P(None, "model", None, None)),
    check_vma=False))
k_out, v_out = f(k_cache, v_cache, k_new, v_new, pos)
k_np = np.asarray(k_out)
assert np.all(k_np[0, 13] == 1.0)
mask = np.ones(t, bool); mask[13] = False
assert np.all(k_np[0, mask] == 0.0)
assert np.all(np.asarray(v_out)[0, 13] == 2.0)
print("SP-CACHE-OK")
""")


def test_distributed_search_matches_local():
    _run("""
import jax, jax.numpy as jnp, numpy as np
from repro.core import engine as eng
from repro.core import distributed as D
from repro.core import search as S
from repro.core.selectors import stack_filters
from repro.data.synth import make_filtered_dataset, make_selectors
from repro.launch.mesh import make_local_mesh

ds = make_filtered_dataset(n=2048, d=16, n_queries=4, n_labels=30, seed=0)
cfg = eng.IndexConfig(r=12, r_dense=96, l_build=24, pq_m=8, max_labels=16)
e = eng.FilteredANNEngine.build(ds.vectors, ds.label_offsets, ds.label_flat,
                                ds.n_labels, ds.values, cfg)
sels = make_selectors(ds, e, "label_or")
plans = [s.plan(cfg.ql, cfg.cap) for s in sels]
qf = stack_filters([p.qfilter for p in plans])
queries = jnp.asarray(np.pad(ds.queries, ((0, 0), (0, 0))))
params = S.SearchParams(l_search=32, k=10, max_hops=128, mode="spec_in")

local = S.filtered_search(e.store, e.codes, e.codebook, e.mem, qf,
                          queries, e.medoid, params)

mesh = make_local_mesh(2, 4)
plan = D.ShardPlan(mesh=mesh, shard_axes=("data", "model"))
store = D.pad_store(e.store, plan.n_shards)
dist = D.distributed_filtered_search(plan, store, e.codes, e.codebook,
                                     e.mem, qf, queries, e.medoid, params)
np.testing.assert_array_equal(np.asarray(local.ids), np.asarray(dist.ids))
np.testing.assert_allclose(np.asarray(local.dists), np.asarray(dist.dists),
                           rtol=1e-5)
np.testing.assert_array_equal(np.asarray(local.io_pages),
                              np.asarray(dist.io_pages))
print("DIST-SEARCH-OK")
""", timeout=600)


def test_compressed_psum_matches_mean():
    _run("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.launch.mesh import make_local_mesh
from repro.train import grad_compress as GC

mesh = make_local_mesh(8, 1)
rng = np.random.default_rng(0)
grads = {"w": jnp.asarray(rng.normal(0, 1, (8, 64, 40)).astype(np.float32))}
err = {"w": jnp.zeros((64, 40), jnp.float32)}

def body(g, e):
    mean, new_e = GC.compressed_psum_grads(
        {"w": g["w"][0]}, {"w": e["w"]}, "data")
    return mean, {"w": new_e["w"][None]}     # stack per-device error states

from repro.utils.compat import shard_map
f = jax.jit(shard_map(
    body, mesh=mesh,
    in_specs=({"w": P("data", None, None)}, {"w": P()}),
    out_specs=({"w": P()}, {"w": P("data", None, None)}),
    check_vma=False))
mean, new_e = f(grads, err)
want = np.asarray(grads["w"]).mean(0)
got = np.asarray(mean["w"])
# int8-quantized mean within block-scale tolerance
tol = np.abs(np.asarray(grads["w"])).max() / 127 * 1.5
assert np.abs(got - want).max() < tol, np.abs(got - want).max()
# error feedback carries the residual
assert np.asarray(new_e["w"]).shape == (8, 64, 40)
print("GC-OK")
""")


# ---------------------------------------------------------------------------
# Sharded pipelined execution (ShardedSearchRunner / build_vamana_sharded)
# ---------------------------------------------------------------------------

def test_sharded_runner_bit_identical_across_shard_counts():
    """1-vs-2-vs-4-shard pipelined search: every SearchResult field matches
    the single-device driver bit-for-bit (distances to float tolerance),
    in all three filter modes."""
    _run("""
import jax, jax.numpy as jnp, numpy as np
from repro.core import engine as eng
from repro.core import distributed as D
from repro.core import search as S
from repro.core.selectors import stack_filters
from repro.data.synth import make_filtered_dataset, make_selectors
from repro.launch.mesh import make_local_mesh

ds = make_filtered_dataset(n=2048, d=16, n_queries=33, n_labels=30, seed=0)
cfg = eng.IndexConfig(r=12, r_dense=96, l_build=24, pq_m=8, max_labels=16)
e = eng.FilteredANNEngine.build(ds.vectors, ds.label_offsets, ds.label_flat,
                                ds.n_labels, ds.values, cfg)
sels = make_selectors(ds, e, "label_or")
plans = [s.plan(cfg.ql, cfg.cap) for s in sels]
qf = stack_filters([p.qfilter for p in plans])
queries = jnp.asarray(ds.queries)

INT_FIELDS = ("ids", "io_pages", "dist_comps", "hops", "fp_explored",
              "explored", "n_valid", "faults", "retries", "degraded")
for mode in ("post", "spec_in", "strict_in"):
    params = S.SearchParams(l_search=32, k=10, max_hops=128, mode=mode)
    base = S.filtered_search_pipelined(e.store, e.codes, e.codebook, e.mem,
                                       qf, queries, e.medoid, params,
                                       hop_chunk=16)
    for shards in (2, 4):
        plan = D.ShardPlan(mesh=make_local_mesh(1, shards),
                           shard_axes=("model",))
        runner = D.ShardedSearchRunner(plan, e.store, e.codes, e.codebook,
                                       e.mem)
        got = S.filtered_search_pipelined(e.store, e.codes, e.codebook,
                                          e.mem, qf, queries, e.medoid,
                                          params, hop_chunk=16,
                                          runner=runner)
        for f in INT_FIELDS:
            if hasattr(base, f):
                np.testing.assert_array_equal(
                    np.asarray(getattr(base, f)),
                    np.asarray(getattr(got, f)), err_msg=f"{mode}:{f}")
        np.testing.assert_allclose(np.asarray(base.dists),
                                   np.asarray(got.dists), rtol=1e-5)
        assert runner.cache_size() == 1   # one shard_map jit per params
print("SHARD-PARITY-OK")
""", devices=4, timeout=900)


def test_sharded_build_recall_within_one_percent():
    """Sharded Vamana build: exact-nav reproduces the batched builder's
    RNG stream (identical recall); PQ-approximate navigation stays within
    the 1% recall@10 envelope."""
    _run("""
import jax, jax.numpy as jnp, numpy as np
from repro.core import distributed as D
from repro.core import graph, pq
from repro.launch.mesh import make_local_mesh

rng = np.random.default_rng(0)
n, d = 1536, 16
data = rng.standard_normal((n, d), dtype=np.float32)
queries = rng.standard_normal((32, d), dtype=np.float32)

adj_b, med_b = graph.build_vamana_batched(data, r=12, ell=24, batch=256,
                                          seed=3)
rb = graph.greedy_recall_at_k(data, adj_b, med_b, queries, ell=32, k=10)

plan = D.ShardPlan(mesh=make_local_mesh(1, 4), shard_axes=("model",))
st = {}
adj_s, med_s = D.build_vamana_sharded(data, plan, r=12, ell=24, batch=256,
                                      seed=3, stage_times=st)
assert med_s == med_b
rs = graph.greedy_recall_at_k(data, adj_s, med_s, queries, ell=32, k=10)
assert abs(rs - rb) <= 0.01, (rs, rb)
assert st["nav_prune_s"] > 0 and st["scatter_s"] > 0

cb = pq.train_pq(jax.random.PRNGKey(0), data, m=8, iters=4)
codes = pq.encode_pq(cb, data)
adj_p, med_p = D.build_vamana_sharded(data, plan, r=12, ell=24, batch=256,
                                      seed=3, codes=codes, codebook=cb)
rp = graph.greedy_recall_at_k(data, adj_p, med_p, queries, ell=32, k=10)
assert rp >= rb - 0.01, (rp, rb)
print("SHARD-BUILD-OK", rb, rs, rp)
""", devices=4, timeout=900)


def test_sharded_warmup_compiles_once_then_serves_hot():
    """Index.build(shards=…) -> Session.warmup covers the sharded bucket-jit
    ladder: serving production-width batches afterwards triggers NO fresh
    compile (runner jit cache sizes frozen), and repeat widths reuse the
    same single shard_map artifact per params."""
    _run("""
import numpy as np
from repro.api import Index, SearchRequest, Session
from repro.api.filters import Tag
from repro.core.engine import IndexConfig

rng = np.random.default_rng(0)
n, d = 1536, 16
vectors = rng.standard_normal((n, d), dtype=np.float32)
cats = ["a", "b", "c", "d"]
meta = [{"cat": cats[int(rng.integers(0, 4))], "price": float(rng.random())}
        for _ in range(n)]
idx = Index.build(vectors, meta,
                  IndexConfig(r=12, r_dense=96, l_build=24, pq_m=8,
                              max_labels=16), shards=2)
runner = idx.engine._runner
assert runner is not None and runner.n_shards == 2

# policy="post" pins the graph-search mechanism: the prefilter route
# never touches the hop loop, so it would leave the runner cache cold
reqs = [SearchRequest(query=vectors[i] + 0.01, k=5, policy="post",
                      filter=Tag("cat") == cats[i % 4])
        for i in range(16)]
with Session(idx) as sess:
    sess.warmup(reqs, rungs=())
    # snapshot: outer shard_map jits (one per params variant warmed) and
    # their per-width compile counts
    n_outer = runner.cache_size()
    n_inner = sum(f._cache_size() for f in runner._run_cache.values())
    assert n_outer >= 1 and n_inner >= 1
    # production traffic at widths the ladder covered: must stay hot
    for lo, hi in ((0, 16), (4, 12), (0, 8), (7, 8)):
        hs = sess.submit_many(reqs[lo:hi])
        sess.flush()
        for h in hs:
            h.result(timeout=300)
    assert runner.cache_size() == n_outer
    assert sum(f._cache_size()
               for f in runner._run_cache.values()) == n_inner, \
        "fresh sharded jit mid-serve: warmup ladder missed a width"
print("SHARD-WARM-OK", n_outer, n_inner)
""", devices=4, timeout=900)
