"""Index.insert (streaming inserts) + batched-build regression coverage.

The insert tests run as an ordered journey over one module-scoped index
(inserts mutate it, so it is deliberately not the session-shared engine).
"""
import numpy as np
import pytest

from repro.api import Index, Num, SearchRequest, Tag
from repro.core import engine as eng
from repro.core import search as search_mod
from repro.core.engine import recall_at_k
from repro.data.synth import make_selectors

pytestmark = pytest.mark.fast

N0 = 2500
D = 24


@pytest.fixture(scope="module")
def corpus():
    rng = np.random.default_rng(7)
    centers = rng.normal(0, 1.0, (8, D)).astype(np.float32)
    assign = rng.integers(0, 8, N0)
    vecs = (centers[assign]
            + rng.normal(0, 0.3, (N0, D))).astype(np.float32)
    meta = [{"cat": int(rng.integers(0, 6)),
             "v": float(rng.lognormal(2.0, 0.6))} for _ in range(N0)]
    new_vecs = (centers[rng.integers(0, 8, 300)]
                + rng.normal(0, 0.3, (300, D))).astype(np.float32)
    # cats 6/7 only appear in inserted records (vocabulary growth)
    new_meta = [{"cat": int(rng.integers(0, 8)),
                 "v": float(rng.lognormal(2.0, 0.6))} for _ in range(300)]
    return vecs, meta, new_vecs, new_meta


@pytest.fixture(scope="module")
def index(corpus):
    vecs, meta, new_vecs, new_meta = corpus
    cfg = eng.IndexConfig(r=16, r_dense=160, l_build=32, pq_m=8,
                          max_labels=8, ql=4, cap=1024)
    idx = Index.build(vecs, meta, cfg,
                      defaults=eng.SearchConfig(k=10, l=32, max_hops=300,
                                                max_pool=512))
    ids = idx.insert(new_vecs, new_meta)
    assert ids.tolist() == list(range(N0, N0 + 300))
    return idx


def test_insert_grows_index(index):
    assert len(index) == N0 + 300


def test_inserted_searchable_under_tag_filter(index, corpus):
    _, _, new_vecs, new_meta = corpus
    found = 0
    for j in range(0, 60):
        req = SearchRequest(query=new_vecs[j],
                            filter=(Tag("cat") == new_meta[j]["cat"]), k=5)
        res = index.search(req)
        found += int(N0 + j in res.ids.tolist())
        # every hit satisfies the filter exactly
        for rec_id, _, meta in res.matches:
            assert meta["cat"] == new_meta[j]["cat"]
    assert found >= 55, found


def test_inserted_searchable_under_range_filter(index, corpus):
    _, _, new_vecs, new_meta = corpus
    found = 0
    for j in range(60, 120):
        v = new_meta[j]["v"]
        req = SearchRequest(query=new_vecs[j],
                            filter=Num("v").between(v - 2.0, v + 2.0), k=5)
        res = index.search(req)
        found += int(N0 + j in res.ids.tolist())
        for rec_id, _, meta in res.matches:
            assert v - 2.0 <= meta["v"] < v + 2.0
    assert found >= 55, found


def test_ground_truth_agrees_after_insert(index, corpus):
    _, _, new_vecs, new_meta = corpus
    recalls = []
    for j in range(0, 40):
        req = SearchRequest(query=new_vecs[j],
                            filter=(Tag("cat") == new_meta[j]["cat"]), k=10)
        gt = index.ground_truth(req)
        assert gt.max() < len(index)
        # ground truth sees inserted records
        res = index.search(req)
        recalls.append(recall_at_k(res.ids, gt, 10))
    assert np.mean(recalls) >= 0.85, np.mean(recalls)
    # at least one ground-truth set contains an inserted id
    any_inserted = any(
        (index.ground_truth(SearchRequest(
            query=new_vecs[j], filter=(Tag("cat") == new_meta[j]["cat"]),
            k=10)) >= N0).any() for j in range(10))
    assert any_inserted


def test_new_vocabulary_entries_resolve(index):
    # cats 6 and 7 exist only in inserted records
    assert index.label_id("cat", 6) is not None
    assert index.label_id("cat", 7) is not None
    req_meta = [index.record_metadata(i) for i in range(N0, N0 + 50)]
    assert any(m["cat"] in (6, 7) for m in req_meta)


def test_insert_save_load_roundtrip(index, corpus, tmp_path):
    _, _, new_vecs, _ = corpus
    path = str(tmp_path / "ckpt")
    index.save(path)
    loaded = Index.load(path)
    assert len(loaded) == len(index)
    assert loaded.vocab == index.vocab
    for j in (0, 7, 42):
        r1 = index.search(SearchRequest(query=new_vecs[j], k=5))
        r2 = loaded.search(SearchRequest(query=new_vecs[j], k=5))
        np.testing.assert_array_equal(r1.ids, r2.ids)
        assert index.record_metadata(N0 + j) == \
            loaded.record_metadata(N0 + j)


def test_insert_validation(index):
    with pytest.raises(ValueError):
        index.insert(np.zeros((2, D), np.float32), [{"cat": 1, "v": 1.0}])
    with pytest.raises(ValueError):   # missing the numeric field
        index.insert(np.zeros((1, D), np.float32), [{"cat": 1}])
    with pytest.raises(ValueError):   # exceeds index dim
        index.insert(np.zeros((1, 4096), np.float32), [{"cat": 1, "v": 1.0}])
    assert index.insert(np.zeros((0, D), np.float32), []).size == 0


def test_insert_rejects_new_float_field():
    vecs = np.eye(8, dtype=np.float32)
    idx = Index.build(vecs, [{"cat": i % 2} for i in range(8)],
                      eng.IndexConfig(r=4, r_dense=8, l_build=8, pq_m=4,
                                      max_labels=4, ql=2, cap=64))
    with pytest.raises(ValueError):
        idx.insert(np.eye(8, dtype=np.float32)[:1], [{"cat": 1, "w": 2.5}])


def test_steady_state_insert_compiles_once():
    """ROADMAP insert-path perf: capacity-padded stores must keep every
    device-array shape stable across steady-state inserts, so the search
    path compiles once instead of re-specializing per insert."""
    rng = np.random.default_rng(3)
    vecs = rng.normal(0, 1, (600, 16)).astype(np.float32)
    meta = [{"cat": int(rng.integers(0, 4)), "v": float(rng.uniform(0, 50))}
            for _ in range(600)]
    idx = Index.build(vecs, meta,
                      eng.IndexConfig(r=8, r_dense=48, l_build=16, pq_m=4),
                      defaults=eng.SearchConfig(k=5, l=32, max_hops=100))

    def batch(seed, m=64):
        r = np.random.default_rng(seed)
        return (r.normal(0, 1, (m, 16)).astype(np.float32),
                [{"cat": int(r.integers(0, 4)),
                  "v": float(r.uniform(0, 50))} for _ in range(m)])

    def reqs(seed):
        r = np.random.default_rng(seed)
        q = r.normal(0, 1, 16).astype(np.float32)
        return [SearchRequest(query=q),
                SearchRequest(query=q, filter=Tag("cat") == 1),
                SearchRequest(query=q, filter=Num("v").between(5.0, 30.0))]

    idx.insert(*batch(0))            # first insert: grows to capacity
    shape0 = idx.store.vectors.shape
    for r in reqs(0):
        idx.search(r)                # warm the search path at capacity shapes

    def caches():
        # the engine's pipelined path: init → chunked runner → finalize
        return (search_mod.init_search._cache_size(),
                search_mod.run_hops._cache_size(),
                search_mod.finalize_search._cache_size())

    c0 = caches()
    idx.insert(*batch(1))            # steady state: capacity unchanged
    assert idx.store.vectors.shape == shape0
    assert idx.store.rec_values.shape == (shape0[0], 1)
    for r in reqs(1):
        idx.search(r)
    assert caches() == c0, \
        "steady-state insert re-specialized the search jit"
    # the padded rows stay unreachable: results never leak pad ids
    res = idx.search(SearchRequest(query=batch(1)[0][0], k=10))
    assert res.ids[res.ids >= 0].max() < len(idx)
    assert len(idx) == 600 + 128


def test_insert_dedupes_repeated_labels_on_device():
    """Engine-level inserts must dedupe (vector, label) pairs before padding
    the device label rows: a repeated label could otherwise push a real
    label past the max_labels slots that the host inverted index still
    serves — an exact-verify false negative."""
    rng = np.random.default_rng(0)
    vecs = rng.normal(0, 1, (64, 8)).astype(np.float32)
    cfg = eng.IndexConfig(r=8, r_dense=16, l_build=16, pq_m=4, max_labels=4,
                          ql=4, cap=64)
    offsets = np.arange(65, dtype=np.int64)
    labels = np.zeros(64, np.int32)
    e = eng.FilteredANNEngine.build(vecs, offsets, labels, 8,
                                    np.zeros(64, np.float32), cfg)
    # one record: label 5 repeated past the slot budget, then label 7
    new_flat = np.array([5, 5, 5, 5, 7], np.int32)
    e.insert(vecs[:1] + 0.01, np.array([0, 5], np.int64), new_flat, 8,
             np.zeros(1, np.float32))
    row = np.asarray(e.store.rec_labels[64])
    assert set(row[row >= 0].tolist()) == {5, 7}, row
    assert 64 in e.label_store.postings(7).tolist()


def test_multi_filter_ab_probe_batched_vs_reference():
    """ROADMAP watch item: the single-shared-filter evidence for the
    spec-in recall deficit of batched-built graphs is replaced by a sweep
    over ≥4 distinct mid-selectivity (0.2–0.4) range filters. Both graphs
    search identically-configured spec-in routes; the batched builder must
    stay within 0.1 mean recall@10 of the reference oracle on every
    filter."""
    import jax.numpy as jnp
    from repro.core.selectors import RangeSelector, stack_filters
    ds_rng = np.random.default_rng(17)
    n, d, nq = 2000, 24, 12
    centers = ds_rng.normal(0, 1.0, (8, d)).astype(np.float32)
    data = (centers[ds_rng.integers(0, 8, n)]
            + ds_rng.normal(0, 0.3, (n, d))).astype(np.float32)
    values = ds_rng.uniform(0, 100, n).astype(np.float32)
    queries = (centers[ds_rng.integers(0, 8, nq)]
               + ds_rng.normal(0, 0.3, (nq, d))).astype(np.float32)
    offsets = np.arange(n + 1, dtype=np.int64)
    labels = ds_rng.integers(0, 10, n).astype(np.int32)

    engines = {}
    for builder in ("batched", "reference"):
        cfg = eng.IndexConfig(r=12, r_dense=96, l_build=24, pq_m=4,
                              max_labels=4, ql=4, cap=256, builder=builder)
        engines[builder] = eng.FilteredANNEngine.build(
            data, offsets, labels, 10, values, cfg)

    # ≥4 distinct windows at 0.2–0.4 selectivity, staggered offsets
    sv = np.sort(values)
    windows = []
    for frac, start in ((0.20, 0.05), (0.25, 0.30), (0.30, 0.55),
                        (0.40, 0.10), (0.35, 0.45)):
        lo_i = int(start * n)
        hi_i = min(n - 1, lo_i + int(frac * n))
        windows.append((float(sv[lo_i]), float(sv[hi_i])))

    deficits = []
    for lo, hi in windows:
        recalls = {}
        for builder, e in engines.items():
            sel = RangeSelector(e.range_store, lo, hi)
            plan = sel.plan(e.config.ql, e.config.cap, e.config.qr)
            qf = stack_filters([plan.qfilter] * nq)
            sp = search_mod.SearchParams(l_search=64, k=10, beam_width=1,
                                         max_hops=200, mode="spec_in",
                                         l_valid=32)
            res = search_mod.filtered_search(
                e.store, e.codes, e.codebook, e.mem, qf,
                jnp.asarray(queries), e.medoid, sp)
            rs = []
            for i in range(nq):
                gt = eng.brute_force_filtered(
                    data, np.asarray(e.store.rec_labels),
                    np.asarray(e.store.rec_values), plan.qfilter,
                    queries[i], 10)
                rs.append(recall_at_k(np.asarray(res.ids[i]), gt, 10))
            recalls[builder] = float(np.mean(rs))
        deficits.append(recalls["reference"] - recalls["batched"])

    assert len(deficits) >= 4
    # per-filter evidence replaces the old single-shared-filter probe
    assert float(np.mean(deficits)) <= 0.10, deficits
    assert max(deficits) <= 0.20, deficits


def test_strict_in_small_l_regression(shared_ds, shared_engine):
    """ROADMAP baseline item: strict in-filtering must stay usable at small
    L (strict pool sizing via cost_model.effective_l + valid entry seeds).
    Mirrors the assertion in benchmarks/fig7_9_workloads.py's run()."""
    ds, e = shared_ds, shared_engine
    sels = make_selectors(ds, e, "label")
    scfg = eng.SearchConfig(k=10, l=16, max_hops=400, policy="strict_in",
                            max_pool=1024)
    ids, _, stats = e.search(ds.queries, sels, scfg)
    vectors = np.asarray(e.store.vectors)
    rl = np.asarray(e.store.rec_labels)
    rv = np.asarray(e.store.rec_values)
    recalls = []
    for i, sel in enumerate(sels):
        plan = sel.plan(e.config.ql, e.config.cap)
        q = ds.queries[i]
        if q.shape[0] != vectors.shape[1]:
            q = np.pad(q, (0, vectors.shape[1] - q.shape[0]))
        gt = eng.brute_force_filtered(vectors, rl, rv, plan.qfilter, q, 10)
        recalls.append(recall_at_k(ids[i], gt, 10))
    assert np.mean(recalls) >= 0.30, np.mean(recalls)
    # strict in-filtering still pays the neighbor-attribute reads the paper
    # eliminates — its I/O must dominate what the router would spend
    assert stats.io_pages.mean() > 0


def test_skewed_insert_stream_refreshes_device_buckets():
    """ROADMAP insert-path remainder: a skewed insert stream must trigger
    the per-field quantile refresh, and the engine must re-upload the
    FULL device bucket-code column (a row-tail write would mix codes from
    two bounds generations and break no-false-negatives)."""
    import jax.numpy as jnp
    from repro.core.selectors import RangeSelector, is_member_approx
    rng = np.random.default_rng(11)
    vecs = rng.normal(0, 1, (300, 16)).astype(np.float32)
    meta = [{"v": float(rng.uniform(0, 50))} for _ in range(300)]
    idx = Index.build(vecs, meta,
                      eng.IndexConfig(r=8, r_dense=48, l_build=16, pq_m=4,
                                      max_labels=4, ql=2, cap=64))
    # far above the build-time max, big enough to trip REFRESH_FRAC
    m = 200
    new_vecs = rng.normal(0, 1, (m, 16)).astype(np.float32)
    idx.insert(new_vecs, [{"v": float(rng.uniform(1000, 1050))}
                          for _ in range(m)])
    e = idx.engine
    assert e.range_store.bounds_refreshed, "skewed stream did not refresh"
    n = e.n
    # device tier consistent with the refreshed host codes, all rows
    np.testing.assert_array_equal(
        np.asarray(e.mem.bucket_codes)[:n],
        e.range_store.bucket_codes.astype(
            np.asarray(e.mem.bucket_codes).dtype))
    # the refreshed buckets discriminate the new region...
    fs = e.range_store.field_store(0)
    assert fs.precision(1000.0, 1025.0) > 0.3
    # ...and keep the no-false-negative contract through the device path
    sel = RangeSelector(e.range_store, 1000.0, 1025.0)
    plan = sel.plan(e.config.ql, e.config.cap, e.config.qr)
    approx = np.asarray(is_member_approx(plan.qfilter,
                                         jnp.arange(n), e.mem))
    vals = fs.values[:n]
    truth = (vals >= 1000.0) & (vals < 1025.0)
    assert not np.any(truth & ~approx), "approx false negative after refresh"
