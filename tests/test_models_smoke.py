"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, output shapes + finiteness; decode-path consistency for representative
families."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import list_archs, smoke_config
from repro.models import lm
from repro.models.common import ModelConfig


def make_smoke_batch(cfg: ModelConfig, b=2, s=32, seed=0):
    rng = np.random.default_rng(seed)
    if cfg.frontend == "audio":
        return {
            "frame_embeds": jnp.asarray(
                rng.normal(0, 1, (b, s, cfg.d_model)).astype(np.float32)),
            "targets": jnp.asarray(rng.integers(0, cfg.vocab, (b, s)),
                                   dtype=jnp.int32),
        }
    if cfg.frontend == "vision":
        p = cfg.vision_prefix
        return {
            "patch_embeds": jnp.asarray(
                rng.normal(0, 1, (b, p, cfg.d_model)).astype(np.float32)),
            "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (b, s - p)),
                                  dtype=jnp.int32),
            "targets": jnp.asarray(rng.integers(0, cfg.vocab, (b, s - p)),
                                   dtype=jnp.int32),
        }
    return {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (b, s)),
                              dtype=jnp.int32),
        "targets": jnp.asarray(rng.integers(0, cfg.vocab, (b, s)),
                               dtype=jnp.int32),
    }


@pytest.mark.parametrize("arch", list_archs())
def test_forward_shapes_and_finiteness(arch):
    cfg = smoke_config(arch)
    params = lm.init_lm(cfg, jax.random.PRNGKey(0))
    batch = make_smoke_batch(cfg)
    logits, aux = jax.jit(lambda p, b: lm.lm_forward(p, cfg, b))(params, batch)
    b = 2
    s = 32 if cfg.frontend != "vision" else 32
    assert logits.shape == (b, s, cfg.vocab)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))
    for v in aux.values():
        assert np.isfinite(float(v))


@pytest.mark.parametrize("arch", list_archs())
def test_train_step_no_nans(arch):
    cfg = smoke_config(arch)
    params = lm.init_lm(cfg, jax.random.PRNGKey(1))
    batch = make_smoke_batch(cfg, seed=1)

    def loss_fn(p):
        total, metrics = lm.lm_loss(p, cfg, batch)
        return total

    loss, grads = jax.jit(jax.value_and_grad(loss_fn))(params)
    assert np.isfinite(float(loss))
    flat = jax.tree_util.tree_leaves(grads)
    assert all(np.all(np.isfinite(np.asarray(g, np.float32))) for g in flat)
    # loss should be near ln(vocab) for random init
    assert 0.5 * np.log(cfg.vocab) < float(loss) < 2.5 * np.log(cfg.vocab)


@pytest.mark.parametrize("arch", ["qwen2-7b", "mamba2-2.7b", "jamba-v0.1-52b",
                                  "mixtral-8x22b"])
def test_decode_matches_forward(arch):
    """Prefill + step-by-step decode must reproduce the full-sequence
    forward logits (KV-cache / SSM-state correctness)."""
    cfg = smoke_config(arch)
    params = lm.init_lm(cfg, jax.random.PRNGKey(2))
    b, s = 2, 24
    rng = np.random.default_rng(3)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), dtype=jnp.int32)

    full_logits, _ = lm.lm_forward(params, cfg, {"tokens": tokens})

    prefix = 16
    logits_p, caches = lm.lm_prefill(params, cfg,
                                     {"tokens": tokens[:, :prefix]},
                                     max_t=s + 8)
    np.testing.assert_allclose(np.asarray(logits_p[:, 0]),
                               np.asarray(full_logits[:, prefix - 1]),
                               rtol=2e-3, atol=2e-3)
    step = jax.jit(lambda p, c, t: lm.lm_decode_step(p, c, cfg, t))
    for i in range(prefix, s):
        logits_d, caches = step(params, caches, tokens[:, i:i + 1])
        np.testing.assert_allclose(np.asarray(logits_d[:, 0]),
                                   np.asarray(full_logits[:, i]),
                                   rtol=2e-3, atol=2e-3)


def test_sliding_window_ring_decode():
    """SWA ring-buffer decode == full forward with windowed mask."""
    cfg = smoke_config("mixtral-8x22b")
    assert cfg.window == 32
    params = lm.init_lm(cfg, jax.random.PRNGKey(4))
    b, s = 1, 48                    # exceed the window to exercise the ring
    rng = np.random.default_rng(5)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), dtype=jnp.int32)
    full_logits, _ = lm.lm_forward(params, cfg, {"tokens": tokens})

    prefix = 40                     # > window: prefill must fold the ring
    _, caches = lm.lm_prefill(params, cfg, {"tokens": tokens[:, :prefix]},
                              max_t=s)
    step = jax.jit(lambda p, c, t: lm.lm_decode_step(p, c, cfg, t))
    for i in range(prefix, s):
        logits_d, caches = step(params, caches, tokens[:, i:i + 1])
        np.testing.assert_allclose(np.asarray(logits_d[:, 0]),
                                   np.asarray(full_logits[:, i]),
                                   rtol=2e-3, atol=2e-3)


def test_blockwise_attention_matches_full():
    from repro.models import attention as A
    import dataclasses
    cfg = smoke_config("qwen2-1.5b")
    cfg = dataclasses.replace(cfg, attn_chunk_q=8, attn_chunk_kv=8)
    p = A.init_attn(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(0, 1, (2, 32, cfg.d_model)).astype(np.float32))
    pos = jnp.broadcast_to(jnp.arange(32)[None], (2, 32))
    q, k, v = A._project_qkv(p, x, cfg, pos)
    full = A.full_attention(q, k, v, cfg)
    blocked = A.blockwise_attention(q, k, v, cfg)
    np.testing.assert_allclose(np.asarray(blocked), np.asarray(full),
                               rtol=2e-3, atol=2e-3)


def test_blockwise_attention_windowed():
    from repro.models import attention as A
    import dataclasses
    cfg = smoke_config("mixtral-8x22b")
    cfg = dataclasses.replace(cfg, attn_chunk_q=8, attn_chunk_kv=8, window=12)
    p = A.init_attn(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(0, 1, (1, 64, cfg.d_model)).astype(np.float32))
    pos = jnp.broadcast_to(jnp.arange(64)[None], (1, 64))
    q, k, v = A._project_qkv(p, x, cfg, pos)
    full = A.full_attention(q, k, v, cfg)
    blocked = A.blockwise_attention(q, k, v, cfg)
    np.testing.assert_allclose(np.asarray(blocked), np.asarray(full),
                               rtol=2e-3, atol=2e-3)
