"""Schema-first attribute API: multi-field numeric filters end-to-end.

Covers the tentpole acceptance path (a tag ∧ two-numeric-field conjunction
compiling natively onto device verification and matching the exact host
scan bit-for-bit on a ≥10K corpus), the DSL error paths (unknown fields
fail at compile time, same-field intervals intersect, mixed-field ANDs
avoid the MaskSelector fallback), and the format-1 → F=1 checkpoint shim.
"""
import json
import os

import numpy as np
import pytest

from repro.api import (Index, IndexConfig, Num, Schema, SearchConfig,
                       SearchRequest, Tag, UnknownFieldError, compile_expr)
from repro.core.selectors import (AndSelector, MaskSelector, RangeSelector)

pytestmark = pytest.mark.fast

N = 10_000
D = 24


@pytest.fixture(scope="module")
def corpus():
    rng = np.random.default_rng(42)
    centers = rng.normal(0, 1.0, (12, D)).astype(np.float32)
    assign = rng.integers(0, 12, N)
    vecs = (centers[assign] + rng.normal(0, 0.3, (N, D))).astype(np.float32)
    cats = rng.integers(0, 5, N)
    prices = rng.uniform(0, 100, N).astype(np.float32)
    years = rng.integers(2000, 2030, N).astype(np.float32)
    meta = [{"cat": int(c), "price": float(p), "year": float(y)}
            for c, p, y in zip(cats, prices, years)]
    return vecs, meta, cats, prices, years


@pytest.fixture(scope="module")
def index(corpus):
    vecs, meta, *_ = corpus
    return Index.build(
        vecs, meta,
        IndexConfig(r=16, r_dense=160, l_build=32, pq_m=8),
        schema=Schema(tags=["cat"], nums=["price", "year"]),
        defaults=SearchConfig(k=10, l=64, max_hops=400, max_pool=1024))


# ---------------------------------------------------------------------------
# Schema object
# ---------------------------------------------------------------------------

def test_schema_inference_floats_become_nums():
    meta = [{"cat": 1, "price": 9.5, "year": 2021.0},
            {"cat": 2, "price": 1.0, "year": 2000.0, "lang": "en"}]
    s = Schema.infer(meta)
    assert s.nums == ("price", "year")          # sorted, deterministic
    assert s.tags == ("cat", "lang")
    assert s.num_index("year") == 1


def test_schema_rejects_overlap_and_mixed_types():
    with pytest.raises(ValueError, match="both"):
        Schema(tags=["x"], nums=["x"])
    with pytest.raises(ValueError, match="disambiguate"):
        Schema.infer([{"x": 1.0}, {"x": "red"}])


def test_schema_unknown_field_is_keyerror_style():
    s = Schema(tags=["cat"], nums=["price"])
    with pytest.raises(UnknownFieldError):
        s.num_index("prize")
    # KeyError-style *and* backward-compatible with ValueError handlers
    assert issubclass(UnknownFieldError, KeyError)
    assert issubclass(UnknownFieldError, ValueError)


def test_build_infers_multi_field_schema(corpus):
    vecs, meta, *_ = corpus
    sub = Index.build(vecs[:200], meta[:200],
                      IndexConfig(r=8, r_dense=32, l_build=16, pq_m=4))
    assert sub.schema.nums == ("price", "year")
    assert sub.schema.tags == ("cat",)
    assert sub.store.rec_values.shape == (200, 2)


# ---------------------------------------------------------------------------
# DSL error paths + compilation targets (satellite: compile-time failures)
# ---------------------------------------------------------------------------

def test_unknown_fields_fail_at_compile_time(index):
    with pytest.raises(UnknownFieldError, match="not indexed"):
        compile_expr(Num("prize") < 5.0, index)
    with pytest.raises(UnknownFieldError, match="not indexed"):
        compile_expr(Tag("catt") == 1, index)
    # ...and through ground_truth, which must validate too
    with pytest.raises(UnknownFieldError, match="not indexed"):
        index.ground_truth(SearchRequest(query=np.zeros(D, np.float32),
                                         filter=Num("prize") < 5.0))


def test_same_field_ranges_intersect_into_one_interval(index):
    sel = compile_expr((Num("price") >= 10.0) & (Num("price") < 50.0), index)
    assert isinstance(sel, RangeSelector)       # one interval, no combinator
    assert sel.lo == 10.0 and sel.hi == 50.0
    # intersecting with a tag keeps a single merged range slot
    sel = compile_expr((Tag("cat") == 1) & (Num("price") >= 10.0)
                       & (Num("price") < 50.0), index)
    assert isinstance(sel, AndSelector)
    assert len(sel.range_sels) == 1
    assert sel.range_sels[0].lo == 10.0 and sel.range_sels[0].hi == 50.0


def test_mixed_field_and_avoids_mask_fallback(index):
    expr = ((Tag("cat") == 2) & (Num("price") < 50.0)
            & (Num("year") >= 2020.0))
    sel = compile_expr(expr, index)
    assert isinstance(sel, AndSelector), type(sel).__name__
    assert not isinstance(sel, MaskSelector)
    fields = sorted(r.field for r in sel.range_sels)
    assert fields == [0, 1]                     # price, year columns
    plan = sel.plan(index.ql, index.config.cap, index.qr)
    assert plan.force_mech is None              # native device route
    # the emitted filter carries both predicates in distinct slots
    active = np.asarray(plan.qfilter.range_field) >= 0
    assert active.sum() == 2


def test_ranges_only_multi_field_and(index):
    sel = compile_expr((Num("price") < 30.0) & (Num("year") >= 2010.0),
                       index)
    assert isinstance(sel, AndSelector) and sel.label_sel is None
    assert len(sel.range_sels) == 2


def test_more_fields_than_qr_slots_falls_back(corpus):
    """An AND over more numeric fields than IndexConfig.qr predicate slots
    cannot ride the fixed-width filter: exact MaskSelector fallback."""
    vecs, *_ = corpus
    rng = np.random.default_rng(0)
    meta = [{f"n{j}": float(rng.uniform(0, 1)) for j in range(3)}
            for _ in range(150)]
    sub = Index.build(vecs[:150], meta,
                      IndexConfig(r=8, r_dense=32, l_build=16, pq_m=4, qr=2))
    expr = ((Num("n0") < 0.9) & (Num("n1") < 0.9) & (Num("n2") < 0.9))
    sel = compile_expr(expr, sub)
    assert isinstance(sel, MaskSelector)
    # still answers exactly (forced-pre route)
    res = sub.search(SearchRequest(query=vecs[0], filter=expr, k=5))
    gt = sub.ground_truth(SearchRequest(query=vecs[0], filter=expr, k=5))
    assert set(res.ids[res.ids >= 0].tolist()) <= set(gt.tolist()) | {-1}


# ---------------------------------------------------------------------------
# Tentpole acceptance: tag ∧ two numeric ranges, end to end
# ---------------------------------------------------------------------------

def test_tag_and_two_numeric_ranges_matches_ground_truth(index, corpus):
    """A query AND-ing one tag predicate with ranges over two *different*
    numeric fields routes through device-side verification (no MaskSelector
    fallback) and returns results bit-identical to the exact host scan."""
    vecs, meta, cats, prices, years = corpus
    rng = np.random.default_rng(5)
    expr = ((Tag("cat") == 2) & (Num("price") < 15.0)
            & (Num("year") >= 2020.0))
    sel = compile_expr(expr, index)
    assert isinstance(sel, AndSelector) and not isinstance(sel, MaskSelector)
    assert sel.plan(index.ql, index.config.cap, index.qr).force_mech is None

    # independent host truth over the raw metadata (no engine structures)
    want = (cats == 2) & (prices < np.float32(15.0)) \
        & (years >= np.float32(2020.0))
    n_valid = int(want.sum())
    assert 30 <= n_valid <= 500, n_valid        # realistic joint selectivity

    for trial in range(6):
        q = vecs[rng.integers(0, N)] + rng.normal(0, 0.1, D) \
            .astype(np.float32)
        req = SearchRequest(query=q, filter=expr, k=10)
        gt = index.ground_truth(req)
        res = index.search(req)
        got = res.ids
        assert res.stats.mechanism in ("pre", "in", "post")
        np.testing.assert_array_equal(
            got[:gt.size], gt, err_msg=f"trial {trial}")
        assert np.all(got[gt.size:] == -1)
        # every hit exactly satisfies the three-predicate conjunction
        for rec_id, _, m in res.matches:
            assert m["cat"] == 2 and m["price"] < 15.0 and m["year"] >= 2020


def test_multi_field_or_still_exact(index, corpus):
    """OR over two numeric fields is outside the approximate algebra —
    falls back to the exact mask route and stays correct."""
    vecs, _, cats, prices, years = corpus
    expr = (Num("price") < 5.0) | (Num("year") >= 2028.0)
    sel = compile_expr(expr, index)
    assert isinstance(sel, MaskSelector)
    want = (prices < np.float32(5.0)) | (years >= np.float32(2028.0))
    got = np.zeros(N, bool)
    got[sel.valid_ids] = True
    np.testing.assert_array_equal(got, want)


# ---------------------------------------------------------------------------
# Persistence: format-2 roundtrip + format-1 (legacy F=1) shim
# ---------------------------------------------------------------------------

def test_save_load_roundtrip_two_fields(index, tmp_path):
    path = str(tmp_path / "idx2f")
    index.save(path)
    loaded = Index.load(path)
    assert loaded.schema == index.schema
    assert loaded.range_store.n_fields == 2
    rng = np.random.default_rng(11)
    q = rng.normal(0, 1, D).astype(np.float32)
    expr = ((Tag("cat") == 1) & (Num("price") < 40.0)
            & (Num("year") >= 2010.0))
    for policy in ("speculative", "post"):
        req = SearchRequest(query=q, filter=expr, policy=policy)
        a, b = index.search(req), loaded.search(req)
        np.testing.assert_array_equal(a.ids, b.ids)
        np.testing.assert_allclose(a.dists, b.dists, rtol=1e-6)


def _rewrite_as_legacy_checkpoint(src: str, dst: str):
    """Down-convert a freshly-saved F=1 checkpoint to the format-1 layout
    (flat (n,) range arrays, ``numeric_field`` sidecar key, no schema)."""
    import jax
    from repro.ckpt import checkpoint as ckpt
    with open(os.path.join(src, "index_meta.json")) as fh:
        meta = json.load(fh)
    target = {k: jax.ShapeDtypeStruct(tuple(v["shape"]), np.dtype(v["dtype"]))
              for k, v in meta["arrays"].items()}
    t = {k: np.asarray(v) for k, v in ckpt.restore(src, 0, target).items()}
    assert t["rs_values"].shape[1] == 1
    for key in ("store_rec_values", "rs_values", "rs_bucket_codes"):
        t[key] = t[key][:, 0]
    for key in ("rs_sorted_values", "rs_sorted_ids", "rs_bucket_bounds",
                "rs_quantiles"):
        t[key] = t[key][0]
    ckpt.save(dst, step=0, tree=t, async_write=False, keep_last=1)
    schema = meta.pop("schema")
    meta["format"] = 1
    meta["numeric_field"] = schema["nums"][0] if schema["nums"] else None
    meta["arrays"] = {k: {"shape": list(a.shape), "dtype": str(a.dtype)}
                      for k, a in t.items()}
    with open(os.path.join(dst, "index_meta.json"), "w") as fh:
        json.dump(meta, fh)


def test_legacy_single_field_checkpoint_shim(tmp_path):
    """A pre-schema (format-1) single-numeric-field checkpoint loads through
    the F=1 shim and answers unchanged."""
    rng = np.random.default_rng(23)
    vecs = rng.normal(0, 1, (500, 16)).astype(np.float32)
    meta = [{"cat": int(rng.integers(0, 4)), "v": float(rng.uniform(0, 100))}
            for _ in range(500)]
    idx = Index.build(vecs, meta,
                      IndexConfig(r=8, r_dense=48, l_build=16, pq_m=4),
                      defaults=SearchConfig(k=5, l=32))
    new_path = str(tmp_path / "new")
    legacy_path = str(tmp_path / "legacy")
    idx.save(new_path)
    _rewrite_as_legacy_checkpoint(new_path, legacy_path)

    loaded = Index.load(legacy_path)
    assert loaded.schema == Schema(tags=("cat",), nums=("v",))
    assert loaded.numeric_field == "v"          # deprecated accessor shims
    assert loaded.store.rec_values.shape == (500, 1)
    for seed in (0, 1, 2):
        q = np.random.default_rng(seed).normal(0, 1, 16).astype(np.float32)
        for f in (None, (Tag("cat") == 2) & (Num("v") < 50.0)):
            req = SearchRequest(query=q, filter=f)
            a, b = idx.search(req), loaded.search(req)
            np.testing.assert_array_equal(a.ids, b.ids)
            np.testing.assert_allclose(a.dists, b.dists, rtol=1e-6)
    assert loaded.record_metadata(3) == idx.record_metadata(3)
