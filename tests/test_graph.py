import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import graph


@pytest.fixture(scope="module")
def built():
    rng = np.random.default_rng(0)
    data = rng.normal(0, 1, (1500, 24)).astype(np.float32)
    adj, medoid = graph.build_vamana(data, r=24, ell=40, alpha=1.2, seed=0)
    return data, adj, medoid


def test_adjacency_valid(built):
    data, adj, medoid = built
    n, r = adj.shape
    assert r == 24
    valid = adj >= 0
    assert np.all(adj[valid] < n)
    # no self loops
    self_loop = adj == np.arange(n)[:, None]
    assert not np.any(self_loop)
    stats = graph.graph_stats(adj)
    assert stats["avg_degree"] > 4


def test_unfiltered_search_recall(built):
    """Greedy search over the built graph must find near-exact neighbors."""
    data, adj, medoid = built
    rng = np.random.default_rng(1)
    queries = data[rng.integers(0, len(data), 20)] + \
        rng.normal(0, 0.01, (20, data.shape[1])).astype(np.float32)
    ids, dists = graph.greedy_search(jnp.asarray(data), jnp.asarray(adj),
                                     medoid, jnp.asarray(queries),
                                     ell=40, max_hops=200)
    ids = np.asarray(ids)
    recalls = []
    for i, q in enumerate(queries):
        exact = np.argsort(np.sum((data - q[None]) ** 2, 1))[:10]
        got = set(ids[i, :10].tolist())
        recalls.append(len(got & set(exact.tolist())) / 10)
    assert np.mean(recalls) >= 0.9, f"mean recall {np.mean(recalls)}"


def test_densify_2hop(built):
    data, adj, medoid = built
    dense = graph.densify_2hop(adj, r_dense=200, seed=3)
    assert dense.shape == (len(data), 200)
    valid = dense >= 0
    assert valid.mean() > 0.5
    # 2-hop entries must actually be reachable in <= 2 hops
    n_check = 50
    rng = np.random.default_rng(0)
    for i in rng.integers(0, len(data), n_check):
        one_hop = set(adj[i][adj[i] >= 0].tolist())
        two_hop = set()
        for j in one_hop:
            two_hop |= set(adj[j][adj[j] >= 0].tolist())
        cand = set(dense[i][dense[i] >= 0].tolist())
        assert cand <= (one_hop | two_hop | {int(i)})
