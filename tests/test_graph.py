import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import graph


@pytest.fixture(scope="module")
def data():
    rng = np.random.default_rng(0)
    return rng.normal(0, 1, (1500, 24)).astype(np.float32)


@pytest.fixture(scope="module")
def built(data):
    """Sequential numpy reference build (the correctness oracle)."""
    adj, medoid = graph.build_vamana(data, r=24, ell=40, alpha=1.2, seed=0)
    return data, adj, medoid


@pytest.fixture(scope="module")
def built_batched(data):
    """Device-resident batched build at identical parameters/seed."""
    adj, medoid = graph.build_vamana_batched(data, r=24, ell=40, alpha=1.2,
                                             seed=0)
    return data, adj, medoid


def _recall10(data, adj, medoid, queries):
    return graph.greedy_recall_at_k(data, adj, medoid, queries, ell=40)


def _check_adjacency(data, adj, r):
    n = len(data)
    assert adj.shape == (n, r)
    valid = adj >= 0
    assert np.all(adj[valid] < n)
    # no self loops
    assert not np.any(adj == np.arange(n)[:, None])
    # no duplicate neighbors within a row
    srt = np.sort(np.where(valid, adj, np.iinfo(np.int32).max), axis=1)
    assert not np.any((srt[:, 1:] == srt[:, :-1]) & (srt[:, 1:] >= 0)
                      & (srt[:, 1:] < np.iinfo(np.int32).max))


def test_adjacency_valid(built):
    data, adj, medoid = built
    _check_adjacency(data, adj, 24)
    stats = graph.graph_stats(adj)
    assert stats["avg_degree"] > 4


def test_adjacency_valid_batched(built_batched):
    data, adj, medoid = built_batched
    _check_adjacency(data, adj, 24)
    stats = graph.graph_stats(adj)
    assert stats["avg_degree"] > 4


def test_unfiltered_search_recall(built):
    """Greedy search over the built graph must find near-exact neighbors."""
    data, adj, medoid = built
    rng = np.random.default_rng(1)
    queries = data[rng.integers(0, len(data), 20)] + \
        rng.normal(0, 0.01, (20, data.shape[1])).astype(np.float32)
    assert _recall10(data, adj, medoid, queries) >= 0.9


def test_batched_matches_reference(built, built_batched):
    """Equivalence gate: identical seeds/parameters → the batched builder
    reaches recall@10 within 1% of the sequential reference, and the degree
    profile stays within the same bounds."""
    data, adj_r, med_r = built
    _, adj_b, med_b = built_batched
    assert med_b == med_r                      # same medoid computation
    rng = np.random.default_rng(2)
    queries = data[rng.integers(0, len(data), 32)] + \
        rng.normal(0, 0.05, (32, data.shape[1])).astype(np.float32)
    rec_r = _recall10(data, adj_r, med_r, queries)
    rec_b = _recall10(data, adj_b, med_b, queries)
    assert rec_b >= rec_r - 0.01, (rec_b, rec_r)
    s_r, s_b = graph.graph_stats(adj_r), graph.graph_stats(adj_b)
    assert s_b["max_degree"] <= 24
    assert s_b["min_degree"] >= 1
    assert abs(s_b["avg_degree"] - s_r["avg_degree"]) < 2.0, (s_b, s_r)


def test_beam_pool_matches_plain_greedy(built):
    """The batched builder's beam navigator returns pools of the same
    quality as the single-step greedy search."""
    data, adj, medoid = built
    rng = np.random.default_rng(3)
    queries = jnp.asarray(
        data[rng.integers(0, len(data), 16)]
        + rng.normal(0, 0.05, (16, data.shape[1])).astype(np.float32))
    d = jnp.asarray(data)
    a = jnp.asarray(adj)
    ids_plain, _ = graph.greedy_search(d, a, medoid, queries, ell=40,
                                       max_hops=200)
    ids_beam, _ = graph.greedy_search_beam(d, a, medoid, queries, ell=40,
                                           max_hops=200)
    # top-10 pool overlap stays high (beam explores in coarser order)
    overlaps = []
    for p, b in zip(np.asarray(ids_plain), np.asarray(ids_beam)):
        overlaps.append(len(set(p[:10].tolist()) & set(b[:10].tolist())) / 10)
    assert np.mean(overlaps) >= 0.8, np.mean(overlaps)


def test_robust_prune_batch_matches_numpy(data):
    """Single-node bit-compat: the vectorized prune keeps the same ids in
    the same order as the sequential numpy RobustPrune."""
    rng = np.random.default_rng(4)
    for alpha in (1.0, 1.2):
        p_ids = rng.integers(0, len(data), 8).astype(np.int32)
        cand = np.full((8, 48), -1, np.int32)
        for i in range(8):
            c = rng.choice(len(data), size=rng.integers(5, 48),
                           replace=False)
            c = np.unique(c[c != p_ids[i]])
            cand[i, :c.size] = c
        rows = np.asarray(graph.robust_prune_batch(
            jnp.asarray(data), jnp.asarray(p_ids), jnp.asarray(cand),
            r=8, alpha=alpha))
        for i in range(8):
            c = cand[i][cand[i] >= 0]
            want = graph.robust_prune(data[p_ids[i]], c, data[c], 8, alpha)
            got = rows[i][rows[i] >= 0]
            np.testing.assert_array_equal(got, want)


def test_incremental_builder_appends(data):
    b = graph.IncrementalBuilder.build(data[:1000], r=16, ell=32, alpha=1.2,
                                       seed=0)
    ids1 = b.add_batch(data[1000:1200])
    ids2 = b.add_batch(data[1200:1250])
    assert ids1.tolist() == list(range(1000, 1200))
    assert ids2.tolist() == list(range(1200, 1250))
    assert b.n == 1250
    adj = b.adjacency
    _check_adjacency(data[:1250], adj, 16)
    # inserted nodes are wired in (non-trivial degree both directions)
    new_deg = (adj[1000:] >= 0).sum(1)
    assert new_deg.mean() > 4
    incoming = np.isin(adj[:1000], np.arange(1000, 1250)).sum()
    assert incoming > 0
    # and they are findable by search
    rng = np.random.default_rng(5)
    qidx = rng.integers(1000, 1250, 20)
    queries = data[qidx]
    ids, _ = graph.greedy_search(jnp.asarray(b.data),
                                 jnp.asarray(adj), b.medoid,
                                 jnp.asarray(queries), ell=32, max_hops=200)
    ids = np.asarray(ids)
    hits = sum(int(qidx[i]) in ids[i, :10].tolist() for i in range(20))
    assert hits >= 18, hits


def test_incremental_builder_rejects_bad_shape(data):
    b = graph.IncrementalBuilder.build(data[:500], r=16, ell=32, seed=0)
    with pytest.raises(ValueError):
        b.add_batch(np.zeros((3, 7), np.float32))
    assert b.add_batch(np.zeros((0, 24), np.float32)).size == 0


def test_densify_2hop(built):
    data, adj, medoid = built
    dense = graph.densify_2hop(adj, r_dense=200, seed=3)
    assert dense.shape == (len(data), 200)
    valid = dense >= 0
    assert valid.mean() > 0.5
    # 2-hop entries must actually be reachable in <= 2 hops
    n_check = 50
    rng = np.random.default_rng(0)
    for i in rng.integers(0, len(data), n_check):
        one_hop = set(adj[i][adj[i] >= 0].tolist())
        two_hop = set()
        for j in one_hop:
            two_hop |= set(adj[j][adj[j] >= 0].tolist())
        cand = set(dense[i][dense[i] >= 0].tolist())
        assert cand <= (one_hop | two_hop | {int(i)})
