"""Tiered record storage (storage/): slab format, clock page cache,
bloom-gated reads, fault routing, and the disk-backend facade.

Core property throughout: the disk backend is an *I/O path*, never a
*result path* — every suite here pins some disk configuration (cache
size, read-ahead depth, fault plan, eviction pressure) against the
all-resident device backend and asserts bit-identical ids and distances.
"""
import copy
import dataclasses
import glob
import os

import numpy as np
import pytest

from repro.api import (Index, IndexConfig, SearchConfig, SearchRequest,
                       Session, SessionConfig, Tag)
from repro.ckpt.checkpoint import CheckpointCorruptionError
from repro.core import search as search_mod
from repro.core.faults import (FaultPlan, read_attempt_bad,
                               read_attempt_bad_np)
from repro.core.io_sim import IOModel
from repro.storage import (DiskRecordStore, PageCache, SlabLayout,
                           StorageConfig)
from repro.storage import slab as slab_mod

pytestmark = pytest.mark.disk

POLICIES = ("strict_in", "post", "speculative", "strict_pre")


@pytest.fixture(scope="module", autouse=True)
def _fresh_compile_state():
    """Drop executables accumulated by the rest of the suite.

    This module compiles the pipelined search with an embedded io_callback
    custom call; doing that on top of several hundred live XLA executables
    has produced flaky CPU backend_compile segfaults on single-core runners.
    The suite orders this file last, so clearing costs no downstream
    recompiles — the module's own fixtures compile fresh either way.
    """
    import gc
    import jax
    jax.clear_caches()
    gc.collect()


# ---------------------------------------------------------------------------
# Unit: slab encode/decode (fast)
# ---------------------------------------------------------------------------

@pytest.mark.fast
def test_slab_roundtrip_and_crc():
    rng = np.random.default_rng(0)
    lo = SlabLayout(dim=48, r=16, r_dense=100, max_labels=8, n_fields=2)
    vec = rng.normal(0, 1, 48).astype(np.float32)
    nbrs = rng.integers(-1, 500, 16).astype(np.int32)
    dense = rng.integers(-1, 500, 100).astype(np.int32)
    labels = rng.integers(-1, 60, 8).astype(np.int32)
    values = rng.uniform(0, 1, 2).astype(np.float32)
    cf = rng.integers(0, 2, 116).astype(bool)
    blob = slab_mod.encode_slab(lo, vec, nbrs, dense, labels, values, cf)
    assert len(blob) == lo.slab_bytes and lo.slab_bytes % lo.page_bytes == 0

    rec = slab_mod.decode_std(lo, blob[:lo.std_bytes])
    np.testing.assert_array_equal(rec["vector"], vec)
    np.testing.assert_array_equal(rec["neighbors"], nbrs)
    np.testing.assert_array_equal(rec["rec_labels"], labels)
    np.testing.assert_array_equal(rec["rec_values"], values)
    np.testing.assert_array_equal(rec["cand_first"], cf)
    np.testing.assert_array_equal(
        slab_mod.decode_dense(lo, blob[lo.std_bytes:]), dense)

    # attr probe decodes from the std block's final page alone
    pg = blob[lo.attr_page * lo.page_bytes:(lo.attr_page + 1) * lo.page_bytes]
    attrs = slab_mod.decode_attrs(lo, pg)
    np.testing.assert_array_equal(attrs["rec_labels"], labels)
    np.testing.assert_array_equal(attrs["rec_values"], values)

    # a bit flip in any region is a *detected* checksum failure
    for off in (0, lo.tail_off + 3):
        bad = bytearray(blob)
        bad[off] ^= 0xFF
        with pytest.raises(slab_mod.SlabChecksumError):
            slab_mod.decode_std(lo, bytes(bad[:lo.std_bytes]))
    bad = bytearray(blob)
    bad[lo.std_bytes] ^= 0xFF
    with pytest.raises(slab_mod.SlabChecksumError):
        slab_mod.decode_dense(lo, bytes(bad[lo.std_bytes:]))


@pytest.mark.fast
def test_slab_layout_tail_fits_one_page():
    lo = SlabLayout(dim=128, r=64, r_dense=500, max_labels=16, n_fields=4)
    assert lo.tail_bytes <= lo.page_bytes
    assert lo.attr_page == lo.std_pages - 1
    assert lo.slab_pages == lo.std_pages + lo.dense_pages
    # round-trip through the meta encoding
    assert SlabLayout.from_json(lo.to_json()).slab_bytes == lo.slab_bytes


# ---------------------------------------------------------------------------
# Unit: clock page cache (fast)
# ---------------------------------------------------------------------------

@pytest.mark.fast
def test_page_cache_clock_eviction_and_counters():
    c = PageCache(4)
    for pid in range(4):
        c.put(pid, bytes([pid]))
    assert c.get(1) == b"\x01"
    # every fresh frame gets one second chance: the sweep clears all four
    # ref bits, wraps, and evicts the oldest (0)
    c.put(4, b"\x04")
    assert c.evictions == 1 and not c.contains(0) and c.contains(1)
    assert c.get(0) is None
    snap = c.counters()
    assert snap["hits"] == 1 and snap["misses"] == 1
    assert snap["resident_pages"] == 4 and snap["capacity_pages"] == 4

    # a re-referenced frame (1) survives the next eviction; a cold one dies
    c.get(1)
    c.put(5, b"\x05")
    assert c.contains(1) and c.evictions == 2

    # readahead provenance: only the first demand hit counts
    c.put(7, b"\x07", readahead=True)
    assert c.readahead_hits == 0
    c.get(7); c.get(7)
    assert c.readahead_hits == 1

    # invalidate drops frames; stale ring slots are reaped by the sweep
    before = len(c)
    c.invalidate([1, 7])
    assert not c.contains(1) and not c.contains(7) and len(c) == before - 2
    for pid in range(10, 20):
        c.put(pid, b"x")
    assert len(c) <= 4 and c.contains(19)


# ---------------------------------------------------------------------------
# Unit: IOModel calibration from measured samples (fast)
# ---------------------------------------------------------------------------

@pytest.mark.fast
def test_calibrate_from_samples_recovers_synthetic_device():
    t_page, par = 80.0, 8
    serial = [{"pages": p, "us": p * t_page, "kind": "serial"}
              for p in (1, 1, 2, 3, 1)]
    batch = [{"pages": p, "us": -(-p // par) * t_page, "kind": "batch"}
             for p in (8, 16, 24, 64, 128, 40)]
    m = IOModel.calibrate_from_samples(serial + batch)
    assert m.t_page_us == pytest.approx(t_page)
    assert m.parallelism == par

    # median fit shrugs off one OS-cache outlier
    noisy = serial + [{"pages": 1, "us": 50000.0, "kind": "serial"}]
    assert IOModel.calibrate_from_samples(noisy).t_page_us == \
        pytest.approx(t_page)

    # empty families fall back to the class defaults
    m0 = IOModel.calibrate_from_samples([])
    assert m0.t_page_us == IOModel.t_page_us
    assert m0.parallelism == IOModel.parallelism


@pytest.mark.fast
def test_prefetch_depth_validation():
    search_mod.SearchParams(l_search=16, prefetch_depth=4)      # widened: ok
    with pytest.raises(AssertionError, match="prefetch_depth"):
        search_mod.SearchParams(l_search=16,
                                prefetch_depth=IOModel.parallelism + 1)
    with pytest.raises(AssertionError, match="prefetch_depth"):
        search_mod.SearchParams(l_search=16, prefetch_depth=0)
    # the per-request override carries through SearchRequest
    assert SearchRequest(query=np.zeros(4, np.float32),
                         prefetch_depth=3).overrides()["prefetch_depth"] == 3


@pytest.mark.fast
def test_fault_draw_twins_bit_identical():
    """The host read path and the jitted ladder must see the same draws."""
    import jax.numpy as jnp
    plan = FaultPlan(read_fail_rate=0.2, corrupt_rate=0.1, seed=11)
    ids = np.arange(4096)
    hops = ids % 17
    for a in range(plan.attempts):
        dev = np.asarray(read_attempt_bad(jnp.asarray(ids), jnp.asarray(hops),
                                          a, plan))
        host = read_attempt_bad_np(ids, hops, a, plan)
        np.testing.assert_array_equal(dev, host)


# ---------------------------------------------------------------------------
# Integration: disk backend vs device backend
# ---------------------------------------------------------------------------

N = 600
DIM = 24


@pytest.fixture(scope="module")
def corpus():
    rng = np.random.default_rng(7)
    vectors = rng.normal(0, 1, (N, DIM)).astype(np.float32)
    metadata = [{"cat": sorted(set(int(x) for x in
                               rng.integers(0, 8, rng.integers(1, 4)))),
                 "value": float(v)}
                for v in rng.uniform(0, 100, N)]
    return vectors, metadata


CFG = IndexConfig(r=12, r_dense=60, l_build=24, pq_m=8)
DEFAULTS = SearchConfig(k=5, l=16, max_hops=60)


@pytest.fixture(scope="module")
def mem_index(corpus):
    vectors, metadata = corpus
    return Index.build(vectors, metadata, CFG, defaults=DEFAULTS)


@pytest.fixture(scope="module")
def slab_dir(tmp_path_factory, mem_index):
    """Slabs spilled once from the built engine; reopened per test with
    different StorageConfigs."""
    path = str(tmp_path_factory.mktemp("slabs"))
    DiskRecordStore.from_record_store(path, mem_index.engine.store,
                                      n=mem_index.engine.n).close()
    return path


def _requests(vectors, n=6, policies=POLICIES):
    return [SearchRequest(query=vectors[i] + 0.01,
                          filter=(Tag("cat") == 2), policy=pol)
            for i in range(n) for pol in policies]


def _disk_twin(mem_index, slab_dir, config=StorageConfig()):
    """A disk-backend clone of the device index sharing graph/PQ state —
    only the record tier differs, which is exactly what's under test."""
    twin = copy.copy(mem_index)
    twin.engine = copy.copy(mem_index.engine)
    twin.engine.attach_disk_store(DiskRecordStore(slab_dir, config))
    return twin


def _assert_identical(res_a, res_b):
    for a, b in zip(res_a, res_b):
        np.testing.assert_array_equal(a.ids, b.ids)
        np.testing.assert_array_equal(a.dists, b.dists)


def test_disk_bit_identical_across_policies(corpus, mem_index, slab_dir):
    vectors, _ = corpus
    reqs = _requests(vectors)
    dsk = _disk_twin(mem_index, slab_dir)
    _assert_identical(mem_index.search_batch(reqs, with_metadata=False),
                      dsk.search_batch(reqs, with_metadata=False))
    snap = dsk.engine.disk_store.snapshot()
    assert snap["pages_read"] > 0 and snap["records_fetched"] > 0
    assert snap["n_samples"] > 0 and snap["p50_page_us"] > 0.0


def test_eviction_order_never_changes_results(corpus, mem_index, slab_dir):
    """Sweep cache capacity from eviction-heavy to all-resident: results
    must be bit-identical throughout (the cache is transparent)."""
    vectors, _ = corpus
    reqs = _requests(vectors, n=4, policies=("strict_in", "post"))
    want = mem_index.search_batch(reqs, with_metadata=False)
    evictions = []
    for cap in (8, 64, 1 << 20):
        dsk = _disk_twin(mem_index, slab_dir,
                         StorageConfig(cache_pages=cap))
        _assert_identical(want, dsk.search_batch(reqs, with_metadata=False))
        evictions.append(dsk.engine.disk_store.snapshot()["evictions"])
    assert evictions[0] > 0          # the tiny cache really thrashed
    assert evictions[-1] == 0        # the big one held everything


def test_bloom_gated_attr_reads_skip_pages(corpus, mem_index, slab_dir):
    vectors, _ = corpus
    reqs = _requests(vectors, n=6, policies=("strict_in",))
    dsk = _disk_twin(mem_index, slab_dir)
    _assert_identical(mem_index.search_batch(reqs, with_metadata=False),
                      dsk.search_batch(reqs, with_metadata=False))
    snap = dsk.engine.disk_store.snapshot()
    assert snap["attr_probes"] > 0
    assert snap["gated_skips"] > 0                     # pages actually saved
    assert snap["attr_reads"] + snap["gated_skips"] == snap["attr_probes"]


def test_readahead_depth_changes_io_not_results(corpus, mem_index, slab_dir):
    vectors, _ = corpus
    want = mem_index.search_batch(_requests(vectors, n=4), with_metadata=False)
    snaps = {}
    for depth in (1, 3):
        reqs = [dataclasses.replace(r, prefetch_depth=depth)
                for r in _requests(vectors, n=4)]
        dsk = _disk_twin(mem_index, slab_dir)
        _assert_identical(want, dsk.search_batch(reqs, with_metadata=False))
        snaps[depth] = dsk.engine.disk_store.snapshot()
    assert snaps[1]["readahead_pages"] == 0
    assert snaps[3]["readahead_pages"] > 0
    assert snaps[3]["readahead_hits"] > 0    # the warmed pages got used


def test_fault_plan_routes_through_real_reads(corpus, mem_index, slab_dir):
    """Same plan, both backends: identical results AND identical ladder
    accounting — the disk tier's genuine IOError/CRC failures follow the
    jitted retry→hedge→degrade ladder draw-for-draw."""
    vectors, _ = corpus
    plan = FaultPlan(read_fail_rate=0.08, corrupt_rate=0.04, seed=11)
    reqs = _requests(vectors, n=4,
                     policies=("strict_in", "post", "speculative"))
    scfg = dataclasses.replace(DEFAULTS, fault_plan=plan)
    mem_f = copy.copy(mem_index)
    mem_f.defaults = scfg
    dsk = _disk_twin(mem_index, slab_dir)
    dsk.defaults = scfg
    rm = mem_f.search_batch(reqs, with_metadata=False)
    rd = dsk.search_batch(reqs, with_metadata=False)
    _assert_identical(rm, rd)
    for a, b in zip(rm, rd):
        assert (a.stats.faults, a.stats.retries, a.stats.degraded) == \
            (b.stats.faults, b.stats.retries, b.stats.degraded)
    snap = dsk.engine.disk_store.snapshot()
    assert snap["faults"] > 0 and snap["retries"] > 0
    # moderate rates: the ladder always recovered -> answers are exact,
    # never fallback-substituted
    assert snap["degraded"] == 0
    assert all(r.stats.degraded == 0 for r in rd)


def test_ladder_exhaustion_degrades_identically(corpus, mem_index, slab_dir):
    vectors, _ = corpus
    plan = FaultPlan(read_fail_rate=0.7, seed=3, max_retries=1, hedge=False)
    scfg = dataclasses.replace(DEFAULTS, fault_plan=plan)
    reqs = _requests(vectors, n=3, policies=("strict_in", "post"))
    mem_f = copy.copy(mem_index)
    mem_f.defaults = scfg
    dsk = _disk_twin(mem_index, slab_dir)
    dsk.defaults = scfg
    rm = mem_f.search_batch(reqs, with_metadata=False)
    rd = dsk.search_batch(reqs, with_metadata=False)
    _assert_identical(rm, rd)
    assert dsk.engine.disk_store.snapshot()["degraded"] > 0
    assert sum(r.stats.degraded for r in rd) > 0


def test_query_stats_and_session_surface_disk_counters(corpus, mem_index,
                                                       slab_dir):
    vectors, _ = corpus
    dsk = _disk_twin(mem_index, slab_dir)
    _, stats = dsk.search_batch(_requests(vectors, n=2), with_stats=True,
                                with_metadata=False)
    assert stats.disk is not None
    assert stats.disk["pages_read"] >= 0 and "hit_rate" in stats.disk
    # device backend reports no disk block
    _, stats_m = mem_index.search_batch(_requests(vectors, n=2),
                                        with_stats=True, with_metadata=False)
    assert stats_m.disk is None

    with Session(dsk, SessionConfig(max_batch=4)) as s:
        h = s.submit(SearchRequest(query=vectors[0],
                                   filter=(Tag("cat") == 2)))
        h.result()
        assert s.disk_stats()["records_fetched"] > 0
    assert Session(mem_index).disk_stats() is None


def test_calibrate_io_fits_model_from_measured_reads(corpus, mem_index,
                                                     slab_dir):
    vectors, _ = corpus
    dsk = _disk_twin(mem_index, slab_dir)
    assert dsk.engine.calibrate_io() is None           # no samples yet
    dsk.search_batch(_requests(vectors, n=4), with_metadata=False)
    model = dsk.engine.calibrate_io()
    assert model is not None and model.t_page_us > 0.0
    assert 1 <= model.parallelism <= 256
    assert dsk.engine.io_model is model


def test_ground_truth_matches_device_backend(corpus, mem_index, slab_dir):
    vectors, _ = corpus
    dsk = _disk_twin(mem_index, slab_dir)
    for flt in (Tag("cat") == 2, None):
        req = SearchRequest(query=vectors[3] + 0.01, filter=flt, k=5)
        np.testing.assert_array_equal(mem_index.ground_truth(req),
                                      dsk.ground_truth(req))


def test_device_budget_honesty(corpus, mem_index, slab_dir):
    """The disk backend's device-resident record bytes (the stub) must be
    tiny; the corpus truly lives on disk (file > any sane budget)."""
    dsk = _disk_twin(mem_index, slab_dir)
    ds = dsk.engine.disk_store
    budget = 64 * 1024
    assert ds.stub_bytes() < budget < ds.file_bytes
    # a device-backend store of the same corpus would blow the budget
    s = mem_index.engine.store
    dev_bytes = sum(int(np.asarray(a).nbytes) for a in
                    (s.vectors, s.neighbors, s.dense_neighbors,
                     s.rec_labels, s.rec_values))
    assert dev_bytes > budget


def test_insert_rejected_on_disk_backend(corpus, mem_index, slab_dir):
    dsk = _disk_twin(mem_index, slab_dir)
    with pytest.raises(NotImplementedError, match="disk backend"):
        dsk.engine.insert(np.zeros((1, DIM), np.float32),
                          np.array([0, 1]), np.array([0]), 8,
                          np.zeros(1, np.float32))


# ---------------------------------------------------------------------------
# Facade: build(store="disk") + checkpoint round-trip
# ---------------------------------------------------------------------------

def test_index_build_save_load_roundtrip_disk(corpus, tmp_path):
    vectors, metadata = corpus
    dsk = Index.build(vectors, metadata, CFG, defaults=DEFAULTS,
                      store="disk", storage_dir=str(tmp_path / "slabs"))
    assert dsk.engine.disk_store is not None
    reqs = _requests(vectors, n=3, policies=("strict_in", "post"))
    want = dsk.search_batch(reqs, with_metadata=False)

    ck = str(tmp_path / "ckpt")
    dsk.save(ck)
    loaded = Index.load(ck)
    assert loaded.engine.disk_store is not None
    _assert_identical(want, loaded.search_batch(reqs, with_metadata=False))
    # metadata round-trips too (resolved off label/range stores)
    r = loaded.search(SearchRequest(query=vectors[0],
                                    filter=(Tag("cat") == 2)))
    for _, _, m in r.matches:
        cats = m["cat"] if isinstance(m["cat"], list) else [m["cat"]]
        assert 2 in cats

    # a flipped byte in the checkpointed slab file is a detected
    # corruption: load must refuse to serve it (single step -> raise)
    slab = glob.glob(os.path.join(ck, "step_*", "slabs",
                                  "records.slab"))[0]
    with open(slab, "r+b") as f:
        f.seek(4096)
        f.write(b"\xff" * 4)
    with pytest.raises(CheckpointCorruptionError):
        Index.load(ck)
    assert glob.glob(os.path.join(ck, "*.quarantined"))


def test_index_build_rejects_unknown_store(corpus):
    vectors, metadata = corpus
    with pytest.raises(ValueError, match="store"):
        Index.build(vectors[:50], metadata[:50], CFG, store="tape")
