"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs pure-jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.pq_scan import pq_scan
from repro.kernels.approx_probe import approx_probe
from repro.kernels.l2_rerank import l2_rerank
from repro.kernels.prune_scan import prune_scan


# ---------------------------------------------------------------------------
# pq_scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [1, 5, 128, 700, 1024])
@pytest.mark.parametrize("m,k", [(8, 256), (16, 256), (32, 16)])
@pytest.mark.parametrize("codes_dtype", [jnp.uint8, jnp.int32])
def test_pq_scan_matches_ref(n, m, k, codes_dtype):
    rng = np.random.default_rng(n * m + k)
    codes = jnp.asarray(rng.integers(0, k, (n, m)), dtype=codes_dtype)
    table = jnp.asarray(rng.normal(0, 1, (m, k)).astype(np.float32))
    got = pq_scan(codes, table, interpret=True, tile_n=256)
    want = ref.pq_scan_ref(codes, table)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-5)


def test_pq_scan_tile_invariance():
    rng = np.random.default_rng(0)
    codes = jnp.asarray(rng.integers(0, 256, (1000, 16)), dtype=jnp.uint8)
    table = jnp.asarray(rng.normal(0, 1, (16, 256)).astype(np.float32))
    a = pq_scan(codes, table, interpret=True, tile_n=128)
    b = pq_scan(codes, table, interpret=True, tile_n=512)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)


# ---------------------------------------------------------------------------
# approx_probe
# ---------------------------------------------------------------------------

def _rand_probe_inputs(rng, n, ql=8):
    blooms = jnp.asarray(rng.integers(0, 2 ** 31, n, dtype=np.int64)
                         .astype(np.uint32))
    buckets = jnp.asarray(rng.integers(0, 256, n).astype(np.uint8))
    or_masks = jnp.asarray(rng.integers(0, 2 ** 16, ql).astype(np.uint32))
    params = jnp.asarray(np.array([
        int(rng.integers(0, 2 ** 16)),   # and_mask
        ql,                               # n_or_masks
        int(rng.integers(0, 128)),        # lo
        int(rng.integers(128, 256)),      # hi
        int(rng.integers(0, 3)),          # label_mode
        int(rng.integers(0, 2)),          # range_on
        int(rng.integers(0, 2)),          # combine
        0], np.int32))
    return blooms, buckets, or_masks, params


@pytest.mark.parametrize("n", [1, 64, 999, 2048])
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_approx_probe_matches_ref(n, seed):
    rng = np.random.default_rng(seed)
    blooms, buckets, or_masks, params = _rand_probe_inputs(rng, n)
    got = approx_probe(blooms, buckets, or_masks, params,
                       interpret=True, tile_n=256)
    want = ref.approx_probe_ref(blooms, buckets, or_masks, params)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_approx_probe_all_mode_combos():
    rng = np.random.default_rng(7)
    n = 333
    blooms = jnp.asarray(rng.integers(0, 2 ** 31, n, dtype=np.int64)
                         .astype(np.uint32))
    buckets = jnp.asarray(rng.integers(0, 256, n).astype(np.uint8))
    or_masks = jnp.asarray(rng.integers(0, 2 ** 12, 8).astype(np.uint32))
    for label_mode in (0, 1, 2):
        for range_on in (0, 1):
            for combine in (0, 1):
                params = jnp.asarray(np.array(
                    [0b1010, 8, 50, 200, label_mode, range_on, combine, 0],
                    np.int32))
                got = approx_probe(blooms, buckets, or_masks, params,
                                   interpret=True, tile_n=128)
                want = ref.approx_probe_ref(blooms, buckets, or_masks, params)
                np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# hop_fused (the filtered-search hot loop's candidate pass)
# ---------------------------------------------------------------------------

def _rand_hop_inputs(rng, b, c, m=8, k=256, f=3, ql=8, nr=4):
    codes = jnp.asarray(rng.integers(0, k, (b, c, m)).astype(np.uint8))
    blooms = jnp.asarray(rng.integers(0, 2 ** 31, (b, c), dtype=np.int64)
                         .astype(np.int32))
    buckets = jnp.asarray(rng.integers(0, 256, (b, c, f)).astype(np.int32))
    in_merged = jnp.asarray(rng.integers(0, 2, (b, c)).astype(bool))
    table = jnp.asarray(rng.normal(0, 1, (b, m, k)).astype(np.float32))
    scalars = jnp.asarray(np.stack([
        rng.integers(0, 2 ** 16, b),      # and_mask
        rng.integers(0, 3, b),            # label_mode
        rng.integers(0, 3, b),            # merged_mode
        rng.integers(0, 2, b)], axis=1).astype(np.int32))   # combine
    or_masks = jnp.asarray(rng.integers(0, 2 ** 12, (b, ql)).astype(np.int32))
    range_field = jnp.asarray(
        np.where(rng.random((b, nr)) < 0.5,
                 rng.integers(0, f, (b, nr)), -1).astype(np.int32))
    lo = rng.integers(0, 128, (b, nr)).astype(np.int32)
    hi = rng.integers(128, 256, (b, nr)).astype(np.int32)
    return (codes, blooms, buckets, in_merged, table, scalars, or_masks,
            range_field, jnp.asarray(lo), jnp.asarray(hi))


@pytest.mark.parametrize("b,c", [(1, 7), (3, 64), (4, 300), (2, 520)])
@pytest.mark.parametrize("seed", [0, 1])
def test_hop_fused_matches_ref(b, c, seed):
    rng = np.random.default_rng(seed * 100 + b * c)
    args = _rand_hop_inputs(rng, b, c)
    key_k, ok_k = ops.hop_fused_interpret(*args)
    key_r, ok_r = ref.hop_fused_ref(*args)
    np.testing.assert_array_equal(np.asarray(ok_k), np.asarray(ok_r))
    np.testing.assert_allclose(np.asarray(key_k), np.asarray(key_r),
                               rtol=1e-5, atol=1e-4)


def test_hop_fused_ref_matches_selectors_and_pq():
    """The decomposed kernel inputs must reproduce the production
    primitives exactly: ok == selectors.is_member_approx on the gathered
    ids, and the distance term == pq.adc_lookup (bitwise)."""
    from repro.core import pq as core_pq
    from repro.core.selectors import (InMemory, is_member_approx,
                                      kernel_filter_params, kernel_view,
                                      merged_membership)
    from repro.data.synth import make_filtered_dataset, make_selectors
    from repro.core import engine as eng

    ds = make_filtered_dataset(n=800, d=16, n_queries=6, n_labels=20, seed=2)
    cfg = eng.IndexConfig(r=8, r_dense=32, l_build=16, pq_m=8, max_labels=8)
    e = eng.FilteredANNEngine.build(ds.vectors, ds.label_offsets,
                                   ds.label_flat, ds.n_labels, ds.values,
                                   cfg)
    rng = np.random.default_rng(0)
    for workload in ("label_or", "label_and", "range", "hybrid"):
        sels = make_selectors(ds, e, workload)
        from repro.core.selectors import stack_filters
        qf = stack_filters([s.plan(cfg.ql, cfg.cap).qfilter for s in sels])
        B = len(sels)
        ids = jnp.asarray(rng.integers(0, 800, (B, 50)).astype(np.int32))
        tables = jax.vmap(
            lambda q: core_pq.distance_table(e.codebook, q))(
                jnp.asarray(ds.queries[:B]))
        bl, bc = kernel_view(e.mem)
        in_merged = jax.vmap(merged_membership)(qf, ids)
        key, ok = ref.hop_fused_ref(e.codes[ids], bl[ids], bc[ids],
                                    in_merged, tables,
                                    *kernel_filter_params(qf))
        want_ok = jax.vmap(is_member_approx, in_axes=(0, 0, None))(
            qf, ids, e.mem)
        np.testing.assert_array_equal(np.asarray(ok), np.asarray(want_ok))
        want_d = np.asarray(
            jax.vmap(core_pq.adc_lookup)(e.codes[ids], tables))
        ok_np = np.asarray(ok)
        key_np = np.asarray(key)
        # valid candidates: key IS the distance, bitwise
        np.testing.assert_array_equal(key_np[ok_np], want_d[ok_np])
        # invalid: distance + penalty, in the same f32 arithmetic
        np.testing.assert_array_equal(
            key_np[~ok_np],
            (want_d.astype(np.float32) + np.float32(1e12))[~ok_np])


# ---------------------------------------------------------------------------
# l2_rerank
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,d", [(1, 8), (17, 64), (300, 128), (256, 48)])
def test_l2_rerank_matches_ref(b, d):
    rng = np.random.default_rng(b * d)
    vecs = jnp.asarray(rng.normal(0, 1, (b, d)).astype(np.float32))
    q = jnp.asarray(rng.normal(0, 1, d).astype(np.float32))
    got = l2_rerank(vecs, q, interpret=True, tile_b=64)
    want = ref.l2_rerank_ref(vecs, q)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# oracles agree with the production (core) implementations
# ---------------------------------------------------------------------------

def test_refs_match_core_pq():
    from repro.core import pq as core_pq
    rng = np.random.default_rng(3)
    codes = jnp.asarray(rng.integers(0, 256, (500, 8)), dtype=jnp.uint8)
    table = jnp.asarray(rng.normal(0, 1, (8, 256)).astype(np.float32))
    np.testing.assert_allclose(
        np.asarray(ref.pq_scan_ref(codes, table)),
        np.asarray(core_pq.adc_lookup(codes, table)), rtol=1e-6)


def test_ops_dispatch_cpu():
    rng = np.random.default_rng(4)
    codes = jnp.asarray(rng.integers(0, 256, (100, 8)), dtype=jnp.uint8)
    table = jnp.asarray(rng.normal(0, 1, (8, 256)).astype(np.float32))
    got = ops.pq_scan(codes, table)            # CPU -> XLA reference path
    want = ops.pq_scan_interpret(codes, table) # Pallas interpret path
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5)


# ---------------------------------------------------------------------------
# or_scatter
# ---------------------------------------------------------------------------

def _or_scatter_numpy(words, slots):
    out = np.asarray(words, np.int32).copy()
    n_bits = out.shape[1] * 32
    for b, row in enumerate(np.asarray(slots)):
        for s in row:
            if 0 <= s < n_bits:
                out[b, s >> 5] |= np.int32(1) << np.int32(s & 31)
    return out


@pytest.mark.parametrize("b,nw,c", [(1, 1, 4), (3, 8, 33), (7, 4, 128),
                                    (2, 32, 300)])
@pytest.mark.parametrize("seed", [0, 1])
def test_or_scatter_matches_ref(b, nw, c, seed):
    rng = np.random.default_rng(seed * 997 + b * nw * c)
    # dense slot range + negatives and overflow sentinels + duplicates,
    # over words with bits already set
    words = jnp.asarray(
        rng.integers(-2 ** 31, 2 ** 31, (b, nw), dtype=np.int64)
        .astype(np.int32))
    slots = jnp.asarray(
        rng.integers(-8, nw * 32 + 8, (b, c)).astype(np.int32))
    want = _or_scatter_numpy(words, slots)
    got_k = ops.or_scatter_interpret(words, slots)
    got_r = ref.or_scatter_ref(words, slots)
    np.testing.assert_array_equal(np.asarray(got_r), want)
    np.testing.assert_array_equal(np.asarray(got_k), want)


def test_or_scatter_idempotent_and_sign_bit():
    words = jnp.zeros((2, 2), jnp.int32)
    # duplicate slots, the sign bit (31), and a word-1 slot; row 1 all
    # out-of-range -> untouched
    slots = jnp.asarray([[31, 31, 0, 32, 0], [-1, 64, 64, 100, -5]],
                        jnp.int32)
    want = np.array([[np.int32(1) << 31 | 1, 1], [0, 0]], np.int32)
    np.testing.assert_array_equal(
        np.asarray(ref.or_scatter_ref(words, slots)), want)
    np.testing.assert_array_equal(
        np.asarray(ops.or_scatter_interpret(words, slots)), want)
    # OR-ing into already-set words is a no-op
    again = ref.or_scatter_ref(jnp.asarray(want), slots)
    np.testing.assert_array_equal(np.asarray(again), want)


# ---------------------------------------------------------------------------
# prune_scan
# ---------------------------------------------------------------------------

def _prune_inputs(rng, b, c, pad_frac=0.3):
    """Sorted candidate→point distances (+inf right pads) + pairwise dists."""
    dp = np.sort(rng.normal(2, 1, (b, c)).astype(np.float32) ** 2, axis=1)
    for i, k in enumerate(rng.integers(0, max(1, int(c * pad_frac)), b)):
        if k:
            dp[i, -k:] = np.inf
    dcc = rng.normal(0, 1, (b, c, c)).astype(np.float32) ** 2
    dcc = (dcc + dcc.transpose(0, 2, 1)) / 2
    for i in range(b):
        np.fill_diagonal(dcc[i], 0.0)
    return jnp.asarray(dp), jnp.asarray(dcc)


@pytest.mark.parametrize("b,c,r", [(1, 16, 4), (8, 48, 12), (5, 96, 32),
                                   (2, 33, 5)])
@pytest.mark.parametrize("alpha", [1.0, 1.2])
def test_prune_scan_matches_ref(b, c, r, alpha):
    rng = np.random.default_rng(b * c + r)
    dp, dcc = _prune_inputs(rng, b, c)
    a2 = alpha * alpha
    got = np.asarray(prune_scan(dp, dcc, a2, r, interpret=True))
    want = np.asarray(ref.prune_scan_ref(dp, dcc, a2, r))
    np.testing.assert_array_equal(got, want)
    assert (got.sum(1) <= r).all()


def test_prune_scan_matches_numpy_robust_prune():
    """Sorted-space scan keep set == the sequential numpy RobustPrune."""
    from repro.core.graph import robust_prune
    rng = np.random.default_rng(0)
    n, d, r, alpha = 80, 16, 8, 1.2
    data = rng.normal(0, 1, (n, d)).astype(np.float32)
    p_vec = rng.normal(0, 1, d).astype(np.float32)
    cand = np.arange(n)
    want = robust_prune(p_vec, cand, data, r, alpha)

    d_p = np.sum((data - p_vec[None]) ** 2, axis=1).astype(np.float32)
    order = np.argsort(d_p, kind="stable")
    dp_s = d_p[order][None]
    diff = data[order][:, None, :] - data[order][None, :, :]
    dcc = np.sum(diff * diff, axis=-1).astype(np.float32)[None]
    keep = np.asarray(ref.prune_scan_ref(
        jnp.asarray(dp_s), jnp.asarray(dcc), alpha * alpha, r))[0]
    got = cand[order][keep]        # keeps happen in ascending-distance order
    np.testing.assert_array_equal(got, want)


def test_prune_scan_respects_cap():
    rng = np.random.default_rng(7)
    dp, dcc = _prune_inputs(rng, 6, 40, pad_frac=0.0)
    # alpha=1, zero pairwise distances -> everything dominated by the first
    keep = np.asarray(ref.prune_scan_ref(
        dp, jnp.zeros_like(dcc), 1.0, 10))
    assert (keep.sum(1) == 1).all()
    # huge alpha -> nothing dominated, cap at r survivors
    keep = np.asarray(ref.prune_scan_ref(dp, dcc, 1e9, 10))
    assert (keep.sum(1) == 10).all()
