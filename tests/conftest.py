"""Session-scoped build cache for the build-heavy suites.

One synthetic corpus and one built engine/Index are shared across every
suite that needs a real Vamana graph (test_engine, test_build, ...), so the
build cost is paid once per pytest session — with the batched device
builder that is seconds, not minutes, and ``scripts/test_fast.sh`` no
longer needs to skip build-heavy suites.
"""
from __future__ import annotations

import pytest

from repro.core import engine as eng
from repro.data.synth import make_filtered_dataset


@pytest.fixture(scope="session")
def shared_ds():
    """The engine-suite corpus (same parameters test_engine always used)."""
    return make_filtered_dataset(n=6000, d=32, n_queries=24, n_labels=60,
                                 seed=0)


@pytest.fixture(scope="session")
def shared_engine(shared_ds):
    ds = shared_ds
    cfg = eng.IndexConfig(r=24, r_dense=240, l_build=48, pq_m=8,
                          max_labels=16, ql=8, cap=2048)
    return eng.FilteredANNEngine.build(ds.vectors, ds.label_offsets,
                                       ds.label_flat, ds.n_labels, ds.values,
                                       cfg)


# (Index.insert tests build their own module-scoped index in test_build.py:
#  inserts mutate the index, so sharing one across suites would leak state.)
