"""Paper Figs. 10/11: cost-model accuracy — estimated vs actual I/O for
speculative in-filtering and post-filtering across pool lengths."""
from __future__ import annotations

import numpy as np

from benchmarks.common import BenchResult, get_engine, run_policy
from repro.data.synth import make_selectors


def run() -> list:
    ds, e, _ = get_engine()
    sels = make_selectors(ds, e, "label_or")
    results = []
    for policy, fig in (("speculative", "fig10_in"), ("post", "fig11_post")):
        for l in (16, 32, 64):
            r = run_policy(ds, e, sels, policy, l=l)
            st = r["stats"]
            mask = [i for i, m in enumerate(st.mechanism)
                    if (m == "in") == (policy == "speculative")]
            if not mask:
                mask = list(range(len(st.mechanism)))
            est = float(np.mean(st.est_io_pages[mask]))
            act = float(np.mean(st.io_pages[mask]))
            results.append(BenchResult(
                name=f"{fig}/L={l}",
                us_per_call=r["cpu_us"],
                derived={"est_io": f"{est:.0f}", "actual_io": f"{act:.0f}",
                         "ratio": f"{est / max(act, 1e-9):.2f}"}))
    return results
