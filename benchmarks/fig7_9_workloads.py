"""Paper Figs. 7–9: the 100M-scale workload suite (scaled to CPU): single
Label, Range, Hybrid — speculative vs BaseFilter vs strict in-filtering.
Includes the paper's key recall claim: speculative in-filtering reaches
higher peak recall than strict in-filtering (bridge nodes reconnect the
valid sub-graph)."""
from __future__ import annotations

from benchmarks.common import (BenchResult, get_engine, modeled_latency_us,
                               modeled_qps, run_policy)
from repro.data.synth import make_selectors


# Regression floor for strict in-filtering at small L on the label workload
# (ROADMAP baseline item): the strict pool is sized by the strict branch of
# cost_model.effective_l and seeded with exactly-valid entry points (the fix
# that took range-workload strict recall off zero). On this zipf-label
# corpus the L=16 point sits at ~0.10; the floor guards the catastrophic
# regression class (pool mis-sizing, dead entry seeds → ≈0 recall).
# tests/test_build.py asserts the same property on the engine-suite corpus,
# where the headroom is larger.
STRICT_SMALL_L = 16
STRICT_SMALL_L_RECALL_FLOOR = 0.08


def run() -> list:
    ds, e, _ = get_engine()
    results = []
    for workload in ("label", "range", "hybrid"):
        sels = make_selectors(ds, e, workload)
        for policy in ("speculative", "basefilter", "strict_in"):
            r = run_policy(ds, e, sels, policy, l=48)
            mech = max(r["mech_counts"], key=r["mech_counts"].get)
            lat = modeled_latency_us(mech, r["hops"], r["io_pages"],
                                     r["cpu_us"])
            results.append(BenchResult(
                name=f"fig7_9/{workload}/{policy}",
                us_per_call=r["cpu_us"],
                derived={"latency_us_model": f"{lat:.0f}",
                         "qps_model": f"{modeled_qps(r['io_pages'], r['cpu_us']):.0f}",
                         "recall": f"{r['recall']:.3f}",
                         "io_pages": f"{r['io_pages']:.0f}"}))
    # strict in-filtering small-L regression point (label workload)
    sels = make_selectors(ds, e, "label")
    r = run_policy(ds, e, sels, "strict_in", l=STRICT_SMALL_L)
    assert r["recall"] >= STRICT_SMALL_L_RECALL_FLOOR, \
        f"strict_in recall {r['recall']:.3f} at L={STRICT_SMALL_L} fell " \
        f"below the {STRICT_SMALL_L_RECALL_FLOOR} regression floor"
    results.append(BenchResult(
        name=f"fig7_9/label/strict_in_L{STRICT_SMALL_L}",
        us_per_call=r["cpu_us"],
        derived={"recall": f"{r['recall']:.3f}",
                 "io_pages": f"{r['io_pages']:.0f}",
                 "floor": f"{STRICT_SMALL_L_RECALL_FLOOR}"}))
    return results
