"""Paper Figs. 7–9: the 100M-scale workload suite (scaled to CPU): single
Label, Range, Hybrid — speculative vs BaseFilter vs strict in-filtering.
Includes the paper's key recall claim: speculative in-filtering reaches
higher peak recall than strict in-filtering (bridge nodes reconnect the
valid sub-graph)."""
from __future__ import annotations

from benchmarks.common import (BenchResult, get_engine, modeled_latency_us,
                               modeled_qps, run_policy)
from repro.data.synth import make_selectors


def run() -> list:
    ds, e, _ = get_engine()
    results = []
    for workload in ("label", "range", "hybrid"):
        sels = make_selectors(ds, e, workload)
        for policy in ("speculative", "basefilter", "strict_in"):
            r = run_policy(ds, e, sels, policy, l=48)
            mech = max(r["mech_counts"], key=r["mech_counts"].get)
            lat = modeled_latency_us(mech, r["hops"], r["io_pages"],
                                     r["cpu_us"])
            results.append(BenchResult(
                name=f"fig7_9/{workload}/{policy}",
                us_per_call=r["cpu_us"],
                derived={"latency_us_model": f"{lat:.0f}",
                         "qps_model": f"{modeled_qps(r['io_pages'], r['cpu_us']):.0f}",
                         "recall": f"{r['recall']:.3f}",
                         "io_pages": f"{r['io_pages']:.0f}"}))
    return results
