"""Paper Figs. 5/6: throughput + latency on label workloads —
PIPEANN-FILTER (speculative) vs PipeANN-BaseFilter (pre<1%/post router) vs
strict baselines. label_or ≈ YT5M, label_and ≈ YFCC10M."""
from __future__ import annotations

from benchmarks.common import (BenchResult, get_engine, modeled_latency_us,
                               modeled_qps, run_policy)
from repro.data.synth import make_selectors


def run() -> list:
    ds, e, _ = get_engine()
    results = []
    for workload in ("label_or", "label_and"):
        sels = make_selectors(ds, e, workload)
        for policy in ("speculative", "basefilter", "post", "strict_in"):
            r = run_policy(ds, e, sels, policy, l=48)
            mech = max(r["mech_counts"], key=r["mech_counts"].get)
            lat = modeled_latency_us(mech, r["hops"], r["io_pages"],
                                     r["cpu_us"])
            qps = modeled_qps(r["io_pages"], r["cpu_us"])
            results.append(BenchResult(
                name=f"fig5_6/{workload}/{policy}",
                us_per_call=r["cpu_us"],
                derived={"latency_us_model": f"{lat:.0f}",
                         "qps_model": f"{qps:.0f}",
                         "recall": f"{r['recall']:.3f}",
                         "io_pages": f"{r['io_pages']:.0f}",
                         "routes": str(r["mech_counts"]).replace(",", "/")}))
    return results
