"""Build-throughput benchmark: batched device pipeline vs numpy reference.

Builds the benchmark corpus (the same 12 K-point clustered dataset the
workload suites use) with both Vamana builders at equal parameters and
writes ``BENCH_build.json`` — build seconds, nodes/sec, recall@10 — so the
build-perf trajectory is tracked across PRs. The batched builder is timed
twice: cold (including JIT compilation, what a one-off build pays) and warm
(steady-state, what any repeated/larger build amortizes to). The
acceptance bar is ≥5× over the reference with recall@10 within 1%.

``--smoke`` (also ``run(smoke=True)``) builds a tiny corpus end-to-end
with no perf bars and no JSON output — a bitrot check cheap enough for
the tier-1-adjacent ``scripts/test_fast.sh`` lane.

``--shards`` adds a ``sharded`` block: each shard count in {1, 2, 4}
runs ``distributed.build_vamana_sharded`` (PQ-approximate navigation, the
exact RobustPrune re-rank) in its own subprocess under a 4-fake-device
mesh, reporting the honest wall clock (serialized fake devices — slower),
the per-stage split (sharded navigate+prune vs replicated scatter/drain),
recall@10 vs the batched baseline (±1% gate), and an Amdahl model of a
real mesh: ``T(S) = t_scatter + t_nav_prune / S`` from the 1-shard stage
timers. The PQ-navigation compute cut (sharded-1 wall vs the batched
builder) is reported separately so the two effects don't get conflated.
Methodology: docs/distributed.md.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import tempfile
import time

from benchmarks.common import BenchResult
from repro.core import graph
from repro.data.synth import make_filtered_dataset

N, D = 12_000, 48
N_SMOKE = 600
R, ELL, ALPHA = 24, 48, 1.2
N_QUERIES = 32
OUT_PATH = "BENCH_build.json"


def run(out_path: str = OUT_PATH, smoke: bool = False) -> list:
    n = N_SMOKE if smoke else N
    ds = make_filtered_dataset(n=n, d=D, n_queries=N_QUERIES, seed=0)
    data, queries = ds.vectors, ds.queries

    if smoke:
        adj_b, med_b = graph.build_vamana_batched(data, R, ELL, ALPHA,
                                                  seed=0)
        adj_r, med_r = graph.build_vamana(data, R, ELL, ALPHA, seed=0)
        rec_b = graph.greedy_recall_at_k(data, adj_b, med_b, queries, ell=64)
        rec_r = graph.greedy_recall_at_k(data, adj_r, med_r, queries, ell=64)
        # end-to-end sanity only — no timing bars on a shared CI box
        assert adj_b.shape == adj_r.shape == (n, R)
        assert rec_b >= 0.5 and rec_r >= 0.5, (rec_b, rec_r)
        return [BenchResult(name="build/smoke", us_per_call=0.0,
                            derived={"n": n, "recall_batched": f"{rec_b:.3f}",
                                     "recall_reference": f"{rec_r:.3f}"})]

    t0 = time.time()
    adj_b, med_b = graph.build_vamana_batched(data, R, ELL, ALPHA, seed=0)
    cold_s = time.time() - t0
    # best-of-3 warm: the CI box is a small shared container with very
    # noisy CPU timings; min over repeats is the steady-state number
    warm_times = []
    for _ in range(3):
        t0 = time.time()
        adj_b, med_b = graph.build_vamana_batched(data, R, ELL, ALPHA,
                                                  seed=0)
        warm_times.append(time.time() - t0)
    warm_s = min(warm_times)

    t0 = time.time()
    adj_r, med_r = graph.build_vamana(data, R, ELL, ALPHA, seed=0)
    ref_s = time.time() - t0

    rec_b = graph.greedy_recall_at_k(data, adj_b, med_b, queries, ell=64)
    rec_r = graph.greedy_recall_at_k(data, adj_r, med_r, queries, ell=64)

    payload = {
        "corpus": {"n": N, "d": D, "r": R, "l_build": ELL, "alpha": ALPHA},
        "batched": {"seconds": warm_s, "seconds_cold": cold_s,
                    "nodes_per_sec": N / warm_s, "recall_at_10": rec_b,
                    "stats": graph.graph_stats(adj_b)},
        "reference": {"seconds": ref_s, "nodes_per_sec": N / ref_s,
                      "recall_at_10": rec_r,
                      "stats": graph.graph_stats(adj_r)},
        "speedup_warm": ref_s / warm_s,
        "speedup_cold": ref_s / cold_s,
        "recall_gap": rec_r - rec_b,
    }
    with open(out_path, "w") as fh:
        json.dump(payload, fh, indent=2)

    assert payload["speedup_warm"] >= 5.0, \
        f"batched builder only {payload['speedup_warm']:.1f}x vs reference"
    # one-sided: the batched graph may be better, just not >1% worse
    assert payload["recall_gap"] <= 0.01, \
        f"batched recall trails reference by {payload['recall_gap']:.3f}"

    return [
        BenchResult(name="build/batched", us_per_call=warm_s * 1e6,
                    derived={"nodes_per_sec": f"{N / warm_s:.0f}",
                             "cold_s": f"{cold_s:.1f}",
                             "recall@10": f"{rec_b:.3f}"}),
        BenchResult(name="build/reference", us_per_call=ref_s * 1e6,
                    derived={"nodes_per_sec": f"{N / ref_s:.0f}",
                             "recall@10": f"{rec_r:.3f}"}),
        BenchResult(name="build/speedup", us_per_call=0.0,
                    derived={"warm": f"{payload['speedup_warm']:.1f}x",
                             "cold": f"{payload['speedup_cold']:.1f}x"}),
    ]


# ---------------------------------------------------------------------------
# Sharded build (--shards): subprocess per shard count, 4 fake devices
# ---------------------------------------------------------------------------
SHARD_COUNTS = (1, 2, 4)
SHARD_DEVICES = 4
SCALING_MODEL = "amdahl_stage_decomposition"
RECALL_GAP_MAX = 0.01


def _shard_worker(shards: int, smoke: bool, out_path: str) -> None:
    """One shard count in a subprocess: PQ-nav sharded build (cold +
    warm-with-stage-timers) and, at shards=1 only, the batched baseline
    for the recall gate and the PQ-nav compute-cut column."""
    import jax
    import jax.numpy as jnp
    from repro.core import pq as pq_mod
    from repro.core.distributed import ShardPlan, build_vamana_sharded
    from repro.launch.mesh import make_local_mesh

    n = N_SMOKE if smoke else N
    ds = make_filtered_dataset(n=n, d=D, n_queries=N_QUERIES, seed=0)
    data, queries = ds.vectors, ds.queries

    t0 = time.time()
    cb = pq_mod.train_pq(jax.random.PRNGKey(0), jnp.asarray(data), 8,
                         iters=8)
    codes = pq_mod.encode_pq(cb, jnp.asarray(data))
    jax.block_until_ready(codes)
    pq_s = time.time() - t0

    plan = ShardPlan(mesh=make_local_mesh(1, shards),
                     shard_axes=("model",))
    t0 = time.time()
    build_vamana_sharded(data, plan, R, ELL, ALPHA, seed=0, codes=codes,
                         codebook=cb)
    cold_s = time.time() - t0
    stages: dict = {}
    t0 = time.time()
    adj, med = build_vamana_sharded(data, plan, R, ELL, ALPHA, seed=0,
                                    codes=codes, codebook=cb,
                                    stage_times=stages)
    warm_s = time.time() - t0
    rec = graph.greedy_recall_at_k(data, adj, med, queries, ell=64)

    block = {"shards": shards, "pq_train_s": pq_s,
             "wall_s": warm_s, "wall_s_cold": cold_s,
             "stage_times": stages, "recall_at_10": rec}
    if shards == 1:
        t0 = time.time()
        graph.build_vamana_batched(data, R, ELL, ALPHA, seed=0)
        cold_b = time.time() - t0
        t0 = time.time()
        adj_b, med_b = graph.build_vamana_batched(data, R, ELL, ALPHA,
                                                  seed=0)
        block["batched_warm_s"] = time.time() - t0
        block["batched_cold_s"] = cold_b
        block["batched_recall_at_10"] = graph.greedy_recall_at_k(
            data, adj_b, med_b, queries, ell=64)
    with open(out_path, "w") as fh:
        json.dump(block, fh)


def run_sharded(out_path: str = OUT_PATH, smoke: bool = False) -> list:
    """Orchestrate the shard-count subprocesses and merge a ``sharded``
    block into ``out_path`` (leaving the plain-bench payload in place)."""
    blocks = {}
    for s in SHARD_COUNTS:
        with tempfile.NamedTemporaryFile(suffix=".json", delete=False) as f:
            tmp = f.name
        env = dict(os.environ)
        env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                            + " --xla_force_host_platform_device_count="
                            + str(SHARD_DEVICES)).strip()
        cmd = [sys.executable, "-m", "benchmarks.bench_build",
               "--shard-worker", str(s), "--worker-out", tmp]
        if smoke:
            cmd.append("--smoke")
        out = subprocess.run(cmd, env=env, capture_output=True, text=True)
        assert out.returncode == 0, \
            f"shard worker {s} failed:\n{out.stdout}\n{out.stderr}"
        with open(tmp) as fh:
            blocks[s] = json.load(fh)
        os.unlink(tmp)

    b1 = blocks[1]
    nav = b1["stage_times"]["nav_prune_s"]
    rest = b1["stage_times"]["scatter_s"]
    n = N_SMOKE if smoke else N
    shards_out = {}
    for s in SHARD_COUNTS:
        modeled = rest + nav / s
        shards_out[str(s)] = dict(
            blocks[s],
            modeled_s=modeled,
            nodes_per_sec_modeled=n / modeled,
            build_scaling_modeled=(rest + nav) / modeled,
            speedup_vs_batched_modeled=(b1.get("batched_warm_s", 0.0)
                                        / modeled)
            if "batched_warm_s" in b1 else None,
        )
    sharded = {
        "devices": SHARD_DEVICES,
        "scaling_model": SCALING_MODEL,
        "note": "fake single-core devices execute shard_map serially: "
                "wall_s is the honest (slower) measured time; modeled_s = "
                "t_scatter + t_nav_prune/S from the 1-shard stage timers "
                "(navigation+prune shard over the mesh, the reverse-edge "
                "scatter/overflow drain stays replicated). The PQ-nav "
                "compute cut (batched_warm_s vs shards=1 wall_s) is a "
                "separate, fully measured effect (docs/distributed.md)",
        "recall_gap_max": RECALL_GAP_MAX,
        "shards": shards_out,
    }

    results = []
    for s in SHARD_COUNTS:
        bk = shards_out[str(s)]
        results.append(BenchResult(
            name=f"build/shards{s}", us_per_call=bk["wall_s"] * 1e6,
            derived={"modeled_s": f"{bk['modeled_s']:.1f}",
                     "scaling": f"{bk['build_scaling_modeled']:.2f}x",
                     "recall@10": f"{bk['recall_at_10']:.3f}"}))

    if not smoke:
        rb = b1["batched_recall_at_10"]
        for s in SHARD_COUNTS:
            gap = rb - blocks[s]["recall_at_10"]
            assert gap <= RECALL_GAP_MAX, \
                f"shards={s}: PQ-nav build recall trails batched by " \
                f"{gap:.3f} (> {RECALL_GAP_MAX})"
        try:
            with open(out_path) as fh:
                payload = json.load(fh)
        except FileNotFoundError:
            payload = {}
        payload["sharded"] = sharded
        with open(out_path, "w") as fh:
            json.dump(payload, fh, indent=2)
    return results


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny end-to-end run, no perf bars / JSON output")
    ap.add_argument("--shards", action="store_true",
                    help="run the sharded-build scaling block (subprocess "
                         "per shard count in {1,2,4} under a 4-fake-device "
                         "mesh) and merge it into the JSON")
    ap.add_argument("--shard-worker", type=int, default=0,
                    help=argparse.SUPPRESS)
    ap.add_argument("--worker-out", default="", help=argparse.SUPPRESS)
    ap.add_argument("--out", default=OUT_PATH)
    args = ap.parse_args()
    if args.shard_worker:
        _shard_worker(args.shard_worker, args.smoke, args.worker_out)
        return
    if args.shards:
        for res in run_sharded(out_path=args.out, smoke=args.smoke):
            print(res.csv())
        return
    for res in run(out_path=args.out, smoke=args.smoke):
        print(res.csv())


if __name__ == "__main__":
    main()
