"""Build-throughput benchmark: batched device pipeline vs numpy reference.

Builds the benchmark corpus (the same 12 K-point clustered dataset the
workload suites use) with both Vamana builders at equal parameters and
writes ``BENCH_build.json`` — build seconds, nodes/sec, recall@10 — so the
build-perf trajectory is tracked across PRs. The batched builder is timed
twice: cold (including JIT compilation, what a one-off build pays) and warm
(steady-state, what any repeated/larger build amortizes to). The
acceptance bar is ≥5× over the reference with recall@10 within 1%.

``--smoke`` (also ``run(smoke=True)``) builds a tiny corpus end-to-end
with no perf bars and no JSON output — a bitrot check cheap enough for
the tier-1-adjacent ``scripts/test_fast.sh`` lane.
"""
from __future__ import annotations

import json
import time

from benchmarks.common import BenchResult
from repro.core import graph
from repro.data.synth import make_filtered_dataset

N, D = 12_000, 48
N_SMOKE = 600
R, ELL, ALPHA = 24, 48, 1.2
N_QUERIES = 32
OUT_PATH = "BENCH_build.json"


def run(out_path: str = OUT_PATH, smoke: bool = False) -> list:
    n = N_SMOKE if smoke else N
    ds = make_filtered_dataset(n=n, d=D, n_queries=N_QUERIES, seed=0)
    data, queries = ds.vectors, ds.queries

    if smoke:
        adj_b, med_b = graph.build_vamana_batched(data, R, ELL, ALPHA,
                                                  seed=0)
        adj_r, med_r = graph.build_vamana(data, R, ELL, ALPHA, seed=0)
        rec_b = graph.greedy_recall_at_k(data, adj_b, med_b, queries, ell=64)
        rec_r = graph.greedy_recall_at_k(data, adj_r, med_r, queries, ell=64)
        # end-to-end sanity only — no timing bars on a shared CI box
        assert adj_b.shape == adj_r.shape == (n, R)
        assert rec_b >= 0.5 and rec_r >= 0.5, (rec_b, rec_r)
        return [BenchResult(name="build/smoke", us_per_call=0.0,
                            derived={"n": n, "recall_batched": f"{rec_b:.3f}",
                                     "recall_reference": f"{rec_r:.3f}"})]

    t0 = time.time()
    adj_b, med_b = graph.build_vamana_batched(data, R, ELL, ALPHA, seed=0)
    cold_s = time.time() - t0
    # best-of-3 warm: the CI box is a small shared container with very
    # noisy CPU timings; min over repeats is the steady-state number
    warm_times = []
    for _ in range(3):
        t0 = time.time()
        adj_b, med_b = graph.build_vamana_batched(data, R, ELL, ALPHA,
                                                  seed=0)
        warm_times.append(time.time() - t0)
    warm_s = min(warm_times)

    t0 = time.time()
    adj_r, med_r = graph.build_vamana(data, R, ELL, ALPHA, seed=0)
    ref_s = time.time() - t0

    rec_b = graph.greedy_recall_at_k(data, adj_b, med_b, queries, ell=64)
    rec_r = graph.greedy_recall_at_k(data, adj_r, med_r, queries, ell=64)

    payload = {
        "corpus": {"n": N, "d": D, "r": R, "l_build": ELL, "alpha": ALPHA},
        "batched": {"seconds": warm_s, "seconds_cold": cold_s,
                    "nodes_per_sec": N / warm_s, "recall_at_10": rec_b,
                    "stats": graph.graph_stats(adj_b)},
        "reference": {"seconds": ref_s, "nodes_per_sec": N / ref_s,
                      "recall_at_10": rec_r,
                      "stats": graph.graph_stats(adj_r)},
        "speedup_warm": ref_s / warm_s,
        "speedup_cold": ref_s / cold_s,
        "recall_gap": rec_r - rec_b,
    }
    with open(out_path, "w") as fh:
        json.dump(payload, fh, indent=2)

    assert payload["speedup_warm"] >= 5.0, \
        f"batched builder only {payload['speedup_warm']:.1f}x vs reference"
    # one-sided: the batched graph may be better, just not >1% worse
    assert payload["recall_gap"] <= 0.01, \
        f"batched recall trails reference by {payload['recall_gap']:.3f}"

    return [
        BenchResult(name="build/batched", us_per_call=warm_s * 1e6,
                    derived={"nodes_per_sec": f"{N / warm_s:.0f}",
                             "cold_s": f"{cold_s:.1f}",
                             "recall@10": f"{rec_b:.3f}"}),
        BenchResult(name="build/reference", us_per_call=ref_s * 1e6,
                    derived={"nodes_per_sec": f"{N / ref_s:.0f}",
                             "recall@10": f"{rec_r:.3f}"}),
        BenchResult(name="build/speedup", us_per_call=0.0,
                    derived={"warm": f"{payload['speedup_warm']:.1f}x",
                             "cold": f"{payload['speedup_cold']:.1f}x"}),
    ]


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny end-to-end run, no perf bars / JSON output")
    ap.add_argument("--out", default=OUT_PATH)
    args = ap.parse_args()
    for res in run(out_path=args.out, smoke=args.smoke):
        print(res.csv())


if __name__ == "__main__":
    main()
