"""Shared benchmark fixtures: dataset + index build (cached), SSD model.

The container is CPU-only, so the paper's latency/throughput numbers are
reproduced through (a) exact algorithmic counters (pages, hops, distance
comps — hardware-independent) and (b) a parameterized SSD model applied to
them (Samsung PM9A3-class: ~100 µs 4 KB random read incl. queueing,
~800 K IOPS, 56 worker threads like the paper's testbed). Measured CPU time
per query bounds the compute side.

Benchmarks drive the engine through the ``repro.api`` request path
(``Index.search_batch`` with per-request policy/L overrides); the returned
``Index`` duck-types the old engine handle (label_store/range_store/store/
config pass through), so workload generators keep working unchanged.
"""
from __future__ import annotations

import dataclasses
import functools
import time

import numpy as np

from repro.api import Index, SearchRequest
from repro.core import engine as eng
from repro.data.synth import make_filtered_dataset, make_selectors

# SSD + host model (paper §5.1 testbed analogues)
T_PAGE_US = 100.0          # one dependent 4 KB random read
SSD_IOPS = 800_000.0       # parallel random-read throughput
N_THREADS = 56             # search threads saturating the SSD


@dataclasses.dataclass
class BenchResult:
    name: str
    us_per_call: float
    derived: dict

    def csv(self) -> str:
        d = ";".join(f"{k}={v}" for k, v in self.derived.items())
        return f"{self.name},{self.us_per_call:.1f},{d}"


@functools.lru_cache(maxsize=2)
def get_engine(n: int = 12000, seed: int = 0):
    """Build the benchmark index (cached). Returns (ds, Index, build_s)."""
    ds = make_filtered_dataset(n=n, d=48, n_queries=32, n_labels=120,
                               avg_labels=4.0, seed=seed)
    cfg = eng.IndexConfig(r=24, r_dense=360, l_build=48, pq_m=8,
                          max_labels=16, ql=8, cap=4096)
    t0 = time.time()
    index = Index.build(ds.vectors, ds.metadata(), cfg,
                        defaults=eng.SearchConfig(max_pool=1024))
    build_s = time.time() - t0
    return ds, index, build_s


def modeled_latency_us(mechanism: str, hops: float, io_pages: float,
                       cpu_us: float) -> float:
    """Paper-shaped latency: graph hops serialize (dependent reads);
    pre-filter scans and re-rank fetches are parallel reads."""
    if mechanism in ("in", "post"):
        serial = hops
        parallel = max(0.0, io_pages - hops)
    else:
        serial = 1.0
        parallel = io_pages
    io_us = serial * T_PAGE_US + (parallel / (SSD_IOPS / 1e6)) / 64.0
    return io_us + cpu_us


def modeled_qps(io_pages_per_query: float, cpu_us_per_query: float) -> float:
    """Throughput = min(SSD-bound, CPU-bound with N_THREADS workers)."""
    qps_io = SSD_IOPS / max(io_pages_per_query, 1e-9)
    qps_cpu = N_THREADS * 1e6 / max(cpu_us_per_query, 1e-9)
    return min(qps_io, qps_cpu)


def run_policy(ds, index: Index, selectors, policy: str, l: int, k: int = 10,
               max_hops: int = 400):
    """Execute one policy through the api request path; returns aggregates."""
    requests = [SearchRequest(query=ds.queries[i], filter=sel, k=k, l=l,
                              policy=policy, max_hops=max_hops)
                for i, sel in enumerate(selectors)]
    # warm up compile; skip host-side metadata resolution in the timed
    # region so cpu_us measures only the engine path
    index.search_batch(requests[:2], with_metadata=False)
    t0 = time.time()
    results, stats = index.search_batch(requests, with_stats=True,
                                        with_metadata=False)
    wall = time.time() - t0
    recalls = []
    for req, res in zip(requests, results):
        gt = index.ground_truth(req)
        recalls.append(eng.recall_at_k(res.ids, gt, k))
    nq = len(selectors)
    return {
        "recall": float(np.mean(recalls)),
        "io_pages": float(stats.io_pages.mean()),
        "hops": float(stats.hops.mean()),
        "cpu_us": wall / nq * 1e6,
        "mech_counts": {m: stats.mechanism.count(m)
                        for m in set(stats.mechanism)},
        "stats": stats,
        "results": results,
    }
