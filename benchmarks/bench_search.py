"""Search-latency benchmark: pipelined vs fused vs the pre-fused baseline.

Runs every mode (post / spec_in / strict_in) over the 12 K benchmark
corpus at L=64 and times three implementations of the batched search:

  * ``filtered_search_pipelined`` — the production path (PR 5): chunked
    hop runner + straggler compaction over the double-buffered loop;
  * ``filtered_search``          — the single-shot fused jit (PR 4's
    structure, now also carrying the cross-hop prefetch and the
    precomputed per-record dedup mask);
  * ``filtered_search_legacy``   — the pre-fused-pipeline implementation
    (pairwise dedup broadcasts, full argsort merges).

Writes ``BENCH_search.json`` so the search-side perf trajectory is
tracked across PRs (BENCH_build.json covers the build side). The per-mode
stats now include ``mean_approx_checks`` — together with ``dist_comps``
and ``hops`` it feeds ``cost_model.Calibration`` (measured per-hop
compute for the router).

Acceptance bars (all implementation changes, never algorithm changes —
the three paths return bit-identical results, asserted here):
  * pipelined spec_in W=1 ≥ ``PIPELINE_SPEEDUP_FLOOR`` (1.5×) faster than
    the committed PR-4 fused numbers (``PR4_FUSED_MS``, same container);
  * pipelined post / strict_in no slower than PR 4 (small jitter
    allowance);
  * warm fused spec_in_beam4 ≥ 3× the legacy path (the PR-4 floor).

``--smoke`` builds a tiny corpus and runs every mode end-to-end with no
perf bars and no JSON — the bitrot check ``scripts/test_fast.sh`` runs.
``--active-trace`` additionally records per-hop active-query counts, the
driver's compaction buckets, and the modeled SSD latency with/without
prefetch (``io_sim.IOModel.latency_us``) for the spec_in W=1 config.

``--fault-plan`` (default: the committed 10% page-fault operating point,
``rate=0.1,seed=7``; pass ``none`` to skip) re-times the pipelined path
under seeded fault injection (core/faults.py) and reports degraded-mode
QPS/recall alongside the clean numbers in a ``fault_plan`` block, with
two committed floors: recall@10 within ``FAULT_RECALL_DROP_MAX`` of the
clean run, and degraded-mode latency within ``FAULT_SLOWDOWN_MAX``× the
clean pipelined time. Runs in ``--smoke`` too — that is the CI fault
smoke ``scripts/test_fast.sh`` wires in. The clean-path floors are
untouched: with no plan the fault layer traces zero extra ops.

``--shards`` adds a ``sharded`` block to the JSON: each shard count in
{1, 2, 4} runs in its own subprocess under a 4-fake-device host mesh
(``--xla_force_host_platform_device_count``), asserts the sharded driver
bit-identical to the single-device pipelined path, and reports BOTH the
honest wall clock (fake devices on one CPU core execute shard_map
serially — wall time goes UP with shard count here) and a labeled
critical-path model: each shard's slice of the query batch re-timed as a
standalone single-device run, max over shards = the wall clock a real
S-device mesh would see. ``qps_scaling = critical_path(1) /
critical_path(S)`` carries the committed floors (≥1.6× at 2, ≥2.5× at 4
for every W=1 mode). Methodology: docs/distributed.md.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import tempfile
import time

import jax.numpy as jnp
import numpy as np

from benchmarks.common import BenchResult, get_engine
from repro.core import engine as eng
from repro.core import search as S
from repro.core.faults import parse_plan
from repro.core.io_sim import IOModel
from repro.core.selectors import stack_filters

N, N_SMOKE = 12_000, 600
L, K, MAX_HOPS = 64, 10, 512
SELECTIVITY = 0.30          # mid-selectivity range filters (paper Fig. 2)
OUT_PATH = "BENCH_search.json"
# (bench name, search mode, beam width). ``spec_in_beam4`` is the
# pipelined-beam configuration — PipeANN keeps W reads in flight per
# step; its TPU-batch analogue is beam_width>1 — and carries the legacy
# speedup floor: the legacy path's dedup broadcast is O(W·C·res_cap)
# while the fused pipeline stays near-linear in the slab, so the gap is
# widest exactly where the paper operates.
CONFIGS = (("post", "post", 1), ("spec_in", "spec_in", 1),
           ("spec_in_beam4", "spec_in", 4), ("strict_in", "strict_in", 1))
SPEC_IN_SPEEDUP_FLOOR = 3.0        # fused vs legacy, on spec_in_beam4
# PR-4 warm fused_ms on this container (committed BENCH_search.json @
# PR 4) — the pipelined path is measured against them:
PR4_FUSED_MS = {"post": 75.80, "spec_in": 501.46, "spec_in_beam4": 627.23,
                "strict_in": 96.83}
PIPELINE_SPEEDUP_FLOOR = 1.5       # pipelined vs PR-4 fused, spec_in W=1
NO_SLOWER_TOL = 1.05               # post/strict_in jitter allowance
RECALL_TOL = 0.01
# degraded-mode floors (the fault_plan block): at the committed 10%
# per-attempt page-fault rate the retry→hedge→degrade ladder must hold
# recall within 5 points of clean, at bounded extra wall time
FAULT_PLAN_DEFAULT = "rate=0.1,seed=7"
FAULT_RECALL_DROP_MAX = 0.05
FAULT_SLOWDOWN_MAX = 2.0
# disk-tier floors (``--store disk``; storage/disk.py). The corpus must
# genuinely live on disk: the declared device-resident record budget is
# far below the slab file size, and the stub store must fit it. Floors
# committed from measured runs on this container:
DISK_DEVICE_BUDGET_BYTES = 1 << 20     # 1 MB device budget for record data
DISK_GATED_SKIP_FLOOR = 0.30   # bloom gate skips ≥30% of attr page reads
DISK_HIT_RATE_FLOOR = 0.10     # page-cache hit rate across the run
                               # (measured: 0.21 spec_in .. 0.49 strict_in)
DISK_QPS_FLOOR = 40.0          # spec_in W=1 QPS through real io_callbacks
                               # (measured: 82 on this container)
DISK_RECALL_GAP_MAX = 0.005    # disk vs device recall (bit-identity => 0)


def _selectors(e, n_queries: int):
    """Sliding mid-selectivity range windows (one filter per query)."""
    from repro.data.synth import make_sliding_range_selectors
    return make_sliding_range_selectors(e, SELECTIVITY, n_queries)


def _mode_inputs(e, ds, mode):
    sels = _selectors(e, ds.queries.shape[0])
    qf = stack_filters([s.plan(e.config.ql, e.config.cap).qfilter
                        for s in sels])
    queries = jnp.asarray(
        np.pad(ds.queries, ((0, 0), (0, e.store.dim - ds.queries.shape[1]))))
    entries = None
    if mode == "strict_in":
        ents = np.full((len(sels), 4), -1, np.int32)
        for j, s in enumerate(sels):
            seeds, _ = eng._strict_seed_ids(s, e.medoid, 4)
            ents[j, :seeds.size] = seeds
        entries = jnp.asarray(ents)
    return sels, qf, queries, entries


def _time_impl(impl, e, qf, queries, params, entries, repeats=3):
    """(cold_s, warm_s, result) — warm is best-of-``repeats``."""
    t0 = time.time()
    res = impl(e.store, e.codes, e.codebook, e.mem, qf, queries, e.medoid,
               params, entries=entries)
    res.ids.block_until_ready()
    cold = time.time() - t0
    warm = []
    for _ in range(repeats):
        t0 = time.time()
        res = impl(e.store, e.codes, e.codebook, e.mem, qf, queries,
                   e.medoid, params, entries=entries)
        res.ids.block_until_ready()
        warm.append(time.time() - t0)
    return cold, min(warm), res


def _recall(ds, e, sels, res, k=K):
    vectors = np.asarray(e.store.vectors)
    rl = np.asarray(e.store.rec_labels)
    rv = np.asarray(e.store.rec_values)
    rec = []
    for i, s in enumerate(sels):
        plan = s.plan(e.config.ql, e.config.cap)
        q = np.pad(ds.queries[i], (0, vectors.shape[1] - ds.queries.shape[1]))
        gt = eng.brute_force_filtered(vectors, rl, rv, plan.qfilter, q, k)
        rec.append(eng.recall_at_k(np.asarray(res.ids[i]), gt, k))
    return float(np.mean(rec))


def _assert_bit_identical(a: S.SearchResult, b: S.SearchResult, tag: str):
    for field in S.SearchResult._fields:
        av, bv = np.asarray(getattr(a, field)), np.asarray(getattr(b, field))
        assert np.array_equal(av, bv), f"{tag}: {field} diverged"


def active_trace(e, ds, smoke: bool, warm_us_per_query: float) -> dict:
    """Per-hop active-query counts + compaction buckets + modeled SSD
    latency for the spec_in W=1 config (the straggler-bound case the
    compaction attacks)."""
    params = S.SearchParams(l_search=L, k=K, beam_width=1,
                            max_hops=MAX_HOPS, mode="spec_in")
    _, qf, queries, entries = _mode_inputs(e, ds, "spec_in")
    res, chunks = S.filtered_search_pipelined(
        e.store, e.codes, e.codebook, e.mem, qf, queries, e.medoid, params,
        entries=entries, collect_trace=True)
    hops = np.asarray(res.hops)
    # a query is active at hop t iff its final hop count exceeds t — the
    # per-hop active width is exact from the counters, no loop probes
    per_hop_active = [int((hops > t).sum()) for t in range(int(hops.max()))]
    io = IOModel()
    mean_hops = float(hops.mean())
    pages_hop = e.store.pages_dense
    compute_us = warm_us_per_query
    modeled = {
        "t_page_us": io.t_page_us,
        "mean_dependent_pages": mean_hops * pages_hop,
        "compute_us_per_query": compute_us,
        # serial issue order (prefetch_depth=1): read + compute add up
        "latency_us_prefetch1": io.latency_us(
            int(round(mean_hops * pages_hop)), 0, prefetch_depth=1,
            compute_us=compute_us),
        # double-buffered loop: compute hides behind the in-flight read
        "latency_us_prefetch2": io.latency_us(
            int(round(mean_hops * pages_hop)), 0, prefetch_depth=2,
            compute_us=compute_us),
    }
    trace = {"mode": "spec_in", "beam_width": 1,
             "hop_chunk": S.DEFAULT_HOP_CHUNK,
             "min_bucket": S.MIN_COMPACT_BUCKET,
             "per_hop_active": per_hop_active,
             "chunks": chunks, "modeled": modeled}
    if not smoke:
        # the whole point of compaction: the batch thins out long before
        # the last straggler settles
        assert per_hop_active[-1] < per_hop_active[0], "no straggler tail?"
    return trace


def _fault_block(e, ds, plan, clean_modes: dict, smoke: bool,
                 results: list) -> dict:
    """Re-time the pipelined path under ``plan`` for every config and
    check the degraded-mode floors against the clean numbers."""
    B = ds.queries.shape[0]
    io = IOModel()
    block = {"plan": plan.to_json(),
             "floors": {"recall_drop_max": FAULT_RECALL_DROP_MAX,
                        "slowdown_max": FAULT_SLOWDOWN_MAX},
             "modes": {}}
    for name, mode, w in CONFIGS:
        params = S.SearchParams(l_search=L, k=K, beam_width=w,
                                max_hops=MAX_HOPS, mode=mode,
                                fault_plan=plan)
        sels, qf, queries, entries = _mode_inputs(e, ds, mode)
        reps = 2 if smoke else 3
        cold, warm, res = _time_impl(S.filtered_search_pipelined, e, qf,
                                     queries, params, entries, repeats=reps)
        rec = _recall(ds, e, sels, res)
        clean = clean_modes[name]
        drop = clean["recall_at_10"] - rec
        faults = float(np.mean(np.asarray(res.faults)))
        retries = float(np.mean(np.asarray(res.retries)))
        degraded = float(np.mean(np.asarray(res.degraded)))
        mean_hops = float(np.mean(np.asarray(res.hops)))
        pages = e.store.pages_dense if mode == "spec_in" \
            else e.store.pages_std
        stats = {
            "faulted_ms": warm * 1e3, "faulted_ms_cold": cold * 1e3,
            "qps_degraded": B / warm,
            "recall_at_10_faulted": rec, "recall_drop": drop,
            "mean_faults": faults, "mean_retries": retries,
            "mean_degraded": degraded,
            "slowdown_vs_clean": warm * 1e3 / clean["pipelined_ms"],
            # modeled per-query SSD latency incl. retry backoff + spikes
            "modeled_latency_us": io.faulted_latency_us(
                int(round(mean_hops * pages)), plan,
                faults=int(round(faults)), retries=int(round(retries)),
                prefetch_depth=2),
        }
        block["modes"][name] = stats
        results.append(BenchResult(
            name=f"search/{name}@fault", us_per_call=warm * 1e6 / B,
            derived={"qps": f"{stats['qps_degraded']:.0f}",
                     "recall@10": f"{rec:.3f}",
                     "drop": f"{drop:.3f}",
                     "faults": f"{faults:.0f}",
                     "retries": f"{retries:.0f}"}))
        # the plan must actually engage, and the ladder must hold recall —
        # asserted in smoke too (this is the CI fault smoke)
        assert np.asarray(res.faults).sum() > 0, f"{name}: plan never fired"
        assert drop <= FAULT_RECALL_DROP_MAX, \
            f"{name}: faulted recall dropped {drop:.3f} " \
            f"(> {FAULT_RECALL_DROP_MAX})"
        if not smoke:
            assert stats["slowdown_vs_clean"] <= FAULT_SLOWDOWN_MAX, \
                f"{name}: degraded-mode {stats['faulted_ms']:.0f}ms " \
                f"exceeds {FAULT_SLOWDOWN_MAX}x clean " \
                f"({clean['pipelined_ms']:.0f}ms)"
    return block


def _disk_tier_block(e, ds, smoke: bool, results: list) -> dict:
    """Re-run every config against the disk backend (real slab files,
    page cache, bloom-gated attribute reads) and compare to the device
    path: results must stay bit-identical while the block reports the
    *measured* I/O — cache hit rate, per-page latency percentiles, the
    bloom gate's saved page fraction, and the fitted IOModel."""
    import tempfile

    from repro.storage import DiskRecordStore, StorageConfig

    path = tempfile.mkdtemp(prefix="bench_slabs_")
    dsd = DiskRecordStore.from_record_store(
        path, e.store, n=e.n,
        config=StorageConfig(device_budget_bytes=DISK_DEVICE_BUDGET_BYTES))
    stub = dsd.stub_store()
    # the whole point of the tier: the corpus does NOT fit the device
    # budget, but the stub (all that stays device-resident) does
    assert dsd.stub_bytes() <= DISK_DEVICE_BUDGET_BYTES < dsd.file_bytes
    B = ds.queries.shape[0]
    block = {"slab_path_bytes": dsd.file_bytes,
             "stub_bytes": dsd.stub_bytes(),
             "device_budget_bytes": DISK_DEVICE_BUDGET_BYTES,
             "cache_pages": dsd.config.cache_pages,
             "floors": {"gated_skip_frac_min": DISK_GATED_SKIP_FLOOR,
                        "hit_rate_min": DISK_HIT_RATE_FLOOR,
                        "qps_spec_in_min": DISK_QPS_FLOOR,
                        "recall_gap_max": DISK_RECALL_GAP_MAX},
             "modes": {}}
    reps = 2 if smoke else 3

    def run_disk(params, qf, queries, entries):
        return S.filtered_search_pipelined(
            stub, e.codes, e.codebook, e.mem, qf, queries, e.medoid,
            params, entries=entries, fetch_fn=dsd.fetch_callable)

    for name, mode, w in CONFIGS:
        params = S.SearchParams(l_search=L, k=K, beam_width=w,
                                max_hops=MAX_HOPS, mode=mode)
        sels, qf, queries, entries = _mode_inputs(e, ds, mode)
        res_dev = S.filtered_search_pipelined(
            e.store, e.codes, e.codebook, e.mem, qf, queries, e.medoid,
            params, entries=entries)
        before = dsd.snapshot()
        t0 = time.time()
        res_disk = run_disk(params, qf, queries, entries)
        res_disk.ids.block_until_ready()
        cold = time.time() - t0
        warm = []
        for _ in range(reps):
            t0 = time.time()
            res_disk = run_disk(params, qf, queries, entries)
            res_disk.ids.block_until_ready()
            warm.append(time.time() - t0)
        delta = DiskRecordStore.delta(before, dsd.snapshot())
        # the disk tier is an I/O path, not a result path
        _assert_bit_identical(res_dev, res_disk, f"disk/{name}")
        rec = _recall(ds, e, sels, res_disk)
        rec_dev = _recall(ds, e, sels, res_dev)
        probes = delta["attr_probes"] if mode == "strict_in" else 0
        gated_frac = (delta["gated_skips"] / probes) if probes else None
        stats = {
            "mode": mode, "beam_width": w,
            "disk_ms": min(warm) * 1e3, "disk_ms_cold": cold * 1e3,
            "qps": B / min(warm),
            "recall_at_10": rec,
            "recall_gap_vs_device": abs(rec - rec_dev),
            "hit_rate": delta["hit_rate"],
            "pages_read": delta["pages_read"],
            "readahead_pages": delta["readahead_pages"],
            "readahead_hits": delta["readahead_hits"],
            "attr_probes": delta["attr_probes"],
            "gated_skips": delta["gated_skips"],
            "gated_skip_frac": gated_frac,
            "p50_page_us": delta["p50_page_us"],
        }
        block["modes"][name] = stats
        results.append(BenchResult(
            name=f"search/{name}@disk", us_per_call=min(warm) * 1e6 / B,
            derived={"qps": f"{stats['qps']:.0f}",
                     "hit": f"{delta['hit_rate']:.2f}",
                     "pages": f"{delta['pages_read']}",
                     "gated": f"{gated_frac:.2f}" if gated_frac is not None
                     else "-",
                     "recall@10": f"{rec:.3f}"}))

    snap = dsd.snapshot()
    model = IOModel.calibrate_from_samples(
        dsd.samples, page_bytes=dsd.layout.page_bytes)
    block["measured"] = {
        "p50_page_us": snap["p50_page_us"], "p95_page_us": snap["p95_page_us"],
        "n_samples": snap["n_samples"], "hit_rate_total": snap["hit_rate"],
        "fitted_t_page_us": model.t_page_us,
        "fitted_parallelism": model.parallelism}

    if not smoke:
        gf = block["modes"]["strict_in"]["gated_skip_frac"]
        assert gf >= DISK_GATED_SKIP_FLOOR, \
            f"bloom gate saved only {gf:.2f} of attr page reads " \
            f"(< {DISK_GATED_SKIP_FLOOR})"
        for name, stats in block["modes"].items():
            assert stats["hit_rate"] >= DISK_HIT_RATE_FLOOR, \
                f"{name}: cache hit rate {stats['hit_rate']:.2f} below floor"
            assert stats["recall_gap_vs_device"] <= DISK_RECALL_GAP_MAX, \
                f"{name}: disk recall diverged from device backend"
        qps = block["modes"]["spec_in"]["qps"]
        assert qps >= DISK_QPS_FLOOR, \
            f"disk spec_in QPS {qps:.0f} below the committed floor " \
            f"({DISK_QPS_FLOOR})"
    dsd.close()
    return block


# ---------------------------------------------------------------------------
# Sharded execution (--shards): subprocess per shard count, 4 fake devices
# ---------------------------------------------------------------------------
SHARD_COUNTS = (1, 2, 4)
SHARD_DEVICES = 4
# floors on the critical-path QPS scaling, per W=1 mode (ISSUE 10):
SHARD_SCALING_FLOORS = {2: 1.6, 4: 2.5}
SCALING_MODEL = "critical_path_single_core_host"


def _assert_results_match(a: S.SearchResult, b: S.SearchResult, tag: str):
    """Bit-identity for counters/ids; float fields to 1e-6 (the psum adds
    exact zeros, but XLA fusion order may differ across program shapes)."""
    for field in S.SearchResult._fields:
        av, bv = np.asarray(getattr(a, field)), np.asarray(getattr(b, field))
        if av.dtype.kind == "f":
            np.testing.assert_allclose(av, bv, rtol=1e-6, atol=0,
                                       err_msg=f"{tag}: {field}")
        else:
            assert np.array_equal(av, bv), f"{tag}: {field} diverged"


def _shard_worker(shards: int, smoke: bool, out_path: str) -> None:
    """One shard count, inside a subprocess with SHARD_DEVICES fake
    devices. Emits {"shards", "modes": {name: wall/critical-path stats}}."""
    from repro.core.distributed import ShardPlan, ShardedSearchRunner
    from repro.launch.mesh import make_local_mesh
    import jax

    n = N_SMOKE if smoke else N
    ds, index, _ = get_engine(n=n)
    e = index.engine
    B = ds.queries.shape[0]
    # best-of-6 in the full run: the 2-shard spec_in scaling sits ~5%
    # above its floor, and cp(1)/cp(S) come from different subprocesses,
    # so best-of-3 jitter on a shared core can eat the margin
    reps = 2 if smoke else 6
    runner = None
    if shards > 1:
        plan = ShardPlan(mesh=make_local_mesh(1, shards),
                         shard_axes=("model",))
        runner = ShardedSearchRunner(plan, e.store, e.codes, e.codebook,
                                     e.mem)

    def timed(params, qf, queries, entries, use_runner):
        best, res = np.inf, None
        for i in range(reps + 1):        # first rep is the compile pass
            t0 = time.time()
            res = S.filtered_search_pipelined(
                e.store, e.codes, e.codebook, e.mem, qf, queries, e.medoid,
                params, entries=entries,
                **({"runner": runner} if use_runner else {}))
            res.ids.block_until_ready()
            if i:
                best = min(best, time.time() - t0)
        return best, res

    block = {"shards": shards, "modes": {}}
    for name, mode, w in CONFIGS:
        params = S.SearchParams(l_search=L, k=K, beam_width=w,
                                max_hops=MAX_HOPS, mode=mode)
        _, qf, queries, entries = _mode_inputs(e, ds, mode)
        base_s, res_base = timed(params, qf, queries, entries, False)
        if shards > 1:
            wall_s, res_sh = timed(params, qf, queries, entries, True)
            _assert_results_match(res_base, res_sh, f"shards={shards}/{name}")
            # critical path: each shard's contiguous query slice re-timed
            # as a standalone single-device run; a real S-device mesh's
            # wall clock is the slowest shard (hops march in lockstep, so
            # per-slice compaction is the per-shard workload)
            bs = B // shards
            cps = []
            for s_i in range(shards):
                sl = slice(s_i * bs, (s_i + 1) * bs)
                qf_s = jax.tree_util.tree_map(lambda a: a[sl], qf)
                ent_s = entries[sl] if entries is not None else None
                cp_s, _ = timed(params, qf_s, queries[sl], ent_s, False)
                cps.append(cp_s)
            cp = max(cps)
        else:
            wall_s, cp = base_s, base_s
        block["modes"][name] = {
            "wall_ms": wall_s * 1e3, "wall_qps": B / wall_s,
            "critical_path_ms": cp * 1e3,
            "critical_path_qps": B / cp,
            "bit_identical_vs_single_device": shards > 1 or None,
        }
    with open(out_path, "w") as fh:
        json.dump(block, fh)


def run_sharded(out_path: str = OUT_PATH, smoke: bool = False) -> list:
    """Orchestrate one subprocess per shard count and merge the scaling
    block into ``out_path`` (the rest of the payload is left untouched —
    run the plain bench first for the mode stats)."""
    blocks = {}
    for s in SHARD_COUNTS:
        with tempfile.NamedTemporaryFile(suffix=".json", delete=False) as f:
            tmp = f.name
        env = dict(os.environ)
        env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                            + " --xla_force_host_platform_device_count="
                            + str(SHARD_DEVICES)).strip()
        cmd = [sys.executable, "-m", "benchmarks.bench_search",
               "--shard-worker", str(s), "--worker-out", tmp]
        if smoke:
            cmd.append("--smoke")
        out = subprocess.run(cmd, env=env, capture_output=True, text=True)
        assert out.returncode == 0, \
            f"shard worker {s} failed:\n{out.stdout}\n{out.stderr}"
        with open(tmp) as fh:
            blocks[s] = json.load(fh)
        os.unlink(tmp)

    scaling = {}
    for name, _, w in CONFIGS:
        cp1 = blocks[1]["modes"][name]["critical_path_ms"]
        scaling[name] = {
            str(s): cp1 / blocks[s]["modes"][name]["critical_path_ms"]
            for s in SHARD_COUNTS if s > 1}
    sharded = {
        "devices": SHARD_DEVICES,
        "scaling_model": SCALING_MODEL,
        "note": "fake single-core devices execute shard_map serially: "
                "wall_ms is the honest (slower) measured time; "
                "critical_path_ms models a real S-device mesh as the "
                "slowest shard's standalone slice run (docs/distributed.md)",
        "floors": {str(k): v for k, v in SHARD_SCALING_FLOORS.items()},
        "shards": {str(s): blocks[s] for s in SHARD_COUNTS},
        "qps_scaling": scaling,
    }

    results = []
    for name, _, w in CONFIGS:
        derived = {"cp1_ms": f"{blocks[1]['modes'][name]['critical_path_ms']:.0f}"}
        for s in SHARD_COUNTS[1:]:
            derived[f"x{s}"] = f"{scaling[name][str(s)]:.2f}"
        results.append(BenchResult(
            name=f"search/{name}@shards",
            us_per_call=blocks[1]["modes"][name]["critical_path_ms"] * 1e3,
            derived=derived))

    if not smoke:
        for name, mode, w in CONFIGS:
            if w != 1:
                continue   # beam4 reported, not floored
            for s, floor in SHARD_SCALING_FLOORS.items():
                got = scaling[name][str(s)]
                assert got >= floor, \
                    f"{name}: {s}-shard QPS scaling {got:.2f}x below the " \
                    f"{floor}x floor"
        try:
            with open(out_path) as fh:
                payload = json.load(fh)
        except FileNotFoundError:
            payload = {}
        payload["sharded"] = sharded
        with open(out_path, "w") as fh:
            json.dump(payload, fh, indent=2)
    return results


def run(out_path: str = OUT_PATH, smoke: bool = False,
        with_trace: bool = False,
        fault_spec: str | None = FAULT_PLAN_DEFAULT,
        store: str = "device") -> list:
    n = N_SMOKE if smoke else N
    ds, index, _ = get_engine(n=n)
    e = index.engine if hasattr(index, "engine") else index
    B = ds.queries.shape[0]

    payload = {"corpus": {"n": n, "d": e.store.dim, "r": e.store.degree,
                          "r_dense": e.store.dense_degree, "l": L, "k": K,
                          "batch": B, "selectivity": SELECTIVITY},
               "modes": {}}
    results = []
    warm_p_spec_us = 0.0
    for name, mode, w in CONFIGS:
        params = S.SearchParams(l_search=L, k=K, beam_width=w,
                                max_hops=MAX_HOPS, mode=mode)
        sels, qf, queries, entries = _mode_inputs(e, ds, mode)

        reps = 3 if not smoke else 2
        cold_p, warm_p, res_p = _time_impl(S.filtered_search_pipelined, e,
                                           qf, queries, params, entries,
                                           repeats=reps)
        cold_f, warm_f, res_f = _time_impl(S.filtered_search, e, qf,
                                           queries, params, entries,
                                           repeats=reps)
        cold_l, warm_l, _ = _time_impl(S.filtered_search_legacy, e, qf,
                                       queries, params, entries,
                                       repeats=reps)
        _, _, res_r = _time_impl(S.filtered_search_ref, e, qf, queries,
                                 params, entries, repeats=1)
        # compaction is pure re-indexing; prefetch only moves fetch issue
        # time — all three production-path results must agree bit-exactly
        _assert_bit_identical(res_p, res_f, f"{name}: pipelined vs fused")
        rec_f = _recall(ds, e, sels, res_f)
        rec_r = _recall(ds, e, sels, res_r)
        speedup = warm_l / warm_f
        if name == "spec_in":
            warm_p_spec_us = warm_p * 1e6 / B
        stats = {
            "mode": mode, "beam_width": w,
            "pipelined_ms": warm_p * 1e3, "pipelined_ms_cold": cold_p * 1e3,
            "fused_ms": warm_f * 1e3, "fused_ms_cold": cold_f * 1e3,
            "legacy_ms": warm_l * 1e3, "legacy_ms_cold": cold_l * 1e3,
            "speedup_vs_legacy": speedup,
            "speedup_pipelined_vs_fused": warm_f / warm_p,
            "speedup_pipelined_vs_pr4": (PR4_FUSED_MS[name]
                                         / (warm_p * 1e3))
            if not smoke else None,
            "qps": B / warm_p,
            "latency_ms_per_query": warm_p * 1e3 / B,
            "mean_hops": float(np.mean(np.asarray(res_p.hops))),
            "mean_io_pages": float(np.mean(np.asarray(res_p.io_pages))),
            "mean_dist_comps": float(np.mean(np.asarray(res_p.dist_comps))),
            "mean_approx_checks": float(
                np.mean(np.asarray(res_p.approx_checks))),
            "recall_at_10": rec_f, "recall_at_10_ref": rec_r,
        }
        payload["modes"][name] = stats
        results.append(BenchResult(
            name=f"search/{name}", us_per_call=warm_p * 1e6 / B,
            derived={"qps": f"{stats['qps']:.0f}",
                     "speedup": f"{speedup:.1f}x",
                     "vs_fused": f"{warm_f / warm_p:.2f}x",
                     "hops": f"{stats['mean_hops']:.0f}",
                     "recall@10": f"{rec_f:.3f}"}))

        if not smoke:
            # one-sided: fused may beat the oracle, just not trail it >1%
            assert rec_r - rec_f <= RECALL_TOL, \
                f"{name}: fused recall {rec_f:.3f} trails oracle {rec_r:.3f}"
        else:
            # smoke: correctness only — identical exploration vs the oracle
            assert np.array_equal(np.asarray(res_f.io_pages),
                                  np.asarray(res_r.io_pages)), name
            assert np.array_equal(np.asarray(res_f.explored),
                                  np.asarray(res_r.explored)), name

    if with_trace:
        payload["active_trace"] = active_trace(e, ds, smoke, warm_p_spec_us)

    if fault_spec and fault_spec.lower() != "none":
        payload["fault_plan"] = _fault_block(
            e, ds, parse_plan(fault_spec), payload["modes"], smoke, results)

    if store == "disk":
        payload["disk_tier"] = _disk_tier_block(e, ds, smoke, results)
    elif store != "device":
        raise ValueError(f"unknown store backend {store!r}")

    if not smoke:
        sp = payload["modes"]["spec_in_beam4"]["speedup_vs_legacy"]
        assert sp >= SPEC_IN_SPEEDUP_FLOOR, \
            f"fused spec_in (W=4) only {sp:.1f}x vs the pre-fused vmap path"
        pip = payload["modes"]["spec_in"]["pipelined_ms"]
        floor = PR4_FUSED_MS["spec_in"] / PIPELINE_SPEEDUP_FLOOR
        assert pip <= floor, \
            f"pipelined spec_in W=1 {pip:.0f}ms misses the " \
            f"{PIPELINE_SPEEDUP_FLOOR}x floor vs PR-4 ({floor:.0f}ms)"
        for name in ("post", "strict_in"):
            ms = payload["modes"][name]["pipelined_ms"]
            assert ms <= PR4_FUSED_MS[name] * NO_SLOWER_TOL, \
                f"{name} pipelined {ms:.0f}ms slower than PR-4 " \
                f"({PR4_FUSED_MS[name]:.0f}ms)"
        with open(out_path, "w") as fh:
            json.dump(payload, fh, indent=2)
    return results


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny end-to-end run, no perf bars / JSON output")
    ap.add_argument("--active-trace", action="store_true",
                    help="also record per-hop active counts, compaction "
                         "buckets and modeled SSD latency (spec_in W=1)")
    ap.add_argument("--fault-plan", default=FAULT_PLAN_DEFAULT,
                    help="seeded FaultPlan spec for the degraded-mode "
                         "block, e.g. 'rate=0.1,seed=7' ('none' to skip)")
    ap.add_argument("--store", default="device", choices=("device", "disk"),
                    help="'disk' additionally re-runs every config against "
                         "the slab-file backend (storage/) and emits a "
                         "disk_tier block: measured page latency, cache hit "
                         "rate, bloom-gated read savings")
    ap.add_argument("--shards", action="store_true",
                    help="run the sharded-execution scaling block "
                         "(subprocess per shard count in {1,2,4} under a "
                         "4-fake-device mesh) and merge it into the JSON")
    ap.add_argument("--shard-worker", type=int, default=0,
                    help=argparse.SUPPRESS)   # internal: one shard count
    ap.add_argument("--worker-out", default="", help=argparse.SUPPRESS)
    ap.add_argument("--out", default=OUT_PATH)
    args = ap.parse_args()
    if args.shard_worker:
        _shard_worker(args.shard_worker, args.smoke, args.worker_out)
        return
    if args.shards:
        for res in run_sharded(out_path=args.out, smoke=args.smoke):
            print(res.csv())
        return
    for res in run(out_path=args.out, smoke=args.smoke,
                   with_trace=args.active_trace,
                   fault_spec=args.fault_plan, store=args.store):
        print(res.csv())


if __name__ == "__main__":
    main()
