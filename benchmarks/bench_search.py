"""Search-latency benchmark: fused hop pipeline vs the pre-fused baseline.

Runs the batched ``filtered_search`` of every mode (post / spec_in /
strict_in) over the 12 K benchmark corpus at L=64 and times it against
``filtered_search_legacy`` — the pre-fused-pipeline implementation whose
hop loop pays pairwise dedup broadcasts, a full argsort merge, and a
per-iteration explored-buffer re-sort. Writes ``BENCH_search.json`` so
the *search*-side perf trajectory is tracked across PRs (BENCH_build.json
covers the build side).

Acceptance bars (the fused pipeline is an implementation change, not an
algorithm change):
  * warm batched spec_in latency ≥ 3× better than the legacy path in the
    pipelined-beam configuration (``spec_in_beam4``: W=4, the analogue of
    PipeANN's multiple in-flight reads; the W=1 ratio is recorded too);
  * recall@10 within 1% of the ``filtered_search_ref`` oracle per config.

``--smoke`` builds a tiny corpus and runs every mode end-to-end with no
perf bars and no JSON — the bitrot check ``scripts/test_fast.sh`` runs.
"""
from __future__ import annotations

import json
import time

import jax.numpy as jnp
import numpy as np

from benchmarks.common import BenchResult, get_engine
from repro.core import engine as eng
from repro.core import search as S
from repro.core.selectors import stack_filters

N, N_SMOKE = 12_000, 600
L, K, MAX_HOPS = 64, 10, 512
SELECTIVITY = 0.30          # mid-selectivity range filters (paper Fig. 2)
OUT_PATH = "BENCH_search.json"
# (bench name, search mode, beam width). ``spec_in_beam4`` is the
# pipelined-beam configuration — PipeANN keeps W reads in flight per
# step; its TPU-batch analogue is beam_width>1 — and carries the
# speedup floor: the legacy path's dedup broadcast is O(W·C·res_cap)
# while the fused pipeline stays near-linear in the slab, so the gap is
# widest exactly where the paper operates.
CONFIGS = (("post", "post", 1), ("spec_in", "spec_in", 1),
           ("spec_in_beam4", "spec_in", 4), ("strict_in", "strict_in", 1))
SPEC_IN_SPEEDUP_FLOOR = 3.0        # asserted on spec_in_beam4
RECALL_TOL = 0.01


def _selectors(e, n_queries: int):
    """Sliding mid-selectivity range windows (one filter per query)."""
    from repro.data.synth import make_sliding_range_selectors
    return make_sliding_range_selectors(e, SELECTIVITY, n_queries)


def _mode_inputs(e, ds, mode):
    sels = _selectors(e, ds.queries.shape[0])
    qf = stack_filters([s.plan(e.config.ql, e.config.cap).qfilter
                        for s in sels])
    queries = jnp.asarray(
        np.pad(ds.queries, ((0, 0), (0, e.store.dim - ds.queries.shape[1]))))
    entries = None
    if mode == "strict_in":
        ents = np.full((len(sels), 4), -1, np.int32)
        for j, s in enumerate(sels):
            seeds, _ = eng._strict_seed_ids(s, e.medoid, 4)
            ents[j, :seeds.size] = seeds
        entries = jnp.asarray(ents)
    return sels, qf, queries, entries


def _time_impl(impl, e, qf, queries, params, entries, repeats=3):
    """(cold_s, warm_s, result) — warm is best-of-``repeats``."""
    t0 = time.time()
    res = impl(e.store, e.codes, e.codebook, e.mem, qf, queries, e.medoid,
               params, entries=entries)
    res.ids.block_until_ready()
    cold = time.time() - t0
    warm = []
    for _ in range(repeats):
        t0 = time.time()
        res = impl(e.store, e.codes, e.codebook, e.mem, qf, queries,
                   e.medoid, params, entries=entries)
        res.ids.block_until_ready()
        warm.append(time.time() - t0)
    return cold, min(warm), res


def _recall(ds, e, sels, res, k=K):
    vectors = np.asarray(e.store.vectors)
    rl = np.asarray(e.store.rec_labels)
    rv = np.asarray(e.store.rec_values)
    rec = []
    for i, s in enumerate(sels):
        plan = s.plan(e.config.ql, e.config.cap)
        q = np.pad(ds.queries[i], (0, vectors.shape[1] - ds.queries.shape[1]))
        gt = eng.brute_force_filtered(vectors, rl, rv, plan.qfilter, q, k)
        rec.append(eng.recall_at_k(np.asarray(res.ids[i]), gt, k))
    return float(np.mean(rec))


def run(out_path: str = OUT_PATH, smoke: bool = False) -> list:
    n = N_SMOKE if smoke else N
    ds, index, _ = get_engine(n=n)
    e = index.engine if hasattr(index, "engine") else index
    B = ds.queries.shape[0]

    payload = {"corpus": {"n": n, "d": e.store.dim, "r": e.store.degree,
                          "r_dense": e.store.dense_degree, "l": L, "k": K,
                          "batch": B, "selectivity": SELECTIVITY},
               "modes": {}}
    results = []
    for name, mode, w in CONFIGS:
        params = S.SearchParams(l_search=L, k=K, beam_width=w,
                                max_hops=MAX_HOPS, mode=mode)
        sels, qf, queries, entries = _mode_inputs(e, ds, mode)

        reps = 3 if not smoke else 2
        cold_f, warm_f, res_f = _time_impl(S.filtered_search, e, qf,
                                           queries, params, entries,
                                           repeats=reps)
        cold_l, warm_l, _ = _time_impl(S.filtered_search_legacy, e, qf,
                                       queries, params, entries,
                                       repeats=reps)
        _, _, res_r = _time_impl(S.filtered_search_ref, e, qf, queries,
                                 params, entries, repeats=1)
        rec_f = _recall(ds, e, sels, res_f)
        rec_r = _recall(ds, e, sels, res_r)
        speedup = warm_l / warm_f
        stats = {
            "mode": mode, "beam_width": w,
            "fused_ms": warm_f * 1e3, "fused_ms_cold": cold_f * 1e3,
            "legacy_ms": warm_l * 1e3, "legacy_ms_cold": cold_l * 1e3,
            "speedup_vs_legacy": speedup,
            "qps": B / warm_f,
            "latency_ms_per_query": warm_f * 1e3 / B,
            "mean_hops": float(np.mean(np.asarray(res_f.hops))),
            "mean_io_pages": float(np.mean(np.asarray(res_f.io_pages))),
            "mean_dist_comps": float(np.mean(np.asarray(res_f.dist_comps))),
            "recall_at_10": rec_f, "recall_at_10_ref": rec_r,
        }
        payload["modes"][name] = stats
        results.append(BenchResult(
            name=f"search/{name}", us_per_call=warm_f * 1e6 / B,
            derived={"qps": f"{stats['qps']:.0f}",
                     "speedup": f"{speedup:.1f}x",
                     "hops": f"{stats['mean_hops']:.0f}",
                     "recall@10": f"{rec_f:.3f}"}))

        if not smoke:
            # one-sided: fused may beat the oracle, just not trail it >1%
            assert rec_r - rec_f <= RECALL_TOL, \
                f"{name}: fused recall {rec_f:.3f} trails oracle {rec_r:.3f}"
        else:
            # smoke: correctness only — identical exploration vs the oracle
            assert np.array_equal(np.asarray(res_f.io_pages),
                                  np.asarray(res_r.io_pages)), name
            assert np.array_equal(np.asarray(res_f.explored),
                                  np.asarray(res_r.explored)), name

    if not smoke:
        sp = payload["modes"]["spec_in_beam4"]["speedup_vs_legacy"]
        assert sp >= SPEC_IN_SPEEDUP_FLOOR, \
            f"fused spec_in (W=4) only {sp:.1f}x vs the pre-fused vmap path"
        with open(out_path, "w") as fh:
            json.dump(payload, fh, indent=2)
    return results


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny end-to-end run, no perf bars / JSON output")
    ap.add_argument("--out", default=OUT_PATH)
    args = ap.parse_args()
    for res in run(out_path=args.out, smoke=args.smoke):
        print(res.csv())


if __name__ == "__main__":
    main()
