"""Paper Table 3: in-memory probabilistic-filter footprint vs on-SSD
attribute index size; §5.4 false-positive exploration statistics."""
from __future__ import annotations

import numpy as np

from benchmarks.common import BenchResult, get_engine, run_policy
from repro.data.synth import make_selectors


def run() -> list:
    ds, e, _ = get_engine()
    results = []
    lm = e.label_store.memory_bytes()
    rm = e.range_store.memory_bytes()
    results.append(BenchResult(
        name="table3/label", us_per_call=0.0,
        derived={"filter_bytes": lm["bloom_bytes"],
                 "ssd_index_bytes": lm["ssd_inverted_index_bytes"],
                 "ratio": f"{lm['bloom_bytes'] / max(lm['ssd_inverted_index_bytes'], 1):.3f}"}))
    results.append(BenchResult(
        name="table3/range", us_per_call=0.0,
        derived={"filter_bytes": rm["bucket_codes_bytes"],
                 "ssd_index_bytes": rm["ssd_sorted_index_bytes"],
                 "ratio": f"{rm['bucket_codes_bytes'] / max(rm['ssd_sorted_index_bytes'], 1):.3f}"}))

    # §5.4 false-positive exploration rate during speculative in-filtering
    sels = make_selectors(ds, e, "label_or")
    r = run_policy(ds, e, sels, "speculative", l=48)
    st = r["stats"]
    in_idx = [i for i, m in enumerate(st.mechanism) if m == "in"]
    if in_idx:
        fp = st.fp_explored[in_idx].astype(float)
        ex = np.maximum(st.explored[in_idx].astype(float), 1.0)
        rates = fp / ex
        results.append(BenchResult(
            name="sec5.4/fp_exploration", us_per_call=0.0,
            derived={"mean_fp_rate": f"{rates.mean():.3f}",
                     "median_fp_rate": f"{np.median(rates):.3f}",
                     "max_fp_rate": f"{rates.max():.3f}",
                     "n_in_queries": len(in_idx)}))
    return results
