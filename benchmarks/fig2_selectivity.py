"""Paper Fig. 2: search throughput of each filtering mechanism across query
selectivities (range workload), at a fixed recall knob."""
from __future__ import annotations

import numpy as np

from benchmarks.common import (BenchResult, get_engine, modeled_qps,
                               run_policy)
from repro.core.selectors import RangeSelector


def run() -> list:
    ds, e, _ = get_engine()
    rs = e.range_store
    values = rs.field_store(0).sorted_values
    n = values.size
    results = []
    for sel_frac in (0.001, 0.01, 0.05, 0.2, 0.5):
        lo_i = int(0.25 * n)
        hi_i = min(n - 1, lo_i + max(1, int(sel_frac * n)))
        sels = [RangeSelector(rs, float(values[lo_i]), float(values[hi_i]))
                for _ in range(16)]
        for policy in ("speculative", "post", "strict_pre", "strict_in"):
            r = run_policy(ds, e, sels, policy, l=32)
            qps = modeled_qps(r["io_pages"], r["cpu_us"])
            results.append(BenchResult(
                name=f"fig2/{policy}/sel={sel_frac}",
                us_per_call=r["cpu_us"],
                derived={"qps_model": f"{qps:.0f}",
                         "recall": f"{r['recall']:.3f}",
                         "io_pages": f"{r['io_pages']:.0f}"}))
    return results
