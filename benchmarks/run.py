# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark harness: one module per paper figure/table (see DESIGN.md §1).

  fig2   — mechanism × selectivity throughput sweep (paper Fig. 2)
  fig5_6 — label workloads: throughput/latency vs baselines (Figs. 5/6)
  fig7_9 — Label/Range/Hybrid suite + strict in-filter recall gap (Figs. 7-9)
  fig10_11 — cost-model estimated vs actual I/O (Figs. 10/11)
  table3 — probabilistic-filter memory + §5.4 fp-exploration stats
  kernels — hot-loop micro-benchmarks
  build  — Vamana build throughput: batched pipeline vs numpy reference
           (writes BENCH_build.json)
  search — fused hop pipeline vs the pre-fused baseline per mode
           (writes BENCH_search.json)

Run: PYTHONPATH=src python -m benchmarks.run [--only fig2,...]
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of benchmark names")
    args = ap.parse_args()

    from benchmarks import (bench_build, bench_search, fig2_selectivity,
                            fig5_6_label, fig7_9_workloads,
                            fig10_11_cost_model, kernels_bench,
                            table3_memory)
    suites = {
        "fig2": fig2_selectivity.run,
        "fig5_6": fig5_6_label.run,
        "fig7_9": fig7_9_workloads.run,
        "fig10_11": fig10_11_cost_model.run,
        "table3": table3_memory.run,
        "kernels": kernels_bench.run,
        "build": bench_build.run,
        "search": bench_search.run,
    }
    if args.only:
        keep = set(args.only.split(","))
        suites = {k: v for k, v in suites.items() if k in keep}

    print("name,us_per_call,derived")
    ok = True
    for name, fn in suites.items():
        t0 = time.time()
        try:
            for res in fn():
                print(res.csv(), flush=True)
        except Exception:                                  # noqa: BLE001
            ok = False
            print(f"{name},ERROR,{traceback.format_exc()[-400:]!r}",
                  flush=True)
        print(f"# {name} done in {time.time() - t0:.0f}s", flush=True)
    if not ok:
        sys.exit(1)


if __name__ == '__main__':
    main()
