"""Kernel micro-benchmarks: XLA reference path timing on CPU (the Pallas
TPU kernels are validated in interpret mode; wall-clock belongs to TPU)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import BenchResult
from repro.kernels import ref


def _time(fn, *args, iters: int = 20) -> float:
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def run() -> list:
    rng = np.random.default_rng(0)
    results = []

    n, m = 100_000, 16
    codes = jnp.asarray(rng.integers(0, 256, (n, m)), dtype=jnp.uint8)
    table = jnp.asarray(rng.normal(0, 1, (m, 256)).astype(np.float32))
    f = jax.jit(ref.pq_scan_ref)
    us = _time(f, codes, table)
    results.append(BenchResult(
        name="kernel/pq_scan_ref", us_per_call=us,
        derived={"codes": f"{n}x{m}",
                 "gdist_per_s": f"{n / us:.1f}M"}))

    blooms = jnp.asarray(rng.integers(0, 2**31, n).astype(np.uint32))
    buckets = jnp.asarray(rng.integers(0, 256, n).astype(np.uint8))
    masks = jnp.asarray(rng.integers(0, 2**16, 8).astype(np.uint32))
    params = jnp.asarray(np.array([7, 8, 10, 200, 2, 1, 0, 0], np.int32))
    f = jax.jit(ref.approx_probe_ref)
    us = _time(f, blooms, buckets, masks, params)
    results.append(BenchResult(
        name="kernel/approx_probe_ref", us_per_call=us,
        derived={"n": n, "gprobe_per_s": f"{n / us:.1f}M"}))

    b, d = 4096, 128
    vecs = jnp.asarray(rng.normal(0, 1, (b, d)).astype(np.float32))
    q = jnp.asarray(rng.normal(0, 1, d).astype(np.float32))
    f = jax.jit(ref.l2_rerank_ref)
    us = _time(f, vecs, q)
    results.append(BenchResult(
        name="kernel/l2_rerank_ref", us_per_call=us,
        derived={"b": b, "d": d}))
    return results
